//! Bench target for **Table I** (paper §IV-B): baseline vs AXI4-Stream
//! data transfer networks at 1x256-bit -> 16x16-bit, FIFO depth 32.
//!
//! Regenerates the table from the resource model and benchmarks the
//! *behavioural* networks moving the same traffic, so the comparison
//! covers both cost (resources) and function (data transfer).

use medusa::eval;
use medusa::interconnect::harness::{drive_read, drive_write, gen_lines};
use medusa::interconnect::{build_read_network, build_write_network, Design};
use medusa::util::bench::Bench;

fn main() {
    println!("{}", eval::table1().to_text());

    let g = eval::table1::geometry();
    let lines = gen_lines(&g, 2_048, 0x7ab1e1);
    let mut b = Bench::new();
    for design in [Design::Baseline, Design::Axis] {
        b.run(format!("read_network/{}/2048_lines", design.name()), 2_048, "lines", || {
            let mut net = build_read_network(design, g);
            drive_read(net.as_mut(), &lines, false).0
        });
        b.run(format!("write_network/{}/2048_lines", design.name()), 2_048, "lines", || {
            let mut net = build_write_network(design, g);
            drive_write(net.as_mut(), 2_048 / g.write_ports, 0x7ab1e2, false).0
        });
    }
    b.report("table1 behavioural networks (simulated lines moved per wall-second)");

    // Cycle-efficiency comparison (the architectural claim): both reach
    // ~1 line/cycle, AXIS pays extra latency only. The two 2048-line
    // simulations are independent — run them across threads (untimed
    // section, so concurrency cannot skew a measurement).
    let designs = [Design::Baseline, Design::Axis];
    let results = medusa::util::par_map(&designs, |&design| {
        let mut net = build_read_network(design, g);
        drive_read(net.as_mut(), &lines, false).0
    });
    for (design, res) in designs.iter().zip(results) {
        println!(
            "cycle efficiency {}: {:.3} lines/cycle over {} lines",
            design.name(),
            res.lines_per_cycle(),
            res.lines_moved
        );
    }
}
