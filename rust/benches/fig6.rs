//! Bench target for **Figure 6** (paper §IV-D): the scaling sweep —
//! peak P&R frequency for baseline vs Medusa across all 11 design
//! points and four memory-interface regions, plus the system-level
//! consequence: simulated end-to-end bandwidth delivered to the
//! accelerator at each point's achievable clock.

use medusa::accel::prefetch::{partition, Region};
use medusa::config::SystemConfig;
use medusa::coordinator::System;
use medusa::eval;
use medusa::fpga::DesignPoint;
use medusa::interconnect::Design;
use medusa::types::Line;
use medusa::util::bench::Bench;
use medusa::util::par_map;

/// Simulated time (ps) to stream `total_lines` through a design point's
/// read path at its modelled fabric clock.
fn stream_time_ps(dp: &DesignPoint, total_lines: usize) -> Option<u64> {
    let cfg = SystemConfig {
        design: dp.design,
        geometry: dp.geometry,
        dotprod_units: dp.dpus,
        mem_clock_mhz: 200.0,
        fabric_clock_mhz: None, // use the P&R model
        ddr3_timing: false,
        rotator_stages: 0,
        channel_depths: Default::default(),
        seed: 1,
    };
    let mut sys = System::new(cfg).ok()?; // None = failed timing
    let n = dp.geometry.words_per_line();
    sys.controller_mut().preload(0, (0..total_lines as u64).map(|_| Line::zeroed(n)));
    let scheds = partition(&[Region { base: 0, lines: total_lines }], dp.geometry.read_ports);
    sys.lp_mut().begin_layer(&scheds, 1);
    sys.run_until_compute_done(50_000_000).ok()?;
    Some(sys.now_ps())
}

fn main() {
    println!("{}", eval::fig6().to_text());
    println!();
    print!("{}", eval::fig6::ascii_plot());
    println!();

    // System-level: delivered read bandwidth (GB/s) at the modelled clock.
    // Each design point is an independent System simulation; the 22 sims
    // run across threads and the rows print in order afterwards.
    println!("### delivered bandwidth at modelled fabric clock (2048 lines, ideal DRAM)");
    println!("{:>6} {:>9} {:>10} {:>14} {:>14}", "DSPs", "iface", "lines", "base GB/s", "medusa GB/s");
    let total_lines = 2048usize;
    let steps: Vec<usize> = (0..=10).collect();
    let gbs = |dp: &DesignPoint| -> String {
        match stream_time_ps(dp, total_lines) {
            Some(ps) => {
                let bytes = (total_lines * dp.geometry.w_line / 8) as f64;
                format!("{:.2}", bytes / (ps as f64 / 1e12) / 1e9)
            }
            None => "fail".to_string(),
        }
    };
    let rows = par_map(&steps, |&step| {
        let b = DesignPoint::fig6_step(Design::Baseline, step);
        let m = DesignPoint::fig6_step(Design::Medusa, step);
        (b.dsps(), b.geometry.w_line, gbs(&b), gbs(&m))
    });
    for (dsps, w_line, base_gbs, medusa_gbs) in rows {
        println!(
            "{:>6} {:>9} {:>10} {:>14} {:>14}",
            dsps,
            format!("{w_line}b"),
            total_lines,
            base_gbs,
            medusa_gbs
        );
    }
    println!();

    // Wall-clock cost of regenerating the figure (the P&R search itself).
    let mut bench = Bench::new();
    bench.run("fig6/full_sweep_regeneration", 22, "P&R searches", || eval::fig6::sweep());
    bench.report("fig6 regeneration");
}
