//! Rotation-unit micro-benchmarks (paper §III-B / Fig 5): combinational
//! vs pipelined barrel rotation across sizes — the Medusa datapath's
//! innermost operation in the simulator.

use medusa::hw::rotator::{rotate_left, PipelinedRotator};
use medusa::types::Word;
use medusa::util::bench::Bench;

fn main() {
    let mut b = Bench::new();
    for n in [8usize, 16, 32, 64] {
        let base: Vec<Word> = (0..n as u64).collect();
        let iters = 100_000u64;
        b.run(format!("combinational/n{n}/{iters}_rots"), iters, "rotations", || {
            let mut v = base.clone();
            let mut acc = 0u64;
            for i in 0..iters {
                rotate_left(&mut v, (i as usize) % n);
                acc = acc.wrapping_add(v[0]);
            }
            acc
        });
        let items = 10_000u64;
        b.run(format!("pipelined/n{n}/{items}_items"), items, "items", || {
            let mut r: PipelinedRotator<u64> = PipelinedRotator::new(n);
            let mut sent = 0u64;
            let mut recv = 0u64;
            let mut acc = 0u64;
            while recv < items {
                if let Some((words, _tag)) = r.tick() {
                    recv += 1;
                    acc = acc.wrapping_add(words[0]);
                }
                if sent < items && r.can_accept() {
                    r.accept(base.clone(), (sent as usize) % n, sent);
                    sent += 1;
                }
            }
            acc
        });
    }
    b.report("rotation unit micro-benchmarks");
}
