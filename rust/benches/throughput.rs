//! Simulator hot-path throughput (§Perf primary metric): simulated
//! line-transfers per wall-second through each data-transfer network,
//! across geometries — the number the performance pass optimizes.

use medusa::interconnect::harness::{drive_read, drive_write_streams, gen_lines, gen_write_streams};
use medusa::interconnect::{build_read_network, build_write_network, Design};
use medusa::types::Geometry;
use medusa::util::bench::Bench;

fn main() {
    let geoms = [
        ("128b/8p", Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 32 }),
        ("512b/32p", Geometry::paper_default()),
        ("1024b/64p", Geometry { w_line: 1024, w_acc: 16, read_ports: 64, write_ports: 64, max_burst: 32 }),
    ];
    let total = 8_192usize;
    let mut b = Bench::new();
    for (gname, g) in geoms {
        let lines = gen_lines(&g, total, 42);
        let streams = gen_write_streams(&g, total / g.write_ports, 43);
        for design in [Design::Baseline, Design::Medusa] {
            b.run(format!("read/{}/{gname}", design.name()), total as u64, "lines", || {
                let mut net = build_read_network(design, g);
                drive_read(net.as_mut(), &lines, false).0
            });
            b.run(format!("write/{}/{gname}", design.name()), total as u64, "lines", || {
                let mut net = build_write_network(design, g);
                drive_write_streams(net.as_mut(), &streams, false).0
            });
        }
    }
    let report = b.report("interconnect simulator throughput (simulated lines per wall-second)");
    // The §Perf target: >= 1M simulated line-transfers/s on the paper
    // geometry read path.
    let paper_read = b
        .results()
        .iter()
        .find(|m| m.name == "read/medusa/512b/32p")
        .expect("paper-point measurement");
    println!(
        "\n§Perf gate: medusa read @512b/32p = {:.3e} lines/s (target >= 1e6)",
        paper_read.throughput()
    );
    let _ = report;
}
