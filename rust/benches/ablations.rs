//! Ablation studies for the design choices DESIGN.md calls out:
//!
//! 1. **Rotator pipelining** (paper §III-B: "single cycle or pipelined,
//!    depending on the frequency requirements") — latency cost vs the
//!    frequency headroom the timing model credits.
//! 2. **Burst provisioning** (§III-C: buffers sized `MaxBurst x N`) —
//!    BRAM cost vs sustained bandwidth under bursty traffic.
//! 3. **Buffer technology** (§IV-C): Medusa-in-BRAM vs the hypothetical
//!    baseline-in-BRAM (the 960-BRAM trade-off the paper rejects).
//! 4. **Arbiter policy**: round-robin vs read-priority on a mixed
//!    read/write inference workload.

use medusa::accel::dnn::Network;
use medusa::accel::quant::Fixed16;
use medusa::config::SystemConfig;
use medusa::coordinator::{ComputeBackend, InferenceDriver};
use medusa::fpga::resources::{self, bram18_for};
use medusa::fpga::timing::TimingModel;
use medusa::fpga::{DesignPoint, Device};
use medusa::interconnect::harness::{drive_read, gen_lines};
use medusa::interconnect::medusa::{MedusaReadNetwork, MedusaTuning};
use medusa::interconnect::{Design, ReadNetwork};
use medusa::types::Geometry;
use medusa::util::par_map;
use medusa::util::Prng;

fn main() {
    ablation_rotator_pipelining();
    ablation_burst_provisioning();
    ablation_buffer_technology();
    ablation_ddr3_vs_ideal();
}

/// 1. Rotator pipelining: stage count vs added latency (measured) and
///    achievable frequency (modelled — the pipelined path is what the
///    calibrated timing model assumes; a combinational 32-wide rotator
///    adds log2(N) LUT levels to the critical path).
fn ablation_rotator_pipelining() {
    println!("### ablation 1: rotator pipelining (512b/32p)");
    println!("{:>7} {:>12} {:>14} {:>12}", "stages", "first-word", "lines/cyc", "est. MHz");
    let g = Geometry::paper_default();
    let model = TimingModel::calibrated();
    let dev = Device::virtex7_690t();
    let dp = DesignPoint { design: Design::Medusa, geometry: g, dpus: 64 };
    let piped_mhz = model.peak_frequency_mhz(&dp, &dev);
    // The four stage counts are independent simulations: run them across
    // threads, print rows in order.
    let stage_counts = [0usize, 1, 3, 5];
    let rows = par_map(&stage_counts, |&stages| {
        let mut net = MedusaReadNetwork::with_tuning(g, MedusaTuning { rotator_stages: stages });
        // First-word latency: one line to port 0.
        let mut stats = medusa::sim::Stats::new();
        let line = gen_lines(&g, 1, 9).remove(0);
        net.mem_deliver(line);
        let mut lat = 0u64;
        for c in 0.. {
            net.tick(c, &mut stats);
            if net.port_word_available(0) {
                lat = c + 1;
                break;
            }
            assert!(c < 500);
        }
        // Sustained throughput unaffected by pipelining.
        let mut net2 = MedusaReadNetwork::with_tuning(g, MedusaTuning { rotator_stages: stages });
        let lines = gen_lines(&g, 1024, 10);
        let (res, _) = drive_read(&mut net2, &lines, false);
        // Frequency estimate: combinational rotation adds log2(N) LUT
        // levels (~0.45ns each) to the pipelined critical path.
        let extra_ns = if stages == 0 { 5.0 * 0.45 } else { (5 - stages.min(5)) as f64 * 0.45 };
        let mhz = (1000.0 / (1000.0 / piped_mhz as f64 + extra_ns)) as u32;
        (stages, lat, res.lines_per_cycle(), mhz / 25 * 25)
    });
    for (stages, lat, lpc, mhz) in rows {
        println!("{stages:>7} {lat:>12} {lpc:>14.3} {mhz:>12}");
    }
    println!("-> pipelining trades +stages cycles of constant latency for ~60% higher clock\n");
}

/// 2. Burst provisioning: buffer BRAM cost vs sustained bandwidth when a
///    port must absorb bursts of the provisioned size.
fn ablation_burst_provisioning() {
    println!("### ablation 2: burst provisioning (512b/32p, bursty single-port traffic)");
    println!("{:>9} {:>12} {:>12} {:>14}", "max_burst", "medusa BRAM", "base LUTRAM", "lines/cyc");
    let bursts = [4usize, 8, 16, 32, 64];
    let rows = par_map(&bursts, |&burst| {
        let g = Geometry { max_burst: burst, ..Geometry::paper_default() };
        let m = resources::medusa_read(&g).bram18 + resources::medusa_write(&g).bram18;
        let b_lut = resources::baseline_read(&g).lut + resources::baseline_write(&g).lut;
        // Bursty traffic: each port receives its lines in back-to-back
        // bursts of `burst` lines.
        let mut lines = Vec::new();
        let mut prng = Prng::new(5);
        for rep in 0..8 {
            for p in 0..g.read_ports {
                for i in 0..burst {
                    let mut l = gen_lines(&g, 1, prng.next_u64()).remove(0);
                    l.port = p;
                    let _ = (rep, i);
                    lines.push(l);
                }
            }
        }
        let mut net = medusa::interconnect::build_read_network(Design::Medusa, g);
        let (res, _) = drive_read(net.as_mut(), &lines, false);
        (burst, m, b_lut, res.lines_per_cycle())
    });
    for (burst, m, b_lut, lpc) in rows {
        println!("{burst:>9} {m:>12} {b_lut:>12} {lpc:>14.3}");
    }
    println!("-> bandwidth holds at every provisioning; BRAM cost scales with MaxBurst\n");
}

/// 3. Buffer technology cross-over (§IV-C's 960-BRAM argument).
fn ablation_buffer_technology() {
    println!("### ablation 3: buffer technology at the Table II point");
    let g = Geometry::paper_default();
    let medusa_brams = resources::medusa_read(&g).bram18 + resources::medusa_write(&g).bram18;
    let baseline_in_bram = bram18_for(g.w_line, g.max_burst) * 64;
    let baseline_lutram = resources::baseline_read(&g).lut + resources::baseline_write(&g).lut;
    println!("  medusa deep-narrow banks:        {medusa_brams} BRAM-18K");
    println!("  baseline FIFOs moved to BRAM:    {baseline_in_bram} BRAM-18K (paper: 960)");
    println!("  baseline FIFOs in LUTRAM:        {baseline_lutram} LUTs (the paper's choice)");
    println!(
        "-> shallow+wide FIFOs waste {}x more BRAM than Medusa's deep banks\n",
        baseline_in_bram / medusa_brams
    );
}

/// 4. DDR3 timing vs ideal memory on the end-to-end workload (also an
///    arbiter-policy sanity check — both policies must verify).
fn ablation_ddr3_vs_ideal() {
    println!("### ablation 4: DDR3 timing vs ideal memory (tiny-VGG, medusa @ 225MHz)");
    let net = Network::tiny_vgg();
    let input: Vec<Fixed16> = {
        let mut p = Prng::new(0xab1a);
        (0..net.layers[0].ifmap_words())
            .map(|_| Fixed16::from_f32((p.f64() as f32) - 0.5))
            .collect()
    };
    // The two memory models are independent full-inference simulations
    // (each System is Send); run them on two threads.
    let modes = [false, true];
    let reports = par_map(&modes, |&ddr3| {
        let cfg = SystemConfig {
            design: Design::Medusa,
            ddr3_timing: ddr3,
            fabric_clock_mhz: Some(225.0),
            ..SystemConfig::paper_default()
        };
        let mut drv = InferenceDriver::new(cfg, ComputeBackend::Golden).unwrap();
        let (rep, _) = drv.run(&net, &input).unwrap();
        rep
    });
    for (ddr3, rep) in modes.iter().zip(reports) {
        println!(
            "  {:<6} {:>9} fabric cycles, {:>7.3} ms, {:>5.2} GB/s effective, verified={}",
            if *ddr3 { "ddr3" } else { "ideal" },
            rep.total_cycles(),
            rep.total_time_ms(),
            rep.effective_bandwidth_gbs(512),
            rep.all_verified()
        );
    }
    println!("-> row-miss/latency effects cost ~30-40% of cycles on this workload\n");
}
