//! Bench target for **Table II** (paper §IV-C): Medusa vs baseline at the
//! representative design point — resource table, headline factors, and
//! the behavioural networks' simulated throughput at the same geometry.

use medusa::eval;
use medusa::interconnect::harness::{drive_read, drive_write, gen_lines};
use medusa::interconnect::{build_read_network, build_write_network, Design};
use medusa::util::bench::Bench;

fn main() {
    println!("{}", eval::table2().to_text());
    let h = eval::table2::headline();
    println!(
        "headline: {:.2}x LUT / {:.2}x FF network savings (paper 4.73x / 6.02x); \
         networks are {:.1}%/{:.1}% of baseline total LUT/FF (paper 22.6%/22.7%), \
         {:.1}%/{:.1}% with Medusa (paper 6.1%/4.7%)\n",
        h.lut_factor,
        h.ff_factor,
        h.baseline_net_lut_share,
        h.baseline_net_ff_share,
        h.medusa_net_lut_share,
        h.medusa_net_ff_share
    );

    let g = eval::table2::geometry();
    let lines = gen_lines(&g, 4_096, 0x7ab1e2);
    let mut b = Bench::new();
    for design in [Design::Baseline, Design::Medusa] {
        b.run(format!("read_network/{}/4096_lines", design.name()), 4_096, "lines", || {
            let mut net = build_read_network(design, g);
            drive_read(net.as_mut(), &lines, false).0
        });
        b.run(format!("write_network/{}/4096_lines", design.name()), 4_096, "lines", || {
            let mut net = build_write_network(design, g);
            drive_write(net.as_mut(), 4_096 / g.write_ports, 1, false).0
        });
    }
    b.report("table2 behavioural networks at 512b/32+32 ports");

    // Independent 4096-line read+write simulations per design: run the
    // four across threads (untimed section).
    let designs = [Design::Baseline, Design::Medusa];
    let results = medusa::util::par_map(&designs, |&design| {
        let mut rd = build_read_network(design, g);
        let (r, _) = drive_read(rd.as_mut(), &lines, false);
        let mut wr = build_write_network(design, g);
        let (w, _) = drive_write(wr.as_mut(), 4_096 / g.write_ports, 1, false);
        (r, w)
    });
    for (design, (r, w)) in designs.iter().zip(results) {
        println!(
            "cycle efficiency {}: read {:.3} lines/cycle, write {:.3} lines/cycle \
             (both designs must sustain ~1.0 — §III-A)",
            design.name(),
            r.lines_per_cycle(),
            w.lines_per_cycle()
        );
    }
}
