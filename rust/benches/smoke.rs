//! Smoke benchmark: a short fig6 sweep plus the simulation-core
//! throughput number (simulated fabric cycles per wall-second on the
//! paper-default geometry), written to `BENCH_PR1.json`, the
//! scenario-engine numbers (per-scenario wall time, capture overhead,
//! replay speedup) written to `BENCH_PR3.json`, and the design-space
//! explorer numbers (smoke-grid sweep seq vs parallel, cold vs warm
//! cache) written to `BENCH_PR4.json` — the perf trajectory future PRs
//! compare against.
//!
//! Run with `cargo bench --bench smoke` (set `MEDUSA_BENCH_SAMPLES=1`
//! for the quickest run). The fig6 sweep runs twice — sequentially
//! (`MEDUSA_THREADS=1`) and with the default thread count — and asserts
//! the results are bit-identical, which is the correctness contract of
//! the parallel sweep path; the scenario matrix asserts the same via
//! explicit worker counts.

use medusa::accel::prefetch::{partition, Region};
use medusa::config::SystemConfig;
use medusa::coordinator::System;
use medusa::eval::fig6;
use medusa::interconnect::Design;
use medusa::types::Line;
use medusa::util::bench::Bench;
use std::path::Path;
use std::time::Instant;

/// Build the paper-default system with a pinned fabric clock and stream
/// `lines` read lines through it; returns (fabric cycles, wall seconds).
fn sim_throughput(design: Design, lines: usize) -> (u64, f64) {
    let cfg = SystemConfig {
        design,
        fabric_clock_mhz: Some(225.0),
        ddr3_timing: false,
        ..SystemConfig::paper_default()
    };
    let mut sys = System::new(cfg).unwrap();
    let n = sys.cfg.geometry.words_per_line();
    sys.controller_mut().preload(0, (0..lines as u64).map(|_| Line::zeroed(n)));
    let scheds = partition(&[Region { base: 0, lines }], sys.cfg.geometry.read_ports);
    sys.lp_mut().begin_layer(&scheds, 1);
    let t0 = Instant::now();
    sys.run_until_compute_done(200_000_000).unwrap();
    (sys.fabric_cycles(), t0.elapsed().as_secs_f64())
}

fn json_f(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.6}")
    } else {
        "null".to_string()
    }
}

fn main() {
    let mut b = Bench::new();

    // --- 1. fig6 sweep: sequential vs parallel, identical results.
    std::env::set_var("MEDUSA_THREADS", "1");
    let t0 = Instant::now();
    let seq = fig6::sweep();
    let seq_secs = t0.elapsed().as_secs_f64();
    std::env::remove_var("MEDUSA_THREADS");
    let t0 = Instant::now();
    let par = fig6::sweep();
    let par_secs = t0.elapsed().as_secs_f64();
    let identical = seq.len() == par.len()
        && seq
            .iter()
            .zip(par.iter())
            .all(|(a, b)| a.baseline_mhz == b.baseline_mhz && a.medusa_mhz == b.medusa_mhz);
    assert!(identical, "parallel fig6 sweep diverged from sequential run");
    let sweep_speedup = seq_secs / par_secs.max(1e-12);
    println!(
        "fig6 sweep: sequential {seq_secs:.4}s, parallel {par_secs:.4}s ({sweep_speedup:.2}x), results identical"
    );
    b.run("fig6/sweep_parallel", seq.len() as u64, "points", fig6::sweep);

    // --- 2. Simulation-core throughput: simulated fabric cycles per
    // wall-clock second at the paper-default geometry.
    let lines = 4096usize;
    let mut core = Vec::new();
    for design in [Design::Medusa, Design::Baseline] {
        let (cycles, secs) = sim_throughput(design, lines);
        let cps = cycles as f64 / secs.max(1e-12);
        println!(
            "core throughput {}: {} fabric cycles in {:.4}s = {:.3e} cycles/s",
            design.name(),
            cycles,
            secs,
            cps
        );
        core.push((design.name(), cycles, secs, cps));
        b.run(format!("system/{}/{}_lines", design.name(), lines), lines as u64, "lines", || {
            sim_throughput(design, lines).0
        });
    }
    b.report("smoke: simulation core + fig6 sweep");

    // --- 3. Persist the trajectory point. Both JSON outputs land next
    // to ROADMAP.md (repo root when run from rust/, cwd otherwise).
    let json_dir = if Path::new("../ROADMAP.md").exists() { ".." } else { "." };
    let out_path = format!("{json_dir}/BENCH_PR1.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"smoke_pr1\",\n");
    j.push_str(&format!("  \"threads_parallel\": {},\n", medusa::util::parallel::max_threads()));
    j.push_str(&format!(
        "  \"fig6_sweep\": {{\"points\": {}, \"sequential_s\": {}, \"parallel_s\": {}, \"speedup\": {}, \"results_identical\": {}}},\n",
        seq.len(),
        json_f(seq_secs),
        json_f(par_secs),
        json_f(sweep_speedup),
        identical
    ));
    j.push_str("  \"core_throughput\": [\n");
    for (i, (name, cycles, secs, cps)) in core.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"design\": \"{name}\", \"lines\": {lines}, \"fabric_cycles\": {cycles}, \"wall_s\": {}, \"sim_cycles_per_s\": {}}}{}\n",
            json_f(*secs),
            json_f(*cps),
            if i + 1 < core.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    std::fs::write(&out_path, &j).expect("writing BENCH_PR1.json");
    println!("wrote {out_path}");

    // --- 4. PR 3: scenario engine + trace capture/replay numbers.
    use medusa::run::RunOptions;
    let t0 = Instant::now();
    let seq = RunOptions::new().threads(1).sweep().expect("sequential scenario matrix");
    let seq_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let par = RunOptions::new()
        .threads(medusa::util::parallel::max_threads())
        .sweep()
        .expect("parallel scenario matrix");
    let par_secs = t0.elapsed().as_secs_f64();
    let identical = seq.len() == par.len()
        && seq.iter().zip(par.iter()).all(|(a, b)| a.fingerprint == b.fingerprint);
    assert!(identical, "parallel scenario matrix diverged from sequential run");
    println!(
        "scenario matrix: sequential {seq_secs:.4}s, parallel {par_secs:.4}s ({:.2}x), results identical",
        seq_secs / par_secs.max(1e-12)
    );
    // Capture vs replay on the heaviest builtin: replay skips workload
    // generation + golden math — the trace-driven scaling surface.
    let sc = medusa::workload::Scenario::builtin("single-tiny-vgg").unwrap();
    let t0 = Instant::now();
    let (_, trace) = medusa::workload::run_scenario_captured(&sc).expect("capture");
    let capture_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let replayed = medusa::workload::replay(&trace).expect("replay");
    let replay_secs = t0.elapsed().as_secs_f64();
    assert_eq!(replayed.fabric_cycles, trace.expect.fabric_cycles, "replay cycle drift");
    let replay_speedup = capture_secs / replay_secs.max(1e-12);
    println!(
        "trace replay: capture {capture_secs:.4}s, replay {replay_secs:.4}s ({replay_speedup:.2}x workload-skip speedup)"
    );
    let pr3_path = format!("{json_dir}/BENCH_PR3.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"smoke_pr3\",\n");
    j.push_str(&format!("  \"threads_parallel\": {},\n", medusa::util::parallel::max_threads()));
    j.push_str(&format!(
        "  \"scenario_matrix\": {{\"points\": {}, \"sequential_s\": {}, \"parallel_s\": {}, \"speedup\": {}, \"results_identical\": {}}},\n",
        seq.len(),
        json_f(seq_secs),
        json_f(par_secs),
        json_f(seq_secs / par_secs.max(1e-12)),
        identical
    ));
    j.push_str("  \"scenarios\": [\n");
    for (i, p) in seq.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"scenario\": \"{}\", \"design\": \"{}\", \"fabric_cycles\": {}, \"lines_moved\": {}, \"verified\": {}}}{}\n",
            p.scenario,
            p.design.name(),
            p.fabric_cycles,
            p.lines_moved,
            p.verified,
            if i + 1 < seq.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n");
    j.push_str(&format!(
        "  \"trace_replay\": {{\"scenario\": \"single-tiny-vgg\", \"capture_s\": {}, \"replay_s\": {}, \"workload_skip_speedup\": {}, \"fabric_cycles\": {}}}\n",
        json_f(capture_secs),
        json_f(replay_secs),
        json_f(replay_speedup),
        replayed.fabric_cycles
    ));
    j.push_str("}\n");
    std::fs::write(&pr3_path, &j).expect("writing BENCH_PR3.json");
    println!("wrote {pr3_path}");

    // --- 5. PR 4: design-space explorer on the smoke grid — sequential
    // vs parallel (bit-identical), then cold vs warm cache (also
    // bit-identical; the warm run must be pure cache reads).
    use medusa::explore::{run_search, DesignSpace, ExploreCache, Strategy};
    let space = DesignSpace::smoke();
    let t0 = Instant::now();
    let seq = run_search(&space, &Strategy::Grid, 1, 1, None).expect("sequential explore");
    let seq_secs = t0.elapsed().as_secs_f64();
    let t0 = Instant::now();
    let par = run_search(
        &space,
        &Strategy::Grid,
        1,
        medusa::util::parallel::max_threads(),
        None,
    )
    .expect("parallel explore");
    let par_secs = t0.elapsed().as_secs_f64();
    let identical = seq.evaluated == par.evaluated && seq.frontier.len() == par.frontier.len();
    assert!(identical, "parallel explore sweep diverged from sequential run");
    println!(
        "explore smoke grid: sequential {seq_secs:.4}s, parallel {par_secs:.4}s ({:.2}x), results identical",
        seq_secs / par_secs.max(1e-12)
    );
    let cache_path = std::env::temp_dir().join(format!("medusa-bench-explore-{}.cache", std::process::id()));
    let _ = std::fs::remove_file(&cache_path);
    let mut cache = ExploreCache::open(&cache_path);
    let t0 = Instant::now();
    let cold = run_search(&space, &Strategy::Grid, 1, medusa::util::parallel::max_threads(), Some(&mut cache))
        .expect("cold explore");
    let cold_secs = t0.elapsed().as_secs_f64();
    let mut cache = ExploreCache::open(&cache_path);
    let t0 = Instant::now();
    let warm = run_search(&space, &Strategy::Grid, 1, medusa::util::parallel::max_threads(), Some(&mut cache))
        .expect("warm explore");
    let warm_secs = t0.elapsed().as_secs_f64();
    assert_eq!(cold.evaluated, warm.evaluated, "warm cache diverged from cold run");
    assert_eq!(warm.cache_hits, warm.evaluated.len(), "warm run must be pure cache hits");
    let _ = std::fs::remove_file(&cache_path);
    println!(
        "explore cache: cold {cold_secs:.4}s, warm {warm_secs:.4}s ({:.2}x incremental speedup)",
        cold_secs / warm_secs.max(1e-12)
    );
    let extras = [
        ("smoke_sequential_s", json_f(seq_secs)),
        ("smoke_parallel_s", json_f(par_secs)),
        ("smoke_parallel_speedup", json_f(seq_secs / par_secs.max(1e-12))),
        ("results_identical", identical.to_string()),
        ("cache_cold_s", json_f(cold_secs)),
        ("cache_warm_s", json_f(warm_secs)),
        ("cache_speedup", json_f(cold_secs / warm_secs.max(1e-12))),
    ];
    let pr4_path = format!("{json_dir}/BENCH_PR4.json");
    std::fs::write(
        &pr4_path,
        medusa::eval::explore::bench_json(&seq, &space, "grid-smoke", &extras),
    )
    .expect("writing BENCH_PR4.json");
    println!("wrote {pr4_path}");

    // --- 6. PR 5: the stats-exact fast backend — full-vs-elided and
    // stepwise-vs-leap wall-time ratios on a full scenario run and on
    // the explorer smoke grid. (The fig6 sweep itself is a pure P&R
    // model sweep with no simulated payload, so the explorer grid —
    // which runs a zoo probe through every feasible fig6-style point —
    // is its simulation-bearing analogue.) Every variant must land on
    // identical cycle counts; only the wall clock may move.
    use medusa::config::{EdgeMode, PayloadMode, SimBackend};
    let scenario_with = |sim: SimBackend| -> (f64, u64) {
        let mut sc = medusa::workload::Scenario::builtin("single-tiny-vgg").unwrap();
        sc.cfg.sim = sim;
        let t0 = Instant::now();
        let out = medusa::workload::run_scenario(&sc).expect("scenario run");
        (t0.elapsed().as_secs_f64(), out.fabric_cycles)
    };
    let (sc_full_s, sc_full_cycles) = scenario_with(SimBackend::full());
    let (sc_elided_s, sc_elided_cycles) =
        scenario_with(SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise });
    let (sc_leap_s, sc_leap_cycles) =
        scenario_with(SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap });
    let (sc_fast_s, sc_fast_cycles) = scenario_with(SimBackend::fast());
    assert_eq!(sc_full_cycles, sc_elided_cycles, "elision changed cycles");
    assert_eq!(sc_full_cycles, sc_leap_cycles, "leaping changed cycles");
    assert_eq!(sc_full_cycles, sc_fast_cycles, "fast backend changed cycles");
    println!(
        "fast backend (single-tiny-vgg): full {sc_full_s:.4}s, elided {sc_elided_s:.4}s \
         ({:.2}x), leap {sc_leap_s:.4}s ({:.2}x), fast {sc_fast_s:.4}s ({:.2}x) — cycles identical",
        sc_full_s / sc_elided_s.max(1e-12),
        sc_full_s / sc_leap_s.max(1e-12),
        sc_full_s / sc_fast_s.max(1e-12),
    );
    let explore_with = |sim: SimBackend| {
        let t0 = Instant::now();
        let r = RunOptions::new()
            .backend(sim)
            .run_search(&space, &Strategy::Grid, 1, None)
            .expect("explore");
        (t0.elapsed().as_secs_f64(), r)
    };
    let (ex_full_s, ex_full) = explore_with(SimBackend::full());
    let (ex_fast_s, ex_fast) = explore_with(SimBackend::fast());
    let ex_identical = ex_full.evaluated == ex_fast.evaluated;
    assert!(ex_identical, "fast-backend explore metrics diverged from full backend");
    let ex_full_n = ex_full.evaluated.len();
    println!(
        "fast backend (explore smoke grid, {ex_full_n} points): full {ex_full_s:.4}s, \
         fast {ex_fast_s:.4}s ({:.2}x), results identical",
        ex_full_s / ex_fast_s.max(1e-12)
    );
    let pr5_path = format!("{json_dir}/BENCH_PR5.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"fast_backend_pr5\",\n");
    j.push_str(&format!("  \"threads_parallel\": {},\n", medusa::util::parallel::max_threads()));
    j.push_str(&format!(
        "  \"scenario\": {{\"name\": \"single-tiny-vgg\", \"fabric_cycles\": {sc_full_cycles}, \
         \"full_s\": {}, \"elided_s\": {}, \"leap_s\": {}, \"fast_s\": {}, \
         \"elided_speedup\": {}, \"leap_speedup\": {}, \"fast_speedup\": {}, \
         \"cycles_identical\": true}},\n",
        json_f(sc_full_s),
        json_f(sc_elided_s),
        json_f(sc_leap_s),
        json_f(sc_fast_s),
        json_f(sc_full_s / sc_elided_s.max(1e-12)),
        json_f(sc_full_s / sc_leap_s.max(1e-12)),
        json_f(sc_full_s / sc_fast_s.max(1e-12)),
    ));
    j.push_str(&format!(
        "  \"explore_smoke\": {{\"points\": {ex_full_n}, \"full_s\": {}, \"fast_s\": {}, \
         \"fast_speedup\": {}, \"results_identical\": {ex_identical}}}\n",
        json_f(ex_full_s),
        json_f(ex_fast_s),
        json_f(ex_full_s / ex_fast_s.max(1e-12)),
    ));
    j.push_str("}\n");
    std::fs::write(&pr5_path, &j).expect("writing BENCH_PR5.json");
    println!("wrote {pr5_path}");

    // --- 7. PR 6: fault-injection overhead — the standard stall+corrupt
    // campaign on a full scenario run, full vs fast backend. Faults are
    // pre-scheduled, so the faulted run must stay cycle-identical across
    // backends; the interesting numbers are the wall-time overhead of
    // injection and how much leaping the fault windows forfeit.
    use medusa::fault::FaultSpec;
    let faulted_with = |sim: SimBackend| -> (f64, u64, u64) {
        let mut sc = medusa::workload::Scenario::builtin("single-tiny-vgg").unwrap();
        sc.faults = FaultSpec::parse_cli("dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3")
            .expect("builtin fault campaign");
        sc.cfg.sim = sim;
        let t0 = Instant::now();
        let out = medusa::workload::run_scenario(&sc).expect("faulted scenario run");
        let injected = out.stats.get("fault.dram_refresh_stall_cycles")
            + out.stats.get("fault.cdc_stall_cycles")
            + out.stats.get("fault.lp_slowdown_cycles")
            + out.stats.get("fault.corrupt_injected");
        (t0.elapsed().as_secs_f64(), out.fabric_cycles, injected)
    };
    let (flt_full_s, flt_full_cycles, flt_full_events) = faulted_with(SimBackend::full());
    let (flt_fast_s, flt_fast_cycles, flt_fast_events) = faulted_with(SimBackend::fast());
    assert_eq!(flt_full_cycles, flt_fast_cycles, "faulted run cycles diverged across backends");
    assert_eq!(flt_full_events, flt_fast_events, "faulted run events diverged across backends");
    assert!(flt_full_events > 0, "standard campaign injected no faults");
    let fault_overhead = flt_full_s / sc_full_s.max(1e-12);
    println!(
        "fault campaign (single-tiny-vgg): full {flt_full_s:.4}s ({fault_overhead:.2}x vs clean), \
         fast {flt_fast_s:.4}s ({:.2}x vs clean fast), {flt_full_events} fault events, \
         cycles identical across backends",
        flt_fast_s / sc_fast_s.max(1e-12)
    );
    let pr6_path = format!("{json_dir}/BENCH_PR6.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"fault_injection_pr6\",\n");
    j.push_str(
        "  \"campaign\": \"dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3\",\n",
    );
    j.push_str(&format!(
        "  \"faulted_scenario\": {{\"name\": \"single-tiny-vgg\", \"fabric_cycles\": {flt_full_cycles}, \
         \"fault_events\": {flt_full_events}, \"full_s\": {}, \"fast_s\": {}, \
         \"clean_full_s\": {}, \"clean_fast_s\": {}, \
         \"fault_overhead_full\": {}, \"fault_overhead_fast\": {}, \
         \"cycles_identical\": true}}\n",
        json_f(flt_full_s),
        json_f(flt_fast_s),
        json_f(sc_full_s),
        json_f(sc_fast_s),
        json_f(fault_overhead),
        json_f(flt_fast_s / sc_fast_s.max(1e-12)),
    ));
    j.push_str("}\n");
    std::fs::write(&pr6_path, &j).expect("writing BENCH_PR6.json");
    println!("wrote {pr6_path}");

    // --- 8. PR 7: the serving layer — open-loop arrivals + dynamic
    // batching on the serving-poisson builtin, across all four backend
    // combinations. Latency percentiles and the outcome fingerprint
    // must be bit-identical everywhere (the serving-conformance
    // contract); the wall clock shows what leaping the idle
    // inter-arrival gaps buys a steady-state serving run.
    let serve_with = |sim: SimBackend| -> (f64, u64, u64, u64) {
        let sc = medusa::workload::Scenario::builtin("serving-poisson").unwrap();
        let t0 = Instant::now();
        let out = RunOptions::new().backend(sim).run(&sc).expect("serving run");
        let rep = out.serving.as_ref().expect("serving report");
        let worst = rep.tenants.iter().map(|t| t.p99_cycles).max().unwrap_or(0);
        (t0.elapsed().as_secs_f64(), out.fabric_cycles, worst, out.fingerprint())
    };
    let (sv_full_s, sv_cycles, sv_p99, sv_fp) = serve_with(SimBackend::full());
    let (sv_elided_s, c2, p2, f2) =
        serve_with(SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise });
    let (sv_leap_s, c3, p3, f3) =
        serve_with(SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap });
    let (sv_fast_s, c4, p4, f4) = serve_with(SimBackend::fast());
    assert_eq!((sv_cycles, sv_p99), (c2, p2), "elision changed serving results");
    // Leap preserves payload, so the FULL fingerprint must match; the
    // elided variants agree with each other (payload-free fingerprint).
    assert_eq!((sv_cycles, sv_p99, sv_fp), (c3, p3, f3), "leaping changed serving results");
    assert_eq!((sv_cycles, sv_p99, f2), (c4, p4, f4), "fast backend changed serving results");
    println!(
        "serving (serving-poisson): full {sv_full_s:.4}s, elided {sv_elided_s:.4}s ({:.2}x), \
         leap {sv_leap_s:.4}s ({:.2}x), fast {sv_fast_s:.4}s ({:.2}x) — p99 {sv_p99} cycles, \
         results identical",
        sv_full_s / sv_elided_s.max(1e-12),
        sv_full_s / sv_leap_s.max(1e-12),
        sv_full_s / sv_fast_s.max(1e-12),
    );
    let pr7_path = format!("{json_dir}/BENCH_PR7.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"serving_pr7\",\n");
    j.push_str(&format!(
        "  \"serving_scenario\": {{\"name\": \"serving-poisson\", \"fabric_cycles\": {sv_cycles}, \
         \"p99_cycles\": {sv_p99}, \"full_s\": {}, \"elided_s\": {}, \"leap_s\": {}, \
         \"fast_s\": {}, \"elided_speedup\": {}, \"leap_speedup\": {}, \"fast_speedup\": {}, \
         \"results_identical\": true}}\n",
        json_f(sv_full_s),
        json_f(sv_elided_s),
        json_f(sv_leap_s),
        json_f(sv_fast_s),
        json_f(sv_full_s / sv_elided_s.max(1e-12)),
        json_f(sv_full_s / sv_leap_s.max(1e-12)),
        json_f(sv_full_s / sv_fast_s.max(1e-12)),
    ));
    j.push_str("}\n");
    std::fs::write(&pr7_path, &j).expect("writing BENCH_PR7.json");
    println!("wrote {pr7_path}");

    // --- 9. PR 8: the hierarchical family — a third (trunk) clock
    // domain under every backend combination. Cycles and trunk-crossing
    // counters must be bit-identical everywhere (the
    // hierarchical-conformance contract); the wall clock shows what the
    // N-domain leap costs on a three-domain system, and the cycle
    // ratio vs flat Medusa shows what the trunk serialization costs.
    use medusa::interconnect::hierarchical::HierConfig;
    let hier = Design::Hierarchical(HierConfig {
        levels: 2,
        cluster_ports: 4,
        bypass_ports: 0,
        trunk_mhz: 300,
    });
    let hier_with = |sim: SimBackend| -> (f64, u64, u64) {
        let mut sc = medusa::workload::Scenario::builtin("single-tiny-vgg").unwrap();
        sc.cfg.design = hier;
        sc.cfg.sim = sim;
        let t0 = Instant::now();
        let out = medusa::workload::run_scenario(&sc).expect("hierarchical scenario run");
        let trunk = out.stats.get("hier_read.lines_over_trunk")
            + out.stats.get("hier_write.lines_over_trunk");
        (t0.elapsed().as_secs_f64(), out.fabric_cycles, trunk)
    };
    let (hr_full_s, hr_cycles, hr_trunk) = hier_with(SimBackend::full());
    let (hr_elided_s, hc2, ht2) =
        hier_with(SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise });
    let (hr_leap_s, hc3, ht3) =
        hier_with(SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap });
    let (hr_fast_s, hc4, ht4) = hier_with(SimBackend::fast());
    assert_eq!((hr_cycles, hr_trunk), (hc2, ht2), "elision changed the hierarchical run");
    assert_eq!((hr_cycles, hr_trunk), (hc3, ht3), "leaping changed the hierarchical run");
    assert_eq!((hr_cycles, hr_trunk), (hc4, ht4), "fast backend changed the hierarchical run");
    assert!(hr_trunk > 0, "no lines crossed the trunk");
    println!(
        "hierarchical l2:c4 (single-tiny-vgg): full {hr_full_s:.4}s, elided {hr_elided_s:.4}s \
         ({:.2}x), leap {hr_leap_s:.4}s ({:.2}x), fast {hr_fast_s:.4}s ({:.2}x) — \
         {hr_trunk} trunk crossings, {:.3}x cycles vs flat, results identical",
        hr_full_s / hr_elided_s.max(1e-12),
        hr_full_s / hr_leap_s.max(1e-12),
        hr_full_s / hr_fast_s.max(1e-12),
        hr_cycles as f64 / sc_full_cycles.max(1) as f64,
    );
    let pr8_path = format!("{json_dir}/BENCH_PR8.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"hierarchical_pr8\",\n");
    j.push_str("  \"design\": \"hierarchical:l2:c4:b0:t300\",\n");
    j.push_str(&format!(
        "  \"hierarchical_scenario\": {{\"name\": \"single-tiny-vgg\", \"fabric_cycles\": {hr_cycles}, \
         \"trunk_crossings\": {hr_trunk}, \"flat_fabric_cycles\": {sc_full_cycles}, \
         \"cycle_ratio_vs_flat\": {}, \"full_s\": {}, \"elided_s\": {}, \"leap_s\": {}, \
         \"fast_s\": {}, \"elided_speedup\": {}, \"leap_speedup\": {}, \"fast_speedup\": {}, \
         \"results_identical\": true}}\n",
        json_f(hr_cycles as f64 / sc_full_cycles.max(1) as f64),
        json_f(hr_full_s),
        json_f(hr_elided_s),
        json_f(hr_leap_s),
        json_f(hr_fast_s),
        json_f(hr_full_s / hr_elided_s.max(1e-12)),
        json_f(hr_full_s / hr_leap_s.max(1e-12)),
        json_f(hr_full_s / hr_fast_s.max(1e-12)),
    ));
    j.push_str("}\n");
    std::fs::write(&pr8_path, &j).expect("writing BENCH_PR8.json");
    println!("wrote {pr8_path}");

    // --- 10. PR 9: the observability layer — profile-on vs profile-off
    // wall time (the cost of zero-perturbation profiling) and the
    // busy-edge breakdown of a fast-backend run: per clock domain,
    // edges stepped vs leapt, plus the leap refusal/cap attribution.
    use medusa::obs::{CapSource, LeapBlock};
    let profiled_with = |profile: bool| {
        let mut sc = medusa::workload::Scenario::builtin("single-tiny-vgg").unwrap();
        sc.cfg.sim = SimBackend::fast();
        let mut opts = RunOptions::new();
        if profile {
            opts = opts.profile(medusa::obs::DEFAULT_WINDOW);
        }
        let t0 = Instant::now();
        let out = opts.run(&sc).expect("profiled scenario run");
        (t0.elapsed().as_secs_f64(), out)
    };
    let (off_s, off) = profiled_with(false);
    let (on_s, on) = profiled_with(true);
    assert_eq!(off.fingerprint(), on.fingerprint(), "profiling perturbed the run");
    let prof = on.profile.as_ref().expect("profiled run carries a report");
    let lt = &prof.sys.leap;
    assert_eq!(lt.refusal_total(), lt.refused(), "refusal breakdown out of balance");
    assert_eq!(lt.cap_total(), lt.taken, "cap breakdown out of balance");
    assert!(lt.attempts > 0, "leap backend never attempted a leap");
    let overhead = on_s / off_s.max(1e-12);
    println!(
        "observability (single-tiny-vgg, fast): profile off {off_s:.4}s, on {on_s:.4}s \
         ({overhead:.2}x), fingerprints identical — {} leaps taken of {} attempts",
        lt.taken, lt.attempts
    );
    let pr9_path = format!("{json_dir}/BENCH_PR9.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"observability_pr9\",\n");
    j.push_str("  \"scenario\": \"single-tiny-vgg\",\n");
    j.push_str("  \"backend\": \"elided+leap\",\n");
    j.push_str(&format!(
        "  \"overhead\": {{\"profile_off_s\": {}, \"profile_on_s\": {}, \"ratio\": {}, \
         \"fingerprints_identical\": true}},\n",
        json_f(off_s),
        json_f(on_s),
        json_f(overhead),
    ));
    j.push_str("  \"busy_edges\": {\n    \"domains\": [\n");
    for (i, d) in prof.sys.domains.iter().enumerate() {
        j.push_str(&format!(
            "      {{\"domain\": \"{}\", \"stepped\": {}, \"leapt\": {}, \"total\": {}}}{}\n",
            d.name,
            d.stepped,
            d.leapt,
            d.total(),
            if i + 1 < prof.sys.domains.len() { "," } else { "" }
        ));
    }
    j.push_str("    ],\n");
    j.push_str(&format!(
        "    \"leap\": {{\"attempts\": {}, \"taken\": {}, \"refused\": {}}},\n",
        lt.attempts,
        lt.taken,
        lt.refused(),
    ));
    j.push_str("    \"refusals\": {");
    for (i, b) in LeapBlock::ALL.iter().enumerate() {
        j.push_str(&format!(
            "\"{}\": {}{}",
            b.name(),
            lt.refusals[*b as usize],
            if i + 1 < LeapBlock::ALL.len() { ", " } else { "" }
        ));
    }
    j.push_str("},\n    \"caps\": {");
    for (i, c) in CapSource::ALL.iter().enumerate() {
        j.push_str(&format!(
            "\"{}\": {}{}",
            c.name(),
            lt.caps[*c as usize],
            if i + 1 < CapSource::ALL.len() { ", " } else { "" }
        ));
    }
    j.push_str("}\n  }\n}\n");
    std::fs::write(&pr9_path, &j).expect("writing BENCH_PR9.json");
    println!("wrote {pr9_path}");

    // --- 11. PR 10: overload-robust serving — the oversubscribed
    // serving-overload builtin (bounded drop-oldest queue under a
    // 12-request burst, deadlines and a retry budget armed) across all
    // four backend combinations. Shed / timed-out counters and the
    // fingerprint must be bit-identical everywhere (the
    // overload-conformance contract); the wall clock vs the PR 7
    // serving-poisson baseline above shows what bounded admission,
    // expiry scanning, and the extra next_event terms cost per run.
    let overload_with = |sim: SimBackend| -> (f64, u64, u64, u64, u64) {
        let sc = medusa::workload::Scenario::builtin("serving-overload").unwrap();
        let t0 = Instant::now();
        let out = RunOptions::new().backend(sim).run(&sc).expect("overload run");
        let shed = out.stats.get("serving.requests_shed");
        let timed_out = out.stats.get("serving.requests_timed_out");
        (t0.elapsed().as_secs_f64(), out.fabric_cycles, shed, timed_out, out.fingerprint())
    };
    let (ov_full_s, ov_cycles, ov_shed, ov_to, ov_fp) = overload_with(SimBackend::full());
    let (ov_elided_s, oc2, os2, ot2, of2) =
        overload_with(SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Stepwise });
    let (ov_leap_s, oc3, os3, ot3, of3) =
        overload_with(SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Leap });
    let (ov_fast_s, oc4, os4, ot4, of4) = overload_with(SimBackend::fast());
    assert_eq!((ov_cycles, ov_shed, ov_to), (oc2, os2, ot2), "elision changed overload results");
    assert_eq!(
        (ov_cycles, ov_shed, ov_to, ov_fp),
        (oc3, os3, ot3, of3),
        "leaping changed overload results"
    );
    assert_eq!(
        (ov_cycles, ov_shed, ov_to, of2),
        (oc4, os4, ot4, of4),
        "fast backend changed overload results"
    );
    assert_eq!(ov_shed, 7, "cap-3 drop-oldest queue under the 12-request burst sheds 7");
    println!(
        "overload (serving-overload): full {ov_full_s:.4}s, elided {ov_elided_s:.4}s ({:.2}x), \
         leap {ov_leap_s:.4}s ({:.2}x), fast {ov_fast_s:.4}s ({:.2}x) — {ov_shed} shed, \
         {ov_to} timed out, {:.2}x full-backend cost vs serving-poisson, results identical",
        ov_full_s / ov_elided_s.max(1e-12),
        ov_full_s / ov_leap_s.max(1e-12),
        ov_full_s / ov_fast_s.max(1e-12),
        ov_full_s / sv_full_s.max(1e-12),
    );
    let pr10_path = format!("{json_dir}/BENCH_PR10.json");
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"overload_pr10\",\n");
    j.push_str(&format!(
        "  \"overload_scenario\": {{\"name\": \"serving-overload\", \"fabric_cycles\": {ov_cycles}, \
         \"requests_shed\": {ov_shed}, \"requests_timed_out\": {ov_to}, \"full_s\": {}, \
         \"elided_s\": {}, \"leap_s\": {}, \"fast_s\": {}, \"elided_speedup\": {}, \
         \"leap_speedup\": {}, \"fast_speedup\": {}, \"results_identical\": true}},\n",
        json_f(ov_full_s),
        json_f(ov_elided_s),
        json_f(ov_leap_s),
        json_f(ov_fast_s),
        json_f(ov_full_s / ov_elided_s.max(1e-12)),
        json_f(ov_full_s / ov_leap_s.max(1e-12)),
        json_f(ov_full_s / ov_fast_s.max(1e-12)),
    ));
    j.push_str(&format!(
        "  \"vs_pr7_baseline\": {{\"serving_poisson_full_s\": {}, \"overload_full_s\": {}, \
         \"cost_ratio\": {}}}\n",
        json_f(sv_full_s),
        json_f(ov_full_s),
        json_f(ov_full_s / sv_full_s.max(1e-12)),
    ));
    j.push_str("}\n");
    std::fs::write(&pr10_path, &j).expect("writing BENCH_PR10.json");
    println!("wrote {pr10_path}");
}
