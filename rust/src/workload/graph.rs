//! Workload graphs: the generalized network representation the scenario
//! engine executes.
//!
//! `accel::dnn::Network` models a straight chain of dense convolutions —
//! enough for the paper's VGG traffic but not for the residual,
//! depthwise, or GEMM-shaped workloads whose interconnect behaviour
//! diverges sharply (Krishnan et al. 2021). A [`WorkloadNet`] is a
//! topologically ordered DAG of [`Layer`]s: dense/grouped convolutions,
//! GEMMs (lowered to 1x1 convolutions for compute), and elementwise
//! residual adds whose second operand may skip back to any earlier
//! node's output (or the network input).

use crate::accel::dnn::ConvLayer;
use crate::accel::golden::{add_q88, conv2d_grouped_q88, conv2d_q88};
use crate::accel::quant::Fixed16;
use anyhow::{ensure, Result};

/// Feature-map shape, channel-major: (channels, height, width).
pub type Shape = (usize, usize, usize);

/// One workload layer. All kinds reduce to the same port traffic
/// pattern: stream the operand tensors in, stall for the modelled
/// compute, stream the output tensor out.
#[derive(Clone, Debug)]
pub enum Layer {
    /// (Optionally grouped) 2D convolution. `groups == 1` is a dense
    /// conv; `groups == in_c == out_c` is depthwise.
    Conv { conv: ConvLayer, groups: usize },
    /// Token-major GEMM: `m` tokens of `k` features -> `m` tokens of
    /// `n` features (transformer-style projection / MLP layer).
    /// Computed by lowering to a 1x1 convolution over a 1 x m map.
    Gemm { name: &'static str, m: usize, k: usize, n: usize, relu: bool },
    /// Elementwise residual add of two same-shaped feature maps.
    Add { name: &'static str, c: usize, h: usize, w: usize, relu: bool },
}

impl Layer {
    pub fn name(&self) -> &'static str {
        match self {
            Layer::Conv { conv, .. } => conv.name,
            Layer::Gemm { name, .. } => name,
            Layer::Add { name, .. } => name,
        }
    }

    /// Shape the primary input must have.
    pub fn in_shape(&self) -> Shape {
        match self {
            Layer::Conv { conv, .. } => (conv.in_c, conv.in_h, conv.in_w),
            Layer::Gemm { m, k, .. } => (*k, 1, *m),
            Layer::Add { c, h, w, .. } => (*c, *h, *w),
        }
    }

    pub fn out_shape(&self) -> Shape {
        match self {
            Layer::Conv { conv, .. } => (conv.out_c, conv.out_h(), conv.out_w()),
            Layer::Gemm { m, n, .. } => (*n, 1, *m),
            Layer::Add { c, h, w, .. } => (*c, *h, *w),
        }
    }

    /// Words of the primary input tensor.
    pub fn ifmap_words(&self) -> usize {
        let (c, h, w) = self.in_shape();
        c * h * w
    }

    /// Words of the output tensor.
    pub fn ofmap_words(&self) -> usize {
        let (c, h, w) = self.out_shape();
        c * h * w
    }

    /// Words of weights + one bias word per output channel (0 for Add).
    pub fn weight_words(&self) -> usize {
        match self {
            Layer::Conv { conv, groups } => {
                conv.out_c * (conv.in_c / groups) * conv.k * conv.k + conv.out_c
            }
            Layer::Gemm { k, n, .. } => n * k + n,
            Layer::Add { .. } => 0,
        }
    }

    /// Modelled MAC-array work: multiply-accumulates for convs/GEMMs,
    /// one op per element for adds.
    pub fn macs(&self) -> u64 {
        match self {
            Layer::Conv { conv, groups } => {
                (conv.out_c * conv.out_h() * conv.out_w() * (conv.in_c / groups) * conv.k * conv.k)
                    as u64
            }
            Layer::Gemm { m, k, n, .. } => (m * k * n) as u64,
            Layer::Add { c, h, w, .. } => (c * h * w) as u64,
        }
    }

    /// Run the layer's math in the exact Q8.8 golden semantics.
    /// `skip` is the second operand (Add only).
    pub fn golden(&self, input: &[Fixed16], skip: Option<&[Fixed16]>, weights: &[Fixed16], bias: &[Fixed16]) -> Vec<Fixed16> {
        match self {
            Layer::Conv { conv, groups } => {
                if *groups == 1 {
                    conv2d_q88(conv, input, weights, bias)
                } else {
                    conv2d_grouped_q88(conv, *groups, input, weights, bias)
                }
            }
            Layer::Gemm { .. } => conv2d_q88(&self.lowered_conv(), input, weights, bias),
            Layer::Add { relu, .. } => add_q88(input, skip.expect("Add needs a skip operand"), *relu),
        }
    }

    /// The 1x1 convolution a GEMM lowers to (panics for other kinds).
    pub fn lowered_conv(&self) -> ConvLayer {
        match self {
            Layer::Gemm { name, m, k, n, relu } => ConvLayer {
                name: *name,
                in_c: *k,
                in_h: 1,
                in_w: *m,
                out_c: *n,
                k: 1,
                stride: 1,
                pad: 0,
                relu: *relu,
            },
            _ => panic!("only GEMM layers lower to a conv"),
        }
    }

    pub fn validate(&self) -> Result<()> {
        match self {
            Layer::Conv { conv, groups } => {
                ensure!(*groups >= 1, "{}: groups must be >= 1", conv.name);
                ensure!(
                    conv.in_c % groups == 0 && conv.out_c % groups == 0,
                    "{}: groups {} must divide in_c {} and out_c {}",
                    conv.name,
                    groups,
                    conv.in_c,
                    conv.out_c
                );
                ensure!(conv.k >= 1 && conv.stride >= 1, "{}: degenerate kernel", conv.name);
            }
            Layer::Gemm { name, m, k, n, .. } => {
                ensure!(*m >= 1 && *k >= 1 && *n >= 1, "{name}: degenerate GEMM");
            }
            Layer::Add { name, c, h, w, .. } => {
                ensure!(*c >= 1 && *h >= 1 && *w >= 1, "{name}: degenerate add");
            }
        }
        Ok(())
    }
}

/// Where a node's operand comes from.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Src {
    /// The network input tensor.
    Input,
    /// The output of an earlier node (index into `WorkloadNet::nodes`).
    Node(usize),
}

/// One node of the workload graph.
#[derive(Clone, Debug)]
pub struct Node {
    pub layer: Layer,
    /// Primary input source.
    pub input: Src,
    /// Second operand (Add layers only).
    pub skip: Option<Src>,
}

impl Node {
    /// A node consuming the previous node's output (or the network
    /// input for node 0) — the plain-chain case.
    pub fn chained(layer: Layer, index: usize) -> Node {
        let input = if index == 0 { Src::Input } else { Src::Node(index - 1) };
        Node { layer, input, skip: None }
    }
}

/// A topologically ordered workload graph. The network output is the
/// last node's output.
#[derive(Clone, Debug)]
pub struct WorkloadNet {
    pub name: &'static str,
    /// Shape of the network input tensor.
    pub input_shape: Shape,
    pub nodes: Vec<Node>,
}

impl WorkloadNet {
    /// Chain of layers, each feeding the next (first from the input).
    pub fn chain(name: &'static str, input_shape: Shape, layers: Vec<Layer>) -> WorkloadNet {
        let nodes = layers.into_iter().enumerate().map(|(i, l)| Node::chained(l, i)).collect();
        WorkloadNet { name, input_shape, nodes }
    }

    /// Convert a legacy dense-conv chain.
    pub fn from_legacy(net: &crate::accel::dnn::Network) -> WorkloadNet {
        let l0 = &net.layers[0];
        WorkloadNet::chain(
            net.name,
            (l0.in_c, l0.in_h, l0.in_w),
            net.layers.iter().map(|&conv| Layer::Conv { conv, groups: 1 }).collect(),
        )
    }

    pub fn input_words(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Output shape of `src` as seen by a consumer.
    fn src_shape(&self, src: Src) -> Shape {
        match src {
            Src::Input => self.input_shape,
            Src::Node(i) => self.nodes[i].layer.out_shape(),
        }
    }

    pub fn output_shape(&self) -> Shape {
        self.nodes.last().expect("network has nodes").layer.out_shape()
    }

    pub fn output_words(&self) -> usize {
        let (c, h, w) = self.output_shape();
        c * h * w
    }

    pub fn total_macs(&self) -> u64 {
        self.nodes.iter().map(|n| n.layer.macs()).sum()
    }

    /// Check topological ordering and that every edge's shapes chain.
    pub fn validate(&self) -> Result<()> {
        ensure!(!self.nodes.is_empty(), "{}: network has no nodes", self.name);
        for (i, node) in self.nodes.iter().enumerate() {
            node.layer.validate()?;
            let check_src = |src: Src, what: &str| -> Result<()> {
                if let Src::Node(j) = src {
                    ensure!(
                        j < i,
                        "{}: node {} ({}) {} references node {} out of topological order",
                        self.name,
                        i,
                        node.layer.name(),
                        what,
                        j
                    );
                }
                Ok(())
            };
            check_src(node.input, "input")?;
            let got = self.src_shape(node.input);
            let want = node.layer.in_shape();
            ensure!(
                got == want,
                "{}: node {} ({}) expects input {:?}, gets {:?}",
                self.name,
                i,
                node.layer.name(),
                want,
                got
            );
            match (&node.layer, node.skip) {
                (Layer::Add { .. }, Some(skip)) => {
                    check_src(skip, "skip")?;
                    let got = self.src_shape(skip);
                    ensure!(
                        got == want,
                        "{}: node {} ({}) skip operand {:?} != {:?}",
                        self.name,
                        i,
                        node.layer.name(),
                        got,
                        want
                    );
                }
                (Layer::Add { .. }, None) => {
                    anyhow::bail!("{}: add node {} has no skip operand", self.name, i)
                }
                (_, Some(_)) => {
                    anyhow::bail!("{}: non-add node {} has a skip operand", self.name, i)
                }
                (_, None) => {}
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dnn::Network;

    #[test]
    fn legacy_conversion_chains() {
        let net = WorkloadNet::from_legacy(&Network::tiny_vgg());
        net.validate().unwrap();
        assert_eq!(net.nodes.len(), 6);
        assert_eq!(net.input_shape, (3, 32, 32));
        assert_eq!(net.total_macs(), Network::tiny_vgg().total_macs());
    }

    #[test]
    fn gemm_lowering_preserves_counts() {
        let g = Layer::Gemm { name: "proj", m: 8, k: 16, n: 32, relu: true };
        assert_eq!(g.ifmap_words(), 16 * 8);
        assert_eq!(g.ofmap_words(), 32 * 8);
        assert_eq!(g.weight_words(), 32 * 16 + 32);
        assert_eq!(g.macs(), 8 * 16 * 32);
        let c = g.lowered_conv();
        assert_eq!(c.ifmap_words(), g.ifmap_words());
        assert_eq!(c.ofmap_words(), g.ofmap_words());
        assert_eq!(c.macs(), g.macs());
    }

    #[test]
    fn depthwise_counts() {
        let conv = ConvLayer { name: "dw", in_c: 8, in_h: 4, in_w: 4, out_c: 8, k: 3, stride: 1, pad: 1, relu: true };
        let l = Layer::Conv { conv, groups: 8 };
        l.validate().unwrap();
        assert_eq!(l.weight_words(), 8 * 9 + 8);
        assert_eq!(l.macs(), (8 * 16 * 9) as u64);
    }

    #[test]
    fn add_without_skip_rejected() {
        let net = WorkloadNet::chain(
            "bad",
            (2, 4, 4),
            vec![Layer::Add { name: "a", c: 2, h: 4, w: 4, relu: false }],
        );
        assert!(net.validate().is_err());
    }

    #[test]
    fn shape_mismatch_rejected() {
        let conv = ConvLayer { name: "c", in_c: 3, in_h: 8, in_w: 8, out_c: 4, k: 3, stride: 1, pad: 1, relu: true };
        let net = WorkloadNet::chain("bad", (2, 8, 8), vec![Layer::Conv { conv, groups: 1 }]);
        assert!(net.validate().is_err());
    }

    #[test]
    fn forward_skip_reference_rejected() {
        let conv = ConvLayer { name: "c", in_c: 2, in_h: 4, in_w: 4, out_c: 2, k: 3, stride: 1, pad: 1, relu: true };
        let net = WorkloadNet {
            name: "bad",
            input_shape: (2, 4, 4),
            nodes: vec![
                Node {
                    layer: Layer::Add { name: "a", c: 2, h: 4, w: 4, relu: false },
                    input: Src::Input,
                    skip: Some(Src::Node(1)),
                },
                Node { layer: Layer::Conv { conv, groups: 1 }, input: Src::Input, skip: None },
            ],
        };
        assert!(net.validate().is_err());
    }
}
