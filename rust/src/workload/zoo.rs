//! The network zoo: named workload graphs covering the traffic classes
//! whose interconnect behaviour diverges (dense conv chains, residual
//! stacks with downsample skips, depthwise+pointwise stacks, GEMM-heavy
//! transformer-style layers).
//!
//! Every zoo entry is sized so a full end-to-end run through the
//! cycle-accurate interconnect — golden math included — finishes in
//! test time; `vgg16-head` keeps VGG-16's head *structure* (3x3 convs,
//! channel doubling at the downsample) at a reduced input/width. The
//! paper-scale `accel::dnn::Network::vgg16_head` remains available for
//! bandwidth-realistic benchmarks.

use crate::accel::dnn::{ConvLayer, Network};
use crate::workload::graph::{Layer, Node, Src, WorkloadNet};

fn conv(name: &'static str, in_c: usize, in_hw: usize, out_c: usize, stride: usize) -> Layer {
    Layer::Conv {
        conv: ConvLayer {
            name,
            in_c,
            in_h: in_hw,
            in_w: in_hw,
            out_c,
            k: 3,
            stride,
            pad: 1,
            relu: true,
        },
        groups: 1,
    }
}

/// The legacy tiny-VGG chain, as a workload graph.
pub fn tiny_vgg() -> WorkloadNet {
    WorkloadNet::from_legacy(&Network::tiny_vgg())
}

/// VGG-16 head structure (conv1_1, conv1_2, downsample, conv2_1) with
/// the characteristic channel doubling, test-scaled to 28x28 / 16ch.
pub fn vgg16_head() -> WorkloadNet {
    WorkloadNet::chain(
        "vgg16-head",
        (3, 28, 28),
        vec![
            conv("conv1_1", 3, 28, 16, 1),
            conv("conv1_2", 16, 28, 16, 1),
            // Stride-2 conv stands in for the 2x2 pool, doubling channels
            // as VGG does across the pool boundary.
            conv("down1", 16, 28, 32, 2),
            conv("conv2_1", 32, 14, 32, 1),
        ],
    )
}

/// ResNet-style residual stack: an identity block, then a downsample
/// block whose skip path is a strided 1x1 projection.
pub fn resnet_tiny() -> WorkloadNet {
    let mut nodes = Vec::new();
    // Identity block on (8, 16, 16).
    nodes.push(Node {
        layer: conv("b1_conv1", 8, 16, 8, 1),
        input: Src::Input,
        skip: None,
    });
    nodes.push(Node {
        layer: Layer::Conv {
            conv: ConvLayer { name: "b1_conv2", in_c: 8, in_h: 16, in_w: 16, out_c: 8, k: 3, stride: 1, pad: 1, relu: false },
            groups: 1,
        },
        input: Src::Node(0),
        skip: None,
    });
    nodes.push(Node {
        layer: Layer::Add { name: "b1_add", c: 8, h: 16, w: 16, relu: true },
        input: Src::Node(1),
        skip: Some(Src::Input),
    });
    // Downsample block to (16, 8, 8) with a projection skip.
    nodes.push(Node {
        layer: conv("b2_conv1", 8, 16, 16, 2),
        input: Src::Node(2),
        skip: None,
    });
    nodes.push(Node {
        layer: Layer::Conv {
            conv: ConvLayer { name: "b2_conv2", in_c: 16, in_h: 8, in_w: 8, out_c: 16, k: 3, stride: 1, pad: 1, relu: false },
            groups: 1,
        },
        input: Src::Node(3),
        skip: None,
    });
    nodes.push(Node {
        layer: Layer::Conv {
            conv: ConvLayer { name: "b2_proj", in_c: 8, in_h: 16, in_w: 16, out_c: 16, k: 1, stride: 2, pad: 0, relu: false },
            groups: 1,
        },
        input: Src::Node(2),
        skip: None,
    });
    nodes.push(Node {
        layer: Layer::Add { name: "b2_add", c: 16, h: 8, w: 8, relu: true },
        input: Src::Node(4),
        skip: Some(Src::Node(5)),
    });
    WorkloadNet { name: "resnet-tiny", input_shape: (8, 16, 16), nodes }
}

/// MobileNet-style stack: a dense stem, then depthwise + pointwise
/// pairs (the second pair downsampling).
pub fn mobilenet_tiny() -> WorkloadNet {
    let dw = |name: &'static str, c: usize, hw: usize, stride: usize| Layer::Conv {
        conv: ConvLayer { name, in_c: c, in_h: hw, in_w: hw, out_c: c, k: 3, stride, pad: 1, relu: true },
        groups: c,
    };
    let pw = |name: &'static str, in_c: usize, hw: usize, out_c: usize| Layer::Conv {
        conv: ConvLayer { name, in_c, in_h: hw, in_w: hw, out_c, k: 1, stride: 1, pad: 0, relu: true },
        groups: 1,
    };
    WorkloadNet::chain(
        "mobilenet-tiny",
        (4, 16, 16),
        vec![
            conv("stem", 4, 16, 8, 1),
            dw("dw1", 8, 16, 1),
            pw("pw1", 8, 16, 16),
            dw("dw2", 16, 16, 2),
            pw("pw2", 16, 8, 32),
        ],
    )
}

/// Transformer-ish GEMM stack: token-major projection + MLP layers
/// (32-feature tokens, expand to 64, contract to 32).
pub fn gemm_mlp() -> WorkloadNet {
    WorkloadNet::chain(
        "gemm-mlp",
        (32, 1, 16),
        vec![
            Layer::Gemm { name: "qkv_proj", m: 16, k: 32, n: 64, relu: true },
            Layer::Gemm { name: "mlp_up", m: 16, k: 64, n: 64, relu: true },
            Layer::Gemm { name: "mlp_down", m: 16, k: 64, n: 32, relu: false },
        ],
    )
}

/// Every zoo network name, in registry order.
pub fn names() -> &'static [&'static str] {
    &["tiny-vgg", "vgg16-head", "resnet-tiny", "mobilenet-tiny", "gemm-mlp"]
}

/// Look a zoo network up by its registry name.
pub fn by_name(name: &str) -> Option<WorkloadNet> {
    match name {
        "tiny-vgg" => Some(tiny_vgg()),
        "vgg16-head" => Some(vgg16_head()),
        "resnet-tiny" => Some(resnet_tiny()),
        "mobilenet-tiny" => Some(mobilenet_tiny()),
        "gemm-mlp" => Some(gemm_mlp()),
        _ => None,
    }
}

/// All zoo networks.
pub fn all() -> Vec<WorkloadNet> {
    names().iter().map(|n| by_name(n).unwrap()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn every_zoo_network_validates() {
        for net in all() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
            assert!(net.total_macs() > 0, "{}", net.name);
        }
    }

    #[test]
    fn registry_is_consistent() {
        assert_eq!(names().len(), all().len());
        for (n, net) in names().iter().zip(all()) {
            assert_eq!(*n, net.name);
        }
        assert!(by_name("nope").is_none());
    }

    #[test]
    fn resnet_has_skips_and_mobilenet_has_depthwise() {
        let r = resnet_tiny();
        assert!(r.nodes.iter().any(|n| n.skip.is_some()));
        let m = mobilenet_tiny();
        assert!(m
            .nodes
            .iter()
            .any(|n| matches!(n.layer, Layer::Conv { groups, .. } if groups > 1)));
        let g = gemm_mlp();
        assert!(g.nodes.iter().all(|n| matches!(n.layer, Layer::Gemm { .. })));
    }

    #[test]
    fn vgg_head_doubles_channels_at_downsample() {
        let v = vgg16_head();
        assert_eq!(v.nodes[1].layer.out_shape().0 * 2, v.nodes[2].layer.out_shape().0);
        assert_eq!(v.output_shape(), (32, 14, 14));
    }
}
