//! The workload scenario subsystem.
//!
//! Generalizes the legacy dense-conv chains of [`crate::accel::dnn`]
//! into a full scenario engine:
//!
//! * [`graph`] — workload graphs: dense/grouped convs, GEMMs, and
//!   residual adds with skip connections;
//! * [`zoo`] — named networks covering the traffic classes (tiny-VGG,
//!   VGG-16 head, ResNet-style residual stack, MobileNet-style
//!   depthwise stack, transformer-ish GEMM stack);
//! * [`scenario`] — TOML-loadable mappings of networks onto port
//!   groups: single-net, multi-tenant fabric sharing, staggered starts;
//! * [`engine`] — the execution engine with golden-model verification
//!   and deterministic trace capture/replay
//!   (see [`crate::sim::trace`] for the trace format).

pub mod engine;
pub mod graph;
pub mod scenario;
pub mod zoo;

pub use engine::{replay, run_scenario, run_scenario_captured, verify_replay, ScenarioOutcome};
// Deprecated `_with` shims, kept importable for external callers; new
// code goes through `crate::run::RunOptions`.
#[allow(deprecated)]
pub use engine::{replay_with, verify_replay_with};
pub use graph::{Layer, Node, Src, WorkloadNet};
pub use scenario::{Scenario, TenantSpec};
