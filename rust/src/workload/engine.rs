//! The scenario engine: executes a [`Scenario`] — one or many tenant
//! networks on disjoint port groups of a single simulated system — and
//! the deterministic trace capture/replay harness built on it.
//!
//! # Execution model
//!
//! All workload math is *precomputed*: inputs and weights are generated
//! from the tenant seed, golden outputs are computed layer by layer, and
//! every layer pass is reduced to an [`ExecStep`] — per-port burst
//! schedules plus the exact write-port word streams. The simulation
//! loop then only moves data: each tenant's layer processor walks
//! Load -> Compute -> Drain, gated per tenant by a data-independent
//! "my writes have landed in DRAM" signal
//! ([`MemoryController::write_lines_landed`]) so tenants overlap freely
//! without read-after-write hazards on their own tensors.
//!
//! Because the precomputation is seeded and the simulation is
//! single-threaded within one system, a scenario run is bit-identical
//! regardless of `MEDUSA_THREADS`; parallelism happens only *across*
//! scenario/design points (`eval::scenarios`).
//!
//! # Capture / replay
//!
//! Capturing a run records the executed steps as a
//! [`ScenarioTrace`](crate::sim::trace::ScenarioTrace) plus the final
//! stats. Replaying re-drives the interconnect from the trace alone —
//! no workload generation, no golden math (write data is synthesized
//! from the step's `write_seed`) — and must reproduce the captured
//! cycle counts and counters exactly; [`verify_replay`] asserts that.

use crate::accel::layer_processor::{Phase, PortGroup};
use crate::accel::prefetch::{partition, PortSchedule, Region};
use crate::accel::quant::Fixed16;
use crate::coordinator::driver::gen_conv_weights;
use crate::coordinator::metrics::{LayerReport, RunReport};
use crate::coordinator::System;
use crate::dram::MemoryController;
use crate::interconnect::Design;
use crate::fault::{FaultPolicy, SimError};
use crate::obs::{CapSource, RunProfile};
use crate::serving::{ServingReport, ServingRun, ServingState};
use crate::sim::trace::{ScenarioTrace, TraceExpect, TraceHeader, TraceStep, TraceTenant, MOVEMENT_COUNTERS};
use crate::sim::stats::{Counter, SampleId};
use crate::types::{Line, LineAddr, Word};
use crate::util::Prng;
use crate::workload::graph::{Layer, Src, WorkloadNet};
use crate::workload::scenario::Scenario;
use anyhow::{ensure, Context, Result};
use std::collections::{HashMap, VecDeque};

/// One fully precomputed layer pass.
#[derive(Clone)]
struct ExecStep {
    label: &'static str,
    macs: u64,
    reads: Vec<PortSchedule>,
    writes: Vec<PortSchedule>,
    /// The exact words each local write port will stream, in order.
    write_data: Vec<VecDeque<Word>>,
    /// Expected words per local read port (empty = don't verify).
    expected_ports: Vec<Vec<Word>>,
    /// Expected DRAM content of the output region after the flush.
    dram_check: Option<(Region, Vec<Word>)>,
    /// Recorded into the trace; seeds synthesized data on replay.
    write_seed: u64,
}

impl ExecStep {
    fn read_lines(&self) -> u64 {
        self.reads.iter().map(|s| s.total_lines() as u64).sum()
    }

    fn write_lines(&self) -> u64 {
        self.writes.iter().map(|s| s.total_lines() as u64).sum()
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum TState {
    WaitStart,
    Loading,
    Draining,
    WaitFlush,
    Finished,
    /// Serving mode only: between batches, waiting for the dispatcher
    /// to re-arm the tenant's pass template.
    Parked,
}

/// Per-tenant runtime state.
struct TenantRt {
    network: &'static str,
    group: PortGroup,
    start_cycle: u64,
    steps: VecDeque<ExecStep>,
    /// Serving mode: the tenant's full pass, cloned back into `steps`
    /// every time the batcher dispatches (one pass serves one batch).
    /// Empty in classic fixed-schedule runs.
    template: Vec<ExecStep>,
    state: TState,
    cur: Option<ExecStep>,
    /// Lines handed to the write network so far (cumulative); compared
    /// against the controller's landed count for this group.
    supplied_lines: u64,
    /// Layer-report baselines.
    t0_ps: u64,
    load0: u64,
    comp0: u64,
    drain0: u64,
    report: RunReport,
    verified: bool,
    /// Golden final feature map (net mode; empty on replay).
    final_fm: Vec<Fixed16>,
    /// DRAM region of the network output (net mode; dumped into the
    /// outcome after the run so tests can compare what the fabric
    /// actually delivered, not just the precomputed golden).
    final_region: Option<Region>,
}

/// What one tenant produced.
pub struct TenantOutcome {
    pub network: &'static str,
    pub report: RunReport,
    /// Cumulative per-port wait cycles (local indices).
    pub read_waits: Vec<u64>,
    pub write_waits: Vec<u64>,
    /// The network's final feature map (golden-checked; empty on
    /// replay).
    pub final_fm: Vec<Fixed16>,
    /// The words that actually landed in the output's DRAM region —
    /// dumped from the simulated store after the run, NOT derived from
    /// the golden model (empty on replay). This is what cross-design
    /// data-transparency assertions must compare.
    pub final_dram: Vec<Word>,
    pub verified: bool,
}

/// The result of one scenario (or replay) run.
pub struct ScenarioOutcome {
    pub scenario: String,
    pub design: &'static str,
    pub fabric_mhz: f64,
    pub fabric_cycles: u64,
    pub mem_cycles: u64,
    pub now_ps: u64,
    pub tenants: Vec<TenantOutcome>,
    pub stats: crate::sim::Stats,
    /// Per-tenant serving summary (`None` for classic fixed-schedule
    /// runs).
    pub serving: Option<ServingReport>,
    /// Observability report (`None` unless the run was profiled).
    /// Strictly outside the determinism domain: never folded into
    /// [`ScenarioOutcome::fingerprint`], traces, or timing entries.
    pub profile: Option<Box<RunProfile>>,
}

impl ScenarioOutcome {
    pub fn all_verified(&self) -> bool {
        self.tenants.iter().all(|t| t.verified)
    }

    /// Stable FNV-1a digest of everything observable: cycle counts,
    /// every counter, per-port waits, and the tenants' final feature
    /// maps. Two runs are "bit-identical" iff their fingerprints match.
    pub fn fingerprint(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        let mut mix = |v: u64| {
            h ^= v;
            h = h.wrapping_mul(0x1000_0000_01b3);
        };
        mix(self.fabric_cycles);
        mix(self.mem_cycles);
        mix(self.now_ps);
        // Mix (index, value) over the FULL registry — no zero-filter —
        // so equal values landing on different counters cannot collide.
        for &id in Counter::ALL.iter() {
            mix(id as u64);
            mix(self.stats.count(id));
        }
        for &id in SampleId::ALL.iter() {
            let s = self.stats.series_of(id);
            mix(id as u64);
            mix(s.sum);
            mix(s.count);
        }
        for t in &self.tenants {
            for &w in t.read_waits.iter().chain(t.write_waits.iter()) {
                mix(w);
            }
            for fm in &t.final_fm {
                mix(fm.0 as u16 as u64);
            }
            mix(t.report.total_cycles());
        }
        // Serving aggregates (absent for classic runs, so pre-serving
        // fingerprints are unchanged). The raw latency series already
        // flows in through the SampleId loop above.
        if let Some(srv) = &self.serving {
            for t in &srv.tenants {
                mix(t.arrived as u64);
                mix(t.completed as u64);
                mix(t.batches as u64);
                mix(t.slo_met as u64);
                mix(t.shed as u64);
                mix(t.timed_out as u64);
                mix(t.retried as u64);
                mix(t.failed as u64);
                mix(t.p50_cycles);
                mix(t.p99_cycles);
                mix(t.max_cycles);
            }
        }
        h
    }
}

/// Deterministic workload weights for one layer (none for adds):
/// delegates to the driver's shared conv generator so legacy-infer and
/// scenario workloads use identical data.
fn gen_weights(prng: &mut Prng, layer: &Layer) -> (Vec<Fixed16>, Vec<Fixed16>) {
    match layer {
        Layer::Conv { conv, groups } => gen_conv_weights(prng, conv, *groups),
        Layer::Gemm { .. } => gen_conv_weights(prng, &layer.lowered_conv(), 1),
        Layer::Add { .. } => (Vec::new(), Vec::new()),
    }
}

/// Pack quantized words into zero-padded lines of `n` words.
fn pad_words(data: &[Fixed16], lines: usize, n: usize) -> Vec<Word> {
    let mut out: Vec<Word> = data.iter().map(|v| v.to_word()).collect();
    out.resize(lines * n, 0);
    out
}

/// Split a region's padded words into the per-port streams its write
/// schedule drains (same packing the inference driver uses).
fn split_write_data(
    scheds: &[PortSchedule],
    region: Region,
    padded: &[Word],
    n: usize,
) -> Vec<VecDeque<Word>> {
    scheds
        .iter()
        .map(|s| {
            let mut q = VecDeque::new();
            for run in &s.runs {
                for a in run.base..run.end() {
                    let off = ((a - region.base) as usize) * n;
                    q.extend(&padded[off..off + n]);
                }
            }
            q
        })
        .collect()
}

/// Expected words each read port gathers, from the line image.
fn expected_per_port(
    scheds: &[PortSchedule],
    image: &HashMap<LineAddr, Vec<Word>>,
    n: usize,
) -> Vec<Vec<Word>> {
    scheds
        .iter()
        .map(|s| {
            let mut out = Vec::with_capacity(s.total_lines() * n);
            for run in &s.runs {
                for a in run.base..run.end() {
                    out.extend_from_slice(&image[&a]);
                }
            }
            out
        })
        .collect()
}

/// Precompute one tenant's full step list from its network, preloading
/// inputs and weights into DRAM and advancing the shared line allocator.
///
/// In payload-elided mode the entire data plane is skipped — no input
/// generation, no weight material, no golden math, no DRAM preload —
/// because every burst schedule below derives from layer *shapes* alone
/// ([`Layer::ifmap_words`]/[`Layer::weight_words`]/[`Layer::ofmap_words`]),
/// never from values. The step list (and therefore every counter and
/// cycle of the simulated run) is identical either way; only the
/// verification payloads are absent.
fn precompute_tenant(
    spec_net: &WorkloadNet,
    seed: u64,
    group: PortGroup,
    n: usize,
    alloc: &mut LineAddr,
    controller: &mut MemoryController,
    elided: bool,
) -> Result<(VecDeque<ExecStep>, Vec<Fixed16>, Option<Region>)> {
    spec_net.validate()?;
    let alloc_lines = |words: usize, alloc: &mut LineAddr| -> Region {
        let lines = words.div_ceil(n);
        let r = Region { base: *alloc, lines };
        *alloc += lines as u64;
        r
    };
    if elided {
        // Shape-only twin of the full path below: identical region
        // allocation order, identical schedules, empty payloads.
        let input_region = alloc_lines(spec_net.input_words(), alloc);
        let mut node_regions: Vec<Region> = Vec::with_capacity(spec_net.nodes.len());
        let mut steps = VecDeque::with_capacity(spec_net.nodes.len());
        for (i, node) in spec_net.nodes.iter().enumerate() {
            let region_of = |s: Src| -> Region {
                match s {
                    Src::Input => input_region,
                    Src::Node(j) => node_regions[j],
                }
            };
            let mut read_regions = vec![region_of(node.input)];
            if let (Layer::Add { .. }, Some(s)) = (&node.layer, node.skip) {
                read_regions.push(region_of(s));
            }
            if node.layer.weight_words() > 0 {
                read_regions.push(alloc_lines(node.layer.weight_words(), alloc));
            }
            let reads = partition(&read_regions, group.read_ports);
            let ofmap_region = alloc_lines(node.layer.ofmap_words(), alloc);
            let writes = partition(&[ofmap_region], group.write_ports);
            steps.push_back(ExecStep {
                label: node.layer.name(),
                macs: node.layer.macs(),
                reads,
                writes,
                write_data: Vec::new(),
                expected_ports: Vec::new(),
                dram_check: None,
                write_seed: seed.wrapping_add(i as u64),
            });
            node_regions.push(ofmap_region);
        }
        let final_region = node_regions.last().copied();
        return Ok((steps, Vec::new(), final_region));
    }
    let mut prng = Prng::new(seed);
    let mut image: HashMap<LineAddr, Vec<Word>> = HashMap::new();
    let preload = |region: Region, padded: &[Word], image: &mut HashMap<LineAddr, Vec<Word>>, controller: &mut MemoryController, to_dram: bool| {
        for (li, a) in (region.base..region.end()).enumerate() {
            image.insert(a, padded[li * n..(li + 1) * n].to_vec());
        }
        if to_dram {
            controller.preload(
                region.base,
                padded.chunks(n).map(Line::from_slice),
            );
        }
    };

    // Network input.
    let input_fm: Vec<Fixed16> = (0..spec_net.input_words())
        .map(|_| Fixed16::from_f32(prng.f64() as f32 * 2.0 - 1.0))
        .collect();
    let input_region = alloc_lines(input_fm.len(), alloc);
    let input_padded = pad_words(&input_fm, input_region.lines, n);
    preload(input_region, &input_padded, &mut image, controller, true);

    let mut node_fms: Vec<Vec<Fixed16>> = Vec::with_capacity(spec_net.nodes.len());
    let mut node_regions: Vec<Region> = Vec::with_capacity(spec_net.nodes.len());
    let mut steps = VecDeque::with_capacity(spec_net.nodes.len());
    for (i, node) in spec_net.nodes.iter().enumerate() {
        let src_of = |s: Src| -> (Region, &Vec<Fixed16>) {
            match s {
                Src::Input => (input_region, &input_fm),
                Src::Node(j) => (node_regions[j], &node_fms[j]),
            }
        };
        let (in_region, in_fm) = src_of(node.input);
        let (weights, bias) = gen_weights(&mut prng, &node.layer);
        // Read operands: primary + (weights | skip).
        let mut read_regions = vec![in_region];
        let skip_fm: Option<&Vec<Fixed16>> = match (&node.layer, node.skip) {
            (Layer::Add { .. }, Some(s)) => {
                let (r, fm) = src_of(s);
                read_regions.push(r);
                Some(fm)
            }
            _ => None,
        };
        if !weights.is_empty() {
            let mut wdata = weights.clone();
            wdata.extend_from_slice(&bias);
            let r = alloc_lines(wdata.len(), alloc);
            let padded = pad_words(&wdata, r.lines, n);
            preload(r, &padded, &mut image, controller, true);
            read_regions.push(r);
        }
        let reads = partition(&read_regions, group.read_ports);
        // Compute the golden output.
        let out_fm = node.layer.golden(in_fm, skip_fm.map(|v| v.as_slice()), &weights, &bias);
        ensure!(
            out_fm.len() == node.layer.ofmap_words(),
            "{}: golden output size mismatch",
            node.layer.name()
        );
        let ofmap_region = alloc_lines(out_fm.len(), alloc);
        let out_padded = pad_words(&out_fm, ofmap_region.lines, n);
        // Image only (the simulation itself must write it to DRAM).
        preload(ofmap_region, &out_padded, &mut image, controller, false);
        let writes = partition(&[ofmap_region], group.write_ports);
        let write_data = split_write_data(&writes, ofmap_region, &out_padded, n);
        let expected_ports = expected_per_port(&reads, &image, n);
        steps.push_back(ExecStep {
            label: node.layer.name(),
            macs: node.layer.macs(),
            reads,
            writes,
            write_data,
            expected_ports,
            dram_check: Some((ofmap_region, out_padded)),
            write_seed: seed.wrapping_add(i as u64),
        });
        node_fms.push(out_fm);
        node_regions.push(ofmap_region);
    }
    let final_region = node_regions.last().copied();
    let final_fm = node_fms.pop().unwrap_or_default();
    Ok((steps, final_fm, final_region))
}

/// Edge budget generous enough for any legal run; hitting it means a
/// deadlock, which must be an error, not a hang.
fn edge_budget(tenants: &[TenantRt], n: usize, srv: Option<&ServingRun>) -> u64 {
    let mut cycles = 200_000u64;
    for (t, rt) in tenants.iter().enumerate() {
        cycles += 4 * rt.start_cycle;
        // A serving tenant holds its pass in `template` (steps is
        // empty until dispatch) and re-runs it once per batch; one
        // pass per request — times one extra pass per retry budget
        // slot, since a failed-fast request can re-dispatch — is the
        // upper bound.
        let passes = srv
            .map(|s| {
                (s.state.arrivals[t].len().max(1) as u64)
                    .saturating_mul(1 + s.state.spec.retries as u64)
            })
            .unwrap_or(1);
        for s in rt.steps.iter().chain(rt.template.iter()) {
            cycles += (64 * (s.read_lines() + s.write_lines() + 64) * n as u64
                + s.macs / 32
                + 20_000)
                .saturating_mul(passes);
        }
    }
    if let Some(s) = srv {
        // Idle inter-arrival gaps are simulated (or leapt) time too,
        // and so are backoff waits before retry re-admission.
        cycles += 4 * s.state.last_arrival();
        cycles = cycles.saturating_add(4 * s.state.backoff_horizon());
    }
    cycles.saturating_mul(8)
}

fn begin_next(sys: &mut System, t: usize, rt: &mut TenantRt) {
    match rt.steps.pop_front() {
        Some(step) => {
            rt.t0_ps = sys.now_ps();
            rt.load0 = sys.lps[t].load_cycles;
            rt.comp0 = sys.lps[t].compute_cycles;
            rt.drain0 = sys.lps[t].drain_cycles;
            sys.lps[t].begin_layer(&step.reads, step.macs);
            rt.cur = Some(step);
            rt.state = TState::Loading;
        }
        None => rt.state = TState::Finished,
    }
}

/// Advance one tenant's control state (called once per simulated edge).
fn service(sys: &mut System, t: usize, rt: &mut TenantRt) {
    match rt.state {
        TState::WaitStart => {
            if sys.fabric_cycles() >= rt.start_cycle {
                begin_next(sys, t, rt);
            }
        }
        TState::Loading => {
            if sys.lps[t].compute_done() {
                let cur = rt.cur.as_mut().expect("loading tenant has a current step");
                // Verify the read path delivered exactly the preloaded
                // tensors (transport golden check; no payloads exist to
                // check in elided mode).
                for (p, expect) in cur.expected_ports.iter().enumerate() {
                    if !expect.is_empty() && sys.lps[t].loaded(p) != &expect[..] {
                        rt.verified = false;
                    }
                }
                let data = std::mem::take(&mut cur.write_data);
                rt.supplied_lines += cur.write_lines();
                let writes = std::mem::take(&mut cur.writes);
                if sys.cfg.sim.payload.is_elided() {
                    sys.lps[t].supply_output_elided(&writes);
                } else {
                    sys.lps[t].supply_output(&writes, data);
                }
                rt.cur.as_mut().unwrap().writes = writes;
                rt.state = TState::Draining;
            }
        }
        TState::Draining => {
            if sys.lps[t].phase() == Phase::Done {
                rt.state = TState::WaitFlush;
            }
        }
        TState::WaitFlush => {
            let g = rt.group;
            let landed: u64 = (g.write_base..g.write_base + g.write_ports)
                .map(|p| sys.controller().write_lines_landed(p))
                .sum();
            debug_assert!(landed <= rt.supplied_lines);
            if landed == rt.supplied_lines {
                let cur = rt.cur.take().expect("flushing tenant has a current step");
                if let Some((region, expect)) = &cur.dram_check {
                    let dumped = sys.controller().dump(region.base, region.lines);
                    let mut words: Vec<Word> = Vec::with_capacity(expect.len());
                    for l in &dumped {
                        words.extend_from_slice(l.words());
                    }
                    if &words != expect {
                        rt.verified = false;
                    }
                }
                rt.report.layers.push(LayerReport {
                    layer: cur.label,
                    load_cycles: sys.lps[t].load_cycles - rt.load0,
                    compute_cycles: sys.lps[t].compute_cycles - rt.comp0,
                    drain_cycles: sys.lps[t].drain_cycles - rt.drain0,
                    lines_read: cur.read_lines(),
                    lines_written: cur.write_lines(),
                    sim_time_ps: sys.now_ps() - rt.t0_ps,
                    verified: rt.verified,
                });
                begin_next(sys, t, rt);
            }
        }
        TState::Finished => {}
        // Parked tenants move only when the serving dispatcher re-arms
        // them (in `drive`, which owns the `ServingRun`).
        TState::Parked => {}
    }
}

/// One tenant's forward-progress signature: a hash of everything that
/// moves when the tenant is healthy — its engine state, remaining
/// steps, its layer processor's phase/cycle counters, and the lines
/// landed on its write ports. A wedged tenant's signature freezes
/// (suppressed processors stop bumping even their stall counters),
/// while ordinary backpressure keeps bumping wait counters — which is
/// what makes the signature a precise wedge detector with no false
/// positives on merely-slow tenants.
fn progress_sig(sys: &System, t: usize, rt: &TenantRt) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    let mut mix = |v: u64| {
        h = (h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
    };
    mix(rt.state as u64);
    mix(rt.steps.len() as u64);
    mix(sys.lps[t].progress_sig());
    let g = rt.group;
    let landed: u64 = (g.write_base..g.write_base + g.write_ports)
        .map(|p| sys.controller().write_lines_landed(p))
        .sum();
    mix(landed);
    h
}

/// The per-tenant progress watchdog + degrade bookkeeping. Armed only
/// when a fault campaign is installed, so fault-free runs carry zero
/// watchdog state and stay bit-identical to pre-watchdog builds.
struct Watchdog {
    armed: bool,
    horizon: u64,
    policy: FaultPolicy,
    /// Last observed signature / the fabric cycle it last changed.
    sig: Vec<u64>,
    progress_cycle: Vec<u64>,
    /// Degrade policy: quiesce cycle, last force-drain observation.
    degraded_at: Vec<Option<u64>>,
    drain_count: Vec<u64>,
    drain_change_cycle: Vec<u64>,
    recovery_sampled: Vec<bool>,
}

/// Force-drain quiescence window: a quiesced tenant's recovery is
/// declared complete once its ports have drained nothing for this many
/// fabric cycles.
const DRAIN_SETTLE_CYCLES: u64 = 64;

impl Watchdog {
    fn new(sys: &System, tenants: usize) -> Watchdog {
        let spec = sys.fault_spec();
        Watchdog {
            armed: !spec.is_none(),
            horizon: spec.watchdog(),
            policy: spec.policy,
            sig: vec![0; tenants],
            progress_cycle: vec![0; tenants],
            degraded_at: vec![None; tenants],
            drain_count: vec![0; tenants],
            drain_change_cycle: vec![0; tenants],
            recovery_sampled: vec![false; tenants],
        }
    }

    /// Observe every tenant once per engine iteration; returns the
    /// tenant index whose watchdog fired (if any). Pure bookkeeping —
    /// the policy decision stays in `drive` where `tenants` is mutable.
    fn observe(&mut self, sys: &System, tenants: &[TenantRt]) -> Option<usize> {
        let now = sys.fabric_cycles();
        let mut fired = None;
        for (t, rt) in tenants.iter().enumerate() {
            if self.degraded_at[t].is_some() {
                // Degraded tenants are watched for drain settling, not
                // progress.
                let d = sys.quiesce_drained(t);
                if d != self.drain_count[t] {
                    self.drain_count[t] = d;
                    self.drain_change_cycle[t] = now;
                }
                continue;
            }
            if rt.state == TState::WaitStart
                || rt.state == TState::Finished
                || rt.state == TState::Parked
            {
                // Deliberately idle (not started, done, or between
                // serving batches) — not a wedge.
                self.progress_cycle[t] = now;
                continue;
            }
            let sig = progress_sig(sys, t, rt);
            if sig != self.sig[t] {
                self.sig[t] = sig;
                self.progress_cycle[t] = now;
            } else if now - self.progress_cycle[t] >= self.horizon && fired.is_none() {
                fired = Some(t);
            }
        }
        fired
    }

    /// A degraded tenant whose force-drain has settled (one-shot).
    fn settled(&mut self, now: u64) -> Option<(usize, u64)> {
        for t in 0..self.degraded_at.len() {
            let Some(at) = self.degraded_at[t] else { continue };
            if !self.recovery_sampled[t]
                && now.saturating_sub(self.drain_change_cycle[t]) >= DRAIN_SETTLE_CYCLES
            {
                self.recovery_sampled[t] = true;
                return Some((t, self.drain_change_cycle[t].max(at) - at));
            }
        }
        None
    }

    fn any_degraded(&self) -> bool {
        self.degraded_at.iter().any(|d| d.is_some())
    }
}

/// Drive every tenant to completion (or a typed watchdog verdict).
///
/// With a [`ServingRun`] installed, every serving decision — admission,
/// batch dispatch, completion — happens on the exact fabric edge its
/// condition becomes observable, before the leap decision at the bottom
/// of the loop. That ordering is the leap-exactness argument: when the
/// engine considers leaping, every serving event due at or before `now`
/// has already been processed, so [`ServingRun::next_event`] is
/// strictly in the future and capping the leap there reproduces the
/// stepwise schedule cycle for cycle.
fn drive(
    sys: &mut System,
    tenants: &mut [TenantRt],
    mut srv: Option<&mut ServingRun>,
) -> Result<()> {
    let n = sys.cfg.geometry.words_per_line();
    let max_edges = edge_budget(tenants, n, srv.as_deref());
    let mut edges = 0u64;
    let mut dog = Watchdog::new(sys, tenants.len());
    loop {
        let now = sys.fabric_cycles();
        if let Some(srv) = srv.as_deref_mut() {
            srv.admit(now, &mut sys.stats);
            // Expiry runs after admission (a request arriving on its
            // own deadline edge dies immediately) and before any
            // dispatch below — expiry beats dispatch on ties, on every
            // backend.
            srv.expire(now, &mut sys.stats);
        }
        let mut all_done = true;
        for (t, rt) in tenants.iter_mut().enumerate() {
            service(sys, t, rt);
            // A degrade-quiesced tenant's ports are dead: its wedged
            // batch never completes and it must not be re-armed.
            if let Some(srv) = srv.as_deref_mut().filter(|_| dog.degraded_at[t].is_none()) {
                // Pass boundary: the batch that just finished completes
                // on this edge; the tenant re-parks if more work exists.
                if rt.state == TState::Finished && srv.in_flight(t) > 0 {
                    srv.complete(t, now, &mut sys.stats);
                    if srv.has_more(t) {
                        rt.state = TState::Parked;
                    }
                }
                // Batcher: a parked tenant whose policy fires re-arms
                // its template and begins the pass on this same edge.
                if rt.state == TState::Parked {
                    if srv.dispatch(t, now, &mut sys.stats).is_some() {
                        rt.steps = rt.template.iter().cloned().collect();
                        begin_next(sys, t, rt);
                    } else if !srv.has_more(t) {
                        // Every remaining request was shed, timed out,
                        // or failed for good: nothing can ever dispatch
                        // again, so the tenant is done (pre-overload
                        // specs never reach this — a parked tenant
                        // always had live work).
                        rt.state = TState::Finished;
                    }
                }
            }
            all_done &= rt.state == TState::Finished;
        }
        if sys.profiling_enabled() {
            // Queue-depth and cumulative-shed timelines: sampled after
            // this edge's admission and dispatch decisions,
            // change-driven inside the recorder.
            if let Some(srv) = srv.as_deref() {
                sys.obs_serving_depth(srv.total_queued());
                sys.obs_serving_shed(srv.total_shed());
            }
        }
        if dog.armed {
            if let Some(t) = dog.observe(sys, tenants) {
                let now = sys.fabric_cycles();
                let state = format!("{:?}", tenants[t].state);
                match dog.policy {
                    FaultPolicy::Error => {
                        // The typed verdict ISSUE 6 requires: a wedged
                        // tenant terminates the run with a state dump,
                        // never a hang or a panic. Fires at the same
                        // elapsed cycle under stepwise and leap backends
                        // (suppression disables leaping, so the frozen
                        // span is stepped in both).
                        let dump = format!(
                            "  engine states: {:?}\n{}",
                            tenants.iter().map(|rt| rt.state).collect::<Vec<_>>(),
                            sys.state_dump_with(srv.as_deref())
                        );
                        return Err(anyhow::Error::new(SimError::TenantStalled {
                            tenant: t,
                            cycle: now,
                            state,
                            dump,
                        }));
                    }
                    FaultPolicy::Degrade => {
                        // Quiesce the wedged tenant's port group and keep
                        // the rest of the fabric running; its in-flight
                        // reads are force-drained by the system so shared
                        // buffers cannot wedge the survivors.
                        sys.quiesce_tenant(t);
                        tenants[t].state = TState::Finished;
                        tenants[t].verified = false;
                        dog.degraded_at[t] = Some(now);
                        dog.drain_count[t] = sys.quiesce_drained(t);
                        dog.drain_change_cycle[t] = now;
                        // Serving hand-off: the wedged tenant's
                        // in-flight batch will never complete. Fail it
                        // fast so each request either schedules a
                        // backed-off retry or counts in
                        // serving.requests_failed — instead of
                        // silently stranding in `inflight` forever.
                        if let Some(srv) = srv.as_deref_mut() {
                            srv.fail_batch(t, now, &mut sys.stats);
                        }
                        all_done = false;
                    }
                }
            }
            if let Some((_, recovery)) = dog.settled(sys.fabric_cycles()) {
                sys.stats.sample(SampleId::DegradeRecoveryCycles, recovery);
            }
        }
        if all_done {
            if dog.any_degraded() {
                // A degraded tenant that never settled before the run
                // ended: report the drain time observed so far.
                while let Some((_, recovery)) = dog.settled(u64::MAX) {
                    sys.stats.sample(SampleId::DegradeRecoveryCycles, recovery);
                }
                // Degraded goodput: lines each surviving tenant still
                // moved through its completed layers.
                for (t, rt) in tenants.iter().enumerate() {
                    if dog.degraded_at[t].is_some() {
                        continue;
                    }
                    let lines: u64 =
                        rt.report.layers.iter().map(|l| l.lines_read + l.lines_written).sum();
                    sys.stats.sample(SampleId::DegradeGoodputLines, lines);
                }
            }
            return Ok(());
        }
        // Leap backend: skip the idle span, but never past a staggered
        // tenant's start cycle — `service` observes `fabric_cycles`
        // between edges, and a tenant must begin on exactly the edge a
        // stepwise run would give it. (All other `service` conditions
        // are covered by the system-level horizon: a waiting-for-flush
        // or loading tenant keeps some component non-idle. Fault edges
        // — slowdown windows, wedges, quiesces — cap or disable the
        // leap inside `try_leap_idle` itself, which is what makes the
        // watchdog fire at identical cycles stepwise-vs-leap.)
        let mut cap = u64::MAX;
        // Which engine-level term set `cap` — pure attribution for the
        // profiler (recorded only when the cap turns out to be the
        // binding constraint of a taken leap). Computing it is branch
        // arithmetic on values already at hand; it never touches
        // simulation state, so the zero-perturbation contract holds.
        let mut cap_src = CapSource::EdgeBudget;
        for rt in tenants.iter() {
            if rt.state == TState::WaitStart {
                // start_cycle > fabric_cycles here: service() above
                // starts any tenant whose cycle has arrived.
                let d = rt.start_cycle - sys.fabric_cycles();
                if d < cap {
                    cap_src = CapSource::TenantStart;
                }
                cap = cap.min(d);
            }
        }
        if let Some(srv) = srv.as_deref() {
            // Serving horizon: never leap past the next arrival, a
            // parked tenant's max-wait dispatch deadline, a request's
            // deadline-expiry edge, or a backed-off retry's
            // re-admission cycle. Strictly future because
            // admit/expire/dispatch above already processed every
            // event due at `now`.
            let parked: Vec<bool> =
                tenants.iter().map(|rt| rt.state == TState::Parked).collect();
            let next = srv.next_event(&parked);
            if next != u64::MAX {
                debug_assert!(next > sys.fabric_cycles());
                let d = next - sys.fabric_cycles();
                if d < cap {
                    cap_src = CapSource::ServingHorizon;
                }
                cap = cap.min(d);
            }
        }
        if sys.profiling_enabled() {
            sys.obs_note_cap_source(cap_src);
        }
        match sys.try_leap_idle(cap, max_edges - edges) {
            Some(leap) => edges += leap.steps,
            None => {
                sys.step();
                edges += 1;
            }
        }
        ensure!(
            edges < max_edges,
            "scenario stalled after {edges} edges (states: {:?})\n{}  stats:\n{}",
            tenants.iter().map(|t| t.state).collect::<Vec<_>>(),
            sys.state_dump_with(srv.as_deref()),
            sys.stats
        );
    }
}

fn build_outcome(
    sc_name: &str,
    sys: &System,
    tenants: Vec<TenantRt>,
    serving: Option<ServingReport>,
) -> ScenarioOutcome {
    let mut outs = Vec::with_capacity(tenants.len());
    for (t, rt) in tenants.into_iter().enumerate() {
        let g = rt.group;
        let final_dram = match rt.final_region {
            // No payload landed in elided mode — there is nothing to
            // dump (and shadow lines have no words to flatten).
            Some(_) if sys.cfg.sim.payload.is_elided() => Vec::new(),
            Some(r) => sys
                .controller()
                .dump(r.base, r.lines)
                .iter()
                .flat_map(|l| l.words().to_vec())
                .collect(),
            None => Vec::new(),
        };
        outs.push(TenantOutcome {
            network: rt.network,
            read_waits: (0..g.read_ports).map(|p| sys.lps[t].read_wait_cycles(p)).collect(),
            write_waits: (0..g.write_ports).map(|p| sys.lps[t].write_wait_cycles(p)).collect(),
            verified: rt.verified && rt.report.layers.iter().all(|l| l.verified),
            report: rt.report,
            final_fm: rt.final_fm,
            final_dram,
        });
    }
    ScenarioOutcome {
        scenario: sc_name.to_string(),
        design: sys.cfg.design.name(),
        fabric_mhz: sys.fabric_mhz,
        fabric_cycles: sys.fabric_cycles(),
        mem_cycles: sys.mem_cycles(),
        now_ps: sys.now_ps(),
        tenants: outs,
        stats: sys.stats.clone(),
        serving,
        profile: None,
    }
}

/// Canonical timing-entry list (non-movement counters, sample series,
/// per-tenant per-port waits), sorted by key. ONE construction shared
/// by capture (`snapshot_expect`) and verification (`verify_replay`) so
/// the two key schemes can never drift apart.
fn timing_entries(
    stats: &crate::sim::Stats,
    waits: &[(Vec<u64>, Vec<u64>)],
) -> Vec<(String, u64)> {
    let mut out: Vec<(String, u64)> = Vec::new();
    for &id in Counter::ALL.iter() {
        let name = id.name();
        if MOVEMENT_COUNTERS.contains(&name) {
            continue;
        }
        let v = stats.count(id);
        // Untouched serving entries are elided so serving-free captures
        // stay byte-identical to pre-serving traces (the serving
        // registry entries would otherwise appear as zeros in every
        // classic trace).
        if v == 0 && name.starts_with("serving.") {
            continue;
        }
        out.push((name.to_string(), v));
    }
    for &id in SampleId::ALL.iter() {
        let s = stats.series_of(id);
        if s.count == 0 && id.name().starts_with("serving.") {
            continue;
        }
        out.push((format!("series.{}.count", id.name()), s.count));
        out.push((format!("series.{}.sum", id.name()), s.sum));
    }
    for (t, (reads, writes)) in waits.iter().enumerate() {
        for (p, &w) in reads.iter().enumerate() {
            out.push((format!("wait.t{t}.read.{p}"), w));
        }
        for (p, &w) in writes.iter().enumerate() {
            out.push((format!("wait.t{t}.write.{p}"), w));
        }
    }
    out.sort();
    out
}

/// Per-tenant (read waits, write waits) of a live system.
fn system_waits(sys: &System) -> Vec<(Vec<u64>, Vec<u64>)> {
    sys.lps
        .iter()
        .map(|lp| {
            let g = lp.group();
            (
                (0..g.read_ports).map(|p| lp.read_wait_cycles(p)).collect(),
                (0..g.write_ports).map(|p| lp.write_wait_cycles(p)).collect(),
            )
        })
        .collect()
}

/// The trace expect block for the current system state (full timing).
fn snapshot_expect(sys: &System) -> TraceExpect {
    let mut exact: Vec<(String, u64)> = MOVEMENT_COUNTERS
        .iter()
        .map(|&name| (name.to_string(), sys.stats.get(name)))
        .collect();
    exact.sort();
    TraceExpect {
        timing_recorded: true,
        fabric_cycles: sys.fabric_cycles(),
        mem_cycles: sys.mem_cycles(),
        now_ps: sys.now_ps(),
        exact,
        timing: timing_entries(&sys.stats, &system_waits(sys)),
    }
}

fn build_tenants(
    sc: &Scenario,
    groups: &[PortGroup],
    sys: &mut System,
) -> Result<Vec<TenantRt>> {
    let n = sys.cfg.geometry.words_per_line();
    let mut alloc: LineAddr = 0;
    let mut tenants = Vec::with_capacity(sc.tenants.len());
    let elided = sys.cfg.sim.payload.is_elided();
    for (i, (spec, &group)) in sc.tenants.iter().zip(groups.iter()).enumerate() {
        let (steps, final_fm, final_region) = precompute_tenant(
            &spec.net,
            spec.seed,
            group,
            n,
            &mut alloc,
            sys.controller_mut(),
            elided,
        )
        .with_context(|| format!("tenant {i} ({})", spec.net.name))?;
        // Serving mode: the precomputed pass becomes the re-armable
        // template and the tenant parks until its first batch.
        let serving = !sc.serving.is_none();
        let (steps, template, state) = if serving {
            (VecDeque::new(), steps.into_iter().collect(), TState::Parked)
        } else {
            (steps, Vec::new(), TState::WaitStart)
        };
        tenants.push(TenantRt {
            network: spec.net.name,
            group,
            start_cycle: spec.start_cycle,
            steps,
            template,
            state,
            cur: None,
            supplied_lines: 0,
            t0_ps: 0,
            load0: 0,
            comp0: 0,
            drain0: 0,
            report: RunReport {
                network: spec.net.name,
                design: sys.cfg.design.name(),
                fabric_mhz: sys.fabric_mhz,
                layers: Vec::new(),
            },
            verified: true,
            final_fm,
            final_region,
        });
    }
    Ok(tenants)
}

/// Host wall-clock per run phase. Armed only when profiling: a
/// disabled clock never calls `Instant::now`, and an enabled one only
/// records into the profile report — host time can never reach stats,
/// traces, or cache keys.
struct PhaseClock {
    t: Option<std::time::Instant>,
    spans: Vec<(&'static str, f64)>,
}

impl PhaseClock {
    fn new(enabled: bool) -> PhaseClock {
        PhaseClock { t: enabled.then(std::time::Instant::now), spans: Vec::new() }
    }

    /// Close the span named `phase` (elapsed since the previous lap).
    fn lap(&mut self, phase: &'static str) {
        if let Some(t) = self.t.as_mut() {
            self.spans.push((phase, t.elapsed().as_secs_f64()));
            *t = std::time::Instant::now();
        }
    }
}

/// Run a scenario end to end; every tenant's data movement is verified
/// against the golden model (read path, DRAM content).
pub fn run_scenario(sc: &Scenario) -> Result<ScenarioOutcome> {
    Ok(run_impl(sc, false, None)?.0)
}

/// Run a scenario and capture its canonical trace (with a fully
/// recorded expect block).
pub fn run_scenario_captured(sc: &Scenario) -> Result<(ScenarioOutcome, ScenarioTrace)> {
    let (out, trace) = run_impl(sc, true, None)?;
    Ok((out, trace.expect("capture requested")))
}

/// The shared run path. `profile` is the utilization-window size; when
/// set, the system records cycle attribution and the outcome carries a
/// [`RunProfile`] — with bit-identical stats, cycles, and traces either
/// way (the profile-conformance suite enforces this).
pub(crate) fn run_impl(
    sc: &Scenario,
    capture: bool,
    profile: Option<u64>,
) -> Result<(ScenarioOutcome, Option<ScenarioTrace>)> {
    sc.validate()?;
    let mut clock = PhaseClock::new(profile.is_some());
    let groups = sc.groups()?;
    let mut sys = System::builder(sc.cfg.clone())
        .port_groups(&groups)
        .faults(&sc.faults)
        .build()?;
    if let Some(window) = profile {
        sys.enable_profiling(window);
    }
    clock.lap("build");
    let mut tenants = build_tenants(sc, &groups, &mut sys)?;
    let mut srv: Option<ServingRun> = if sc.serving.is_none() {
        None
    } else {
        Some(ServingRun::new(ServingState::build(&sc.serving, sc.tenants.len())?))
    };
    let trace_steps: Option<Vec<TraceStep>> = capture.then(|| {
        let mut steps = Vec::new();
        for (t, rt) in tenants.iter().enumerate() {
            // Exactly one of `steps` / `template` is populated
            // (classic vs serving), so chaining covers both.
            for s in rt.steps.iter().chain(rt.template.iter()) {
                steps.push(TraceStep {
                    tenant: t,
                    label: s.label.to_string(),
                    macs: s.macs,
                    write_seed: s.write_seed,
                    reads: s
                        .reads
                        .iter()
                        .map(|ps| ps.runs.iter().map(|r| (r.base, r.lines as u64)).collect())
                        .collect(),
                    writes: s
                        .writes
                        .iter()
                        .map(|ps| ps.runs.iter().map(|r| (r.base, r.lines as u64)).collect())
                        .collect(),
                });
            }
        }
        steps
    });
    clock.lap("precompute");
    drive(&mut sys, &mut tenants, srv.as_mut())?;
    clock.lap("drive");
    let trace = trace_steps.map(|steps| ScenarioTrace {
        header: TraceHeader {
            scenario: sc.name.clone(),
            // The parseable spec (not the bare family name): replay must
            // reconstruct parameterized designs exactly.
            design: sys.cfg.design.spec(),
            w_line: sc.cfg.geometry.w_line,
            w_acc: sc.cfg.geometry.w_acc,
            read_ports: sc.cfg.geometry.read_ports,
            write_ports: sc.cfg.geometry.write_ports,
            max_burst: sc.cfg.geometry.max_burst,
            dotprod_units: sc.cfg.dotprod_units,
            rotator_stages: sc.cfg.rotator_stages,
            mem_mhz: sc.cfg.mem_clock_mhz,
            fabric_mhz: sys.fabric_mhz,
            ddr3_timing: sc.cfg.ddr3_timing,
            cmd_depth: sc.cfg.channel_depths.cmd,
            rd_line_depth: sc.cfg.channel_depths.rd_line,
            wr_data_depth: sc.cfg.channel_depths.wr_data,
            seed: sc.cfg.seed,
            // Recording the spec (not the materialized windows) is
            // enough: the whole schedule re-derives from it, so faulty
            // runs capture/replay bit-exactly.
            faults: sc.faults.clone(),
            // Same story for serving: arrivals re-materialize from the
            // spec, so a replay re-arms the identical request stream.
            serving: sc.serving.clone(),
            tenants: groups
                .iter()
                .zip(sc.tenants.iter())
                .map(|(g, spec)| TraceTenant {
                    read_base: g.read_base,
                    read_ports: g.read_ports,
                    write_base: g.write_base,
                    write_ports: g.write_ports,
                    start_cycle: spec.start_cycle,
                })
                .collect(),
        },
        steps,
        expect: snapshot_expect(&sys),
    });
    let serving = srv.map(|s| ServingReport::from_run(&s));
    let mut outcome = build_outcome(&sc.name, &sys, tenants, serving);
    clock.lap("report");
    outcome.profile = sys
        .take_profile()
        .map(|sp| Box::new(RunProfile { sys: sp, host: clock.spans }));
    Ok((outcome, trace))
}

/// Rebuild the system a trace describes, under the given backend.
/// Rebuild the system a trace describes (faults installed), under the
/// given backend.
fn system_from_header(
    h: &TraceHeader,
    backend: crate::config::SimBackend,
) -> Result<(System, Vec<PortGroup>)> {
    let design = Design::parse(&h.design)
        .ok_or_else(|| anyhow::anyhow!("trace names unknown design {:?}", h.design))?;
    let cfg = crate::config::SystemConfig {
        design,
        geometry: crate::types::Geometry {
            w_line: h.w_line,
            w_acc: h.w_acc,
            read_ports: h.read_ports,
            write_ports: h.write_ports,
            max_burst: h.max_burst,
        },
        dotprod_units: h.dotprod_units,
        mem_clock_mhz: h.mem_mhz,
        fabric_clock_mhz: Some(h.fabric_mhz),
        ddr3_timing: h.ddr3_timing,
        rotator_stages: h.rotator_stages,
        channel_depths: crate::config::ChannelDepths {
            cmd: h.cmd_depth,
            rd_line: h.rd_line_depth,
            wr_data: h.wr_data_depth,
        },
        seed: h.seed,
        sim: backend,
    };
    let groups: Vec<PortGroup> = h
        .tenants
        .iter()
        .map(|t| PortGroup {
            read_base: t.read_base,
            read_ports: t.read_ports,
            write_base: t.write_base,
            write_ports: t.write_ports,
        })
        .collect();
    let sys = System::builder(cfg).port_groups(&groups).faults(&h.faults).build()?;
    Ok((sys, groups))
}

fn sched_from_runs(runs: &[Vec<(u64, u64)>]) -> Vec<PortSchedule> {
    runs.iter()
        .map(|rs| PortSchedule {
            runs: rs.iter().map(|&(base, lines)| Region { base, lines: lines as usize }).collect(),
        })
        .collect()
}

/// Re-drive the interconnect from a trace: no workload generation, no
/// golden math — pure data movement with synthesized write words.
pub fn replay(trace: &ScenarioTrace) -> Result<ScenarioOutcome> {
    replay_impl(trace, crate::config::SimBackend::full(), None)
}

/// [`replay`] under an explicit simulation backend. Superseded by
/// [`crate::run::RunOptions::replay`].
#[deprecated(since = "0.7.0", note = "use run::RunOptions::new().backend(..).replay(..)")]
pub fn replay_with(
    trace: &ScenarioTrace,
    backend: crate::config::SimBackend,
) -> Result<ScenarioOutcome> {
    replay_impl(trace, backend, None)
}

/// [`replay`] under an explicit simulation backend. Trace headers
/// deliberately don't record a backend (any backend reproduces the
/// same stats), so the choice is the caller's: the CLI's `--payload` /
/// `--edges` flags and the fast-backend conformance suite both land
/// here (via [`crate::run::RunOptions`]).
pub(crate) fn replay_impl(
    trace: &ScenarioTrace,
    backend: crate::config::SimBackend,
    profile: Option<u64>,
) -> Result<ScenarioOutcome> {
    trace.validate()?;
    let mut clock = PhaseClock::new(profile.is_some());
    let (mut sys, groups) = system_from_header(&trace.header, backend)?;
    if let Some(window) = profile {
        sys.enable_profiling(window);
    }
    clock.lap("build");
    let n = sys.cfg.geometry.words_per_line();
    let elided = backend.payload.is_elided();
    let mut tenants: Vec<TenantRt> = groups
        .iter()
        .zip(trace.header.tenants.iter())
        .map(|(&group, ht)| TenantRt {
            network: "replay",
            group,
            start_cycle: ht.start_cycle,
            steps: VecDeque::new(),
            template: Vec::new(),
            state: TState::WaitStart,
            cur: None,
            supplied_lines: 0,
            t0_ps: 0,
            load0: 0,
            comp0: 0,
            drain0: 0,
            report: RunReport {
                network: "replay",
                design: sys.cfg.design.name(),
                fabric_mhz: sys.fabric_mhz,
                layers: Vec::new(),
            },
            verified: true,
            final_fm: Vec::new(),
            final_region: None,
        })
        .collect();
    for step in &trace.steps {
        let reads = sched_from_runs(&step.reads);
        let writes = sched_from_runs(&step.writes);
        let write_data: Vec<VecDeque<Word>> = if elided {
            Vec::new() // shadow counts come from the schedules at supply time
        } else {
            writes
                .iter()
                .map(|s| {
                    let mut q = VecDeque::new();
                    for run in &s.runs {
                        for a in run.base..run.end() {
                            for lane in 0..n as u64 {
                                q.push_back(ScenarioTrace::synth_word(step.write_seed, a, lane));
                            }
                        }
                    }
                    q
                })
                .collect()
        };
        let expected_ports = vec![Vec::new(); reads.len()];
        tenants[step.tenant].steps.push_back(ExecStep {
            label: "replayed",
            macs: step.macs,
            reads,
            writes,
            write_data,
            expected_ports,
            dram_check: None,
            write_seed: step.write_seed,
        });
    }
    // A serving trace re-arms the identical request stream: arrivals
    // re-materialize from the recorded spec, and the replayed steps
    // become each tenant's batch template.
    let mut srv: Option<ServingRun> = if trace.header.serving.is_none() {
        None
    } else {
        for rt in tenants.iter_mut() {
            rt.template = std::mem::take(&mut rt.steps).into_iter().collect();
            rt.state = TState::Parked;
        }
        Some(ServingRun::new(ServingState::build(&trace.header.serving, tenants.len())?))
    };
    clock.lap("precompute");
    drive(&mut sys, &mut tenants, srv.as_mut())?;
    clock.lap("drive");
    let serving = srv.map(|s| ServingReport::from_run(&s));
    let mut outcome = build_outcome(&trace.header.scenario, &sys, tenants, serving);
    clock.lap("report");
    outcome.profile = sys
        .take_profile()
        .map(|sp| Box::new(RunProfile { sys: sp, host: clock.spans }));
    Ok(outcome)
}

/// Replay `trace` and assert it reproduces the recorded expectations:
/// every `exact` (data-movement) counter always, and — when the trace
/// has timing recorded — the exact cycle counts, every timing counter,
/// and the per-port wait cycles.
pub fn verify_replay(trace: &ScenarioTrace) -> Result<ScenarioOutcome> {
    verify_replay_impl(trace, crate::config::SimBackend::full(), None)
}

/// [`verify_replay`] under an explicit backend. Superseded by
/// [`crate::run::RunOptions::verify_replay`].
#[deprecated(
    since = "0.7.0",
    note = "use run::RunOptions::new().backend(..).verify_replay(..)"
)]
pub fn verify_replay_with(
    trace: &ScenarioTrace,
    backend: crate::config::SimBackend,
) -> Result<ScenarioOutcome> {
    verify_replay_impl(trace, backend, None)
}

/// [`verify_replay`] under an explicit backend — the fast-backend
/// conformance path: a trace captured by a full run must replay to the
/// same counters, cycles, and waits under payload elision and edge
/// leaping (the recorded expect block is the cross-backend oracle).
pub(crate) fn verify_replay_impl(
    trace: &ScenarioTrace,
    backend: crate::config::SimBackend,
    profile: Option<u64>,
) -> Result<ScenarioOutcome> {
    let out = replay_impl(trace, backend, profile)?;
    for (name, want) in &trace.expect.exact {
        let got = out.stats.get(name);
        ensure!(
            got == *want,
            "replay diverged on exact counter {name}: trace says {want}, replay got {got}"
        );
    }
    if trace.expect.timing_recorded {
        ensure!(
            out.fabric_cycles == trace.expect.fabric_cycles,
            "replay fabric_cycles {} != recorded {}",
            out.fabric_cycles,
            trace.expect.fabric_cycles
        );
        ensure!(
            out.mem_cycles == trace.expect.mem_cycles,
            "replay mem_cycles {} != recorded {}",
            out.mem_cycles,
            trace.expect.mem_cycles
        );
        ensure!(
            out.now_ps == trace.expect.now_ps,
            "replay now_ps {} != recorded {}",
            out.now_ps,
            trace.expect.now_ps
        );
        let got = replay_timing_map(&out);
        for (name, want) in &trace.expect.timing {
            let g = got.get(name).copied();
            ensure!(
                g == Some(*want),
                "replay diverged on timing entry {name}: trace says {want}, replay got {g:?}"
            );
        }
    }
    Ok(out)
}

/// The timing-entry map of a finished replay, keyed by the same shared
/// `timing_entries` scheme the capture side uses.
fn replay_timing_map(out: &ScenarioOutcome) -> std::collections::BTreeMap<String, u64> {
    let waits: Vec<(Vec<u64>, Vec<u64>)> = out
        .tenants
        .iter()
        .map(|t| (t.read_waits.clone(), t.write_waits.clone()))
        .collect();
    timing_entries(&out.stats, &waits).into_iter().collect()
}
