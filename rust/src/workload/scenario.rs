//! Scenario descriptors: which networks run on which port groups of one
//! simulated system.
//!
//! A [`Scenario`] maps one or more zoo networks ([`crate::workload::zoo`])
//! onto the fabric: a single network owning every port, multiple tenant
//! networks sharing the fabric on disjoint port groups, or phase-offset
//! staggered starts (a tenant idles until a given fabric cycle). The
//! TOML form embeds a full system config plus `[scenario]` /
//! `[tenant.N]` sections:
//!
//! ```text
//! [scenario]
//! name = "multi-tenant-mix"
//!
//! [system]
//! design = "medusa"
//! seed = 7
//! [geometry]
//! w_line = 128
//! read_ports = 8
//! write_ports = 8
//! ...
//!
//! [tenant.0]
//! network = "resnet-tiny"   # a zoo name
//! read_ports = 4            # this tenant's share (assigned in order)
//! write_ports = 4
//! start_cycle = 0           # optional phase offset (fabric cycles)
//! seed = 11                 # optional per-tenant workload seed
//! ```
//!
//! Port groups are carved sequentially: tenant 0 gets the lowest port
//! indices, tenant 1 the next, and so on. A single tenant may omit the
//! port counts to take the whole fabric.

use crate::accel::layer_processor::PortGroup;
use crate::config::{parse_toml_subset, SystemConfig, Value};
use crate::fault::FaultSpec;
use crate::serving::{OverloadPolicy, ServingSpec};
use crate::workload::graph::WorkloadNet;
use crate::workload::zoo;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// The default per-tenant workload seed: the system seed hashed with
/// the tenant index. THE one formula — `Scenario::single`, the file
/// parser's default, and `Scenario::reseed` all route through here so
/// CLI `--seed` runs, file defaults, and builtins can never drift.
pub fn tenant_seed(system_seed: u64, idx: usize) -> u64 {
    system_seed ^ 0xda7a ^ (idx as u64).wrapping_mul(0x9e37_79b9)
}

/// One tenant: a network, its share of the fabric ports, and its phase.
#[derive(Clone, Debug)]
pub struct TenantSpec {
    pub net: WorkloadNet,
    /// Read/write ports this tenant owns (0 = "all of them", only valid
    /// for a lone tenant).
    pub read_ports: usize,
    pub write_ports: usize,
    /// Fabric cycle before which the tenant stays idle (staggered
    /// starts).
    pub start_cycle: u64,
    /// Workload seed (inputs + weights). Defaults to the system seed
    /// hashed with the tenant index.
    pub seed: u64,
}

/// A complete scenario: system config + tenant mapping + (optional)
/// fault-injection campaign (`[faults]` section; see `fault::FaultSpec`)
/// + (optional) open-loop serving front-end (`[serving]` section; see
/// `serving::ServingSpec`).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub name: String,
    pub cfg: SystemConfig,
    pub tenants: Vec<TenantSpec>,
    pub faults: FaultSpec,
    pub serving: ServingSpec,
}

impl Scenario {
    /// A single network owning the whole fabric of `cfg`.
    pub fn single(name: &str, cfg: SystemConfig, net: WorkloadNet) -> Scenario {
        let seed = tenant_seed(cfg.seed, 0);
        Scenario {
            name: name.to_string(),
            tenants: vec![TenantSpec { net, read_ports: 0, write_ports: 0, start_cycle: 0, seed }],
            cfg,
            faults: FaultSpec::none(),
            serving: ServingSpec::none(),
        }
    }

    /// Load from a TOML-subset file (see module docs).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading scenario {}", path.as_ref().display()))?;
        Self::from_str(&text)
            .with_context(|| format!("parsing scenario {}", path.as_ref().display()))
    }

    /// Parse from scenario text.
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self> {
        let raw = parse_toml_subset(text)?;
        let mut cfg = SystemConfig::default();
        let mut name = String::new();
        let mut faults = FaultSpec::none();
        let mut serving = ServingSpec::none();
        let mut tenant_keys: BTreeMap<usize, BTreeMap<String, Value>> = BTreeMap::new();
        for (key, value) in &raw {
            if cfg.apply_key(key, value)? {
                continue;
            }
            if key == "scenario.name" {
                name = value.as_str()?.to_string();
                continue;
            }
            if faults.apply_key(key, value)? {
                continue;
            }
            if serving.apply_key(key, value)? {
                continue;
            }
            if let Some(rest) = key.strip_prefix("tenant.") {
                let (idx, field) = rest
                    .split_once('.')
                    .ok_or_else(|| anyhow!("malformed tenant key {key:?}"))?;
                let idx: usize =
                    idx.parse().map_err(|_| anyhow!("bad tenant index in {key:?}"))?;
                tenant_keys.entry(idx).or_default().insert(field.to_string(), value.clone());
                continue;
            }
            bail!("unknown scenario key {key:?}");
        }
        ensure!(!name.is_empty(), "scenario file must set scenario.name");
        ensure!(!tenant_keys.is_empty(), "scenario needs at least one [tenant.N]");
        let expected: Vec<usize> = (0..tenant_keys.len()).collect();
        let got: Vec<usize> = tenant_keys.keys().copied().collect();
        ensure!(got == expected, "tenant indices must be 0..N contiguous, got {got:?}");
        let mut tenants = Vec::with_capacity(tenant_keys.len());
        for (idx, fields) in &tenant_keys {
            let mut net = None;
            let mut read_ports = 0usize;
            let mut write_ports = 0usize;
            let mut start_cycle = 0u64;
            let mut seed = tenant_seed(cfg.seed, *idx);
            for (field, value) in fields {
                match field.as_str() {
                    "network" => {
                        let n = value.as_str()?;
                        net = Some(zoo::by_name(n).ok_or_else(|| {
                            anyhow!("tenant {idx}: unknown network {n:?} (zoo: {:?})", zoo::names())
                        })?);
                    }
                    "read_ports" => read_ports = value.as_usize()?,
                    "write_ports" => write_ports = value.as_usize()?,
                    "start_cycle" => start_cycle = value.as_usize()? as u64,
                    "seed" => seed = value.as_usize()? as u64,
                    other => bail!("tenant {idx}: unknown key {other:?}"),
                }
            }
            let net = net.ok_or_else(|| anyhow!("tenant {idx}: missing network"))?;
            tenants.push(TenantSpec { net, read_ports, write_ports, start_cycle, seed });
        }
        let sc = Scenario { name, cfg, tenants, faults, serving };
        sc.validate()?;
        Ok(sc)
    }

    /// Override the system seed and re-derive every tenant's workload
    /// seed from it with the default formula (the CLI `--seed` path).
    /// Explicit per-tenant seeds from a scenario file are replaced —
    /// a reseeded scenario is a wholly new workload instance.
    pub fn reseed(&mut self, seed: u64) {
        self.cfg.seed = seed;
        for (i, t) in self.tenants.iter_mut().enumerate() {
            t.seed = tenant_seed(seed, i);
        }
    }

    /// Carve the fabric into per-tenant port groups, in tenant order.
    pub fn groups(&self) -> Result<Vec<PortGroup>> {
        let geom = &self.cfg.geometry;
        let mut out = Vec::with_capacity(self.tenants.len());
        let (mut rcur, mut wcur) = (0usize, 0usize);
        for (i, t) in self.tenants.iter().enumerate() {
            let (r, w) = if t.read_ports == 0 && t.write_ports == 0 {
                ensure!(
                    self.tenants.len() == 1,
                    "tenant {i}: port counts are required when sharing the fabric"
                );
                (geom.read_ports, geom.write_ports)
            } else {
                ensure!(
                    t.read_ports >= 1 && t.write_ports >= 1,
                    "tenant {i}: needs at least one read and one write port"
                );
                (t.read_ports, t.write_ports)
            };
            let g = PortGroup { read_base: rcur, read_ports: r, write_base: wcur, write_ports: w };
            g.validate(geom).with_context(|| format!("tenant {i} port group"))?;
            rcur += r;
            wcur += w;
            out.push(g);
        }
        ensure!(
            rcur <= geom.read_ports && wcur <= geom.write_ports,
            "tenants claim {rcur} read / {wcur} write ports; geometry has {} / {}",
            geom.read_ports,
            geom.write_ports
        );
        Ok(out)
    }

    pub fn validate(&self) -> Result<()> {
        self.cfg.validate()?;
        ensure!(!self.tenants.is_empty(), "scenario {:?} has no tenants", self.name);
        for t in &self.tenants {
            t.net.validate()?;
        }
        self.faults
            .validate(Some(self.tenants.len()))
            .with_context(|| format!("scenario {:?} [faults]", self.name))?;
        self.serving
            .validate()
            .with_context(|| format!("scenario {:?} [serving]", self.name))?;
        self.groups().map(|_| ())
    }

    /// The built-in scenario suite the evaluation matrix and the
    /// conformance tests run: single-net, multi-tenant, and staggered.
    pub fn builtin(name: &str) -> Option<Scenario> {
        let small = |read_ports: usize, dpus: usize| SystemConfig {
            geometry: crate::types::Geometry {
                w_line: 16 * read_ports.max(8),
                w_acc: 16,
                read_ports: read_ports.max(8),
                write_ports: read_ports.max(8),
                max_burst: 8,
            },
            dotprod_units: dpus,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: Some(200.0),
            ddr3_timing: false,
            seed: 7,
            ..SystemConfig::default()
        };
        match name {
            "single-tiny-vgg" => Some(Scenario::single("single-tiny-vgg", small(8, 16), zoo::tiny_vgg())),
            "multi-tenant-mix" => {
                let cfg = small(8, 8);
                Some(Scenario {
                    name: "multi-tenant-mix".into(),
                    tenants: vec![
                        TenantSpec {
                            net: zoo::resnet_tiny(),
                            read_ports: 4,
                            write_ports: 4,
                            start_cycle: 0,
                            seed: 11,
                        },
                        TenantSpec {
                            net: zoo::mobilenet_tiny(),
                            read_ports: 4,
                            write_ports: 4,
                            start_cycle: 0,
                            seed: 13,
                        },
                    ],
                    cfg,
                    faults: FaultSpec::none(),
                    serving: ServingSpec::none(),
                })
            }
            "staggered-gemm" => {
                let cfg = small(8, 8);
                Some(Scenario {
                    name: "staggered-gemm".into(),
                    tenants: vec![
                        TenantSpec {
                            net: zoo::gemm_mlp(),
                            read_ports: 4,
                            write_ports: 4,
                            start_cycle: 0,
                            seed: 21,
                        },
                        TenantSpec {
                            net: zoo::gemm_mlp(),
                            read_ports: 4,
                            write_ports: 4,
                            start_cycle: 1500,
                            seed: 22,
                        },
                    ],
                    cfg,
                    faults: FaultSpec::none(),
                    serving: ServingSpec::none(),
                })
            }
            "serving-poisson" => {
                let mut sc = Scenario::single("serving-poisson", small(8, 16), zoo::gemm_mlp());
                sc.serving = ServingSpec {
                    seed: 5,
                    requests: 6,
                    mean_gap: 4_000,
                    max_batch: 2,
                    max_wait: 2_500,
                    slo_cycles: 200_000,
                    ..ServingSpec::default()
                };
                Some(sc)
            }
            "serving-overload" => {
                // Oversubscribed on purpose: a 12-request burst against
                // a 3-deep bounded queue while the tenant is mid-pass
                // guarantees drop-oldest sheds on every design, and the
                // per-request deadline exercises expiry edges under
                // leap. Retries are armed (header coverage) but never
                // fire without a fault campaign.
                let mut sc =
                    Scenario::single("serving-overload", small(8, 16), zoo::gemm_mlp());
                sc.serving = ServingSpec {
                    seed: 5,
                    arrivals: (0..12).map(|i| 100 + i).collect(),
                    max_batch: 2,
                    max_wait: 50,
                    slo_cycles: 150_000,
                    queue_cap: 3,
                    overload: OverloadPolicy::DropOldest,
                    deadline: 30_000,
                    retries: 2,
                    backoff: 1_500,
                    ..ServingSpec::default()
                };
                Some(sc)
            }
            _ => None,
        }
    }

    /// Names of the built-in scenarios.
    pub fn builtin_names() -> &'static [&'static str] {
        &[
            "single-tiny-vgg",
            "multi-tenant-mix",
            "staggered-gemm",
            "serving-poisson",
            "serving-overload",
        ]
    }

    /// The micro scenario behind the checked-in golden traces
    /// (`rust/golden/micro_{baseline,medusa}.trace`): one tiny conv on a
    /// 4-port / 64-bit geometry, small enough that its data-movement
    /// counters are verifiable by hand. Regenerate the goldens with
    /// `MEDUSA_REGEN_GOLDEN=1 cargo test -q golden_trace`.
    pub fn golden_micro(design: crate::interconnect::Design) -> Scenario {
        use crate::accel::dnn::ConvLayer;
        use crate::workload::graph::{Layer, WorkloadNet};
        let net = WorkloadNet::chain(
            "micro-conv",
            (2, 8, 8),
            vec![Layer::Conv {
                conv: ConvLayer {
                    name: "conv1",
                    in_c: 2,
                    in_h: 8,
                    in_w: 8,
                    out_c: 4,
                    k: 3,
                    stride: 1,
                    pad: 1,
                    relu: true,
                },
                groups: 1,
            }],
        );
        let cfg = SystemConfig {
            design,
            geometry: crate::types::Geometry {
                w_line: 64,
                w_acc: 16,
                read_ports: 4,
                write_ports: 4,
                max_burst: 4,
            },
            dotprod_units: 4,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: Some(200.0),
            ddr3_timing: false,
            seed: 7,
            ..SystemConfig::default()
        };
        Scenario {
            name: format!("micro-{}", design.name()),
            tenants: vec![TenantSpec { net, read_ports: 0, write_ports: 0, start_cycle: 0, seed: 5 }],
            cfg,
            faults: FaultSpec::none(),
            serving: ServingSpec::none(),
        }
    }

    /// The micro golden scenario under a standard stall-only fault
    /// campaign (refresh bursts + CDC stalls + LP slowdowns + detected
    /// corruption — no wedge). Behind
    /// `rust/golden/micro_medusa_faulted.trace`: delay faults cannot
    /// change data-movement totals, so its `[expect.exact]` block is
    /// identical to the fault-free golden's.
    pub fn golden_micro_faulted(design: crate::interconnect::Design) -> Scenario {
        let mut sc = Scenario::golden_micro(design);
        sc.name = format!("micro-{}-faulted", design.name());
        sc.faults = FaultSpec::parse_cli("dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,seed=3")
            .expect("builtin fault campaign is well-formed");
        sc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::Design;

    const MIX: &str = r#"
[scenario]
name = "mix"

[system]
design = "medusa"
seed = 3

[geometry]
w_line = 128
w_acc = 16
read_ports = 8
write_ports = 8
max_burst = 8

[clocks]
mem_mhz = 200
fabric_mhz = 200

[memory]
ddr3_timing = false

[tenant.0]
network = "resnet-tiny"
read_ports = 4
write_ports = 4

[tenant.1]
network = "mobilenet-tiny"
read_ports = 4
write_ports = 4
start_cycle = 500
seed = 99
"#;

    #[test]
    fn parses_multi_tenant_scenario() {
        let sc = Scenario::from_str(MIX).unwrap();
        assert_eq!(sc.name, "mix");
        assert_eq!(sc.cfg.design, Design::Medusa);
        assert_eq!(sc.cfg.seed, 3);
        assert_eq!(sc.tenants.len(), 2);
        assert_eq!(sc.tenants[0].net.name, "resnet-tiny");
        assert_eq!(sc.tenants[1].start_cycle, 500);
        assert_eq!(sc.tenants[1].seed, 99);
        let groups = sc.groups().unwrap();
        assert_eq!(groups[0].read_base, 0);
        assert_eq!(groups[1].read_base, 4);
        assert_eq!(groups[1].write_base, 4);
    }

    #[test]
    fn lone_tenant_defaults_to_full_fabric() {
        let text = r#"
[scenario]
name = "solo"
[geometry]
w_line = 128
read_ports = 8
write_ports = 8
[clocks]
fabric_mhz = 200
[memory]
ddr3_timing = false
[tenant.0]
network = "gemm-mlp"
"#;
        let sc = Scenario::from_str(text).unwrap();
        let g = sc.groups().unwrap();
        assert_eq!(g.len(), 1);
        assert_eq!(g[0].read_ports, 8);
        assert_eq!(g[0].write_ports, 8);
    }

    #[test]
    fn oversubscribed_ports_rejected() {
        let text = MIX.replace("read_ports = 4\nwrite_ports = 4\nstart_cycle", "read_ports = 6\nwrite_ports = 6\nstart_cycle");
        assert!(Scenario::from_str(&text).is_err());
    }

    #[test]
    fn unknown_network_rejected() {
        let text = MIX.replace("mobilenet-tiny", "imaginary-net");
        let err = Scenario::from_str(&text).unwrap_err();
        assert!(format!("{err:#}").contains("unknown network"));
    }

    #[test]
    fn missing_name_rejected() {
        let text = MIX.replace("name = \"mix\"", "");
        assert!(Scenario::from_str(&text).is_err());
    }

    #[test]
    fn noncontiguous_tenant_indices_rejected() {
        let text = MIX.replace("[tenant.1]", "[tenant.2]");
        assert!(Scenario::from_str(&text).is_err());
    }

    #[test]
    fn reseed_rederives_tenant_seeds() {
        let mut sc = Scenario::from_str(MIX).unwrap();
        assert_eq!(sc.tenants[1].seed, 99);
        sc.reseed(123);
        assert_eq!(sc.cfg.seed, 123);
        assert_eq!(sc.tenants[0].seed, 123 ^ 0xda7a);
        assert_ne!(sc.tenants[1].seed, 99, "explicit seeds must be re-derived");
        assert_ne!(sc.tenants[0].seed, sc.tenants[1].seed);
    }

    #[test]
    fn parses_faults_section() {
        let text = format!(
            "{MIX}\n[faults]\nseed = 9\ndram_refresh_period = 64\ndram_refresh_len = 8\n\
             wedge_tenant = 1\nwedge_cycle = 2000\nwatchdog_cycles = 5000\npolicy = \"degrade\"\n"
        );
        let sc = Scenario::from_str(&text).unwrap();
        assert_eq!(sc.faults.seed, 9);
        assert_eq!(sc.faults.dram_refresh_period, 64);
        assert_eq!(sc.faults.wedge_tenant, Some(1));
        assert_eq!(sc.faults.policy, crate::fault::FaultPolicy::Degrade);
    }

    #[test]
    fn parses_serving_section() {
        let text = format!(
            "{MIX}\n[serving]\nseed = 4\nrequests = 12\nmean_gap = 2000\nmax_batch = 3\n\
             max_wait = 800\nslo_cycles = 90000\n"
        );
        let sc = Scenario::from_str(&text).unwrap();
        assert_eq!(sc.serving.seed, 4);
        assert_eq!(sc.serving.requests, 12);
        assert_eq!(sc.serving.mean_gap, 2000);
        assert_eq!(sc.serving.max_batch, 3);
        assert!(!sc.serving.is_none());
        // Explicit arrival traces parse from the quoted list form.
        let text = format!("{MIX}\n[serving]\nmax_batch = 2\narrivals = \"100,400,900\"\n");
        let sc = Scenario::from_str(&text).unwrap();
        assert_eq!(sc.serving.arrivals, vec![100, 400, 900]);
    }

    #[test]
    fn invalid_serving_section_rejected() {
        // Enabled serving without a batcher bound is a config error.
        let text = format!("{MIX}\n[serving]\nrequests = 4\nmean_gap = 100\nmax_batch = 0\n");
        assert!(Scenario::from_str(&text).is_err());
        let text = format!("{MIX}\n[serving]\nwarp_factor = 9\n");
        assert!(Scenario::from_str(&text).is_err());
    }

    #[test]
    fn fault_wedge_tenant_out_of_range_rejected() {
        let text = format!("{MIX}\n[faults]\nwedge_tenant = 5\nwedge_cycle = 100\n");
        let err = Scenario::from_str(&text).unwrap_err();
        assert!(format!("{err:#}").contains("wedge_tenant"), "{err:#}");
    }

    #[test]
    fn unknown_fault_key_rejected() {
        let text = format!("{MIX}\n[faults]\nflux_capacitor = 1\n");
        assert!(Scenario::from_str(&text).is_err());
    }

    #[test]
    fn builtins_validate() {
        for name in Scenario::builtin_names() {
            let sc = Scenario::builtin(name).unwrap();
            sc.validate().unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(&sc.name, name);
        }
        assert!(Scenario::builtin("nope").is_none());
    }
}
