//! Minimal leveled logger (the offline registry has no `log`/`env_logger`).
//!
//! Level is set once at startup (CLI `--log-level` or `MEDUSA_LOG`), read
//! lock-free afterwards. Output goes to stderr so tables/CSV on stdout
//! stay machine-readable.

use std::sync::atomic::{AtomicU8, Ordering};

#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

/// Set the global level; also honours `MEDUSA_LOG` when called with `None`.
pub fn init(level: Option<Level>) {
    let lvl = level
        .or_else(|| std::env::var("MEDUSA_LOG").ok().as_deref().and_then(Level::parse))
        .unwrap_or(Level::Info);
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

pub fn enabled(level: Level) -> bool {
    (level as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: Level, args: std::fmt::Arguments<'_>) {
    if enabled(level) {
        eprintln!("[{}] {}", level.tag(), args);
    }
}

#[macro_export]
macro_rules! log_error { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Error, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_warn { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Warn, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_info { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_debug { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*)) } }
#[macro_export]
macro_rules! log_trace { ($($t:tt)*) => { $crate::util::logging::log($crate::util::logging::Level::Trace, format_args!($($t)*)) } }

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_levels() {
        assert_eq!(Level::parse("info"), Some(Level::Info));
        assert_eq!(Level::parse("WARN"), Some(Level::Warn));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("bogus"), None);
    }

    #[test]
    fn level_ordering_gates_output() {
        init(Some(Level::Warn));
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        init(Some(Level::Info)); // restore default for other tests
    }
}
