//! Small self-contained utilities: a deterministic PRNG (the offline
//! registry has no `rand`), a leveled logger, and integer math helpers
//! used throughout the resource and timing models.

pub mod bench;
pub mod logging;
pub mod mathutil;
pub mod parallel;
pub mod prng;

pub use mathutil::{ceil_div, ceil_log2, next_pow2, snap_to_freq_grid};
pub use parallel::{par_map, par_map_owned, par_map_with};
pub use prng::Prng;
