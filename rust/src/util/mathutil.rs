//! Integer math helpers for the resource and timing models.

/// Ceiling division for positive integers.
pub fn ceil_div(a: usize, b: usize) -> usize {
    debug_assert!(b > 0);
    a.div_ceil(b)
}

/// `ceil(log2(n))` — the number of select bits / mux layers needed to
/// address or rotate among `n` items. `ceil_log2(1) == 0`.
pub fn ceil_log2(n: usize) -> usize {
    debug_assert!(n > 0);
    (usize::BITS - (n - 1).leading_zeros()) as usize
}

/// Smallest power of two >= `n` (used to size memory interfaces for
/// irregular port counts, paper §IV-D: "the width of the memory interface
/// is always set to a power of two").
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Linear interpolation.
pub fn lerp(a: f64, b: f64, t: f64) -> f64 {
    a + (b - a) * t
}

/// Snap a frequency down to the paper's 25 MHz P&R search grid
/// (§IV-A: "searching in steps of 25MHz"); frequencies below 25 MHz
/// report 0 ("Vivado was not able to meet timing at 25MHz").
pub fn snap_to_freq_grid(mhz: f64) -> u32 {
    if mhz < 25.0 {
        0
    } else {
        ((mhz / 25.0).floor() as u32) * 25
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ceil_div_basics() {
        assert_eq!(ceil_div(10, 3), 4);
        assert_eq!(ceil_div(9, 3), 3);
        assert_eq!(ceil_div(0, 3), 0);
        assert_eq!(ceil_div(1, 1), 1);
    }

    #[test]
    fn ceil_log2_basics() {
        assert_eq!(ceil_log2(1), 0);
        assert_eq!(ceil_log2(2), 1);
        assert_eq!(ceil_log2(3), 2);
        assert_eq!(ceil_log2(4), 2);
        assert_eq!(ceil_log2(5), 3);
        assert_eq!(ceil_log2(32), 5);
        assert_eq!(ceil_log2(33), 6);
    }

    #[test]
    fn next_pow2_basics() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(8), 8);
        assert_eq!(next_pow2(9), 16);
        // Paper §IV-D examples: 12 ports * 16b = 192b -> 256b iface;
        // 20 ports * 16b = 320b -> 512b iface.
        assert_eq!(next_pow2(12 * 16), 256);
        assert_eq!(next_pow2(20 * 16), 512);
    }

    #[test]
    fn freq_grid_snapping() {
        assert_eq!(snap_to_freq_grid(24.9), 0);
        assert_eq!(snap_to_freq_grid(25.0), 25);
        assert_eq!(snap_to_freq_grid(49.9), 25);
        assert_eq!(snap_to_freq_grid(226.0), 225);
        assert_eq!(snap_to_freq_grid(200.0), 200);
    }
}
