//! Order-preserving parallel map over independent work items, built on
//! `std::thread::scope` (the offline registry has no rayon).
//!
//! Used by the evaluation sweeps (`eval::fig6`, the table generators)
//! and the benchmark targets: each design point is an independent, pure,
//! deterministic computation, so running them across threads changes
//! wall-clock only — results are returned in input order and are
//! bit-identical to a sequential run (asserted by the smoke benchmark).
//!
//! Thread count: `MEDUSA_THREADS` if set (1 forces the sequential path,
//! useful for before/after benchmarking), else the machine's available
//! parallelism, capped by the number of items.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// How many worker threads a parallel region may use.
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("MEDUSA_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Map `f` over `items` in parallel, preserving input order in the
/// output. `f` runs at most once per item; panics in workers propagate
/// to the caller.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    par_map_with(max_threads(), items, f)
}

/// [`par_map`] with an explicit worker count (ignores `MEDUSA_THREADS`).
/// Used by determinism tests to compare a sequential run against a
/// parallel one without racing on process-global environment state.
pub fn par_map_with<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let workers = workers.max(1).min(items.len());
    if workers <= 1 {
        return items.iter().map(f).collect();
    }
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed every claimed item"))
        .collect()
}

/// `par_map` over owned items (moves each item into exactly one worker).
pub fn par_map_owned<T, R, F>(items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let workers = max_threads().min(items.len());
    if workers <= 1 {
        return items.into_iter().map(f).collect();
    }
    let slots: Vec<Mutex<Option<R>>> = items.iter().map(|_| Mutex::new(None)).collect();
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("item claimed once");
                let r = f(item);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| m.into_inner().unwrap().expect("worker completed every claimed item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order_and_covers_all_items() {
        let items: Vec<u64> = (0..257).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, items.iter().map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn owned_variant_moves_items() {
        let items: Vec<String> = (0..50).map(|i| format!("s{i}")).collect();
        let out = par_map_owned(items, |s| s.len());
        assert_eq!(out.len(), 50);
        assert_eq!(out[0], 2);
        assert_eq!(out[10], 3);
    }

    #[test]
    fn empty_and_single_inputs() {
        let none: Vec<u32> = Vec::new();
        assert!(par_map(&none, |&x| x).is_empty());
        assert_eq!(par_map(&[7u32], |&x| x + 1), vec![8]);
    }

    #[test]
    fn matches_sequential_bit_for_bit() {
        // The determinism contract the sweeps rely on: same inputs, same
        // outputs, regardless of thread count.
        let items: Vec<u64> = (0..64).collect();
        let f = |&x: &u64| -> u64 {
            // A little non-trivial arithmetic (FNV-style mix).
            let mut h = 0xcbf2_9ce4_8422_2325u64 ^ x;
            for _ in 0..100 {
                h = h.wrapping_mul(0x1000_0000_01b3).rotate_left(7);
            }
            h
        };
        let seq: Vec<u64> = items.iter().map(f).collect();
        let par = par_map(&items, f);
        assert_eq!(seq, par);
    }
}
