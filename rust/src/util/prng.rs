//! Deterministic xoshiro256** PRNG.
//!
//! Used for traffic generation, property-based tests, and failure
//! injection. Seeded explicitly everywhere so every simulation and every
//! test run is reproducible bit-for-bit.

/// xoshiro256** by Blackman & Vigna (public domain reference
/// implementation, ported). Not cryptographic; excellent statistical
/// quality for simulation workloads.
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Create a generator from a seed, expanding it with splitmix64 as
    /// the xoshiro authors recommend.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        // All-zero state is the one invalid state; the seed expansion
        // cannot produce it for any input, but guard anyway.
        let s = if s == [0, 0, 0, 0] { [1, 2, 3, 4] } else { s };
        Prng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, bound)`; `bound` must be non-zero. Uses Lemire's
    /// nearly-divisionless method.
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    /// Bernoulli trial with probability `p` (clamped to [0,1]).
    pub fn chance(&mut self, p: f64) -> bool {
        let p = p.clamp(0.0, 1.0);
        (self.next_u64() as f64 / u64::MAX as f64) < p
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut p = Prng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = p.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues should appear in 1000 draws");
    }

    #[test]
    fn range_inclusive_bounds() {
        let mut p = Prng::new(3);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..2000 {
            let v = p.range(5, 8);
            assert!((5..=8).contains(&v));
            lo_seen |= v == 5;
            hi_seen |= v == 8;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn f64_unit_interval() {
        let mut p = Prng::new(9);
        for _ in 0..1000 {
            let v = p.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut p = Prng::new(11);
        let mut xs: Vec<usize> = (0..100).collect();
        p.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>(), "astronomically unlikely to be identity");
    }
}
