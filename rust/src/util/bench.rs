//! Minimal benchmarking harness (the offline registry has no criterion).
//!
//! `cargo bench` runs each `[[bench]]` target with `harness = false`;
//! targets use [`Bench`] to time closures with warmup, multiple samples,
//! and median/mean/min reporting, and print aligned rows so
//! `bench_output.txt` is self-describing.

use std::time::{Duration, Instant};

#[derive(Clone, Debug)]
pub struct Measurement {
    pub name: String,
    pub samples: Vec<Duration>,
    /// Work units per iteration (e.g. lines transferred) for throughput.
    pub units_per_iter: u64,
    pub unit_name: &'static str,
}

impl Measurement {
    pub fn median(&self) -> Duration {
        let mut s = self.samples.clone();
        s.sort();
        s[s.len() / 2]
    }

    pub fn min(&self) -> Duration {
        *self.samples.iter().min().unwrap()
    }

    pub fn mean(&self) -> Duration {
        let total: Duration = self.samples.iter().sum();
        total / self.samples.len() as u32
    }

    /// Units per second at the median sample.
    pub fn throughput(&self) -> f64 {
        let secs = self.median().as_secs_f64();
        if secs == 0.0 {
            f64::INFINITY
        } else {
            self.units_per_iter as f64 / secs
        }
    }
}

pub struct Bench {
    pub warmup: usize,
    pub samples: usize,
    results: Vec<Measurement>,
}

impl Default for Bench {
    fn default() -> Self {
        // MEDUSA_BENCH_SAMPLES=1 gives quick smoke runs in CI.
        let samples = std::env::var("MEDUSA_BENCH_SAMPLES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(10);
        Bench { warmup: 2, samples, results: Vec::new() }
    }
}

impl Bench {
    pub fn new() -> Self {
        Bench::default()
    }

    /// Time `f` (whole-call granularity); `units` is the work done per
    /// call for throughput reporting.
    pub fn run<R>(&mut self, name: impl Into<String>, units: u64, unit_name: &'static str, mut f: impl FnMut() -> R) {
        let name = name.into();
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples.push(t0.elapsed());
        }
        self.results.push(Measurement { name, samples, units_per_iter: units, unit_name });
    }

    pub fn results(&self) -> &[Measurement] {
        &self.results
    }

    /// Print the aligned report; returns it too (for tee-style capture).
    pub fn report(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("### bench: {title}\n"));
        out.push_str(&format!(
            "{:<44} {:>12} {:>12} {:>12} {:>16}\n",
            "case", "median", "mean", "min", "throughput"
        ));
        for m in &self.results {
            out.push_str(&format!(
                "{:<44} {:>12} {:>12} {:>12} {:>13.3e} {}/s\n",
                m.name,
                fmt_dur(m.median()),
                fmt_dur(m.mean()),
                fmt_dur(m.min()),
                m.throughput(),
                m.unit_name,
            ));
        }
        print!("{out}");
        out
    }
}

fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos();
    if ns < 1_000 {
        format!("{ns}ns")
    } else if ns < 1_000_000 {
        format!("{:.2}us", ns as f64 / 1e3)
    } else if ns < 1_000_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else {
        format!("{:.2}s", ns as f64 / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_and_reports() {
        let mut b = Bench { warmup: 1, samples: 3, results: Vec::new() };
        let mut acc = 0u64;
        b.run("spin", 1000, "items", || {
            for i in 0..1000u64 {
                acc = acc.wrapping_add(i);
            }
            acc
        });
        assert_eq!(b.results().len(), 1);
        let m = &b.results()[0];
        assert_eq!(m.samples.len(), 3);
        assert!(m.throughput() > 0.0);
        let rep = b.report("test");
        assert!(rep.contains("spin"));
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.50ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with('s'));
    }
}
