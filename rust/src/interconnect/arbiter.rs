//! Memory request arbiter — shared by both interconnect designs (paper
//! §IV: "both interconnects use the same request arbitration logic").
//!
//! Responsibilities:
//!
//! * queue per-port read/write burst requests from the layer processors;
//! * **read credit control**: a read burst is only issued when the read
//!   network has buffer space for every line of the burst (in-flight
//!   lines included), so bursts can never back-pressure the DRAM
//!   controller (§II-A1 / §III-C1 provisioning);
//! * **write readiness**: a write burst is only issued once the port has
//!   accumulated the full burst inside the write network (§III-C2 — this
//!   requirement applies to the baseline too);
//! * round-robin fairness across ports, alternating read/write grants;
//! * stream issued write bursts' data lines toward the controller in
//!   command order.

use crate::interconnect::{ReadNetwork, WriteNetwork};
use crate::sim::stats::Counter;
use crate::sim::{Channel, Stats};
use crate::types::{Line, LineAddr, PortId, ReadRequest, WriteRequest};
use std::collections::VecDeque;

/// A command crossing into the memory controller's clock domain.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum MemCommand {
    Read { port: PortId, addr: LineAddr, burst_len: usize },
    Write { port: PortId, addr: LineAddr, burst_len: usize },
}

/// Arbitration policy (ablation knob; the paper uses plain fair sharing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Alternate read/write grant opportunities, round-robin within each.
    RoundRobin,
    /// Always grant reads first (reads are latency-critical for
    /// prefetch-driven layer processors).
    ReadPriority,
}

pub struct Arbiter {
    read_q: Vec<VecDeque<ReadRequest>>,
    write_q: Vec<VecDeque<WriteRequest>>,
    /// Read lines issued to the controller but not yet delivered into the
    /// read network (per port) — counted against network buffer space.
    in_flight_read_lines: Vec<usize>,
    /// Issued write bursts whose data lines still need to be streamed
    /// from the write network to the controller, in command order.
    issued_writes: VecDeque<(PortId, usize)>,
    /// Write lines already spoken for by issued-but-not-yet-pulled bursts
    /// (per port) — prevents double-issuing against the same data.
    reserved_write_lines: Vec<usize>,
    rr_read: usize,
    rr_write: usize,
    grant_writes_next: bool,
    policy: Policy,
    queue_cap: usize,
}

impl Arbiter {
    pub fn new(read_ports: usize, write_ports: usize, policy: Policy) -> Self {
        Arbiter {
            read_q: (0..read_ports).map(|_| VecDeque::new()).collect(),
            write_q: (0..write_ports).map(|_| VecDeque::new()).collect(),
            in_flight_read_lines: vec![0; read_ports],
            issued_writes: VecDeque::new(),
            reserved_write_lines: vec![0; write_ports],
            rr_read: 0,
            rr_write: 0,
            grant_writes_next: false,
            policy,
            queue_cap: 8,
        }
    }

    /// Queue a read burst on behalf of a port. Returns false (and drops
    /// nothing) if the port's request queue is full — the layer
    /// processor retries next cycle, exactly like a stalled request
    /// handshake.
    pub fn submit_read(&mut self, r: ReadRequest) -> bool {
        debug_assert!(r.burst_len >= 1);
        let q = &mut self.read_q[r.port];
        if q.len() >= self.queue_cap {
            return false;
        }
        q.push_back(r);
        true
    }

    pub fn submit_write(&mut self, r: WriteRequest) -> bool {
        debug_assert!(r.burst_len >= 1);
        let q = &mut self.write_q[r.port];
        if q.len() >= self.queue_cap {
            return false;
        }
        q.push_back(r);
        true
    }

    /// Number of queued (not yet issued) requests.
    pub fn pending_requests(&self) -> usize {
        self.read_q.iter().map(|q| q.len()).sum::<usize>()
            + self.write_q.iter().map(|q| q.len()).sum::<usize>()
    }

    /// Write bursts issued whose data is still streaming out.
    pub fn writes_in_flight(&self) -> usize {
        self.issued_writes.len()
    }

    /// True iff `tick` is a complete no-op (no grants possible, no
    /// write data to stream, no stats) and stays one until a new
    /// request is submitted — the arbiter's `next_activity_edge()`.
    /// Read credits in flight don't matter: they only *unblock* queued
    /// requests, of which there are none when this returns true.
    pub fn is_leap_idle(&self) -> bool {
        self.issued_writes.is_empty()
            && self.read_q.iter().all(|q| q.is_empty())
            && self.write_q.iter().all(|q| q.is_empty())
    }

    /// The interface adapter calls this when a read line lands in the
    /// read network (credit return).
    pub fn on_read_line_delivered(&mut self, port: PortId) {
        debug_assert!(self.in_flight_read_lines[port] > 0);
        self.in_flight_read_lines[port] -= 1;
    }

    /// One fabric cycle: issue at most one command and stream at most one
    /// write-data line.
    ///
    /// Generic over the network types so the per-cycle calls are static
    /// when the caller holds concrete networks (or the `Any*` enums);
    /// `R`/`W` may still be `dyn` trait objects for harness code.
    pub fn tick<R, W>(
        &mut self,
        rd_net: &R,
        wr_net: &mut W,
        cmd_ch: &mut Channel<MemCommand>,
        wr_data_ch: &mut Channel<Line>,
        stats: &mut Stats,
    ) where
        R: ReadNetwork + ?Sized,
        W: WriteNetwork + ?Sized,
    {
        // --- Stream write data for the oldest issued burst (§III-C2
        // guarantees the data is fully buffered, so this never stalls on
        // the network side).
        if let Some(&(port, remaining)) = self.issued_writes.front() {
            if wr_data_ch.can_push() && wr_net.mem_lines_ready(port) > 0 {
                let line = wr_net.mem_take_line(port).expect("ready line vanished");
                wr_data_ch.push(line);
                stats.bump(Counter::ArbiterWriteLinesStreamed);
                self.reserved_write_lines[port] -= 1;
                if remaining == 1 {
                    self.issued_writes.pop_front();
                } else {
                    self.issued_writes.front_mut().unwrap().1 = remaining - 1;
                }
            }
        }

        // --- Issue one command.
        if !cmd_ch.can_push() {
            stats.bump(Counter::ArbiterCmdChannelStall);
            return;
        }
        let try_write_first = match self.policy {
            Policy::RoundRobin => self.grant_writes_next,
            Policy::ReadPriority => false,
        };
        let issued = if try_write_first {
            self.try_issue_write(wr_net, cmd_ch, stats) || self.try_issue_read(rd_net, cmd_ch, stats)
        } else {
            self.try_issue_read(rd_net, cmd_ch, stats) || self.try_issue_write(wr_net, cmd_ch, stats)
        };
        if issued && self.policy == Policy::RoundRobin {
            self.grant_writes_next = !self.grant_writes_next;
        }
    }

    fn try_issue_read<R: ReadNetwork + ?Sized>(
        &mut self,
        rd_net: &R,
        cmd_ch: &mut Channel<MemCommand>,
        stats: &mut Stats,
    ) -> bool {
        let nports = self.read_q.len();
        for k in 0..nports {
            let p = (self.rr_read + k) % nports;
            let Some(&req) = self.read_q[p].front() else { continue };
            // Credit check: space for the whole burst beyond lines already
            // in flight (§III-C1).
            let free = rd_net.port_free_lines(p);
            if free < self.in_flight_read_lines[p] + req.burst_len {
                stats.bump(Counter::ArbiterReadCreditStall);
                continue;
            }
            self.read_q[p].pop_front();
            self.in_flight_read_lines[p] += req.burst_len;
            cmd_ch.push(MemCommand::Read { port: p, addr: req.addr, burst_len: req.burst_len });
            stats.bump(Counter::ArbiterReadsIssued);
            self.rr_read = p + 1;
            return true;
        }
        false
    }

    fn try_issue_write<W: WriteNetwork + ?Sized>(
        &mut self,
        wr_net: &W,
        cmd_ch: &mut Channel<MemCommand>,
        stats: &mut Stats,
    ) -> bool {
        let nports = self.write_q.len();
        for k in 0..nports {
            let p = (self.rr_write + k) % nports;
            let Some(&req) = self.write_q[p].front() else { continue };
            // §III-C2: only issue once the full burst is buffered (and not
            // already reserved by a previously issued burst).
            let available = wr_net.mem_lines_ready(p).saturating_sub(self.reserved_write_lines[p]);
            if available < req.burst_len {
                stats.bump(Counter::ArbiterWriteDataStall);
                continue;
            }
            self.write_q[p].pop_front();
            self.reserved_write_lines[p] += req.burst_len;
            self.issued_writes.push_back((p, req.burst_len));
            cmd_ch.push(MemCommand::Write { port: p, addr: req.addr, burst_len: req.burst_len });
            stats.bump(Counter::ArbiterWritesIssued);
            self.rr_write = p + 1;
            return true;
        }
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::baseline::{BaselineReadNetwork, BaselineWriteNetwork};
    use crate::types::Geometry;

    fn geom() -> Geometry {
        Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 }
    }

    #[test]
    fn read_issued_only_with_credit() {
        let g = geom();
        let rd = BaselineReadNetwork::new(g);
        let mut wr = BaselineWriteNetwork::new(g);
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        let mut cmd = Channel::new("cmd", 4);
        let mut data = Channel::new("wdata", 4);
        let mut stats = Stats::new();

        // Burst larger than the network's per-port capacity: never issued.
        assert!(arb.submit_read(ReadRequest { port: 0, addr: 0, burst_len: 5 }));
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert!(!cmd.can_pop(), "over-capacity burst must stall");
        assert_eq!(stats.get("arbiter.read_credit_stall"), 1);

        // In-capacity burst issues.
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        assert!(arb.submit_read(ReadRequest { port: 1, addr: 16, burst_len: 4 }));
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(
            cmd.pop(),
            Some(MemCommand::Read { port: 1, addr: 16, burst_len: 4 })
        );
    }

    #[test]
    fn credit_returns_on_delivery() {
        let g = geom();
        let rd = BaselineReadNetwork::new(g);
        let mut wr = BaselineWriteNetwork::new(g);
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        let mut cmd = Channel::new("cmd", 4);
        let mut data = Channel::new("wdata", 4);
        let mut stats = Stats::new();

        arb.submit_read(ReadRequest { port: 0, addr: 0, burst_len: 4 });
        arb.submit_read(ReadRequest { port: 0, addr: 4, burst_len: 4 });
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(stats.get("arbiter.reads_issued"), 1);
        // Second burst stalls: 4 lines already in flight.
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(stats.get("arbiter.reads_issued"), 1);
        // Lines delivered (and instantly drained in this fake) return
        // credit. Simulate 4 deliveries without touching the network —
        // free space in the real flow comes from the network; here the
        // network is empty so port_free_lines is already max. The gate was
        // in_flight, which on_read_line_delivered releases.
        for _ in 0..4 {
            arb.on_read_line_delivered(0);
        }
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(stats.get("arbiter.reads_issued"), 2);
    }

    #[test]
    fn write_waits_for_accumulated_data() {
        let g = geom();
        let n = g.words_per_line();
        let rd = BaselineReadNetwork::new(g);
        let mut wr = BaselineWriteNetwork::new(g);
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        let mut cmd = Channel::new("cmd", 4);
        let mut data = Channel::new("wdata", 8);
        let mut stats = Stats::new();

        arb.submit_write(WriteRequest { port: 2, addr: 8, burst_len: 2 });
        // No data yet: stall.
        wr.tick(0, &mut stats);
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(stats.get("arbiter.writes_issued"), 0);

        // Push 2 lines worth of words into port 2.
        let mut c = 1u64;
        for i in 0..2 * n {
            wr.tick(c, &mut stats);
            wr.port_push_word(2, i as u64);
            c += 1;
        }
        for _ in 0..4 {
            wr.tick(c, &mut stats);
            c += 1;
        }
        assert_eq!(wr.mem_lines_ready(2), 2);
        arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
        cmd.commit();
        assert_eq!(
            cmd.pop(),
            Some(MemCommand::Write { port: 2, addr: 8, burst_len: 2 })
        );
        // Data lines stream out over the following cycles.
        for _ in 0..4 {
            wr.tick(c, &mut stats);
            arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
            data.commit();
            cmd.commit();
            c += 1;
        }
        assert_eq!(data.len(), 2, "both burst lines streamed");
        assert_eq!(arb.writes_in_flight(), 0);
    }

    #[test]
    fn round_robin_is_fair_across_ports() {
        let g = geom();
        let rd = BaselineReadNetwork::new(g);
        let mut wr = BaselineWriteNetwork::new(g);
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        let mut cmd = Channel::new("cmd", 16);
        let mut data = Channel::new("wdata", 4);
        let mut stats = Stats::new();
        for p in 0..4 {
            arb.submit_read(ReadRequest { port: p, addr: p as u64, burst_len: 1 });
        }
        let mut order = Vec::new();
        for _ in 0..4 {
            arb.tick(&rd, &mut wr, &mut cmd, &mut data, &mut stats);
            cmd.commit();
            if let Some(MemCommand::Read { port, .. }) = cmd.pop() {
                order.push(port);
            }
        }
        assert_eq!(order, vec![0, 1, 2, 3]);
    }

    #[test]
    fn queue_capacity_backpressures_submitter() {
        let mut arb = Arbiter::new(1, 1, Policy::RoundRobin);
        let mut accepted = 0;
        for i in 0..20 {
            if arb.submit_read(ReadRequest { port: 0, addr: i, burst_len: 1 }) {
                accepted += 1;
            }
        }
        assert_eq!(accepted, 8, "queue cap must bound outstanding requests");
    }
}
