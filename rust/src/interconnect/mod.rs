//! The memory interconnect data-transfer networks — the paper's subject.
//!
//! Two families, behaviourally interchangeable (paper §III-F: Medusa is a
//! "drop-in replacement" for the baseline, differing only by a constant
//! latency):
//!
//! * [`baseline`] — the traditional design (paper §II, Figs 1–2): a wide
//!   1-to-N demux into per-port `W_line`-wide FIFOs and data-width
//!   converters (read), and converters into FIFOs into an N-to-1 mux
//!   (write).
//! * [`medusa`] — the transposition-based design (paper §III, Figs 3–5):
//!   deep banked buffers and a shared barrel rotator.
//! * [`axis`] — an AXI4-Stream-IP-like variant of the baseline used by
//!   the Table I comparison.
//!
//! Both implement [`ReadNetwork`] / [`WriteNetwork`], so the arbiter, the
//! DRAM controller, the layer processors and the whole evaluation harness
//! are design-agnostic.
//!
//! ## Cycle contract
//!
//! The system owner advances one fabric cycle as:
//!
//! 1. `network.tick(cycle, stats)` — internal datapath advance;
//! 2. memory-side interactions (`mem_deliver` / `mem_take_line`);
//! 3. port-side interactions (`port_take_word` / `port_push_word`);
//!
//! Data moved in steps 2–3 becomes visible to the datapath at the next
//! `tick`, giving registered (order-independent) semantics. Each port may
//! move at most one word per cycle and the memory side at most one line
//! per cycle — the networks assert this.

pub mod arbiter;
pub mod axis;
pub mod baseline;
pub mod harness;
pub mod medusa;

use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, TaggedLine, Word};

/// Which interconnect design a component should instantiate.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Design {
    Baseline,
    Medusa,
    /// Baseline built from AXI4-Stream-style IP (Table I comparator).
    Axis,
}

impl Design {
    pub fn parse(s: &str) -> Option<Design> {
        match s.to_ascii_lowercase().as_str() {
            "baseline" | "base" => Some(Design::Baseline),
            "medusa" | "transpose" => Some(Design::Medusa),
            "axis" | "axi4-stream" => Some(Design::Axis),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Design::Baseline => "baseline",
            Design::Medusa => "medusa",
            Design::Axis => "axis",
        }
    }
}

/// Read-side data transfer network: wide memory lines in, narrow port
/// words out.
pub trait ReadNetwork {
    fn geometry(&self) -> &Geometry;

    /// May the memory controller deliver a line destined to `port` this
    /// cycle? (Backpressure; with a credit-respecting arbiter this is
    /// always true — the networks are provisioned for `max_burst` lines
    /// per port, §II-A/§III-C1.)
    fn mem_can_deliver(&self, port: PortId) -> bool;

    /// Deliver one `W_line` line from the memory controller (at most one
    /// per cycle across all ports — it is a single shared interface).
    fn mem_deliver(&mut self, line: TaggedLine);

    /// Lines of space currently available for `port` (the arbiter's
    /// credit counter source).
    fn port_free_lines(&self, port: PortId) -> usize;

    /// Is a word available on `port` this cycle?
    fn port_word_available(&self, port: PortId) -> bool;

    /// Pop one word from `port` (at most once per port per cycle).
    fn port_take_word(&mut self, port: PortId) -> Option<Word>;

    /// Advance one fabric cycle.
    fn tick(&mut self, cycle: u64, stats: &mut Stats);

    /// Constant latency overhead vs an ideal wire, in cycles — used by
    /// the §III-E latency validation tests.
    fn nominal_latency(&self) -> usize;
}

/// Write-side data transfer network: narrow port words in, wide memory
/// lines out.
pub trait WriteNetwork {
    fn geometry(&self) -> &Geometry;

    /// May `port` push a word this cycle?
    fn port_can_accept(&self, port: PortId) -> bool;

    /// Push one word from `port` (at most once per port per cycle).
    fn port_push_word(&mut self, port: PortId, w: Word);

    /// Number of complete `W_line` lines ready for `port` on the memory
    /// side. The request arbiter "must monitor data coming from the write
    /// ports, and only issue requests for ports that have accumulated
    /// enough data" (§III-C2).
    fn mem_lines_ready(&self, port: PortId) -> usize;

    /// Pop one completed line for `port` toward the memory controller
    /// (at most one line per cycle across all ports).
    fn mem_take_line(&mut self, port: PortId) -> Option<Line>;

    fn tick(&mut self, cycle: u64, stats: &mut Stats);

    fn nominal_latency(&self) -> usize;
}

/// Construct a read network of the given design.
///
/// Returns a trait object for harness/test code that wants design
/// erasure; the per-cycle simulation core uses [`AnyReadNetwork`]
/// instead so every `tick` devirtualizes and inlines.
pub fn build_read_network(design: Design, geom: Geometry) -> Box<dyn ReadNetwork + Send> {
    match design {
        Design::Baseline => Box::new(baseline::BaselineReadNetwork::new(geom)),
        Design::Medusa => Box::new(medusa::MedusaReadNetwork::new(geom)),
        Design::Axis => Box::new(axis::AxisReadNetwork::new(geom)),
    }
}

/// Construct a write network of the given design (trait-object form; see
/// [`build_read_network`]).
pub fn build_write_network(design: Design, geom: Geometry) -> Box<dyn WriteNetwork + Send> {
    match design {
        Design::Baseline => Box::new(baseline::BaselineWriteNetwork::new(geom)),
        Design::Medusa => Box::new(medusa::MedusaWriteNetwork::new(geom)),
        Design::Axis => Box::new(axis::AxisWriteNetwork::new(geom)),
    }
}

/// Statically dispatched read network: a closed enum over the three
/// designs. `System` holds this instead of `Box<dyn ReadNetwork>` so the
/// per-cycle `tick`/`port_*` calls monomorphize into direct (inlinable)
/// calls — the match on a three-variant discriminant predicts perfectly,
/// where a vtable load did not. The [`ReadNetwork`] trait impl keeps it
/// interchangeable with the boxed path for the harness and tests.
pub enum AnyReadNetwork {
    Baseline(baseline::BaselineReadNetwork),
    Medusa(medusa::MedusaReadNetwork),
    Axis(axis::AxisReadNetwork),
}

impl AnyReadNetwork {
    pub fn build(design: Design, geom: Geometry) -> Self {
        match design {
            Design::Baseline => AnyReadNetwork::Baseline(baseline::BaselineReadNetwork::new(geom)),
            Design::Medusa => AnyReadNetwork::Medusa(medusa::MedusaReadNetwork::new(geom)),
            Design::Axis => AnyReadNetwork::Axis(axis::AxisReadNetwork::new(geom)),
        }
    }

    /// Medusa with explicit tuning (rotator-pipelining ablations).
    pub fn medusa_with_tuning(geom: Geometry, tuning: medusa::MedusaTuning) -> Self {
        AnyReadNetwork::Medusa(medusa::MedusaReadNetwork::with_tuning(geom, tuning))
    }

    pub fn design(&self) -> Design {
        match self {
            AnyReadNetwork::Baseline(_) => Design::Baseline,
            AnyReadNetwork::Medusa(_) => Design::Medusa,
            AnyReadNetwork::Axis(_) => Design::Axis,
        }
    }
}

macro_rules! any_read_dispatch {
    ($self:expr, $net:ident => $body:expr) => {
        match $self {
            AnyReadNetwork::Baseline($net) => $body,
            AnyReadNetwork::Medusa($net) => $body,
            AnyReadNetwork::Axis($net) => $body,
        }
    };
}

impl ReadNetwork for AnyReadNetwork {
    #[inline]
    fn geometry(&self) -> &Geometry {
        any_read_dispatch!(self, n => n.geometry())
    }

    #[inline]
    fn mem_can_deliver(&self, port: PortId) -> bool {
        any_read_dispatch!(self, n => n.mem_can_deliver(port))
    }

    #[inline]
    fn mem_deliver(&mut self, line: TaggedLine) {
        any_read_dispatch!(self, n => n.mem_deliver(line))
    }

    #[inline]
    fn port_free_lines(&self, port: PortId) -> usize {
        any_read_dispatch!(self, n => n.port_free_lines(port))
    }

    #[inline]
    fn port_word_available(&self, port: PortId) -> bool {
        any_read_dispatch!(self, n => n.port_word_available(port))
    }

    #[inline]
    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        any_read_dispatch!(self, n => n.port_take_word(port))
    }

    #[inline]
    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        any_read_dispatch!(self, n => n.tick(cycle, stats))
    }

    #[inline]
    fn nominal_latency(&self) -> usize {
        any_read_dispatch!(self, n => n.nominal_latency())
    }
}

/// Statically dispatched write network; see [`AnyReadNetwork`].
pub enum AnyWriteNetwork {
    Baseline(baseline::BaselineWriteNetwork),
    Medusa(medusa::MedusaWriteNetwork),
    Axis(axis::AxisWriteNetwork),
}

impl AnyWriteNetwork {
    pub fn build(design: Design, geom: Geometry) -> Self {
        match design {
            Design::Baseline => {
                AnyWriteNetwork::Baseline(baseline::BaselineWriteNetwork::new(geom))
            }
            Design::Medusa => AnyWriteNetwork::Medusa(medusa::MedusaWriteNetwork::new(geom)),
            Design::Axis => AnyWriteNetwork::Axis(axis::AxisWriteNetwork::new(geom)),
        }
    }

    pub fn medusa_with_tuning(geom: Geometry, tuning: medusa::MedusaTuning) -> Self {
        AnyWriteNetwork::Medusa(medusa::MedusaWriteNetwork::with_tuning(geom, tuning))
    }

    pub fn design(&self) -> Design {
        match self {
            AnyWriteNetwork::Baseline(_) => Design::Baseline,
            AnyWriteNetwork::Medusa(_) => Design::Medusa,
            AnyWriteNetwork::Axis(_) => Design::Axis,
        }
    }
}

macro_rules! any_write_dispatch {
    ($self:expr, $net:ident => $body:expr) => {
        match $self {
            AnyWriteNetwork::Baseline($net) => $body,
            AnyWriteNetwork::Medusa($net) => $body,
            AnyWriteNetwork::Axis($net) => $body,
        }
    };
}

impl WriteNetwork for AnyWriteNetwork {
    #[inline]
    fn geometry(&self) -> &Geometry {
        any_write_dispatch!(self, n => n.geometry())
    }

    #[inline]
    fn port_can_accept(&self, port: PortId) -> bool {
        any_write_dispatch!(self, n => n.port_can_accept(port))
    }

    #[inline]
    fn port_push_word(&mut self, port: PortId, w: Word) {
        any_write_dispatch!(self, n => n.port_push_word(port, w))
    }

    #[inline]
    fn mem_lines_ready(&self, port: PortId) -> usize {
        any_write_dispatch!(self, n => n.mem_lines_ready(port))
    }

    #[inline]
    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        any_write_dispatch!(self, n => n.mem_take_line(port))
    }

    #[inline]
    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        any_write_dispatch!(self, n => n.tick(cycle, stats))
    }

    #[inline]
    fn nominal_latency(&self) -> usize {
        any_write_dispatch!(self, n => n.nominal_latency())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn design_parsing() {
        assert_eq!(Design::parse("baseline"), Some(Design::Baseline));
        assert_eq!(Design::parse("MEDUSA"), Some(Design::Medusa));
        assert_eq!(Design::parse("axi4-stream"), Some(Design::Axis));
        assert_eq!(Design::parse("nope"), None);
    }

    #[test]
    fn factory_builds_all_designs() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
        for d in [Design::Baseline, Design::Medusa, Design::Axis] {
            let r = build_read_network(d, g);
            assert_eq!(r.geometry().read_ports, 4);
            let w = build_write_network(d, g);
            assert_eq!(w.geometry().write_ports, 4);
        }
    }

    #[test]
    fn any_network_matches_boxed_factory() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
        for d in [Design::Baseline, Design::Medusa, Design::Axis] {
            let r = AnyReadNetwork::build(d, g);
            assert_eq!(r.design(), d);
            assert_eq!(r.geometry().read_ports, 4);
            assert_eq!(r.nominal_latency(), build_read_network(d, g).nominal_latency());
            let w = AnyWriteNetwork::build(d, g);
            assert_eq!(w.design(), d);
            assert_eq!(w.nominal_latency(), build_write_network(d, g).nominal_latency());
        }
    }
}
