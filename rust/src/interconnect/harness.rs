//! Traffic-driving harness: saturate a data-transfer network and measure
//! cycles. Used by the benchmark targets, the property tests, and the
//! integration suite — one implementation of "drive this network at full
//! rate" shared everywhere.

use crate::interconnect::{ReadNetwork, WriteNetwork};
use crate::sim::Stats;
use crate::types::{Geometry, Line, TaggedLine, Word};
use crate::util::Prng;

/// Outcome of a saturation run.
#[derive(Clone, Copy, Debug)]
pub struct DriveResult {
    pub cycles: u64,
    pub lines_moved: u64,
    pub words_moved: u64,
}

impl DriveResult {
    /// Aggregate lines per cycle (1.0 = full DRAM interface bandwidth).
    pub fn lines_per_cycle(&self) -> f64 {
        self.lines_moved as f64 / self.cycles as f64
    }
}

/// Generate `total` lines round-robin across ports with seeded content.
pub fn gen_lines(geom: &Geometry, total: usize, seed: u64) -> Vec<TaggedLine> {
    let n = geom.words_per_line();
    let mut p = Prng::new(seed);
    (0..total)
        .map(|i| TaggedLine {
            port: i % geom.read_ports,
            line: Line::from_words(
                (0..n).map(|_| p.next_u64() & geom.word_mask()).collect(),
            ),
        })
        .collect()
}

/// Push `lines` through a read network as fast as it accepts them, popping
/// every port every cycle. Panics if the network stalls for 10k cycles.
/// Returns the measured totals and (optionally) the received streams.
pub fn drive_read(
    net: &mut dyn ReadNetwork,
    lines: &[TaggedLine],
    collect: bool,
) -> (DriveResult, Vec<Vec<Word>>) {
    let geom = *net.geometry();
    let n = geom.words_per_line();
    let total_words = lines.len() * n;
    let mut stats = Stats::new();
    let mut got: Vec<Vec<Word>> = vec![Vec::new(); geom.read_ports];
    let mut next = 0usize;
    let mut popped = 0usize;
    let mut cycles = 0u64;
    let mut idle = 0u32;
    while popped < total_words {
        net.tick(cycles, &mut stats);
        let mut progress = false;
        if next < lines.len() && net.mem_can_deliver(lines[next].port) {
            net.mem_deliver(lines[next].clone());
            next += 1;
            progress = true;
        }
        for p in 0..geom.read_ports {
            if net.port_word_available(p) {
                let w = net.port_take_word(p).unwrap();
                if collect {
                    got[p].push(w);
                }
                popped += 1;
                progress = true;
            }
        }
        cycles += 1;
        idle = if progress { 0 } else { idle + 1 };
        assert!(idle < 10_000, "read network stalled at {popped}/{total_words} words");
    }
    (DriveResult { cycles, lines_moved: lines.len() as u64, words_moved: popped as u64 }, got)
}

/// Generate the per-port word streams `drive_write` pushes (exposed so
/// benchmarks can hoist generation out of the timed region).
pub fn gen_write_streams(geom: &Geometry, lines_per_port: usize, seed: u64) -> Vec<Vec<Word>> {
    let n = geom.words_per_line();
    let mut prng = Prng::new(seed);
    (0..geom.write_ports)
        .map(|_| (0..lines_per_port * n).map(|_| prng.next_u64() & geom.word_mask()).collect())
        .collect()
}

/// Push `lines_per_port` lines of words into every write port, draining
/// completed lines round-robin on the memory side.
pub fn drive_write(
    net: &mut dyn WriteNetwork,
    lines_per_port: usize,
    seed: u64,
    collect: bool,
) -> (DriveResult, Vec<Vec<Line>>) {
    let streams = gen_write_streams(net.geometry(), lines_per_port, seed);
    drive_write_streams(net, &streams, collect)
}

/// `drive_write` over pre-generated streams (each stream must be a whole
/// number of lines).
pub fn drive_write_streams(
    net: &mut dyn WriteNetwork,
    streams: &[Vec<Word>],
    collect: bool,
) -> (DriveResult, Vec<Vec<Line>>) {
    let geom = *net.geometry();
    let n = geom.words_per_line();
    assert_eq!(streams.len(), geom.write_ports);
    let lines_per_port = streams[0].len() / n;
    let mut cursors: Vec<usize> = vec![0; geom.write_ports];
    let total = lines_per_port * geom.write_ports;
    let mut stats = Stats::new();
    let mut got: Vec<Vec<Line>> = vec![Vec::new(); geom.write_ports];
    let mut taken = 0usize;
    let mut cycles = 0u64;
    let mut rr = 0usize;
    let mut idle = 0u32;
    while taken < total {
        net.tick(cycles, &mut stats);
        let mut progress = false;
        for k in 0..geom.write_ports {
            let p = (rr + k) % geom.write_ports;
            if net.mem_lines_ready(p) > 0 {
                let line = net.mem_take_line(p).unwrap();
                if collect {
                    got[p].push(line);
                }
                taken += 1;
                rr = p + 1;
                progress = true;
                break;
            }
        }
        for (p, cur) in cursors.iter_mut().enumerate() {
            if *cur < streams[p].len() && net.port_can_accept(p) {
                net.port_push_word(p, streams[p][*cur]);
                *cur += 1;
                progress = true;
            }
        }
        cycles += 1;
        idle = if progress { 0 } else { idle + 1 };
        assert!(idle < 10_000, "write network stalled at {taken}/{total} lines");
    }
    (
        DriveResult { cycles, lines_moved: taken as u64, words_moved: (taken * n) as u64 },
        got,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::{build_read_network, build_write_network, Design};

    fn geom() -> Geometry {
        Geometry { w_line: 128, w_acc: 16, read_ports: 8, write_ports: 8, max_burst: 8 }
    }

    #[test]
    fn drive_read_reaches_full_bandwidth() {
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_read_network(design, geom());
            let lines = gen_lines(&geom(), 256, 1);
            let (res, _) = drive_read(net.as_mut(), &lines, false);
            assert!(
                res.lines_per_cycle() > 0.85,
                "{design:?}: {:.3} lines/cycle",
                res.lines_per_cycle()
            );
        }
    }

    #[test]
    fn drive_write_reaches_full_bandwidth() {
        for design in [Design::Baseline, Design::Medusa] {
            let mut net = build_write_network(design, geom());
            let (res, _) = drive_write(net.as_mut(), 32, 2, false);
            assert!(
                res.lines_per_cycle() > 0.85,
                "{design:?}: {:.3} lines/cycle",
                res.lines_per_cycle()
            );
        }
    }

    #[test]
    fn read_data_integrity_all_designs() {
        let g = geom();
        let lines = gen_lines(&g, 64, 3);
        for design in [Design::Baseline, Design::Medusa, Design::Axis] {
            let mut net = build_read_network(design, g);
            let (_, got) = drive_read(net.as_mut(), &lines, true);
            for p in 0..g.read_ports {
                let expect: Vec<Word> = lines
                    .iter()
                    .filter(|l| l.port == p)
                    .flat_map(|l| l.line.words().to_vec())
                    .collect();
                assert_eq!(got[p], expect, "{design:?} port {p}");
            }
        }
    }

    #[test]
    fn write_data_integrity_all_designs() {
        let g = geom();
        for design in [Design::Baseline, Design::Medusa, Design::Axis] {
            let mut net = build_write_network(design, g);
            let (_, got) = drive_write(net.as_mut(), 8, 4, true);
            // Recreate the pushed streams with the same PRNG.
            let n = g.words_per_line();
            let mut prng = Prng::new(4);
            for p in 0..g.write_ports {
                let words: Vec<Word> =
                    (0..8 * n).map(|_| prng.next_u64() & g.word_mask()).collect();
                let flat: Vec<Word> =
                    got[p].iter().flat_map(|l| l.words().to_vec()).collect();
                assert_eq!(flat, words, "{design:?} port {p}");
            }
        }
    }
}
