//! The Medusa transposition-based interconnect (paper §III, Figs 3–5).
//!
//! Instead of routing full-bandwidth lines through wide demuxes/muxes,
//! Medusa *transposes*: memory lines land in a deep, banked input buffer
//! (one `W_acc`-wide bank per word index, per-port address regions); a
//! shared barrel rotator moves one diagonal of words per cycle into a
//! banked output buffer laid out per-port. All parts operate on
//! `W_line` bits per cycle, so full DRAM bandwidth is preserved and
//! statically, evenly partitioned across the ports.
//!
//! ## Transposition schedule (read direction, paper Fig 4)
//!
//! Word `(x, y)` = word index `y` of a line destined to port `x`, stored
//! in input-buffer **bank `y`** at the address of `x`'s head line slot.
//! On fabric cycle `c` (`rot = c mod N`):
//!
//! * each *active* port `x` reads word index `y = (x + c) mod N`, i.e.
//!   input bank `(x + c) mod N` — a diagonal; banks are touched at most
//!   once;
//! * the shared rotator left-rotates the diagonal by `rot`, landing the
//!   word for port `j` at vector position `j`;
//! * output bank `j` (one bank per port) stores it at word address
//!   `(j + c) mod N` of the port's fill half.
//!
//! A line finishes after `N` participating cycles — the constant §III-E
//! latency. Ports join and leave the schedule independently (§III-F);
//! the global cycle counter keeps every port's read index aligned with
//! the single shared rotation control.

mod read;
mod write;

pub use read::MedusaReadNetwork;
pub use write::MedusaWriteNetwork;

/// Configuration knobs beyond the geometry (ablations).
#[derive(Clone, Copy, Debug)]
pub struct MedusaTuning {
    /// Extra pipeline stages in the rotation unit (0 = single-cycle
    /// combinational rotation, the default; `ceil(log2 N)` = fully
    /// pipelined as in Fig 5). Pipelining raises achievable frequency at
    /// the cost of `stages` extra cycles of latency.
    pub rotator_stages: usize,
}

impl Default for MedusaTuning {
    fn default() -> Self {
        MedusaTuning { rotator_stages: 0 }
    }
}
