//! Medusa memory-read data transfer network (paper §III-A1, Figs 3a/4).

use super::MedusaTuning;
use crate::config::PayloadMode;
use crate::hw::BankedSram;
use crate::interconnect::ReadNetwork;
use crate::sim::stats::{Counter, SampleId};
use crate::sim::Stats;
use crate::types::{Geometry, PortId, TaggedLine, Word};
use std::collections::VecDeque;

/// Per-port control state: head/tail pointers over the port's input
/// buffer region (§III-C1) plus double-buffer bookkeeping on the output
/// side (Fig 3a: "buffers next to the DNN accelerator are double
/// buffered").
#[derive(Debug)]
struct PortCtl {
    /// Lines currently resident in the port's input region (including the
    /// one being transposed).
    in_count: usize,
    /// Input region slot of the line currently/next being transposed.
    head: usize,
    /// Input region slot the next arriving line will occupy.
    tail: usize,
    /// Words of the head line already sent through the rotator.
    done_words: usize,
    /// Head line is mid-transposition.
    active: bool,
    /// Output half currently being filled by the rotator.
    fill_half: usize,
    /// Output half currently being drained by the port.
    drain_half: usize,
    /// Which output halves hold a complete line.
    half_full: [bool; 2],
    /// Words already drained from `drain_half`.
    drain_idx: usize,
    /// Per-cycle pop guard.
    word_taken_this_cycle: bool,
    /// Cycle at which each resident line arrived (latency accounting).
    arrival_cycles: VecDeque<u64>,
}

impl PortCtl {
    fn new() -> Self {
        PortCtl {
            in_count: 0,
            head: 0,
            tail: 0,
            done_words: 0,
            active: false,
            fill_half: 0,
            drain_half: 0,
            half_full: [false; 2],
            drain_idx: 0,
            word_taken_this_cycle: false,
            arrival_cycles: VecDeque::new(),
        }
    }
}

/// A completed fill waiting for the (ablation-only) pipelined rotator to
/// flush before the half becomes visible to the port.
#[derive(Debug)]
struct PendingHalf {
    port: PortId,
    half: usize,
    ready_cycle: u64,
}

pub struct MedusaReadNetwork {
    geom: Geometry,
    tuning: MedusaTuning,
    /// N banks (one per word index), W_acc wide, `ports * max_burst` deep.
    input: BankedSram,
    /// One bank per port, 2 * N deep (double buffer).
    output: BankedSram,
    ports: Vec<PortCtl>,
    pending_halves: VecDeque<PendingHalf>,
    delivered_this_cycle: bool,
    /// Fast backend: skip all bank payload traffic; every pointer,
    /// counter, and stat update stays identical (see DESIGN.md §"Fast
    /// backend").
    payload: PayloadMode,
    cycle: u64,
}

impl MedusaReadNetwork {
    pub fn new(geom: Geometry) -> Self {
        Self::with_tuning(geom, MedusaTuning::default())
    }

    pub fn with_tuning(geom: Geometry, tuning: MedusaTuning) -> Self {
        geom.validate().expect("invalid geometry");
        let n = geom.words_per_line();
        MedusaReadNetwork {
            geom,
            tuning,
            input: BankedSram::new(n, geom.read_ports * geom.max_burst),
            output: BankedSram::new(geom.read_ports, 2 * n),
            ports: (0..geom.read_ports).map(|_| PortCtl::new()).collect(),
            pending_halves: VecDeque::new(),
            delivered_this_cycle: false,
            payload: PayloadMode::Full,
            cycle: 0,
        }
    }

    fn n(&self) -> usize {
        self.geom.words_per_line()
    }

    /// Input-region base address for a port.
    fn region(&self, port: PortId) -> usize {
        port * self.geom.max_burst
    }
}

impl ReadNetwork for MedusaReadNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Medusa
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn mem_can_deliver(&self, port: PortId) -> bool {
        !self.delivered_this_cycle && self.ports[port].in_count < self.geom.max_burst
    }

    fn mem_deliver(&mut self, tl: TaggedLine) {
        assert!(!self.delivered_this_cycle, "second line on the memory interface in one cycle");
        let n = self.n();
        assert_eq!(tl.line.num_words(), n);
        let p = tl.port;
        assert!(self.ports[p].in_count < self.geom.max_burst, "input region overflow, port {p}");
        self.delivered_this_cycle = true;
        // The W_line line is written across all N banks in one cycle
        // (word y -> bank y), at the port's tail slot address. Elided
        // mode skips the payload writes; the pointer bookkeeping below
        // is what the rest of the datapath actually keys off.
        if !self.payload.is_elided() {
            let slot = self.region(p) + self.ports[p].tail;
            for y in 0..n {
                self.input.write(y, slot, tl.line.word(y) & self.geom.word_mask());
            }
        }
        let ctl = &mut self.ports[p];
        ctl.tail = (ctl.tail + 1) % self.geom.max_burst;
        ctl.in_count += 1;
        ctl.arrival_cycles.push_back(self.cycle);
    }

    fn port_free_lines(&self, port: PortId) -> usize {
        self.geom.max_burst - self.ports[port].in_count
    }

    fn port_word_available(&self, port: PortId) -> bool {
        let c = &self.ports[port];
        !c.word_taken_this_cycle && c.half_full[c.drain_half]
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        let n = self.n();
        let elided = self.payload.is_elided();
        let ctl = &mut self.ports[port];
        assert!(!ctl.word_taken_this_cycle, "port {port} popped twice in one cycle");
        if !ctl.half_full[ctl.drain_half] {
            return None;
        }
        let w = if elided {
            0 // the canonical shadow word; drain pointers advance as usual
        } else {
            let addr = ctl.drain_half * n + ctl.drain_idx;
            self.output.read(port, addr)
        };
        let ctl = &mut self.ports[port];
        ctl.word_taken_this_cycle = true;
        ctl.drain_idx += 1;
        if ctl.drain_idx == n {
            ctl.half_full[ctl.drain_half] = false;
            ctl.drain_half = 1 - ctl.drain_half;
            ctl.drain_idx = 0;
        }
        Some(w)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.delivered_this_cycle = false;
        let elided = self.payload.is_elided();
        if !elided {
            self.input.new_cycle();
            self.output.new_cycle();
        }
        let n = self.n();
        let rot = (cycle % n as u64) as usize;

        // Release halves whose pipelined-rotator flush completed.
        while let Some(p) = self.pending_halves.front() {
            if p.ready_cycle <= cycle {
                let p = self.pending_halves.pop_front().unwrap();
                self.ports[p.port].half_full[p.half] = true;
            } else {
                break;
            }
        }

        // Activation: a port starts transposing its head line when one is
        // resident and its fill half is free (§III-F: no waiting on other
        // ports).
        for port in 0..self.geom.read_ports {
            let pending_blocks = self
                .pending_halves
                .iter()
                .any(|ph| ph.port == port && ph.half == self.ports[port].fill_half);
            let ctl = &mut self.ports[port];
            ctl.word_taken_this_cycle = false;
            if !ctl.active && ctl.in_count > 0 && !ctl.half_full[ctl.fill_half] && !pending_blocks
            {
                ctl.active = true;
                ctl.done_words = 0;
            }
        }

        // Diagonal read + shared rotation + transposed store, fused.
        //
        // The physical datapath (Fig 4) reads the diagonal
        // `v[k] = bank[k][head(port (k - rot) mod N)]`, left-rotates the
        // vector by `rot` through the shared barrel shifter, and stores
        // position j into output bank j at word address (j + rot) mod N.
        // Composing the three steps: output bank j receives exactly the
        // word read from input bank (j + rot) mod N — so the simulator
        // applies the composition directly per port, touching each input
        // and output bank at most once per cycle (the SRAM models still
        // enforce the physical port limits). The rotation unit itself is
        // modelled and tested in `hw::rotator`; its pipeline latency is
        // accounted by `tuning.rotator_stages`. The property suite
        // (prop_read_data_integrity, fig4_example) pins the composed
        // schedule to the paper's semantics.
        let mut completed = 0u64;
        let mut words_rotated = 0u64;
        for j in 0..self.geom.read_ports {
            if !self.ports[j].active {
                continue;
            }
            // The diagonal address math stays live in elided mode (it
            // drives no state, but keeping it out of the gate would
            // invite drift); only the bank accesses are skipped.
            let k = (j + rot) % n;
            if !elided {
                let slot = self.region(j) + self.ports[j].head;
                let word = self.input.read(k, slot);
                let ctl = &self.ports[j];
                self.output.write(j, ctl.fill_half * n + k, word);
            }
            let ctl = &mut self.ports[j];
            ctl.done_words += 1;
            words_rotated += 1;
            if ctl.done_words == n {
                // Line fully transposed: advance head, free the input
                // slot, flip the fill half.
                ctl.active = false;
                ctl.done_words = 0;
                ctl.head = (ctl.head + 1) % self.geom.max_burst;
                ctl.in_count -= 1;
                if let Some(arr) = ctl.arrival_cycles.pop_front() {
                    stats.sample(SampleId::MedusaReadLineLatencyCycles, cycle - arr);
                }
                if self.tuning.rotator_stages == 0 {
                    ctl.half_full[ctl.fill_half] = true;
                } else {
                    self.pending_halves.push_back(PendingHalf {
                        port: j,
                        half: ctl.fill_half,
                        ready_cycle: cycle + self.tuning.rotator_stages as u64,
                    });
                }
                ctl.fill_half = 1 - ctl.fill_half;
                completed += 1;
            }
        }
        stats.add(Counter::MedusaReadWordsRotated, words_rotated);
        stats.add(Counter::MedusaReadLinesTransposed, completed);
    }

    fn nominal_latency(&self) -> usize {
        // §III-E: constant W_line / W_acc cycles, plus rotator pipelining
        // if enabled, plus one activation cycle.
        self.n() + self.tuning.rotator_stages + 1
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert!(
            self.ports.iter().all(|c| c.in_count == 0 && !c.active),
            "payload mode change on a non-empty network"
        );
        self.payload = mode;
    }

    fn is_leap_idle(&self) -> bool {
        self.pending_halves.is_empty()
            && self.ports.iter().all(|c| {
                c.in_count == 0 && !c.active && !c.half_full[0] && !c.half_full[1]
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Line;

    fn geom(n_ports: usize, w_line: usize, max_burst: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst }
    }

    fn mk_line(port: usize, tag: u64, n: usize) -> Line {
        // Distinct 16-bit words: [port:5][tag:5][y:6] (ports are masked
        // to W_acc = 16 bits at the memory interface, so stay within it).
        Line::from_words(
            (0..n as u64)
                .map(|y| (((port as u64) & 0x1f) << 11) | ((tag & 0x1f) << 6) | y)
                .collect(),
        )
    }

    /// Drive the network: deliver `lines[i]` when possible, pop words
    /// eagerly from all ports, return per-port word streams.
    fn run(
        net: &mut MedusaReadNetwork,
        lines: Vec<TaggedLine>,
        max_cycles: u64,
    ) -> Vec<Vec<Word>> {
        let mut stats = Stats::new();
        let nports = net.geometry().read_ports;
        let total_words = lines.len() * net.geometry().words_per_line();
        let mut got: Vec<Vec<Word>> = vec![Vec::new(); nports];
        let mut next = 0usize;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            if next < lines.len() && net.mem_can_deliver(lines[next].port) {
                net.mem_deliver(lines[next].clone());
                next += 1;
            }
            for p in 0..nports {
                if net.port_word_available(p) {
                    got[p].push(net.port_take_word(p).unwrap());
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == total_words {
                break;
            }
        }
        got
    }

    #[test]
    fn fig4_example_n4() {
        // The paper's Fig 4: W_line = 64, W_acc = 16, N = 4. One line per
        // port; each port must receive its own line's words in index
        // order.
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaReadNetwork::new(g);
        let lines: Vec<TaggedLine> =
            (0..4).map(|p| TaggedLine { port: p, line: mk_line(p, 0, n) }).collect();
        let got = run(&mut net, lines, 100);
        for p in 0..4 {
            assert_eq!(got[p], mk_line(p, 0, n).words().to_vec(), "port {p}");
        }
    }

    #[test]
    fn latency_overhead_is_constant_n() {
        // §III-E: first word arrives W_line/W_acc (+O(1)) cycles after
        // the line is delivered — for every port, regardless of when the
        // transfer starts.
        for start_port in 0..4usize {
            let g = geom(4, 64, 4);
            let n = g.words_per_line();
            let mut net = MedusaReadNetwork::new(g);
            let mut stats = Stats::new();
            // Warm up an odd number of cycles so transfers start at
            // arbitrary rotation phases.
            let warm = 3 + start_port as u64;
            for c in 0..warm {
                net.tick(c, &mut stats);
            }
            net.mem_deliver(TaggedLine { port: start_port, line: mk_line(start_port, 1, n) });
            let mut first = None;
            for c in warm..warm + 40 {
                net.tick(c, &mut stats);
                if net.port_word_available(start_port) {
                    first = Some(c - warm + 1);
                    break;
                }
            }
            let lat = first.expect("word never arrived") as usize;
            assert!(
                lat <= net.nominal_latency() && lat >= n,
                "port {start_port}: latency {lat}, nominal {} (N = {n})",
                net.nominal_latency()
            );
        }
    }

    #[test]
    fn full_bandwidth_one_line_per_cycle() {
        // All ports busy: the network must absorb one line per cycle and
        // deliver one word per port per cycle, sustained.
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaReadNetwork::new(g);
        let total = 64usize;
        let lines: Vec<TaggedLine> =
            (0..total).map(|i| TaggedLine { port: i % 4, line: mk_line(i % 4, i as u64, n) }).collect();
        let mut stats = Stats::new();
        let mut next = 0usize;
        let mut popped = 0usize;
        let mut done_at = 0u64;
        for c in 0..2000u64 {
            net.tick(c, &mut stats);
            if next < lines.len() && net.mem_can_deliver(lines[next].port) {
                net.mem_deliver(lines[next].clone());
                next += 1;
            }
            for p in 0..4 {
                if net.port_word_available(p) {
                    net.port_take_word(p).unwrap();
                    popped += 1;
                }
            }
            if popped == total * n {
                done_at = c;
                break;
            }
        }
        assert_eq!(popped, total * n, "did not drain");
        // 64 lines x 4 words at 4 words/cycle = 64 cycles + pipeline fill.
        assert!(
            done_at <= total as u64 + 3 * n as u64,
            "took {done_at} cycles for {total} lines (N = {n})"
        );
    }

    #[test]
    fn no_interference_between_ports() {
        // §III-F: a port joining mid-stream progresses at full rate and
        // does not perturb ports already in progress. Port 0 streams
        // continuously; port 1 joins late. Compare port 0's word-arrival
        // cadence with and without port 1's traffic.
        let g = geom(4, 64, 8);
        let n = g.words_per_line();

        let cadence = |with_p1: bool| -> Vec<u64> {
            let mut net = MedusaReadNetwork::new(g);
            let mut stats = Stats::new();
            let mut arrivals = Vec::new();
            let mut sent0 = 0u64;
            let mut sent1 = 0u64;
            for c in 0..400u64 {
                net.tick(c, &mut stats);
                // Port 0 keeps its region topped up.
                if sent0 < 16 && net.mem_can_deliver(0) {
                    net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, sent0, n) });
                    sent0 += 1;
                } else if with_p1 && c >= 17 && sent1 < 8 && net.mem_can_deliver(1) {
                    net.mem_deliver(TaggedLine { port: 1, line: mk_line(1, sent1, n) });
                    sent1 += 1;
                }
                if net.port_word_available(0) {
                    net.port_take_word(0).unwrap();
                    arrivals.push(c);
                }
                if with_p1 && net.port_word_available(1) {
                    net.port_take_word(1).unwrap();
                }
            }
            arrivals
        };

        let solo = cadence(false);
        let shared = cadence(true);
        assert_eq!(solo, shared, "port 1's traffic changed port 0's word cadence");
    }

    #[test]
    fn burst_to_single_port_absorbed() {
        // §III-C1: the input buffer holds MaxBurstLen lines per port; a
        // full burst arrives back-to-back at one line/cycle with no
        // backpressure.
        let g = geom(4, 64, 8);
        let n = g.words_per_line();
        let mut net = MedusaReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        // Burst arrival requires draining in parallel after N cycles; we
        // deliver 8 lines on consecutive cycles.
        for i in 0..8u64 {
            assert!(net.mem_can_deliver(0), "line {i} back-pressured");
            net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, i, n) });
            net.tick(i + 1, &mut stats);
            if net.port_word_available(0) {
                net.port_take_word(0).unwrap();
            }
        }
        // Drain everything; verify order across the whole burst.
        let mut got = Vec::new();
        for c in 9..400u64 {
            net.tick(c, &mut stats);
            if net.port_word_available(0) {
                got.push(net.port_take_word(0).unwrap());
            }
            if got.len() == 8 * n - 1 {
                break;
            }
        }
        // (one word was popped during the arrival loop — re-derive full
        // expected stream minus that prefix)
        let mut expect = Vec::new();
        for i in 0..8u64 {
            expect.extend(mk_line(0, i, n).words().to_vec());
        }
        // The popped prefix words were in order; `got` must be the
        // remaining suffix.
        assert_eq!(&expect[expect.len() - got.len()..], &got[..]);
    }

    #[test]
    fn irregular_port_count() {
        // §III-G: 3 ports on a 4-word interface — unused port pruned.
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 3, write_ports: 3, max_burst: 4 };
        let n = g.words_per_line();
        let mut net = MedusaReadNetwork::new(g);
        let lines: Vec<TaggedLine> =
            (0..9).map(|i| TaggedLine { port: i % 3, line: mk_line(i % 3, i as u64, n) }).collect();
        let got = run(&mut net, lines, 1000);
        for p in 0..3 {
            let mut expect = Vec::new();
            for i in 0..9 {
                if i % 3 == p {
                    expect.extend(mk_line(p, i as u64, n).words().to_vec());
                }
            }
            assert_eq!(got[p], expect, "port {p}");
        }
    }

    #[test]
    fn wide_interface_32_ports() {
        // The paper's representative point: 512-bit interface, 32 ports.
        let g = geom(32, 512, 4);
        let n = g.words_per_line();
        assert_eq!(n, 32);
        let mut net = MedusaReadNetwork::new(g);
        let lines: Vec<TaggedLine> =
            (0..64).map(|i| TaggedLine { port: i % 32, line: mk_line(i % 32, i as u64, n) }).collect();
        let got = run(&mut net, lines, 5000);
        for p in 0..32 {
            let mut expect = Vec::new();
            for i in 0..64 {
                if i % 32 == p {
                    expect.extend(mk_line(p, i as u64, n).words().to_vec());
                }
            }
            assert_eq!(got[p], expect, "port {p}");
        }
    }

    #[test]
    fn pipelined_rotator_same_data_more_latency() {
        let g = geom(8, 128, 4);
        let n = g.words_per_line();
        let lines: Vec<TaggedLine> =
            (0..16).map(|i| TaggedLine { port: i % 8, line: mk_line(i % 8, i as u64, n) }).collect();

        let mut plain = MedusaReadNetwork::new(g);
        let got_plain = run(&mut plain, lines.clone(), 2000);

        let mut piped =
            MedusaReadNetwork::with_tuning(g, MedusaTuning { rotator_stages: 3 });
        assert_eq!(piped.nominal_latency(), plain.nominal_latency() + 3);
        let got_piped = run(&mut piped, lines, 2000);
        assert_eq!(got_plain, got_piped, "pipelining must not change data");
    }

    #[test]
    fn credit_accounting_matches_occupancy() {
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        assert_eq!(net.port_free_lines(2), 4);
        net.mem_deliver(TaggedLine { port: 2, line: mk_line(2, 0, n) });
        assert_eq!(net.port_free_lines(2), 3);
    }
}
