//! Medusa memory-write data transfer network (paper §III-A2, Fig 3b).
//!
//! The mirror image of the read direction: each accelerator port writes
//! words into its own bank of a double-buffered input buffer; the shared
//! rotator transposes completed port lines into the line-organized,
//! `MaxBurstLen x N`-deep output buffer; the request arbiter only issues
//! a write once a port has accumulated the full burst there (§III-C2).
//!
//! Schedule derivation (inverse of the read direction): on cycle `c`
//! (`rot = c mod N`), active port `x` reads word index `y = (x + c) mod N`
//! from its own input bank; the vector indexed by *port* is rotated
//! **right** by `rot`, landing `word(p, j)` at position `j` where
//! `p = (j - c) mod N`; output bank `j` (one bank per word index) stores
//! it at port `p`'s current output line slot.

use super::MedusaTuning;
use crate::config::PayloadMode;
use crate::hw::BankedSram;
use crate::interconnect::WriteNetwork;
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, Word};
use std::collections::VecDeque;

#[derive(Debug)]
struct PortCtl {
    /// Input half being filled by the accelerator port.
    fill_half: usize,
    /// Words pushed into the fill half so far.
    fill_idx: usize,
    /// Which input halves hold a complete, untransposed line.
    half_full: [bool; 2],
    /// Input half being drained by the rotator.
    drain_half: usize,
    /// Transposition in progress for `drain_half`.
    active: bool,
    done_words: usize,
    /// Output region slot the in-progress line is landing in.
    out_tail: usize,
    /// Output region slot of the oldest completed line.
    out_head: usize,
    /// Completed lines resident in the output region.
    ready: usize,
    /// Lines in the output region (completed + in-progress).
    out_count: usize,
    word_pushed_this_cycle: bool,
}

impl PortCtl {
    fn new() -> Self {
        PortCtl {
            fill_half: 0,
            fill_idx: 0,
            half_full: [false; 2],
            drain_half: 0,
            active: false,
            done_words: 0,
            out_tail: 0,
            out_head: 0,
            ready: 0,
            out_count: 0,
            word_pushed_this_cycle: false,
        }
    }
}

#[derive(Debug)]
struct PendingReady {
    port: PortId,
    ready_cycle: u64,
}

pub struct MedusaWriteNetwork {
    geom: Geometry,
    tuning: MedusaTuning,
    /// One bank per port, 2 * N deep (input double buffer, Fig 3b).
    input: BankedSram,
    /// N banks (one per word index), `ports * max_burst` deep.
    output: BankedSram,
    ports: Vec<PortCtl>,
    pending_ready: VecDeque<PendingReady>,
    line_taken_this_cycle: bool,
    /// Fast backend: skip bank payload traffic, emit elided lines.
    payload: PayloadMode,
    cycle: u64,
}

impl MedusaWriteNetwork {
    pub fn new(geom: Geometry) -> Self {
        Self::with_tuning(geom, MedusaTuning::default())
    }

    pub fn with_tuning(geom: Geometry, tuning: MedusaTuning) -> Self {
        geom.validate().expect("invalid geometry");
        let n = geom.words_per_line();
        MedusaWriteNetwork {
            geom,
            tuning,
            input: BankedSram::new(geom.write_ports, 2 * n),
            output: BankedSram::new(n, geom.write_ports * geom.max_burst),
            ports: (0..geom.write_ports).map(|_| PortCtl::new()).collect(),
            pending_ready: VecDeque::new(),
            line_taken_this_cycle: false,
            payload: PayloadMode::Full,
            cycle: 0,
        }
    }

    fn n(&self) -> usize {
        self.geom.words_per_line()
    }

    fn region(&self, port: PortId) -> usize {
        port * self.geom.max_burst
    }
}

impl WriteNetwork for MedusaWriteNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Medusa
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn port_can_accept(&self, port: PortId) -> bool {
        let c = &self.ports[port];
        !c.word_pushed_this_cycle && !c.half_full[c.fill_half]
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        let n = self.n();
        let mask = self.geom.word_mask();
        let elided = self.payload.is_elided();
        let ctl = &mut self.ports[port];
        assert!(!ctl.word_pushed_this_cycle, "port {port} pushed twice in one cycle");
        assert!(!ctl.half_full[ctl.fill_half], "input half overflow, port {port}");
        let addr = ctl.fill_half * n + ctl.fill_idx;
        ctl.word_pushed_this_cycle = true;
        ctl.fill_idx += 1;
        let fill_half = ctl.fill_half;
        if ctl.fill_idx == n {
            ctl.half_full[fill_half] = true;
            ctl.fill_half = 1 - fill_half;
            ctl.fill_idx = 0;
        }
        if !elided {
            self.input.write(port, addr, w & mask);
        }
    }

    fn mem_lines_ready(&self, port: PortId) -> usize {
        self.ports[port].ready
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        assert!(!self.line_taken_this_cycle, "second line on the memory interface in one cycle");
        let n = self.n();
        if self.ports[port].ready == 0 {
            return None;
        }
        // Fill the line straight from the banks — no intermediate Vec,
        // and for inline-sized lines (N <= 32) no allocation at all.
        // Elided mode emits a header-only shadow instead.
        let line = if self.payload.is_elided() {
            Line::elided(n)
        } else {
            let slot = self.region(port) + self.ports[port].out_head;
            let output = &mut self.output;
            Line::from_fn(n, |y| output.read(y, slot))
        };
        let ctl = &mut self.ports[port];
        ctl.out_head = (ctl.out_head + 1) % self.geom.max_burst;
        ctl.ready -= 1;
        ctl.out_count -= 1;
        self.line_taken_this_cycle = true;
        Some(line)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.line_taken_this_cycle = false;
        let elided = self.payload.is_elided();
        if !elided {
            self.input.new_cycle();
            self.output.new_cycle();
        }
        let n = self.n();
        let rot = (cycle % n as u64) as usize;

        while let Some(p) = self.pending_ready.front() {
            if p.ready_cycle <= cycle {
                let p = self.pending_ready.pop_front().unwrap();
                self.ports[p.port].ready += 1;
            } else {
                break;
            }
        }

        // Activation: start transposing a completed input half when the
        // output region has a free slot.
        for port in 0..self.geom.write_ports {
            let ctl = &mut self.ports[port];
            ctl.word_pushed_this_cycle = false;
            if !ctl.active && ctl.half_full[ctl.drain_half] && ctl.out_count < self.geom.max_burst
            {
                ctl.active = true;
                ctl.done_words = 0;
                ctl.out_count += 1; // reserve the slot at out_tail
            }
        }

        // Diagonal read + right-rotation + line-organized store, fused.
        //
        // The physical datapath reads `v[x] = input bank x, word index
        // (x + rot) mod N`, right-rotates by `rot` through the shared
        // barrel shifter (landing word(p, j) at position j, with
        // p = (j - rot) mod N), and stores position j into output bank
        // j at port p's reserved slot. Composed per port: the word read
        // from input bank p at index j = (p + rot) mod N goes straight
        // to output bank j — each input and output bank touched at most
        // once per cycle, with the SRAM models enforcing the physical
        // port limits. Rotation hardware is modelled/tested in
        // `hw::rotator`; its latency is `tuning.rotator_stages`.
        let mut completed = 0u64;
        let mut words_rotated = 0u64;
        for p in 0..self.geom.write_ports {
            if !self.ports[p].active {
                continue;
            }
            let j = (p + rot) % n;
            if !elided {
                let addr = self.ports[p].drain_half * n + j;
                let word = self.input.read(p, addr);
                let slot = self.region(p) + self.ports[p].out_tail;
                self.output.write(j, slot, word);
            }
            let ctl = &mut self.ports[p];
            ctl.done_words += 1;
            words_rotated += 1;
            if ctl.done_words == n {
                // Line fully transposed: release the input half, finalize
                // the output slot.
                ctl.active = false;
                ctl.done_words = 0;
                ctl.half_full[ctl.drain_half] = false;
                ctl.drain_half = 1 - ctl.drain_half;
                ctl.out_tail = (ctl.out_tail + 1) % self.geom.max_burst;
                if self.tuning.rotator_stages == 0 {
                    ctl.ready += 1;
                } else {
                    self.pending_ready.push_back(PendingReady {
                        port: p,
                        ready_cycle: cycle + self.tuning.rotator_stages as u64,
                    });
                }
                completed += 1;
            }
        }
        stats.add(Counter::MedusaWriteWordsRotated, words_rotated);
        stats.add(Counter::MedusaWriteLinesTransposed, completed);
    }

    fn nominal_latency(&self) -> usize {
        self.n() + self.tuning.rotator_stages + 1
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert!(
            self.ports.iter().all(|c| c.fill_idx == 0 && !c.active && c.out_count == 0),
            "payload mode change on a non-empty network"
        );
        self.payload = mode;
    }

    fn is_leap_idle(&self) -> bool {
        self.pending_ready.is_empty()
            && self.ports.iter().all(|c| {
                !c.active
                    && c.fill_idx == 0
                    && !c.half_full[0]
                    && !c.half_full[1]
                    && c.ready == 0
                    && c.out_count == 0
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(n_ports: usize, w_line: usize, max_burst: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst }
    }

    fn word_of(port: usize, line: u64, y: usize) -> Word {
        ((port as u64) << 12) | ((line & 0x3f) << 6) | y as u64
    }

    /// Push `lines_per_port` lines of words on every port, drain lines on
    /// the memory side round-robin; return lines per port in arrival
    /// order.
    fn run(
        net: &mut MedusaWriteNetwork,
        lines_per_port: usize,
        max_cycles: u64,
    ) -> Vec<Vec<Line>> {
        let mut stats = Stats::new();
        let g = *net.geometry();
        let n = g.words_per_line();
        let mut pushed = vec![0usize; g.write_ports];
        let mut got: Vec<Vec<Line>> = vec![Vec::new(); g.write_ports];
        let mut rr = 0usize;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            // Memory side: take one ready line per cycle, round-robin.
            for k in 0..g.write_ports {
                let p = (rr + k) % g.write_ports;
                if net.mem_lines_ready(p) > 0 {
                    got[p].push(net.mem_take_line(p).unwrap());
                    rr = p + 1;
                    break;
                }
            }
            // Port side: push next word on each port.
            for p in 0..g.write_ports {
                if pushed[p] < lines_per_port * n && net.port_can_accept(p) {
                    let line_idx = (pushed[p] / n) as u64;
                    let y = pushed[p] % n;
                    net.port_push_word(p, word_of(p, line_idx, y));
                    pushed[p] += 1;
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == lines_per_port * g.write_ports {
                break;
            }
        }
        got
    }

    #[test]
    fn transposes_port_words_into_lines() {
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let got = run(&mut net, 2, 500);
        for p in 0..4 {
            assert_eq!(got[p].len(), 2, "port {p}");
            for (li, line) in got[p].iter().enumerate() {
                for y in 0..n {
                    assert_eq!(
                        line.word(y),
                        word_of(p, li as u64, y),
                        "port {p} line {li} word {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn all_ports_full_rate_aggregate_bandwidth() {
        // 4 ports x 1 word/cycle = 1 line/cycle aggregate; draining 32
        // lines must take ~32 + fill cycles.
        let g = geom(4, 64, 8);
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let lines_per_port = 8usize;
        let mut stats = Stats::new();
        let mut pushed = vec![0usize; 4];
        let mut taken = 0usize;
        let total = lines_per_port * 4;
        let mut rr = 0;
        let mut done_at = 0u64;
        for c in 0..4000u64 {
            net.tick(c, &mut stats);
            for k in 0..4 {
                let p = (rr + k) % 4;
                if net.mem_lines_ready(p) > 0 {
                    net.mem_take_line(p).unwrap();
                    taken += 1;
                    rr = p + 1;
                    break;
                }
            }
            for p in 0..4 {
                if pushed[p] < lines_per_port * n && net.port_can_accept(p) {
                    net.port_push_word(p, word_of(p, (pushed[p] / n) as u64, pushed[p] % n));
                    pushed[p] += 1;
                }
            }
            if taken == total {
                done_at = c;
                break;
            }
        }
        assert_eq!(taken, total);
        assert!(done_at <= (lines_per_port * n) as u64 + 4 * n as u64, "took {done_at} cycles");
    }

    #[test]
    fn write_latency_constant_overhead() {
        // From last word pushed to line ready ~= N cycles (§III-E applies
        // symmetrically).
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let mut stats = Stats::new();
        let mut c = 0u64;
        for y in 0..n {
            net.tick(c, &mut stats);
            net.port_push_word(0, word_of(0, 0, y));
            c += 1;
        }
        let pushed_done = c;
        loop {
            net.tick(c, &mut stats);
            if net.mem_lines_ready(0) > 0 {
                break;
            }
            c += 1;
            assert!(c < pushed_done + 3 * n as u64, "line never became ready");
        }
        let overhead = (c - pushed_done) as usize;
        assert!(overhead <= net.nominal_latency() + 1, "overhead {overhead} cycles");
    }

    #[test]
    fn arbiter_view_only_counts_complete_lines() {
        let g = geom(4, 64, 4);
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let mut stats = Stats::new();
        for y in 0..n - 1 {
            net.tick(y as u64, &mut stats);
            net.port_push_word(0, y as Word);
        }
        // Partial line: never ready no matter how long we wait.
        for c in n as u64..(4 * n) as u64 {
            net.tick(c, &mut stats);
            assert_eq!(net.mem_lines_ready(0), 0);
        }
    }

    #[test]
    fn irregular_port_count() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 3, write_ports: 3, max_burst: 4 };
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let got = run(&mut net, 3, 1000);
        for p in 0..3 {
            assert_eq!(got[p].len(), 3);
            for (li, line) in got[p].iter().enumerate() {
                for y in 0..n {
                    assert_eq!(line.word(y), word_of(p, li as u64, y));
                }
            }
        }
    }

    #[test]
    fn wide_32_port_roundtrip() {
        let g = geom(32, 512, 4);
        let mut net = MedusaWriteNetwork::new(g);
        let got = run(&mut net, 2, 10_000);
        for p in 0..32 {
            assert_eq!(got[p].len(), 2, "port {p}");
        }
    }

    #[test]
    fn backpressure_when_output_region_full() {
        // Never drain the memory side: after max_burst lines + both input
        // halves, the port must be back-pressured.
        let g = geom(4, 64, 2);
        let n = g.words_per_line();
        let mut net = MedusaWriteNetwork::new(g);
        let mut stats = Stats::new();
        let mut pushed = 0usize;
        for c in 0..400u64 {
            net.tick(c, &mut stats);
            if net.port_can_accept(0) {
                net.port_push_word(0, pushed as Word);
                pushed += 1;
            }
        }
        // Capacity: max_burst output slots + 2 input halves = 4 lines.
        let max_capacity = (g.max_burst + 2) * n;
        assert!(pushed <= max_capacity, "pushed {pushed} > capacity {max_capacity}");
        assert!(pushed >= g.max_burst * n, "absorbed too little: {pushed}");
        assert_eq!(net.mem_lines_ready(0), g.max_burst);
    }

    #[test]
    fn pipelined_rotator_same_data() {
        let g = geom(8, 128, 4);
        let mut plain = MedusaWriteNetwork::new(g);
        let got_plain = run(&mut plain, 4, 4000);
        let mut piped = MedusaWriteNetwork::with_tuning(g, MedusaTuning { rotator_stages: 3 });
        let got_piped = run(&mut piped, 4, 4000);
        assert_eq!(got_plain, got_piped);
    }
}
