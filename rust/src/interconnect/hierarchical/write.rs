//! Hierarchical memory-write network: per-cluster Medusa transposers
//! assemble lines locally; the shared trunk carries them to a staging
//! buffer on the memory interface (see the module docs in [`super`]).

use super::{HierConfig, Route};
use crate::config::PayloadMode;
use crate::interconnect::medusa::MedusaWriteNetwork;
use crate::interconnect::{Design, WriteNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, Word};
use std::collections::VecDeque;

/// One assembled line crossing the trunk toward the memory interface.
/// `remaining` is a relative countdown (see the read-side docs for why
/// absolute stamps are forbidden).
struct TrunkEntry {
    port: PortId,
    line: Line,
    remaining: u64,
}

pub struct HierWriteNetwork {
    geom: Geometry,
    cfg: HierConfig,
    clusters: Vec<MedusaWriteNetwork>,
    bypass: Option<MedusaWriteNetwork>,
    /// The shared trunk bus: strict FIFO, at most one line staged per
    /// trunk edge.
    trunk: VecDeque<TrunkEntry>,
    /// Trunk occupancy per clustered global port (bounds the staging
    /// buffer together with `staged`).
    in_trunk: Vec<usize>,
    /// Post-trunk lines per clustered global port, visible to the
    /// arbiter via `mem_lines_ready`.
    staged: Vec<VecDeque<Line>>,
    /// Round-robin scan start per cluster for trunk ingress (prevents
    /// a low-numbered port from starving its cluster mates).
    rr: Vec<usize>,
    /// Memory-interface guard: one line taken per fabric cycle.
    line_taken_this_cycle: bool,
    /// Bypassed takes since the last tick (`mem_take_line` has no
    /// stats handle; flushed into the counter at the next tick).
    pending_bypassed: u64,
}

impl HierWriteNetwork {
    pub fn new(geom: Geometry, cfg: HierConfig) -> Self {
        geom.validate().expect("invalid geometry");
        cfg.validate(&geom).expect("invalid hierarchical config");
        let sub = cfg.sub_geom(&geom, cfg.cluster_ports);
        let n_clusters = cfg.clusters(geom.write_ports);
        HierWriteNetwork {
            clusters: (0..n_clusters).map(|_| MedusaWriteNetwork::new(sub)).collect(),
            bypass: (cfg.bypass_ports > 0)
                .then(|| MedusaWriteNetwork::new(cfg.sub_geom(&geom, cfg.bypass_ports))),
            trunk: VecDeque::new(),
            in_trunk: vec![0; geom.write_ports],
            staged: (0..geom.write_ports).map(|_| VecDeque::new()).collect(),
            rr: vec![0; n_clusters],
            line_taken_this_cycle: false,
            pending_bypassed: 0,
            geom,
            cfg,
        }
    }

    fn route(&self, port: PortId) -> Route {
        self.cfg.route(port, self.geom.write_ports)
    }
}

impl WriteNetwork for HierWriteNetwork {
    fn design(&self) -> Design {
        Design::Hierarchical(self.cfg)
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn port_can_accept(&self, port: PortId) -> bool {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_ref().unwrap().port_can_accept(l),
            Route::Cluster(c, l) => self.clusters[c].port_can_accept(l),
        }
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_mut().unwrap().port_push_word(l, w),
            Route::Cluster(c, l) => self.clusters[c].port_push_word(l, w),
        }
    }

    fn mem_lines_ready(&self, port: PortId) -> usize {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_ref().unwrap().mem_lines_ready(l),
            // The arbiter only sees lines that finished the crossing —
            // it must never issue a write the trunk cannot yet back.
            Route::Cluster(..) => self.staged[port].len(),
        }
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        assert!(!self.line_taken_this_cycle, "second line on the memory interface in one cycle");
        let line = match self.route(port) {
            Route::Bypass(l) => {
                let line = self.bypass.as_mut().unwrap().mem_take_line(l)?;
                self.pending_bypassed += 1;
                Some(line)
            }
            Route::Cluster(..) => self.staged[port].pop_front(),
        };
        if line.is_some() {
            self.line_taken_this_cycle = true;
        }
        line
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        if self.pending_bypassed > 0 {
            stats.add(Counter::HierWriteLinesBypassed, self.pending_bypassed);
            self.pending_bypassed = 0;
        }
        self.line_taken_this_cycle = false;
        for cl in &mut self.clusters {
            cl.tick(cycle, stats);
        }
        if let Some(b) = &mut self.bypass {
            b.tick(cycle, stats);
        }
        // Trunk ingress: each cluster may surrender at most one
        // assembled line per fabric cycle (its memory-side interface is
        // one line wide), chosen round-robin over its local ports. The
        // `max_burst` gate bounds staging occupancy exactly as the
        // cluster bounds its own output region, so the trunk head can
        // always drain — no deadlock.
        for c in 0..self.clusters.len() {
            let np = self.cfg.cluster_ports;
            let start = self.rr[c];
            for i in 0..np {
                let l = (start + i) % np;
                let gp = c * np + l;
                if self.clusters[c].mem_lines_ready(l) > 0
                    && self.staged[gp].len() + self.in_trunk[gp] < self.geom.max_burst
                {
                    let line = self.clusters[c].mem_take_line(l).unwrap();
                    self.trunk.push_back(TrunkEntry {
                        port: gp,
                        line,
                        remaining: self.cfg.trunk_crossing(),
                    });
                    self.in_trunk[gp] += 1;
                    self.rr[c] = (l + 1) % np;
                    break;
                }
            }
        }
    }

    /// One trunk-clock edge: every in-flight line advances one pipeline
    /// stage; the bus then stages at most one fully-crossed line at the
    /// memory interface. Staging space was reserved at trunk entry, so
    /// the head always sinks.
    fn trunk_tick(&mut self, stats: &mut Stats) {
        for e in &mut self.trunk {
            if e.remaining > 0 {
                e.remaining -= 1;
            }
        }
        if self.trunk.front().map_or(false, |h| h.remaining == 0) {
            let e = self.trunk.pop_front().unwrap();
            self.staged[e.port].push_back(e.line);
            self.in_trunk[e.port] -= 1;
            stats.bump(Counter::HierWriteLinesOverTrunk);
        }
    }

    fn nominal_latency(&self) -> usize {
        self.clusters[0].nominal_latency() + self.cfg.levels
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert!(
            self.trunk.is_empty() && self.staged.iter().all(|q| q.is_empty()),
            "payload mode change with lines in flight"
        );
        for cl in &mut self.clusters {
            cl.set_payload_mode(mode);
        }
        if let Some(b) = &mut self.bypass {
            b.set_payload_mode(mode);
        }
    }

    fn is_leap_idle(&self) -> bool {
        self.trunk.is_empty()
            && self.pending_bypassed == 0
            && self.staged.iter().all(|q| q.is_empty())
            && self.clusters.iter().all(|c| c.is_leap_idle())
            && self.bypass.as_ref().map_or(true, |b| b.is_leap_idle())
    }

    fn trunk_occupancy(&self) -> usize {
        self.trunk.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(n_ports: usize, w_line: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst: 4 }
    }

    fn words_for(port: usize, tag: u64, n: usize) -> Vec<Word> {
        (0..n as u64).map(|y| (((port as u64) & 0x1f) << 11) | ((tag & 0x1f) << 6) | y).collect()
    }

    /// Push `lines_per_port` lines of words into every port, tick with a
    /// 1:1 trunk cadence, drain the memory side eagerly; return per-port
    /// line payloads in arrival order.
    fn run(
        net: &mut HierWriteNetwork,
        lines_per_port: usize,
        max_cycles: u64,
    ) -> Vec<Vec<Vec<Word>>> {
        let mut stats = Stats::new();
        let nports = net.geometry().write_ports;
        let n = net.geometry().words_per_line();
        let mut fed: Vec<usize> = vec![0; nports]; // words pushed per port
        let mut got: Vec<Vec<Vec<Word>>> = vec![Vec::new(); nports];
        let total = lines_per_port * n;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            net.trunk_tick(&mut stats);
            for p in 0..nports {
                if fed[p] < total && net.port_can_accept(p) {
                    let (tag, y) = ((fed[p] / n) as u64, fed[p] % n);
                    net.port_push_word(p, words_for(p, tag, n)[y]);
                    fed[p] += 1;
                }
            }
            // One line per cycle across the memory interface, scanning
            // ports in order (a stand-in for the arbiter).
            for p in 0..nports {
                if net.mem_lines_ready(p) > 0 {
                    let line = net.mem_take_line(p).unwrap();
                    got[p].push(line.words().to_vec());
                    break;
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == lines_per_port * nports {
                break;
            }
        }
        got
    }

    #[test]
    fn all_ports_assemble_their_lines_in_order() {
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 3, bypass_ports: 2, ..Default::default() };
        let mut net = HierWriteNetwork::new(g, cfg);
        let got = run(&mut net, 3, 4000);
        for p in 0..8 {
            assert_eq!(got[p].len(), 3, "port {p} line count");
            for (tag, line) in got[p].iter().enumerate() {
                assert_eq!(line, &words_for(p, tag as u64, n), "port {p} line {tag}");
            }
        }
        assert!(net.is_leap_idle());
    }

    #[test]
    fn clustered_lines_cross_the_trunk_and_count() {
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 3, bypass_ports: 2, ..Default::default() };
        let mut net = HierWriteNetwork::new(g, cfg);
        let mut stats = Stats::new();
        // One full line into clustered port 0 and bypass port 6.
        let mut done = false;
        for c in 0..200u64 {
            net.tick(c, &mut stats);
            net.trunk_tick(&mut stats);
            let y = c as usize;
            if y < n {
                net.port_push_word(0, words_for(0, 0, n)[y]);
                net.port_push_word(6, words_for(6, 0, n)[y]);
            }
            if net.mem_lines_ready(0) > 0 && net.mem_lines_ready(6) > 0 {
                done = true;
                break;
            }
        }
        assert!(done, "lines never reached the memory side");
        assert_eq!(stats.count(Counter::HierWriteLinesOverTrunk), 1, "clustered line crossed");
        assert_eq!(net.mem_take_line(0).unwrap().words(), &words_for(0, 0, n)[..]);
        // Bypass takes count on the next tick's flush.
        net.tick(1000, &mut stats);
        assert_eq!(net.mem_take_line(6).unwrap().words(), &words_for(6, 0, n)[..]);
        assert_eq!(stats.count(Counter::HierWriteLinesBypassed), 0);
        net.tick(1001, &mut stats);
        assert_eq!(stats.count(Counter::HierWriteLinesBypassed), 1);
    }

    #[test]
    fn trunk_ingress_round_robins_cluster_ports() {
        // Both local ports of one cluster hold finished lines; ingress
        // must alternate between them rather than always picking the
        // lower index.
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 2, ..Default::default() };
        let mut net = HierWriteNetwork::new(g, cfg);
        let mut stats = Stats::new();
        // Feed two lines into each of ports 0 and 1 (cluster 0), at one
        // word per port per cycle.
        for c in 0..(2 * n as u64 + 40) {
            net.tick(c, &mut stats);
            net.trunk_tick(&mut stats);
            let y = c as usize;
            if y < 2 * n {
                if net.port_can_accept(0) {
                    net.port_push_word(0, words_for(0, (y / n) as u64, n)[y % n]);
                }
                if net.port_can_accept(1) {
                    net.port_push_word(1, words_for(1, (y / n) as u64, n)[y % n]);
                }
            }
        }
        // All four lines staged; order must interleave the two ports.
        assert_eq!(net.mem_lines_ready(0), 2);
        assert_eq!(net.mem_lines_ready(1), 2);
        assert_eq!(stats.count(Counter::HierWriteLinesOverTrunk), 4);
    }

    #[test]
    fn staging_respects_the_burst_credit() {
        // Feed port 0 forever and never drain the memory side: the
        // ingress gate must cap staged + in-trunk occupancy at
        // max_burst, and backpressure must eventually reach the port.
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 2, ..Default::default() };
        let mut net = HierWriteNetwork::new(g, cfg);
        let mut stats = Stats::new();
        let mut fed = 0usize;
        for c in 0..400u64 {
            net.tick(c, &mut stats);
            net.trunk_tick(&mut stats);
            assert!(
                net.staged[0].len() + net.in_trunk[0] <= g.max_burst,
                "staging credit overrun at cycle {c}"
            );
            if net.port_can_accept(0) {
                net.port_push_word(0, words_for(0, (fed / n) as u64, n)[fed % n]);
                fed += 1;
            }
        }
        assert_eq!(net.mem_lines_ready(0), g.max_burst, "staging fills to the burst credit");
        assert!(!net.port_can_accept(0), "backpressure must reach the port");
        assert!(
            fed < 400,
            "an undrained memory side cannot absorb words forever (fed {fed})"
        );
    }

    #[test]
    fn idle_tick_and_trunk_tick_are_no_ops() {
        let g = geom(8, 128);
        let cfg = HierConfig { cluster_ports: 4, ..Default::default() };
        let mut net = HierWriteNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        assert!(net.is_leap_idle());
        let before: Vec<(&str, u64)> = stats.counters().collect();
        net.tick(1, &mut stats);
        net.trunk_tick(&mut stats);
        let after: Vec<(&str, u64)> = stats.counters().collect();
        assert_eq!(before, after, "idle edges must not move a counter");
        assert!(net.is_leap_idle());
    }
}
