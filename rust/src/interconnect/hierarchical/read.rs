//! Hierarchical memory-read network: trunk bus + per-cluster Medusa
//! transposers + optional trunk-direct bypass transposer (see the
//! module docs in [`super`] for the port mapping and the trunk model).

use super::{HierConfig, Route};
use crate::config::PayloadMode;
use crate::interconnect::medusa::MedusaReadNetwork;
use crate::interconnect::{Design, ReadNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, PortId, TaggedLine, Word};
use std::collections::VecDeque;

/// One line in flight on the trunk: the tagged line (global port id)
/// plus the remaining trunk-cycle countdown. Countdowns are relative
/// ages, never absolute cycle stamps, so trunk state cannot go stale
/// across idle-edge leaps (a leap requires the queue empty anyway).
struct TrunkEntry {
    tl: TaggedLine,
    remaining: u64,
}

pub struct HierReadNetwork {
    geom: Geometry,
    cfg: HierConfig,
    clusters: Vec<MedusaReadNetwork>,
    bypass: Option<MedusaReadNetwork>,
    /// The shared trunk bus: strict FIFO (preserves per-port order),
    /// at most one delivery per trunk edge.
    trunk: VecDeque<TrunkEntry>,
    /// Trunk lines in flight per clustered global port — cluster buffer
    /// slots reserved at trunk entry, so the trunk head can always sink.
    in_flight: Vec<usize>,
    /// Memory-interface guard: one line per fabric cycle across all
    /// ports (same contract every flat network asserts).
    delivered_this_cycle: bool,
    /// Bypassed deliveries since the last tick (`mem_deliver` has no
    /// stats handle; flushed into the counter at the next tick).
    pending_bypassed: u64,
}

impl HierReadNetwork {
    pub fn new(geom: Geometry, cfg: HierConfig) -> Self {
        geom.validate().expect("invalid geometry");
        cfg.validate(&geom).expect("invalid hierarchical config");
        let sub = cfg.sub_geom(&geom, cfg.cluster_ports);
        HierReadNetwork {
            clusters: (0..cfg.clusters(geom.read_ports))
                .map(|_| MedusaReadNetwork::new(sub))
                .collect(),
            bypass: (cfg.bypass_ports > 0)
                .then(|| MedusaReadNetwork::new(cfg.sub_geom(&geom, cfg.bypass_ports))),
            trunk: VecDeque::new(),
            in_flight: vec![0; geom.read_ports],
            delivered_this_cycle: false,
            pending_bypassed: 0,
            geom,
            cfg,
        }
    }

    fn route(&self, port: PortId) -> Route {
        self.cfg.route(port, self.geom.read_ports)
    }
}

impl ReadNetwork for HierReadNetwork {
    fn design(&self) -> Design {
        Design::Hierarchical(self.cfg)
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn mem_can_deliver(&self, port: PortId) -> bool {
        if self.delivered_this_cycle {
            return false;
        }
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_ref().unwrap().mem_can_deliver(l),
            // Clustered: the line enters the trunk; it may only do so if
            // the destination cluster has an unreserved buffer slot.
            Route::Cluster(c, l) => {
                self.clusters[c].port_free_lines(l) > self.in_flight[port]
            }
        }
    }

    fn mem_deliver(&mut self, tl: TaggedLine) {
        assert!(!self.delivered_this_cycle, "second line on the memory interface in one cycle");
        self.delivered_this_cycle = true;
        let port = tl.port;
        match self.route(port) {
            Route::Bypass(l) => {
                self.pending_bypassed += 1;
                self.bypass.as_mut().unwrap().mem_deliver(TaggedLine { port: l, line: tl.line });
            }
            Route::Cluster(c, l) => {
                assert!(
                    self.clusters[c].port_free_lines(l) > self.in_flight[port],
                    "trunk entry without a reserved cluster slot, port {port}"
                );
                self.in_flight[port] += 1;
                self.trunk.push_back(TrunkEntry { tl, remaining: self.cfg.trunk_crossing() });
            }
        }
    }

    fn port_free_lines(&self, port: PortId) -> usize {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_ref().unwrap().port_free_lines(l),
            // The arbiter's credit view must subtract slots reserved by
            // lines still on the trunk.
            Route::Cluster(c, l) => {
                self.clusters[c].port_free_lines(l).saturating_sub(self.in_flight[port])
            }
        }
    }

    fn port_word_available(&self, port: PortId) -> bool {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_ref().unwrap().port_word_available(l),
            Route::Cluster(c, l) => self.clusters[c].port_word_available(l),
        }
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        match self.route(port) {
            Route::Bypass(l) => self.bypass.as_mut().unwrap().port_take_word(l),
            Route::Cluster(c, l) => self.clusters[c].port_take_word(l),
        }
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        if self.pending_bypassed > 0 {
            stats.add(Counter::HierReadLinesBypassed, self.pending_bypassed);
            self.pending_bypassed = 0;
        }
        self.delivered_this_cycle = false;
        for cl in &mut self.clusters {
            cl.tick(cycle, stats);
        }
        if let Some(b) = &mut self.bypass {
            b.tick(cycle, stats);
        }
    }

    /// One trunk-clock edge: every in-flight line advances one pipeline
    /// stage; the (single, shared) bus then delivers at most one
    /// fully-crossed line into its destination cluster. The cluster's
    /// own one-delivery-per-fabric-cycle guard serializes a fast trunk
    /// against a slow fabric; a blocked head simply waits (its slot is
    /// reserved, so it sinks at the next fabric tick — no deadlock).
    fn trunk_tick(&mut self, stats: &mut Stats) {
        for e in &mut self.trunk {
            if e.remaining > 0 {
                e.remaining -= 1;
            }
        }
        let ready = match self.trunk.front() {
            Some(head) if head.remaining == 0 => Some(head.tl.port),
            _ => None,
        };
        if let Some(port) = ready {
            let Route::Cluster(c, l) = self.route(port) else {
                unreachable!("bypass line on the trunk")
            };
            if self.clusters[c].mem_can_deliver(l) {
                let e = self.trunk.pop_front().unwrap();
                self.clusters[c].mem_deliver(TaggedLine { port: l, line: e.tl.line });
                self.in_flight[port] -= 1;
                stats.bump(Counter::HierReadLinesOverTrunk);
            }
        }
    }

    fn nominal_latency(&self) -> usize {
        // Cluster transposer latency plus the trunk crossing (the
        // bypass path is strictly faster; latency bounds are quoted for
        // the slow path).
        self.clusters[0].nominal_latency() + self.cfg.levels
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert!(self.trunk.is_empty(), "payload mode change with lines on the trunk");
        for cl in &mut self.clusters {
            cl.set_payload_mode(mode);
        }
        if let Some(b) = &mut self.bypass {
            b.set_payload_mode(mode);
        }
    }

    fn is_leap_idle(&self) -> bool {
        self.trunk.is_empty()
            && self.pending_bypassed == 0
            && self.clusters.iter().all(|c| c.is_leap_idle())
            && self.bypass.as_ref().map_or(true, |b| b.is_leap_idle())
    }

    fn trunk_occupancy(&self) -> usize {
        self.trunk.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Line;

    fn geom(n_ports: usize, w_line: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst: 4 }
    }

    fn mk_line(port: usize, tag: u64, n: usize) -> Line {
        Line::from_words(
            (0..n as u64)
                .map(|y| (((port as u64) & 0x1f) << 11) | ((tag & 0x1f) << 6) | y)
                .collect(),
        )
    }

    /// Drive the network with a 1:1 trunk:fabric cadence: deliver
    /// `lines[i]` when possible, pop words eagerly, return per-port
    /// word streams.
    fn run(net: &mut HierReadNetwork, lines: Vec<TaggedLine>, max_cycles: u64) -> Vec<Vec<Word>> {
        let mut stats = Stats::new();
        let nports = net.geometry().read_ports;
        let total_words = lines.len() * net.geometry().words_per_line();
        let mut got: Vec<Vec<Word>> = vec![Vec::new(); nports];
        let mut next = 0usize;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            net.trunk_tick(&mut stats);
            if next < lines.len() && net.mem_can_deliver(lines[next].port) {
                net.mem_deliver(lines[next].clone());
                next += 1;
            }
            for p in 0..nports {
                if net.port_word_available(p) {
                    got[p].push(net.port_take_word(p).unwrap());
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == total_words {
                break;
            }
        }
        got
    }

    #[test]
    fn all_ports_receive_their_lines_in_order() {
        // 8 ports, 2 clusters of 3 + 2 bypass: every port gets its own
        // lines' words, in order, through whichever path it maps to.
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 3, bypass_ports: 2, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let lines: Vec<TaggedLine> =
            (0..24).map(|i| TaggedLine { port: i % 8, line: mk_line(i % 8, i as u64, n) }).collect();
        let got = run(&mut net, lines, 2000);
        for p in 0..8 {
            let mut expect = Vec::new();
            for i in 0..24 {
                if i % 8 == p {
                    expect.extend(mk_line(p, i as u64, n).words().to_vec());
                }
            }
            assert_eq!(got[p], expect, "port {p}");
        }
        assert!(net.is_leap_idle());
    }

    #[test]
    fn bypass_skips_the_trunk_crossing() {
        // Same line delivered to a clustered port and to a bypass port:
        // the bypass word arrives at least the trunk crossing earlier.
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { levels: 4, cluster_ports: 3, bypass_ports: 2, ..Default::default() };
        let first_word_at = |port: usize| -> u64 {
            let mut net = HierReadNetwork::new(g, cfg);
            let mut stats = Stats::new();
            net.tick(0, &mut stats);
            net.mem_deliver(TaggedLine { port, line: mk_line(port, 0, n) });
            for c in 1..200u64 {
                net.tick(c, &mut stats);
                net.trunk_tick(&mut stats);
                if net.port_word_available(port) {
                    return c;
                }
            }
            panic!("word never arrived on port {port}");
        };
        let clustered = first_word_at(0);
        let bypass = first_word_at(6);
        assert!(
            clustered >= bypass + cfg.trunk_crossing(),
            "clustered latency {clustered} must trail bypass latency {bypass} \
             by the trunk crossing ({})",
            cfg.trunk_crossing()
        );
    }

    #[test]
    fn trunk_delivers_at_most_one_line_per_trunk_edge() {
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 4, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, 0, n) });
        net.tick(1, &mut stats);
        net.mem_deliver(TaggedLine { port: 4, line: mk_line(4, 1, n) });
        assert_eq!(net.trunk.len(), 2);
        // Both have crossed after this edge, but only one may deliver.
        net.trunk_tick(&mut stats);
        assert_eq!(net.trunk.len(), 1, "one delivery per trunk edge");
        assert_eq!(stats.count(Counter::HierReadLinesOverTrunk), 1);
        net.tick(2, &mut stats);
        net.trunk_tick(&mut stats);
        assert_eq!(net.trunk.len(), 0);
        assert_eq!(stats.count(Counter::HierReadLinesOverTrunk), 2);
    }

    #[test]
    fn fast_trunk_respects_the_cluster_delivery_guard() {
        // Two lines for the same cluster, trunk ticking many times per
        // fabric tick: the cluster's one-delivery-per-fabric-cycle
        // contract must hold (the second line waits for the next tick).
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 4, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, 0, n) });
        net.tick(1, &mut stats);
        net.mem_deliver(TaggedLine { port: 1, line: mk_line(1, 1, n) });
        for _ in 0..5 {
            net.trunk_tick(&mut stats);
        }
        assert_eq!(net.trunk.len(), 1, "second line must wait for the next fabric cycle");
        net.tick(2, &mut stats);
        net.trunk_tick(&mut stats);
        assert!(net.trunk.is_empty());
    }

    #[test]
    fn credit_view_subtracts_trunk_occupancy() {
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 4, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        assert_eq!(net.port_free_lines(0), 4);
        net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, 0, n) });
        // The line is on the trunk, not yet in the cluster — the credit
        // view must already account for it.
        assert_eq!(net.port_free_lines(0), 3);
        // Fill the remaining credits without any trunk progress.
        for i in 1..4u64 {
            net.tick(i, &mut stats);
            assert!(net.mem_can_deliver(0));
            net.mem_deliver(TaggedLine { port: 0, line: mk_line(0, i, n) });
        }
        net.tick(4, &mut stats);
        assert!(!net.mem_can_deliver(0), "all four slots reserved by trunk lines");
        assert_eq!(net.port_free_lines(0), 0);
    }

    #[test]
    fn bypassed_lines_count_and_flush_on_tick() {
        let g = geom(8, 128);
        let n = g.words_per_line();
        let cfg = HierConfig { cluster_ports: 3, bypass_ports: 2, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 7, line: mk_line(7, 0, n) });
        assert!(!net.is_leap_idle(), "pending counter flush must block leaping");
        assert_eq!(stats.count(Counter::HierReadLinesBypassed), 0);
        net.tick(1, &mut stats);
        assert_eq!(stats.count(Counter::HierReadLinesBypassed), 1);
        assert_eq!(stats.count(Counter::HierReadLinesOverTrunk), 0);
    }

    #[test]
    fn idle_tick_and_trunk_tick_are_no_ops() {
        let g = geom(8, 128);
        let cfg = HierConfig { cluster_ports: 4, ..Default::default() };
        let mut net = HierReadNetwork::new(g, cfg);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        assert!(net.is_leap_idle());
        let before: Vec<(&str, u64)> = stats.counters().collect();
        net.tick(1, &mut stats);
        net.trunk_tick(&mut stats);
        let after: Vec<(&str, u64)> = stats.counters().collect();
        assert_eq!(before, after, "idle edges must not move a counter");
        assert!(net.is_leap_idle());
    }
}
