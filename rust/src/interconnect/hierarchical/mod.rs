//! The hierarchical (clustered) interconnect family.
//!
//! The paper's scalability analysis (§III-D) prices a flat Medusa
//! rotator at `W_line x log2(N)` mux2 — fine at N = 32, but the control
//! fan-out and the all-ports wiring funnel become the placement
//! bottleneck well before the LUT count does (the §IV congestion model
//! captures exactly this). The classical fix is the one every NoC ends
//! up with: **cluster the ports**. Each cluster of `cluster_ports`
//! ports gets its own small Medusa transposer; clusters meet on a
//! shared **trunk bus** that runs in its own clock domain, pipelined
//! `levels - 1` deep. Latency-critical tenants can be pinned to
//! **bypass ports** that sit directly on the memory interface (a
//! dedicated small transposer, no trunk crossing).
//!
//! ```text
//!   DRAM ──┬── trunk (own clock, levels-1 pipeline) ──┬─ cluster 0 ─ ports
//!          │                                          ├─ cluster 1 ─ ports
//!          │                                          └─ ...
//!          └── bypass transposer ── trunk-direct tenant ports
//! ```
//!
//! ## Semantics
//!
//! * **Port mapping.** The *last* `bypass_ports` global ports are the
//!   bypass group; the remaining ports are clustered contiguously:
//!   global port `p` lives in cluster `p / cluster_ports`, local index
//!   `p % cluster_ports`.
//! * **Read path.** The memory controller delivers into the trunk
//!   (bypass ports: straight into the bypass transposer). A line
//!   crosses the trunk in `levels - 1` trunk-clock cycles, modelled as
//!   a per-entry countdown — relative ages, never absolute cycle
//!   stamps, so trunk state cannot go stale across idle-edge leaps.
//!   The trunk delivers at most one line per trunk edge (it is a
//!   single shared bus), in strict FIFO order (which preserves
//!   per-port order). Credit accounting reserves cluster buffer space
//!   at trunk entry, so the trunk head can always sink — no deadlock.
//! * **Write path.** Clusters assemble lines locally; the trunk picks
//!   at most one completed line per cluster per fabric cycle
//!   (round-robin over the cluster's local ports), carries it
//!   `levels - 1` trunk cycles, and stages it at the memory interface
//!   where the arbiter sees it via `mem_lines_ready`. The staging
//!   buffer is bounded by the same `max_burst` credit the cluster
//!   itself enforces.
//! * **Leap exactness.** Every queue reports through `is_leap_idle`;
//!   a leap only fires when the trunk is empty, at which point
//!   `trunk_tick` is a pure no-op and bulk-adding the skipped trunk
//!   edges reproduces the stepwise counters bit-exactly (DESIGN.md
//!   §10).

mod read;
mod write;

pub use read::HierReadNetwork;
pub use write::HierWriteNetwork;

use crate::types::Geometry;
use anyhow::{ensure, Result};

/// Parameters of one member of the hierarchical family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HierConfig {
    /// Hierarchy depth: 2 = clusters directly on the trunk (one
    /// pipeline stage), 3–4 add trunk pipeline stages. A line crosses
    /// the trunk in `levels - 1` trunk-clock cycles.
    pub levels: usize,
    /// Ports per cluster (each cluster is a small Medusa transposer).
    pub cluster_ports: usize,
    /// Trunk-direct ports (the *last* `bypass_ports` global ports):
    /// they get a dedicated transposer on the memory interface and
    /// never cross the trunk.
    pub bypass_ports: usize,
    /// Trunk clock in MHz — its own scheduler domain, independent of
    /// both the fabric and the memory clocks.
    pub trunk_mhz: u32,
}

impl Default for HierConfig {
    fn default() -> Self {
        HierConfig { levels: 2, cluster_ports: 4, bypass_ports: 0, trunk_mhz: 300 }
    }
}

impl HierConfig {
    /// Trunk-crossing latency in trunk-clock cycles.
    pub fn trunk_crossing(&self) -> u64 {
        (self.levels - 1) as u64
    }

    /// Number of clusters on the given side (`ports` = read or write
    /// port count). Callers must have validated first.
    pub fn clusters(&self, ports: usize) -> usize {
        (ports - self.bypass_ports) / self.cluster_ports
    }

    /// The sub-geometry one transposer (cluster or bypass group)
    /// instantiates on: same widths and burst, `ports` ports a side.
    pub fn sub_geom(&self, geom: &Geometry, ports: usize) -> Geometry {
        Geometry { read_ports: ports, write_ports: ports, ..*geom }
    }

    /// Validate against the geometry this config will instantiate on.
    pub fn validate(&self, geom: &Geometry) -> Result<()> {
        ensure!(
            (2..=4).contains(&self.levels),
            "levels {} out of range [2, 4]",
            self.levels
        );
        ensure!(self.cluster_ports >= 1, "cluster_ports must be at least 1");
        ensure!(
            (25..=1000).contains(&self.trunk_mhz),
            "trunk clock {} MHz out of range [25, 1000]",
            self.trunk_mhz
        );
        for (side, ports) in [("read", geom.read_ports), ("write", geom.write_ports)] {
            ensure!(
                self.bypass_ports < ports,
                "bypass_ports {} leaves no clustered {side} ports (of {ports})",
                self.bypass_ports
            );
            let clustered = ports - self.bypass_ports;
            ensure!(
                clustered % self.cluster_ports == 0,
                "{clustered} clustered {side} ports do not divide into clusters of {}",
                self.cluster_ports
            );
            ensure!(
                clustered / self.cluster_ports >= 2,
                "a single {side} cluster is just medusa behind a pointless trunk \
                 (need at least 2 clusters, got {clustered} ports / {} per cluster)",
                self.cluster_ports
            );
            self.sub_geom(geom, self.cluster_ports)
                .validate()
                .map_err(|e| e.context(format!("{side} cluster sub-geometry")))?;
        }
        if self.bypass_ports > 0 {
            self.sub_geom(geom, self.bypass_ports)
                .validate()
                .map_err(|e| e.context("bypass sub-geometry"))?;
        }
        Ok(())
    }

    /// Canonical spec-string form,
    /// `hierarchical:l<levels>:c<cluster>:b<bypass>:t<trunk_mhz>`.
    /// [`parse_spec`] inverts this exactly (round-trip locked by tests).
    pub fn spec(&self) -> String {
        format!(
            "hierarchical:l{}:c{}:b{}:t{}",
            self.levels, self.cluster_ports, self.bypass_ports, self.trunk_mhz
        )
    }
}

/// Parse a hierarchical spec string: `hierarchical`, `hierarchical:l3`,
/// `hierarchical:l2:c4:b2:t300` (segments optional, any order after the
/// family name; unspecified fields take [`HierConfig::default`] values).
/// Returns `None` for anything that is not a hierarchical spec.
pub fn parse_spec(s: &str) -> Option<HierConfig> {
    let rest = s.strip_prefix("hierarchical")?;
    let mut cfg = HierConfig::default();
    if rest.is_empty() {
        return Some(cfg);
    }
    let rest = rest.strip_prefix(':')?;
    for seg in rest.split(':') {
        let (key, val) = seg.split_at(1.min(seg.len()));
        match key {
            "l" => cfg.levels = val.parse().ok()?,
            "c" => cfg.cluster_ports = val.parse().ok()?,
            "b" => cfg.bypass_ports = val.parse().ok()?,
            "t" => cfg.trunk_mhz = val.parse().ok()?,
            _ => return None,
        }
    }
    Some(cfg)
}

/// Where a global port lives in the hierarchy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub(crate) enum Route {
    /// `(cluster index, local port index)`.
    Cluster(usize, usize),
    /// Local port index within the bypass transposer.
    Bypass(usize),
}

impl HierConfig {
    /// Route a global port (callers must have validated; `ports` is the
    /// side's total port count).
    pub(crate) fn route(&self, port: usize, ports: usize) -> Route {
        let clustered = ports - self.bypass_ports;
        if port >= clustered {
            Route::Bypass(port - clustered)
        } else {
            Route::Cluster(port / self.cluster_ports, port % self.cluster_ports)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(n_ports: usize, w_line: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst: 4 }
    }

    #[test]
    fn spec_round_trips() {
        for cfg in [
            HierConfig::default(),
            HierConfig { levels: 3, cluster_ports: 8, bypass_ports: 4, trunk_mhz: 450 },
            HierConfig { levels: 4, cluster_ports: 2, bypass_ports: 0, trunk_mhz: 25 },
        ] {
            assert_eq!(parse_spec(&cfg.spec()), Some(cfg));
        }
    }

    #[test]
    fn spec_parsing_variants() {
        assert_eq!(parse_spec("hierarchical"), Some(HierConfig::default()));
        assert_eq!(
            parse_spec("hierarchical:l3"),
            Some(HierConfig { levels: 3, ..HierConfig::default() })
        );
        assert_eq!(
            parse_spec("hierarchical:c8:t450"),
            Some(HierConfig { cluster_ports: 8, trunk_mhz: 450, ..HierConfig::default() })
        );
        assert_eq!(parse_spec("hierarchical:x3"), None);
        assert_eq!(parse_spec("hierarchical:"), None);
        assert_eq!(parse_spec("hierarchical:l"), None);
        assert_eq!(parse_spec("hierarchical:l2:"), None);
        assert_eq!(parse_spec("medusa"), None);
        assert_eq!(parse_spec("hierarchicall2"), None);
    }

    #[test]
    fn validation_rules() {
        let g = geom(8, 128); // N = 8
        HierConfig { cluster_ports: 4, ..Default::default() }.validate(&g).unwrap();
        HierConfig { cluster_ports: 2, bypass_ports: 2, ..Default::default() }
            .validate(&g)
            .unwrap();
        // Levels out of range.
        assert!(HierConfig { levels: 1, ..Default::default() }.validate(&g).is_err());
        assert!(HierConfig { levels: 5, ..Default::default() }.validate(&g).is_err());
        // Ports don't divide into clusters.
        assert!(HierConfig { cluster_ports: 3, ..Default::default() }.validate(&g).is_err());
        // A single cluster is not a hierarchy.
        assert!(HierConfig { cluster_ports: 8, ..Default::default() }.validate(&g).is_err());
        // Bypass eating every port.
        assert!(HierConfig { bypass_ports: 8, ..Default::default() }.validate(&g).is_err());
        // Trunk clock out of range.
        assert!(HierConfig { trunk_mhz: 0, ..Default::default() }.validate(&g).is_err());
        assert!(HierConfig { trunk_mhz: 2000, ..Default::default() }.validate(&g).is_err());
    }

    #[test]
    fn routing_maps_clusters_then_bypass() {
        let cfg = HierConfig { cluster_ports: 2, bypass_ports: 2, ..Default::default() };
        let ports = 8;
        assert_eq!(cfg.route(0, ports), Route::Cluster(0, 0));
        assert_eq!(cfg.route(1, ports), Route::Cluster(0, 1));
        assert_eq!(cfg.route(2, ports), Route::Cluster(1, 0));
        assert_eq!(cfg.route(5, ports), Route::Cluster(2, 1));
        assert_eq!(cfg.route(6, ports), Route::Bypass(0));
        assert_eq!(cfg.route(7, ports), Route::Bypass(1));
        assert_eq!(cfg.clusters(ports), 3);
    }

    #[test]
    fn trunk_crossing_tracks_levels() {
        assert_eq!(HierConfig { levels: 2, ..Default::default() }.trunk_crossing(), 1);
        assert_eq!(HierConfig { levels: 4, ..Default::default() }.trunk_crossing(), 3);
    }
}
