//! The hybrid (partial-transpose) interconnect family.
//!
//! The paper compares exactly two points of a design space its own
//! complexity analysis describes as a continuum: the baseline mux-tree
//! datapath costs `W_line x (N-1)` mux2 equivalents (§II-B) while the
//! fully transposed Medusa datapath costs `W_line x log2(N)` (§III-D).
//! This module fills in the family between them with a single dial,
//! [`HybridConfig::transpose_radix`]:
//!
//! * **radix 2** — no shared rotation at all; a radix-2 rotate block *is*
//!   a 2:1 mux, the primitive the baseline's per-port mux trees are built
//!   from, so the family collapses to the exact baseline datapath
//!   (per-port wide FIFOs + width converters). The simulator instantiates
//!   [`crate::interconnect::baseline`] directly, which is what makes the
//!   radix-2 point *bit-identical* to `baseline` in both data and stats.
//! * **radix N** — the generalized schedule below degenerates exactly to
//!   Medusa's full-transpose diagonal schedule (set `r = N` in the
//!   formulas: the chunk index vanishes and `k = (j + c) mod N` remains),
//!   so the simulator instantiates [`crate::interconnect::medusa`]
//!   directly — bit-identical to `medusa`.
//! * **2 < radix < N** — the genuinely new *grouped partial transpose*:
//!   the line's `N` words are viewed as `N/r` chunks of `r` words; a
//!   shared radix-`r` rotator (cost `W_line x log2 r` mux2) rotates
//!   *within* chunks under one global control, while a per-port
//!   `(N/r):1` fine-select mux (cost `W_acc x (N/r - 1)` mux2 per port)
//!   walks the chunks. Storage stays in shared banked SRAM exactly as in
//!   Medusa.
//!
//! ## The generalized diagonal schedule
//!
//! With `r = transpose_radix`, `C = N / r` chunks, on fabric cycle `c`
//! an active read port `j` reads input bank
//!
//! ```text
//! k = m*r + w,   w = ((j mod r) + c) mod r          (shared rotation)
//!                m = ((j div r) + (c div r)) mod C   (per-port fine select)
//! ```
//!
//! and stores the word at line index `k` of its output buffer. Two
//! distinct active ports can never collide on a bank: ports differing
//! mod `r` read different in-chunk offsets `w`; ports equal mod `r`
//! differ in `j div r` and therefore in `m`. Over any `N` *consecutive*
//! active cycles the pair `(m, w)` covers every bank exactly once (the
//! `w` sequence covers all `r` residues per `r`-cycle block while `m`
//! steps through the chunks, the wrapped first/last partial blocks
//! covering complementary offsets of the same chunk), so a line still
//! completes in exactly `N` cycles — the §III-E constant-latency law
//! holds across the whole family — and ports join and leave the schedule
//! independently (§III-F). The write direction runs the inverse schedule,
//! mirroring `medusa::write`.
//!
//! `stage_pipelining` adds pipeline stages to the shared rotator (same
//! semantics as [`MedusaTuning::rotator_stages`]): latency up, achievable
//! frequency up. `port_group_width` is a floorplanning knob consumed by
//! the resource/timing models only — ports in one group share a
//! fine-select decoder — and does not change simulated behaviour.
//!
//! [`MedusaTuning::rotator_stages`]: crate::interconnect::medusa::MedusaTuning

mod read;
mod write;

pub use read::HybridReadNetwork;
pub use write::HybridWriteNetwork;

use crate::types::Geometry;
use anyhow::{ensure, Result};

/// Parameters of one member of the hybrid family.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HybridConfig {
    /// Transpose radix `r`: 2 = baseline endpoint, `N` = Medusa endpoint,
    /// intermediate powers of two = grouped partial transpose. Must be a
    /// power of two with `2 <= r <= N` for the instantiating geometry.
    pub transpose_radix: usize,
    /// Extra pipeline stages in the shared rotator (0 = combinational).
    /// Must be 0 at radix 2 — that endpoint has no shared rotator.
    pub stage_pipelining: usize,
    /// Ports per fine-select control group (resource/timing model knob;
    /// behaviour-neutral). Must be at least 1.
    pub port_group_width: usize,
}

impl Default for HybridConfig {
    fn default() -> Self {
        HybridConfig { transpose_radix: 4, stage_pipelining: 0, port_group_width: 1 }
    }
}

impl HybridConfig {
    /// Validate against the geometry this config will instantiate on.
    pub fn validate(&self, geom: &Geometry) -> Result<()> {
        let n = geom.words_per_line();
        let r = self.transpose_radix;
        ensure!(r.is_power_of_two(), "transpose_radix {r} must be a power of two");
        ensure!((2..=n).contains(&r), "transpose_radix {r} out of range [2, {n}] for W_line/W_acc = {n}");
        ensure!(
            self.transpose_radix != 2 || self.stage_pipelining == 0,
            "radix-2 hybrid has no shared rotator to pipeline (stage_pipelining must be 0)"
        );
        ensure!(self.stage_pipelining <= 16, "stage_pipelining {} is implausibly deep", self.stage_pipelining);
        ensure!(self.port_group_width >= 1, "port_group_width must be at least 1");
        ensure!(
            self.port_group_width <= geom.read_ports.max(geom.write_ports),
            "port_group_width {} exceeds the port count",
            self.port_group_width
        );
        Ok(())
    }

    /// Canonical spec-string form, `hybrid:r<radix>:s<stages>:g<group>`.
    /// [`parse_spec`] inverts this exactly (round-trip locked by tests).
    pub fn spec(&self) -> String {
        format!("hybrid:r{}:s{}:g{}", self.transpose_radix, self.stage_pipelining, self.port_group_width)
    }

    /// Number of fine-select control groups for `ports` ports.
    pub fn select_groups(&self, ports: usize) -> usize {
        ports.div_ceil(self.port_group_width.max(1))
    }
}

/// Parse a hybrid spec string: `hybrid`, `hybrid:r8`, `hybrid:r8:s2`,
/// `hybrid:r8:s2:g4` (segments optional, any order after the family
/// name; unspecified fields take [`HybridConfig::default`] values).
/// Returns `None` for anything that is not a hybrid spec.
pub fn parse_spec(s: &str) -> Option<HybridConfig> {
    let rest = s.strip_prefix("hybrid")?;
    let mut cfg = HybridConfig::default();
    if rest.is_empty() {
        return Some(cfg);
    }
    let rest = rest.strip_prefix(':')?;
    for seg in rest.split(':') {
        let (key, val) = seg.split_at(1.min(seg.len()));
        let val: usize = val.parse().ok()?;
        match key {
            "r" => cfg.transpose_radix = val,
            "s" => cfg.stage_pipelining = val,
            "g" => cfg.port_group_width = val,
            _ => return None,
        }
    }
    Some(cfg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(n_ports: usize, w_line: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst: 4 }
    }

    #[test]
    fn spec_round_trips() {
        for cfg in [
            HybridConfig::default(),
            HybridConfig { transpose_radix: 8, stage_pipelining: 3, port_group_width: 4 },
            HybridConfig { transpose_radix: 2, stage_pipelining: 0, port_group_width: 1 },
        ] {
            assert_eq!(parse_spec(&cfg.spec()), Some(cfg));
        }
    }

    #[test]
    fn spec_parsing_variants() {
        assert_eq!(parse_spec("hybrid"), Some(HybridConfig::default()));
        assert_eq!(
            parse_spec("hybrid:r8"),
            Some(HybridConfig { transpose_radix: 8, ..HybridConfig::default() })
        );
        assert_eq!(
            parse_spec("hybrid:r16:s4"),
            Some(HybridConfig { transpose_radix: 16, stage_pipelining: 4, port_group_width: 1 })
        );
        assert_eq!(parse_spec("hybrid:x3"), None);
        assert_eq!(parse_spec("hybrid:"), None);
        assert_eq!(parse_spec("medusa"), None);
    }

    #[test]
    fn validation_rules() {
        let g = geom(8, 128); // N = 8
        for r in [2usize, 4, 8] {
            HybridConfig { transpose_radix: r, ..Default::default() }.validate(&g).unwrap();
        }
        // Radix above N, not a power of two, or below 2: rejected.
        assert!(HybridConfig { transpose_radix: 16, ..Default::default() }.validate(&g).is_err());
        assert!(HybridConfig { transpose_radix: 3, ..Default::default() }.validate(&g).is_err());
        assert!(HybridConfig { transpose_radix: 1, ..Default::default() }.validate(&g).is_err());
        // Radix 2 cannot pipeline a rotator it does not have.
        assert!(HybridConfig { transpose_radix: 2, stage_pipelining: 1, port_group_width: 1 }
            .validate(&g)
            .is_err());
        // Group width above the port count is meaningless.
        assert!(HybridConfig { transpose_radix: 4, stage_pipelining: 0, port_group_width: 9 }
            .validate(&g)
            .is_err());
    }

    #[test]
    fn select_groups_rounds_up() {
        let c = HybridConfig { transpose_radix: 4, stage_pipelining: 0, port_group_width: 3 };
        assert_eq!(c.select_groups(8), 3);
        assert_eq!(c.select_groups(3), 1);
    }
}
