//! Hybrid memory-read data transfer network (see the module docs in
//! [`super`] for the family and the generalized diagonal schedule).
//!
//! The wrapper selects the datapath by radix: the endpoints instantiate
//! the exact baseline / Medusa networks (which is what makes them
//! bit-identical to those designs in data *and* stats), intermediate
//! radices instantiate [`PartialReadNetwork`] — Medusa's banked-buffer
//! structure driven by the chunked schedule.

use super::HybridConfig;
use crate::config::PayloadMode;
use crate::hw::BankedSram;
use crate::interconnect::baseline::BaselineReadNetwork;
use crate::interconnect::medusa::{MedusaReadNetwork, MedusaTuning};
use crate::interconnect::{Design, ReadNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, PortId, TaggedLine, Word};
use std::collections::VecDeque;

/// Per-port control state of the partial datapath — the same pointer set
/// Medusa's read network keeps (input region head/tail/count, output
/// double-buffer halves).
#[derive(Debug)]
struct PortCtl {
    in_count: usize,
    head: usize,
    tail: usize,
    done_words: usize,
    active: bool,
    fill_half: usize,
    drain_half: usize,
    half_full: [bool; 2],
    drain_idx: usize,
    word_taken_this_cycle: bool,
}

impl PortCtl {
    fn new() -> Self {
        PortCtl {
            in_count: 0,
            head: 0,
            tail: 0,
            done_words: 0,
            active: false,
            fill_half: 0,
            drain_half: 0,
            half_full: [false; 2],
            drain_idx: 0,
            word_taken_this_cycle: false,
        }
    }
}

/// A completed fill waiting for the pipelined rotator to flush.
#[derive(Debug)]
struct PendingHalf {
    port: PortId,
    half: usize,
    ready_cycle: u64,
}

/// The grouped-partial-transpose read datapath (2 < radix < N).
pub(crate) struct PartialReadNetwork {
    geom: Geometry,
    cfg: HybridConfig,
    /// N banks (one per word index), W_acc wide, `ports * max_burst` deep
    /// — identical to Medusa's input buffer.
    input: BankedSram,
    /// One bank per port, 2 * N deep (double buffer).
    output: BankedSram,
    ports: Vec<PortCtl>,
    pending_halves: VecDeque<PendingHalf>,
    delivered_this_cycle: bool,
    /// Fast backend: skip bank payload traffic (see `medusa::read`).
    payload: PayloadMode,
    cycle: u64,
}

impl PartialReadNetwork {
    fn new(geom: Geometry, cfg: HybridConfig) -> Self {
        let n = geom.words_per_line();
        debug_assert!(cfg.transpose_radix > 2 && cfg.transpose_radix < n);
        PartialReadNetwork {
            geom,
            cfg,
            input: BankedSram::new(n, geom.read_ports * geom.max_burst),
            output: BankedSram::new(geom.read_ports, 2 * n),
            ports: (0..geom.read_ports).map(|_| PortCtl::new()).collect(),
            pending_halves: VecDeque::new(),
            delivered_this_cycle: false,
            payload: PayloadMode::Full,
            cycle: 0,
        }
    }

    fn n(&self) -> usize {
        self.geom.words_per_line()
    }

    fn region(&self, port: PortId) -> usize {
        port * self.geom.max_burst
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.delivered_this_cycle = false;
        let elided = self.payload.is_elided();
        if !elided {
            self.input.new_cycle();
            self.output.new_cycle();
        }
        let n = self.n();
        let r = self.cfg.transpose_radix;
        let chunks = n / r;
        // Shared in-chunk rotation amount and the fine-select chunk phase.
        let rot_w = (cycle % r as u64) as usize;
        let rot_m = ((cycle / r as u64) % chunks as u64) as usize;

        while let Some(p) = self.pending_halves.front() {
            if p.ready_cycle <= cycle {
                let p = self.pending_halves.pop_front().unwrap();
                self.ports[p.port].half_full[p.half] = true;
            } else {
                break;
            }
        }

        // Activation: identical policy to Medusa — a port starts on its
        // head line when one is resident and its fill half is free.
        for port in 0..self.geom.read_ports {
            let pending_blocks = self
                .pending_halves
                .iter()
                .any(|ph| ph.port == port && ph.half == self.ports[port].fill_half);
            let ctl = &mut self.ports[port];
            ctl.word_taken_this_cycle = false;
            if !ctl.active && ctl.in_count > 0 && !ctl.half_full[ctl.fill_half] && !pending_blocks
            {
                ctl.active = true;
                ctl.done_words = 0;
            }
        }

        // Chunked diagonal: shared rotation picks the in-chunk offset,
        // the per-port fine mux picks the chunk. Bank-conflict freedom
        // and N-cycle coverage are proved in the module docs; the SRAM
        // models enforce the physical port limits regardless.
        let mut completed = 0u64;
        let mut words_rotated = 0u64;
        for j in 0..self.geom.read_ports {
            if !self.ports[j].active {
                continue;
            }
            let w = ((j % r) + rot_w) % r;
            let m = ((j / r) + rot_m) % chunks;
            let k = m * r + w;
            if !elided {
                let slot = self.region(j) + self.ports[j].head;
                let word = self.input.read(k, slot);
                let ctl = &self.ports[j];
                self.output.write(j, ctl.fill_half * n + k, word);
            }
            let ctl = &mut self.ports[j];
            ctl.done_words += 1;
            words_rotated += 1;
            if ctl.done_words == n {
                ctl.active = false;
                ctl.done_words = 0;
                ctl.head = (ctl.head + 1) % self.geom.max_burst;
                ctl.in_count -= 1;
                if self.cfg.stage_pipelining == 0 {
                    ctl.half_full[ctl.fill_half] = true;
                } else {
                    self.pending_halves.push_back(PendingHalf {
                        port: j,
                        half: ctl.fill_half,
                        ready_cycle: cycle + self.cfg.stage_pipelining as u64,
                    });
                }
                ctl.fill_half = 1 - ctl.fill_half;
                completed += 1;
            }
        }
        stats.add(Counter::HybridReadWordsRotated, words_rotated);
        stats.add(Counter::HybridReadLinesTransposed, completed);
    }

    fn mem_can_deliver(&self, port: PortId) -> bool {
        !self.delivered_this_cycle && self.ports[port].in_count < self.geom.max_burst
    }

    fn mem_deliver(&mut self, tl: TaggedLine) {
        assert!(!self.delivered_this_cycle, "second line on the memory interface in one cycle");
        let n = self.n();
        assert_eq!(tl.line.num_words(), n);
        let p = tl.port;
        assert!(self.ports[p].in_count < self.geom.max_burst, "input region overflow, port {p}");
        self.delivered_this_cycle = true;
        if !self.payload.is_elided() {
            let slot = self.region(p) + self.ports[p].tail;
            for y in 0..n {
                self.input.write(y, slot, tl.line.word(y) & self.geom.word_mask());
            }
        }
        let ctl = &mut self.ports[p];
        ctl.tail = (ctl.tail + 1) % self.geom.max_burst;
        ctl.in_count += 1;
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        let n = self.n();
        let elided = self.payload.is_elided();
        let ctl = &mut self.ports[port];
        assert!(!ctl.word_taken_this_cycle, "port {port} popped twice in one cycle");
        if !ctl.half_full[ctl.drain_half] {
            return None;
        }
        let w = if elided {
            0
        } else {
            let addr = ctl.drain_half * n + ctl.drain_idx;
            self.output.read(port, addr)
        };
        let ctl = &mut self.ports[port];
        ctl.word_taken_this_cycle = true;
        ctl.drain_idx += 1;
        if ctl.drain_idx == n {
            ctl.half_full[ctl.drain_half] = false;
            ctl.drain_half = 1 - ctl.drain_half;
            ctl.drain_idx = 0;
        }
        Some(w)
    }
}

enum ReadInner {
    /// Radix 2 — the exact baseline datapath.
    Baseline(BaselineReadNetwork),
    /// Radix N — the exact Medusa datapath.
    Medusa(MedusaReadNetwork),
    /// 2 < radix < N — grouped partial transpose.
    Partial(PartialReadNetwork),
}

/// A read network of the hybrid family. See the module docs of
/// [`super`]; the endpoints share the baseline/Medusa implementations,
/// which makes their stat- and data-equivalence structural rather than
/// merely tested.
pub struct HybridReadNetwork {
    cfg: HybridConfig,
    inner: ReadInner,
}

impl HybridReadNetwork {
    pub fn new(geom: Geometry, cfg: HybridConfig) -> Self {
        geom.validate().expect("invalid geometry");
        cfg.validate(&geom).expect("invalid hybrid config");
        let n = geom.words_per_line();
        let inner = if cfg.transpose_radix == 2 {
            ReadInner::Baseline(BaselineReadNetwork::new(geom))
        } else if cfg.transpose_radix == n {
            ReadInner::Medusa(MedusaReadNetwork::with_tuning(
                geom,
                MedusaTuning { rotator_stages: cfg.stage_pipelining },
            ))
        } else {
            ReadInner::Partial(PartialReadNetwork::new(geom, cfg))
        };
        HybridReadNetwork { cfg, inner }
    }

    pub fn config(&self) -> HybridConfig {
        self.cfg
    }
}

macro_rules! read_delegate {
    ($self:expr, $net:ident => $body:expr, partial $p:ident => $pbody:expr) => {
        match &$self.inner {
            ReadInner::Baseline($net) => $body,
            ReadInner::Medusa($net) => $body,
            ReadInner::Partial($p) => $pbody,
        }
    };
    (mut $self:expr, $net:ident => $body:expr, partial $p:ident => $pbody:expr) => {
        match &mut $self.inner {
            ReadInner::Baseline($net) => $body,
            ReadInner::Medusa($net) => $body,
            ReadInner::Partial($p) => $pbody,
        }
    };
}

impl ReadNetwork for HybridReadNetwork {
    fn design(&self) -> Design {
        Design::Hybrid(self.cfg)
    }

    fn geometry(&self) -> &Geometry {
        read_delegate!(self, n => n.geometry(), partial p => &p.geom)
    }

    fn mem_can_deliver(&self, port: PortId) -> bool {
        read_delegate!(self, n => n.mem_can_deliver(port), partial p => p.mem_can_deliver(port))
    }

    fn mem_deliver(&mut self, line: TaggedLine) {
        read_delegate!(mut self, n => n.mem_deliver(line), partial p => p.mem_deliver(line))
    }

    fn port_free_lines(&self, port: PortId) -> usize {
        read_delegate!(self, n => n.port_free_lines(port),
            partial p => p.geom.max_burst - p.ports[port].in_count)
    }

    fn port_word_available(&self, port: PortId) -> bool {
        read_delegate!(self, n => n.port_word_available(port), partial p => {
            let c = &p.ports[port];
            !c.word_taken_this_cycle && c.half_full[c.drain_half]
        })
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        read_delegate!(mut self, n => n.port_take_word(port), partial p => p.port_take_word(port))
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        read_delegate!(mut self, n => n.tick(cycle, stats), partial p => p.tick(cycle, stats))
    }

    fn nominal_latency(&self) -> usize {
        read_delegate!(self, n => n.nominal_latency(),
            partial p => p.n() + p.cfg.stage_pipelining + 1)
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        read_delegate!(mut self, n => n.set_payload_mode(mode), partial p => p.payload = mode)
    }

    fn is_leap_idle(&self) -> bool {
        read_delegate!(self, n => n.is_leap_idle(), partial p => {
            p.pending_halves.is_empty()
                && p.ports.iter().all(|c| {
                    c.in_count == 0 && !c.active && !c.half_full[0] && !c.half_full[1]
                })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Line;

    fn geom(n_ports: usize, w_line: usize, max_burst: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst }
    }

    fn cfg(r: usize) -> HybridConfig {
        HybridConfig { transpose_radix: r, ..HybridConfig::default() }
    }

    fn mk_line(port: usize, tag: u64, n: usize) -> Line {
        Line::from_words(
            (0..n as u64)
                .map(|y| (((port as u64) & 0x1f) << 11) | ((tag & 0x1f) << 6) | y)
                .collect(),
        )
    }

    /// Deliver lines when possible, pop eagerly; per-port word streams.
    fn run(net: &mut HybridReadNetwork, lines: Vec<TaggedLine>, max_cycles: u64) -> Vec<Vec<Word>> {
        let mut stats = Stats::new();
        let nports = net.geometry().read_ports;
        let total_words = lines.len() * net.geometry().words_per_line();
        let mut got: Vec<Vec<Word>> = vec![Vec::new(); nports];
        let mut next = 0usize;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            if next < lines.len() && net.mem_can_deliver(lines[next].port) {
                net.mem_deliver(lines[next].clone());
                next += 1;
            }
            for p in 0..nports {
                if net.port_word_available(p) {
                    got[p].push(net.port_take_word(p).unwrap());
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == total_words {
                break;
            }
        }
        got
    }

    #[test]
    fn intermediate_radix_delivers_each_ports_lines_in_order() {
        // N = 16, radix 4: 4 chunks of 4 — a genuinely partial transpose.
        let g = geom(16, 256, 4);
        let n = g.words_per_line();
        let mut net = HybridReadNetwork::new(g, cfg(4));
        let lines: Vec<TaggedLine> =
            (0..32).map(|i| TaggedLine { port: i % 16, line: mk_line(i % 16, i as u64, n) }).collect();
        let got = run(&mut net, lines, 2000);
        for p in 0..16 {
            let mut expect = Vec::new();
            for i in 0..32 {
                if i % 16 == p {
                    expect.extend(mk_line(p, i as u64, n).words().to_vec());
                }
            }
            assert_eq!(got[p], expect, "port {p}");
        }
    }

    #[test]
    fn all_valid_radices_move_identical_data() {
        // The whole family is behaviourally transparent: only timing may
        // differ between radices, never data.
        let g = geom(8, 128, 4);
        let n = g.words_per_line();
        let lines: Vec<TaggedLine> =
            (0..24).map(|i| TaggedLine { port: i % 8, line: mk_line(i % 8, i as u64, n) }).collect();
        let golden = run(&mut HybridReadNetwork::new(g, cfg(8)), lines.clone(), 2000);
        for r in [2usize, 4] {
            let got = run(&mut HybridReadNetwork::new(g, cfg(r)), lines.clone(), 2000);
            assert_eq!(got, golden, "radix {r}");
        }
    }

    #[test]
    fn full_bandwidth_at_intermediate_radix() {
        // All ports busy: one line absorbed and one word per port
        // delivered per cycle, sustained — the partial transpose keeps
        // Medusa's full-bandwidth property.
        let g = geom(8, 128, 4);
        let n = g.words_per_line();
        let mut net = HybridReadNetwork::new(g, cfg(4));
        let total = 64usize;
        let lines: Vec<TaggedLine> =
            (0..total).map(|i| TaggedLine { port: i % 8, line: mk_line(i % 8, i as u64, n) }).collect();
        let mut stats = Stats::new();
        let mut next = 0usize;
        let mut popped = 0usize;
        let mut done_at = 0u64;
        for c in 0..4000u64 {
            net.tick(c, &mut stats);
            if next < lines.len() && net.mem_can_deliver(lines[next].port) {
                net.mem_deliver(lines[next].clone());
                next += 1;
            }
            for p in 0..8 {
                if net.port_word_available(p) {
                    net.port_take_word(p).unwrap();
                    popped += 1;
                }
            }
            if popped == total * n {
                done_at = c;
                break;
            }
        }
        assert_eq!(popped, total * n, "did not drain");
        assert!(done_at <= total as u64 + 3 * n as u64, "took {done_at} cycles");
        assert!(stats.get("hybrid_read.lines_transposed") == total as u64);
    }

    #[test]
    fn ports_join_at_arbitrary_phases() {
        // The wrapped chunk walk must cover all N banks from any start
        // cycle; start one port at every phase offset mod N.
        let g = geom(8, 128, 4);
        let n = g.words_per_line();
        for warm in 0..n as u64 {
            let mut net = HybridReadNetwork::new(g, cfg(4));
            let mut stats = Stats::new();
            for c in 0..warm {
                net.tick(c, &mut stats);
            }
            net.mem_deliver(TaggedLine { port: 3, line: mk_line(3, 1, n) });
            let mut got = Vec::new();
            for c in warm..warm + 60 {
                net.tick(c, &mut stats);
                if net.port_word_available(3) {
                    got.push(net.port_take_word(3).unwrap());
                }
                if got.len() == n {
                    break;
                }
            }
            assert_eq!(got, mk_line(3, 1, n).words().to_vec(), "warm-up {warm}");
        }
    }

    #[test]
    fn stage_pipelining_adds_latency_not_data_change() {
        let g = geom(8, 128, 4);
        let n = g.words_per_line();
        let lines: Vec<TaggedLine> =
            (0..16).map(|i| TaggedLine { port: i % 8, line: mk_line(i % 8, i as u64, n) }).collect();
        let plain = HybridReadNetwork::new(g, cfg(4));
        let piped = HybridReadNetwork::new(
            g,
            HybridConfig { transpose_radix: 4, stage_pipelining: 2, port_group_width: 1 },
        );
        assert_eq!(piped.nominal_latency(), plain.nominal_latency() + 2);
        let mut plain = plain;
        let mut piped = piped;
        let a = run(&mut plain, lines.clone(), 2000);
        let b = run(&mut piped, lines, 2000);
        assert_eq!(a, b);
    }

    #[test]
    fn irregular_port_count_intermediate_radix() {
        // 6 ports on a 16-word interface, radix 4: ports 4 and 5 share
        // residues with ports 0 and 1 but sit in a different chunk row.
        let g = geom(6, 256, 4);
        let n = g.words_per_line();
        let mut net = HybridReadNetwork::new(g, cfg(4));
        let lines: Vec<TaggedLine> =
            (0..18).map(|i| TaggedLine { port: i % 6, line: mk_line(i % 6, i as u64, n) }).collect();
        let got = run(&mut net, lines, 4000);
        for p in 0..6 {
            let mut expect = Vec::new();
            for i in 0..18 {
                if i % 6 == p {
                    expect.extend(mk_line(p, i as u64, n).words().to_vec());
                }
            }
            assert_eq!(got[p], expect, "port {p}");
        }
    }

    #[test]
    fn endpoint_radices_instantiate_endpoint_datapaths() {
        let g = geom(4, 64, 4);
        let r2 = HybridReadNetwork::new(g, cfg(2));
        assert!(matches!(r2.inner, ReadInner::Baseline(_)));
        let rn = HybridReadNetwork::new(g, cfg(4));
        assert!(matches!(rn.inner, ReadInner::Medusa(_)));
        assert_eq!(r2.design(), Design::Hybrid(cfg(2)));
    }
}
