//! Hybrid memory-write data transfer network — the mirror image of
//! [`super::read`], running the inverse of the generalized diagonal
//! schedule (see the module docs in [`super`]). As on the read side, the
//! radix endpoints instantiate the exact baseline / Medusa write
//! datapaths; intermediate radices run the grouped partial transpose
//! over Medusa's banked-buffer structure.

use super::HybridConfig;
use crate::config::PayloadMode;
use crate::hw::BankedSram;
use crate::interconnect::baseline::BaselineWriteNetwork;
use crate::interconnect::medusa::{MedusaTuning, MedusaWriteNetwork};
use crate::interconnect::{Design, WriteNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, Word};
use std::collections::VecDeque;

/// Per-port control — the same pointer set Medusa's write network keeps.
#[derive(Debug)]
struct PortCtl {
    fill_half: usize,
    fill_idx: usize,
    half_full: [bool; 2],
    drain_half: usize,
    active: bool,
    done_words: usize,
    out_tail: usize,
    out_head: usize,
    ready: usize,
    out_count: usize,
    word_pushed_this_cycle: bool,
}

impl PortCtl {
    fn new() -> Self {
        PortCtl {
            fill_half: 0,
            fill_idx: 0,
            half_full: [false; 2],
            drain_half: 0,
            active: false,
            done_words: 0,
            out_tail: 0,
            out_head: 0,
            ready: 0,
            out_count: 0,
            word_pushed_this_cycle: false,
        }
    }
}

#[derive(Debug)]
struct PendingReady {
    port: PortId,
    ready_cycle: u64,
}

/// The grouped-partial-transpose write datapath (2 < radix < N).
pub(crate) struct PartialWriteNetwork {
    geom: Geometry,
    cfg: HybridConfig,
    /// One bank per port, 2 * N deep (input double buffer).
    input: BankedSram,
    /// N banks (one per word index), `ports * max_burst` deep.
    output: BankedSram,
    ports: Vec<PortCtl>,
    pending_ready: VecDeque<PendingReady>,
    line_taken_this_cycle: bool,
    /// Fast backend: skip bank payload traffic (see `medusa::write`).
    payload: PayloadMode,
    cycle: u64,
}

impl PartialWriteNetwork {
    fn new(geom: Geometry, cfg: HybridConfig) -> Self {
        let n = geom.words_per_line();
        debug_assert!(cfg.transpose_radix > 2 && cfg.transpose_radix < n);
        PartialWriteNetwork {
            geom,
            cfg,
            input: BankedSram::new(geom.write_ports, 2 * n),
            output: BankedSram::new(n, geom.write_ports * geom.max_burst),
            ports: (0..geom.write_ports).map(|_| PortCtl::new()).collect(),
            pending_ready: VecDeque::new(),
            line_taken_this_cycle: false,
            payload: PayloadMode::Full,
            cycle: 0,
        }
    }

    fn n(&self) -> usize {
        self.geom.words_per_line()
    }

    fn region(&self, port: PortId) -> usize {
        port * self.geom.max_burst
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.line_taken_this_cycle = false;
        let elided = self.payload.is_elided();
        if !elided {
            self.input.new_cycle();
            self.output.new_cycle();
        }
        let n = self.n();
        let r = self.cfg.transpose_radix;
        let chunks = n / r;
        let rot_w = (cycle % r as u64) as usize;
        let rot_m = ((cycle / r as u64) % chunks as u64) as usize;

        while let Some(p) = self.pending_ready.front() {
            if p.ready_cycle <= cycle {
                let p = self.pending_ready.pop_front().unwrap();
                self.ports[p.port].ready += 1;
            } else {
                break;
            }
        }

        for port in 0..self.geom.write_ports {
            let ctl = &mut self.ports[port];
            ctl.word_pushed_this_cycle = false;
            if !ctl.active && ctl.half_full[ctl.drain_half] && ctl.out_count < self.geom.max_burst
            {
                ctl.active = true;
                ctl.done_words = 0;
                ctl.out_count += 1; // reserve the slot at out_tail
            }
        }

        // Inverse chunked diagonal: active port p reads word index j of
        // its own input half (j = chunk-select * r + shared offset) and
        // stores it to output bank j at the port's reserved line slot.
        // Distinct active ports land in distinct output banks by the
        // same residue/chunk argument as the read direction.
        let mut completed = 0u64;
        let mut words_rotated = 0u64;
        for p in 0..self.geom.write_ports {
            if !self.ports[p].active {
                continue;
            }
            let w = ((p % r) + rot_w) % r;
            let m = ((p / r) + rot_m) % chunks;
            let j = m * r + w;
            if !elided {
                let addr = self.ports[p].drain_half * n + j;
                let word = self.input.read(p, addr);
                let slot = self.region(p) + self.ports[p].out_tail;
                self.output.write(j, slot, word);
            }
            let ctl = &mut self.ports[p];
            ctl.done_words += 1;
            words_rotated += 1;
            if ctl.done_words == n {
                ctl.active = false;
                ctl.done_words = 0;
                ctl.half_full[ctl.drain_half] = false;
                ctl.drain_half = 1 - ctl.drain_half;
                ctl.out_tail = (ctl.out_tail + 1) % self.geom.max_burst;
                if self.cfg.stage_pipelining == 0 {
                    ctl.ready += 1;
                } else {
                    self.pending_ready.push_back(PendingReady {
                        port: p,
                        ready_cycle: cycle + self.cfg.stage_pipelining as u64,
                    });
                }
                completed += 1;
            }
        }
        stats.add(Counter::HybridWriteWordsRotated, words_rotated);
        stats.add(Counter::HybridWriteLinesTransposed, completed);
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        let n = self.n();
        let mask = self.geom.word_mask();
        let elided = self.payload.is_elided();
        let ctl = &mut self.ports[port];
        assert!(!ctl.word_pushed_this_cycle, "port {port} pushed twice in one cycle");
        assert!(!ctl.half_full[ctl.fill_half], "input half overflow, port {port}");
        let addr = ctl.fill_half * n + ctl.fill_idx;
        ctl.word_pushed_this_cycle = true;
        ctl.fill_idx += 1;
        let fill_half = ctl.fill_half;
        if ctl.fill_idx == n {
            ctl.half_full[fill_half] = true;
            ctl.fill_half = 1 - fill_half;
            ctl.fill_idx = 0;
        }
        if !elided {
            self.input.write(port, addr, w & mask);
        }
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        assert!(!self.line_taken_this_cycle, "second line on the memory interface in one cycle");
        let n = self.n();
        if self.ports[port].ready == 0 {
            return None;
        }
        let line = if self.payload.is_elided() {
            Line::elided(n)
        } else {
            let slot = self.region(port) + self.ports[port].out_head;
            let output = &mut self.output;
            Line::from_fn(n, |y| output.read(y, slot))
        };
        let ctl = &mut self.ports[port];
        ctl.out_head = (ctl.out_head + 1) % self.geom.max_burst;
        ctl.ready -= 1;
        ctl.out_count -= 1;
        self.line_taken_this_cycle = true;
        Some(line)
    }
}

enum WriteInner {
    Baseline(BaselineWriteNetwork),
    Medusa(MedusaWriteNetwork),
    Partial(PartialWriteNetwork),
}

/// A write network of the hybrid family (see [`HybridReadNetwork`] and
/// the module docs of [`super`] for the endpoint-sharing structure).
///
/// [`HybridReadNetwork`]: super::HybridReadNetwork
pub struct HybridWriteNetwork {
    cfg: HybridConfig,
    inner: WriteInner,
}

impl HybridWriteNetwork {
    pub fn new(geom: Geometry, cfg: HybridConfig) -> Self {
        geom.validate().expect("invalid geometry");
        cfg.validate(&geom).expect("invalid hybrid config");
        let n = geom.words_per_line();
        let inner = if cfg.transpose_radix == 2 {
            WriteInner::Baseline(BaselineWriteNetwork::new(geom))
        } else if cfg.transpose_radix == n {
            WriteInner::Medusa(MedusaWriteNetwork::with_tuning(
                geom,
                MedusaTuning { rotator_stages: cfg.stage_pipelining },
            ))
        } else {
            WriteInner::Partial(PartialWriteNetwork::new(geom, cfg))
        };
        HybridWriteNetwork { cfg, inner }
    }

    pub fn config(&self) -> HybridConfig {
        self.cfg
    }
}

macro_rules! write_delegate {
    ($self:expr, $net:ident => $body:expr, partial $p:ident => $pbody:expr) => {
        match &$self.inner {
            WriteInner::Baseline($net) => $body,
            WriteInner::Medusa($net) => $body,
            WriteInner::Partial($p) => $pbody,
        }
    };
    (mut $self:expr, $net:ident => $body:expr, partial $p:ident => $pbody:expr) => {
        match &mut $self.inner {
            WriteInner::Baseline($net) => $body,
            WriteInner::Medusa($net) => $body,
            WriteInner::Partial($p) => $pbody,
        }
    };
}

impl WriteNetwork for HybridWriteNetwork {
    fn design(&self) -> Design {
        Design::Hybrid(self.cfg)
    }

    fn geometry(&self) -> &Geometry {
        write_delegate!(self, n => n.geometry(), partial p => &p.geom)
    }

    fn port_can_accept(&self, port: PortId) -> bool {
        write_delegate!(self, n => n.port_can_accept(port), partial p => {
            let c = &p.ports[port];
            !c.word_pushed_this_cycle && !c.half_full[c.fill_half]
        })
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        write_delegate!(mut self, n => n.port_push_word(port, w),
            partial p => p.port_push_word(port, w))
    }

    fn mem_lines_ready(&self, port: PortId) -> usize {
        write_delegate!(self, n => n.mem_lines_ready(port), partial p => p.ports[port].ready)
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        write_delegate!(mut self, n => n.mem_take_line(port), partial p => p.mem_take_line(port))
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        write_delegate!(mut self, n => n.tick(cycle, stats), partial p => p.tick(cycle, stats))
    }

    fn nominal_latency(&self) -> usize {
        write_delegate!(self, n => n.nominal_latency(),
            partial p => p.n() + p.cfg.stage_pipelining + 1)
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        write_delegate!(mut self, n => n.set_payload_mode(mode), partial p => p.payload = mode)
    }

    fn is_leap_idle(&self) -> bool {
        write_delegate!(self, n => n.is_leap_idle(), partial p => {
            p.pending_ready.is_empty()
                && p.ports.iter().all(|c| {
                    !c.active
                        && c.fill_idx == 0
                        && !c.half_full[0]
                        && !c.half_full[1]
                        && c.ready == 0
                        && c.out_count == 0
                })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom(n_ports: usize, w_line: usize, max_burst: usize) -> Geometry {
        Geometry { w_line, w_acc: 16, read_ports: n_ports, write_ports: n_ports, max_burst }
    }

    fn cfg(r: usize) -> HybridConfig {
        HybridConfig { transpose_radix: r, ..HybridConfig::default() }
    }

    fn word_of(port: usize, line: u64, y: usize) -> Word {
        ((port as u64) << 12) | ((line & 0x3f) << 6) | y as u64
    }

    /// Push `lines_per_port` lines on every port, drain round-robin.
    fn run(net: &mut HybridWriteNetwork, lines_per_port: usize, max_cycles: u64) -> Vec<Vec<Line>> {
        let mut stats = Stats::new();
        let g = *net.geometry();
        let n = g.words_per_line();
        let mut pushed = vec![0usize; g.write_ports];
        let mut got: Vec<Vec<Line>> = vec![Vec::new(); g.write_ports];
        let mut rr = 0usize;
        for c in 0..max_cycles {
            net.tick(c, &mut stats);
            for k in 0..g.write_ports {
                let p = (rr + k) % g.write_ports;
                if net.mem_lines_ready(p) > 0 {
                    got[p].push(net.mem_take_line(p).unwrap());
                    rr = p + 1;
                    break;
                }
            }
            for p in 0..g.write_ports {
                if pushed[p] < lines_per_port * n && net.port_can_accept(p) {
                    let line_idx = (pushed[p] / n) as u64;
                    let y = pushed[p] % n;
                    net.port_push_word(p, word_of(p, line_idx, y));
                    pushed[p] += 1;
                }
            }
            if got.iter().map(|v| v.len()).sum::<usize>() == lines_per_port * g.write_ports {
                break;
            }
        }
        got
    }

    #[test]
    fn intermediate_radix_transposes_port_words_into_lines() {
        let g = geom(16, 256, 4);
        let n = g.words_per_line();
        let mut net = HybridWriteNetwork::new(g, cfg(4));
        let got = run(&mut net, 3, 2000);
        for p in 0..16 {
            assert_eq!(got[p].len(), 3, "port {p}");
            for (li, line) in got[p].iter().enumerate() {
                for y in 0..n {
                    assert_eq!(line.word(y), word_of(p, li as u64, y), "port {p} line {li} word {y}");
                }
            }
        }
    }

    #[test]
    fn all_valid_radices_write_identical_lines() {
        let g = geom(8, 128, 4);
        let golden = run(&mut HybridWriteNetwork::new(g, cfg(8)), 4, 4000);
        for r in [2usize, 4] {
            let got = run(&mut HybridWriteNetwork::new(g, cfg(r)), 4, 4000);
            // Compare line *content* per port (arrival interleave across
            // ports differs between datapath timings, content must not).
            for p in 0..8 {
                assert_eq!(got[p], golden[p], "radix {r} port {p}");
            }
        }
    }

    #[test]
    fn irregular_port_count_intermediate_radix() {
        let g = Geometry { w_line: 256, w_acc: 16, read_ports: 6, write_ports: 6, max_burst: 4 };
        let n = g.words_per_line();
        let mut net = HybridWriteNetwork::new(g, cfg(4));
        let got = run(&mut net, 3, 4000);
        for p in 0..6 {
            assert_eq!(got[p].len(), 3);
            for (li, line) in got[p].iter().enumerate() {
                for y in 0..n {
                    assert_eq!(line.word(y), word_of(p, li as u64, y));
                }
            }
        }
    }

    #[test]
    fn aggregate_bandwidth_at_intermediate_radix() {
        // 8 ports x 1 word/cycle = 1 line/cycle aggregate, sustained.
        let g = geom(8, 128, 8);
        let n = g.words_per_line();
        let mut net = HybridWriteNetwork::new(g, cfg(4));
        let lines_per_port = 8usize;
        let mut stats = Stats::new();
        let mut pushed = vec![0usize; 8];
        let mut taken = 0usize;
        let total = lines_per_port * 8;
        let mut rr = 0;
        let mut done_at = 0u64;
        for c in 0..8000u64 {
            net.tick(c, &mut stats);
            for k in 0..8 {
                let p = (rr + k) % 8;
                if net.mem_lines_ready(p) > 0 {
                    net.mem_take_line(p).unwrap();
                    taken += 1;
                    rr = p + 1;
                    break;
                }
            }
            for p in 0..8 {
                if pushed[p] < lines_per_port * n && net.port_can_accept(p) {
                    net.port_push_word(p, word_of(p, (pushed[p] / n) as u64, pushed[p] % n));
                    pushed[p] += 1;
                }
            }
            if taken == total {
                done_at = c;
                break;
            }
        }
        assert_eq!(taken, total);
        assert!(done_at <= (lines_per_port * n) as u64 + 4 * n as u64, "took {done_at} cycles");
    }

    #[test]
    fn pipelined_partial_rotator_same_data() {
        let g = geom(8, 256, 4); // N = 16, radix 4 partial
        let mut plain = HybridWriteNetwork::new(g, cfg(4));
        let got_plain = run(&mut plain, 3, 4000);
        let mut piped = HybridWriteNetwork::new(
            g,
            HybridConfig { transpose_radix: 4, stage_pipelining: 2, port_group_width: 1 },
        );
        let got_piped = run(&mut piped, 3, 4000);
        assert_eq!(got_plain, got_piped);
    }
}
