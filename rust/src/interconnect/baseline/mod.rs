//! The traditional ("baseline") interconnect data-transfer networks the
//! paper compares against (paper §II, Figs 1–2), representative of
//! mainstream mux/demux interconnects (Xilinx AXI Interconnect, Altera
//! Qsys).

mod read;
mod write;

pub use read::BaselineReadNetwork;
pub use write::BaselineWriteNetwork;
