//! Baseline memory-read data transfer network (paper §II-A1, Fig 1).
//!
//! A 1-to-N demux routes each incoming `W_line` line from the memory
//! controller into the destination port's FIFO; each FIFO is `W_line`
//! wide and `MaxBurstLen` deep (large enough to hold the largest burst a
//! port can request, so bursts never back-pressure the controller); each
//! FIFO feeds a data-width converter presenting the narrow `W_acc` port.

use crate::config::PayloadMode;
use crate::hw::{BoundedFifo, Unpacker};
use crate::interconnect::ReadNetwork;
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, TaggedLine, Word};

struct PortLane {
    fifo: BoundedFifo<Line>,
    conv: Unpacker,
    /// Per-cycle guard: at most one word popped per port per cycle.
    word_taken_this_cycle: bool,
}

pub struct BaselineReadNetwork {
    geom: Geometry,
    lanes: Vec<PortLane>,
    /// Per-cycle guard: the memory interface delivers at most one line.
    delivered_this_cycle: bool,
    cycle: u64,
}

impl BaselineReadNetwork {
    pub fn new(geom: Geometry) -> Self {
        geom.validate().expect("invalid geometry");
        let n = geom.words_per_line();
        let lanes = (0..geom.read_ports)
            .map(|_| PortLane {
                fifo: BoundedFifo::new(geom.max_burst),
                conv: Unpacker::new(n),
                word_taken_this_cycle: false,
            })
            .collect();
        BaselineReadNetwork { geom, lanes, delivered_this_cycle: false, cycle: 0 }
    }

    /// Peak FIFO occupancy across ports (provisioning check).
    pub fn max_fifo_high_water(&self) -> usize {
        self.lanes.iter().map(|l| l.fifo.high_water()).max().unwrap_or(0)
    }
}

impl ReadNetwork for BaselineReadNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Baseline
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn mem_can_deliver(&self, port: PortId) -> bool {
        !self.delivered_this_cycle && !self.lanes[port].fifo.is_full()
    }

    fn mem_deliver(&mut self, tl: TaggedLine) {
        assert!(!self.delivered_this_cycle, "second line on the memory interface in one cycle");
        assert_eq!(tl.line.num_words(), self.geom.words_per_line());
        self.delivered_this_cycle = true;
        self.lanes[tl.port].fifo.push(tl.line);
    }

    fn port_free_lines(&self, port: PortId) -> usize {
        // Space for future lines: FIFO free slots. The converter's
        // in-flight line is already drained from the FIFO.
        self.lanes[port].fifo.free()
    }

    fn port_word_available(&self, port: PortId) -> bool {
        let l = &self.lanes[port];
        !l.word_taken_this_cycle && l.conv.has_word()
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        let l = &mut self.lanes[port];
        assert!(!l.word_taken_this_cycle, "port {port} popped twice in one cycle");
        let w = l.conv.take_word()?;
        l.word_taken_this_cycle = true;
        Some(w)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.delivered_this_cycle = false;
        for lane in self.lanes.iter_mut() {
            lane.word_taken_this_cycle = false;
            // FIFO -> converter refill: one line transfer per port per
            // cycle, only when the converter has fully drained.
            if lane.conv.can_load() {
                if let Some(line) = lane.fifo.pop() {
                    lane.conv.load(line);
                    stats.bump(Counter::BaselineReadLinesIntoConverter);
                }
            }
        }
    }

    fn nominal_latency(&self) -> usize {
        // Demux register + FIFO fall-through + converter load.
        2
    }

    fn set_payload_mode(&mut self, _mode: PayloadMode) {
        // Nothing to gate: the controller already delivers elided
        // shadows in elided mode, the per-port FIFOs store whatever
        // line value arrives (a shadow is a header-only value), and the
        // unpacker streams shadow words from it. Occupancy, credits,
        // and stats are line/word-count-driven either way.
    }

    fn is_leap_idle(&self) -> bool {
        self.lanes.iter().all(|l| l.fifo.is_empty() && l.conv.remaining() == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Line;

    fn geom4() -> Geometry {
        Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 }
    }

    fn line_for(port: usize, tag: u64, n: usize) -> Line {
        Line::from_words((0..n as u64).map(|y| (port as u64) << 32 | tag << 8 | y).collect())
    }

    #[test]
    fn single_line_delivered_in_order() {
        let g = geom4();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        let n = g.words_per_line();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 1, line: line_for(1, 0, n) });
        let mut got = Vec::new();
        for c in 1..20 {
            net.tick(c, &mut stats);
            if net.port_word_available(1) {
                got.push(net.port_take_word(1).unwrap());
            }
        }
        assert_eq!(got, line_for(1, 0, n).words().to_vec());
    }

    #[test]
    fn words_only_appear_on_destination_port() {
        let g = geom4();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 2, line: line_for(2, 0, 4) });
        for c in 1..10 {
            net.tick(c, &mut stats);
            for p in [0usize, 1, 3] {
                assert!(!net.port_word_available(p), "port {p} should stay empty");
            }
            while net.port_word_available(2) {
                net.port_take_word(2);
            }
        }
    }

    #[test]
    fn full_bandwidth_one_line_per_cycle() {
        // With all ports draining, the network must accept one line per
        // cycle indefinitely (§II-A1: "the demux can accept a new input
        // from the memory controller on every cycle").
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        let total_lines = 64usize;
        let mut delivered = 0usize;
        let mut popped = vec![0usize; g.read_ports];
        for c in 0..10_000u64 {
            net.tick(c, &mut stats);
            // Round-robin destination: every port gets every 4th line, so
            // each port consumes words at exactly rate 1 (4 ports x 1
            // word/cycle = 1 line/cycle aggregate).
            if delivered < total_lines {
                let port = delivered % g.read_ports;
                if net.mem_can_deliver(port) {
                    net.mem_deliver(TaggedLine { port, line: line_for(port, delivered as u64, n) });
                    delivered += 1;
                }
            }
            for p in 0..g.read_ports {
                if net.port_word_available(p) {
                    net.port_take_word(p).unwrap();
                    popped[p] += 1;
                }
            }
            if popped.iter().sum::<usize>() == total_lines * n {
                // Aggregate throughput check: popping 64 lines x 4 words
                // at 4 words/cycle needs >= 64 cycles; allow small
                // pipeline fill slack.
                assert!(c < (total_lines as u64 + 16), "took too long: {c} cycles");
                return;
            }
        }
        panic!("did not drain: popped {popped:?} delivered {delivered}");
    }

    #[test]
    fn burst_fits_without_backpressure() {
        // A full MaxBurst to one idle port must be absorbed at one line
        // per cycle (FIFO is provisioned for the largest burst, §II-A1).
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        for c in 0..g.max_burst as u64 {
            net.tick(c, &mut stats);
            assert!(net.mem_can_deliver(0), "burst line {c} back-pressured");
            net.mem_deliver(TaggedLine { port: 0, line: line_for(0, c, n) });
        }
        // One line is moved into the converter each refill, so high water
        // stays within the provisioned FIFO depth.
        assert!(net.max_fifo_high_water() <= g.max_burst);
    }

    #[test]
    fn latency_is_small_constant() {
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 0, line: line_for(0, 7, n) });
        let mut first_word_cycle = None;
        for c in 1..10 {
            net.tick(c, &mut stats);
            if net.port_word_available(0) {
                first_word_cycle = Some(c);
                break;
            }
        }
        let lat = first_word_cycle.expect("word never arrived") - 0;
        assert!(lat as usize <= net.nominal_latency(), "latency {lat} > nominal");
    }

    #[test]
    #[should_panic(expected = "second line on the memory interface")]
    fn two_lines_same_cycle_panics() {
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 0, line: line_for(0, 0, n) });
        net.mem_deliver(TaggedLine { port: 1, line: line_for(1, 0, n) });
    }

    #[test]
    fn irregular_port_count_fewer_ports_than_words() {
        // §III-G / §IV-D: e.g. 3 ports on a 64-bit interface (4 words).
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 3, write_ports: 3, max_burst: 2 };
        let n = g.words_per_line();
        let mut net = BaselineReadNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 2, line: line_for(2, 1, n) });
        let mut got = Vec::new();
        for c in 1..20 {
            net.tick(c, &mut stats);
            if net.port_word_available(2) {
                got.push(net.port_take_word(2).unwrap());
            }
        }
        assert_eq!(got.len(), n);
    }
}
