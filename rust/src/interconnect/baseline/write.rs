//! Baseline memory-write data transfer network (paper §II-A2, Fig 2).
//!
//! Each accelerator write port feeds a data-width converter accumulating
//! `W_acc` words into `W_line` lines, which queue in a per-port FIFO
//! (`W_line` wide, `MaxBurstLen` deep). An N-to-1 mux forwards one
//! completed line per cycle to the memory controller — full bursts stream
//! back-to-back at the controller's full bandwidth.

use crate::config::PayloadMode;
use crate::hw::{BoundedFifo, Packer};
use crate::interconnect::WriteNetwork;
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, Word};

struct PortLane {
    conv: Packer,
    fifo: BoundedFifo<Line>,
    word_pushed_this_cycle: bool,
}

pub struct BaselineWriteNetwork {
    geom: Geometry,
    lanes: Vec<PortLane>,
    line_taken_this_cycle: bool,
    cycle: u64,
}

impl BaselineWriteNetwork {
    pub fn new(geom: Geometry) -> Self {
        geom.validate().expect("invalid geometry");
        let n = geom.words_per_line();
        let lanes = (0..geom.write_ports)
            .map(|_| PortLane {
                conv: Packer::new(n),
                fifo: BoundedFifo::new(geom.max_burst),
                word_pushed_this_cycle: false,
            })
            .collect();
        BaselineWriteNetwork { geom, lanes, line_taken_this_cycle: false, cycle: 0 }
    }

    pub fn max_fifo_high_water(&self) -> usize {
        self.lanes.iter().map(|l| l.fifo.high_water()).max().unwrap_or(0)
    }
}

impl WriteNetwork for BaselineWriteNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Baseline
    }

    fn geometry(&self) -> &Geometry {
        &self.geom
    }

    fn port_can_accept(&self, port: PortId) -> bool {
        let l = &self.lanes[port];
        // A port can push unless its converter is stalled behind a full
        // FIFO (which a credit-respecting arbiter prevents).
        !l.word_pushed_this_cycle && l.conv.can_accept()
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        let l = &mut self.lanes[port];
        assert!(!l.word_pushed_this_cycle, "port {port} pushed twice in one cycle");
        l.conv.accept(w & self.geom.word_mask());
        l.word_pushed_this_cycle = true;
    }

    fn mem_lines_ready(&self, port: PortId) -> usize {
        self.lanes[port].fifo.len()
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        assert!(!self.line_taken_this_cycle, "second line on the memory interface in one cycle");
        let line = self.lanes[port].fifo.pop()?;
        self.line_taken_this_cycle = true;
        Some(line)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.line_taken_this_cycle = false;
        for lane in self.lanes.iter_mut() {
            lane.word_pushed_this_cycle = false;
            // Converter -> FIFO: move a completed line if there is room.
            if lane.conv.has_line() && !lane.fifo.is_full() {
                lane.fifo.push(lane.conv.take_line().unwrap());
                stats.bump(Counter::BaselineWriteLinesIntoFifo);
            }
        }
    }

    fn nominal_latency(&self) -> usize {
        // Converter output register + FIFO + mux register.
        2
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        // The packers assemble the lines, so they are the one place the
        // write path touches payload: in elided mode they count words
        // and promote header-only shadows.
        for lane in self.lanes.iter_mut() {
            lane.conv.set_elided(mode.is_elided());
        }
    }

    fn is_leap_idle(&self) -> bool {
        self.lanes
            .iter()
            .all(|l| l.fifo.is_empty() && !l.conv.has_line() && l.conv.pending_words() == 0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn geom4() -> Geometry {
        Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 }
    }

    #[test]
    fn words_assemble_into_lines_in_order() {
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        let words: Vec<Word> = (0..n as u64).map(|x| x + 0x100).collect();
        let mut pushed = 0;
        let mut line = None;
        for c in 0..20 {
            net.tick(c, &mut stats);
            if pushed < n && net.port_can_accept(0) {
                net.port_push_word(0, words[pushed]);
                pushed += 1;
            }
            if net.mem_lines_ready(0) > 0 {
                line = net.mem_take_line(0);
                break;
            }
        }
        assert_eq!(line.expect("no line").words().to_vec(), words);
    }

    #[test]
    fn aggregate_full_bandwidth() {
        // All 4 ports pushing one word/cycle -> one full line completed
        // per cycle in aggregate; the mux must sustain draining them.
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        let lines_per_port = 8usize;
        let mut taken = 0usize;
        let total = lines_per_port * g.write_ports;
        let mut counters = vec![0u64; g.write_ports];
        let mut rr = 0usize;
        for c in 0..10_000u64 {
            net.tick(c, &mut stats);
            for p in 0..g.write_ports {
                if (counters[p] as usize) < lines_per_port * n && net.port_can_accept(p) {
                    net.port_push_word(p, counters[p]);
                    counters[p] += 1;
                }
            }
            // Round-robin drain.
            for k in 0..g.write_ports {
                let p = (rr + k) % g.write_ports;
                if net.mem_lines_ready(p) > 0 {
                    net.mem_take_line(p).unwrap();
                    taken += 1;
                    rr = p + 1;
                    break;
                }
            }
            if taken == total {
                assert!(c < (total * n / g.write_ports) as u64 + 24, "too slow: {c}");
                return;
            }
        }
        panic!("only drained {taken}/{total} lines");
    }

    #[test]
    fn lines_ready_only_after_full_line() {
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        for c in 0..(n as u64 - 1) {
            net.tick(c, &mut stats);
            net.port_push_word(0, c);
            assert_eq!(net.mem_lines_ready(0), 0, "partial line must not be ready");
        }
        net.tick(n as u64 - 1, &mut stats);
        net.port_push_word(0, 99);
        net.tick(n as u64, &mut stats); // converter -> FIFO transfer
        net.tick(n as u64 + 1, &mut stats);
        assert_eq!(net.mem_lines_ready(0), 1);
    }

    #[test]
    fn word_mask_applied() {
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        for c in 0..n as u64 {
            net.tick(c, &mut stats);
            net.port_push_word(0, 0xdead_beef_cafe);
        }
        for c in n as u64..n as u64 + 4 {
            net.tick(c, &mut stats);
        }
        let line = net.mem_take_line(0).unwrap();
        for y in 0..n {
            assert_eq!(line.word(y), 0xcafe, "16-bit port words must be masked");
        }
    }

    #[test]
    #[should_panic(expected = "pushed twice in one cycle")]
    fn double_push_panics() {
        let g = geom4();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        net.tick(0, &mut stats);
        net.port_push_word(0, 1);
        net.port_push_word(0, 2);
    }

    #[test]
    fn ports_do_not_interfere() {
        // Port 1 stalling (never drained) must not affect port 0's
        // ability to stream lines, until port 1's own FIFO fills.
        let g = geom4();
        let n = g.words_per_line();
        let mut net = BaselineWriteNetwork::new(g);
        let mut stats = Stats::new();
        let mut p0_lines = 0usize;
        let mut w0 = 0u64;
        let mut w1 = 0u64;
        for c in 0..400u64 {
            net.tick(c, &mut stats);
            if net.port_can_accept(0) {
                net.port_push_word(0, w0);
                w0 += 1;
            }
            if net.port_can_accept(1) {
                net.port_push_word(1, w1);
                w1 += 1;
            }
            if net.mem_lines_ready(0) > 0 {
                net.mem_take_line(0).unwrap();
                p0_lines += 1;
            }
        }
        // Port 0 should have streamed ~400/n lines despite port 1 jamming.
        assert!(p0_lines >= 380 / n, "port 0 starved: {p0_lines} lines");
    }
}
