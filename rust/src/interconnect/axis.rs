//! AXI4-Stream-IP-style data transfer networks (the paper's Table I
//! comparator).
//!
//! Functionally these move the same data as the baseline networks — the
//! Xilinx AXI4-Stream Interconnect is a demux/mux + FIFO fabric — but the
//! IP carries extra protocol plumbing the paper's hand-rolled baseline
//! omits: per-hop register slices (TVALID/TREADY/TDATA/TKEEP/TLAST
//! pipelining) and handshake conversion. Behaviourally that shows up as
//! extra latency; in resources it shows up as the much larger LUT/FF
//! numbers of Table I (modelled in [`crate::fpga::resources`]).
//!
//! The AXI4-Stream Interconnect IP also tops out at 16 ports (§IV-B),
//! which `AxisReadNetwork::new` enforces.

use crate::config::PayloadMode;
use crate::interconnect::baseline::{BaselineReadNetwork, BaselineWriteNetwork};
use crate::interconnect::{ReadNetwork, WriteNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, Line, PortId, TaggedLine, Word};
use std::collections::VecDeque;

/// Maximum port count supported by the Xilinx AXI4-Stream Interconnect
/// (paper §IV-B: "only supports up to 16 ports").
pub const AXIS_MAX_PORTS: usize = 16;

/// Register-slice stages the IP inserts on the wide path.
const REG_SLICE_STAGES: u64 = 2;

/// One delayed item: visible after `ready_cycle`.
struct Delayed<T> {
    item: T,
    ready_cycle: u64,
}

pub struct AxisReadNetwork {
    inner: BaselineReadNetwork,
    /// Register-slice pipeline between the controller and the demux.
    slice: VecDeque<Delayed<TaggedLine>>,
    cycle: u64,
}

impl AxisReadNetwork {
    pub fn new(geom: Geometry) -> Self {
        assert!(
            geom.read_ports <= AXIS_MAX_PORTS,
            "AXI4-Stream Interconnect supports at most {AXIS_MAX_PORTS} ports (got {})",
            geom.read_ports
        );
        AxisReadNetwork { inner: BaselineReadNetwork::new(geom), slice: VecDeque::new(), cycle: 0 }
    }
}

impl ReadNetwork for AxisReadNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Axis
    }

    fn geometry(&self) -> &Geometry {
        self.inner.geometry()
    }

    fn mem_can_deliver(&self, _port: PortId) -> bool {
        // Register slices absorb one line per cycle while they have
        // space; per-port FIFO fullness back-pressures the slice drain
        // (head-of-line, as the real IP's wide path does), which fills
        // the slice and propagates here. Checking the inner network's
        // same-cycle delivery flag would halve throughput — the slice
        // drain and the slice fill are distinct pipeline stages.
        self.slice.len() < REG_SLICE_STAGES as usize + 1
    }

    fn mem_deliver(&mut self, line: TaggedLine) {
        self.slice.push_back(Delayed { item: line, ready_cycle: self.cycle + REG_SLICE_STAGES });
    }

    fn port_free_lines(&self, port: PortId) -> usize {
        self.inner.port_free_lines(port).saturating_sub(self.slice.len())
    }

    fn port_word_available(&self, port: PortId) -> bool {
        self.inner.port_word_available(port)
    }

    fn port_take_word(&mut self, port: PortId) -> Option<Word> {
        self.inner.port_take_word(port)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.inner.tick(cycle, stats);
        if let Some(front) = self.slice.front() {
            if front.ready_cycle <= cycle && self.inner.mem_can_deliver(front.item.port) {
                let d = self.slice.pop_front().unwrap();
                self.inner.mem_deliver(d.item);
                stats.bump(Counter::AxisReadLinesThroughSlices);
            }
        }
    }

    fn nominal_latency(&self) -> usize {
        self.inner.nominal_latency() + REG_SLICE_STAGES as usize
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        // The register slices carry whole lines by value; shadows flow
        // through them unchanged. Only the inner converters care.
        self.inner.set_payload_mode(mode);
    }

    fn is_leap_idle(&self) -> bool {
        self.slice.is_empty() && self.inner.is_leap_idle()
    }
}

pub struct AxisWriteNetwork {
    inner: BaselineWriteNetwork,
    slice: VecDeque<Delayed<(PortId, Line)>>,
    cycle: u64,
}

impl AxisWriteNetwork {
    pub fn new(geom: Geometry) -> Self {
        assert!(
            geom.write_ports <= AXIS_MAX_PORTS,
            "AXI4-Stream Interconnect supports at most {AXIS_MAX_PORTS} ports (got {})",
            geom.write_ports
        );
        AxisWriteNetwork { inner: BaselineWriteNetwork::new(geom), slice: VecDeque::new(), cycle: 0 }
    }
}

impl WriteNetwork for AxisWriteNetwork {
    fn design(&self) -> crate::interconnect::Design {
        crate::interconnect::Design::Axis
    }

    fn geometry(&self) -> &Geometry {
        self.inner.geometry()
    }

    fn port_can_accept(&self, port: PortId) -> bool {
        self.inner.port_can_accept(port)
    }

    fn port_push_word(&mut self, port: PortId, w: Word) {
        self.inner.port_push_word(port, w)
    }

    fn mem_lines_ready(&self, port: PortId) -> usize {
        // Lines become arbiter-visible only after traversing the
        // register slices.
        self.slice
            .iter()
            .filter(|d| d.item.0 == port && d.ready_cycle <= self.cycle)
            .count()
    }

    fn mem_take_line(&mut self, port: PortId) -> Option<Line> {
        let idx = self
            .slice
            .iter()
            .position(|d| d.item.0 == port && d.ready_cycle <= self.cycle)?;
        Some(self.slice.remove(idx).unwrap().item.1)
    }

    fn tick(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        self.inner.tick(cycle, stats);
        // Pull completed lines from the inner mux into the register
        // slices (one per cycle — single wide path).
        if self.slice.len() < 4 {
            for p in 0..self.geometry().write_ports {
                if self.inner.mem_lines_ready(p) > 0 {
                    let line = self.inner.mem_take_line(p).unwrap();
                    self.slice.push_back(Delayed {
                        item: (p, line),
                        ready_cycle: cycle + REG_SLICE_STAGES,
                    });
                    stats.bump(Counter::AxisWriteLinesThroughSlices);
                    break;
                }
            }
        }
    }

    fn nominal_latency(&self) -> usize {
        self.inner.nominal_latency() + REG_SLICE_STAGES as usize
    }

    fn set_payload_mode(&mut self, mode: PayloadMode) {
        self.inner.set_payload_mode(mode);
    }

    fn is_leap_idle(&self) -> bool {
        self.slice.is_empty() && self.inner.is_leap_idle()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::Line;

    fn geom() -> Geometry {
        // Table I configuration: 256-bit interface, 16 x 16-bit ports.
        Geometry { w_line: 256, w_acc: 16, read_ports: 16, write_ports: 16, max_burst: 32 }
    }

    #[test]
    fn port_limit_enforced() {
        let g = Geometry { w_line: 512, w_acc: 16, read_ports: 32, write_ports: 32, max_burst: 32 };
        let r = std::panic::catch_unwind(|| AxisReadNetwork::new(g));
        assert!(r.is_err(), "32 ports must exceed the AXIS IP limit");
    }

    #[test]
    fn read_data_intact_with_extra_latency() {
        let g = geom();
        let n = g.words_per_line();
        let mut net = AxisReadNetwork::new(g);
        let mut base = BaselineReadNetwork::new(g);
        assert!(net.nominal_latency() > base.nominal_latency());
        let mut stats = Stats::new();
        let line = Line::from_words((0..n as u64).collect());
        net.tick(0, &mut stats);
        base.tick(0, &mut stats);
        net.mem_deliver(TaggedLine { port: 3, line: line.clone() });
        base.mem_deliver(TaggedLine { port: 3, line: line.clone() });
        let mut got = Vec::new();
        for c in 1..60 {
            net.tick(c, &mut stats);
            if net.port_word_available(3) {
                got.push(net.port_take_word(3).unwrap());
            }
        }
        assert_eq!(got, line.words().to_vec());
    }

    #[test]
    fn write_roundtrip() {
        let g = geom();
        let n = g.words_per_line();
        let mut net = AxisWriteNetwork::new(g);
        let mut stats = Stats::new();
        let mut line = None;
        let mut pushed = 0usize;
        for c in 0..100u64 {
            net.tick(c, &mut stats);
            if pushed < n && net.port_can_accept(5) {
                net.port_push_word(5, 0x40 + pushed as Word);
                pushed += 1;
            }
            if net.mem_lines_ready(5) > 0 {
                line = net.mem_take_line(5);
                break;
            }
        }
        let line = line.expect("line never emerged");
        assert_eq!(line.words().to_vec(), (0..n as u64).map(|x| 0x40 + x).collect::<Vec<_>>());
    }
}
