//! The unified run API: one builder, every entry point.
//!
//! Before PR 7 each subsystem grew its own `_with` variants as knobs
//! accreted (`replay_with`, `verify_replay_with`, `sweep_with_threads`,
//! `sweep_with_threads_backend`, `run_search_with`, `evaluate_with`) —
//! every new knob meant another positional parameter on every entry
//! point. [`RunOptions`] replaces that family with a single builder:
//!
//! ```no_run
//! use medusa::config::SimBackend;
//! use medusa::run::RunOptions;
//! # let sc = medusa::workload::Scenario::builtin("serving-poisson").unwrap();
//! let out = RunOptions::new().backend(SimBackend::fast()).run(&sc).unwrap();
//! let matrix = RunOptions::new().threads(2).sweep().unwrap();
//! ```
//!
//! Unset knobs mean "the callee's long-standing default", so migrating
//! a caller from `replay(t)` to `RunOptions::new().replay(t)` changes
//! nothing: replay/verify/sweep default to the full reference backend,
//! the explorer entry points default to the fast (stats-exact) backend,
//! and the thread count honours `MEDUSA_THREADS`. The old `_with`
//! functions survive as `#[deprecated]` shims over the same
//! `pub(crate)` implementations; CI denies the lint so no internal
//! caller can quietly regress onto them.

use crate::config::SimBackend;
use crate::eval::scenarios::ScenarioPoint;
use crate::explore::cache::ExploreCache;
use crate::explore::search::{SearchResult, Strategy};
use crate::explore::space::{DesignSpace, ExplorePoint, Metrics};
use crate::fault::FaultSpec;
use crate::serving::ServingSpec;
use crate::sim::trace::ScenarioTrace;
use crate::workload::engine::ScenarioOutcome;
use crate::workload::scenario::Scenario;
use anyhow::Result;

/// Options shared by every run-like entry point. Construct with
/// [`RunOptions::new`], chain setters, finish with a verb
/// ([`run`](RunOptions::run), [`replay`](RunOptions::replay),
/// [`verify_replay`](RunOptions::verify_replay),
/// [`sweep`](RunOptions::sweep), [`evaluate`](RunOptions::evaluate),
/// [`run_search`](RunOptions::run_search)). `Default` is "no
/// overrides": each verb keeps the defaults it has always had.
#[derive(Clone, Debug, Default)]
pub struct RunOptions {
    threads: Option<usize>,
    backend: Option<SimBackend>,
    faults: Option<FaultSpec>,
    serving: Option<ServingSpec>,
    profile: Option<u64>,
}

impl RunOptions {
    pub fn new() -> Self {
        RunOptions::default()
    }

    /// Parallel width for batch verbs (`sweep`, `run_search`). Unset:
    /// `util::parallel::max_threads()` (honours `MEDUSA_THREADS`).
    pub fn threads(mut self, workers: usize) -> Self {
        self.threads = Some(workers);
        self
    }

    /// Simulation backend override. Unset: the verb's own default —
    /// [`SimBackend::full`] for `run`/`replay`/`verify_replay`/`sweep`
    /// (these carry golden verification), [`SimBackend::fast`] for the
    /// explorer verbs (stats-exact by the conformance contract).
    pub fn backend(mut self, backend: SimBackend) -> Self {
        self.backend = Some(backend);
        self
    }

    /// Fault-injection campaign override for `run` (replaces the
    /// scenario's own `[faults]` section).
    pub fn faults(mut self, spec: FaultSpec) -> Self {
        self.faults = Some(spec);
        self
    }

    /// Serving front-end override: replaces the scenario's `[serving]`
    /// section in `run`, and attaches a serving probe to every
    /// explorer evaluation in `evaluate`/`run_search` (see
    /// `DesignSpace::serving` for the space-level equivalent).
    pub fn serving(mut self, spec: ServingSpec) -> Self {
        self.serving = Some(spec);
        self
    }

    /// Enable the observability layer on `run`/`run_captured`/
    /// `replay`/`verify_replay` with the given utilization-window size
    /// in fabric cycles (see [`crate::obs::DEFAULT_WINDOW`]). The
    /// outcome then carries [`ScenarioOutcome::profile`]; stats,
    /// cycles, and traces stay bit-identical to an unprofiled run (the
    /// zero-perturbation contract, enforced by `profile_conformance`).
    pub fn profile(mut self, window: u64) -> Self {
        self.profile = Some(window);
        self
    }

    fn workers(&self) -> usize {
        self.threads.unwrap_or_else(crate::util::parallel::max_threads)
    }

    fn scenario_with_overrides(&self, sc: &Scenario) -> Scenario {
        let mut sc = sc.clone();
        if let Some(b) = self.backend {
            sc.cfg.sim = b;
        }
        if let Some(f) = &self.faults {
            sc.faults = f.clone();
        }
        if let Some(s) = &self.serving {
            sc.serving = s.clone();
        }
        sc
    }

    /// Run one scenario (with any backend/faults/serving overrides
    /// applied to a clone — the input scenario is untouched).
    pub fn run(&self, sc: &Scenario) -> Result<ScenarioOutcome> {
        let (out, _) =
            crate::workload::engine::run_impl(&self.scenario_with_overrides(sc), false, self.profile)?;
        Ok(out)
    }

    /// Run one scenario and capture its replayable trace.
    pub fn run_captured(&self, sc: &Scenario) -> Result<(ScenarioOutcome, ScenarioTrace)> {
        let (out, trace) =
            crate::workload::engine::run_impl(&self.scenario_with_overrides(sc), true, self.profile)?;
        Ok((out, trace.expect("capture requested")))
    }

    /// Re-execute a captured trace. Default backend: full reference.
    pub fn replay(&self, trace: &ScenarioTrace) -> Result<ScenarioOutcome> {
        crate::workload::engine::replay_impl(
            trace,
            self.backend.unwrap_or_else(SimBackend::full),
            self.profile,
        )
    }

    /// Re-execute a captured trace and assert its expect block.
    /// Default backend: full reference.
    pub fn verify_replay(&self, trace: &ScenarioTrace) -> Result<ScenarioOutcome> {
        crate::workload::engine::verify_replay_impl(
            trace,
            self.backend.unwrap_or_else(SimBackend::full),
            self.profile,
        )
    }

    /// The scenario matrix: every builtin scenario on every design.
    /// Default backend: full reference (this is where golden
    /// verification earns its column).
    pub fn sweep(&self) -> Result<Vec<ScenarioPoint>> {
        crate::eval::scenarios::sweep_impl(
            self.workers(),
            self.backend.unwrap_or_else(SimBackend::full),
        )
    }

    /// Evaluate one explorer design point. Default backend: fast
    /// (stats-exact). A `serving` override attaches the serving probe
    /// and populates `Metrics::serving_p99`.
    pub fn evaluate(&self, point: &ExplorePoint, probe: &str) -> Metrics {
        crate::explore::space::evaluate_impl(
            point,
            probe,
            self.backend.unwrap_or_else(SimBackend::fast),
            self.serving.as_ref(),
        )
    }

    /// Run a design-space search. Default backend: fast (stats-exact).
    /// A `serving` override on the options takes precedence over the
    /// space's own `serving` probe.
    pub fn run_search(
        &self,
        space: &DesignSpace,
        strategy: &Strategy,
        seed: u64,
        cache: Option<&mut ExploreCache>,
    ) -> Result<SearchResult> {
        let space = match &self.serving {
            Some(s) => {
                let mut sp = space.clone();
                sp.serving = Some(s.clone());
                sp
            }
            None => space.clone(),
        };
        crate::explore::search::run_search_impl(
            &space,
            strategy,
            seed,
            self.workers(),
            cache,
            self.backend.unwrap_or_else(SimBackend::fast),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_the_plain_entry_points() {
        let sc = Scenario::builtin("single-tiny-vgg").unwrap();
        let plain = crate::workload::engine::run_scenario(&sc).unwrap();
        let via_options = RunOptions::new().run(&sc).unwrap();
        assert_eq!(plain.fingerprint(), via_options.fingerprint());
    }

    #[test]
    fn deprecated_shims_route_to_the_same_implementation() {
        #[allow(deprecated)]
        let old = crate::eval::scenarios::sweep_with_threads(1).unwrap();
        let new = RunOptions::new().threads(1).sweep().unwrap();
        assert_eq!(old.len(), new.len());
        for (a, b) in old.iter().zip(new.iter()) {
            assert_eq!(a.fingerprint, b.fingerprint, "{} {:?}", a.scenario, a.design);
        }
    }

    #[test]
    fn overrides_replace_scenario_sections_without_mutating_the_input() {
        let sc = Scenario::builtin("single-tiny-vgg").unwrap();
        let spec = ServingSpec {
            seed: 9,
            requests: 2,
            mean_gap: 500,
            max_batch: 1,
            max_wait: 100,
            ..ServingSpec::default()
        };
        let out = RunOptions::new()
            .backend(SimBackend::fast())
            .serving(spec)
            .run(&sc)
            .unwrap();
        let report = out.serving.expect("serving override must reach the engine");
        assert_eq!(report.tenants[0].arrived, 2);
        // The input scenario was cloned, not mutated.
        assert!(sc.serving.is_none());
        assert_eq!(sc.cfg.sim, SimBackend::full());
    }

    #[test]
    fn replay_roundtrip_through_options() {
        let sc = Scenario::builtin("serving-poisson").unwrap();
        let (out, trace) = RunOptions::new().run_captured(&sc).unwrap();
        let re = RunOptions::new().verify_replay(&trace).unwrap();
        assert_eq!(out.fingerprint(), re.fingerprint());
    }
}
