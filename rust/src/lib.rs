//! # Medusa — a scalable memory interconnect for many-port DNN accelerators
//!
//! Full-system reproduction of *"Medusa: A Scalable Interconnect for
//! Many-Port DNN Accelerators and Wide DRAM Controller Interfaces"*
//! (Shen, Ji, Ferdman, Milder — 2018).
//!
//! The paper's testbed (Virtex-7 FPGA + Vivado P&R + Bluespec RTL) is
//! substituted per DESIGN.md §1 with:
//!
//! * a **cycle-accurate RTL-level simulator** of both the traditional
//!   (crossbar + FIFO + width-converter) interconnect and the Medusa
//!   transposition-based interconnect ([`sim`], [`hw`], [`interconnect`]);
//! * an **analytical FPGA resource model** and a **routing-congestion
//!   timing model** calibrated against the paper's Tables I/II and
//!   Figure 6 ([`fpga`]);
//! * a **DDR3 memory-controller model** ([`dram`]) and a **convolutional
//!   layer-processor model** ([`accel`]) that generate the paper's port
//!   traffic (perfect prefetch, double buffering);
//! * a **PJRT runtime** ([`runtime`]) that loads the JAX/Pallas-authored,
//!   AOT-lowered compute artifacts so the end-to-end examples run real
//!   DNN math through the simulated memory system ([`coordinator`]).
//!
//! The evaluation harness ([`eval`]) regenerates every table and figure
//! of the paper's evaluation section; see `EXPERIMENTS.md` for
//! paper-vs-measured numbers. Beyond the paper, [`interconnect::hybrid`]
//! generalizes the two designs into a radix-parameterized family and
//! [`explore`] searches that family for Pareto-efficient design points.

pub mod accel;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dram;
pub mod eval;
pub mod explore;
pub mod fault;
pub mod fpga;
pub mod hw;
pub mod interconnect;
pub mod obs;
pub mod run;
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod testing;
pub mod types;
pub mod util;
pub mod workload;

pub use types::{Geometry, Line, PortId, Word};
