//! Artifact discovery: find `artifacts/` and parse the manifest that
//! `python/compile/aot.py` writes alongside the HLO text files.
//!
//! Manifest format (one artifact per line):
//! `name kind in_c in_h in_w out_c k stride pad relu path`

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One compiled computation we know how to call.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ArtifactEntry {
    pub name: String,
    /// "conv" (layer forward) or "transpose" (the Medusa kernel demo).
    pub kind: String,
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k: usize,
    pub stride: usize,
    pub pad: usize,
    pub relu: bool,
    pub path: PathBuf,
}

#[derive(Debug)]
pub struct Artifacts {
    pub dir: PathBuf,
    entries: BTreeMap<String, ArtifactEntry>,
}

impl Artifacts {
    /// Locate the artifacts directory: `$MEDUSA_ARTIFACTS`, else
    /// `./artifacts`, else `../artifacts` (for tests running from the
    /// crate dir). Errors if no manifest is found — run `make artifacts`.
    pub fn discover() -> Result<Self> {
        let candidates: Vec<PathBuf> = std::env::var("MEDUSA_ARTIFACTS")
            .map(|p| vec![PathBuf::from(p)])
            .unwrap_or_else(|_| vec![PathBuf::from("artifacts"), PathBuf::from("../artifacts")]);
        for dir in candidates {
            if dir.join("manifest.txt").is_file() {
                return Self::load(&dir);
            }
        }
        bail!("artifacts not found — run `make artifacts` first (or set MEDUSA_ARTIFACTS)")
    }

    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {}", manifest.display()))?;
        let mut entries = BTreeMap::new();
        for (ln, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 11 {
                bail!("manifest line {}: expected 11 fields, got {}", ln + 1, f.len());
            }
            let parse = |s: &str, what: &str| -> Result<usize> {
                s.parse().map_err(|_| anyhow!("manifest line {}: bad {what}: {s:?}", ln + 1))
            };
            let e = ArtifactEntry {
                name: f[0].to_string(),
                kind: f[1].to_string(),
                in_c: parse(f[2], "in_c")?,
                in_h: parse(f[3], "in_h")?,
                in_w: parse(f[4], "in_w")?,
                out_c: parse(f[5], "out_c")?,
                k: parse(f[6], "k")?,
                stride: parse(f[7], "stride")?,
                pad: parse(f[8], "pad")?,
                relu: f[9] == "1" || f[9] == "true",
                path: dir.join(f[10]),
            };
            if !e.path.is_file() {
                bail!("manifest entry {} points to missing file {}", e.name, e.path.display());
            }
            entries.insert(e.name.clone(), e);
        }
        anyhow::ensure!(!entries.is_empty(), "manifest is empty");
        Ok(Artifacts { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("no artifact named {name:?}; have: {:?}", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.keys().map(|s| s.as_str()).collect()
    }

    pub fn entries(&self) -> impl Iterator<Item = &ArtifactEntry> {
        self.entries.values()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        let mut f = std::fs::File::create(dir.join("manifest.txt")).unwrap();
        f.write_all(body.as_bytes()).unwrap();
    }

    #[test]
    fn parses_valid_manifest() {
        let dir = std::env::temp_dir().join("medusa_test_artifacts_ok");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("conv1.hlo.txt"), "HloModule x").unwrap();
        write_manifest(&dir, "# comment\nconv1 conv 3 32 32 16 3 1 1 1 conv1.hlo.txt\n");
        let a = Artifacts::load(&dir).unwrap();
        let e = a.get("conv1").unwrap();
        assert_eq!(e.in_c, 3);
        assert_eq!(e.out_c, 16);
        assert!(e.relu);
        assert_eq!(a.names(), vec!["conv1"]);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_rejected() {
        let dir = std::env::temp_dir().join("medusa_test_artifacts_miss");
        write_manifest(&dir, "conv1 conv 3 32 32 16 3 1 1 1 nope.hlo.txt\n");
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_line_rejected() {
        let dir = std::env::temp_dir().join("medusa_test_artifacts_bad");
        write_manifest(&dir, "conv1 conv 3 32\n");
        assert!(Artifacts::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
