//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! produced at build time and executes them from the Rust hot path.
//! Python is never involved at run time.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto` —
//! jax >= 0.5 emits protos with 64-bit instruction ids that the
//! xla_extension 0.5.1 backing the `xla` crate rejects; the text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README).

pub mod artifacts;
pub mod client;
pub mod executor;

pub use artifacts::Artifacts;
pub use client::RuntimeClient;
pub use executor::ConvExecutor;
