//! PJRT runtime: loads the HLO-text artifacts that `python/compile/aot.py`
//! produced at build time and executes them from the Rust hot path.
//! Python is never involved at run time.
//!
//! Interchange is HLO **text**, not serialized `HloModuleProto` —
//! jax >= 0.5 emits protos with 64-bit instruction ids that the
//! xla_extension 0.5.1 backing the `xla` crate rejects; the text parser
//! reassigns ids and round-trips cleanly (see /opt/xla-example/README).
//!
//! The real client requires the `xla` crate, which is not in the offline
//! registry; it compiles only under the `pjrt` feature (add the crate as
//! a path/git dependency alongside). Without the feature, [`stub`]
//! provides the same public types whose constructors return a clear
//! error, so every caller (driver, CLI, examples, e2e tests) falls back
//! to the Rust golden-model backend exactly as it does when artifacts
//! are missing.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod executor;
#[cfg(not(feature = "pjrt"))]
pub mod stub;

pub use artifacts::Artifacts;
#[cfg(feature = "pjrt")]
pub use client::RuntimeClient;
#[cfg(feature = "pjrt")]
pub use executor::ConvExecutor;
#[cfg(not(feature = "pjrt"))]
pub use stub::{ConvExecutor, RuntimeClient};
