//! Compile-time stand-ins for the PJRT runtime when the `pjrt` feature
//! (and its `xla` crate dependency) is absent. Constructors return
//! errors; since no instance can ever exist, the execution methods are
//! unreachable but keep the same signatures so call sites compile
//! unchanged and fall back to the golden backend at run time.

use crate::accel::dnn::ConvLayer;
use crate::accel::quant::Fixed16;
use crate::runtime::Artifacts;
use anyhow::{bail, Result};

const UNAVAILABLE: &str =
    "PJRT support not compiled in — build with `--features pjrt` and provide the `xla` crate";

/// Stub of the PJRT CPU client wrapper.
pub struct RuntimeClient {
    _private: (),
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn platform(&self) -> String {
        unreachable!("RuntimeClient cannot be constructed without the pjrt feature")
    }

    pub fn is_loaded(&self, _name: &str) -> bool {
        unreachable!("RuntimeClient cannot be constructed without the pjrt feature")
    }
}

/// Stub of the conv-artifact executor.
pub struct ConvExecutor {
    _private: (),
}

impl ConvExecutor {
    pub fn new() -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn with_artifacts(_artifacts: Artifacts) -> Result<Self> {
        bail!(UNAVAILABLE)
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        unreachable!("ConvExecutor cannot be constructed without the pjrt feature")
    }

    pub fn layer_of(&self, _name: &str) -> Result<ConvLayer> {
        unreachable!("ConvExecutor cannot be constructed without the pjrt feature")
    }

    pub fn run_conv(
        &mut self,
        _name: &str,
        _ifmap: &[Fixed16],
        _weights: &[Fixed16],
        _bias: &[Fixed16],
    ) -> Result<Vec<Fixed16>> {
        unreachable!("ConvExecutor cannot be constructed without the pjrt feature")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stubs_error_cleanly() {
        let err = RuntimeClient::cpu().unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
        assert!(ConvExecutor::new().is_err());
    }
}
