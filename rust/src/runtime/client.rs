//! Thin wrapper over the `xla` crate's PJRT CPU client: compile HLO text
//! once, execute many times.

use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;

pub struct RuntimeClient {
    client: xla::PjRtClient,
    /// Compiled executables, keyed by artifact name.
    executables: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl RuntimeClient {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(RuntimeClient { client, executables: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile an HLO text file and cache the executable under `name`.
    pub fn load_hlo_text(&mut self, name: &str, path: &Path) -> Result<()> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-UTF8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name} ({})", path.display()))?;
        self.executables.insert(name.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, name: &str) -> bool {
        self.executables.contains_key(name)
    }

    /// Execute a loaded computation. The artifacts are lowered with
    /// `return_tuple=True`, so the single output is a tuple; we return
    /// its elements as literals.
    pub fn execute(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self
            .executables
            .get(name)
            .with_context(|| format!("artifact {name} not loaded"))?;
        let result = exe
            .execute::<xla::Literal>(inputs)
            .with_context(|| format!("executing {name}"))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {name}"))?;
        let elems = tuple.decompose_tuple().context("decomposing result tuple")?;
        Ok(elems)
    }
}

#[cfg(test)]
mod tests {
    // Client tests that need real artifacts live in
    // rust/tests/runtime_integration.rs (they require `make artifacts`).
    use super::*;

    #[test]
    fn cpu_client_comes_up() {
        let c = RuntimeClient::cpu().unwrap();
        assert_eq!(c.platform(), "cpu");
        assert!(!c.is_loaded("nothing"));
    }

    #[test]
    fn missing_executable_errors() {
        let c = RuntimeClient::cpu().unwrap();
        assert!(c.execute("ghost", &[]).is_err());
    }
}
