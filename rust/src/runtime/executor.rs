//! Conv-layer executor: runs the AOT-compiled JAX/Pallas artifacts on
//! Q8.8 tensors.
//!
//! Numeric contract with `python/compile/model.py`: tensors cross the
//! boundary as **raw Q8.8 integers carried in f64** (exact: products fit
//! in 2^30, receptive-field sums in well under 2^53). The artifact
//! performs the convolution in this integer-in-f64 domain, then the
//! round-half-even shift, saturation, and optional ReLU — bit-identical
//! to `accel::golden::conv2d_q88`.

use crate::accel::dnn::ConvLayer;
use crate::accel::quant::Fixed16;
use crate::runtime::{Artifacts, RuntimeClient};
use anyhow::{Context, Result};

pub struct ConvExecutor {
    client: RuntimeClient,
    artifacts: Artifacts,
}

impl ConvExecutor {
    /// Discover artifacts and bring up the PJRT CPU client.
    pub fn new() -> Result<Self> {
        Ok(ConvExecutor { client: RuntimeClient::cpu()?, artifacts: Artifacts::discover()? })
    }

    pub fn with_artifacts(artifacts: Artifacts) -> Result<Self> {
        Ok(ConvExecutor { client: RuntimeClient::cpu()?, artifacts })
    }

    pub fn artifact_names(&self) -> Vec<&str> {
        self.artifacts.names()
    }

    /// The ConvLayer shape an artifact was compiled for (shape is baked
    /// into the HLO; the caller must match it).
    pub fn layer_of(&self, name: &str) -> Result<ConvLayer> {
        let e = self.artifacts.get(name)?;
        anyhow::ensure!(e.kind == "conv", "artifact {name} is {:?}, not conv", e.kind);
        Ok(ConvLayer {
            name: "artifact",
            in_c: e.in_c,
            in_h: e.in_h,
            in_w: e.in_w,
            out_c: e.out_c,
            k: e.k,
            stride: e.stride,
            pad: e.pad,
            relu: e.relu,
        })
    }

    /// Execute the named conv artifact. Input sizes must match the baked
    /// shape; returns the quantized output map.
    pub fn run_conv(
        &mut self,
        name: &str,
        ifmap: &[Fixed16],
        weights: &[Fixed16],
        bias: &[Fixed16],
    ) -> Result<Vec<Fixed16>> {
        let layer = self.layer_of(name)?;
        anyhow::ensure!(
            ifmap.len() == layer.ifmap_words(),
            "ifmap size {} != expected {}",
            ifmap.len(),
            layer.ifmap_words()
        );
        anyhow::ensure!(weights.len() == layer.out_c * layer.in_c * layer.k * layer.k);
        anyhow::ensure!(bias.len() == layer.out_c);
        if !self.client.is_loaded(name) {
            let path = self.artifacts.get(name)?.path.clone();
            self.client.load_hlo_text(name, &path)?;
        }
        let to_f64 = |xs: &[Fixed16]| -> Vec<f64> { xs.iter().map(|v| v.0 as f64).collect() };
        let lit = |xs: &[Fixed16]| -> Result<xla::Literal> {
            Ok(xla::Literal::vec1(&to_f64(xs)))
        };
        let outputs = self
            .client
            .execute(name, &[lit(ifmap)?, lit(weights)?, lit(bias)?])
            .with_context(|| format!("conv artifact {name}"))?;
        anyhow::ensure!(outputs.len() == 1, "expected 1 output, got {}", outputs.len());
        let raw: Vec<f64> = outputs[0].to_vec::<f64>().context("reading output literal")?;
        anyhow::ensure!(
            raw.len() == layer.ofmap_words(),
            "output size {} != expected {}",
            raw.len(),
            layer.ofmap_words()
        );
        Ok(raw
            .into_iter()
            .map(|v| {
                debug_assert!(v.fract() == 0.0, "artifact output must be integral, got {v}");
                Fixed16(v.clamp(i16::MIN as f64, i16::MAX as f64) as i16)
            })
            .collect())
    }
}

// Integration tests (needing real artifacts) are in
// rust/tests/runtime_integration.rs.
