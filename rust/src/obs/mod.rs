//! Zero-perturbation observability (PR 9).
//!
//! An always-compiled, off-by-default profiling layer with one hard
//! contract: **a run with profiling enabled is bit-identical — stats,
//! cycles, traces, fingerprints — to the same run with it disabled**
//! (`tests/profile_conformance.rs` enforces this with the same
//! discipline as the elided-vs-full and leap-vs-stepwise suites).
//!
//! The contract is held structurally, not by care alone:
//!
//! * every recorder hook only *reads* state the simulator already
//!   computes (channel occupancy, `is_leap_idle`, LP phases, the leap
//!   horizon the scheduler was about to take anyway) — no hook feeds a
//!   value back into control flow, counters, PRNG draws, or traces;
//! * profile data lives in its own [`SysRecorder`] / [`RunProfile`]
//!   structs, never in [`Stats`](crate::sim::Stats) — so the counter
//!   registry that trace expect blocks and outcome fingerprints
//!   serialize is untouched;
//! * host-time spans use `std::time::Instant` only when profiling is
//!   on, and the readings go straight into the report — they never
//!   enter cache keys, traces, or anything a conformance test hashes.
//!
//! Three instruments:
//!
//! 1. **Cycle attribution** — per clock domain, edges stepped vs
//!    leapt, plus a per-reason breakdown of every refused leap
//!    ([`LeapBlock`]) and a cap-source breakdown of every taken leap
//!    ([`CapSource`]). Invariants (property-tested): per domain,
//!    `stepped + leapt` equals the domain's total elapsed cycles;
//!    refusal reasons sum exactly to `attempts - taken`; cap sources
//!    sum exactly to `taken`.
//! 2. **Utilization timelines** — windowed occupancy series: per
//!    port-group activity, CDC channel occupancy, trunk queue depth,
//!    and (on serving runs) a change-driven serving queue-depth
//!    series. Only *stepped* fabric edges contribute samples: a leapt
//!    span is idle by construction (that is what made it leapable), so
//!    absent windows read as "fully idle".
//! 3. **Host-time spans** — wall-clock per run phase
//!    (build/precompute/drive/report) and per explorer point
//!    ([`PointTiming`]: eval seconds + cache hit/miss).

pub mod json;
pub mod report;

/// Default utilization window, in fabric cycles.
pub const DEFAULT_WINDOW: u64 = 4096;

/// Cap on retained utilization windows: when a run outgrows it the
/// series self-coarsens (adjacent windows merge, the window doubles),
/// so arbitrarily long runs profile in bounded memory.
const MAX_WINDOWS: usize = 2048;

/// Why a leap attempt was refused outright (no edges were leapt).
/// Mirrors the check order of `System::leap_horizon` so every refusal
/// lands on the *first* blocking component, the one that would act on
/// the very next edge.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LeapBlock {
    /// A CDC channel (cmd / rd_line / wr_data) holds in-flight data.
    ChannelOccupied,
    /// A hierarchical trunk queue is non-empty (checked before the
    /// generic network probe so trunk traffic attributes distinctly).
    TrunkQueue,
    /// A read/write network holds state beyond the trunk queue.
    NetworkBusy,
    /// The arbiter has pending requests or writes in flight.
    ArbiterBusy,
    /// The memory controller's command engine is mid-operation.
    ControllerBusy,
    /// Some layer processor is in its Load or Drain phase.
    LpLoadDrain,
    /// A fault suppression window (slowdown/wedge) is in force.
    FaultWindow,
    /// A tenant is quiesced by the degrade policy (stepped exactly).
    Quiesced,
    /// Every component was idle but the caller's cap (or the fault
    /// edge) allowed zero fabric cycles.
    ZeroCap,
    /// The scheduler could not fit even one fabric edge in the step
    /// budget (or the domain count exceeds the exact-leap limit).
    StepBudget,
}

impl LeapBlock {
    pub const ALL: [LeapBlock; 10] = [
        LeapBlock::ChannelOccupied,
        LeapBlock::TrunkQueue,
        LeapBlock::NetworkBusy,
        LeapBlock::ArbiterBusy,
        LeapBlock::ControllerBusy,
        LeapBlock::LpLoadDrain,
        LeapBlock::FaultWindow,
        LeapBlock::Quiesced,
        LeapBlock::ZeroCap,
        LeapBlock::StepBudget,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LeapBlock::ChannelOccupied => "channel_occupied",
            LeapBlock::TrunkQueue => "trunk_queue",
            LeapBlock::NetworkBusy => "network_busy",
            LeapBlock::ArbiterBusy => "arbiter_busy",
            LeapBlock::ControllerBusy => "controller_busy",
            LeapBlock::LpLoadDrain => "lp_load_drain",
            LeapBlock::FaultWindow => "fault_window",
            LeapBlock::Quiesced => "quiesced",
            LeapBlock::ZeroCap => "zero_cap",
            LeapBlock::StepBudget => "step_budget",
        }
    }
}

/// What bounded a *taken* leap — which constraint set the number of
/// fabric cycles actually covered. Ties attribute to the intrinsic
/// horizon (the leap would have stopped there regardless of caps).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CapSource {
    /// No finite bound: everything was Done and the span ran to the
    /// caller's budget.
    Uncapped,
    /// A layer processor's compute countdown set the horizon.
    LpCompute,
    /// The next fault edge (refresh/CDC/slowdown window start).
    FaultWindow,
    /// A waiting tenant's start cycle (scenario engine cap).
    TenantStart,
    /// The serving layer's next arrival/retire event.
    ServingHorizon,
    /// The caller's edge/cycle budget (run loops, benchmarks).
    EdgeBudget,
    /// The scheduler's step budget truncated the span.
    StepBudget,
}

impl CapSource {
    pub const ALL: [CapSource; 7] = [
        CapSource::Uncapped,
        CapSource::LpCompute,
        CapSource::FaultWindow,
        CapSource::TenantStart,
        CapSource::ServingHorizon,
        CapSource::EdgeBudget,
        CapSource::StepBudget,
    ];

    pub fn name(self) -> &'static str {
        match self {
            CapSource::Uncapped => "uncapped",
            CapSource::LpCompute => "lp_compute",
            CapSource::FaultWindow => "fault_window",
            CapSource::TenantStart => "tenant_start",
            CapSource::ServingHorizon => "serving_horizon",
            CapSource::EdgeBudget => "edge_budget",
            CapSource::StepBudget => "step_budget",
        }
    }
}

/// Leap attempt accounting. Invariants:
/// `attempts == taken + refusals.sum()` and `caps.sum() == taken`.
#[derive(Clone, Debug, Default)]
pub struct LeapTelemetry {
    pub attempts: u64,
    pub taken: u64,
    /// Refusal count per [`LeapBlock`], indexed by discriminant.
    pub refusals: [u64; LeapBlock::ALL.len()],
    /// Cap-source count per [`CapSource`], indexed by discriminant.
    pub caps: [u64; CapSource::ALL.len()],
}

impl LeapTelemetry {
    pub fn refused(&self) -> u64 {
        self.attempts - self.taken
    }

    pub fn refusal_total(&self) -> u64 {
        self.refusals.iter().sum()
    }

    pub fn cap_total(&self) -> u64 {
        self.caps.iter().sum()
    }
}

/// One utilization window: sums of per-edge observations over up to
/// `window` stepped fabric cycles starting at `start`. Divide by
/// `edges` for mean occupancy; `edges < window` happens in the final
/// window and in windows partially covered by leaps.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WindowSample {
    /// First fabric cycle the window covers.
    pub start: u64,
    /// Stepped fabric edges observed (leapt edges never sample).
    pub edges: u64,
    /// Per port group: edges on which that group's layer processor was
    /// not Done (busy-ish: loading, computing, or draining).
    pub busy: Vec<u64>,
    /// Summed cmd-channel occupancy over the window's edges.
    pub cmd_occ: u64,
    /// Summed read-line-channel occupancy.
    pub rd_line_occ: u64,
    /// Summed write-data-channel occupancy.
    pub wr_data_occ: u64,
    /// Summed trunk queue depth (read + write; 0 on flat designs).
    pub trunk_occ: u64,
}

impl WindowSample {
    fn fresh(start: u64, groups: usize) -> Self {
        WindowSample { start, busy: vec![0; groups], ..Default::default() }
    }

    fn merge(&mut self, other: &WindowSample) {
        self.edges += other.edges;
        for (a, b) in self.busy.iter_mut().zip(other.busy.iter()) {
            *a += b;
        }
        self.cmd_occ += other.cmd_occ;
        self.rd_line_occ += other.rd_line_occ;
        self.wr_data_occ += other.wr_data_occ;
        self.trunk_occ += other.trunk_occ;
    }
}

/// Windowed occupancy accumulator (instrument b). Bounded memory: at
/// [`MAX_WINDOWS`] retained windows the series coarsens in place.
#[derive(Clone, Debug)]
pub struct Utilization {
    pub window: u64,
    pub groups: usize,
    samples: Vec<WindowSample>,
    cur: WindowSample,
    cur_open: bool,
}

impl Utilization {
    fn new(groups: usize, window: u64) -> Self {
        let window = window.max(1);
        Utilization {
            window,
            groups,
            samples: Vec::new(),
            cur: WindowSample::fresh(0, groups),
            cur_open: false,
        }
    }

    /// Called once per *stepped* fabric edge, before the per-edge
    /// `mark_busy` / `add_occupancy` observations.
    pub(crate) fn begin_edge(&mut self, cycle: u64) {
        let start = (cycle / self.window) * self.window;
        if self.cur_open && self.cur.start != start {
            self.roll();
        }
        if !self.cur_open {
            self.cur = WindowSample::fresh(start, self.groups);
            self.cur_open = true;
        }
        self.cur.edges += 1;
    }

    pub(crate) fn mark_busy(&mut self, group: usize) {
        self.cur.busy[group] += 1;
    }

    pub(crate) fn add_occupancy(&mut self, cmd: u64, rd_line: u64, wr_data: u64, trunk: u64) {
        self.cur.cmd_occ += cmd;
        self.cur.rd_line_occ += rd_line;
        self.cur.wr_data_occ += wr_data;
        self.cur.trunk_occ += trunk;
    }

    fn roll(&mut self) {
        let groups = self.groups;
        let done = std::mem::replace(&mut self.cur, WindowSample::fresh(0, groups));
        self.samples.push(done);
        self.cur_open = false;
        if self.samples.len() >= MAX_WINDOWS {
            self.coarsen();
        }
    }

    /// Merge adjacent window pairs and double the window size; an odd
    /// trailing window stands alone under the new (coarser) width.
    fn coarsen(&mut self) {
        self.window *= 2;
        let mut merged: Vec<WindowSample> = Vec::with_capacity(self.samples.len() / 2 + 1);
        for s in self.samples.drain(..) {
            match merged.last_mut() {
                Some(last) if s.start / self.window == last.start / self.window => {
                    last.merge(&s);
                }
                _ => merged.push(s),
            }
        }
        self.samples = merged;
    }

    fn finish(mut self) -> (u64, Vec<WindowSample>) {
        if self.cur_open {
            self.roll();
        }
        (self.window, self.samples)
    }
}

/// Live recorder owned by the `System` while profiling is on. Every
/// field is write-only from the simulator's point of view: nothing in
/// here is ever read back into simulation decisions.
#[derive(Clone, Debug)]
pub struct SysRecorder {
    /// Clock domain names, scheduler order (fabric, mem[, trunk]).
    pub domains: Vec<&'static str>,
    /// Edges executed one at a time, per domain.
    pub stepped: Vec<u64>,
    /// Edges covered by idle-span leaps, per domain.
    pub leapt: Vec<u64>,
    pub leap: LeapTelemetry,
    /// The external cap source in force for the next leap attempt —
    /// set by the drive loop before each `try_leap_idle` call so a
    /// budget-capped leap attributes to the right constraint. Defaults
    /// to [`CapSource::EdgeBudget`] (the run-loop budget).
    pub pending_cap: CapSource,
    pub util: Utilization,
    /// Change-driven serving queue-depth series: `(fabric_cycle,
    /// total_queued)` pushed only when the depth differs from the
    /// previous sample.
    pub serving_depth: Vec<(u64, u64)>,
    /// Change-driven cumulative shed series: `(fabric_cycle,
    /// total_shed)`. Pairs with `serving_depth` so a flat depth under
    /// a full bounded queue reads as overload sheds, not idleness.
    pub serving_shed: Vec<(u64, u64)>,
}

impl SysRecorder {
    pub fn new(domains: Vec<&'static str>, groups: usize, window: u64) -> Self {
        let n = domains.len();
        SysRecorder {
            domains,
            stepped: vec![0; n],
            leapt: vec![0; n],
            leap: LeapTelemetry::default(),
            pending_cap: CapSource::EdgeBudget,
            util: Utilization::new(groups, window),
            serving_depth: Vec::new(),
            serving_shed: Vec::new(),
        }
    }

    pub fn serving_depth_sample(&mut self, cycle: u64, depth: u64) {
        if self.serving_depth.last().map(|&(_, d)| d) != Some(depth) {
            self.serving_depth.push((cycle, depth));
        }
    }

    pub fn serving_shed_sample(&mut self, cycle: u64, shed: u64) {
        if self.serving_shed.last().map(|&(_, s)| s) != Some(shed) {
            self.serving_shed.push((cycle, shed));
        }
    }

    pub fn finish(self) -> SysProfile {
        let domains = self
            .domains
            .iter()
            .zip(self.stepped.iter().zip(self.leapt.iter()))
            .map(|(&name, (&stepped, &leapt))| DomainEdges { name, stepped, leapt })
            .collect();
        let (window, utilization) = self.util.finish();
        SysProfile {
            domains,
            leap: self.leap,
            window,
            groups: self.util.groups,
            utilization,
            serving_depth: self.serving_depth,
            serving_shed: self.serving_shed,
        }
    }
}

/// Per-domain edge attribution: `stepped + leapt == total elapsed
/// cycles` for that domain, enforced by the conformance suite.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DomainEdges {
    pub name: &'static str,
    pub stepped: u64,
    pub leapt: u64,
}

impl DomainEdges {
    pub fn total(&self) -> u64 {
        self.stepped + self.leapt
    }
}

/// Finished simulator-side profile (instruments a and b).
#[derive(Clone, Debug)]
pub struct SysProfile {
    pub domains: Vec<DomainEdges>,
    pub leap: LeapTelemetry,
    /// Final utilization window width (>= the configured window if the
    /// series coarsened).
    pub window: u64,
    pub groups: usize,
    pub utilization: Vec<WindowSample>,
    pub serving_depth: Vec<(u64, u64)>,
    pub serving_shed: Vec<(u64, u64)>,
}

/// A complete run profile: simulator-side attribution plus host-time
/// spans (instrument c). Attached to `ScenarioOutcome` *outside* the
/// fingerprint — the outcome hash never sees it.
#[derive(Clone, Debug)]
pub struct RunProfile {
    pub sys: SysProfile,
    /// `(phase, seconds)` host wall-clock spans in phase order:
    /// build, precompute, drive, report.
    pub host: Vec<(&'static str, f64)>,
}

/// Per-point explorer telemetry: host-side only, never part of the
/// memo key, the on-disk cache, or the evaluated-set comparison.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PointTiming {
    /// Grid index of the point in its design space.
    pub index: usize,
    /// True when the metrics came from the on-disk cache (eval_s is 0).
    pub cache_hit: bool,
    /// Wall-clock seconds the evaluation took (0 on cache hits).
    pub eval_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_rolls_and_finishes() {
        let mut u = Utilization::new(2, 4);
        for c in 0..10u64 {
            u.begin_edge(c);
            u.mark_busy(0);
            u.add_occupancy(1, 0, 0, 0);
        }
        let (w, samples) = u.finish();
        assert_eq!(w, 4);
        assert_eq!(samples.len(), 3);
        assert_eq!(samples[0].start, 0);
        assert_eq!(samples[0].edges, 4);
        assert_eq!(samples[1].start, 4);
        assert_eq!(samples[2].start, 8);
        assert_eq!(samples[2].edges, 2);
        assert_eq!(samples[0].busy, vec![4, 0]);
        assert_eq!(samples[0].cmd_occ, 4);
    }

    #[test]
    fn sparse_edges_skip_windows() {
        // Leapt spans never call begin_edge: windows with no stepped
        // edges simply don't exist in the series.
        let mut u = Utilization::new(1, 4);
        u.begin_edge(1);
        u.begin_edge(100);
        u.begin_edge(101);
        let (_, samples) = u.finish();
        assert_eq!(samples.len(), 2);
        assert_eq!(samples[0].start, 0);
        assert_eq!(samples[0].edges, 1);
        assert_eq!(samples[1].start, 100);
        assert_eq!(samples[1].edges, 2);
    }

    #[test]
    fn coarsening_preserves_totals() {
        let mut u = Utilization::new(1, 1);
        let n = (MAX_WINDOWS as u64) * 3;
        for c in 0..n {
            u.begin_edge(c);
            u.mark_busy(0);
        }
        let (w, samples) = u.finish();
        assert!(w > 1, "series must have coarsened");
        assert!(samples.len() <= MAX_WINDOWS);
        let edges: u64 = samples.iter().map(|s| s.edges).sum();
        let busy: u64 = samples.iter().map(|s| s.busy[0]).sum();
        assert_eq!(edges, n);
        assert_eq!(busy, n);
        // Starts strictly increase and stay window-aligned after the
        // final coarsening pass.
        for pair in samples.windows(2) {
            assert!(pair[0].start < pair[1].start);
        }
    }

    #[test]
    fn serving_depth_is_change_driven() {
        let mut r = SysRecorder::new(vec!["fabric", "mem"], 1, 16);
        r.serving_depth_sample(0, 0);
        r.serving_depth_sample(5, 0);
        r.serving_depth_sample(9, 2);
        r.serving_depth_sample(12, 2);
        r.serving_depth_sample(20, 1);
        assert_eq!(r.serving_depth, vec![(0, 0), (9, 2), (20, 1)]);
    }

    #[test]
    fn telemetry_invariants_hold_on_fresh_recorder() {
        let r = SysRecorder::new(vec!["fabric", "mem", "trunk"], 2, DEFAULT_WINDOW);
        let p = r.finish();
        assert_eq!(p.domains.len(), 3);
        assert_eq!(p.leap.attempts, p.leap.taken + p.leap.refusal_total());
        assert_eq!(p.leap.cap_total(), p.leap.taken);
    }

    #[test]
    fn reason_names_are_unique_within_each_enum() {
        // CapSource and LeapBlock share fault_window/step_budget by
        // design (same physical constraint, two roles); everything
        // else is distinct within its own enum.
        let mut lbs: Vec<&str> = LeapBlock::ALL.iter().map(|b| b.name()).collect();
        lbs.sort_unstable();
        lbs.dedup();
        assert_eq!(lbs.len(), LeapBlock::ALL.len());
        let mut css: Vec<&str> = CapSource::ALL.iter().map(|c| c.name()).collect();
        css.sort_unstable();
        css.dedup();
        assert_eq!(css.len(), CapSource::ALL.len());
    }
}
