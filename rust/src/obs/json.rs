//! Minimal JSON reader for profile reports.
//!
//! The crate writes all its JSON by hand (no serde dependency); the
//! `medusa profile` subcommand and the CI smoke step need to *read*
//! one format back — the profile reports this module's sibling
//! [`report`](super::report) writes. This is a small recursive-descent
//! parser over that grammar: full JSON syntax, numbers as `f64`,
//! objects as ordered key/value vectors (insertion order preserved, so
//! pretty-printing round-trips the writer's ordering).

/// A parsed JSON value. Objects keep insertion order; duplicate keys
/// keep their first occurrence on lookup.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object member lookup (None on non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(members) => members.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Numeric member as an unsigned integer (counts, cycles).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Value::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(items) => Some(items),
            _ => None,
        }
    }

    /// Object members in insertion order.
    pub fn entries(&self) -> Option<&[(String, Value)]> {
        match self {
            Value::Obj(members) => Some(members),
            _ => None,
        }
    }
}

/// Parse a complete JSON document; trailing non-whitespace is an
/// error. Errors carry a byte offset for quick diagnosis.
pub fn parse(text: &str) -> Result<Value, String> {
    let mut p = Parser { bytes: text.as_bytes(), pos: 0, depth: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

/// Nesting guard: reports are a few levels deep; anything past this is
/// malformed input, not a report.
const MAX_DEPTH: usize = 64;

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while let Some(&b) = self.bytes.get(self.pos) {
            if matches!(b, b' ' | b'\t' | b'\n' | b'\r') {
                self.pos += 1;
            } else {
                break;
            }
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", b as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        if self.depth >= MAX_DEPTH {
            return Err(format!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos));
        }
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal("true", Value::Bool(true)),
            Some(b'f') => self.literal("false", Value::Bool(false)),
            Some(b'n') => self.literal("null", Value::Null),
            Some(b) if b == b'-' || b.is_ascii_digit() => self.number(),
            Some(b) => Err(format!("unexpected byte '{}' at {}", b as char, self.pos)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.expect(b'{')?;
        self.depth += 1;
        let mut members = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Obj(members));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            members.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Obj(members));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }

    fn array(&mut self) -> Result<Value, String> {
        self.expect(b'[')?;
        self.depth += 1;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            self.depth -= 1;
            return Ok(Value::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    self.depth -= 1;
                    return Ok(Value::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{0008}'),
                        Some(b'f') => out.push('\u{000c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // BMP only; lone/paired surrogates render
                            // as the replacement character (the
                            // writers never emit non-BMP text).
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            continue;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.pos)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a full UTF-8 scalar (input is a &str, so
                    // boundaries are valid by construction).
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest).map_err(|_| "invalid UTF-8")?;
                    let ch = s.chars().next().unwrap();
                    out.push(ch);
                    self.pos += ch.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, String> {
        let end = self.pos + 4;
        if end > self.bytes.len() {
            return Err("truncated \\u escape".into());
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..end])
            .map_err(|_| "bad \\u escape".to_string())?;
        let cp = u32::from_str_radix(s, 16).map_err(|_| "bad \\u escape".to_string())?;
        self.pos = end;
        Ok(cp)
    }

    fn number(&mut self) -> Result<Value, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while let Some(b) = self.peek() {
            if b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number '{}' at byte {}", text, start))
    }
}

/// Escape a string for embedding in hand-written JSON output.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars_and_containers() {
        let v = parse(r#"{"a": 1, "b": [true, null, -2.5], "c": {"d": "x"}}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_u64(), Some(1));
        let arr = v.get("b").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_bool(), Some(true));
        assert_eq!(arr[1], Value::Null);
        assert_eq!(arr[2].as_f64(), Some(-2.5));
        assert_eq!(v.get("c").unwrap().get("d").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn preserves_member_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<&str> = v.entries().unwrap().iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["z", "a", "m"]);
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = "line1\nline2\t\"quoted\" back\\slash";
        let wire = format!("\"{}\"", escape(original));
        assert_eq!(parse(&wire).unwrap().as_str(), Some(original));
    }

    #[test]
    fn unicode_escape_decodes() {
        assert_eq!(parse("\"A\\u00e9\"").unwrap().as_str(), Some("A\u{e9}"));
        // Raw multibyte UTF-8 passes through unescaped too.
        assert_eq!(parse("\"é\"").unwrap().as_str(), Some("é"));
    }

    #[test]
    fn rejects_malformed_input() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
        // Nesting bomb trips the depth guard, not the stack.
        let bomb = "[".repeat(100_000);
        assert!(parse(&bomb).is_err());
    }

    #[test]
    fn integers_roundtrip_exactly_to_2_pow_53() {
        let v = parse("9007199254740992").unwrap();
        assert_eq!(v.as_u64(), Some(1u64 << 53));
        // Fractional numbers are not integers.
        assert_eq!(parse("1.5").unwrap().as_u64(), None);
    }
}
