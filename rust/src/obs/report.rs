//! Profile report rendering: the `--profile` JSON writers, the schema
//! validator, and the `medusa profile` pretty-printer.
//!
//! Two schemas, discriminated by the top-level `"profile"` key:
//!
//! * `medusa_run_v1` — one simulated run (run/replay/serve): cycle
//!   attribution per clock domain, the leap-refusal and cap-source
//!   breakdowns, utilization windows, and host-time phase spans.
//! * `medusa_explore_v1` — one explorer campaign: per-point eval time
//!   and cache hit/miss, plus search/render host spans.
//!
//! [`validate`] is the CI gate: it checks required keys *and* the
//! accounting invariants (refusals sum to refused leaps, cap sources
//! sum to taken leaps, per-domain stepped+leapt = total), so a report
//! that parses but lies fails the smoke step.

use super::json::{escape, parse, Value};
use super::{CapSource, LeapBlock, PointTiming, RunProfile};
use std::fmt::Write as _;

fn fsec(s: f64) -> String {
    // Finite by construction (Instant differences); fixed precision
    // keeps the output stable and valid JSON.
    format!("{s:.6}")
}

/// Render a run profile as `medusa_run_v1` JSON.
pub fn run_profile_json(p: &RunProfile, scenario: &str, design: &str, backend: &str) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"profile\": \"medusa_run_v1\",");
    let _ = writeln!(out, "  \"scenario\": \"{}\",", escape(scenario));
    let _ = writeln!(out, "  \"design\": \"{}\",", escape(design));
    let _ = writeln!(out, "  \"backend\": \"{}\",", escape(backend));

    let _ = writeln!(out, "  \"edges\": {{");
    let _ = writeln!(out, "    \"domains\": [");
    for (i, d) in p.sys.domains.iter().enumerate() {
        let comma = if i + 1 < p.sys.domains.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "      {{\"domain\": \"{}\", \"stepped\": {}, \"leapt\": {}, \"total\": {}}}{comma}",
            escape(d.name),
            d.stepped,
            d.leapt,
            d.total()
        );
    }
    let _ = writeln!(out, "    ]");
    let _ = writeln!(out, "  }},");

    let leap = &p.sys.leap;
    let _ = writeln!(out, "  \"leap\": {{");
    let _ = writeln!(out, "    \"attempts\": {},", leap.attempts);
    let _ = writeln!(out, "    \"taken\": {},", leap.taken);
    let _ = writeln!(out, "    \"refused\": {},", leap.refused());
    let _ = writeln!(out, "    \"refusals\": {{");
    for (i, b) in LeapBlock::ALL.iter().enumerate() {
        let comma = if i + 1 < LeapBlock::ALL.len() { "," } else { "" };
        let _ = writeln!(out, "      \"{}\": {}{comma}", b.name(), leap.refusals[i]);
    }
    let _ = writeln!(out, "    }},");
    let _ = writeln!(out, "    \"caps\": {{");
    for (i, c) in CapSource::ALL.iter().enumerate() {
        let comma = if i + 1 < CapSource::ALL.len() { "," } else { "" };
        let _ = writeln!(out, "      \"{}\": {}{comma}", c.name(), leap.caps[i]);
    }
    let _ = writeln!(out, "    }}");
    let _ = writeln!(out, "  }},");

    let _ = writeln!(out, "  \"utilization\": {{");
    let _ = writeln!(out, "    \"window\": {},", p.sys.window);
    let _ = writeln!(out, "    \"port_groups\": {},", p.sys.groups);
    let _ = writeln!(out, "    \"windows\": [");
    for (i, w) in p.sys.utilization.iter().enumerate() {
        let comma = if i + 1 < p.sys.utilization.len() { "," } else { "" };
        let busy: Vec<String> = w.busy.iter().map(|b| b.to_string()).collect();
        let _ = writeln!(
            out,
            "      {{\"start\": {}, \"edges\": {}, \"busy\": [{}], \"cmd_occ\": {}, \
             \"rd_line_occ\": {}, \"wr_data_occ\": {}, \"trunk_occ\": {}}}{comma}",
            w.start,
            w.edges,
            busy.join(", "),
            w.cmd_occ,
            w.rd_line_occ,
            w.wr_data_occ,
            w.trunk_occ
        );
    }
    let _ = writeln!(out, "    ],");
    let depth: Vec<String> =
        p.sys.serving_depth.iter().map(|(c, d)| format!("[{c}, {d}]")).collect();
    let _ = writeln!(out, "    \"serving_queue_depth\": [{}],", depth.join(", "));
    let shed: Vec<String> =
        p.sys.serving_shed.iter().map(|(c, s)| format!("[{c}, {s}]")).collect();
    let _ = writeln!(out, "    \"serving_requests_shed\": [{}]", shed.join(", "));
    let _ = writeln!(out, "  }},");

    let _ = writeln!(out, "  \"host\": {{");
    let _ = writeln!(out, "    \"spans\": [");
    for (i, (phase, secs)) in p.host.iter().enumerate() {
        let comma = if i + 1 < p.host.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "      {{\"phase\": \"{}\", \"seconds\": {}}}{comma}",
            escape(phase),
            fsec(*secs)
        );
    }
    let _ = writeln!(out, "    ]");
    let _ = writeln!(out, "  }}");
    let _ = writeln!(out, "}}");
    out
}

/// Render an explorer campaign profile as `medusa_explore_v1` JSON.
/// `points` pairs each [`PointTiming`] with its design spec label.
pub fn explore_profile_json(
    strategy: &str,
    probe: &str,
    host: &[(&'static str, f64)],
    points: &[(String, PointTiming)],
) -> String {
    let computed = points.iter().filter(|(_, t)| !t.cache_hit).count();
    let hits = points.len() - computed;
    let mut out = String::new();
    let _ = writeln!(out, "{{");
    let _ = writeln!(out, "  \"profile\": \"medusa_explore_v1\",");
    let _ = writeln!(out, "  \"strategy\": \"{}\",", escape(strategy));
    let _ = writeln!(out, "  \"probe\": \"{}\",", escape(probe));
    let _ = writeln!(out, "  \"points_evaluated\": {},", points.len());
    let _ = writeln!(out, "  \"points_computed\": {computed},");
    let _ = writeln!(out, "  \"cache_hits\": {hits},");
    let _ = writeln!(out, "  \"host\": {{");
    let _ = writeln!(out, "    \"spans\": [");
    for (i, (phase, secs)) in host.iter().enumerate() {
        let comma = if i + 1 < host.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "      {{\"phase\": \"{}\", \"seconds\": {}}}{comma}",
            escape(phase),
            fsec(*secs)
        );
    }
    let _ = writeln!(out, "    ]");
    let _ = writeln!(out, "  }},");
    let _ = writeln!(out, "  \"points\": [");
    for (i, (design, t)) in points.iter().enumerate() {
        let comma = if i + 1 < points.len() { "," } else { "" };
        let _ = writeln!(
            out,
            "    {{\"index\": {}, \"design\": \"{}\", \"cache_hit\": {}, \"eval_s\": {}}}{comma}",
            t.index,
            escape(design),
            t.cache_hit,
            fsec(t.eval_s)
        );
    }
    let _ = writeln!(out, "  ]");
    let _ = writeln!(out, "}}");
    out
}

fn req<'a>(v: &'a Value, key: &str, ctx: &str) -> Result<&'a Value, String> {
    v.get(key).ok_or_else(|| format!("missing required key \"{key}\" in {ctx}"))
}

fn req_u64(v: &Value, key: &str, ctx: &str) -> Result<u64, String> {
    req(v, key, ctx)?
        .as_u64()
        .ok_or_else(|| format!("\"{key}\" in {ctx} must be a non-negative integer"))
}

/// Validate a parsed report against its schema. Returns the schema
/// name on success. Checks structure *and* the accounting invariants
/// the conformance suite promises, so this doubles as the CI gate.
pub fn validate(v: &Value) -> Result<&'static str, String> {
    let kind = req(v, "profile", "report")?
        .as_str()
        .ok_or_else(|| "\"profile\" must be a string".to_string())?;
    match kind {
        "medusa_run_v1" => {
            validate_run(v)?;
            Ok("medusa_run_v1")
        }
        "medusa_explore_v1" => {
            validate_explore(v)?;
            Ok("medusa_explore_v1")
        }
        other => Err(format!("unknown profile schema \"{other}\"")),
    }
}

fn validate_run(v: &Value) -> Result<(), String> {
    for key in ["scenario", "design", "backend"] {
        req(v, key, "run report")?
            .as_str()
            .ok_or_else(|| format!("\"{key}\" must be a string"))?;
    }
    let domains = req(req(v, "edges", "run report")?, "domains", "edges")?
        .as_arr()
        .ok_or_else(|| "\"edges.domains\" must be an array".to_string())?;
    if domains.is_empty() {
        return Err("\"edges.domains\" must be non-empty".into());
    }
    for d in domains {
        let name = req(d, "domain", "edges.domains[]")?
            .as_str()
            .ok_or_else(|| "\"domain\" must be a string".to_string())?;
        let stepped = req_u64(d, "stepped", "edges.domains[]")?;
        let leapt = req_u64(d, "leapt", "edges.domains[]")?;
        let total = req_u64(d, "total", "edges.domains[]")?;
        if stepped + leapt != total {
            return Err(format!(
                "domain \"{name}\": stepped ({stepped}) + leapt ({leapt}) != total ({total})"
            ));
        }
    }
    let leap = req(v, "leap", "run report")?;
    let attempts = req_u64(leap, "attempts", "leap")?;
    let taken = req_u64(leap, "taken", "leap")?;
    let refused = req_u64(leap, "refused", "leap")?;
    if attempts != taken + refused {
        return Err(format!("leap: attempts ({attempts}) != taken ({taken}) + refused ({refused})"));
    }
    let refusals = req(leap, "refusals", "leap")?
        .entries()
        .ok_or_else(|| "\"leap.refusals\" must be an object".to_string())?;
    let rsum: u64 = refusals
        .iter()
        .map(|(k, n)| n.as_u64().ok_or_else(|| format!("refusal \"{k}\" must be an integer")))
        .collect::<Result<Vec<_>, _>>()?
        .iter()
        .sum();
    if rsum != refused {
        return Err(format!("leap refusal reasons sum to {rsum}, expected refused = {refused}"));
    }
    let caps = req(leap, "caps", "leap")?
        .entries()
        .ok_or_else(|| "\"leap.caps\" must be an object".to_string())?;
    let csum: u64 = caps
        .iter()
        .map(|(k, n)| n.as_u64().ok_or_else(|| format!("cap \"{k}\" must be an integer")))
        .collect::<Result<Vec<_>, _>>()?
        .iter()
        .sum();
    if csum != taken {
        return Err(format!("leap cap sources sum to {csum}, expected taken = {taken}"));
    }
    let util = req(v, "utilization", "run report")?;
    req_u64(util, "window", "utilization")?;
    req_u64(util, "port_groups", "utilization")?;
    let windows = req(util, "windows", "utilization")?
        .as_arr()
        .ok_or_else(|| "\"utilization.windows\" must be an array".to_string())?;
    for w in windows {
        req_u64(w, "start", "utilization.windows[]")?;
        req_u64(w, "edges", "utilization.windows[]")?;
        req(w, "busy", "utilization.windows[]")?
            .as_arr()
            .ok_or_else(|| "\"busy\" must be an array".to_string())?;
    }
    req(util, "serving_queue_depth", "utilization")?
        .as_arr()
        .ok_or_else(|| "\"serving_queue_depth\" must be an array".to_string())?;
    req(util, "serving_requests_shed", "utilization")?
        .as_arr()
        .ok_or_else(|| "\"serving_requests_shed\" must be an array".to_string())?;
    validate_spans(v)
}

fn validate_explore(v: &Value) -> Result<(), String> {
    let evaluated = req_u64(v, "points_evaluated", "explore report")?;
    let computed = req_u64(v, "points_computed", "explore report")?;
    let hits = req_u64(v, "cache_hits", "explore report")?;
    if computed + hits != evaluated {
        return Err(format!(
            "points_computed ({computed}) + cache_hits ({hits}) != points_evaluated ({evaluated})"
        ));
    }
    let points = req(v, "points", "explore report")?
        .as_arr()
        .ok_or_else(|| "\"points\" must be an array".to_string())?;
    if points.len() as u64 != evaluated {
        return Err(format!(
            "points array has {} entries, expected points_evaluated = {evaluated}",
            points.len()
        ));
    }
    for p in points {
        req_u64(p, "index", "points[]")?;
        req(p, "cache_hit", "points[]")?
            .as_bool()
            .ok_or_else(|| "\"cache_hit\" must be a bool".to_string())?;
        req(p, "eval_s", "points[]")?
            .as_f64()
            .ok_or_else(|| "\"eval_s\" must be a number".to_string())?;
    }
    validate_spans(v)
}

fn validate_spans(v: &Value) -> Result<(), String> {
    let spans = req(req(v, "host", "report")?, "spans", "host")?
        .as_arr()
        .ok_or_else(|| "\"host.spans\" must be an array".to_string())?;
    for s in spans {
        req(s, "phase", "host.spans[]")?
            .as_str()
            .ok_or_else(|| "\"phase\" must be a string".to_string())?;
        let secs = req(s, "seconds", "host.spans[]")?
            .as_f64()
            .ok_or_else(|| "\"seconds\" must be a number".to_string())?;
        if !secs.is_finite() || secs < 0.0 {
            return Err("host span seconds must be finite and non-negative".into());
        }
    }
    Ok(())
}

/// Parse, validate, and pretty-print a report for `medusa profile`.
pub fn pretty_print(text: &str) -> Result<String, String> {
    let v = parse(text)?;
    match validate(&v)? {
        "medusa_run_v1" => Ok(pretty_run(&v)),
        _ => Ok(pretty_explore(&v)),
    }
}

fn pct(part: u64, whole: u64) -> String {
    if whole == 0 {
        "-".into()
    } else {
        format!("{:.1}%", 100.0 * part as f64 / whole as f64)
    }
}

/// Non-zero members of an object, rendered `name count · name count`.
fn breakdown(members: &[(String, Value)]) -> String {
    let parts: Vec<String> = members
        .iter()
        .filter(|(_, n)| n.as_u64().unwrap_or(0) > 0)
        .map(|(k, n)| format!("{k} {}", n.as_u64().unwrap_or(0)))
        .collect();
    if parts.is_empty() {
        "none".into()
    } else {
        parts.join(" · ")
    }
}

const SPARK: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];

/// Busy-fraction sparkline over the utilization windows, downsampled
/// to at most 64 buckets.
fn sparkline(windows: &[Value], groups: u64) -> String {
    if windows.is_empty() || groups == 0 {
        return String::new();
    }
    let fracs: Vec<f64> = windows
        .iter()
        .map(|w| {
            let edges = w.get("edges").and_then(Value::as_u64).unwrap_or(0);
            let busy: u64 = w
                .get("busy")
                .and_then(Value::as_arr)
                .map(|b| b.iter().filter_map(Value::as_u64).sum())
                .unwrap_or(0);
            if edges == 0 {
                0.0
            } else {
                busy as f64 / (edges * groups) as f64
            }
        })
        .collect();
    let bucket = fracs.len().div_ceil(64);
    fracs
        .chunks(bucket)
        .map(|c| {
            let mean = c.iter().sum::<f64>() / c.len() as f64;
            SPARK[((mean * 7.0).round() as usize).min(7)]
        })
        .collect()
}

fn spans_line(v: &Value) -> String {
    let spans = v.get("host").and_then(|h| h.get("spans")).and_then(Value::as_arr).unwrap_or(&[]);
    spans
        .iter()
        .map(|s| {
            format!(
                "{} {:.3}s",
                s.get("phase").and_then(Value::as_str).unwrap_or("?"),
                s.get("seconds").and_then(Value::as_f64).unwrap_or(0.0)
            )
        })
        .collect::<Vec<_>>()
        .join(" · ")
}

fn pretty_run(v: &Value) -> String {
    let gs = |k: &str| v.get(k).and_then(Value::as_str).unwrap_or("?").to_string();
    let mut out = String::new();
    let _ = writeln!(
        out,
        "medusa profile — run {} · design {} · backend {}\n",
        gs("scenario"),
        gs("design"),
        gs("backend")
    );
    let _ = writeln!(out, "cycle attribution");
    let domains =
        v.get("edges").and_then(|e| e.get("domains")).and_then(Value::as_arr).unwrap_or(&[]);
    for d in domains {
        let name = d.get("domain").and_then(Value::as_str).unwrap_or("?");
        let stepped = d.get("stepped").and_then(Value::as_u64).unwrap_or(0);
        let leapt = d.get("leapt").and_then(Value::as_u64).unwrap_or(0);
        let total = stepped + leapt;
        let _ = writeln!(
            out,
            "  {name:<8} total {total:>12}   stepped {stepped:>12} ({})   leapt {leapt:>12} ({})",
            pct(stepped, total),
            pct(leapt, total)
        );
    }
    if let Some(leap) = v.get("leap") {
        let attempts = leap.get("attempts").and_then(Value::as_u64).unwrap_or(0);
        let taken = leap.get("taken").and_then(Value::as_u64).unwrap_or(0);
        let refused = leap.get("refused").and_then(Value::as_u64).unwrap_or(0);
        let _ = writeln!(
            out,
            "\nleap telemetry   attempts {attempts} · taken {taken} ({}) · refused {refused}",
            pct(taken, attempts)
        );
        if let Some(r) = leap.get("refusals").and_then(Value::entries) {
            let _ = writeln!(out, "  refusals   {}", breakdown(r));
        }
        if let Some(c) = leap.get("caps").and_then(Value::entries) {
            let _ = writeln!(out, "  caps       {}", breakdown(c));
        }
    }
    if let Some(util) = v.get("utilization") {
        let window = util.get("window").and_then(Value::as_u64).unwrap_or(0);
        let groups = util.get("port_groups").and_then(Value::as_u64).unwrap_or(0);
        let windows = util.get("windows").and_then(Value::as_arr).unwrap_or(&[]);
        let _ = writeln!(
            out,
            "\nutilization   window {window} cycles · {groups} port group(s) · {} window(s)",
            windows.len()
        );
        let spark = sparkline(windows, groups);
        if !spark.is_empty() {
            let _ = writeln!(out, "  busy fraction  {spark}");
        }
        let depth = util.get("serving_queue_depth").and_then(Value::as_arr).unwrap_or(&[]);
        if !depth.is_empty() {
            let peak = depth
                .iter()
                .filter_map(|p| p.as_arr().and_then(|p| p.get(1)).and_then(Value::as_u64))
                .max()
                .unwrap_or(0);
            let _ = writeln!(out, "  serving queue  {} change sample(s) · peak depth {peak}", depth.len());
        }
        let shed = util.get("serving_requests_shed").and_then(Value::as_arr).unwrap_or(&[]);
        if !shed.is_empty() {
            let total = shed
                .iter()
                .filter_map(|p| p.as_arr().and_then(|p| p.get(1)).and_then(Value::as_u64))
                .max()
                .unwrap_or(0);
            let _ = writeln!(out, "  serving shed   {} change sample(s) · {total} shed in total", shed.len());
        }
    }
    let _ = writeln!(out, "\nhost time   {}", spans_line(v));
    out
}

fn pretty_explore(v: &Value) -> String {
    let gs = |k: &str| v.get(k).and_then(Value::as_str).unwrap_or("?").to_string();
    let gu = |k: &str| v.get(k).and_then(Value::as_u64).unwrap_or(0);
    let mut out = String::new();
    let _ = writeln!(
        out,
        "medusa profile — explore campaign · strategy {} · probe {}\n",
        gs("strategy"),
        gs("probe")
    );
    let _ = writeln!(
        out,
        "points   {} evaluated · {} computed · {} cache hit(s)",
        gu("points_evaluated"),
        gu("points_computed"),
        gu("cache_hits")
    );
    let points = v.get("points").and_then(Value::as_arr).unwrap_or(&[]);
    let evals: Vec<f64> = points
        .iter()
        .filter(|p| p.get("cache_hit").and_then(Value::as_bool) == Some(false))
        .filter_map(|p| p.get("eval_s").and_then(Value::as_f64))
        .collect();
    if !evals.is_empty() {
        let total: f64 = evals.iter().sum();
        let max = evals.iter().cloned().fold(0.0f64, f64::max);
        let _ = writeln!(
            out,
            "eval     total {total:.3}s · mean {:.4}s · max {max:.4}s over {} computed point(s)",
            total / evals.len() as f64,
            evals.len()
        );
    }
    let _ = writeln!(out, "host     {}", spans_line(v));
    out
}

#[cfg(test)]
mod tests {
    use super::super::{DomainEdges, SysProfile, WindowSample};
    use super::*;

    fn sample_profile() -> RunProfile {
        let mut leap = super::super::LeapTelemetry::default();
        leap.attempts = 10;
        leap.taken = 7;
        leap.refusals[LeapBlock::ChannelOccupied as usize] = 2;
        leap.refusals[LeapBlock::LpLoadDrain as usize] = 1;
        leap.caps[CapSource::LpCompute as usize] = 6;
        leap.caps[CapSource::TenantStart as usize] = 1;
        RunProfile {
            sys: SysProfile {
                domains: vec![
                    DomainEdges { name: "fabric", stepped: 100, leapt: 900 },
                    DomainEdges { name: "mem", stepped: 80, leapt: 720 },
                ],
                leap,
                window: 64,
                groups: 2,
                utilization: vec![WindowSample {
                    start: 0,
                    edges: 64,
                    busy: vec![64, 10],
                    cmd_occ: 5,
                    rd_line_occ: 6,
                    wr_data_occ: 7,
                    trunk_occ: 0,
                }],
                serving_depth: vec![(0, 0), (10, 3)],
                serving_shed: vec![(12, 1)],
            },
            host: vec![("build", 0.001), ("drive", 0.5)],
        }
    }

    #[test]
    fn run_report_parses_and_validates() {
        let text = run_profile_json(&sample_profile(), "zoo-x", "medusa", "elided+leap");
        let v = parse(&text).unwrap();
        assert_eq!(validate(&v).unwrap(), "medusa_run_v1");
        assert_eq!(v.get("scenario").unwrap().as_str(), Some("zoo-x"));
        let leap = v.get("leap").unwrap();
        assert_eq!(leap.get("refused").unwrap().as_u64(), Some(3));
    }

    #[test]
    fn validator_rejects_broken_accounting() {
        let mut p = sample_profile();
        p.sys.leap.refusals[LeapBlock::ChannelOccupied as usize] = 5; // sum now 6 != refused 3
        let text = run_profile_json(&p, "s", "d", "b");
        let v = parse(&text).unwrap();
        let err = validate(&v).unwrap_err();
        assert!(err.contains("refusal"), "unexpected error: {err}");
    }

    #[test]
    fn validator_rejects_missing_keys() {
        let v = parse(r#"{"profile": "medusa_run_v1"}"#).unwrap();
        assert!(validate(&v).is_err());
        let v = parse(r#"{"profile": "unknown_v9"}"#).unwrap();
        assert!(validate(&v).is_err());
        let v = parse(r#"{"nope": 1}"#).unwrap();
        assert!(validate(&v).is_err());
    }

    #[test]
    fn explore_report_parses_and_validates() {
        let points = vec![
            ("medusa".to_string(), PointTiming { index: 0, cache_hit: false, eval_s: 0.25 }),
            ("baseline".to_string(), PointTiming { index: 1, cache_hit: true, eval_s: 0.0 }),
        ];
        let text =
            explore_profile_json("grid", "single-layer", &[("search", 1.0), ("render", 0.01)], &points);
        let v = parse(&text).unwrap();
        assert_eq!(validate(&v).unwrap(), "medusa_explore_v1");
        assert_eq!(v.get("points_computed").unwrap().as_u64(), Some(1));
        assert_eq!(v.get("cache_hits").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn pretty_print_renders_both_schemas() {
        let run_text = run_profile_json(&sample_profile(), "zoo-x", "medusa", "full+stepwise");
        let rendered = pretty_print(&run_text).unwrap();
        assert!(rendered.contains("cycle attribution"));
        assert!(rendered.contains("fabric"));
        assert!(rendered.contains("leap telemetry"));
        assert!(rendered.contains("host time"));
        let points =
            vec![("medusa".to_string(), PointTiming { index: 0, cache_hit: false, eval_s: 0.1 })];
        let ex_text = explore_profile_json("random", "probe", &[("search", 0.5)], &points);
        let rendered = pretty_print(&ex_text).unwrap();
        assert!(rendered.contains("explore campaign"));
        assert!(rendered.contains("1 computed"));
    }

    #[test]
    fn pretty_print_rejects_invalid_input() {
        assert!(pretty_print("not json").is_err());
        assert!(pretty_print(r#"{"profile": "medusa_run_v1"}"#).is_err());
    }
}
