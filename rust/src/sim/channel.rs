//! Registered valid/ready stream channel.
//!
//! Semantics (matching a synchronous RTL FIFO with registered flags):
//!
//! * `pop`/`peek` only observe items committed on *previous* cycles, so a
//!   push and a pop in the same cycle never race regardless of component
//!   tick order — an item pushed on cycle `c` is poppable on `c+1` at the
//!   earliest (one register stage of latency, as real inter-module FIFOs
//!   have).
//! * `can_push` compares start-of-cycle occupancy plus this cycle's
//!   pushes against capacity: space freed by a pop on cycle `c` becomes
//!   visible to the producer on `c+1` (registered `full`), which is the
//!   conservative behaviour real designs use to close timing.
//!
//! The netlist owner must call [`Channel::commit`] exactly once per cycle
//! after ticking all components.

use std::collections::VecDeque;

#[derive(Debug)]
pub struct Channel<T> {
    name: &'static str,
    cap: usize,
    /// Items visible to the consumer (committed on prior cycles).
    q: VecDeque<T>,
    /// Items pushed this cycle; moved into `q` at commit.
    staged: Vec<T>,
    /// Occupancy at the start of the current cycle (set by commit).
    start_len: usize,
    /// Lifetime counters.
    pushed_total: u64,
    popped_total: u64,
}

impl<T> Channel<T> {
    pub fn new(name: &'static str, cap: usize) -> Self {
        assert!(cap >= 1, "channel {name} needs capacity >= 1");
        Channel {
            name,
            cap,
            q: VecDeque::with_capacity(cap),
            staged: Vec::new(),
            start_len: 0,
            pushed_total: 0,
            popped_total: 0,
        }
    }

    pub fn name(&self) -> &'static str {
        self.name
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    /// Producer-side ready: may `push` be called this cycle?
    pub fn can_push(&self) -> bool {
        self.start_len + self.staged.len() < self.cap
    }

    /// Push an item; visible to the consumer from the next cycle.
    /// Panics if `can_push()` is false — producers must check ready,
    /// exactly as RTL must respect backpressure.
    pub fn push(&mut self, v: T) {
        assert!(self.can_push(), "push into full channel {}", self.name);
        self.staged.push(v);
        self.pushed_total += 1;
    }

    /// Consumer-side valid: is there a committed item to pop?
    pub fn can_pop(&self) -> bool {
        !self.q.is_empty()
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }

    pub fn pop(&mut self) -> Option<T> {
        let v = self.q.pop_front();
        if v.is_some() {
            self.popped_total += 1;
        }
        v
    }

    /// Number of items currently visible to the consumer.
    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    /// Total occupancy including uncommitted pushes (for assertions and
    /// capacity accounting, not for component logic).
    pub fn occupancy(&self) -> usize {
        self.q.len() + self.staged.len()
    }

    pub fn pushed_total(&self) -> u64 {
        self.pushed_total
    }

    pub fn popped_total(&self) -> u64 {
        self.popped_total
    }

    /// End-of-cycle commit: make this cycle's pushes visible and latch
    /// the occupancy that next cycle's `can_push` checks against.
    pub fn commit(&mut self) {
        self.q.extend(self.staged.drain(..));
        self.start_len = self.q.len();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_not_visible_until_commit() {
        let mut ch = Channel::new("t", 4);
        ch.push(1u32);
        assert!(!ch.can_pop(), "push must not be visible same cycle");
        ch.commit();
        assert!(ch.can_pop());
        assert_eq!(ch.pop(), Some(1));
    }

    #[test]
    fn registered_ready_conservative() {
        let mut ch = Channel::new("t", 1);
        ch.push(1u32);
        ch.commit();
        // Cycle 2: consumer pops, but producer still sees full (registered
        // full flag) because start-of-cycle occupancy was 1.
        assert_eq!(ch.pop(), Some(1));
        assert!(!ch.can_push());
        ch.commit();
        // Cycle 3: space is now visible.
        assert!(ch.can_push());
        ch.push(2);
        ch.commit();
        assert_eq!(ch.pop(), Some(2));
    }

    #[test]
    fn capacity_respected_within_cycle() {
        let mut ch = Channel::new("t", 2);
        ch.push(1u32);
        ch.push(2);
        assert!(!ch.can_push());
        ch.commit();
        assert_eq!(ch.len(), 2);
    }

    #[test]
    #[should_panic(expected = "push into full channel")]
    fn push_when_full_panics() {
        let mut ch = Channel::new("t", 1);
        ch.push(1u32);
        ch.push(2);
    }

    #[test]
    fn fifo_order_preserved() {
        let mut ch = Channel::new("t", 8);
        for i in 0..5u32 {
            ch.push(i);
        }
        ch.commit();
        for i in 0..5u32 {
            assert_eq!(ch.pop(), Some(i));
        }
    }

    #[test]
    fn counters_track_traffic() {
        let mut ch = Channel::new("t", 4);
        for i in 0..3u32 {
            ch.push(i);
        }
        ch.commit();
        ch.pop();
        assert_eq!(ch.pushed_total(), 3);
        assert_eq!(ch.popped_total(), 1);
        assert_eq!(ch.occupancy(), 2);
    }
}
