//! Bounded event trace for debugging cycle-level behaviour.
//!
//! Off by default (zero cost beyond a branch); when enabled it records
//! `(cycle, component, event)` tuples into a ring buffer and can dump
//! them as text or a minimal VCD-like listing. Used heavily while
//! bringing up the transposition control logic.

use std::collections::VecDeque;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    pub cycle: u64,
    pub component: &'static str,
    pub detail: String,
}

#[derive(Debug)]
pub struct Trace {
    enabled: bool,
    cap: usize,
    events: VecDeque<Event>,
    dropped: u64,
}

impl Trace {
    pub fn disabled() -> Self {
        Trace { enabled: false, cap: 0, events: VecDeque::new(), dropped: 0 }
    }

    pub fn bounded(cap: usize) -> Self {
        Trace { enabled: true, cap, events: VecDeque::with_capacity(cap.min(4096)), dropped: 0 }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn record(&mut self, cycle: u64, component: &'static str, detail: impl FnOnce() -> String) {
        if !self.enabled {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(Event { cycle, component, detail: detail() });
    }

    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn dump(&self) -> String {
        let mut out = String::new();
        if self.dropped > 0 {
            out.push_str(&format!("... {} earlier events dropped ...\n", self.dropped));
        }
        for e in &self.events {
            out.push_str(&format!("@{:>8} {:<24} {}\n", e.cycle, e.component, e.detail));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(1, "x", || "should not materialize".to_string());
        assert!(t.is_empty());
    }

    #[test]
    fn bounded_trace_drops_oldest() {
        let mut t = Trace::bounded(2);
        t.record(1, "a", || "e1".into());
        t.record(2, "b", || "e2".into());
        t.record(3, "c", || "e3".into());
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 1);
        let evs: Vec<_> = t.events().map(|e| e.cycle).collect();
        assert_eq!(evs, vec![2, 3]);
        assert!(t.dump().contains("earlier events dropped"));
    }
}
