//! Event traces: a bounded debug ring buffer, and the **canonical
//! scenario trace** used for deterministic capture/replay.
//!
//! # Debug trace
//!
//! [`Trace`] is off by default (zero cost beyond a branch); when enabled
//! it records `(cycle, component, event)` tuples into a ring buffer and
//! can dump them as text. Used heavily while bringing up the
//! transposition control logic.
//!
//! # Canonical scenario trace format (v1)
//!
//! A [`ScenarioTrace`] is the compact, replayable record of one workload
//! scenario run: everything the interconnect saw, nothing the workload
//! layer computed. Replaying a trace re-drives the same port-level
//! transfer schedule through a freshly built system — skipping network
//! construction, weight generation, and golden math entirely — and must
//! reproduce the captured cycle counts and statistics bit-for-bit.
//! Capture is seeded ([`util::Prng`]) and single-threaded inside one
//! system, so traces are bit-identical regardless of `MEDUSA_THREADS`.
//!
//! Traces serialize to the same TOML subset `config.rs` parses
//! (`medusa replay <file>` and the golden-trace regression tests read
//! them back). Layout:
//!
//! ```text
//! [header]            # full system configuration of the run
//! version = 1
//! scenario = "..."    # scenario name (provenance only)
//! design   = "medusa" # a Design spec: baseline | medusa | axis | hybrid:r<R>:s<S>:g<G>
//! w_line / w_acc / read_ports / write_ports / max_burst = ...
//! dotprod_units / rotator_stages = ...
//! mem_mhz / fabric_mhz = ...     # fabric_mhz is the *resolved* clock
//! ddr3_timing = true|false
//! cmd_depth / rd_line_depth / wr_data_depth = ...
//! seed = ...
//! tenants = N
//!
//! [tenant.T]          # port group + phase offset of tenant T
//! read_base / read_ports / write_base / write_ports = ...
//! start_cycle = ...   # tenant idles until this fabric cycle
//!
//! [step.I]            # one layer pass; steps are grouped by tenant,
//!                     # each tenant's steps in its execution order
//!                     # (replay re-queues them per the `tenant` field,
//!                     # so the global index is NOT a timeline)
//! tenant = T
//! label = "conv1"     # layer name (reporting only)
//! macs = ...          # compute-stall model input
//! write_seed = ...    # seeds synthesized write data on replay
//! reads.P  = "base:lines,base:lines,..."   # local port P's runs
//! writes.P = "base:lines,..."              # ports with no runs omitted
//!
//! [expect]            # cross-check block
//! steps = I_max+1
//! timing_recorded = true|false
//! [expect.exact]      # data-movement counters: always asserted
//! lp.words_loaded = ...
//! [expect.timing]     # cycle/stall numbers: asserted when recorded
//! fabric_cycles / mem_cycles / now_ps = ...
//! wait.tN.read.P / wait.tN.write.P = ...   # per-port wait cycles
//! <every other touched counter> = ...
//! ```
//!
//! The `exact` block holds counters fully determined by the schedule
//! (words/lines/bursts moved); the `timing` block holds everything that
//! depends on cycle-level interleaving. Checked-in golden traces may
//! ship with `timing_recorded = false` (movement locked, timing not yet
//! measured); regenerating them with `MEDUSA_REGEN_GOLDEN=1` or
//! `medusa run --capture` records both.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::VecDeque;
use std::path::Path;

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Event {
    pub cycle: u64,
    pub component: &'static str,
    pub detail: String,
}

#[derive(Debug)]
pub struct Trace {
    enabled: bool,
    cap: usize,
    events: VecDeque<Event>,
    dropped: u64,
}

impl Trace {
    pub fn disabled() -> Self {
        Trace { enabled: false, cap: 0, events: VecDeque::new(), dropped: 0 }
    }

    pub fn bounded(cap: usize) -> Self {
        Trace { enabled: true, cap, events: VecDeque::with_capacity(cap.min(4096)), dropped: 0 }
    }

    #[inline]
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    #[inline]
    pub fn record(&mut self, cycle: u64, component: &'static str, detail: impl FnOnce() -> String) {
        if !self.enabled {
            return;
        }
        if self.events.len() == self.cap {
            self.events.pop_front();
            self.dropped += 1;
        }
        self.events.push_back(Event { cycle, component, detail: detail() });
    }

    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.events.iter()
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn dump(&self) -> String {
        let mut out = String::new();
        if self.dropped > 0 {
            out.push_str(&format!("... {} earlier events dropped ...\n", self.dropped));
        }
        for e in &self.events {
            out.push_str(&format!("@{:>8} {:<24} {}\n", e.cycle, e.component, e.detail));
        }
        out
    }
}

/// Counters whose value is fully determined by the transfer schedule
/// (how much data moved), independent of cycle-level interleaving.
/// These go into a trace's `exact` expect block.
pub const MOVEMENT_COUNTERS: &[&str] = &[
    "arbiter.reads_issued",
    "arbiter.write_lines_streamed",
    "arbiter.writes_issued",
    "axis_read.lines_through_slices",
    "axis_write.lines_through_slices",
    "baseline_read.lines_into_converter",
    "baseline_write.lines_into_fifo",
    "dram.read_bursts",
    "dram.read_lines",
    "dram.write_bursts",
    "dram.write_lines",
    "hier_read.lines_bypassed",
    "hier_read.lines_over_trunk",
    "hier_write.lines_bypassed",
    "hier_write.lines_over_trunk",
    "hybrid_read.lines_transposed",
    "hybrid_read.words_rotated",
    "hybrid_write.lines_transposed",
    "hybrid_write.words_rotated",
    "lp.read_bursts_submitted",
    "lp.words_drained",
    "lp.words_loaded",
    "lp.write_bursts_submitted",
    "medusa_read.lines_transposed",
    "medusa_read.words_rotated",
    "medusa_write.lines_transposed",
    "medusa_write.words_rotated",
    "sys.read_lines_into_fabric",
];

/// One tenant's port group and phase offset, as recorded in a trace.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TraceTenant {
    pub read_base: usize,
    pub read_ports: usize,
    pub write_base: usize,
    pub write_ports: usize,
    /// Fabric cycle before which the tenant stays idle.
    pub start_cycle: u64,
}

/// The system configuration a trace was captured under (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct TraceHeader {
    pub scenario: String,
    pub design: String,
    pub w_line: usize,
    pub w_acc: usize,
    pub read_ports: usize,
    pub write_ports: usize,
    pub max_burst: usize,
    pub dotprod_units: usize,
    pub rotator_stages: usize,
    pub mem_mhz: f64,
    /// The *resolved* fabric clock of the captured run (pinned on
    /// replay so the P&R model cannot drift the comparison).
    pub fabric_mhz: f64,
    pub ddr3_timing: bool,
    pub cmd_depth: usize,
    pub rd_line_depth: usize,
    pub wr_data_depth: usize,
    pub seed: u64,
    /// The fault-injection campaign of the captured run (PR 6). The
    /// whole schedule derives deterministically from these few knobs,
    /// so recording them is enough for a replay to reproduce a faulty
    /// run bit-exactly. `FaultSpec::none()` (the default) emits no
    /// header keys at all, keeping fault-free traces byte-identical to
    /// the pre-fault format.
    pub faults: crate::fault::FaultSpec,
    /// The serving workload of the captured run (PR 7). Like `faults`,
    /// the whole arrival/batching schedule re-derives deterministically
    /// from the spec, so recording it re-arms a replay bit-exactly.
    /// `ServingSpec::none()` emits no header keys, keeping serving-free
    /// traces byte-identical to the pre-serving format.
    pub serving: crate::serving::ServingSpec,
    pub tenants: Vec<TraceTenant>,
}

/// A contiguous run of lines `(base, lines)` one local port streams.
pub type TraceRun = (u64, u64);

/// One layer pass: the exact burst schedule each local port executes.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceStep {
    pub tenant: usize,
    pub label: String,
    pub macs: u64,
    /// Seeds the synthesized write data on replay (timing-neutral).
    pub write_seed: u64,
    /// Per local read port: ordered address runs.
    pub reads: Vec<Vec<TraceRun>>,
    /// Per local write port: ordered address runs.
    pub writes: Vec<Vec<TraceRun>>,
}

impl TraceStep {
    pub fn read_lines(&self) -> u64 {
        self.reads.iter().flatten().map(|&(_, l)| l).sum()
    }

    pub fn write_lines(&self) -> u64 {
        self.writes.iter().flatten().map(|&(_, l)| l).sum()
    }
}

/// Cross-check block: what a replay of the trace must reproduce.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TraceExpect {
    /// When false, only the `exact` entries are meaningful (hand-written
    /// or not-yet-regenerated golden files).
    pub timing_recorded: bool,
    pub fabric_cycles: u64,
    pub mem_cycles: u64,
    pub now_ps: u64,
    /// Data-movement counters (`name -> value`), asserted always.
    pub exact: Vec<(String, u64)>,
    /// Timing-dependent counters and per-port waits, asserted when
    /// `timing_recorded`.
    pub timing: Vec<(String, u64)>,
}

/// A complete captured scenario run. See the module docs for the format.
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioTrace {
    pub header: TraceHeader,
    pub steps: Vec<TraceStep>,
    pub expect: TraceExpect,
}

fn fmt_runs(runs: &[TraceRun]) -> String {
    runs.iter().map(|(b, l)| format!("{b}:{l}")).collect::<Vec<_>>().join(",")
}

fn parse_runs(s: &str) -> Result<Vec<TraceRun>> {
    let s = s.trim();
    if s.is_empty() {
        return Ok(Vec::new());
    }
    s.split(',')
        .map(|part| {
            let (b, l) = part
                .split_once(':')
                .ok_or_else(|| anyhow!("malformed run {part:?} (want base:lines)"))?;
            Ok((b.trim().parse::<u64>()?, l.trim().parse::<u64>()?))
        })
        .collect()
}

impl ScenarioTrace {
    /// Serialize to the canonical text format.
    pub fn to_text(&self) -> String {
        let h = &self.header;
        let mut out = String::new();
        out.push_str("# medusa canonical scenario trace (format: sim/trace.rs module docs)\n");
        out.push_str("[header]\n");
        out.push_str("version = 1\n");
        out.push_str(&format!("scenario = \"{}\"\n", h.scenario));
        out.push_str(&format!("design = \"{}\"\n", h.design));
        out.push_str(&format!("w_line = {}\n", h.w_line));
        out.push_str(&format!("w_acc = {}\n", h.w_acc));
        out.push_str(&format!("read_ports = {}\n", h.read_ports));
        out.push_str(&format!("write_ports = {}\n", h.write_ports));
        out.push_str(&format!("max_burst = {}\n", h.max_burst));
        out.push_str(&format!("dotprod_units = {}\n", h.dotprod_units));
        out.push_str(&format!("rotator_stages = {}\n", h.rotator_stages));
        out.push_str(&format!("mem_mhz = {}\n", h.mem_mhz));
        out.push_str(&format!("fabric_mhz = {}\n", h.fabric_mhz));
        out.push_str(&format!("ddr3_timing = {}\n", h.ddr3_timing));
        out.push_str(&format!("cmd_depth = {}\n", h.cmd_depth));
        out.push_str(&format!("rd_line_depth = {}\n", h.rd_line_depth));
        out.push_str(&format!("wr_data_depth = {}\n", h.wr_data_depth));
        out.push_str(&format!("seed = {}\n", h.seed));
        for (k, v) in h.faults.header_kv() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        for (k, v) in h.serving.header_kv() {
            out.push_str(&format!("{k} = {v}\n"));
        }
        out.push_str(&format!("tenants = {}\n", h.tenants.len()));
        for (t, ten) in h.tenants.iter().enumerate() {
            out.push_str(&format!("\n[tenant.{t}]\n"));
            out.push_str(&format!("read_base = {}\n", ten.read_base));
            out.push_str(&format!("read_ports = {}\n", ten.read_ports));
            out.push_str(&format!("write_base = {}\n", ten.write_base));
            out.push_str(&format!("write_ports = {}\n", ten.write_ports));
            out.push_str(&format!("start_cycle = {}\n", ten.start_cycle));
        }
        for (i, s) in self.steps.iter().enumerate() {
            out.push_str(&format!("\n[step.{i}]\n"));
            out.push_str(&format!("tenant = {}\n", s.tenant));
            out.push_str(&format!("label = \"{}\"\n", s.label));
            out.push_str(&format!("macs = {}\n", s.macs));
            out.push_str(&format!("write_seed = {}\n", s.write_seed));
            for (p, runs) in s.reads.iter().enumerate() {
                if !runs.is_empty() {
                    out.push_str(&format!("reads.{p} = \"{}\"\n", fmt_runs(runs)));
                }
            }
            for (p, runs) in s.writes.iter().enumerate() {
                if !runs.is_empty() {
                    out.push_str(&format!("writes.{p} = \"{}\"\n", fmt_runs(runs)));
                }
            }
        }
        out.push_str("\n[expect]\n");
        out.push_str(&format!("steps = {}\n", self.steps.len()));
        out.push_str(&format!("timing_recorded = {}\n", self.expect.timing_recorded));
        out.push_str("\n[expect.exact]\n");
        for (k, v) in &self.expect.exact {
            out.push_str(&format!("{k} = {v}\n"));
        }
        if self.expect.timing_recorded {
            out.push_str("\n[expect.timing]\n");
            out.push_str(&format!("fabric_cycles = {}\n", self.expect.fabric_cycles));
            out.push_str(&format!("mem_cycles = {}\n", self.expect.mem_cycles));
            out.push_str(&format!("now_ps = {}\n", self.expect.now_ps));
            for (k, v) in &self.expect.timing {
                out.push_str(&format!("{k} = {v}\n"));
            }
        }
        out
    }

    /// Parse the canonical text format.
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self> {
        use crate::config::{parse_toml_subset, Value};
        fn lookup<'a>(
            map: &'a std::collections::BTreeMap<String, Value>,
            key: &str,
        ) -> Result<&'a Value> {
            map.get(key).ok_or_else(|| anyhow!("trace missing key {key:?}"))
        }
        let map = parse_toml_subset(text)?;
        let get = |key: &str| lookup(&map, key);
        let get_usize = |key: &str| -> Result<usize> { lookup(&map, key)?.as_usize() };
        let get_u64 = |key: &str| -> Result<u64> { Ok(lookup(&map, key)?.as_usize()? as u64) };
        let version = get_usize("header.version")?;
        anyhow::ensure!(version == 1, "unsupported trace version {version}");
        let ntenants = get_usize("header.tenants")?;
        anyhow::ensure!(ntenants >= 1, "trace needs at least one tenant");
        let mut tenants = Vec::with_capacity(ntenants);
        for t in 0..ntenants {
            tenants.push(TraceTenant {
                read_base: get_usize(&format!("tenant.{t}.read_base"))?,
                read_ports: get_usize(&format!("tenant.{t}.read_ports"))?,
                write_base: get_usize(&format!("tenant.{t}.write_base"))?,
                write_ports: get_usize(&format!("tenant.{t}.write_ports"))?,
                start_cycle: get_u64(&format!("tenant.{t}.start_cycle"))?,
            });
        }
        // Fault keys are optional: fault-free traces (and every trace
        // from before PR 6) carry none and parse to `FaultSpec::none()`.
        let mut faults = crate::fault::FaultSpec::default();
        for (k, v) in map.range("header.faults.".to_string()..) {
            if !k.starts_with("header.faults.") {
                break;
            }
            let rest = &k["header.".len()..];
            faults.apply_key(rest, v).with_context(|| format!("trace key {k:?}"))?;
        }
        // Serving keys are optional too: serving-free traces (and every
        // trace from before PR 7) carry none and parse to
        // `ServingSpec::none()`.
        let mut serving = crate::serving::ServingSpec::default();
        for (k, v) in map.range("header.serving.".to_string()..) {
            if !k.starts_with("header.serving.") {
                break;
            }
            let rest = &k["header.".len()..];
            serving.apply_key(rest, v).with_context(|| format!("trace key {k:?}"))?;
        }
        let header = TraceHeader {
            scenario: get("header.scenario")?.as_str()?.to_string(),
            design: get("header.design")?.as_str()?.to_string(),
            w_line: get_usize("header.w_line")?,
            w_acc: get_usize("header.w_acc")?,
            read_ports: get_usize("header.read_ports")?,
            write_ports: get_usize("header.write_ports")?,
            max_burst: get_usize("header.max_burst")?,
            dotprod_units: get_usize("header.dotprod_units")?,
            rotator_stages: get_usize("header.rotator_stages")?,
            mem_mhz: get("header.mem_mhz")?.as_f64()?,
            fabric_mhz: get("header.fabric_mhz")?.as_f64()?,
            ddr3_timing: get("header.ddr3_timing")?.as_bool()?,
            cmd_depth: get_usize("header.cmd_depth")?,
            rd_line_depth: get_usize("header.rd_line_depth")?,
            wr_data_depth: get_usize("header.wr_data_depth")?,
            seed: get_u64("header.seed")?,
            faults,
            serving,
            tenants,
        };
        let nsteps = get_usize("expect.steps")?;
        anyhow::ensure!(
            map.get(&format!("step.{nsteps}.tenant")).is_none(),
            "trace declares {nsteps} steps in [expect] but contains [step.{nsteps}] — \
             truncated or tampered expect block"
        );
        let mut steps = Vec::with_capacity(nsteps);
        for i in 0..nsteps {
            let tenant = get_usize(&format!("step.{i}.tenant"))?;
            anyhow::ensure!(tenant < ntenants, "step {i} references unknown tenant {tenant}");
            let ten = header.tenants[tenant];
            let runs_of = |kind: &str, ports: usize| -> Result<Vec<Vec<TraceRun>>> {
                // A schedule key for a port the tenant does not own would
                // be silently unreachable — reject it like the other
                // tampering checks instead of dropping data.
                let prefix = format!("step.{i}.{kind}.");
                for (k, _) in map.range(prefix.clone()..) {
                    let Some(rest) = k.strip_prefix(prefix.as_str()) else { break };
                    let p: usize = rest
                        .parse()
                        .map_err(|_| anyhow!("malformed schedule key {k:?}"))?;
                    anyhow::ensure!(
                        p < ports,
                        "{k:?} addresses port {p}, but step {i}'s tenant owns only {ports} \
                         {kind} ports"
                    );
                }
                let mut out = Vec::with_capacity(ports);
                for p in 0..ports {
                    let key = format!("step.{i}.{kind}.{p}");
                    match map.get(&key) {
                        Some(v) => out.push(
                            parse_runs(v.as_str()?).with_context(|| format!("key {key}"))?,
                        ),
                        None => out.push(Vec::new()),
                    }
                }
                Ok(out)
            };
            steps.push(TraceStep {
                tenant,
                label: get(&format!("step.{i}.label"))?.as_str()?.to_string(),
                macs: get_u64(&format!("step.{i}.macs"))?,
                write_seed: get_u64(&format!("step.{i}.write_seed"))?,
                reads: runs_of("reads", ten.read_ports)?,
                writes: runs_of("writes", ten.write_ports)?,
            });
        }
        let timing_recorded = get("expect.timing_recorded")?.as_bool()?;
        let collect_prefixed = |prefix: &str, skip: &[&str]| -> Result<Vec<(String, u64)>> {
            let mut out = Vec::new();
            for (k, v) in map.range(prefix.to_string()..) {
                let Some(rest) = k.strip_prefix(prefix) else { break };
                if skip.contains(&rest) {
                    continue;
                }
                out.push((rest.to_string(), v.as_usize()? as u64));
            }
            Ok(out)
        };
        let exact = collect_prefixed("expect.exact.", &[])?;
        let timing =
            collect_prefixed("expect.timing.", &["fabric_cycles", "mem_cycles", "now_ps"])?;
        let (fabric_cycles, mem_cycles, now_ps) = if timing_recorded {
            (
                get_u64("expect.timing.fabric_cycles")?,
                get_u64("expect.timing.mem_cycles")?,
                get_u64("expect.timing.now_ps")?,
            )
        } else {
            (0, 0, 0)
        };
        Ok(ScenarioTrace {
            header,
            steps,
            expect: TraceExpect { timing_recorded, fabric_cycles, mem_cycles, now_ps, exact, timing },
        })
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading trace {}", path.as_ref().display()))?;
        Self::from_str(&text).with_context(|| format!("parsing trace {}", path.as_ref().display()))
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.to_text())
            .with_context(|| format!("writing trace {}", path.as_ref().display()))
    }

    /// Sanity-check internal consistency (port counts, tenant bounds,
    /// group disjointness).
    pub fn validate(&self) -> Result<()> {
        let h = &self.header;
        anyhow::ensure!(!h.tenants.is_empty(), "trace has no tenants");
        let mut read_owner = vec![usize::MAX; h.read_ports];
        let mut write_owner = vec![usize::MAX; h.write_ports];
        for (t, ten) in h.tenants.iter().enumerate() {
            anyhow::ensure!(
                ten.read_base + ten.read_ports <= h.read_ports
                    && ten.write_base + ten.write_ports <= h.write_ports,
                "tenant {t} port group exceeds geometry"
            );
            anyhow::ensure!(ten.read_ports >= 1 && ten.write_ports >= 1, "tenant {t} empty group");
            for p in ten.read_base..ten.read_base + ten.read_ports {
                anyhow::ensure!(
                    read_owner[p] == usize::MAX,
                    "tenants {} and {t} overlap on read port {p}",
                    read_owner[p]
                );
                read_owner[p] = t;
            }
            for p in ten.write_base..ten.write_base + ten.write_ports {
                anyhow::ensure!(
                    write_owner[p] == usize::MAX,
                    "tenants {} and {t} overlap on write port {p}",
                    write_owner[p]
                );
                write_owner[p] = t;
            }
        }
        for (i, s) in self.steps.iter().enumerate() {
            anyhow::ensure!(s.tenant < h.tenants.len(), "step {i}: bad tenant");
            let ten = h.tenants[s.tenant];
            anyhow::ensure!(
                s.reads.len() == ten.read_ports && s.writes.len() == ten.write_ports,
                "step {i}: schedule width does not match tenant group"
            );
        }
        Ok(())
    }

    /// The deterministic word replay feeds write-port streams with:
    /// a mix of the step's `write_seed`, the line address, and the word
    /// lane, so corrupted routing cannot alias to the right value.
    pub fn synth_word(write_seed: u64, addr: u64, lane: u64) -> u64 {
        let mut z = write_seed ^ addr.wrapping_mul(0x9e37_79b9_7f4a_7c15) ^ (lane << 17);
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
        z ^ (z >> 31)
    }
}

#[cfg(test)]
mod canonical_tests {
    use super::*;

    fn sample() -> ScenarioTrace {
        ScenarioTrace {
            header: TraceHeader {
                scenario: "unit".into(),
                design: "medusa".into(),
                w_line: 64,
                w_acc: 16,
                read_ports: 4,
                write_ports: 4,
                max_burst: 4,
                dotprod_units: 4,
                rotator_stages: 0,
                mem_mhz: 200.0,
                fabric_mhz: 200.0,
                ddr3_timing: false,
                cmd_depth: 8,
                rd_line_depth: 8,
                wr_data_depth: 8,
                seed: 7,
                faults: crate::fault::FaultSpec::none(),
                serving: crate::serving::ServingSpec::none(),
                tenants: vec![TraceTenant {
                    read_base: 0,
                    read_ports: 4,
                    write_base: 0,
                    write_ports: 4,
                    start_cycle: 0,
                }],
            },
            steps: vec![TraceStep {
                tenant: 0,
                label: "conv1".into(),
                macs: 4608,
                write_seed: 7,
                reads: vec![vec![(0, 12)], vec![(12, 13)], vec![(25, 7), (32, 6)], vec![(38, 13)]],
                writes: vec![vec![(51, 16)], vec![(67, 16)], vec![(83, 16)], vec![(99, 16)]],
            }],
            expect: TraceExpect {
                timing_recorded: true,
                fabric_cycles: 1234,
                mem_cycles: 1200,
                now_ps: 99_000,
                exact: vec![("lp.words_loaded".into(), 204)],
                timing: vec![("wait.t0.read.0".into(), 3)],
            },
        }
    }

    #[test]
    fn round_trips_through_text() {
        let t = sample();
        let text = t.to_text();
        let back = ScenarioTrace::from_str(&text).unwrap();
        assert_eq!(t, back);
        back.validate().unwrap();
    }

    #[test]
    fn unrecorded_timing_round_trips() {
        let mut t = sample();
        t.expect.timing_recorded = false;
        t.expect.fabric_cycles = 0;
        t.expect.mem_cycles = 0;
        t.expect.now_ps = 0;
        t.expect.timing.clear();
        let back = ScenarioTrace::from_str(&t.to_text()).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn faulted_header_round_trips() {
        let mut t = sample();
        t.header.faults = crate::fault::FaultSpec::parse_cli(
            "dram_refresh=64/8,cdc=96/6,slow=128/12,corrupt=7,wedge=1@500,watchdog=4000,seed=3,policy=degrade",
        )
        .unwrap();
        let text = t.to_text();
        assert!(text.contains("faults.seed = 3"), "{text}");
        assert!(text.contains("faults.policy = \"degrade\""), "{text}");
        let back = ScenarioTrace::from_str(&text).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn serving_header_round_trips() {
        let mut t = sample();
        t.header.serving = crate::serving::ServingSpec::parse_cli(
            "requests=6,mean_gap=4000,max_batch=2,max_wait=2500,slo=200000,seed=5",
        )
        .unwrap();
        let text = t.to_text();
        assert!(text.contains("serving.requests = 6"), "{text}");
        assert!(text.contains("serving.slo_cycles = 200000"), "{text}");
        let back = ScenarioTrace::from_str(&text).unwrap();
        assert_eq!(t, back);
        // Explicit arrival traces round-trip through the quoted form.
        t.header.serving =
            crate::serving::ServingSpec::parse_cli("arrivals=5+25+125,max_batch=2").unwrap();
        let text = t.to_text();
        assert!(text.contains("serving.arrivals = \"5+25+125\""), "{text}");
        assert_eq!(ScenarioTrace::from_str(&text).unwrap(), t);
    }

    #[test]
    fn serving_free_trace_carries_no_serving_keys() {
        let t = sample();
        assert!(t.header.serving.is_none());
        assert!(!t.to_text().contains("serving."));
        let back = ScenarioTrace::from_str(&t.to_text()).unwrap();
        assert!(back.header.serving.is_none());
    }

    #[test]
    fn fault_free_trace_carries_no_fault_keys() {
        let t = sample();
        assert!(t.header.faults.is_none());
        assert!(!t.to_text().contains("faults."));
        let back = ScenarioTrace::from_str(&t.to_text()).unwrap();
        assert!(back.header.faults.is_none());
    }

    #[test]
    fn step_line_totals() {
        let t = sample();
        assert_eq!(t.steps[0].read_lines(), 51);
        assert_eq!(t.steps[0].write_lines(), 64);
    }

    #[test]
    fn run_parsing_rejects_garbage() {
        assert!(parse_runs("1:2,3").is_err());
        assert!(parse_runs("x:2").is_err());
        assert_eq!(parse_runs("").unwrap(), Vec::<TraceRun>::new());
        assert_eq!(parse_runs("5:6, 7:8").unwrap(), vec![(5, 6), (7, 8)]);
    }

    #[test]
    fn movement_counter_list_is_sorted_and_known() {
        let mut sorted = MOVEMENT_COUNTERS.to_vec();
        sorted.sort_unstable();
        assert_eq!(MOVEMENT_COUNTERS, &sorted[..]);
        for name in MOVEMENT_COUNTERS {
            assert!(
                crate::sim::stats::Counter::from_name(name).is_some(),
                "{name} is not a registry counter"
            );
        }
    }

    #[test]
    fn bad_tenant_reference_rejected() {
        let mut t = sample();
        t.steps[0].tenant = 3;
        let text = t.to_text();
        assert!(ScenarioTrace::from_str(&text).is_err());
    }

    #[test]
    fn overlapping_tenant_groups_rejected() {
        let mut t = sample();
        t.header.tenants = vec![
            TraceTenant { read_base: 0, read_ports: 3, write_base: 0, write_ports: 2, start_cycle: 0 },
            TraceTenant { read_base: 2, read_ports: 2, write_base: 2, write_ports: 2, start_cycle: 0 },
        ];
        let err = t.validate().unwrap_err();
        assert!(format!("{err}").contains("overlap on read port 2"), "{err}");
    }

    #[test]
    fn out_of_range_port_schedule_key_rejected() {
        let t = sample();
        let text = t.to_text().replace("reads.3 = \"38:13\"", "reads.7 = \"38:13\"");
        let err = ScenarioTrace::from_str(&text).unwrap_err();
        assert!(format!("{err}").contains("owns only 4"), "{err}");
    }

    #[test]
    fn stray_step_beyond_declared_count_rejected() {
        let t = sample();
        let mut text = t.to_text();
        text.push_str("\n[step.1]\ntenant = 0\nlabel = \"stray\"\nmacs = 1\nwrite_seed = 0\n");
        let err = ScenarioTrace::from_str(&text).unwrap_err();
        assert!(format!("{err}").contains("truncated or tampered"), "{err}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_trace_records_nothing() {
        let mut t = Trace::disabled();
        t.record(1, "x", || "should not materialize".to_string());
        assert!(t.is_empty());
    }

    #[test]
    fn bounded_trace_drops_oldest() {
        let mut t = Trace::bounded(2);
        t.record(1, "a", || "e1".into());
        t.record(2, "b", || "e2".into());
        t.record(3, "c", || "e3".into());
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 1);
        let evs: Vec<_> = t.events().map(|e| e.cycle).collect();
        assert_eq!(evs, vec![2, 3]);
        assert!(t.dump().contains("earlier events dropped"));
    }
}
