//! Named counters and simple distributions collected during simulation.
//!
//! Components take `&mut Stats` during ticks; the coordinator aggregates
//! and prints them. String keys are interned as `&'static str` at the
//! call sites (all counter names are literals), so the hot path is a
//! `HashMap<&'static str, u64>` bump — cheap enough that counters stay on
//! even in benchmark runs.

use std::collections::HashMap;
use std::fmt;

#[derive(Default, Debug)]
pub struct Stats {
    counters: HashMap<&'static str, u64>,
    /// min/max/sum/count per named sample series (e.g. latencies).
    samples: HashMap<&'static str, Series>,
}

#[derive(Clone, Copy, Debug)]
pub struct Series {
    pub min: u64,
    pub max: u64,
    pub sum: u64,
    pub count: u64,
}

impl Series {
    fn new() -> Self {
        Series { min: u64::MAX, max: 0, sum: 0, count: 0 }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

impl Stats {
    pub fn new() -> Self {
        Stats::default()
    }

    pub fn bump(&mut self, key: &'static str) {
        *self.counters.entry(key).or_insert(0) += 1;
    }

    pub fn add(&mut self, key: &'static str, n: u64) {
        *self.counters.entry(key).or_insert(0) += n;
    }

    pub fn get(&self, key: &str) -> u64 {
        self.counters.get(key).copied().unwrap_or(0)
    }

    pub fn sample(&mut self, key: &'static str, v: u64) {
        let s = self.samples.entry(key).or_insert_with(Series::new);
        s.min = s.min.min(v);
        s.max = s.max.max(v);
        s.sum += v;
        s.count += 1;
    }

    pub fn series(&self, key: &str) -> Option<&Series> {
        self.samples.get(key)
    }

    /// Merge another Stats into this one (used when joining per-thread
    /// sweeps).
    pub fn merge(&mut self, other: &Stats) {
        for (k, v) in &other.counters {
            *self.counters.entry(k).or_insert(0) += v;
        }
        for (k, s) in &other.samples {
            let e = self.samples.entry(k).or_insert_with(Series::new);
            e.min = e.min.min(s.min);
            e.max = e.max.max(s.max);
            e.sum += s.sum;
            e.count += s.count;
        }
    }

    pub fn counters(&self) -> impl Iterator<Item = (&&'static str, &u64)> {
        self.counters.iter()
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut keys: Vec<_> = self.counters.keys().collect();
        keys.sort();
        for k in keys {
            writeln!(f, "  {k:<40} {}", self.counters[*k])?;
        }
        let mut keys: Vec<_> = self.samples.keys().collect();
        keys.sort();
        for k in keys {
            let s = &self.samples[*k];
            writeln!(
                f,
                "  {k:<40} min={} max={} mean={:.2} n={}",
                if s.count == 0 { 0 } else { s.min },
                s.max,
                s.mean(),
                s.count
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut s = Stats::new();
        s.bump("a");
        s.bump("a");
        s.add("a", 3);
        assert_eq!(s.get("a"), 5);
        assert_eq!(s.get("missing"), 0);
    }

    #[test]
    fn samples_track_min_max_mean() {
        let mut s = Stats::new();
        for v in [3u64, 1, 4, 1, 5] {
            s.sample("lat", v);
        }
        let series = s.series("lat").unwrap();
        assert_eq!(series.min, 1);
        assert_eq!(series.max, 5);
        assert_eq!(series.count, 5);
        assert!((series.mean() - 2.8).abs() < 1e-9);
    }

    #[test]
    fn merge_combines() {
        let mut a = Stats::new();
        a.add("x", 2);
        a.sample("lat", 10);
        let mut b = Stats::new();
        b.add("x", 3);
        b.sample("lat", 2);
        a.merge(&b);
        assert_eq!(a.get("x"), 5);
        let s = a.series("lat").unwrap();
        assert_eq!(s.min, 2);
        assert_eq!(s.max, 10);
        assert_eq!(s.count, 2);
    }
}
