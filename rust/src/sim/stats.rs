//! Named counters and simple distributions collected during simulation.
//!
//! Components take `&mut Stats` during ticks; the coordinator aggregates
//! and prints them. The hot path is fully interned: every counter is a
//! compile-time [`Counter`] id and every sample series a [`SampleId`],
//! so `bump`/`add`/`sample` are a single indexed add into a fixed-size
//! array — no hashing, no heap, no branches beyond the bounds check the
//! compiler elides. The `&'static str` names live in a parallel table
//! used only by the (cold) reporting and string-keyed lookup paths. The
//! report layout is unchanged; the one behavioural difference is that
//! counters whose value is zero are omitted (the map used to print a
//! key touched only by `add(k, 0)`).

use std::fmt;

/// Declare the counter registry: enum + name table + count, kept in one
/// place so an id and its report name can never drift apart.
macro_rules! counters {
    ($enum_name:ident, $count_const:ident, $all_const:ident; $($variant:ident => $name:literal,)*) => {
        /// Compile-time id of one simulation counter.
        #[derive(Clone, Copy, Debug, PartialEq, Eq)]
        #[repr(usize)]
        pub enum $enum_name {
            $($variant,)*
        }

        impl $enum_name {
            pub const $count_const: usize = [$($name,)*].len();
            pub const $all_const: [$enum_name; Self::$count_const] = [$($enum_name::$variant,)*];

            /// The report name (the legacy string key).
            #[inline]
            pub const fn name(self) -> &'static str {
                match self {
                    $($enum_name::$variant => $name,)*
                }
            }

            /// Resolve a legacy string key to its id (cold path: tests
            /// and report tooling only).
            pub fn from_name(name: &str) -> Option<$enum_name> {
                match name {
                    $($name => Some($enum_name::$variant),)*
                    _ => None,
                }
            }
        }
    };
}

counters! {
    Counter, COUNT, ALL;
    // Request arbiter.
    ArbiterCmdChannelStall => "arbiter.cmd_channel_stall",
    ArbiterReadCreditStall => "arbiter.read_credit_stall",
    ArbiterReadsIssued => "arbiter.reads_issued",
    ArbiterWriteDataStall => "arbiter.write_data_stall",
    ArbiterWriteLinesStreamed => "arbiter.write_lines_streamed",
    ArbiterWritesIssued => "arbiter.writes_issued",
    // AXI4-Stream comparator networks.
    AxisReadLinesThroughSlices => "axis_read.lines_through_slices",
    AxisWriteLinesThroughSlices => "axis_write.lines_through_slices",
    // Baseline networks.
    BaselineReadLinesIntoConverter => "baseline_read.lines_into_converter",
    BaselineWriteLinesIntoFifo => "baseline_write.lines_into_fifo",
    // DDR3 memory controller.
    DramIdleCycles => "dram.idle_cycles",
    DramReadBursts => "dram.read_bursts",
    DramReadLines => "dram.read_lines",
    DramReadReturnStall => "dram.read_return_stall",
    DramRowHits => "dram.row_hits",
    DramRowMisses => "dram.row_misses",
    DramTimingStallCycles => "dram.timing_stall_cycles",
    DramWriteBursts => "dram.write_bursts",
    DramWriteDataStall => "dram.write_data_stall",
    DramWriteLines => "dram.write_lines",
    // Fault injection (PR 6). Delay faults count stalled cycles; the
    // corrupt fault counts detection outcomes. None of these are
    // movement counters, so they land in `[expect.timing]`, never in
    // the golden `[expect.exact]` block.
    FaultCdcStallCycles => "fault.cdc_stall_cycles",
    FaultCorruptInjected => "fault.corrupt_injected",
    FaultDetected => "fault.detected",
    FaultDramRefreshStallCycles => "fault.dram_refresh_stall_cycles",
    FaultLpSlowdownCycles => "fault.lp_slowdown_cycles",
    FaultMasked => "fault.masked",
    // Hierarchical (clustered) networks. The per-cluster transposers
    // bump the plain Medusa counters; these count only the trunk/bypass
    // routing the hierarchy adds on top.
    HierReadLinesBypassed => "hier_read.lines_bypassed",
    HierReadLinesOverTrunk => "hier_read.lines_over_trunk",
    HierWriteLinesBypassed => "hier_write.lines_bypassed",
    HierWriteLinesOverTrunk => "hier_write.lines_over_trunk",
    // Hybrid (partial-transpose) networks. Only the intermediate-radix
    // datapaths touch these: the radix endpoints instantiate the exact
    // baseline/Medusa datapaths and bump those counters instead (the
    // bit-for-bit endpoint-equivalence contract).
    HybridReadLinesTransposed => "hybrid_read.lines_transposed",
    HybridReadWordsRotated => "hybrid_read.words_rotated",
    HybridWriteLinesTransposed => "hybrid_write.lines_transposed",
    HybridWriteWordsRotated => "hybrid_write.words_rotated",
    // Layer processor.
    LpDrainStallPortCycles => "lp.drain_stall_port_cycles",
    LpLoadStallPortCycles => "lp.load_stall_port_cycles",
    LpReadBurstsSubmitted => "lp.read_bursts_submitted",
    LpWordsDrained => "lp.words_drained",
    LpWordsLoaded => "lp.words_loaded",
    LpWriteBurstsSubmitted => "lp.write_bursts_submitted",
    // Medusa networks.
    MedusaReadLinesTransposed => "medusa_read.lines_transposed",
    MedusaReadWordsRotated => "medusa_read.words_rotated",
    MedusaWriteLinesTransposed => "medusa_write.lines_transposed",
    MedusaWriteWordsRotated => "medusa_write.words_rotated",
    // Inference serving (PR 7). Request/batch bookkeeping of the
    // open-loop serving front-end; like the fault counters these are
    // not movement counters, so they land in `[expect.timing]` (and
    // only there when non-zero — serving-free captures stay
    // byte-identical to pre-serving builds).
    ServingBatches => "serving.batches_dispatched",
    ServingRequestsArrived => "serving.requests_arrived",
    ServingRequestsCompleted => "serving.requests_completed",
    // Overload robustness (PR 10): requests resolved by something
    // other than completion — failed (retry budget exhausted), retried
    // (failed-fast and re-queued with backoff), shed (bounded-queue
    // admission), timed out (deadline expiry while queued).
    ServingRequestsFailed => "serving.requests_failed",
    ServingRequestsRetried => "serving.requests_retried",
    ServingRequestsShed => "serving.requests_shed",
    ServingRequestsTimedOut => "serving.requests_timed_out",
    ServingSloMet => "serving.slo_met",
    // System-level CDC adapters.
    SysReadLineBackpressure => "sys.read_line_backpressure",
    SysReadLinesIntoFabric => "sys.read_lines_into_fabric",
}

counters! {
    SampleId, COUNT, ALL;
    // Degrade-policy recovery metrics (PR 6): lines each surviving
    // tenant still moved after a quiesce, and how long the quiesce
    // drain took.
    DegradeGoodputLines => "degrade.goodput_lines",
    DegradeRecoveryCycles => "degrade.recovery_cycles",
    MedusaReadLineLatencyCycles => "medusa_read.line_latency_cycles",
    // Inference serving (PR 7): per-request latency (the p50/p99
    // source), queue depth sampled at each admission, and dispatched
    // batch occupancy.
    ServingBatchOccupancy => "serving.batch_occupancy",
    ServingLatencyCycles => "serving.latency_cycles",
    ServingQueueDepth => "serving.queue_depth",
    // Overload robustness (PR 10): the pre-drawn backoff delay applied
    // to each retried request (base << attempt + jitter).
    ServingRetryBackoffCycles => "serving.retry_backoff_cycles",
}

#[derive(Clone, Copy, Debug)]
pub struct Series {
    pub min: u64,
    pub max: u64,
    pub sum: u64,
    pub count: u64,
}

impl Series {
    const fn new() -> Self {
        Series { min: u64::MAX, max: 0, sum: 0, count: 0 }
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum as f64 / self.count as f64
        }
    }
}

#[derive(Clone, Debug)]
pub struct Stats {
    counters: [u64; Counter::COUNT],
    samples: [Series; SampleId::COUNT],
}

impl Default for Stats {
    fn default() -> Self {
        Stats::new()
    }
}

impl Stats {
    pub const fn new() -> Self {
        Stats {
            counters: [0; Counter::COUNT],
            samples: [Series::new(); SampleId::COUNT],
        }
    }

    #[inline(always)]
    pub fn bump(&mut self, id: Counter) {
        self.counters[id as usize] += 1;
    }

    #[inline(always)]
    pub fn add(&mut self, id: Counter, n: u64) {
        self.counters[id as usize] += n;
    }

    /// Fast indexed read.
    #[inline(always)]
    pub fn count(&self, id: Counter) -> u64 {
        self.counters[id as usize]
    }

    /// Legacy string-keyed read (cold: tests and report tooling).
    /// Unknown keys read as 0, matching the old `HashMap` behaviour.
    pub fn get(&self, key: &str) -> u64 {
        Counter::from_name(key).map_or(0, |id| self.count(id))
    }

    #[inline(always)]
    pub fn sample(&mut self, id: SampleId, v: u64) {
        let s = &mut self.samples[id as usize];
        s.min = s.min.min(v);
        s.max = s.max.max(v);
        s.sum += v;
        s.count += 1;
    }

    #[inline(always)]
    pub fn series_of(&self, id: SampleId) -> &Series {
        &self.samples[id as usize]
    }

    /// Legacy string-keyed series lookup; `None` for unknown keys or
    /// series with no samples (matching the old map semantics).
    pub fn series(&self, key: &str) -> Option<&Series> {
        let s = self.series_of(SampleId::from_name(key)?);
        (s.count > 0).then_some(s)
    }

    /// Merge another Stats into this one (used when joining per-thread
    /// sweeps).
    pub fn merge(&mut self, other: &Stats) {
        for i in 0..Counter::COUNT {
            self.counters[i] += other.counters[i];
        }
        for i in 0..SampleId::COUNT {
            let (e, s) = (&mut self.samples[i], &other.samples[i]);
            e.min = e.min.min(s.min);
            e.max = e.max.max(s.max);
            e.sum += s.sum;
            e.count += s.count;
        }
    }

    /// All touched counters as `(name, value)`, sorted by name (the
    /// registry is declared in name order).
    pub fn counters(&self) -> impl Iterator<Item = (&'static str, u64)> + '_ {
        Counter::ALL
            .iter()
            .map(move |&id| (id.name(), self.count(id)))
            .filter(|&(_, v)| v > 0)
    }
}

impl fmt::Display for Stats {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // The registry arrays are declared sorted by name, so iteration
        // order matches the old sorted-HashMap report. Counters that were
        // never touched are omitted, as before.
        for (name, v) in self.counters() {
            writeln!(f, "  {name:<40} {v}")?;
        }
        for &id in SampleId::ALL.iter() {
            let s = self.series_of(id);
            if s.count == 0 {
                continue;
            }
            writeln!(
                f,
                "  {:<40} min={} max={} mean={:.2} n={}",
                id.name(),
                s.min,
                s.max,
                s.mean(),
                s.count
            )?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let mut s = Stats::new();
        s.bump(Counter::ArbiterReadsIssued);
        s.bump(Counter::ArbiterReadsIssued);
        s.add(Counter::ArbiterReadsIssued, 3);
        assert_eq!(s.count(Counter::ArbiterReadsIssued), 5);
        assert_eq!(s.get("arbiter.reads_issued"), 5);
        assert_eq!(s.get("missing"), 0);
    }

    #[test]
    fn samples_track_min_max_mean() {
        let mut s = Stats::new();
        for v in [3u64, 1, 4, 1, 5] {
            s.sample(SampleId::MedusaReadLineLatencyCycles, v);
        }
        let series = s.series("medusa_read.line_latency_cycles").unwrap();
        assert_eq!(series.min, 1);
        assert_eq!(series.max, 5);
        assert_eq!(series.count, 5);
        assert!((series.mean() - 2.8).abs() < 1e-9);
        assert!(s.series("nope").is_none());
    }

    #[test]
    fn merge_combines() {
        let mut a = Stats::new();
        a.add(Counter::DramReadLines, 2);
        a.sample(SampleId::MedusaReadLineLatencyCycles, 10);
        let mut b = Stats::new();
        b.add(Counter::DramReadLines, 3);
        b.sample(SampleId::MedusaReadLineLatencyCycles, 2);
        a.merge(&b);
        assert_eq!(a.count(Counter::DramReadLines), 5);
        let s = a.series_of(SampleId::MedusaReadLineLatencyCycles);
        assert_eq!(s.min, 2);
        assert_eq!(s.max, 10);
        assert_eq!(s.count, 2);
    }

    #[test]
    fn registry_names_are_unique_and_sorted() {
        let names: Vec<&str> = Counter::ALL.iter().map(|c| c.name()).collect();
        let mut sorted = names.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(names, sorted, "counter registry must be declared in sorted order, no dups");
        for &id in Counter::ALL.iter() {
            assert_eq!(Counter::from_name(id.name()), Some(id));
        }
    }

    #[test]
    fn display_skips_untouched_counters() {
        let mut s = Stats::new();
        s.bump(Counter::DramRowHits);
        let text = format!("{s}");
        assert!(text.contains("dram.row_hits"));
        assert!(!text.contains("dram.row_misses"));
    }
}
