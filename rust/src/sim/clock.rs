//! Multi-rate clocking.
//!
//! The paper's systems have (at least) two clock domains: the DDR3
//! memory controller's fixed 200 MHz domain and the accelerator fabric
//! domain whose frequency is whatever place-and-route achieves (25–450
//! MHz in Fig 6). Bandwidth delivered to the accelerator depends on the
//! *ratio* of these clocks, so the simulator models domains explicitly.
//!
//! [`Scheduler`] advances simulated time edge by edge: at each step it
//! finds the domain(s) with the earliest next rising edge and reports
//! which domains fire as a [`Fired`] bitmask — a `Copy` value, so the
//! hot loop performs **no heap allocation**. Components are grouped per
//! domain by the netlist owner, which ticks + commits them when their
//! domain fires.
//!
//! Periods are carried in **femtoseconds**: at picosecond granularity a
//! 225 MHz clock rounds to 4444 ps (≈225.02 MHz), a 1e-4 relative error
//! that drifts systematically over long runs. At femtosecond granularity
//! the worst-case rounding error is 0.5 fs per period (relative error
//! ≤ ~1e-7 for every Fig 6 frequency), which keeps multi-billion-edge
//! runs on the intended clock ratio.

/// Picoseconds are the simulator's reporting unit; periods are tracked
/// at this finer granularity internally.
pub const FS_PER_PS: u64 = 1_000;

/// One clock domain, defined by its period in femtoseconds.
#[derive(Clone, Debug)]
pub struct ClockDomain {
    pub name: &'static str,
    period_fs: u64,
    /// Cycles elapsed in this domain.
    pub cycles: u64,
    /// Absolute time (fs) of the next rising edge.
    next_edge_fs: u64,
}

/// Why a clock rate cannot be turned into a femtosecond period.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ClockRateError {
    /// Zero, negative, NaN, or infinite MHz.
    NonPositive,
    /// Not an integral number of MHz — the exact-arithmetic
    /// constructor refuses rather than silently rounding twice
    /// (once in the float, once to femtoseconds).
    NonIntegralMhz,
    /// Above 1e9 MHz: the period would be below 1 fs, the scheduler's
    /// time quantum.
    PeriodUnderflow,
}

impl std::fmt::Display for ClockRateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClockRateError::NonPositive => write!(f, "clock frequency must be positive"),
            ClockRateError::NonIntegralMhz => {
                write!(f, "clock frequency must be an integral number of MHz")
            }
            ClockRateError::PeriodUnderflow => {
                write!(f, "clock period underflows 1 fs (frequency above 1e9 MHz)")
            }
        }
    }
}

impl std::error::Error for ClockRateError {}

impl ClockDomain {
    /// Exact-arithmetic constructor for integral-MHz rates (every rate
    /// the design space produces: the 25 MHz Fig 6 grid, 200 MHz DDR3,
    /// 225/300/333 MHz fabrics). The period is derived purely in
    /// integers — `(1e9 + mhz/2) / mhz`, i.e. 1e9 fs rounded half-up —
    /// so a float-rounding drift regression (the old 225 MHz → 4444 ps
    /// bug, one level down) is impossible by construction: when `mhz`
    /// divides 1e9 the product `period_fs * mhz` is *exactly* 1e9 fs,
    /// and otherwise the error is at most mhz/2 fs per 1e9, the best
    /// any integral period can do.
    pub fn try_from_mhz(name: &'static str, mhz: f64) -> Result<Self, ClockRateError> {
        if !(mhz > 0.0) || !mhz.is_finite() {
            return Err(ClockRateError::NonPositive);
        }
        if mhz.fract() != 0.0 {
            return Err(ClockRateError::NonIntegralMhz);
        }
        if mhz > 1_000_000_000.0 {
            return Err(ClockRateError::PeriodUnderflow);
        }
        let m = mhz as u64; // exact: integral and ≤ 1e9
        let period_fs = (1_000_000_000 + m / 2) / m;
        debug_assert!(period_fs >= 1);
        Ok(ClockDomain { name, period_fs, cycles: 0, next_edge_fs: 0 })
    }

    /// Permissive constructor: exact integer arithmetic for integral
    /// MHz, nearest-femtosecond float rounding for everything else
    /// (e.g. a hand-entered `--fabric-mhz 212.5`). Panics on
    /// non-positive rates and sub-femtosecond periods.
    pub fn from_mhz(name: &'static str, mhz: f64) -> Self {
        match Self::try_from_mhz(name, mhz) {
            Ok(d) => d,
            Err(ClockRateError::NonIntegralMhz) => {
                // 1 MHz -> 1e9 fs period.
                let period_fs = (1_000_000_000.0 / mhz).round() as u64;
                assert!(period_fs > 0, "clock {name} period underflows 1 fs");
                ClockDomain { name, period_fs, cycles: 0, next_edge_fs: 0 }
            }
            Err(e) => panic!("clock {name}: {e} (got {mhz} MHz)"),
        }
    }

    pub fn period_fs(&self) -> u64 {
        self.period_fs
    }

    /// Period in picoseconds (rounded; reporting only — stepping uses
    /// the exact femtosecond period).
    pub fn period_ps(&self) -> u64 {
        (self.period_fs + FS_PER_PS / 2) / FS_PER_PS
    }

    pub fn freq_mhz(&self) -> f64 {
        1_000_000_000.0 / self.period_fs as f64
    }
}

/// The set of domains that fired at one scheduler step, as a bitmask.
/// `Copy`, allocation-free, iterable in ascending domain order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Fired(u64);

impl Fired {
    pub const EMPTY: Fired = Fired(0);

    #[inline(always)]
    pub fn contains(self, domain: usize) -> bool {
        self.0 & (1u64 << domain) != 0
    }

    #[inline(always)]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    #[inline(always)]
    pub fn count(self) -> u32 {
        self.0.count_ones()
    }

    #[inline(always)]
    pub fn iter(self) -> FiredIter {
        FiredIter(self.0)
    }

    /// The raw bitmask (bit i = domain i fired).
    #[inline(always)]
    pub fn mask(self) -> u64 {
        self.0
    }
}

impl IntoIterator for Fired {
    type Item = usize;
    type IntoIter = FiredIter;

    #[inline(always)]
    fn into_iter(self) -> FiredIter {
        FiredIter(self.0)
    }
}

/// Iterator over set bits of a [`Fired`] mask, ascending.
#[derive(Clone, Copy, Debug)]
pub struct FiredIter(u64);

impl Iterator for FiredIter {
    type Item = usize;

    #[inline(always)]
    fn next(&mut self) -> Option<usize> {
        if self.0 == 0 {
            return None;
        }
        let i = self.0.trailing_zeros() as usize;
        self.0 &= self.0 - 1;
        Some(i)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.0.count_ones() as usize;
        (n, Some(n))
    }
}

impl ExactSizeIterator for FiredIter {}

/// Maximum number of clock domains [`Scheduler::leap`] supports. The
/// inclusion–exclusion span accounting enumerates all `2^n - 1` domain
/// subsets, so this cap keeps the enumeration trivially cheap (at most
/// 255 subsets) while covering every system the simulator builds —
/// fabric + DRAM + trunk today, with headroom for per-channel DRAM
/// clocks.
pub const MAX_LEAP_DOMAINS: usize = 8;

/// Outcome of one [`Scheduler::leap`]: how much stepwise work the leap
/// replaced, exactly.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Leap {
    /// Distinct edge instants covered — the number of `step()` calls a
    /// stepwise run would have used for the same span.
    pub steps: u64,
    /// Edges fired per domain index (leaping supports at most
    /// [`MAX_LEAP_DOMAINS`] domains; unused slots stay 0).
    pub fired: [u64; MAX_LEAP_DOMAINS],
}

fn gcd(mut a: u64, mut b: u64) -> u64 {
    while b != 0 {
        (a, b) = (b, a % b);
    }
    a
}

/// Modular inverse of `a` modulo `m` (`m >= 1`; returns 0 when m == 1).
/// `a` and `m` must be coprime — guaranteed at the call site, where
/// both are divided by their gcd.
fn mod_inverse(a: u64, m: u64) -> u64 {
    if m == 1 {
        return 0;
    }
    // Extended Euclid over i128 (inputs fit u64, intermediates may not).
    let (mut old_r, mut r) = (a as i128 % m as i128, m as i128);
    let (mut old_s, mut s) = (1i128, 0i128);
    while r != 0 {
        let q = old_r / r;
        (old_r, r) = (r, old_r - q * r);
        (old_s, s) = (s, old_s - q * s);
    }
    debug_assert_eq!(old_r, 1, "mod_inverse of non-coprime inputs");
    old_s.rem_euclid(m as i128) as u64
}

/// The edge instants of one clock domain inside a leap window, clipped
/// to `[0, hi]`: empty, a single instant, or a genuine arithmetic
/// progression `x0 + i*step` with at least two in-window terms.
///
/// The normalization invariant — `Arith` implies `x0 + step <= hi`,
/// i.e. both operand steps handed to [`intersect`] are `<= hi <=
/// u64::MAX` — is what keeps the u128 CRT arithmetic overflow-free
/// across an N-way intersection chain: the combined modulus (the lcm)
/// of two in-window steps always fits u128, and the moment it exceeds
/// the window the result collapses to `One`/`Empty`, so moduli never
/// compound beyond one multiplication.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Prog {
    Empty,
    One(u64),
    Arith { x0: u64, step: u64 },
}

impl Prog {
    /// The progression `first + i*period` (`i >= 0`) clipped to `hi`.
    fn new(first: u64, period: u64, hi: u64) -> Prog {
        debug_assert!(period >= 1);
        if first > hi {
            Prog::Empty
        } else if hi - first < period {
            Prog::One(first)
        } else {
            Prog::Arith { x0: first, step: period }
        }
    }

    /// Number of in-window instants.
    fn count(self, hi: u64) -> u64 {
        match self {
            Prog::Empty => 0,
            Prog::One(x) => {
                debug_assert!(x <= hi);
                1
            }
            Prog::Arith { x0, step } => (hi - x0) / step + 1,
        }
    }

    /// Membership test (`t` must already be known in-window).
    fn contains(self, t: u64) -> bool {
        match self {
            Prog::Empty => false,
            Prog::One(x) => x == t,
            Prog::Arith { x0, step } => t >= x0 && (t - x0) % step == 0,
        }
    }
}

/// Intersect two in-window progressions — the instants where both
/// domains fire simultaneously. By CRT the result is again a single
/// progression (with the lcm of the steps as its step), a single
/// instant, or empty, so N-domain simultaneity reduces to a chain of
/// pairwise intersections with no loss of exactness.
fn intersect(a: Prog, b: Prog, hi: u64) -> Prog {
    match (a, b) {
        (Prog::Empty, _) | (_, Prog::Empty) => Prog::Empty,
        (Prog::One(x), o) | (o, Prog::One(x)) => {
            if o.contains(x) {
                Prog::One(x)
            } else {
                Prog::Empty
            }
        }
        (Prog::Arith { x0: a0, step: p }, Prog::Arith { x0: b0, step: q }) => {
            let g = gcd(p, q);
            if a0.abs_diff(b0) % g != 0 {
                return Prog::Empty;
            }
            // qg >= 1 always (g divides q); the qg == 1 degenerate case
            // is handled inside mod_inverse (returns 0, making t == 0,
            // x0 == a0 before the advance below).
            let qg = q / g;
            let lcm = (p as u128) * (qg as u128);
            // x = a0 + p*t with t ≡ (b0 - a0)/g · inv(p/g) (mod q/g).
            let dg = ((b0 as i128 - a0 as i128) / g as i128).rem_euclid(qg as i128) as u128;
            let inv = mod_inverse((p / g) % qg, qg) as u128;
            let t = (dg * inv) % qg as u128;
            let lo = a0.max(b0) as u128;
            let mut x0 = a0 as u128 + t * p as u128; // smallest common instant ≥ a0
            if x0 < lo {
                x0 += lcm * (lo - x0).div_ceil(lcm);
            }
            if x0 > hi as u128 {
                Prog::Empty
            } else if (hi as u128) - x0 < lcm {
                Prog::One(x0 as u64)
            } else {
                Prog::Arith { x0: x0 as u64, step: lcm as u64 }
            }
        }
    }
}

/// Edge-ordered scheduler over a set of clock domains.
#[derive(Clone, Debug)]
pub struct Scheduler {
    domains: Vec<ClockDomain>,
    now_fs: u64,
}

impl Scheduler {
    pub fn new(domains: Vec<ClockDomain>) -> Self {
        assert!(!domains.is_empty());
        assert!(domains.len() <= 64, "Fired bitmask supports at most 64 domains");
        Scheduler { domains, now_fs: 0 }
    }

    /// Single-domain convenience constructor.
    pub fn single(name: &'static str, mhz: f64) -> Self {
        Scheduler::new(vec![ClockDomain::from_mhz(name, mhz)])
    }

    pub fn now_fs(&self) -> u64 {
        self.now_fs
    }

    /// Current simulated time in picoseconds (truncated from fs).
    pub fn now_ps(&self) -> u64 {
        self.now_fs / FS_PER_PS
    }

    pub fn domain(&self, idx: usize) -> &ClockDomain {
        &self.domains[idx]
    }

    pub fn num_domains(&self) -> usize {
        self.domains.len()
    }

    /// Advance to the next rising edge(s). Returns the bitmask of every
    /// domain that fires at that instant (simultaneous edges fire
    /// together, as in RTL simulation) and updates their cycle counters.
    /// Allocation-free: the returned [`Fired`] is a `Copy` bitmask.
    #[inline]
    pub fn step(&mut self) -> Fired {
        let mut t = u64::MAX;
        for d in self.domains.iter() {
            t = t.min(d.next_edge_fs);
        }
        self.now_fs = t;
        let mut mask = 0u64;
        for (i, d) in self.domains.iter_mut().enumerate() {
            if d.next_edge_fs == t {
                d.cycles += 1;
                // u64 femtoseconds cap the horizon at ~5.1 hours of
                // simulated time; fail loudly instead of wrapping and
                // silently corrupting the clock ratio.
                d.next_edge_fs = d
                    .next_edge_fs
                    .checked_add(d.period_fs)
                    .expect("simulated time overflowed u64 femtoseconds (~5.1 h)");
                mask |= 1u64 << i;
            }
        }
        Fired(mask)
    }

    /// Scheduler steps a stepwise run would use to reach (inclusively)
    /// the `k`-th future edge of `domain`, and the edges every other
    /// domain fires on the way. Pure accounting; no state change.
    ///
    /// Exact for any domain count up to [`MAX_LEAP_DOMAINS`]: a
    /// stepwise run spends one `step()` per *distinct* edge instant
    /// (simultaneous edges fire together), so the step count is the
    /// size of the union of the per-domain instant sets in the window —
    /// counted by inclusion–exclusion over every non-empty domain
    /// subset, each subset's simultaneity being a single arithmetic
    /// progression by CRT ([`intersect`]). The public guard in
    /// [`Scheduler::leap`] refuses larger schedulers up front; the hard
    /// assert (not `debug_assert` — that was a latent release-mode
    /// hole) keeps any future internal caller honest in release builds
    /// too, because a silently wrong count here would corrupt every
    /// leap-vs-stepwise contract downstream.
    fn span_for(&self, domain: usize, k: u64) -> (u64, [u64; MAX_LEAP_DOMAINS]) {
        assert!(k >= 1, "span_for needs k >= 1");
        assert!(
            self.domains.len() <= MAX_LEAP_DOMAINS,
            "span_for: exact simultaneity accounting covers at most {MAX_LEAP_DOMAINS} \
             domains ({} configured); leap must refuse instead of miscounting",
            self.domains.len()
        );
        let d = &self.domains[domain];
        let t_stop = d
            .next_edge_fs
            .checked_add((k - 1).checked_mul(d.period_fs).expect("leap span overflow"))
            .expect("leap span overflowed u64 femtoseconds");
        let n = self.domains.len();
        let mut fired = [0u64; MAX_LEAP_DOMAINS];
        let mut single = [Prog::Empty; MAX_LEAP_DOMAINS];
        for (i, o) in self.domains.iter().enumerate() {
            single[i] = Prog::new(o.next_edge_fs, o.period_fs, t_stop);
            fired[i] = single[i].count(t_stop);
        }
        debug_assert_eq!(fired[domain], k, "t_stop is domain's k-th edge by construction");
        // |A_1 ∪ … ∪ A_n| by inclusion–exclusion. Each subset's
        // intersection is memoized from the subset without its lowest
        // bit, so every mask costs one pairwise `intersect`. Fixed
        // stack storage — this runs inside the hot loop's leap path,
        // which must stay allocation-free.
        let mut progs = [Prog::Empty; 1 << MAX_LEAP_DOMAINS];
        let mut steps = 0i128;
        for mask in 1usize..(1usize << n) {
            let low = mask.trailing_zeros() as usize;
            let rest = mask & (mask - 1);
            let prog =
                if rest == 0 { single[low] } else { intersect(progs[rest], single[low], t_stop) };
            progs[mask] = prog;
            let c = prog.count(t_stop) as i128;
            if mask.count_ones() % 2 == 1 {
                steps += c;
            } else {
                steps -= c;
            }
        }
        let steps = u64::try_from(steps).expect("inclusion–exclusion count cannot go negative");
        debug_assert!(steps >= k);
        (steps, fired)
    }

    /// Leap over up to `k` future edges of `domain` (and every other
    /// domain's edges up to the same instant) in one arithmetic move —
    /// the idle-edge-skipping primitive. Produces EXACTLY the state a
    /// stepwise run reaches after `Leap::steps` calls to [`step`]: same
    /// `now_fs`, same per-domain cycle counters, same next-edge times.
    /// The caller is responsible for applying the skipped edges'
    /// component effects in bulk (that is what makes a span skippable).
    ///
    /// `max_steps` bounds the stepwise-step budget: if covering all `k`
    /// edges would exceed it, the leap shrinks to the largest prefix
    /// that fits. Returns `None` (and changes nothing) when no edge
    /// fits, or when the scheduler has more than [`MAX_LEAP_DOMAINS`]
    /// domains (the inclusion–exclusion simultaneity accounting in
    /// `span_for` is exact up to that cap; larger schedulers fall back
    /// to stepping).
    ///
    /// [`step`]: Scheduler::step
    pub fn leap(&mut self, domain: usize, k: u64, max_steps: u64) -> Option<Leap> {
        if self.domains.len() > MAX_LEAP_DOMAINS || k == 0 || max_steps == 0 {
            return None;
        }
        // A span of k domain edges always costs >= k steps, so k can be
        // pre-clamped to the step budget — this also keeps "unbounded
        // horizon, bounded budget" callers clear of span overflow.
        let k = k.min(max_steps);
        // Largest k' ≤ k whose span fits max_steps (span is monotone).
        let (mut steps, mut fired) = self.span_for(domain, k);
        let mut kk = k;
        if steps > max_steps {
            let (mut lo, mut hi) = (0u64, k); // span(lo) fits, span(hi) doesn't
            while hi - lo > 1 {
                let mid = lo + (hi - lo) / 2;
                if self.span_for(domain, mid).0 <= max_steps {
                    lo = mid;
                } else {
                    hi = mid;
                }
            }
            if lo == 0 {
                return None;
            }
            kk = lo;
            (steps, fired) = self.span_for(domain, kk);
        }
        let t_stop = self.domains[domain].next_edge_fs
            + (kk - 1) * self.domains[domain].period_fs;
        for (i, d) in self.domains.iter_mut().enumerate() {
            let n = fired[i];
            if n == 0 {
                continue;
            }
            d.cycles += n;
            d.next_edge_fs = d
                .next_edge_fs
                .checked_add(n.checked_mul(d.period_fs).expect("leap advance overflow"))
                .expect("simulated time overflowed u64 femtoseconds (~5.1 h)");
        }
        self.now_fs = t_stop;
        Some(Leap { steps, fired })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fired_vec(f: Fired) -> Vec<usize> {
        f.iter().collect()
    }

    #[test]
    fn single_domain_counts_cycles() {
        let mut s = Scheduler::single("clk", 200.0);
        for _ in 0..10 {
            let fired = s.step();
            assert_eq!(fired_vec(fired), vec![0]);
            assert!(fired.contains(0));
            assert_eq!(fired.count(), 1);
        }
        assert_eq!(s.domain(0).cycles, 10);
        // 200 MHz -> 5 ns period; 10 edges end at t = 9 periods after the
        // first edge at t=0.
        assert_eq!(s.now_ps(), 9 * 5_000);
    }

    #[test]
    fn two_to_one_ratio() {
        // Fabric at 100 MHz, controller at 200 MHz: controller should see
        // exactly 2x the edges over a long window.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 100.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..3000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        assert!(mem > 0 && fab > 0);
        let ratio = mem as f64 / fab as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn irrational_ratio_approximates() {
        // 225 MHz fabric vs 200 MHz controller — the Fig 6 sweet spot.
        // With femtosecond periods the ratio must be right to ~1e-6 even
        // on a short window (it was only ~1e-2 at ps granularity).
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 225.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..10_000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        let ratio = fab as f64 / mem as f64;
        assert!((ratio - 225.0 / 200.0).abs() < 0.002, "ratio {ratio}");
    }

    #[test]
    fn long_run_does_not_drift() {
        // The satellite fix this guards: at ps granularity 225 MHz became
        // 4444 ps (225.02 MHz), so over 1M controller cycles the fabric
        // gained ~100 edges vs the exact 9:8 ratio. At fs granularity the
        // error bound over the same window is a handful of edges.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 225.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        while s.domain(1).cycles < 1_000_000 {
            s.step();
        }
        let fab = s.domain(0).cycles as i64;
        let expect = 1_000_000i64 * 9 / 8;
        assert!(
            (fab - expect).abs() <= 4,
            "fabric cycles {fab} drifted from exact {expect}"
        );
    }

    #[test]
    fn period_precision_is_femtoseconds() {
        let d = ClockDomain::from_mhz("f", 225.0);
        assert_eq!(d.period_fs(), 4_444_444);
        assert_eq!(d.period_ps(), 4_444);
        assert!((d.freq_mhz() - 225.0).abs() < 1e-4);
    }

    #[test]
    fn integral_mhz_periods_are_exact_by_construction() {
        // Every clock rate the design space can produce: the Fig 6
        // 25 MHz explorer grid, the DDR3 controller's 200 MHz, and the
        // fabric rates the tests and tuning tables use.
        let mut rates: Vec<u64> = (1..=16).map(|i| i * 25).collect(); // 25..=400
        rates.extend([200, 225, 300, 333, 450, 1, 1000]);
        for m in rates {
            let d = ClockDomain::try_from_mhz("p", m as f64)
                .unwrap_or_else(|e| panic!("{m} MHz: {e}"));
            let product = d.period_fs() as u128 * m as u128;
            if 1_000_000_000 % m == 0 {
                // Representable exactly: period_fs * mhz == 1e9 fs.
                assert_eq!(product, 1_000_000_000, "{m} MHz not exact");
            } else {
                // Best integral period: within half a femtosecond-per-
                // period of 1e9 fs (i.e. |err| * 2 <= mhz).
                let err = product.abs_diff(1_000_000_000);
                assert!(err * 2 <= m as u128, "{m} MHz err {err} fs");
            }
            // And the permissive constructor agrees bit-for-bit.
            assert_eq!(ClockDomain::from_mhz("p", m as f64).period_fs(), d.period_fs());
        }
    }

    #[test]
    fn clock_rate_errors_are_typed() {
        assert_eq!(
            ClockDomain::try_from_mhz("z", 0.0).unwrap_err(),
            ClockRateError::NonPositive
        );
        assert_eq!(
            ClockDomain::try_from_mhz("z", -5.0).unwrap_err(),
            ClockRateError::NonPositive
        );
        assert_eq!(
            ClockDomain::try_from_mhz("z", f64::NAN).unwrap_err(),
            ClockRateError::NonPositive
        );
        assert_eq!(
            ClockDomain::try_from_mhz("z", 212.5).unwrap_err(),
            ClockRateError::NonIntegralMhz
        );
        assert_eq!(
            ClockDomain::try_from_mhz("z", 2e9).unwrap_err(),
            ClockRateError::PeriodUnderflow
        );
        // The permissive constructor still accepts fractional MHz via
        // nearest-femtosecond rounding (legacy behavior, reporting-only
        // paths), without drifting the integral-MHz cases.
        assert_eq!(ClockDomain::from_mhz("z", 212.5).period_fs(), 4_705_882);
    }

    #[test]
    fn simultaneous_edges_fire_together() {
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("a", 100.0),
            ClockDomain::from_mhz("b", 100.0),
        ]);
        let fired = s.step();
        assert_eq!(fired_vec(fired), vec![0, 1]);
        assert_eq!(fired.count(), 2);
    }

    /// Drive `a` with one leap and `b` stepwise for the reported step
    /// count; the two schedulers must be indistinguishable afterwards.
    fn assert_leap_matches_steps(mhz: &[f64], warm: u64, domain: usize, k: u64) {
        let mk = || {
            let mut s = Scheduler::new(
                mhz.iter()
                    .enumerate()
                    .map(|(i, &m)| ClockDomain::from_mhz(["a", "b", "c", "d"][i], m))
                    .collect(),
            );
            for _ in 0..warm {
                s.step();
            }
            s
        };
        let mut a = mk();
        let mut b = mk();
        let leap = a.leap(domain, k, u64::MAX).expect("leap supported");
        assert_eq!(leap.fired[domain], k);
        for _ in 0..leap.steps {
            b.step();
        }
        assert_eq!(a.now_fs(), b.now_fs(), "now_fs diverged ({mhz:?}, warm {warm}, k {k})");
        for i in 0..mhz.len() {
            assert_eq!(a.domain(i).cycles, b.domain(i).cycles, "domain {i} cycles");
        }
        // The post-leap edge stream must continue identically.
        for _ in 0..16 {
            assert_eq!(a.step(), b.step());
            assert_eq!(a.now_fs(), b.now_fs());
        }
    }

    #[test]
    fn leap_is_bit_identical_to_stepping() {
        for warm in [0u64, 1, 7] {
            for k in [1u64, 2, 3, 17, 1000] {
                // Rational 2:1, the paper's 225:200 pair (9:8 with huge
                // fs periods), equal clocks (all edges simultaneous),
                // and a single domain.
                assert_leap_matches_steps(&[100.0, 200.0], warm, 0, k);
                assert_leap_matches_steps(&[100.0, 200.0], warm, 1, k);
                assert_leap_matches_steps(&[225.0, 200.0], warm, 0, k);
                assert_leap_matches_steps(&[225.0, 200.0], warm, 1, k);
                assert_leap_matches_steps(&[150.0, 150.0], warm, 0, k);
                assert_leap_matches_steps(&[225.0], warm, 0, k);
                // Irrational-ish pair: periods share only tiny factors.
                assert_leap_matches_steps(&[333.0, 200.0], warm, 0, k);
                // Three domains — the hierarchical cluster/trunk/DRAM
                // shape — leaping on each domain in turn.
                for dom in 0..3 {
                    assert_leap_matches_steps(&[225.0, 300.0, 200.0], warm, dom, k);
                }
                assert_leap_matches_steps(&[100.0, 100.0, 100.0], warm, 1, k);
                assert_leap_matches_steps(&[200.0, 100.0, 50.0], warm, 2, k);
                // Four domains, minimal shared factors.
                assert_leap_matches_steps(&[333.0, 200.0, 225.0, 300.0], warm, 3, k);
            }
        }
    }

    #[test]
    fn beyond_max_leap_domains_refuses_untouched() {
        let mk = || {
            Scheduler::new(
                (0..MAX_LEAP_DOMAINS + 1)
                    .map(|i| ClockDomain::from_mhz("x", (100 + 25 * i) as f64))
                    .collect(),
            )
        };
        let mut s = mk();
        assert!(s.leap(0, 10, u64::MAX).is_none(), "beyond the cap must refuse");
        // Refusal leaves the scheduler bit-identical to a fresh one.
        let mut fresh = mk();
        assert_eq!(s.now_fs(), fresh.now_fs());
        for _ in 0..32 {
            assert_eq!(s.step(), fresh.step());
        }
        // At the cap itself, leaping works and stays exact.
        let mhz: Vec<f64> = (0..MAX_LEAP_DOMAINS).map(|i| (100 + 25 * i) as f64).collect();
        let names = ["d0", "d1", "d2", "d3", "d4", "d5", "d6", "d7"];
        let mk8 = || {
            Scheduler::new(
                mhz.iter()
                    .enumerate()
                    .map(|(i, &m)| ClockDomain::from_mhz(names[i], m))
                    .collect(),
            )
        };
        let mut a = mk8();
        let mut b = mk8();
        let leap = a.leap(0, 50, u64::MAX).expect("8 domains are within the cap");
        assert_eq!(leap.fired[0], 50);
        for _ in 0..leap.steps {
            b.step();
        }
        assert_eq!(a.now_fs(), b.now_fs());
        for i in 0..MAX_LEAP_DOMAINS {
            assert_eq!(a.domain(i).cycles, b.domain(i).cycles, "domain {i}");
        }
    }

    #[test]
    fn leap_respects_the_step_budget() {
        // 2:1 clocks: covering k fabric edges costs ~3k/2 steps (some
        // coincide). A tight budget must shrink the leap, exactly.
        let mut a = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 100.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let mut b = a.clone();
        let leap = a.leap(0, 1000, 10).expect("some prefix fits");
        assert!(leap.steps <= 10);
        assert!(leap.fired[0] < 1000, "budget should have shrunk the leap");
        for _ in 0..leap.steps {
            b.step();
        }
        assert_eq!(a.now_fs(), b.now_fs());
        assert_eq!(a.domain(0).cycles, b.domain(0).cycles);
        assert_eq!(a.domain(1).cycles, b.domain(1).cycles);
        // Zero-budget and zero-edge leaps refuse without touching state.
        let before = a.now_fs();
        assert!(a.leap(0, 0, 100).is_none());
        assert!(a.leap(0, 5, 0).is_none());
        assert_eq!(a.now_fs(), before);
    }

    #[test]
    fn many_domain_leap_is_exact_or_refused_never_wrong() {
        // The release-mode contract for schedulers beyond the paper's
        // fabric+mem pair (e.g. cluster/trunk/DRAM): a leap must either
        // reproduce the exact stepwise state or refuse and change
        // nothing. Pre-fix, the >2-domain span arithmetic was guarded
        // only by a debug_assert — a release build that reached it
        // would have leapt with silently wrong step accounting, which
        // this test catches on every triple below.
        let freq_sets: &[&[f64]] = &[
            &[225.0, 300.0, 200.0], // cluster / trunk / DRAM clocks
            &[100.0, 100.0, 100.0], // all edges simultaneous
            &[200.0, 100.0, 50.0],  // nested 2:1 ratios
            &[333.0, 200.0, 225.0], // only tiny shared factors
        ];
        for mhz in freq_sets {
            for warm in [0u64, 1, 5] {
                for k in [1u64, 2, 13, 400] {
                    let mk = || {
                        let mut s = Scheduler::new(
                            mhz.iter()
                                .enumerate()
                                .map(|(i, &m)| {
                                    ClockDomain::from_mhz(["a", "b", "c", "d"][i], m)
                                })
                                .collect(),
                        );
                        for _ in 0..warm {
                            s.step();
                        }
                        s
                    };
                    let mut a = mk();
                    let mut b = mk();
                    match a.leap(0, k, u64::MAX) {
                        Some(leap) => {
                            // Exact: indistinguishable from stepping.
                            assert_eq!(leap.fired[0], k, "{mhz:?} warm {warm}");
                            for _ in 0..leap.steps {
                                b.step();
                            }
                        }
                        None => {
                            // Refused: state untouched.
                        }
                    }
                    assert_eq!(a.now_fs(), b.now_fs(), "{mhz:?} warm {warm} k {k}");
                    for i in 0..mhz.len() {
                        assert_eq!(
                            a.domain(i).cycles,
                            b.domain(i).cycles,
                            "{mhz:?} warm {warm} k {k} domain {i}"
                        );
                    }
                    // The post-leap edge stream continues identically.
                    for _ in 0..8 {
                        assert_eq!(a.step(), b.step(), "{mhz:?} warm {warm} k {k}");
                        assert_eq!(a.now_fs(), b.now_fs());
                    }
                }
            }
        }
    }

    /// Pairwise simultaneity count through the same Prog/intersect
    /// machinery `span_for` chains — the 2-domain special case.
    fn coincidences(a: u64, p: u64, b: u64, q: u64, hi: u64) -> u64 {
        intersect(Prog::new(a, p, hi), Prog::new(b, q, hi), hi).count(hi)
    }

    #[test]
    fn coincidence_counting_matches_brute_force() {
        // Cross-check the CRT against direct enumeration on small grids.
        for (a, p, b, q, hi) in [
            (0u64, 3u64, 0u64, 5u64, 100u64),
            (2, 4, 6, 6, 200),
            (1, 7, 3, 11, 500),
            (5, 10, 5, 15, 1000),
            (4, 8, 7, 12, 0),
            (9, 2, 4, 2, 50),
        ] {
            let brute = (0..=hi)
                .filter(|&t| t >= a && t >= b && (t - a) % p == 0 && (t - b) % q == 0)
                .count() as u64;
            assert_eq!(coincidences(a, p, b, q, hi), brute, "({a},{p},{b},{q},{hi})");
        }
    }

    #[test]
    fn n_domain_union_counting_matches_brute_force() {
        // The N-domain counting path: inclusion–exclusion over every
        // non-empty subset, chained through `intersect`, cross-checked
        // against direct enumeration. Fixed cases cover the degenerate
        // shapes (identical periods, qg == 1 divisor chains, coprime
        // and non-coprime triples); the seeded sweep covers random 3–4
        // domain grids.
        let mut cases: Vec<Vec<(u64, u64)>> = vec![
            vec![(0, 3), (0, 5), (0, 7)],            // pairwise coprime
            vec![(2, 4), (6, 6), (4, 8)],            // shared factors
            vec![(1, 5), (1, 5), (1, 5)],            // identical progressions
            vec![(3, 5), (1, 5), (4, 5)],            // equal periods, offsets
            vec![(0, 2), (0, 4), (0, 8)],            // qg == 1 divisor chain
            vec![(3, 9), (0, 3), (12, 27)],          // nested multiples, offset
            vec![(5, 10), (5, 15), (5, 6), (5, 35)], // 4-way, common origin
            vec![(7, 11), (3, 13), (0, 17), (1, 2)], // 4-way coprime
            vec![(40, 3), (0, 1), (200, 7)],         // one starts past small hi
        ];
        let mut prng = crate::util::Prng::new(0xC10C);
        for _ in 0..64 {
            let n = 3 + (prng.below(2) as usize);
            cases.push((0..n).map(|_| (prng.below(24), 1 + prng.below(12))).collect());
        }
        for case in &cases {
            for hi in [0u64, 1, 17, 100, 251] {
                let brute = (0..=hi)
                    .filter(|&t| case.iter().any(|&(a, p)| t >= a && (t - a) % p == 0))
                    .count() as u64;
                let mut counted = 0i128;
                for mask in 1usize..(1 << case.len()) {
                    let mut prog: Option<Prog> = None;
                    for (i, &(a, p)) in case.iter().enumerate() {
                        if mask & (1 << i) != 0 {
                            let s = Prog::new(a, p, hi);
                            prog = Some(match prog {
                                None => s,
                                Some(q) => intersect(q, s, hi),
                            });
                        }
                    }
                    let c = prog.unwrap().count(hi) as i128;
                    if mask.count_ones() % 2 == 1 {
                        counted += c;
                    } else {
                        counted -= c;
                    }
                }
                assert_eq!(counted as u64, brute, "{case:?} hi {hi}");
            }
        }
    }

    #[test]
    fn fired_mask_iterates_set_bits() {
        let f = Fired(0b1010_0001);
        assert_eq!(fired_vec(f), vec![0, 5, 7]);
        assert!(f.contains(5) && !f.contains(1));
        assert!(Fired::EMPTY.is_empty());
        assert_eq!(f.iter().len(), 3);
    }
}
