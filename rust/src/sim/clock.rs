//! Multi-rate clocking.
//!
//! The paper's systems have (at least) two clock domains: the DDR3
//! memory controller's fixed 200 MHz domain and the accelerator fabric
//! domain whose frequency is whatever place-and-route achieves (25–450
//! MHz in Fig 6). Bandwidth delivered to the accelerator depends on the
//! *ratio* of these clocks, so the simulator models domains explicitly.
//!
//! [`Scheduler`] advances simulated time edge by edge: at each step it
//! finds the domain(s) with the earliest next rising edge and reports
//! which domains fire as a [`Fired`] bitmask — a `Copy` value, so the
//! hot loop performs **no heap allocation**. Components are grouped per
//! domain by the netlist owner, which ticks + commits them when their
//! domain fires.
//!
//! Periods are carried in **femtoseconds**: at picosecond granularity a
//! 225 MHz clock rounds to 4444 ps (≈225.02 MHz), a 1e-4 relative error
//! that drifts systematically over long runs. At femtosecond granularity
//! the worst-case rounding error is 0.5 fs per period (relative error
//! ≤ ~1e-7 for every Fig 6 frequency), which keeps multi-billion-edge
//! runs on the intended clock ratio.

/// Picoseconds are the simulator's reporting unit; periods are tracked
/// at this finer granularity internally.
pub const FS_PER_PS: u64 = 1_000;

/// One clock domain, defined by its period in femtoseconds.
#[derive(Clone, Debug)]
pub struct ClockDomain {
    pub name: &'static str,
    period_fs: u64,
    /// Cycles elapsed in this domain.
    pub cycles: u64,
    /// Absolute time (fs) of the next rising edge.
    next_edge_fs: u64,
}

impl ClockDomain {
    pub fn from_mhz(name: &'static str, mhz: f64) -> Self {
        assert!(mhz > 0.0, "clock {name} must have positive frequency");
        // 1 MHz -> 1e9 fs period.
        let period_fs = (1_000_000_000.0 / mhz).round() as u64;
        assert!(period_fs > 0, "clock {name} period underflows 1 fs");
        ClockDomain { name, period_fs, cycles: 0, next_edge_fs: 0 }
    }

    pub fn period_fs(&self) -> u64 {
        self.period_fs
    }

    /// Period in picoseconds (rounded; reporting only — stepping uses
    /// the exact femtosecond period).
    pub fn period_ps(&self) -> u64 {
        (self.period_fs + FS_PER_PS / 2) / FS_PER_PS
    }

    pub fn freq_mhz(&self) -> f64 {
        1_000_000_000.0 / self.period_fs as f64
    }
}

/// The set of domains that fired at one scheduler step, as a bitmask.
/// `Copy`, allocation-free, iterable in ascending domain order.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub struct Fired(u64);

impl Fired {
    pub const EMPTY: Fired = Fired(0);

    #[inline(always)]
    pub fn contains(self, domain: usize) -> bool {
        self.0 & (1u64 << domain) != 0
    }

    #[inline(always)]
    pub fn is_empty(self) -> bool {
        self.0 == 0
    }

    #[inline(always)]
    pub fn count(self) -> u32 {
        self.0.count_ones()
    }

    #[inline(always)]
    pub fn iter(self) -> FiredIter {
        FiredIter(self.0)
    }

    /// The raw bitmask (bit i = domain i fired).
    #[inline(always)]
    pub fn mask(self) -> u64 {
        self.0
    }
}

impl IntoIterator for Fired {
    type Item = usize;
    type IntoIter = FiredIter;

    #[inline(always)]
    fn into_iter(self) -> FiredIter {
        FiredIter(self.0)
    }
}

/// Iterator over set bits of a [`Fired`] mask, ascending.
#[derive(Clone, Copy, Debug)]
pub struct FiredIter(u64);

impl Iterator for FiredIter {
    type Item = usize;

    #[inline(always)]
    fn next(&mut self) -> Option<usize> {
        if self.0 == 0 {
            return None;
        }
        let i = self.0.trailing_zeros() as usize;
        self.0 &= self.0 - 1;
        Some(i)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let n = self.0.count_ones() as usize;
        (n, Some(n))
    }
}

impl ExactSizeIterator for FiredIter {}

/// Edge-ordered scheduler over a set of clock domains.
#[derive(Clone, Debug)]
pub struct Scheduler {
    domains: Vec<ClockDomain>,
    now_fs: u64,
}

impl Scheduler {
    pub fn new(domains: Vec<ClockDomain>) -> Self {
        assert!(!domains.is_empty());
        assert!(domains.len() <= 64, "Fired bitmask supports at most 64 domains");
        Scheduler { domains, now_fs: 0 }
    }

    /// Single-domain convenience constructor.
    pub fn single(name: &'static str, mhz: f64) -> Self {
        Scheduler::new(vec![ClockDomain::from_mhz(name, mhz)])
    }

    pub fn now_fs(&self) -> u64 {
        self.now_fs
    }

    /// Current simulated time in picoseconds (truncated from fs).
    pub fn now_ps(&self) -> u64 {
        self.now_fs / FS_PER_PS
    }

    pub fn domain(&self, idx: usize) -> &ClockDomain {
        &self.domains[idx]
    }

    pub fn num_domains(&self) -> usize {
        self.domains.len()
    }

    /// Advance to the next rising edge(s). Returns the bitmask of every
    /// domain that fires at that instant (simultaneous edges fire
    /// together, as in RTL simulation) and updates their cycle counters.
    /// Allocation-free: the returned [`Fired`] is a `Copy` bitmask.
    #[inline]
    pub fn step(&mut self) -> Fired {
        let mut t = u64::MAX;
        for d in self.domains.iter() {
            t = t.min(d.next_edge_fs);
        }
        self.now_fs = t;
        let mut mask = 0u64;
        for (i, d) in self.domains.iter_mut().enumerate() {
            if d.next_edge_fs == t {
                d.cycles += 1;
                // u64 femtoseconds cap the horizon at ~5.1 hours of
                // simulated time; fail loudly instead of wrapping and
                // silently corrupting the clock ratio.
                d.next_edge_fs = d
                    .next_edge_fs
                    .checked_add(d.period_fs)
                    .expect("simulated time overflowed u64 femtoseconds (~5.1 h)");
                mask |= 1u64 << i;
            }
        }
        Fired(mask)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fired_vec(f: Fired) -> Vec<usize> {
        f.iter().collect()
    }

    #[test]
    fn single_domain_counts_cycles() {
        let mut s = Scheduler::single("clk", 200.0);
        for _ in 0..10 {
            let fired = s.step();
            assert_eq!(fired_vec(fired), vec![0]);
            assert!(fired.contains(0));
            assert_eq!(fired.count(), 1);
        }
        assert_eq!(s.domain(0).cycles, 10);
        // 200 MHz -> 5 ns period; 10 edges end at t = 9 periods after the
        // first edge at t=0.
        assert_eq!(s.now_ps(), 9 * 5_000);
    }

    #[test]
    fn two_to_one_ratio() {
        // Fabric at 100 MHz, controller at 200 MHz: controller should see
        // exactly 2x the edges over a long window.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 100.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..3000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        assert!(mem > 0 && fab > 0);
        let ratio = mem as f64 / fab as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn irrational_ratio_approximates() {
        // 225 MHz fabric vs 200 MHz controller — the Fig 6 sweet spot.
        // With femtosecond periods the ratio must be right to ~1e-6 even
        // on a short window (it was only ~1e-2 at ps granularity).
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 225.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..10_000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        let ratio = fab as f64 / mem as f64;
        assert!((ratio - 225.0 / 200.0).abs() < 0.002, "ratio {ratio}");
    }

    #[test]
    fn long_run_does_not_drift() {
        // The satellite fix this guards: at ps granularity 225 MHz became
        // 4444 ps (225.02 MHz), so over 1M controller cycles the fabric
        // gained ~100 edges vs the exact 9:8 ratio. At fs granularity the
        // error bound over the same window is a handful of edges.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 225.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        while s.domain(1).cycles < 1_000_000 {
            s.step();
        }
        let fab = s.domain(0).cycles as i64;
        let expect = 1_000_000i64 * 9 / 8;
        assert!(
            (fab - expect).abs() <= 4,
            "fabric cycles {fab} drifted from exact {expect}"
        );
    }

    #[test]
    fn period_precision_is_femtoseconds() {
        let d = ClockDomain::from_mhz("f", 225.0);
        assert_eq!(d.period_fs(), 4_444_444);
        assert_eq!(d.period_ps(), 4_444);
        assert!((d.freq_mhz() - 225.0).abs() < 1e-4);
    }

    #[test]
    fn simultaneous_edges_fire_together() {
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("a", 100.0),
            ClockDomain::from_mhz("b", 100.0),
        ]);
        let fired = s.step();
        assert_eq!(fired_vec(fired), vec![0, 1]);
        assert_eq!(fired.count(), 2);
    }

    #[test]
    fn fired_mask_iterates_set_bits() {
        let f = Fired(0b1010_0001);
        assert_eq!(fired_vec(f), vec![0, 5, 7]);
        assert!(f.contains(5) && !f.contains(1));
        assert!(Fired::EMPTY.is_empty());
        assert_eq!(f.iter().len(), 3);
    }
}
