//! Multi-rate clocking.
//!
//! The paper's systems have (at least) two clock domains: the DDR3
//! memory controller's fixed 200 MHz domain and the accelerator fabric
//! domain whose frequency is whatever place-and-route achieves (25–450
//! MHz in Fig 6). Bandwidth delivered to the accelerator depends on the
//! *ratio* of these clocks, so the simulator models domains explicitly.
//!
//! [`Scheduler`] advances simulated time edge by edge: at each step it
//! finds the domain(s) with the earliest next rising edge and reports
//! which domains fire. Components are grouped per domain by the netlist
//! owner, which ticks + commits them when their domain fires.

/// One clock domain, defined by its period in picoseconds.
#[derive(Clone, Debug)]
pub struct ClockDomain {
    pub name: &'static str,
    pub period_ps: u64,
    /// Cycles elapsed in this domain.
    pub cycles: u64,
    /// Absolute time (ps) of the next rising edge.
    next_edge_ps: u64,
}

impl ClockDomain {
    pub fn from_mhz(name: &'static str, mhz: f64) -> Self {
        assert!(mhz > 0.0, "clock {name} must have positive frequency");
        let period_ps = (1_000_000.0 / mhz).round() as u64;
        ClockDomain { name, period_ps, cycles: 0, next_edge_ps: 0 }
    }

    pub fn freq_mhz(&self) -> f64 {
        1_000_000.0 / self.period_ps as f64
    }
}

/// Edge-ordered scheduler over a set of clock domains.
#[derive(Debug)]
pub struct Scheduler {
    domains: Vec<ClockDomain>,
    now_ps: u64,
}

impl Scheduler {
    pub fn new(domains: Vec<ClockDomain>) -> Self {
        assert!(!domains.is_empty());
        Scheduler { domains, now_ps: 0 }
    }

    /// Single-domain convenience constructor.
    pub fn single(name: &'static str, mhz: f64) -> Self {
        Scheduler::new(vec![ClockDomain::from_mhz(name, mhz)])
    }

    pub fn now_ps(&self) -> u64 {
        self.now_ps
    }

    pub fn domain(&self, idx: usize) -> &ClockDomain {
        &self.domains[idx]
    }

    pub fn num_domains(&self) -> usize {
        self.domains.len()
    }

    /// Advance to the next rising edge(s). Returns the indices of every
    /// domain that fires at that instant (simultaneous edges fire
    /// together, as in RTL simulation) and updates their cycle counters.
    pub fn step(&mut self) -> Vec<usize> {
        let t = self.domains.iter().map(|d| d.next_edge_ps).min().unwrap();
        self.now_ps = t;
        let mut fired = Vec::new();
        for (i, d) in self.domains.iter_mut().enumerate() {
            if d.next_edge_ps == t {
                d.cycles += 1;
                d.next_edge_ps += d.period_ps;
                fired.push(i);
            }
        }
        fired
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_domain_counts_cycles() {
        let mut s = Scheduler::single("clk", 200.0);
        for _ in 0..10 {
            let fired = s.step();
            assert_eq!(fired, vec![0]);
        }
        assert_eq!(s.domain(0).cycles, 10);
        // 200 MHz -> 5 ns period; 10 edges end at t = 9 periods after the
        // first edge at t=0.
        assert_eq!(s.now_ps(), 9 * 5_000);
    }

    #[test]
    fn two_to_one_ratio() {
        // Fabric at 100 MHz, controller at 200 MHz: controller should see
        // exactly 2x the edges over a long window.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 100.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..3000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        assert!(mem > 0 && fab > 0);
        let ratio = mem as f64 / fab as f64;
        assert!((ratio - 2.0).abs() < 0.01, "ratio {ratio}");
    }

    #[test]
    fn irrational_ratio_approximates() {
        // 225 MHz fabric vs 200 MHz controller — the Fig 6 sweet spot.
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("fabric", 225.0),
            ClockDomain::from_mhz("mem", 200.0),
        ]);
        let (mut fab, mut mem) = (0u64, 0u64);
        for _ in 0..10_000 {
            for d in s.step() {
                match d {
                    0 => fab += 1,
                    1 => mem += 1,
                    _ => unreachable!(),
                }
            }
        }
        let ratio = fab as f64 / mem as f64;
        assert!((ratio - 225.0 / 200.0).abs() < 0.02, "ratio {ratio}");
    }

    #[test]
    fn simultaneous_edges_fire_together() {
        let mut s = Scheduler::new(vec![
            ClockDomain::from_mhz("a", 100.0),
            ClockDomain::from_mhz("b", 100.0),
        ]);
        let fired = s.step();
        assert_eq!(fired, vec![0, 1]);
    }
}
