//! Cycle-accurate simulation kernel.
//!
//! The interconnect, DRAM controller, and layer-processor models are all
//! *clocked components*: structs that advance one fabric cycle at a time
//! under two-phase (evaluate/commit) semantics so the result of a cycle
//! never depends on the order in which components are ticked.
//!
//! * [`channel::Channel`] — a registered valid/ready stream between two
//!   components: pushes become visible at the next commit, `ready` is
//!   computed against start-of-cycle occupancy (like an RTL FIFO with a
//!   registered `full` flag).
//! * [`clock::ClockDomain`] / [`clock::Scheduler`] — multi-rate clocking
//!   (the DDR3 controller runs in its own 200 MHz domain; the fabric
//!   runs at whatever the P&R model says the design closes at).
//! * [`stats::Stats`] — named counters shared by all components.
//! * [`trace::Trace`] — optional bounded event trace for debugging;
//!   [`trace::ScenarioTrace`] — the canonical capture/replay trace the
//!   workload scenario engine records and re-drives.

pub mod channel;
pub mod clock;
pub mod stats;
pub mod trace;

pub use channel::Channel;
pub use clock::{ClockDomain, Fired, Leap, Scheduler};
pub use stats::{Counter, SampleId, Stats};
pub use trace::{ScenarioTrace, Trace};

/// A clocked hardware component. `tick` evaluates one cycle's worth of
/// combinational logic + register updates against the component's *own*
/// state and its channels' committed state; cross-component visibility of
/// channel pushes is deferred to [`Channel::commit`], which the owner of
/// the netlist calls once per cycle after all components have ticked.
pub trait Clocked {
    fn tick(&mut self, cycle: u64);
}
