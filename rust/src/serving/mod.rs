//! Inference-serving front-end (PR 7): open-loop arrivals, per-model
//! request queues, dynamic batching, SLO accounting. PR 10 makes it
//! overload-robust: bounded queues with typed admission policies
//! (`queue_cap`/`overload`), per-request deadlines (`deadline`),
//! deterministic retry with pre-drawn exponential backoff
//! (`retries`/`backoff`), and fault-aware recovery of a
//! degrade-quiesced tenant's in-flight batch.
//!
//! The scenario engine replays fixed layer schedules; this layer turns
//! each tenant into a *served model*: requests arrive open-loop (seeded
//! Poisson or an explicit arrival trace), queue per tenant, and are
//! packed into batches by a max-batch / max-wait policy. One dispatched
//! batch executes one full pass of the tenant's network through the
//! fabric (batching amortizes: a pass serves up to `max_batch` queued
//! requests). Latency is measured arrival → pass completion, against a
//! per-model SLO target.
//!
//! The design follows the `fault` layer's governing rule: **the serving
//! workload is part of the simulated machine, never an intervention on
//! the simulator**. Every arrival cycle is pre-materialized at build
//! into a [`ServingState`] (per-tenant seed-keyed PRNG streams), so:
//!
//! * **data-independence**: whether a request arrives at cycle `c` —
//!   and what every retry attempt would wait, should its batch be
//!   failed fast — depends only on `(spec, tenant)`, never on payload
//!   words or simulation state — elided-vs-full runs see the identical
//!   schedule. Admission (shed), expiry (timeout), and dispatch are
//!   all functions of `(state, fabric cycle)`, so the overload
//!   machinery inherits the same property;
//! * **leap-exactness**: between bursts the fabric is genuinely idle,
//!   and [`ServingRun::next_event`] reports the earliest cycle at which
//!   the serving layer could act (next unadmitted arrival, next
//!   max-wait dispatch deadline, next request-deadline expiry, next
//!   backed-off retry re-admission) — the engine caps idle-edge leaps
//!   there, exactly like staggered tenant starts and
//!   `FaultState::fabric_leap_cap`, so steady-state serving runs are
//!   cheap under `SimBackend::fast()` without moving a single event;
//! * **seq-vs-par**: the schedule is owned by one single-threaded
//!   `System`; parallel sweeps shard whole scenarios.
//!
//! Queue depth, batch occupancy, request latency (the p50/p99 source),
//! and completion/SLO counters land in the ordinary
//! `Counter`/`SampleId` registries, so they flow through stats reports,
//! fingerprints, and captured traces like every other series.

use crate::config::Value;
use crate::sim::stats::{Counter, SampleId, Stats};
use crate::util::Prng;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;

/// Domain-separation key for the per-tenant arrival streams (mixes with
/// the tenant index so each served model draws independently).
const ARRIVAL_KEY: u64 = 0x7365_7276_5f61_7272; // "serv_arr"

/// Domain-separation key for the per-tenant retry-backoff streams —
/// independent of the arrival stream so adding `retries=` to a spec
/// never moves a single arrival.
const BACKOFF_KEY: u64 = 0x7365_7276_5f62_6f66; // "serv_bof"

/// One exponential inter-arrival gap (fabric cycles), floored at 1 so
/// arrivals are strictly increasing and a leap cap is never zero.
fn poisson_gap(prng: &mut Prng, mean_gap: u64) -> u64 {
    let u = prng.f64(); // in [0, 1)
    let g = (-(1.0 - u).ln() * mean_gap as f64).ceil();
    (g as u64).max(1)
}

/// What a bounded queue does when admitting one more request would
/// overflow it (the `overload=` key; meaningless without `queue_cap`).
/// Either way exactly one request is shed, counted in
/// `serving.requests_shed` — the policies differ only in *which* one.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Shed the incoming request; queued work keeps its place.
    #[default]
    Reject,
    /// Shed the oldest queued request to make room for the new one
    /// (fresh work is favoured; stale queued requests were going to
    /// miss their SLO anyway).
    DropOldest,
}

impl OverloadPolicy {
    pub fn parse(s: &str) -> Option<OverloadPolicy> {
        match s {
            "reject" => Some(OverloadPolicy::Reject),
            "drop-oldest" => Some(OverloadPolicy::DropOldest),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            OverloadPolicy::Reject => "reject",
            OverloadPolicy::DropOldest => "drop-oldest",
        }
    }
}

/// The user-facing serving description: what a `[serving]` scenario
/// section, a `--serving=` CLI spec, or a trace header's `serving.*`
/// keys parse into. The default (all zero / empty) means "no serving" —
/// the scenario runs its classic fixed schedule.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServingSpec {
    /// Arrival-stream seed (independent of the workload seed).
    pub seed: u64,
    /// Requests per tenant under the Poisson process (0 = serving off
    /// unless `arrivals` is given).
    pub requests: usize,
    /// Mean Poisson inter-arrival gap in fabric cycles.
    pub mean_gap: u64,
    /// Explicit arrival trace (fabric cycles), shared by every tenant;
    /// overrides the Poisson process when non-empty.
    pub arrivals: Vec<u64>,
    /// Dispatch a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_wait: u64,
    /// Per-request SLO target, arrival → completion, in fabric cycles
    /// (0 = no target: every completion counts as goodput).
    pub slo_cycles: u64,
    /// Bound on each tenant's request queue (0 = unbounded, the
    /// pre-overload behaviour). A full queue sheds per `overload`.
    pub queue_cap: usize,
    /// Admission policy when a bounded queue is full.
    pub overload: OverloadPolicy,
    /// Per-request deadline, arrival → completion, in fabric cycles
    /// (0 = none). A queued or backing-off request whose deadline
    /// passes is abandoned (`serving.requests_timed_out`); a dispatched
    /// request always runs to completion — lateness there is the SLO
    /// tracker's business, not the admission layer's.
    pub deadline: u64,
    /// Retry budget for failed-fast requests (a degrade-quiesced
    /// tenant's in-flight batch): each such request is re-queued up to
    /// this many times before counting in `serving.requests_failed`.
    pub retries: usize,
    /// Exponential-backoff base in fabric cycles: retry attempt `k`
    /// waits `backoff << k` plus a pre-drawn jitter in `[0, backoff)`.
    /// Required >= 1 when `retries` is set.
    pub backoff: u64,
}

impl ServingSpec {
    /// The disabled spec (classic fixed-schedule execution).
    pub fn none() -> ServingSpec {
        ServingSpec::default()
    }

    /// True when this spec serves nothing — the scenario runs exactly
    /// as it did before the serving layer existed.
    pub fn is_none(&self) -> bool {
        self.requests == 0 && self.arrivals.is_empty()
    }

    /// Requests each tenant will serve.
    pub fn requests_per_tenant(&self) -> usize {
        if self.arrivals.is_empty() {
            self.requests
        } else {
            self.arrivals.len()
        }
    }

    /// Apply one parsed `serving.*` key (scenario files route their
    /// `[serving]` section here; trace headers route `serving.*` keys
    /// of `[header]`). Returns `Ok(false)` for keys outside the
    /// `serving.` namespace.
    pub fn apply_key(&mut self, key: &str, value: &Value) -> Result<bool> {
        let Some(k) = key.strip_prefix("serving.") else {
            return Ok(false);
        };
        let as_u64 = |v: &Value| -> Result<u64> { Ok(v.as_usize()? as u64) };
        match k {
            "seed" => self.seed = as_u64(value)?,
            "requests" => self.requests = value.as_usize()?,
            "mean_gap" => self.mean_gap = as_u64(value)?,
            "max_batch" => self.max_batch = value.as_usize()?,
            "max_wait" => self.max_wait = as_u64(value)?,
            "slo_cycles" => self.slo_cycles = as_u64(value)?,
            "arrivals" => self.arrivals = parse_arrivals(value.as_str()?)?,
            "queue_cap" => self.queue_cap = value.as_usize()?,
            "overload" => {
                let s = value.as_str()?;
                self.overload = OverloadPolicy::parse(s).ok_or_else(|| {
                    anyhow!("serving.overload: unknown policy {s:?} (reject | drop-oldest)")
                })?;
            }
            "deadline" => self.deadline = as_u64(value)?,
            "retries" => self.retries = value.as_usize()?,
            "backoff" => self.backoff = as_u64(value)?,
            _ => bail!("unknown serving key {key:?}"),
        }
        Ok(true)
    }

    /// Parse the compact CLI spec: comma-separated items of
    /// `requests=N`, `mean_gap=N`, `max_batch=N`, `max_wait=N`,
    /// `slo=N`, `seed=N`, `arrivals=C+C+...` (cycles joined by `+`),
    /// plus the overload controls `queue_cap=N`,
    /// `overload=reject|drop-oldest`, `deadline=N`, `retries=K`,
    /// `backoff=N`.
    /// Example: `--serving=requests=32,mean_gap=4096,max_batch=4,slo=60000`.
    pub fn parse_cli(spec: &str) -> Result<ServingSpec> {
        let mut out = ServingSpec::default();
        let num = |s: &str, what: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|_| anyhow!("--serving: {what} must be an integer, got {s:?}"))
        };
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("--serving item {item:?}: expected key=value"))?;
            match key {
                "requests" => out.requests = num(val, key)? as usize,
                "mean_gap" => out.mean_gap = num(val, key)?,
                "max_batch" => out.max_batch = num(val, key)? as usize,
                "max_wait" => out.max_wait = num(val, key)?,
                "slo" => out.slo_cycles = num(val, key)?,
                "seed" => out.seed = num(val, key)?,
                "arrivals" => out.arrivals = parse_arrivals(val)?,
                "queue_cap" => out.queue_cap = num(val, key)? as usize,
                "overload" => {
                    out.overload = OverloadPolicy::parse(val).ok_or_else(|| {
                        anyhow!("--serving: unknown overload policy {val:?} (reject | drop-oldest)")
                    })?;
                }
                "deadline" => out.deadline = num(val, key)?,
                "retries" => out.retries = num(val, key)? as usize,
                "backoff" => out.backoff = num(val, key)?,
                _ => bail!("--serving: unknown item {key:?}"),
            }
        }
        out.validate()?;
        Ok(out)
    }

    /// Sanity-check the spec.
    pub fn validate(&self) -> Result<()> {
        if self.is_none() {
            return Ok(());
        }
        ensure!(self.max_batch >= 1, "serving: max_batch must be >= 1");
        if self.arrivals.is_empty() {
            ensure!(
                self.mean_gap >= 1,
                "serving: the Poisson process needs mean_gap >= 1 (or give explicit arrivals)"
            );
        } else {
            // `requests=` and `arrivals=` are mutually exclusive by
            // design: an explicit trace IS the request list, and a
            // spec naming both is ambiguous about which one the author
            // meant. This is a typed error, never a silent override.
            ensure!(
                self.requests == 0,
                "serving: give requests+mean_gap or an explicit arrivals trace, not both \
                 (arrivals would silently win; drop requests= or the trace)"
            );
        }
        if self.queue_cap == 0 {
            ensure!(
                self.overload == OverloadPolicy::Reject,
                "serving: overload=drop-oldest needs a bounded queue (set queue_cap)"
            );
        }
        if self.retries > 0 {
            ensure!(
                self.backoff >= 1,
                "serving: retries need backoff >= 1 (the exponential-backoff base)"
            );
        }
        Ok(())
    }

    /// Canonical `(key, value)` pairs for trace headers (TOML-subset
    /// syntax, fixed order). Empty for the no-serving spec, so classic
    /// captures stay byte-identical to pre-serving builds.
    pub fn header_kv(&self) -> Vec<(&'static str, String)> {
        if self.is_none() {
            return Vec::new();
        }
        let mut kv: Vec<(&'static str, String)> = vec![
            ("serving.seed", self.seed.to_string()),
            ("serving.requests", self.requests.to_string()),
            ("serving.mean_gap", self.mean_gap.to_string()),
            ("serving.max_batch", self.max_batch.to_string()),
            ("serving.max_wait", self.max_wait.to_string()),
            ("serving.slo_cycles", self.slo_cycles.to_string()),
        ];
        // Overload/deadline/retry keys are emitted only when set, so a
        // spec predating them produces a byte-identical header (and the
        // goldens stay untouched). Defaults restore exactly on parse.
        if self.queue_cap > 0 {
            kv.push(("serving.queue_cap", self.queue_cap.to_string()));
        }
        if self.overload != OverloadPolicy::Reject {
            kv.push(("serving.overload", format!("\"{}\"", self.overload.name())));
        }
        if self.deadline > 0 {
            kv.push(("serving.deadline", self.deadline.to_string()));
        }
        if self.retries > 0 {
            kv.push(("serving.retries", self.retries.to_string()));
            kv.push(("serving.backoff", self.backoff.to_string()));
        }
        if !self.arrivals.is_empty() {
            let joined =
                self.arrivals.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("+");
            kv.push(("serving.arrivals", format!("\"{joined}\"")));
        }
        kv
    }
}

/// Parse a `+`- or `,`-separated arrival-cycle list (the `+` form is
/// what CLI specs and trace headers use, where `,` already separates
/// items).
fn parse_arrivals(s: &str) -> Result<Vec<u64>> {
    s.split(['+', ','])
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            t.trim()
                .parse::<u64>()
                .map_err(|_| anyhow!("serving.arrivals: {t:?} is not a cycle number"))
        })
        .collect()
}

/// The materialized arrival schedule: per-tenant sorted arrival cycles,
/// drawn once at build from seed-keyed streams (explicit traces are
/// sorted verbatim). A pure function of the spec and the tenant count,
/// so capture/replay re-arms the identical workload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingState {
    pub spec: ServingSpec,
    /// Arrival cycles per tenant, ascending.
    pub arrivals: Vec<Vec<u64>>,
    /// Pre-drawn retry-backoff delays per tenant, request-major:
    /// `backoffs[t][i * retries + k]` is what attempt `k` of request
    /// `i` waits before re-admission. Drawn once here (the
    /// `FaultState` pattern) so a retried schedule is a pure function
    /// of `(spec, tenant)` — never of simulation state, payload words,
    /// or thread count. Empty when `retries` is 0.
    pub backoffs: Vec<Vec<u64>>,
}

impl ServingState {
    /// Materialize a spec for `tenants` served models.
    pub fn build(spec: &ServingSpec, tenants: usize) -> Result<ServingState> {
        spec.validate()?;
        // An explicit trace is shared by every tenant: sort it ONCE and
        // clone the sorted vector (the old per-tenant clone-and-sort
        // redid identical work `tenants` times).
        let explicit = {
            let mut v = spec.arrivals.clone();
            v.sort_unstable();
            v
        };
        let mut per = Vec::with_capacity(tenants);
        let mut backoffs = Vec::with_capacity(tenants);
        for t in 0..tenants {
            let cycles = if !explicit.is_empty() {
                explicit.clone()
            } else {
                let mut prng = Prng::new(spec.seed ^ ARRIVAL_KEY ^ crate::fault::mix64(t as u64));
                let mut now = 0u64;
                (0..spec.requests)
                    .map(|_| {
                        now += poisson_gap(&mut prng, spec.mean_gap);
                        now
                    })
                    .collect()
            };
            let mut draws = Vec::new();
            if spec.retries > 0 {
                // Exponential base + jitter, every draw materialized up
                // front from a seed-keyed stream independent of the
                // arrival stream. Shifts saturate so absurd retry
                // budgets degrade to "practically never" rather than
                // overflowing.
                let mut prng = Prng::new(spec.seed ^ BACKOFF_KEY ^ crate::fault::mix64(t as u64));
                draws.reserve(cycles.len() * spec.retries);
                for _req in 0..cycles.len() {
                    for k in 0..spec.retries {
                        let base =
                            spec.backoff.checked_shl(k.min(32) as u32).unwrap_or(u64::MAX);
                        draws.push(base.saturating_add(prng.below(spec.backoff)));
                    }
                }
            }
            per.push(cycles);
            backoffs.push(draws);
        }
        Ok(ServingState { spec: spec.clone(), arrivals: per, backoffs })
    }

    /// The last arrival cycle across every tenant (0 when empty) —
    /// the engine's run-budget horizon term.
    pub fn last_arrival(&self) -> u64 {
        self.arrivals.iter().filter_map(|v| v.last().copied()).max().unwrap_or(0)
    }

    /// The pre-drawn backoff delay of attempt `attempt` (0-based) of
    /// tenant `t`'s request `idx`.
    pub fn backoff_delay(&self, t: usize, idx: usize, attempt: u32) -> u64 {
        self.backoffs[t][idx * self.spec.retries + attempt as usize]
    }

    /// Upper bound on the extra simulated time retries can add: the
    /// largest per-tenant sum of pre-drawn delays (the engine's
    /// edge-budget term; saturating, never load-bearing for behaviour).
    pub fn backoff_horizon(&self) -> u64 {
        self.backoffs
            .iter()
            .map(|v| v.iter().fold(0u64, |a, &d| a.saturating_add(d)))
            .max()
            .unwrap_or(0)
    }
}

/// One admitted request, tracked through queueing, dispatch, and (on a
/// failed-fast batch) retry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Req {
    /// Original arrival cycle — the latency and deadline base, stable
    /// across retries.
    pub arrival: u64,
    /// Cycle the request last entered the queue — the max-wait base (a
    /// retry waits from re-admission, not from first arrival). Equals
    /// `arrival` until the first retry, so pre-overload specs batch on
    /// exactly the old schedule.
    pub enqueued: u64,
    /// Index into the tenant's arrival schedule; keys the pre-drawn
    /// backoff stream.
    pub idx: usize,
    /// Dispatch attempts so far (0 = never dispatched).
    pub attempt: u32,
}

/// The live serving front-end one engine run drives: admission from the
/// pre-materialized schedule, per-tenant bounded queues, deadline
/// expiry, the batcher, retry/backoff bookkeeping, and the latency
/// record. All decisions are functions of (state, fabric cycle) —
/// nothing here reads payloads or occupancy.
#[derive(Clone, Debug)]
pub struct ServingRun {
    pub state: ServingState,
    /// Index of the next unadmitted arrival, per tenant.
    next_arrival: Vec<usize>,
    /// Admitted-but-undispatched requests, oldest first.
    queue: Vec<VecDeque<Req>>,
    /// Dispatched-but-uncompleted requests.
    inflight: Vec<Vec<Req>>,
    /// Failed-fast requests waiting out their backoff: `(ready_at,
    /// req)`, ascending by `(ready_at, idx)` so re-admission order is
    /// deterministic.
    pending: Vec<Vec<(u64, Req)>>,
    /// Completed request count per tenant.
    pub completed: Vec<usize>,
    /// Dispatched batch count per tenant.
    pub batches: Vec<usize>,
    /// SLO-met completion count per tenant.
    pub slo_met: Vec<usize>,
    /// Requests shed by the bounded-queue admission policy.
    pub shed: Vec<usize>,
    /// Requests abandoned by deadline expiry.
    pub timed_out: Vec<usize>,
    /// Retry re-admissions scheduled (one request can count several
    /// times, once per attempt).
    pub retried: Vec<usize>,
    /// Requests failed for good (retry budget exhausted).
    pub failed: Vec<usize>,
    /// Completion latencies per tenant, in completion order (the
    /// percentile source; fingerprinted for determinism checks).
    pub latencies: Vec<Vec<u64>>,
}

impl ServingRun {
    pub fn new(state: ServingState) -> ServingRun {
        let n = state.arrivals.len();
        ServingRun {
            state,
            next_arrival: vec![0; n],
            queue: vec![VecDeque::new(); n],
            inflight: vec![Vec::new(); n],
            pending: vec![Vec::new(); n],
            completed: vec![0; n],
            batches: vec![0; n],
            slo_met: vec![0; n],
            shed: vec![0; n],
            timed_out: vec![0; n],
            retried: vec![0; n],
            failed: vec![0; n],
            latencies: vec![Vec::new(); n],
        }
    }

    /// Admission with the bounded-queue policy applied. Queue depth is
    /// sampled only when the queue actually grows or its composition
    /// changes (a rejected request leaves it untouched).
    fn enqueue(&mut self, t: usize, req: Req, stats: &mut Stats) {
        let cap = self.state.spec.queue_cap;
        if cap > 0 && self.queue[t].len() >= cap {
            match self.state.spec.overload {
                OverloadPolicy::Reject => {
                    self.shed[t] += 1;
                    stats.bump(Counter::ServingRequestsShed);
                    return;
                }
                OverloadPolicy::DropOldest => {
                    self.queue[t].pop_front();
                    self.shed[t] += 1;
                    stats.bump(Counter::ServingRequestsShed);
                }
            }
        }
        self.queue[t].push_back(req);
        stats.sample(SampleId::ServingQueueDepth, self.queue[t].len() as u64);
    }

    /// Admit everything due at or before `now`: backed-off retries
    /// whose delay has elapsed first (they are the oldest requests by
    /// arrival), then fresh arrivals — a fixed order, so one edge
    /// receiving both admits them identically on every backend.
    pub fn admit(&mut self, now: u64, stats: &mut Stats) {
        for t in 0..self.queue.len() {
            while self.pending[t].first().is_some_and(|&(ready, _)| ready <= now) {
                let (_, mut req) = self.pending[t].remove(0);
                req.enqueued = now;
                self.enqueue(t, req, stats);
            }
            let arr = &self.state.arrivals[t];
            while self.next_arrival[t] < arr.len() && arr[self.next_arrival[t]] <= now {
                let arrival = arr[self.next_arrival[t]];
                let idx = self.next_arrival[t];
                self.next_arrival[t] += 1;
                stats.bump(Counter::ServingRequestsArrived);
                self.enqueue(t, Req { arrival, enqueued: arrival, idx, attempt: 0 }, stats);
            }
        }
    }

    /// Abandon every queued or backing-off request whose deadline has
    /// passed (`arrival + deadline <= now`). Runs right after `admit`
    /// on every edge, before any dispatch decision — expiry beats
    /// dispatch on ties, and afterwards every surviving request's
    /// expiry cycle is strictly future (the `next_event` guarantee).
    /// In-flight batches are never expired: once dispatched a request
    /// runs to completion.
    pub fn expire(&mut self, now: u64, stats: &mut Stats) {
        let dl = self.state.spec.deadline;
        if dl == 0 {
            return;
        }
        for t in 0..self.queue.len() {
            let before = self.queue[t].len() + self.pending[t].len();
            self.queue[t].retain(|r| r.arrival + dl > now);
            self.pending[t].retain(|(_, r)| r.arrival + dl > now);
            let expired = before - self.queue[t].len() - self.pending[t].len();
            self.timed_out[t] += expired;
            stats.add(Counter::ServingRequestsTimedOut, expired as u64);
        }
    }

    /// Batcher: dispatch tenant `t`'s next batch if the policy fires
    /// (queue reached `max_batch`, or the oldest request has waited
    /// `max_wait` since it was enqueued). Returns the batch size
    /// dispatched.
    pub fn dispatch(&mut self, t: usize, now: u64, stats: &mut Stats) -> Option<usize> {
        let q = &mut self.queue[t];
        let oldest = q.front()?.enqueued;
        let fire = q.len() >= self.state.spec.max_batch || now - oldest >= self.state.spec.max_wait;
        if !fire {
            return None;
        }
        let k = q.len().min(self.state.spec.max_batch);
        for _ in 0..k {
            let mut req = q.pop_front().expect("batch size bounded by queue length");
            req.attempt += 1;
            self.inflight[t].push(req);
        }
        self.batches[t] += 1;
        stats.bump(Counter::ServingBatches);
        stats.sample(SampleId::ServingBatchOccupancy, k as u64);
        Some(k)
    }

    /// Record tenant `t`'s in-flight batch as completed at `now`.
    pub fn complete(&mut self, t: usize, now: u64, stats: &mut Stats) {
        let slo = self.state.spec.slo_cycles;
        for req in std::mem::take(&mut self.inflight[t]) {
            let lat = now - req.arrival;
            self.latencies[t].push(lat);
            self.completed[t] += 1;
            stats.bump(Counter::ServingRequestsCompleted);
            stats.sample(SampleId::ServingLatencyCycles, lat);
            if slo == 0 || lat <= slo {
                self.slo_met[t] += 1;
                stats.bump(Counter::ServingSloMet);
            }
        }
    }

    /// Fail tenant `t`'s in-flight batch fast — the degrade hand-off:
    /// when the watchdog quiesces a wedged tenant, the batch it was
    /// running will never complete, so each of its requests either
    /// schedules a retry after its pre-drawn backoff delay (budget
    /// left) or counts in `serving.requests_failed` (budget spent).
    /// Returns how many failed for good.
    pub fn fail_batch(&mut self, t: usize, now: u64, stats: &mut Stats) -> usize {
        let retries = self.state.spec.retries as u32;
        let mut dead = 0;
        for req in std::mem::take(&mut self.inflight[t]) {
            // `attempt` was bumped at dispatch, so attempt 1 failing
            // consumes the first of `retries` budget slots.
            if req.attempt <= retries {
                let delay = self.state.backoff_delay(t, req.idx, req.attempt - 1);
                let ready = now.saturating_add(delay);
                self.retried[t] += 1;
                stats.bump(Counter::ServingRequestsRetried);
                stats.sample(SampleId::ServingRetryBackoffCycles, delay);
                let pos = self.pending[t]
                    .partition_point(|p| (p.0, p.1.idx) <= (ready, req.idx));
                self.pending[t].insert(pos, (ready, req));
            } else {
                self.failed[t] += 1;
                stats.bump(Counter::ServingRequestsFailed);
                dead += 1;
            }
        }
        dead
    }

    /// Requests currently dispatched into tenant `t`'s running pass.
    pub fn in_flight(&self, t: usize) -> usize {
        self.inflight[t].len()
    }

    /// Admitted-but-undispatched requests in tenant `t`'s queue.
    pub fn queue_depth(&self, t: usize) -> usize {
        self.queue[t].len()
    }

    /// Admitted-but-undispatched requests summed over every tenant
    /// (the observability layer's queue-depth timeline source).
    pub fn total_queued(&self) -> u64 {
        self.queue.iter().map(|q| q.len() as u64).sum()
    }

    /// Requests shed so far, summed over every tenant (the
    /// observability layer's cumulative-shed timeline source — it pairs
    /// with the queue-depth series so a flat depth under a full queue
    /// reads as sheds, not idleness).
    pub fn total_shed(&self) -> u64 {
        self.shed.iter().map(|&s| s as u64).sum()
    }

    /// The next unadmitted arrival cycle of tenant `t`, if any.
    pub fn next_arrival_cycle(&self, t: usize) -> Option<u64> {
        self.state.arrivals[t].get(self.next_arrival[t]).copied()
    }

    /// The earliest deadline-expiry cycle among tenant `t`'s queued and
    /// backing-off requests (`None` when deadlines are off or nothing
    /// can expire).
    pub fn next_deadline(&self, t: usize) -> Option<u64> {
        let dl = self.state.spec.deadline;
        if dl == 0 {
            return None;
        }
        self.queue[t]
            .iter()
            .map(|r| r.arrival + dl)
            .chain(self.pending[t].iter().map(|(_, r)| r.arrival + dl))
            .min()
    }

    /// Per-tenant serving state for watchdog dumps: queue depth,
    /// in-flight batch, backoff population, completion progress, where
    /// requests died (shed / timed out / retried / failed), the next
    /// arrival, and the next pending deadline. Appended to
    /// `System::state_dump` so an overloaded or wedged serving run
    /// shows where its requests went, not just where the fabric is.
    pub fn state_dump(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for t in 0..self.queue.len() {
            let next = match self.next_arrival_cycle(t) {
                Some(c) => c.to_string(),
                None => "-".to_string(),
            };
            let nd = match self.next_deadline(t) {
                Some(c) => c.to_string(),
                None => "-".to_string(),
            };
            let _ = writeln!(
                s,
                "  serving t{t}: queued={} inflight={} backing_off={} completed={}/{} \
                 batches={} shed={} timed_out={} retried={} failed={} \
                 next_arrival={next} next_deadline={nd}",
                self.queue[t].len(),
                self.inflight[t].len(),
                self.pending[t].len(),
                self.completed[t],
                self.state.arrivals[t].len(),
                self.batches[t],
                self.shed[t],
                self.timed_out[t],
                self.retried[t],
                self.failed[t],
            );
        }
        s
    }

    /// Does tenant `t` still have unadmitted, queued, backing-off, or
    /// in-flight work?
    pub fn has_more(&self, t: usize) -> bool {
        self.next_arrival[t] < self.state.arrivals[t].len()
            || !self.queue[t].is_empty()
            || !self.inflight[t].is_empty()
            || !self.pending[t].is_empty()
    }

    /// Every request of every tenant admitted, dispatched, completed?
    /// (Shed, timed-out, and failed requests count as resolved.)
    pub fn all_done(&self) -> bool {
        (0..self.queue.len()).all(|t| !self.has_more(t))
    }

    /// The earliest future cycle at which the serving layer could act:
    /// the next unadmitted arrival of any tenant, the max-wait dispatch
    /// deadline of a *parked* tenant's oldest queued request (busy
    /// tenants dispatch at pass completion, not on a timer), the
    /// re-admission cycle of a backed-off retry, or the deadline expiry
    /// of any queued/backing-off request (expiry changes queue
    /// composition — and therefore dispatch decisions — so it must land
    /// on its exact edge whether the tenant is parked or busy).
    /// `u64::MAX` when nothing is pending — this is the engine's leap
    /// cap, and after `admit`/`expire`/`dispatch` have run at `now`
    /// every value returned is strictly greater than `now` (arrivals
    /// and ready retries `<= now` were admitted, expired requests were
    /// removed, and a parked tenant whose max-wait elapsed was
    /// dispatched), so a leap is never capped at zero.
    pub fn next_event(&self, parked: &[bool]) -> u64 {
        let mut next = u64::MAX;
        for t in 0..self.queue.len() {
            let arr = &self.state.arrivals[t];
            if self.next_arrival[t] < arr.len() {
                next = next.min(arr[self.next_arrival[t]]);
            }
            if parked.get(t).copied().unwrap_or(false) {
                if let Some(front) = self.queue[t].front() {
                    next = next.min(front.enqueued + self.state.spec.max_wait);
                }
            }
            if let Some(&(ready, _)) = self.pending[t].first() {
                next = next.min(ready);
            }
            if let Some(dl) = self.next_deadline(t) {
                next = next.min(dl);
            }
        }
        next
    }
}

/// Per-tenant serving summary, derived from a finished [`ServingRun`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantServing {
    pub arrived: usize,
    pub completed: usize,
    pub batches: usize,
    pub slo_met: usize,
    /// Requests arrived but zero completed — the tenant was admitted
    /// and then starved (e.g. wedged by a fault campaign and degraded
    /// out by the watchdog). When set, the percentile fields below are
    /// defined as 0 by convention; they summarize an empty series, not
    /// an instantaneous latency.
    pub starved: bool,
    /// Requests shed by the bounded-queue admission policy.
    pub shed: usize,
    /// Requests abandoned by deadline expiry.
    pub timed_out: usize,
    /// Retry re-admissions scheduled (attempts, not unique requests).
    pub retried: usize,
    /// Requests failed for good (retry budget exhausted on a
    /// failed-fast batch).
    pub failed: usize,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub max_cycles: u64,
}

impl TenantServing {
    /// Goodput in requests per simulated second (SLO-met completions
    /// over the run's simulated wall time).
    pub fn goodput_rps(&self, now_ps: u64) -> f64 {
        if now_ps == 0 {
            0.0
        } else {
            self.slo_met as f64 / (now_ps as f64 * 1e-12)
        }
    }
}

/// The serving block of a `ScenarioOutcome`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingReport {
    pub tenants: Vec<TenantServing>,
}

/// Nearest-rank percentile over an unsorted latency series (`q` in
/// 0..=100); 0 for an empty series. Sorts a private copy — callers
/// taking several percentiles of the same series should sort once and
/// use [`percentile_sorted`] instead.
pub fn percentile(latencies: &[u64], q: u64) -> u64 {
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    percentile_sorted(&sorted, q)
}

/// Nearest-rank percentile over an already-sorted series (`q` in
/// 0..=100); 0 for an empty series.
pub fn percentile_sorted(sorted: &[u64], q: u64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = (q as usize * (sorted.len() - 1)) / 100;
    sorted[idx]
}

impl ServingReport {
    /// Summarize a finished run.
    pub fn from_run(run: &ServingRun) -> ServingReport {
        let tenants = (0..run.latencies.len())
            .map(|t| {
                // Sort once per tenant; every percentile (and the max)
                // indexes the same sorted copy.
                let mut sorted = run.latencies[t].clone();
                sorted.sort_unstable();
                TenantServing {
                    arrived: run.state.arrivals[t].len(),
                    completed: run.completed[t],
                    starved: run.completed[t] == 0 && !run.state.arrivals[t].is_empty(),
                    batches: run.batches[t],
                    slo_met: run.slo_met[t],
                    shed: run.shed[t],
                    timed_out: run.timed_out[t],
                    retried: run.retried[t],
                    failed: run.failed[t],
                    p50_cycles: percentile_sorted(&sorted, 50),
                    p99_cycles: percentile_sorted(&sorted, 99),
                    max_cycles: sorted.last().copied().unwrap_or(0),
                }
            })
            .collect();
        ServingReport { tenants }
    }

    /// The worst per-tenant p99 (the explorer's serving-probe metric).
    pub fn worst_p99(&self) -> u64 {
        self.tenants.iter().map(|t| t.p99_cycles).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson_spec() -> ServingSpec {
        ServingSpec {
            seed: 11,
            requests: 8,
            mean_gap: 500,
            max_batch: 3,
            max_wait: 800,
            slo_cycles: 50_000,
            ..ServingSpec::default()
        }
    }

    #[test]
    fn build_is_deterministic_and_arrivals_strictly_increase() {
        let spec = poisson_spec();
        let a = ServingState::build(&spec, 2).unwrap();
        let b = ServingState::build(&spec, 2).unwrap();
        assert_eq!(a, b, "same spec must rebuild identically");
        for t in 0..2 {
            assert_eq!(a.arrivals[t].len(), 8);
            for w in a.arrivals[t].windows(2) {
                assert!(w[0] < w[1], "arrivals must strictly increase (gap floor 1)");
            }
        }
        // Tenants draw from independent streams.
        assert_ne!(a.arrivals[0], a.arrivals[1]);
        // A different seed moves the schedule.
        let mut other = spec.clone();
        other.seed = 12;
        assert_ne!(ServingState::build(&other, 2).unwrap().arrivals[0], a.arrivals[0]);
    }

    #[test]
    fn explicit_arrivals_are_sorted_and_shared() {
        let spec = ServingSpec {
            arrivals: vec![900, 100, 500],
            max_batch: 2,
            ..ServingSpec::default()
        };
        let st = ServingState::build(&spec, 2).unwrap();
        assert_eq!(st.arrivals[0], vec![100, 500, 900]);
        assert_eq!(st.arrivals[0], st.arrivals[1]);
        assert_eq!(st.last_arrival(), 900);
        assert_eq!(spec.requests_per_tenant(), 3);
    }

    #[test]
    fn batcher_fires_on_max_batch_and_on_max_wait() {
        let spec = ServingSpec {
            arrivals: vec![10, 20, 1_000],
            max_batch: 2,
            max_wait: 300,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(25, &mut stats);
        // Two queued >= max_batch: fires immediately, takes exactly 2.
        assert_eq!(run.dispatch(0, 25, &mut stats), Some(2));
        assert_eq!(run.dispatch(0, 25, &mut stats), None, "queue drained");
        run.complete(0, 600, &mut stats);
        assert_eq!(run.completed[0], 2);
        assert_eq!(run.latencies[0], vec![590, 580]);
        // One request below max_batch: waits for the max-wait deadline.
        run.admit(1_000, &mut stats);
        assert_eq!(run.dispatch(0, 1_100, &mut stats), None, "max_wait not reached");
        assert_eq!(run.dispatch(0, 1_300, &mut stats), Some(1));
        run.complete(0, 1_500, &mut stats);
        assert!(run.all_done());
        assert_eq!(stats.get("serving.requests_arrived"), 3);
        assert_eq!(stats.get("serving.requests_completed"), 3);
        assert_eq!(stats.get("serving.batches_dispatched"), 2);
        assert_eq!(stats.series("serving.latency_cycles").unwrap().count, 3);
        assert_eq!(stats.series("serving.queue_depth").unwrap().count, 3);
        assert_eq!(stats.series("serving.batch_occupancy").unwrap().count, 2);
    }

    #[test]
    fn next_event_is_strictly_future_after_processing() {
        let spec = ServingSpec {
            arrivals: vec![10, 400],
            max_batch: 4,
            max_wait: 100,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        // Before anything arrives: the cap is the first arrival.
        assert_eq!(run.next_event(&[true]), 10);
        run.admit(10, &mut stats);
        assert_eq!(run.dispatch(0, 10, &mut stats), None);
        // Parked with one queued request: cap at the max-wait deadline.
        assert_eq!(run.next_event(&[true]), 110);
        assert!(run.next_event(&[true]) > 10);
        // Busy (not parked): only the future arrival caps.
        assert_eq!(run.next_event(&[false]), 400);
        // Deadline elapsed: dispatch happens, cap moves strictly past.
        assert_eq!(run.dispatch(0, 110, &mut stats), Some(1));
        assert_eq!(run.next_event(&[true]), 400);
        run.complete(0, 120, &mut stats);
        run.admit(400, &mut stats);
        run.dispatch(0, 500, &mut stats);
        run.complete(0, 520, &mut stats);
        assert_eq!(run.next_event(&[true]), u64::MAX);
        assert!(run.all_done());
    }

    #[test]
    fn slo_accounting_splits_met_and_missed() {
        let spec = ServingSpec {
            arrivals: vec![0, 0],
            max_batch: 2,
            slo_cycles: 100,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(0, &mut stats);
        run.dispatch(0, 0, &mut stats);
        run.complete(0, 150, &mut stats);
        assert_eq!(run.completed[0], 2);
        assert_eq!(run.slo_met[0], 0, "150 > slo 100 on both");
        let report = ServingReport::from_run(&run);
        assert_eq!(report.tenants[0].completed, 2);
        assert_eq!(report.tenants[0].slo_met, 0);
        assert_eq!(report.tenants[0].p50_cycles, 150);
        assert_eq!(report.worst_p99(), 150);
        assert!(report.tenants[0].goodput_rps(1_000_000) == 0.0);
    }

    #[test]
    fn starved_tenant_reports_defined_zero_percentiles() {
        // A tenant admitted (arrivals materialized) but with zero
        // completions — e.g. wedged by a fault campaign before its
        // first batch finished — must summarize to defined zeros plus
        // the starved flag, never a panic or a bogus index.
        let spec =
            ServingSpec { arrivals: vec![5, 10], max_batch: 4, ..ServingSpec::default() };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(10, &mut stats); // arrived, queued, never dispatched
        let report = ServingReport::from_run(&run);
        let t = &report.tenants[0];
        assert_eq!((t.arrived, t.completed), (2, 0));
        assert!(t.starved, "zero completions out of {} arrivals", t.arrived);
        assert_eq!((t.p50_cycles, t.p99_cycles, t.max_cycles), (0, 0, 0));
        assert_eq!(t.goodput_rps(1_000_000), 0.0);
        assert_eq!(report.worst_p99(), 0);
        // A tenant with no arrivals at all is idle, not starved.
        let empty = ServingRun::new(
            ServingState::build(&ServingSpec { arrivals: vec![], ..ServingSpec::default() }, 1)
                .unwrap(),
        );
        assert!(!ServingReport::from_run(&empty).tenants[0].starved);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 99), 0);
        assert_eq!(percentile(&[7], 50), 7);
        let lats: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&lats, 0), 1);
        assert_eq!(percentile(&lats, 50), 50);
        assert_eq!(percentile(&lats, 99), 99);
        assert_eq!(percentile(&lats, 100), 100);
    }

    #[test]
    fn percentile_sorted_matches_unsorted_wrapper() {
        let lats: Vec<u64> = vec![90, 10, 50, 70, 30];
        let mut sorted = lats.clone();
        sorted.sort_unstable();
        for q in [0, 25, 50, 75, 99, 100] {
            assert_eq!(percentile_sorted(&sorted, q), percentile(&lats, q));
        }
        assert_eq!(percentile_sorted(&[], 50), 0);
    }

    #[test]
    fn state_dump_reports_queue_and_inflight() {
        let spec = ServingSpec {
            arrivals: vec![10, 20, 1_000],
            max_batch: 2,
            max_wait: 300,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(25, &mut stats);
        assert_eq!(run.queue_depth(0), 2);
        assert_eq!(run.total_queued(), 2);
        assert_eq!(run.next_arrival_cycle(0), Some(1_000));
        run.dispatch(0, 25, &mut stats);
        let dump = run.state_dump();
        assert!(dump.contains("serving t0:"), "dump: {dump}");
        assert!(dump.contains("queued=0"), "dump: {dump}");
        assert!(dump.contains("inflight=2"), "dump: {dump}");
        assert!(dump.contains("completed=0/3"), "dump: {dump}");
        assert!(dump.contains("next_arrival=1000"), "dump: {dump}");
        run.complete(0, 600, &mut stats);
        run.admit(1_000, &mut stats);
        run.dispatch(0, 1_300, &mut stats);
        run.complete(0, 1_500, &mut stats);
        assert!(run.state_dump().contains("next_arrival=-"));
        assert_eq!(run.total_queued(), 0);
    }

    #[test]
    fn cli_spec_round_trips_through_header_kv() {
        let spec = ServingSpec::parse_cli(
            "requests=16,mean_gap=2048,max_batch=4,max_wait=512,slo=90000,seed=5",
        )
        .unwrap();
        assert_eq!(spec.requests, 16);
        assert_eq!(spec.mean_gap, 2048);
        assert_eq!(spec.max_batch, 4);
        let mut back = ServingSpec::none();
        for (k, v) in spec.header_kv() {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            assert!(back.apply_key(k, &value).unwrap(), "{k} must be a serving key");
        }
        assert_eq!(back, spec);
        // Explicit arrival traces round-trip through the quoted form.
        let spec = ServingSpec::parse_cli("arrivals=5+25+125,max_batch=2").unwrap();
        let kv = spec.header_kv();
        let arr = kv.iter().find(|(k, _)| *k == "serving.arrivals").unwrap();
        assert_eq!(arr.1, "\"5+25+125\"");
        let mut back = ServingSpec::none();
        for (k, v) in kv {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            back.apply_key(k, &value).unwrap();
        }
        assert_eq!(back, spec);
    }

    #[test]
    fn bad_specs_are_rejected_with_typed_errors() {
        assert!(ServingSpec::parse_cli("bogus=1").is_err());
        assert!(ServingSpec::parse_cli("requests").is_err(), "wants key=value");
        assert!(ServingSpec::parse_cli("requests=4").is_err(), "needs mean_gap");
        assert!(ServingSpec::parse_cli("requests=4,mean_gap=100,max_batch=0").is_err());
        assert!(
            ServingSpec::parse_cli("requests=4,mean_gap=100,max_batch=1,arrivals=1+2").is_err(),
            "poisson and explicit arrivals are exclusive"
        );
        assert!(ServingSpec::parse_cli("arrivals=1+x,max_batch=1").is_err());
    }

    #[test]
    fn bounded_queue_reject_sheds_the_incoming_request() {
        let spec = ServingSpec {
            arrivals: vec![10, 11, 12, 13],
            max_batch: 8,
            max_wait: 1_000,
            queue_cap: 2,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(20, &mut stats);
        // Cap 2: the first two queue, the last two are shed. The queue
        // keeps the OLDEST work under reject.
        assert_eq!(run.queue_depth(0), 2);
        assert_eq!(run.shed[0], 2);
        assert_eq!(stats.get("serving.requests_arrived"), 4);
        assert_eq!(stats.get("serving.requests_shed"), 2);
        assert_eq!(run.dispatch(0, 1_010, &mut stats), Some(2));
        run.complete(0, 1_020, &mut stats);
        // Shed requests are resolved work: the run terminates.
        assert!(run.all_done());
        let rep = ServingReport::from_run(&run);
        assert_eq!(rep.tenants[0].shed, 2);
        assert_eq!(rep.tenants[0].completed, 2);
        // The survivors are the oldest arrivals (10, 11).
        assert_eq!(run.latencies[0], vec![1_010, 1_009]);
    }

    #[test]
    fn bounded_queue_drop_oldest_sheds_the_front() {
        let spec = ServingSpec {
            arrivals: vec![10, 11, 12, 13],
            max_batch: 8,
            max_wait: 1_000,
            queue_cap: 2,
            overload: OverloadPolicy::DropOldest,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(20, &mut stats);
        assert_eq!(run.queue_depth(0), 2);
        assert_eq!(run.shed[0], 2);
        // The oldest survivor is now arrival 12, so max_wait fires at
        // 12 + 1000.
        assert_eq!(run.dispatch(0, 1_011, &mut stats), None);
        assert_eq!(run.dispatch(0, 1_012, &mut stats), Some(2));
        run.complete(0, 1_022, &mut stats);
        // The survivors are the NEWEST arrivals (12, 13).
        assert_eq!(run.latencies[0], vec![1_010, 1_009]);
        assert!(run.all_done());
    }

    #[test]
    fn deadline_expires_queued_requests_on_the_exact_edge() {
        let spec = ServingSpec {
            arrivals: vec![10, 400],
            max_batch: 1,
            max_wait: 10_000,
            deadline: 100,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(10, &mut stats);
        run.expire(10, &mut stats);
        assert_eq!(run.queue_depth(0), 1);
        // The expiry edge (arrival 10 + deadline 100) caps next_event
        // for busy AND parked tenants.
        assert_eq!(run.next_event(&[false]), 110);
        assert_eq!(run.next_event(&[true]), 110);
        run.expire(109, &mut stats);
        assert_eq!(run.timed_out[0], 0, "109 < expiry edge 110");
        run.expire(110, &mut stats);
        assert_eq!(run.timed_out[0], 1);
        assert_eq!(stats.get("serving.requests_timed_out"), 1);
        assert_eq!(run.queue_depth(0), 0);
        // After expiry at now=110 the next event is strictly future.
        assert_eq!(run.next_event(&[true]), 400);
        run.admit(400, &mut stats);
        run.expire(400, &mut stats);
        run.dispatch(0, 400, &mut stats);
        run.complete(0, 450, &mut stats);
        assert!(run.all_done());
        let rep = ServingReport::from_run(&run);
        assert_eq!((rep.tenants[0].timed_out, rep.tenants[0].completed), (1, 1));
    }

    #[test]
    fn backoff_schedules_predraw_deterministically() {
        let spec = ServingSpec {
            seed: 7,
            requests: 4,
            mean_gap: 1_000,
            max_batch: 2,
            retries: 3,
            backoff: 50,
            ..ServingSpec::default()
        };
        let a = ServingState::build(&spec, 2).unwrap();
        let b = ServingState::build(&spec, 2).unwrap();
        assert_eq!(a, b);
        for t in 0..2 {
            assert_eq!(a.backoffs[t].len(), 4 * 3);
            for i in 0..4 {
                for k in 0..3u32 {
                    let d = a.backoff_delay(t, i, k);
                    let base = 50u64 << k;
                    assert!(d >= base && d < base + 50, "attempt {k}: {d} vs base {base}");
                }
            }
        }
        // Independent per-tenant streams; independent of arrivals.
        assert_ne!(a.backoffs[0], a.backoffs[1]);
        let mut no_retry = spec.clone();
        no_retry.retries = 0;
        no_retry.backoff = 0;
        assert_eq!(
            ServingState::build(&no_retry, 2).unwrap().arrivals,
            a.arrivals,
            "adding retries must not move a single arrival"
        );
        assert!(a.backoff_horizon() > 0);
        assert_eq!(ServingState::build(&no_retry, 2).unwrap().backoff_horizon(), 0);
    }

    #[test]
    fn fail_batch_requeues_with_budget_and_fails_without() {
        let spec = ServingSpec {
            arrivals: vec![5, 6],
            max_batch: 2,
            max_wait: 100,
            retries: 1,
            backoff: 40,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(6, &mut stats);
        assert_eq!(run.dispatch(0, 6, &mut stats), Some(2));
        // First failure: both requests have budget, both back off.
        assert_eq!(run.fail_batch(0, 10, &mut stats), 0);
        assert_eq!(run.retried[0], 2);
        assert_eq!(stats.get("serving.requests_retried"), 2);
        assert!(run.has_more(0), "backed-off retries are live work");
        // The retry re-enters the queue at its pre-drawn ready cycle:
        // base 40 (attempt 0) plus jitter in [0, 40).
        let ready = run.next_event(&[true]);
        assert!(ready >= 10 + 40 && ready < 10 + 80, "base 40 + jitter < 40, got {ready}");
        run.admit(ready - 1, &mut stats);
        assert_eq!(run.queue_depth(0), 0, "not ready yet");
        // Both ready cycles are < 10 + 80, so by 200 both re-admit.
        run.admit(200, &mut stats);
        assert_eq!(run.queue_depth(0), 2);
        // Fail again: attempt 2 exceeds the budget of 1 for both.
        assert_eq!(run.dispatch(0, 200, &mut stats), Some(2));
        assert_eq!(run.fail_batch(0, 250, &mut stats), 2);
        assert_eq!(run.failed[0], 2);
        assert_eq!(stats.get("serving.requests_failed"), 2);
        assert!(run.all_done(), "failed requests are resolved work");
        let rep = ServingReport::from_run(&run);
        assert_eq!((rep.tenants[0].retried, rep.tenants[0].failed), (2, 2));
        assert_eq!(rep.tenants[0].completed, 0);
        assert!(rep.tenants[0].starved);
    }

    #[test]
    fn retry_latency_counts_from_original_arrival() {
        let spec = ServingSpec {
            arrivals: vec![5],
            max_batch: 1,
            retries: 2,
            backoff: 10,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(5, &mut stats);
        run.dispatch(0, 5, &mut stats);
        run.fail_batch(0, 50, &mut stats);
        let ready = run.next_event(&[true]);
        run.admit(ready, &mut stats);
        run.dispatch(0, ready + 500, &mut stats);
        run.complete(0, ready + 600, &mut stats);
        // Latency spans arrival (5) -> completion, backoff included.
        assert_eq!(run.latencies[0], vec![ready + 595]);
        assert!(run.all_done());
    }

    #[test]
    fn state_dump_reports_overload_columns() {
        let spec = ServingSpec {
            arrivals: vec![10, 11, 12],
            max_batch: 8,
            max_wait: 1_000,
            queue_cap: 2,
            deadline: 500,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(20, &mut stats);
        let dump = run.state_dump();
        assert!(dump.contains("shed=1"), "dump: {dump}");
        assert!(dump.contains("timed_out=0"), "dump: {dump}");
        assert!(dump.contains("retried=0"), "dump: {dump}");
        assert!(dump.contains("failed=0"), "dump: {dump}");
        // Next pending deadline: oldest queued arrival (10) + 500.
        assert!(dump.contains("next_deadline=510"), "dump: {dump}");
        run.expire(511, &mut stats);
        let dump = run.state_dump();
        assert!(dump.contains("timed_out=2"), "dump: {dump}");
        assert!(dump.contains("next_deadline=-"), "dump: {dump}");
    }

    #[test]
    fn overload_keys_round_trip_through_header_kv() {
        let spec = ServingSpec::parse_cli(
            "requests=8,mean_gap=512,max_batch=2,queue_cap=3,overload=drop-oldest,\
             deadline=40000,retries=2,backoff=1000",
        )
        .unwrap();
        assert_eq!(spec.queue_cap, 3);
        assert_eq!(spec.overload, OverloadPolicy::DropOldest);
        assert_eq!(spec.deadline, 40_000);
        assert_eq!((spec.retries, spec.backoff), (2, 1_000));
        let mut back = ServingSpec::none();
        for (k, v) in spec.header_kv() {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            assert!(back.apply_key(k, &value).unwrap(), "{k} must be a serving key");
        }
        assert_eq!(back, spec);
        // A spec setting none of the overload keys emits none of them —
        // its header stays byte-identical to a pre-overload build's.
        let old = ServingSpec::parse_cli("requests=8,mean_gap=512,max_batch=2").unwrap();
        for (k, _) in old.header_kv() {
            assert!(
                !["serving.queue_cap", "serving.overload", "serving.deadline",
                  "serving.retries", "serving.backoff"]
                    .contains(&k),
                "{k} must not appear for a pre-overload spec"
            );
        }
    }

    #[test]
    fn overload_specs_are_validated() {
        // drop-oldest without a bound is meaningless.
        assert!(ServingSpec::parse_cli(
            "requests=4,mean_gap=100,max_batch=1,overload=drop-oldest"
        )
        .is_err());
        // Retries need a backoff base.
        assert!(ServingSpec::parse_cli("requests=4,mean_gap=100,max_batch=1,retries=2").is_err());
        // Unknown policy is a typed parse error.
        assert!(ServingSpec::parse_cli(
            "requests=4,mean_gap=100,max_batch=1,queue_cap=2,overload=bogus"
        )
        .is_err());
        // requests= with an explicit trace stays a typed error through
        // the key-value path (the TOML/[header] route), not a silent
        // override.
        let mut spec = ServingSpec::none();
        spec.apply_key("serving.requests", &Value::Int(4)).unwrap();
        spec.apply_key("serving.arrivals", &Value::Str("1+2".into())).unwrap();
        spec.max_batch = 1;
        let err = spec.validate().unwrap_err().to_string();
        assert!(err.contains("not both"), "got: {err}");
    }

    #[test]
    fn no_serving_spec_emits_no_header_keys() {
        assert!(ServingSpec::none().header_kv().is_empty());
        assert!(ServingSpec::none().is_none());
        assert!(ServingSpec::none().validate().is_ok());
        // A defaulted max_batch on a disabled spec is not an error.
        let mut spec = ServingSpec::none();
        spec.seed = 9;
        assert!(spec.is_none(), "seed alone does not enable serving");
    }
}
