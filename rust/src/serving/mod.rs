//! Inference-serving front-end (PR 7): open-loop arrivals, per-model
//! request queues, dynamic batching, SLO accounting.
//!
//! The scenario engine replays fixed layer schedules; this layer turns
//! each tenant into a *served model*: requests arrive open-loop (seeded
//! Poisson or an explicit arrival trace), queue per tenant, and are
//! packed into batches by a max-batch / max-wait policy. One dispatched
//! batch executes one full pass of the tenant's network through the
//! fabric (batching amortizes: a pass serves up to `max_batch` queued
//! requests). Latency is measured arrival → pass completion, against a
//! per-model SLO target.
//!
//! The design follows the `fault` layer's governing rule: **the serving
//! workload is part of the simulated machine, never an intervention on
//! the simulator**. Every arrival cycle is pre-materialized at build
//! into a [`ServingState`] (per-tenant seed-keyed PRNG streams), so:
//!
//! * **data-independence**: whether a request arrives at cycle `c`
//!   depends only on `(spec, tenant)`, never on payload words or
//!   simulation state — elided-vs-full runs see the identical schedule;
//! * **leap-exactness**: between bursts the fabric is genuinely idle,
//!   and [`ServingRun::next_event`] reports the earliest cycle at which
//!   the serving layer could act (next unadmitted arrival, next
//!   max-wait dispatch deadline) — the engine caps idle-edge leaps
//!   there, exactly like staggered tenant starts and
//!   `FaultState::fabric_leap_cap`, so steady-state serving runs are
//!   cheap under `SimBackend::fast()` without moving a single event;
//! * **seq-vs-par**: the schedule is owned by one single-threaded
//!   `System`; parallel sweeps shard whole scenarios.
//!
//! Queue depth, batch occupancy, request latency (the p50/p99 source),
//! and completion/SLO counters land in the ordinary
//! `Counter`/`SampleId` registries, so they flow through stats reports,
//! fingerprints, and captured traces like every other series.

use crate::config::Value;
use crate::sim::stats::{Counter, SampleId, Stats};
use crate::util::Prng;
use anyhow::{anyhow, bail, ensure, Result};
use std::collections::VecDeque;

/// Domain-separation key for the per-tenant arrival streams (mixes with
/// the tenant index so each served model draws independently).
const ARRIVAL_KEY: u64 = 0x7365_7276_5f61_7272; // "serv_arr"

/// One exponential inter-arrival gap (fabric cycles), floored at 1 so
/// arrivals are strictly increasing and a leap cap is never zero.
fn poisson_gap(prng: &mut Prng, mean_gap: u64) -> u64 {
    let u = prng.f64(); // in [0, 1)
    let g = (-(1.0 - u).ln() * mean_gap as f64).ceil();
    (g as u64).max(1)
}

/// The user-facing serving description: what a `[serving]` scenario
/// section, a `--serving=` CLI spec, or a trace header's `serving.*`
/// keys parse into. The default (all zero / empty) means "no serving" —
/// the scenario runs its classic fixed schedule.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServingSpec {
    /// Arrival-stream seed (independent of the workload seed).
    pub seed: u64,
    /// Requests per tenant under the Poisson process (0 = serving off
    /// unless `arrivals` is given).
    pub requests: usize,
    /// Mean Poisson inter-arrival gap in fabric cycles.
    pub mean_gap: u64,
    /// Explicit arrival trace (fabric cycles), shared by every tenant;
    /// overrides the Poisson process when non-empty.
    pub arrivals: Vec<u64>,
    /// Dispatch a batch as soon as this many requests are queued.
    pub max_batch: usize,
    /// ... or once the oldest queued request has waited this long.
    pub max_wait: u64,
    /// Per-request SLO target, arrival → completion, in fabric cycles
    /// (0 = no target: every completion counts as goodput).
    pub slo_cycles: u64,
}

impl ServingSpec {
    /// The disabled spec (classic fixed-schedule execution).
    pub fn none() -> ServingSpec {
        ServingSpec::default()
    }

    /// True when this spec serves nothing — the scenario runs exactly
    /// as it did before the serving layer existed.
    pub fn is_none(&self) -> bool {
        self.requests == 0 && self.arrivals.is_empty()
    }

    /// Requests each tenant will serve.
    pub fn requests_per_tenant(&self) -> usize {
        if self.arrivals.is_empty() {
            self.requests
        } else {
            self.arrivals.len()
        }
    }

    /// Apply one parsed `serving.*` key (scenario files route their
    /// `[serving]` section here; trace headers route `serving.*` keys
    /// of `[header]`). Returns `Ok(false)` for keys outside the
    /// `serving.` namespace.
    pub fn apply_key(&mut self, key: &str, value: &Value) -> Result<bool> {
        let Some(k) = key.strip_prefix("serving.") else {
            return Ok(false);
        };
        let as_u64 = |v: &Value| -> Result<u64> { Ok(v.as_usize()? as u64) };
        match k {
            "seed" => self.seed = as_u64(value)?,
            "requests" => self.requests = value.as_usize()?,
            "mean_gap" => self.mean_gap = as_u64(value)?,
            "max_batch" => self.max_batch = value.as_usize()?,
            "max_wait" => self.max_wait = as_u64(value)?,
            "slo_cycles" => self.slo_cycles = as_u64(value)?,
            "arrivals" => self.arrivals = parse_arrivals(value.as_str()?)?,
            _ => bail!("unknown serving key {key:?}"),
        }
        Ok(true)
    }

    /// Parse the compact CLI spec: comma-separated items of
    /// `requests=N`, `mean_gap=N`, `max_batch=N`, `max_wait=N`,
    /// `slo=N`, `seed=N`, `arrivals=C+C+...` (cycles joined by `+`).
    /// Example: `--serving=requests=32,mean_gap=4096,max_batch=4,slo=60000`.
    pub fn parse_cli(spec: &str) -> Result<ServingSpec> {
        let mut out = ServingSpec::default();
        let num = |s: &str, what: &str| -> Result<u64> {
            s.parse::<u64>()
                .map_err(|_| anyhow!("--serving: {what} must be an integer, got {s:?}"))
        };
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("--serving item {item:?}: expected key=value"))?;
            match key {
                "requests" => out.requests = num(val, key)? as usize,
                "mean_gap" => out.mean_gap = num(val, key)?,
                "max_batch" => out.max_batch = num(val, key)? as usize,
                "max_wait" => out.max_wait = num(val, key)?,
                "slo" => out.slo_cycles = num(val, key)?,
                "seed" => out.seed = num(val, key)?,
                "arrivals" => out.arrivals = parse_arrivals(val)?,
                _ => bail!("--serving: unknown item {key:?}"),
            }
        }
        out.validate()?;
        Ok(out)
    }

    /// Sanity-check the spec.
    pub fn validate(&self) -> Result<()> {
        if self.is_none() {
            return Ok(());
        }
        ensure!(self.max_batch >= 1, "serving: max_batch must be >= 1");
        if self.arrivals.is_empty() {
            ensure!(
                self.mean_gap >= 1,
                "serving: the Poisson process needs mean_gap >= 1 (or give explicit arrivals)"
            );
        } else {
            ensure!(
                self.requests == 0,
                "serving: give requests+mean_gap or an explicit arrivals trace, not both"
            );
        }
        Ok(())
    }

    /// Canonical `(key, value)` pairs for trace headers (TOML-subset
    /// syntax, fixed order). Empty for the no-serving spec, so classic
    /// captures stay byte-identical to pre-serving builds.
    pub fn header_kv(&self) -> Vec<(&'static str, String)> {
        if self.is_none() {
            return Vec::new();
        }
        let mut kv: Vec<(&'static str, String)> = vec![
            ("serving.seed", self.seed.to_string()),
            ("serving.requests", self.requests.to_string()),
            ("serving.mean_gap", self.mean_gap.to_string()),
            ("serving.max_batch", self.max_batch.to_string()),
            ("serving.max_wait", self.max_wait.to_string()),
            ("serving.slo_cycles", self.slo_cycles.to_string()),
        ];
        if !self.arrivals.is_empty() {
            let joined =
                self.arrivals.iter().map(|c| c.to_string()).collect::<Vec<_>>().join("+");
            kv.push(("serving.arrivals", format!("\"{joined}\"")));
        }
        kv
    }
}

/// Parse a `+`- or `,`-separated arrival-cycle list (the `+` form is
/// what CLI specs and trace headers use, where `,` already separates
/// items).
fn parse_arrivals(s: &str) -> Result<Vec<u64>> {
    s.split(['+', ','])
        .filter(|t| !t.trim().is_empty())
        .map(|t| {
            t.trim()
                .parse::<u64>()
                .map_err(|_| anyhow!("serving.arrivals: {t:?} is not a cycle number"))
        })
        .collect()
}

/// The materialized arrival schedule: per-tenant sorted arrival cycles,
/// drawn once at build from seed-keyed streams (explicit traces are
/// sorted verbatim). A pure function of the spec and the tenant count,
/// so capture/replay re-arms the identical workload.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingState {
    pub spec: ServingSpec,
    /// Arrival cycles per tenant, ascending.
    pub arrivals: Vec<Vec<u64>>,
}

impl ServingState {
    /// Materialize a spec for `tenants` served models.
    pub fn build(spec: &ServingSpec, tenants: usize) -> Result<ServingState> {
        spec.validate()?;
        let mut per = Vec::with_capacity(tenants);
        for t in 0..tenants {
            let cycles = if !spec.arrivals.is_empty() {
                let mut v = spec.arrivals.clone();
                v.sort_unstable();
                v
            } else {
                let mut prng = Prng::new(spec.seed ^ ARRIVAL_KEY ^ crate::fault::mix64(t as u64));
                let mut now = 0u64;
                (0..spec.requests)
                    .map(|_| {
                        now += poisson_gap(&mut prng, spec.mean_gap);
                        now
                    })
                    .collect()
            };
            per.push(cycles);
        }
        Ok(ServingState { spec: spec.clone(), arrivals: per })
    }

    /// The last arrival cycle across every tenant (0 when empty) —
    /// the engine's run-budget horizon term.
    pub fn last_arrival(&self) -> u64 {
        self.arrivals.iter().filter_map(|v| v.last().copied()).max().unwrap_or(0)
    }
}

/// The live serving front-end one engine run drives: admission from the
/// pre-materialized schedule, per-tenant queues, the batcher, and the
/// latency record. All decisions are functions of (state, fabric
/// cycle) — nothing here reads payloads or occupancy.
#[derive(Clone, Debug)]
pub struct ServingRun {
    pub state: ServingState,
    /// Index of the next unadmitted arrival, per tenant.
    next_arrival: Vec<usize>,
    /// Admitted-but-undispatched requests: their arrival cycles.
    queue: Vec<VecDeque<u64>>,
    /// Dispatched-but-uncompleted requests: their arrival cycles.
    inflight: Vec<Vec<u64>>,
    /// Completed request count per tenant.
    pub completed: Vec<usize>,
    /// Dispatched batch count per tenant.
    pub batches: Vec<usize>,
    /// SLO-met completion count per tenant.
    pub slo_met: Vec<usize>,
    /// Completion latencies per tenant, in completion order (the
    /// percentile source; fingerprinted for determinism checks).
    pub latencies: Vec<Vec<u64>>,
}

impl ServingRun {
    pub fn new(state: ServingState) -> ServingRun {
        let n = state.arrivals.len();
        ServingRun {
            state,
            next_arrival: vec![0; n],
            queue: vec![VecDeque::new(); n],
            inflight: vec![Vec::new(); n],
            completed: vec![0; n],
            batches: vec![0; n],
            slo_met: vec![0; n],
            latencies: vec![Vec::new(); n],
        }
    }

    /// Admit every arrival due at or before `now` into its queue.
    pub fn admit(&mut self, now: u64, stats: &mut Stats) {
        for t in 0..self.queue.len() {
            let arr = &self.state.arrivals[t];
            while self.next_arrival[t] < arr.len() && arr[self.next_arrival[t]] <= now {
                self.queue[t].push_back(arr[self.next_arrival[t]]);
                self.next_arrival[t] += 1;
                stats.bump(Counter::ServingRequestsArrived);
                stats.sample(SampleId::ServingQueueDepth, self.queue[t].len() as u64);
            }
        }
    }

    /// Batcher: dispatch tenant `t`'s next batch if the policy fires
    /// (queue reached `max_batch`, or the oldest request has waited
    /// `max_wait`). Returns the batch size dispatched.
    pub fn dispatch(&mut self, t: usize, now: u64, stats: &mut Stats) -> Option<usize> {
        let q = &mut self.queue[t];
        let oldest = *q.front()?;
        let fire = q.len() >= self.state.spec.max_batch || now - oldest >= self.state.spec.max_wait;
        if !fire {
            return None;
        }
        let k = q.len().min(self.state.spec.max_batch);
        for _ in 0..k {
            let arrival = q.pop_front().expect("batch size bounded by queue length");
            self.inflight[t].push(arrival);
        }
        self.batches[t] += 1;
        stats.bump(Counter::ServingBatches);
        stats.sample(SampleId::ServingBatchOccupancy, k as u64);
        Some(k)
    }

    /// Record tenant `t`'s in-flight batch as completed at `now`.
    pub fn complete(&mut self, t: usize, now: u64, stats: &mut Stats) {
        let slo = self.state.spec.slo_cycles;
        for arrival in std::mem::take(&mut self.inflight[t]) {
            let lat = now - arrival;
            self.latencies[t].push(lat);
            self.completed[t] += 1;
            stats.bump(Counter::ServingRequestsCompleted);
            stats.sample(SampleId::ServingLatencyCycles, lat);
            if slo == 0 || lat <= slo {
                self.slo_met[t] += 1;
                stats.bump(Counter::ServingSloMet);
            }
        }
    }

    /// Requests currently dispatched into tenant `t`'s running pass.
    pub fn in_flight(&self, t: usize) -> usize {
        self.inflight[t].len()
    }

    /// Admitted-but-undispatched requests in tenant `t`'s queue.
    pub fn queue_depth(&self, t: usize) -> usize {
        self.queue[t].len()
    }

    /// Admitted-but-undispatched requests summed over every tenant
    /// (the observability layer's queue-depth timeline source).
    pub fn total_queued(&self) -> u64 {
        self.queue.iter().map(|q| q.len() as u64).sum()
    }

    /// The next unadmitted arrival cycle of tenant `t`, if any.
    pub fn next_arrival_cycle(&self, t: usize) -> Option<u64> {
        self.state.arrivals[t].get(self.next_arrival[t]).copied()
    }

    /// Per-tenant serving state for watchdog dumps: queue depth,
    /// in-flight batch, completion progress, and the next arrival.
    /// Appended to `System::state_dump` so a wedged serving run shows
    /// where its requests are stuck, not just where the fabric is.
    pub fn state_dump(&self) -> String {
        use std::fmt::Write as _;
        let mut s = String::new();
        for t in 0..self.queue.len() {
            let next = match self.next_arrival_cycle(t) {
                Some(c) => c.to_string(),
                None => "-".to_string(),
            };
            let _ = writeln!(
                s,
                "  serving t{t}: queued={} inflight={} completed={}/{} batches={} next_arrival={next}",
                self.queue[t].len(),
                self.inflight[t].len(),
                self.completed[t],
                self.state.arrivals[t].len(),
                self.batches[t],
            );
        }
        s
    }

    /// Does tenant `t` still have unadmitted, queued, or in-flight
    /// work?
    pub fn has_more(&self, t: usize) -> bool {
        self.next_arrival[t] < self.state.arrivals[t].len()
            || !self.queue[t].is_empty()
            || !self.inflight[t].is_empty()
    }

    /// Every request of every tenant admitted, dispatched, completed?
    pub fn all_done(&self) -> bool {
        (0..self.queue.len()).all(|t| !self.has_more(t))
    }

    /// The earliest future cycle at which the serving layer could act:
    /// the next unadmitted arrival of any tenant, or the max-wait
    /// dispatch deadline of a *parked* tenant's oldest queued request
    /// (busy tenants dispatch at pass completion, not on a timer).
    /// `u64::MAX` when nothing is pending — this is the engine's leap
    /// cap, and after `admit`/`dispatch` have run at `now` every value
    /// returned is strictly greater than `now` (arrivals `<= now` were
    /// admitted; a parked tenant whose deadline elapsed was dispatched),
    /// so a leap is never capped at zero.
    pub fn next_event(&self, parked: &[bool]) -> u64 {
        let mut next = u64::MAX;
        for t in 0..self.queue.len() {
            let arr = &self.state.arrivals[t];
            if self.next_arrival[t] < arr.len() {
                next = next.min(arr[self.next_arrival[t]]);
            }
            if parked.get(t).copied().unwrap_or(false) {
                if let Some(&oldest) = self.queue[t].front() {
                    next = next.min(oldest + self.state.spec.max_wait);
                }
            }
        }
        next
    }
}

/// Per-tenant serving summary, derived from a finished [`ServingRun`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantServing {
    pub arrived: usize,
    pub completed: usize,
    pub batches: usize,
    pub slo_met: usize,
    /// Requests arrived but zero completed — the tenant was admitted
    /// and then starved (e.g. wedged by a fault campaign and degraded
    /// out by the watchdog). When set, the percentile fields below are
    /// defined as 0 by convention; they summarize an empty series, not
    /// an instantaneous latency.
    pub starved: bool,
    pub p50_cycles: u64,
    pub p99_cycles: u64,
    pub max_cycles: u64,
}

impl TenantServing {
    /// Goodput in requests per simulated second (SLO-met completions
    /// over the run's simulated wall time).
    pub fn goodput_rps(&self, now_ps: u64) -> f64 {
        if now_ps == 0 {
            0.0
        } else {
            self.slo_met as f64 / (now_ps as f64 * 1e-12)
        }
    }
}

/// The serving block of a `ScenarioOutcome`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ServingReport {
    pub tenants: Vec<TenantServing>,
}

/// Nearest-rank percentile over an unsorted latency series (`q` in
/// 0..=100); 0 for an empty series. Sorts a private copy — callers
/// taking several percentiles of the same series should sort once and
/// use [`percentile_sorted`] instead.
pub fn percentile(latencies: &[u64], q: u64) -> u64 {
    let mut sorted = latencies.to_vec();
    sorted.sort_unstable();
    percentile_sorted(&sorted, q)
}

/// Nearest-rank percentile over an already-sorted series (`q` in
/// 0..=100); 0 for an empty series.
pub fn percentile_sorted(sorted: &[u64], q: u64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = (q as usize * (sorted.len() - 1)) / 100;
    sorted[idx]
}

impl ServingReport {
    /// Summarize a finished run.
    pub fn from_run(run: &ServingRun) -> ServingReport {
        let tenants = (0..run.latencies.len())
            .map(|t| {
                // Sort once per tenant; every percentile (and the max)
                // indexes the same sorted copy.
                let mut sorted = run.latencies[t].clone();
                sorted.sort_unstable();
                TenantServing {
                    arrived: run.state.arrivals[t].len(),
                    completed: run.completed[t],
                    starved: run.completed[t] == 0 && !run.state.arrivals[t].is_empty(),
                    batches: run.batches[t],
                    slo_met: run.slo_met[t],
                    p50_cycles: percentile_sorted(&sorted, 50),
                    p99_cycles: percentile_sorted(&sorted, 99),
                    max_cycles: sorted.last().copied().unwrap_or(0),
                }
            })
            .collect();
        ServingReport { tenants }
    }

    /// The worst per-tenant p99 (the explorer's serving-probe metric).
    pub fn worst_p99(&self) -> u64 {
        self.tenants.iter().map(|t| t.p99_cycles).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn poisson_spec() -> ServingSpec {
        ServingSpec {
            seed: 11,
            requests: 8,
            mean_gap: 500,
            max_batch: 3,
            max_wait: 800,
            slo_cycles: 50_000,
            ..ServingSpec::default()
        }
    }

    #[test]
    fn build_is_deterministic_and_arrivals_strictly_increase() {
        let spec = poisson_spec();
        let a = ServingState::build(&spec, 2).unwrap();
        let b = ServingState::build(&spec, 2).unwrap();
        assert_eq!(a, b, "same spec must rebuild identically");
        for t in 0..2 {
            assert_eq!(a.arrivals[t].len(), 8);
            for w in a.arrivals[t].windows(2) {
                assert!(w[0] < w[1], "arrivals must strictly increase (gap floor 1)");
            }
        }
        // Tenants draw from independent streams.
        assert_ne!(a.arrivals[0], a.arrivals[1]);
        // A different seed moves the schedule.
        let mut other = spec.clone();
        other.seed = 12;
        assert_ne!(ServingState::build(&other, 2).unwrap().arrivals[0], a.arrivals[0]);
    }

    #[test]
    fn explicit_arrivals_are_sorted_and_shared() {
        let spec = ServingSpec {
            arrivals: vec![900, 100, 500],
            max_batch: 2,
            ..ServingSpec::default()
        };
        let st = ServingState::build(&spec, 2).unwrap();
        assert_eq!(st.arrivals[0], vec![100, 500, 900]);
        assert_eq!(st.arrivals[0], st.arrivals[1]);
        assert_eq!(st.last_arrival(), 900);
        assert_eq!(spec.requests_per_tenant(), 3);
    }

    #[test]
    fn batcher_fires_on_max_batch_and_on_max_wait() {
        let spec = ServingSpec {
            arrivals: vec![10, 20, 1_000],
            max_batch: 2,
            max_wait: 300,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(25, &mut stats);
        // Two queued >= max_batch: fires immediately, takes exactly 2.
        assert_eq!(run.dispatch(0, 25, &mut stats), Some(2));
        assert_eq!(run.dispatch(0, 25, &mut stats), None, "queue drained");
        run.complete(0, 600, &mut stats);
        assert_eq!(run.completed[0], 2);
        assert_eq!(run.latencies[0], vec![590, 580]);
        // One request below max_batch: waits for the max-wait deadline.
        run.admit(1_000, &mut stats);
        assert_eq!(run.dispatch(0, 1_100, &mut stats), None, "max_wait not reached");
        assert_eq!(run.dispatch(0, 1_300, &mut stats), Some(1));
        run.complete(0, 1_500, &mut stats);
        assert!(run.all_done());
        assert_eq!(stats.get("serving.requests_arrived"), 3);
        assert_eq!(stats.get("serving.requests_completed"), 3);
        assert_eq!(stats.get("serving.batches_dispatched"), 2);
        assert_eq!(stats.series("serving.latency_cycles").unwrap().count, 3);
        assert_eq!(stats.series("serving.queue_depth").unwrap().count, 3);
        assert_eq!(stats.series("serving.batch_occupancy").unwrap().count, 2);
    }

    #[test]
    fn next_event_is_strictly_future_after_processing() {
        let spec = ServingSpec {
            arrivals: vec![10, 400],
            max_batch: 4,
            max_wait: 100,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        // Before anything arrives: the cap is the first arrival.
        assert_eq!(run.next_event(&[true]), 10);
        run.admit(10, &mut stats);
        assert_eq!(run.dispatch(0, 10, &mut stats), None);
        // Parked with one queued request: cap at the max-wait deadline.
        assert_eq!(run.next_event(&[true]), 110);
        assert!(run.next_event(&[true]) > 10);
        // Busy (not parked): only the future arrival caps.
        assert_eq!(run.next_event(&[false]), 400);
        // Deadline elapsed: dispatch happens, cap moves strictly past.
        assert_eq!(run.dispatch(0, 110, &mut stats), Some(1));
        assert_eq!(run.next_event(&[true]), 400);
        run.complete(0, 120, &mut stats);
        run.admit(400, &mut stats);
        run.dispatch(0, 500, &mut stats);
        run.complete(0, 520, &mut stats);
        assert_eq!(run.next_event(&[true]), u64::MAX);
        assert!(run.all_done());
    }

    #[test]
    fn slo_accounting_splits_met_and_missed() {
        let spec = ServingSpec {
            arrivals: vec![0, 0],
            max_batch: 2,
            slo_cycles: 100,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(0, &mut stats);
        run.dispatch(0, 0, &mut stats);
        run.complete(0, 150, &mut stats);
        assert_eq!(run.completed[0], 2);
        assert_eq!(run.slo_met[0], 0, "150 > slo 100 on both");
        let report = ServingReport::from_run(&run);
        assert_eq!(report.tenants[0].completed, 2);
        assert_eq!(report.tenants[0].slo_met, 0);
        assert_eq!(report.tenants[0].p50_cycles, 150);
        assert_eq!(report.worst_p99(), 150);
        assert!(report.tenants[0].goodput_rps(1_000_000) == 0.0);
    }

    #[test]
    fn starved_tenant_reports_defined_zero_percentiles() {
        // A tenant admitted (arrivals materialized) but with zero
        // completions — e.g. wedged by a fault campaign before its
        // first batch finished — must summarize to defined zeros plus
        // the starved flag, never a panic or a bogus index.
        let spec =
            ServingSpec { arrivals: vec![5, 10], max_batch: 4, ..ServingSpec::default() };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(10, &mut stats); // arrived, queued, never dispatched
        let report = ServingReport::from_run(&run);
        let t = &report.tenants[0];
        assert_eq!((t.arrived, t.completed), (2, 0));
        assert!(t.starved, "zero completions out of {} arrivals", t.arrived);
        assert_eq!((t.p50_cycles, t.p99_cycles, t.max_cycles), (0, 0, 0));
        assert_eq!(t.goodput_rps(1_000_000), 0.0);
        assert_eq!(report.worst_p99(), 0);
        // A tenant with no arrivals at all is idle, not starved.
        let empty = ServingRun::new(
            ServingState::build(&ServingSpec { arrivals: vec![], ..ServingSpec::default() }, 1)
                .unwrap(),
        );
        assert!(!ServingReport::from_run(&empty).tenants[0].starved);
    }

    #[test]
    fn percentile_is_nearest_rank() {
        assert_eq!(percentile(&[], 99), 0);
        assert_eq!(percentile(&[7], 50), 7);
        let lats: Vec<u64> = (1..=100).collect();
        assert_eq!(percentile(&lats, 0), 1);
        assert_eq!(percentile(&lats, 50), 50);
        assert_eq!(percentile(&lats, 99), 99);
        assert_eq!(percentile(&lats, 100), 100);
    }

    #[test]
    fn percentile_sorted_matches_unsorted_wrapper() {
        let lats: Vec<u64> = vec![90, 10, 50, 70, 30];
        let mut sorted = lats.clone();
        sorted.sort_unstable();
        for q in [0, 25, 50, 75, 99, 100] {
            assert_eq!(percentile_sorted(&sorted, q), percentile(&lats, q));
        }
        assert_eq!(percentile_sorted(&[], 50), 0);
    }

    #[test]
    fn state_dump_reports_queue_and_inflight() {
        let spec = ServingSpec {
            arrivals: vec![10, 20, 1_000],
            max_batch: 2,
            max_wait: 300,
            ..ServingSpec::default()
        };
        let mut run = ServingRun::new(ServingState::build(&spec, 1).unwrap());
        let mut stats = Stats::default();
        run.admit(25, &mut stats);
        assert_eq!(run.queue_depth(0), 2);
        assert_eq!(run.total_queued(), 2);
        assert_eq!(run.next_arrival_cycle(0), Some(1_000));
        run.dispatch(0, 25, &mut stats);
        let dump = run.state_dump();
        assert!(dump.contains("serving t0:"), "dump: {dump}");
        assert!(dump.contains("queued=0"), "dump: {dump}");
        assert!(dump.contains("inflight=2"), "dump: {dump}");
        assert!(dump.contains("completed=0/3"), "dump: {dump}");
        assert!(dump.contains("next_arrival=1000"), "dump: {dump}");
        run.complete(0, 600, &mut stats);
        run.admit(1_000, &mut stats);
        run.dispatch(0, 1_300, &mut stats);
        run.complete(0, 1_500, &mut stats);
        assert!(run.state_dump().contains("next_arrival=-"));
        assert_eq!(run.total_queued(), 0);
    }

    #[test]
    fn cli_spec_round_trips_through_header_kv() {
        let spec = ServingSpec::parse_cli(
            "requests=16,mean_gap=2048,max_batch=4,max_wait=512,slo=90000,seed=5",
        )
        .unwrap();
        assert_eq!(spec.requests, 16);
        assert_eq!(spec.mean_gap, 2048);
        assert_eq!(spec.max_batch, 4);
        let mut back = ServingSpec::none();
        for (k, v) in spec.header_kv() {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            assert!(back.apply_key(k, &value).unwrap(), "{k} must be a serving key");
        }
        assert_eq!(back, spec);
        // Explicit arrival traces round-trip through the quoted form.
        let spec = ServingSpec::parse_cli("arrivals=5+25+125,max_batch=2").unwrap();
        let kv = spec.header_kv();
        let arr = kv.iter().find(|(k, _)| *k == "serving.arrivals").unwrap();
        assert_eq!(arr.1, "\"5+25+125\"");
        let mut back = ServingSpec::none();
        for (k, v) in kv {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            back.apply_key(k, &value).unwrap();
        }
        assert_eq!(back, spec);
    }

    #[test]
    fn bad_specs_are_rejected_with_typed_errors() {
        assert!(ServingSpec::parse_cli("bogus=1").is_err());
        assert!(ServingSpec::parse_cli("requests").is_err(), "wants key=value");
        assert!(ServingSpec::parse_cli("requests=4").is_err(), "needs mean_gap");
        assert!(ServingSpec::parse_cli("requests=4,mean_gap=100,max_batch=0").is_err());
        assert!(
            ServingSpec::parse_cli("requests=4,mean_gap=100,max_batch=1,arrivals=1+2").is_err(),
            "poisson and explicit arrivals are exclusive"
        );
        assert!(ServingSpec::parse_cli("arrivals=1+x,max_batch=1").is_err());
    }

    #[test]
    fn no_serving_spec_emits_no_header_keys() {
        assert!(ServingSpec::none().header_kv().is_empty());
        assert!(ServingSpec::none().is_none());
        assert!(ServingSpec::none().validate().is_ok());
        // A defaulted max_batch on a disabled spec is not an error.
        let mut spec = ServingSpec::none();
        spec.seed = 9;
        assert!(spec.is_none(), "seed alone does not enable serving");
    }
}
