//! Configuration system: design points and system settings, loadable
//! from a TOML-subset file or built programmatically.
//!
//! The offline registry has no `serde`/`toml`, so this module includes a
//! small parser for the subset we use: `[section]` headers,
//! `key = value` pairs (integers, floats, booleans, quoted strings),
//! `#` comments. That covers every config in `examples/` and keeps the
//! launcher dependency-free.

use crate::interconnect::Design;
use crate::types::Geometry;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::Path;

/// Depths of the clock-domain-crossing channels the system owner wires
/// between the fabric and the memory controller. The seed hardcoded all
/// three at 8; they are now configurable (CDC sizing studies) with the
/// same default.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ChannelDepths {
    /// Fabric -> controller command channel.
    pub cmd: usize,
    /// Controller -> fabric read-line channel.
    pub rd_line: usize,
    /// Fabric -> controller write-data channel.
    pub wr_data: usize,
}

impl Default for ChannelDepths {
    fn default() -> Self {
        ChannelDepths { cmd: 8, rd_line: 8, wr_data: 8 }
    }
}

impl ChannelDepths {
    pub fn validate(&self) -> Result<()> {
        for (name, d) in
            [("cmd", self.cmd), ("rd_line", self.rd_line), ("wr_data", self.wr_data)]
        {
            anyhow::ensure!(d >= 1, "channel depth {name} must be at least 1");
            anyhow::ensure!(d <= 1024, "channel depth {name} = {d} is implausibly deep (max 1024)");
        }
        Ok(())
    }
}

/// Whether the simulation carries real payload words or tag/occupancy
/// shadows.
///
/// In [`PayloadMode::Elided`] mode every `Line` travelling through the
/// DRAM controller, the CDC channels, the networks, and the layer
/// processors is a header-only shadow ([`crate::types::Line::elided`]):
/// hops skip bank/store/converter word traffic entirely. Every control
/// decision in the simulator is data-independent (the PR 3 flush-gating
/// invariant), so all counters and cycle counts are bit-identical to
/// full mode — only the payload (and therefore golden data checks) is
/// gone. See DESIGN.md §"Fast backend" for the soundness argument.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PayloadMode {
    /// Carry and verify real word contents (the default).
    #[default]
    Full,
    /// Tag/occupancy-only shadows; stats-exact, payload-free.
    Elided,
}

impl PayloadMode {
    pub fn parse(s: &str) -> Option<PayloadMode> {
        match s.to_ascii_lowercase().as_str() {
            "full" => Some(PayloadMode::Full),
            "elided" | "elide" => Some(PayloadMode::Elided),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PayloadMode::Full => "full",
            PayloadMode::Elided => "elided",
        }
    }

    #[inline(always)]
    pub fn is_elided(self) -> bool {
        matches!(self, PayloadMode::Elided)
    }
}

/// How simulated time advances across globally idle spans.
///
/// In [`EdgeMode::Leap`] mode the system asks every clocked component
/// (networks, arbiter, CDC channels, memory controller, layer
/// processors) for its next activity; when nothing can fire before some
/// future fabric edge, the scheduler leaps there in one arithmetic step
/// instead of ticking empty edges. Exact by construction: a skipped
/// edge is one where every tick is a provable no-op except bulk-
/// appliable counter updates (compute countdown, DRAM idle cycles),
/// which the leap applies in closed form.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EdgeMode {
    /// Tick every scheduler edge (the default).
    #[default]
    Stepwise,
    /// Skip globally idle spans in O(1).
    Leap,
}

impl EdgeMode {
    pub fn parse(s: &str) -> Option<EdgeMode> {
        match s.to_ascii_lowercase().as_str() {
            "stepwise" | "step" => Some(EdgeMode::Stepwise),
            "leap" | "skip" => Some(EdgeMode::Leap),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EdgeMode::Stepwise => "stepwise",
            EdgeMode::Leap => "leap",
        }
    }

    #[inline(always)]
    pub fn is_leap(self) -> bool {
        matches!(self, EdgeMode::Leap)
    }
}

/// The simulation backend selection: payload handling + time stepping.
/// Both axes are stats-exact; [`SimBackend::fast`] is what explorer-
/// scale sweeps run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SimBackend {
    pub payload: PayloadMode,
    pub edges: EdgeMode,
}

impl SimBackend {
    /// The reference backend: full payload, every edge ticked.
    pub fn full() -> Self {
        SimBackend { payload: PayloadMode::Full, edges: EdgeMode::Stepwise }
    }

    /// The fast backend: payload elision + idle-edge leaping.
    pub fn fast() -> Self {
        SimBackend { payload: PayloadMode::Elided, edges: EdgeMode::Leap }
    }
}

/// A fully specified system configuration: what the launcher builds.
#[derive(Clone, Debug)]
pub struct SystemConfig {
    /// Which data-transfer network design to instantiate.
    pub design: Design,
    pub geometry: Geometry,
    /// Number of 32-wide vector dot-product units in the layer processor.
    pub dotprod_units: usize,
    /// Memory controller clock (MHz). The paper's DDR3-800 setup: 200.
    pub mem_clock_mhz: f64,
    /// Fabric clock (MHz). `None` = ask the P&R timing model.
    pub fabric_clock_mhz: Option<f64>,
    /// Use detailed DDR3 timing (vs ideal memory).
    pub ddr3_timing: bool,
    /// Extra rotator pipeline stages (Medusa ablation).
    pub rotator_stages: usize,
    /// CDC channel depths between fabric and controller.
    pub channel_depths: ChannelDepths,
    /// PRNG seed for workload generation.
    pub seed: u64,
    /// Simulation backend (payload elision / idle-edge leaping). Not
    /// part of the modelled hardware: any backend must produce
    /// bit-identical stats and cycles, so trace headers deliberately do
    /// NOT record it. (The explore cache keys entries per payload mode
    /// — not because numbers differ, but because `verified` means
    /// "golden-checked" only under full payload; see
    /// `explore::cache::point_key`.)
    pub sim: SimBackend,
}

impl Default for SystemConfig {
    fn default() -> Self {
        SystemConfig {
            design: Design::Medusa,
            geometry: Geometry::paper_default(),
            dotprod_units: 64,
            mem_clock_mhz: 200.0,
            fabric_clock_mhz: None,
            ddr3_timing: true,
            rotator_stages: 0,
            channel_depths: ChannelDepths::default(),
            seed: 7,
            sim: SimBackend::default(),
        }
    }
}

impl SystemConfig {
    /// The paper's representative design point (§IV-C): 64 DPUs, 512-bit
    /// interface, 32r + 32w 16-bit ports.
    pub fn paper_default() -> Self {
        Self::default()
    }

    pub fn validate(&self) -> Result<()> {
        self.geometry.validate()?;
        self.channel_depths.validate()?;
        if let Design::Hybrid(hc) = self.design {
            hc.validate(&self.geometry)
                .with_context(|| format!("hybrid design {:?} on this geometry", hc))?;
        }
        if let Design::Hierarchical(hc) = self.design {
            hc.validate(&self.geometry)
                .with_context(|| format!("hierarchical design {:?} on this geometry", hc))?;
        }
        anyhow::ensure!(self.dotprod_units >= 1, "need at least one dot-product unit");
        anyhow::ensure!(self.mem_clock_mhz > 0.0, "mem clock must be positive");
        if let Some(f) = self.fabric_clock_mhz {
            anyhow::ensure!(f > 0.0, "fabric clock must be positive");
        }
        Ok(())
    }

    /// Load from a TOML-subset file (see module docs).
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading config {}", path.as_ref().display()))?;
        Self::from_str(&text)
    }

    /// Parse from config text.
    #[allow(clippy::should_implement_trait)]
    pub fn from_str(text: &str) -> Result<Self> {
        let raw = parse_toml_subset(text)?;
        let mut cfg = SystemConfig::default();
        for (key, value) in &raw {
            if !cfg.apply_key(key, value)? {
                bail!("unknown config key {key:?}");
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Apply one parsed `key = value` to this config. Returns `Ok(false)`
    /// when the key is not a system-config key (so layered formats —
    /// scenario files embed a full system config — can route leftovers
    /// to their own sections).
    pub fn apply_key(&mut self, key: &str, value: &Value) -> Result<bool> {
        match key {
            "system.design" | "design" => {
                self.design = Design::parse(value.as_str()?)
                    .ok_or_else(|| anyhow!("unknown design {value:?}"))?;
            }
            "geometry.w_line" => self.geometry.w_line = value.as_usize()?,
            "geometry.w_acc" => self.geometry.w_acc = value.as_usize()?,
            "geometry.read_ports" => self.geometry.read_ports = value.as_usize()?,
            "geometry.write_ports" => self.geometry.write_ports = value.as_usize()?,
            "geometry.max_burst" => self.geometry.max_burst = value.as_usize()?,
            "accelerator.dotprod_units" | "dotprod_units" => {
                self.dotprod_units = value.as_usize()?
            }
            "clocks.mem_mhz" => self.mem_clock_mhz = value.as_f64()?,
            "clocks.fabric_mhz" => self.fabric_clock_mhz = Some(value.as_f64()?),
            "memory.ddr3_timing" => self.ddr3_timing = value.as_bool()?,
            "medusa.rotator_stages" => self.rotator_stages = value.as_usize()?,
            "channels.cmd_depth" => self.channel_depths.cmd = value.as_usize()?,
            "channels.rd_line_depth" => self.channel_depths.rd_line = value.as_usize()?,
            "channels.wr_data_depth" => self.channel_depths.wr_data = value.as_usize()?,
            "system.seed" | "seed" => self.seed = value.as_usize()? as u64,
            "sim.payload" => {
                self.sim.payload = PayloadMode::parse(value.as_str()?)
                    .ok_or_else(|| anyhow!("sim.payload must be \"full\" or \"elided\", got {value:?}"))?
            }
            "sim.edges" => {
                self.sim.edges = EdgeMode::parse(value.as_str()?)
                    .ok_or_else(|| anyhow!("sim.edges must be \"stepwise\" or \"leap\", got {value:?}"))?
            }
            _ => return Ok(false),
        }
        Ok(true)
    }
}

/// A parsed scalar value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_usize(&self) -> Result<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Ok(*i as usize),
            _ => bail!("expected non-negative integer, got {self:?}"),
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Value::Int(i) => Ok(*i as f64),
            Value::Float(f) => Ok(*f),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Value::Bool(b) => Ok(*b),
            _ => bail!("expected boolean, got {self:?}"),
        }
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Value::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }
}

/// Parse the TOML subset into `section.key -> value` (keys outside any
/// section keep their bare name).
pub fn parse_toml_subset(text: &str) -> Result<BTreeMap<String, Value>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (lineno, raw_line) in text.lines().enumerate() {
        let line = strip_comment(raw_line).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(inner) = line.strip_prefix('[') {
            let name = inner
                .strip_suffix(']')
                .ok_or_else(|| anyhow!("line {}: malformed section header", lineno + 1))?;
            section = name.trim().to_string();
            continue;
        }
        let (key, value) = line
            .split_once('=')
            .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
        let key = key.trim();
        anyhow::ensure!(!key.is_empty(), "line {}: empty key", lineno + 1);
        let full_key =
            if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
        let value = parse_value(value.trim())
            .with_context(|| format!("line {}: value for {full_key:?}", lineno + 1))?;
        if out.insert(full_key.clone(), value).is_some() {
            bail!("line {}: duplicate key {full_key:?}", lineno + 1);
        }
    }
    Ok(out)
}

fn strip_comment(line: &str) -> &str {
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str) -> Result<Value> {
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_config() {
        let text = r#"
# Paper representative point
[system]
design = "medusa"
seed = 42

[geometry]
w_line = 512
w_acc = 16
read_ports = 32
write_ports = 32
max_burst = 32

[accelerator]
dotprod_units = 64

[clocks]
mem_mhz = 200
fabric_mhz = 225.0

[memory]
ddr3_timing = true
"#;
        let cfg = SystemConfig::from_str(text).unwrap();
        assert_eq!(cfg.design, Design::Medusa);
        assert_eq!(cfg.geometry, Geometry::paper_default());
        assert_eq!(cfg.dotprod_units, 64);
        assert_eq!(cfg.fabric_clock_mhz, Some(225.0));
        assert_eq!(cfg.seed, 42);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(SystemConfig::from_str("bogus = 1").is_err());
    }

    #[test]
    fn invalid_geometry_rejected() {
        let text = "[geometry]\nw_line = 512\nw_acc = 13\n";
        assert!(SystemConfig::from_str(text).is_err());
    }

    #[test]
    fn duplicate_key_rejected() {
        assert!(parse_toml_subset("a = 1\na = 2").is_err());
    }

    #[test]
    fn comments_and_strings() {
        let m = parse_toml_subset("s = \"a # not comment\" # real comment\nn = -3\nf = 1.5\n")
            .unwrap();
        assert_eq!(m["s"], Value::Str("a # not comment".into()));
        assert_eq!(m["n"], Value::Int(-3));
        assert_eq!(m["f"], Value::Float(1.5));
    }

    #[test]
    fn value_conversions() {
        assert_eq!(Value::Int(5).as_usize().unwrap(), 5);
        assert!(Value::Int(-5).as_usize().is_err());
        assert_eq!(Value::Int(5).as_f64().unwrap(), 5.0);
        assert!(Value::Str("x".into()).as_bool().is_err());
    }

    #[test]
    fn default_is_paper_point() {
        let cfg = SystemConfig::paper_default();
        cfg.validate().unwrap();
        assert_eq!(cfg.geometry.w_line, 512);
        assert_eq!(cfg.dotprod_units, 64);
        assert_eq!(cfg.channel_depths, ChannelDepths::default());
    }

    #[test]
    fn hybrid_design_parses_and_validates_against_geometry() {
        use crate::interconnect::hybrid::HybridConfig;
        let text = "[system]\ndesign = \"hybrid:r8:s2\"\n[geometry]\nw_line = 512\n";
        let cfg = SystemConfig::from_str(text).unwrap();
        assert_eq!(
            cfg.design,
            Design::Hybrid(HybridConfig {
                transpose_radix: 8,
                stage_pipelining: 2,
                port_group_width: 1
            })
        );
        // Radix above W_line/W_acc fails validation with the geometry.
        let bad = "[system]\ndesign = \"hybrid:r64\"\n[geometry]\nw_line = 512\n";
        assert!(SystemConfig::from_str(bad).is_err());
    }

    #[test]
    fn hierarchical_design_parses_and_validates_against_geometry() {
        use crate::interconnect::hierarchical::HierConfig;
        let text = "[system]\ndesign = \"hierarchical:l3:c8:b0:t450\"\n[geometry]\nw_line = 512\n";
        let cfg = SystemConfig::from_str(text).unwrap();
        assert_eq!(
            cfg.design,
            Design::Hierarchical(HierConfig {
                levels: 3,
                cluster_ports: 8,
                bypass_ports: 0,
                trunk_mhz: 450
            })
        );
        // Cluster size that does not divide the port count fails with
        // the geometry.
        let bad = "[system]\ndesign = \"hierarchical:c5\"\n[geometry]\nw_line = 512\n";
        assert!(SystemConfig::from_str(bad).is_err());
    }

    #[test]
    fn sim_backend_parses_and_defaults_to_full_stepwise() {
        let cfg = SystemConfig::from_str("").unwrap();
        assert_eq!(cfg.sim, SimBackend::full());
        let cfg =
            SystemConfig::from_str("[sim]\npayload = \"elided\"\nedges = \"leap\"\n").unwrap();
        assert_eq!(cfg.sim, SimBackend::fast());
        assert!(SystemConfig::from_str("[sim]\npayload = \"half\"\n").is_err());
        assert!(SystemConfig::from_str("[sim]\nedges = \"sprint\"\n").is_err());
        assert_eq!(PayloadMode::parse("FULL"), Some(PayloadMode::Full));
        assert_eq!(PayloadMode::parse(PayloadMode::Elided.name()), Some(PayloadMode::Elided));
        assert_eq!(EdgeMode::parse(EdgeMode::Leap.name()), Some(EdgeMode::Leap));
    }

    #[test]
    fn channel_depths_parse_and_validate() {
        let cfg = SystemConfig::from_str(
            "[channels]\ncmd_depth = 4\nrd_line_depth = 16\nwr_data_depth = 2\n",
        )
        .unwrap();
        assert_eq!(cfg.channel_depths, ChannelDepths { cmd: 4, rd_line: 16, wr_data: 2 });
        // Depth 0 is rejected at validation.
        assert!(SystemConfig::from_str("[channels]\ncmd_depth = 0\n").is_err());
        // Implausibly deep channels are rejected too.
        assert!(SystemConfig::from_str("[channels]\nrd_line_depth = 100000\n").is_err());
    }
}

#[cfg(test)]
mod file_tests {
    use super::*;

    #[test]
    fn shipped_paper_config_loads() {
        // configs/paper.toml is the documented entry point; keep it valid.
        let path = if std::path::Path::new("configs/paper.toml").exists() {
            "configs/paper.toml"
        } else {
            "../configs/paper.toml"
        };
        let cfg = SystemConfig::from_file(path).expect("configs/paper.toml must parse");
        assert_eq!(cfg.design, Design::Medusa);
        assert_eq!(cfg.geometry, Geometry::paper_default());
        assert_eq!(cfg.dotprod_units, 64);
        assert!(cfg.ddr3_timing);
        assert_eq!(cfg.fabric_clock_mhz, None, "fabric clock left to the P&R model");
    }

    #[test]
    fn missing_file_errors_cleanly() {
        let err = SystemConfig::from_file("/nonexistent/zz.toml").unwrap_err();
        assert!(format!("{err:#}").contains("reading config"));
    }
}
