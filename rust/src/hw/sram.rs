//! Banked SRAM model (the deep/narrow BRAM banks Medusa's transposition
//! buffers are built from, paper §III-A/Fig 4).
//!
//! Physical constraint enforced: each bank is a simple dual-port RAM —
//! at most **one read and one write per bank per cycle** (exactly what a
//! BRAM-18K in SDP mode provides). The interconnect models call
//! [`BankedSram::new_cycle`] at the top of every tick; a second access to
//! the same bank port within one cycle panics, catching any modelling
//! bug that would require more physical ports than the paper's design
//! instantiates.

use crate::types::Word;

#[derive(Debug)]
pub struct BankedSram {
    banks: usize,
    depth: usize,
    data: Vec<Word>,
    /// Per-bank access flags for the current cycle.
    read_used: Vec<bool>,
    write_used: Vec<bool>,
    /// Lifetime access counts (feeds utilization stats).
    reads: u64,
    writes: u64,
}

impl BankedSram {
    pub fn new(banks: usize, depth: usize) -> Self {
        assert!(banks >= 1 && depth >= 1);
        BankedSram {
            banks,
            depth,
            data: vec![0; banks * depth],
            read_used: vec![false; banks],
            write_used: vec![false; banks],
            reads: 0,
            writes: 0,
        }
    }

    pub fn banks(&self) -> usize {
        self.banks
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Reset the per-cycle port-usage flags. Owners call this once at the
    /// start of each tick.
    pub fn new_cycle(&mut self) {
        self.read_used.fill(false);
        self.write_used.fill(false);
    }

    #[inline]
    pub fn read(&mut self, bank: usize, addr: usize) -> Word {
        // Range errors are modelling bugs caught in debug/test builds;
        // the port-conflict check is the physical constraint and stays on
        // in release (it is one predictable branch on the hot path).
        debug_assert!(bank < self.banks, "bank {bank} out of range");
        debug_assert!(addr < self.depth, "addr {addr} out of range (depth {})", self.depth);
        assert!(!self.read_used[bank], "second read on bank {bank} in one cycle");
        self.read_used[bank] = true;
        self.reads += 1;
        self.data[bank * self.depth + addr]
    }

    #[inline]
    pub fn write(&mut self, bank: usize, addr: usize, v: Word) {
        debug_assert!(bank < self.banks, "bank {bank} out of range");
        debug_assert!(addr < self.depth, "addr {addr} out of range (depth {})", self.depth);
        assert!(!self.write_used[bank], "second write on bank {bank} in one cycle");
        self.write_used[bank] = true;
        self.writes += 1;
        self.data[bank * self.depth + addr] = v;
    }

    /// Debug/testing peek that bypasses port accounting.
    pub fn peek(&self, bank: usize, addr: usize) -> Word {
        self.data[bank * self.depth + addr]
    }

    pub fn total_reads(&self) -> u64 {
        self.reads
    }

    pub fn total_writes(&self) -> u64 {
        self.writes
    }

    /// Storage bits given a word width — used by resource accounting
    /// sanity tests.
    pub fn bits(&self, word_width: usize) -> usize {
        self.banks * self.depth * word_width
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn read_write_roundtrip() {
        let mut s = BankedSram::new(4, 16);
        s.new_cycle();
        s.write(2, 7, 0xabcd);
        s.new_cycle();
        assert_eq!(s.read(2, 7), 0xabcd);
        assert_eq!(s.peek(0, 0), 0);
    }

    #[test]
    fn one_read_one_write_same_bank_same_cycle_ok() {
        // Simple dual port: one read AND one write per cycle is legal.
        let mut s = BankedSram::new(2, 8);
        s.new_cycle();
        s.write(1, 3, 42);
        let _ = s.read(1, 0);
    }

    #[test]
    #[should_panic(expected = "second read on bank")]
    fn double_read_same_bank_panics() {
        let mut s = BankedSram::new(2, 8);
        s.new_cycle();
        let _ = s.read(0, 0);
        let _ = s.read(0, 1);
    }

    #[test]
    #[should_panic(expected = "second write on bank")]
    fn double_write_same_bank_panics() {
        let mut s = BankedSram::new(2, 8);
        s.new_cycle();
        s.write(0, 0, 1);
        s.write(0, 1, 2);
    }

    #[test]
    fn parallel_banks_all_accessible_per_cycle() {
        let mut s = BankedSram::new(8, 4);
        s.new_cycle();
        for b in 0..8 {
            s.write(b, 0, b as Word);
        }
        s.new_cycle();
        for b in 0..8 {
            assert_eq!(s.read(b, 0), b as Word);
        }
        assert_eq!(s.total_writes(), 8);
        assert_eq!(s.total_reads(), 8);
    }

    #[test]
    fn bits_accounting() {
        let s = BankedSram::new(32, 1024);
        // The paper's Medusa read input buffer: 32 banks x 16b x 1024 deep
        // = 512 Kib = 32 BRAM-18K.
        assert_eq!(s.bits(16), 32 * 1024 * 16);
    }
}
