//! The Medusa data rotation unit (paper §III-B, Fig 5).
//!
//! Takes `N` words of `W_acc` bits and left-rotates them in word
//! increments through a barrel-shifter structure of `ceil(log2 N)`
//! stages; stage `l` rotates by `2^l` words iff bit `l` of the rotation
//! amount is set. "Data rotation can either be performed in a single
//! cycle or be pipelined, depending on the frequency requirements" — both
//! variants are modelled: [`rotate_left`] is the single-cycle
//! (combinational) form, [`PipelinedRotator`] registers every stage.

use crate::types::Word;
use crate::util::ceil_log2;

/// Combinational left-rotation of `words` by `amount` positions
/// (`out[j] = in[(j + amount) mod N]`), evaluated stage by stage exactly
/// as the barrel structure does so that the stage decomposition itself is
/// covered by tests.
pub fn rotate_left(words: &mut [Word], amount: usize) {
    let n = words.len();
    if n <= 1 {
        return;
    }
    let amount = amount % n;
    let stages = ceil_log2(n);
    let mut scratch = vec![0 as Word; n];
    for l in 0..stages {
        if (amount >> l) & 1 == 1 {
            let shift = 1usize << l;
            for (j, s) in scratch.iter_mut().enumerate() {
                *s = words[(j + shift) % n];
            }
            words.copy_from_slice(&scratch);
        }
    }
}

/// One in-flight item in the pipelined rotator: the words, the remaining
/// rotation control bits, and an opaque tag the caller uses to associate
/// the output with bookkeeping (e.g. destination buffer addresses).
#[derive(Clone, Debug)]
struct InFlight<T> {
    words: Vec<Word>,
    amount: usize,
    tag: T,
    stage: usize,
}

/// Pipelined barrel rotator: `ceil(log2 N)` register stages, one item
/// enters and one leaves per cycle once full. Latency = number of stages.
#[derive(Debug)]
pub struct PipelinedRotator<T> {
    n: usize,
    stages: usize,
    pipe: Vec<Option<InFlight<T>>>,
}

impl<T> PipelinedRotator<T> {
    pub fn new(n: usize) -> Self {
        assert!(n >= 1);
        let stages = ceil_log2(n).max(1);
        let mut pipe = Vec::with_capacity(stages);
        for _ in 0..stages {
            pipe.push(None);
        }
        PipelinedRotator { n, stages, pipe }
    }

    pub fn latency(&self) -> usize {
        self.stages
    }

    pub fn num_words(&self) -> usize {
        self.n
    }

    /// True if a new item can be accepted this cycle (stage 0 empty after
    /// the shift performed by `tick`).
    pub fn can_accept(&self) -> bool {
        self.pipe[0].is_none()
    }

    /// Insert an item into stage 0. Call after `tick` in the owner's
    /// cycle evaluation.
    pub fn accept(&mut self, words: Vec<Word>, amount: usize, tag: T) {
        assert_eq!(words.len(), self.n);
        assert!(self.can_accept(), "rotator stage 0 occupied");
        self.pipe[0] = Some(InFlight { words, amount: amount % self.n.max(1), tag, stage: 0 });
    }

    /// Advance the pipeline one cycle; returns the item leaving the final
    /// stage, fully rotated, if any.
    pub fn tick(&mut self) -> Option<(Vec<Word>, T)> {
        // Pop the last stage.
        let out = self.pipe[self.stages - 1].take().map(|mut f| {
            Self::apply_stage(self.n, &mut f);
            (f.words, f.tag)
        });
        // Shift the rest forward, applying each stage's partial rotation.
        for i in (0..self.stages - 1).rev() {
            if let Some(mut f) = self.pipe[i].take() {
                Self::apply_stage(self.n, &mut f);
                f.stage = i + 1;
                self.pipe[i + 1] = Some(f);
            }
        }
        out
    }

    /// Apply the rotation contribution of the stage the item currently
    /// occupies: rotate by `2^stage` iff that control bit is set.
    fn apply_stage(n: usize, f: &mut InFlight<T>) {
        let l = f.stage;
        if (f.amount >> l) & 1 == 1 {
            let shift = 1usize << l;
            let old = f.words.clone();
            for j in 0..n {
                f.words[j] = old[(j + shift) % n];
            }
        }
    }

    /// Number of items currently in flight.
    pub fn in_flight(&self) -> usize {
        self.pipe.iter().filter(|s| s.is_some()).count()
    }

    pub fn is_empty(&self) -> bool {
        self.in_flight() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn naive_rotate(v: &[Word], amt: usize) -> Vec<Word> {
        let n = v.len();
        (0..n).map(|j| v[(j + amt) % n]).collect()
    }

    #[test]
    fn combinational_matches_naive_all_amounts() {
        for n in [1usize, 2, 4, 8, 16, 32] {
            let base: Vec<Word> = (0..n as u64).collect();
            for amt in 0..2 * n {
                let mut w = base.clone();
                rotate_left(&mut w, amt);
                assert_eq!(w, naive_rotate(&base, amt), "n={n} amt={amt}");
            }
        }
    }

    #[test]
    fn combinational_non_power_of_two() {
        // §III-G: irregular port counts still rotate correctly through the
        // pow2-sized barrel (modulo arithmetic on the actual n).
        for n in [3usize, 5, 6, 7, 12, 20, 24] {
            let base: Vec<Word> = (0..n as u64).map(|x| x * 7 + 1).collect();
            for amt in 0..n {
                let mut w = base.clone();
                rotate_left(&mut w, amt);
                assert_eq!(w, naive_rotate(&base, amt), "n={n} amt={amt}");
            }
        }
    }

    #[test]
    fn pipelined_latency_is_log2() {
        let r: PipelinedRotator<()> = PipelinedRotator::new(8);
        assert_eq!(r.latency(), 3);
        let r: PipelinedRotator<()> = PipelinedRotator::new(32);
        assert_eq!(r.latency(), 5);
        let r: PipelinedRotator<()> = PipelinedRotator::new(20);
        assert_eq!(r.latency(), 5); // ceil(log2 20)
    }

    #[test]
    fn pipelined_matches_combinational() {
        let n = 8;
        let mut r: PipelinedRotator<usize> = PipelinedRotator::new(n);
        let inputs: Vec<(Vec<Word>, usize)> =
            (0..20).map(|i| ((0..n as u64).map(|x| x + 100 * i).collect(), (i as usize) % n)).collect();
        let mut outputs = Vec::new();
        let mut next_in = 0;
        for _cycle in 0..200 {
            if let Some((words, tag)) = r.tick() {
                outputs.push((words, tag));
            }
            if next_in < inputs.len() && r.can_accept() {
                let (w, amt) = inputs[next_in].clone();
                r.accept(w, amt, next_in);
                next_in += 1;
            }
            if outputs.len() == inputs.len() {
                break;
            }
        }
        assert_eq!(outputs.len(), inputs.len());
        for (words, tag) in outputs {
            let (ref iw, amt) = inputs[tag];
            let mut expect = iw.clone();
            rotate_left(&mut expect, amt);
            assert_eq!(words, expect, "item {tag}");
        }
    }

    #[test]
    fn pipelined_sustains_one_per_cycle() {
        // Once the pipe is full, an item must leave every cycle — the
        // rotator is on the full-bandwidth path (one W_line line/cycle).
        let n = 16;
        let mut r: PipelinedRotator<u64> = PipelinedRotator::new(n);
        let mut received = 0u64;
        let total = 100u64;
        let mut sent = 0u64;
        let mut cycles = 0u64;
        while received < total {
            cycles += 1;
            if r.tick().is_some() {
                received += 1;
            }
            if sent < total && r.can_accept() {
                r.accept(vec![sent; n], (sent % n as u64) as usize, sent);
                sent += 1;
            }
            assert!(cycles < total + 20, "rotator stalled");
        }
        // Steady-state throughput 1/cycle: total cycles = total + latency + O(1).
        assert!(cycles <= total + r.latency() as u64 + 2, "cycles={cycles}");
    }
}
