//! Data-width converters (paper §II-A): the baseline interconnect's
//! per-port shims between the `W_line`-bit FIFO side and the `W_acc`-bit
//! accelerator side.
//!
//! * [`Unpacker`] (read path): accepts one `W_line` line, emits its `N`
//!   words one per cycle in increasing index order.
//! * [`Packer`] (write path): accepts one `W_acc` word per cycle, emits a
//!   full `W_line` line every `N` words.
//!
//! Each is the behavioural twin of the `W_acc x (N-1)` 2:1-mux structure
//! whose cost the resource model charges (`fpga::resources`).

use crate::types::{Line, Word};

/// Line -> words, one word per cycle.
#[derive(Debug)]
pub struct Unpacker {
    words_per_line: usize,
    current: Option<Line>,
    idx: usize,
}

impl Unpacker {
    pub fn new(words_per_line: usize) -> Self {
        assert!(words_per_line >= 1);
        Unpacker { words_per_line, current: None, idx: 0 }
    }

    /// Can a new line be loaded? (Previous line fully drained.)
    pub fn can_load(&self) -> bool {
        self.current.is_none()
    }

    pub fn load(&mut self, line: Line) {
        assert!(self.can_load(), "unpacker busy");
        assert_eq!(line.num_words(), self.words_per_line);
        self.current = Some(line);
        self.idx = 0;
    }

    /// Is a word available this cycle?
    pub fn has_word(&self) -> bool {
        self.current.is_some()
    }

    /// Emit the next word (one per cycle enforced by the caller's tick
    /// structure).
    pub fn take_word(&mut self) -> Option<Word> {
        let line = self.current.as_ref()?;
        let w = line.word(self.idx);
        self.idx += 1;
        if self.idx == self.words_per_line {
            self.current = None;
            self.idx = 0;
        }
        Some(w)
    }

    /// Words remaining in the currently loaded line.
    pub fn remaining(&self) -> usize {
        if self.current.is_some() {
            self.words_per_line - self.idx
        } else {
            0
        }
    }
}

/// Words -> line, one word per cycle in, one line out per `N` words.
///
/// Accumulates directly into a [`Line`] so the per-word hot path never
/// grows a `Vec`; for inline-sized lines (`N` ≤ 32) the whole
/// accumulate/promote cycle is allocation-free.
#[derive(Debug)]
pub struct Packer {
    words_per_line: usize,
    acc: Line,
    acc_len: usize,
    ready_line: Option<Line>,
    /// Fast-backend payload elision: count words instead of storing
    /// them, and promote [`Line::elided`] shadows. All readiness and
    /// occupancy behaviour (`can_accept`, `has_line`, `pending_words`)
    /// is word-count-driven and therefore identical in both modes.
    elided: bool,
}

impl Packer {
    pub fn new(words_per_line: usize) -> Self {
        assert!(words_per_line >= 1);
        Packer {
            words_per_line,
            acc: Line::zeroed(words_per_line),
            acc_len: 0,
            ready_line: None,
            elided: false,
        }
    }

    /// Switch this packer to payload-elided assembly. Only valid while
    /// empty (mode is fixed per run, set at system construction).
    pub fn set_elided(&mut self, elided: bool) {
        assert!(
            self.acc_len == 0 && self.ready_line.is_none(),
            "payload mode change on a non-empty packer"
        );
        self.elided = elided;
    }

    /// Can a word be accepted this cycle? Blocked only while a completed
    /// line is waiting to be taken (single output register, as in the
    /// baseline's converter).
    pub fn can_accept(&self) -> bool {
        self.ready_line.is_none() || self.acc_len < self.words_per_line
    }

    pub fn accept(&mut self, w: Word) {
        assert!(self.acc_len < self.words_per_line, "packer accumulator full");
        if !self.elided {
            self.acc.set_word(self.acc_len, w);
        }
        self.acc_len += 1;
        if self.acc_len == self.words_per_line && self.ready_line.is_none() {
            self.promote();
        }
    }

    /// Move the full accumulator into the output register and reset it.
    fn promote(&mut self) {
        let full = if self.elided {
            Line::elided(self.words_per_line)
        } else {
            std::mem::replace(&mut self.acc, Line::zeroed(self.words_per_line))
        };
        self.ready_line = Some(full);
        self.acc_len = 0;
    }

    /// A full line is ready to hand to the FIFO.
    pub fn has_line(&self) -> bool {
        self.ready_line.is_some()
    }

    pub fn take_line(&mut self) -> Option<Line> {
        let out = self.ready_line.take();
        // If the accumulator filled while the output register was
        // occupied, promote it now.
        if self.acc_len == self.words_per_line {
            self.promote();
        }
        out
    }

    /// Words currently accumulated toward the next line.
    pub fn pending_words(&self) -> usize {
        self.acc_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unpacker_emits_words_in_order() {
        let mut u = Unpacker::new(4);
        u.load(Line::from_words(vec![10, 11, 12, 13]));
        assert!(!u.can_load());
        let got: Vec<Word> = (0..4).map(|_| u.take_word().unwrap()).collect();
        assert_eq!(got, vec![10, 11, 12, 13]);
        assert!(u.can_load());
        assert_eq!(u.take_word(), None);
    }

    #[test]
    fn unpacker_remaining_counts_down() {
        let mut u = Unpacker::new(3);
        assert_eq!(u.remaining(), 0);
        u.load(Line::from_words(vec![1, 2, 3]));
        assert_eq!(u.remaining(), 3);
        u.take_word();
        assert_eq!(u.remaining(), 2);
    }

    #[test]
    fn packer_builds_lines() {
        let mut p = Packer::new(4);
        for w in [1u64, 2, 3, 4] {
            assert!(p.can_accept());
            p.accept(w);
        }
        assert!(p.has_line());
        assert_eq!(p.take_line().unwrap(), Line::from_words(vec![1, 2, 3, 4]));
        assert!(!p.has_line());
    }

    #[test]
    fn packer_double_buffers_one_line() {
        let mut p = Packer::new(2);
        p.accept(1);
        p.accept(2); // line 1 complete -> output register
        assert!(p.has_line());
        // Accumulator is free again while line 1 waits.
        p.accept(3);
        p.accept(4);
        assert_eq!(p.take_line().unwrap(), Line::from_words(vec![1, 2]));
        assert!(p.has_line(), "second line promoted on take");
        assert_eq!(p.take_line().unwrap(), Line::from_words(vec![3, 4]));
    }

    #[test]
    fn elided_packer_counts_and_emits_shadows() {
        let mut p = Packer::new(4);
        p.set_elided(true);
        for w in [9u64, 9, 9, 9] {
            assert!(p.can_accept());
            p.accept(w);
        }
        assert!(p.has_line());
        let line = p.take_line().unwrap();
        assert!(line.is_elided());
        assert_eq!(line.num_words(), 4);
        // Double-buffering semantics are unchanged.
        p.accept(1);
        assert_eq!(p.pending_words(), 1);
        // Unpacker streams shadow words from an elided line.
        let mut u = Unpacker::new(4);
        u.load(Line::elided(4));
        assert!(u.has_word());
        assert_eq!((0..4).map(|_| u.take_word().unwrap()).collect::<Vec<_>>(), vec![0; 4]);
        assert!(u.can_load());
    }

    #[test]
    #[should_panic(expected = "packer accumulator full")]
    fn packer_overflow_panics() {
        let mut p = Packer::new(2);
        p.accept(1);
        p.accept(2);
        p.accept(3); // output reg occupied AND accumulator full
        p.accept(4);
        p.accept(5);
    }
}
