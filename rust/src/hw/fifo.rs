//! Bounded FIFO for *intra-component* buffering.
//!
//! Unlike [`crate::sim::Channel`] (which registers pushes across a cycle
//! boundary for inter-component determinism), `BoundedFifo` is immediate:
//! a component's `tick` is sequential code, so within one component the
//! evaluation order is already well defined. The capacity bound is the
//! hardware-faithful part — the baseline interconnect's per-port FIFOs
//! are provisioned at exactly `MaxBurstLen` lines (paper §II-A) and the
//! models must respect that.

use std::collections::VecDeque;

#[derive(Clone, Debug)]
pub struct BoundedFifo<T> {
    cap: usize,
    q: VecDeque<T>,
    high_water: usize,
}

impl<T> BoundedFifo<T> {
    pub fn new(cap: usize) -> Self {
        assert!(cap >= 1);
        BoundedFifo { cap, q: VecDeque::with_capacity(cap), high_water: 0 }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.q.len() == self.cap
    }

    pub fn free(&self) -> usize {
        self.cap - self.q.len()
    }

    /// Highest occupancy ever reached — used to verify provisioning
    /// claims (e.g. that bursts never overflow a `MaxBurstLen` FIFO).
    pub fn high_water(&self) -> usize {
        self.high_water
    }

    pub fn push(&mut self, v: T) {
        assert!(!self.is_full(), "FIFO overflow (cap {})", self.cap);
        self.q.push_back(v);
        self.high_water = self.high_water.max(self.q.len());
    }

    pub fn try_push(&mut self, v: T) -> Result<(), T> {
        if self.is_full() {
            Err(v)
        } else {
            self.push(v);
            Ok(())
        }
    }

    pub fn pop(&mut self) -> Option<T> {
        self.q.pop_front()
    }

    pub fn peek(&self) -> Option<&T> {
        self.q.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_and_capacity() {
        let mut f = BoundedFifo::new(3);
        f.push(1);
        f.push(2);
        f.push(3);
        assert!(f.is_full());
        assert_eq!(f.try_push(4), Err(4));
        assert_eq!(f.pop(), Some(1));
        assert_eq!(f.pop(), Some(2));
        assert_eq!(f.pop(), Some(3));
        assert_eq!(f.pop(), None);
    }

    #[test]
    fn high_water_tracks_peak() {
        let mut f = BoundedFifo::new(8);
        for i in 0..5 {
            f.push(i);
        }
        for _ in 0..5 {
            f.pop();
        }
        f.push(9);
        assert_eq!(f.high_water(), 5);
        assert_eq!(f.len(), 1);
    }

    #[test]
    #[should_panic(expected = "FIFO overflow")]
    fn overflow_panics() {
        let mut f = BoundedFifo::new(1);
        f.push(1);
        f.push(2);
    }
}
