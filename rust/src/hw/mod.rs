//! Behavioural models of the FPGA hardware primitives both interconnects
//! are built from.
//!
//! These are *functional, cycle-level* models: they enforce the same
//! structural constraints the real primitives have (FIFO capacity, one
//! access per SRAM bank port per cycle, `log2 N` rotator stages) so that
//! the interconnect models built on top cannot accidentally assume more
//! hardware than the paper's designs instantiate. Resource *costing* of
//! the same primitives lives separately in [`crate::fpga::resources`].

pub mod fifo;
pub mod rotator;
pub mod sram;
pub mod width_conv;

pub use fifo::BoundedFifo;
pub use rotator::{rotate_left, PipelinedRotator};
pub use sram::BankedSram;
pub use width_conv::{Packer, Unpacker};
