//! DRAM substrate: DDR3 channel timing and the memory controller that
//! exposes the wide (`W_line`-bit) line interface the interconnects
//! multiplex (paper §IV-C: "single channel 800MHz DDR3 ... the memory
//! controller runs in its own clock domain at 200MHz, and exposes a
//! 512-bit interface to the rest of the FPGA").

pub mod controller;
pub mod ddr3;

pub use controller::MemoryController;
pub use ddr3::DdrTiming;
