//! DDR3 channel timing model, at the granularity the interconnect cares
//! about: one `W_line`-bit controller line per controller cycle when
//! streaming within an open row, plus row activate/precharge penalties on
//! row misses.
//!
//! A DDR3-800 x64 channel moves 64 bits x 2 (DDR) x 400 MHz = 12.8 GB/s;
//! its BL8 burst is 64 B = 512 bits — exactly one controller line — and
//! the controller's 200 MHz x 512-bit interface is bandwidth-matched to
//! it. Timing parameters below are expressed in 200 MHz controller
//! cycles (1 controller cycle = 2 DRAM clock cycles).

/// Address geometry + timing of one DRAM channel.
#[derive(Clone, Copy, Debug)]
pub struct DdrTiming {
    /// Banks per channel.
    pub banks: usize,
    /// Row size in `W_line` lines (DDR3 1KB pages / 64B line = 16).
    pub row_lines: usize,
    /// Controller-cycle cost to stream one line within an open row.
    pub line_cycles: u64,
    /// Extra controller cycles on a row miss (tRP + tRCD at DDR3-800,
    /// 13.75 ns each ~= 11 DRAM cycles -> ~6 controller cycles each).
    pub row_miss_cycles: u64,
    /// Pipeline latency from command acceptance to first read data
    /// (controller + PHY + CAS).
    pub read_latency_cycles: u64,
    /// Pipeline latency from write data acceptance to commit.
    pub write_latency_cycles: u64,
}

impl DdrTiming {
    /// Single-channel 800 MT/s DDR3 as in the paper's representative
    /// setup (§IV-C).
    pub fn ddr3_800() -> Self {
        DdrTiming {
            banks: 8,
            row_lines: 16,
            line_cycles: 1,
            row_miss_cycles: 12,
            read_latency_cycles: 10,
            write_latency_cycles: 4,
        }
    }

    /// Ideal memory: fixed small latency, one line per cycle, no bank or
    /// row effects. Used by interconnect-only experiments so measured
    /// effects are attributable to the networks alone.
    pub fn ideal() -> Self {
        DdrTiming {
            banks: 1,
            row_lines: usize::MAX,
            line_cycles: 1,
            row_miss_cycles: 0,
            read_latency_cycles: 2,
            write_latency_cycles: 1,
        }
    }

    /// Map a line address to (bank, row) with low-order bank
    /// interleaving: consecutive rows of lines rotate across banks so
    /// sequential bursts from different ports land in different banks.
    pub fn map(&self, line_addr: u64) -> (usize, u64) {
        if self.row_lines == usize::MAX {
            return (0, 0);
        }
        let row_seq = line_addr / self.row_lines as u64;
        let bank = (row_seq % self.banks as u64) as usize;
        let row = row_seq / self.banks as u64;
        (bank, row)
    }

    /// Peak bandwidth in lines per controller cycle.
    pub fn peak_lines_per_cycle(&self) -> f64 {
        1.0 / self.line_cycles as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mapping_interleaves_banks() {
        let t = DdrTiming::ddr3_800();
        let (b0, r0) = t.map(0);
        let (b1, _) = t.map(16); // next row of lines
        assert_eq!((b0, r0), (0, 0));
        assert_eq!(b1, 1, "consecutive rows must hit different banks");
        // Same row, consecutive lines: same bank/row.
        assert_eq!(t.map(3), (0, 0));
        assert_eq!(t.map(15), (0, 0));
    }

    #[test]
    fn ideal_memory_is_flat() {
        let t = DdrTiming::ideal();
        assert_eq!(t.map(12345), (0, 0));
        assert_eq!(t.row_miss_cycles, 0);
    }

    #[test]
    fn ddr3_800_is_bandwidth_matched() {
        let t = DdrTiming::ddr3_800();
        assert_eq!(t.peak_lines_per_cycle(), 1.0);
    }
}
