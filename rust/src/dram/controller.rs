//! In-order memory controller executing line-granular burst commands
//! against a simulated DRAM backing store.
//!
//! Runs in its own clock domain (200 MHz for the paper's DDR3-800 setup)
//! and talks to the fabric through CDC channels owned by the system:
//! commands in, read lines out (tagged with their destination port),
//! write lines in (in command order). The interconnect under test is the
//! only thing between this controller and the accelerator ports.

use crate::config::PayloadMode;
use crate::dram::DdrTiming;
use crate::interconnect::arbiter::MemCommand;
use crate::sim::stats::Counter;
use crate::sim::{Channel, Stats};
use crate::types::{Line, LineAddr, TaggedLine};
use std::collections::HashMap;

#[derive(Debug)]
enum Active {
    Read { port: usize, next_addr: LineAddr, remaining: usize },
    Write { port: usize, next_addr: LineAddr, remaining: usize },
}

pub struct MemoryController {
    timing: DdrTiming,
    words_per_line: usize,
    /// Backing store, sparse: absent lines read as zero.
    store: HashMap<LineAddr, Line>,
    /// Open row per bank.
    open_rows: Vec<Option<u64>>,
    active: Option<Active>,
    /// Busy until this controller cycle (timing stall).
    busy_until: u64,
    cycle: u64,
    /// Lines committed to the store per originating write port (grown on
    /// demand). The scenario engine uses this as a data-independent
    /// "this tenant's writes have landed" signal.
    write_lines_landed: Vec<u64>,
    /// Fast backend: reads return header-only shadows without touching
    /// the store; writes land as shadows (landed counters and row/bank
    /// timing are address-driven and stay bit-identical).
    payload: PayloadMode,
}

impl MemoryController {
    pub fn new(timing: DdrTiming, words_per_line: usize) -> Self {
        MemoryController {
            timing,
            words_per_line,
            store: HashMap::new(),
            open_rows: vec![None; timing.banks],
            active: None,
            busy_until: 0,
            cycle: 0,
            write_lines_landed: Vec::new(),
            payload: PayloadMode::Full,
        }
    }

    /// Select payload handling; call before any traffic. In elided
    /// mode `preload` becomes a no-op (reads never consult the store)
    /// and `dump` returns shadows.
    pub fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert!(self.store.is_empty() && self.active.is_none(), "mode change mid-run");
        self.payload = mode;
    }

    /// The idle-edge bulk skip: account `n` controller cycles in which
    /// a stepwise run would have ticked an idle controller. Exact
    /// because an idle tick with an empty command channel does nothing
    /// but bump [`Counter::DramIdleCycles`].
    pub fn skip_idle_cycles(&self, n: u64, stats: &mut Stats) {
        debug_assert!(self.is_idle(), "bulk-skipping a busy controller");
        stats.add(Counter::DramIdleCycles, n);
    }

    /// A refresh-stall cycle (fault injection): the system calls this
    /// *instead of* [`MemoryController::tick`] while a scheduled DRAM
    /// refresh window is open. The controller freezes — no command
    /// accept, no line return, no write drain — but wall-clock time
    /// still passes (`busy_until` comparisons use absolute cycles, so a
    /// pending access "ages" through the window exactly as a real
    /// refresh-blocked command would).
    pub fn refresh_stall(&mut self, cycle: u64, stats: &mut Stats) {
        self.cycle = cycle;
        stats.bump(Counter::FaultDramRefreshStallCycles);
    }

    /// Refresh-stall cycles inside a leapt span (closed-form companion
    /// of [`MemoryController::refresh_stall`]): exact because a leap
    /// only engages when the controller is idle and the command channel
    /// empty, and then a refresh-stall tick differs from an idle tick
    /// only in which counter it bumps.
    pub fn skip_refresh_cycles(&self, n: u64, stats: &mut Stats) {
        debug_assert!(self.is_idle(), "bulk-skipping a busy controller");
        stats.add(Counter::FaultDramRefreshStallCycles, n);
    }

    /// Lines committed to the store on behalf of write port `port` so
    /// far (0 if the port never wrote).
    pub fn write_lines_landed(&self, port: usize) -> u64 {
        self.write_lines_landed.get(port).copied().unwrap_or(0)
    }

    /// Preload lines into the backing store (tensor upload path). A
    /// no-op in elided mode: reads never consult the store there, so
    /// storing payload would only cost memory.
    pub fn preload(&mut self, base: LineAddr, lines: impl IntoIterator<Item = Line>) {
        if self.payload.is_elided() {
            return;
        }
        for (i, line) in lines.into_iter().enumerate() {
            assert_eq!(line.num_words(), self.words_per_line);
            self.store.insert(base + i as u64, line);
        }
    }

    /// Read lines back out (result download / golden checks). In
    /// elided mode every line is a shadow — content checks are
    /// meaningless there by construction.
    pub fn dump(&self, base: LineAddr, count: usize) -> Vec<Line> {
        if self.payload.is_elided() {
            return (0..count).map(|_| Line::elided(self.words_per_line)).collect();
        }
        (0..count as u64)
            .map(|i| {
                self.store
                    .get(&(base + i))
                    .cloned()
                    .unwrap_or_else(|| Line::zeroed(self.words_per_line))
            })
            .collect()
    }

    pub fn lines_stored(&self) -> usize {
        self.store.len()
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_none()
    }

    /// Account bank/row timing for accessing `addr`; returns the cycle at
    /// which the access may complete.
    fn access_ready_cycle(&mut self, addr: LineAddr, stats: &mut Stats) -> u64 {
        let (bank, row) = self.timing.map(addr);
        let mut ready = self.cycle.max(self.busy_until);
        if self.open_rows[bank] != Some(row) {
            ready += self.timing.row_miss_cycles;
            self.open_rows[bank] = Some(row);
            stats.bump(Counter::DramRowMisses);
        } else {
            stats.bump(Counter::DramRowHits);
        }
        ready + self.timing.line_cycles - 1
    }

    /// One controller-domain cycle.
    pub fn tick(
        &mut self,
        cycle: u64,
        cmd_ch: &mut Channel<MemCommand>,
        rd_line_ch: &mut Channel<TaggedLine>,
        wr_data_ch: &mut Channel<Line>,
        stats: &mut Stats,
    ) {
        self.cycle = cycle;

        // Accept a new command when idle.
        if self.active.is_none() {
            if let Some(cmd) = cmd_ch.pop() {
                match cmd {
                    MemCommand::Read { port, addr, burst_len } => {
                        self.active =
                            Some(Active::Read { port, next_addr: addr, remaining: burst_len });
                        self.busy_until = cycle + self.timing.read_latency_cycles;
                        stats.bump(Counter::DramReadBursts);
                    }
                    MemCommand::Write { port, addr, burst_len } => {
                        self.active =
                            Some(Active::Write { port, next_addr: addr, remaining: burst_len });
                        self.busy_until = cycle + self.timing.write_latency_cycles;
                        stats.bump(Counter::DramWriteBursts);
                    }
                }
            }
        }

        let Some(active) = self.active.as_mut() else {
            stats.bump(Counter::DramIdleCycles);
            return;
        };

        match active {
            Active::Read { port, next_addr, remaining: _ } => {
                if !rd_line_ch.can_push() {
                    stats.bump(Counter::DramReadReturnStall);
                    return;
                }
                let addr = *next_addr;
                let port = *port;
                let ready = self.access_ready_cycle(addr, stats);
                if ready > cycle {
                    self.busy_until = ready;
                    stats.bump(Counter::DramTimingStallCycles);
                    return;
                }
                let line = if self.payload.is_elided() {
                    Line::elided(self.words_per_line)
                } else {
                    self.store
                        .get(&addr)
                        .cloned()
                        .unwrap_or_else(|| Line::zeroed(self.words_per_line))
                };
                rd_line_ch.push(TaggedLine { port, line });
                stats.bump(Counter::DramReadLines);
                match self.active.as_mut().unwrap() {
                    Active::Read { next_addr, remaining, .. } => {
                        *next_addr += 1;
                        *remaining -= 1;
                        if *remaining == 0 {
                            self.active = None;
                        }
                    }
                    _ => unreachable!(),
                }
            }
            Active::Write { port, next_addr, remaining: _ } => {
                let addr = *next_addr;
                let port = *port;
                let ready = self.access_ready_cycle(addr, stats);
                if ready > cycle {
                    self.busy_until = ready;
                    stats.bump(Counter::DramTimingStallCycles);
                    return;
                }
                let Some(line) = wr_data_ch.pop() else {
                    stats.bump(Counter::DramWriteDataStall);
                    return;
                };
                // Elided mode: the landed counter below is the only
                // observable consequence of a write (the PR 3 flush
                // signal); storing a shadow would buy nothing.
                if !self.payload.is_elided() {
                    self.store.insert(addr, line);
                }
                stats.bump(Counter::DramWriteLines);
                if port >= self.write_lines_landed.len() {
                    self.write_lines_landed.resize(port + 1, 0);
                }
                self.write_lines_landed[port] += 1;
                match self.active.as_mut().unwrap() {
                    Active::Write { next_addr, remaining, .. } => {
                        *next_addr += 1;
                        *remaining -= 1;
                        if *remaining == 0 {
                            self.active = None;
                        }
                    }
                    _ => unreachable!(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_line(n: usize, seed: u64) -> Line {
        Line::from_words((0..n as u64).map(|y| seed * 100 + y).collect())
    }

    fn run_read(
        ctl: &mut MemoryController,
        cmd: MemCommand,
        max_cycles: u64,
    ) -> (Vec<TaggedLine>, u64) {
        let mut cmd_ch = Channel::new("cmd", 4);
        let mut rd_ch = Channel::new("rd", 64);
        let mut wr_ch = Channel::new("wr", 4);
        let mut stats = Stats::new();
        cmd_ch.push(cmd);
        cmd_ch.commit();
        let mut out = Vec::new();
        let mut last = 0;
        for c in 0..max_cycles {
            ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
            cmd_ch.commit();
            rd_ch.commit();
            wr_ch.commit();
            while let Some(tl) = rd_ch.pop() {
                out.push(tl);
                last = c;
            }
            if ctl.is_idle() && rd_ch.is_empty() && !out.is_empty() {
                break;
            }
        }
        (out, last)
    }

    #[test]
    fn read_returns_preloaded_data() {
        let mut ctl = MemoryController::new(DdrTiming::ideal(), 4);
        ctl.preload(10, (0..3).map(|i| mk_line(4, i)));
        let (out, _) = run_read(&mut ctl, MemCommand::Read { port: 2, addr: 10, burst_len: 3 }, 100);
        assert_eq!(out.len(), 3);
        for (i, tl) in out.iter().enumerate() {
            assert_eq!(tl.port, 2);
            assert_eq!(tl.line, mk_line(4, i as u64));
        }
    }

    #[test]
    fn unwritten_lines_read_zero() {
        let mut ctl = MemoryController::new(DdrTiming::ideal(), 4);
        let (out, _) = run_read(&mut ctl, MemCommand::Read { port: 0, addr: 99, burst_len: 1 }, 50);
        assert_eq!(out[0].line, Line::zeroed(4));
    }

    #[test]
    fn burst_streams_one_line_per_cycle_in_open_row() {
        let mut ctl = MemoryController::new(DdrTiming::ddr3_800(), 4);
        ctl.preload(0, (0..8).map(|i| mk_line(4, i)));
        let (out, last) = run_read(&mut ctl, MemCommand::Read { port: 0, addr: 0, burst_len: 8 }, 200);
        assert_eq!(out.len(), 8);
        // 8 lines in one row: one row miss + ~1 line/cycle streaming.
        let t = DdrTiming::ddr3_800();
        assert!(
            last <= t.read_latency_cycles + t.row_miss_cycles + 8 + 4,
            "burst took until cycle {last}"
        );
    }

    #[test]
    fn row_misses_cost_cycles() {
        // Two bursts in different rows must be slower than two in the
        // same row.
        let t = DdrTiming::ddr3_800();
        let same_row = {
            let mut ctl = MemoryController::new(t, 4);
            let (_, last) =
                run_read(&mut ctl, MemCommand::Read { port: 0, addr: 0, burst_len: 8 }, 300);
            last
        };
        let cross_rows = {
            let mut ctl = MemoryController::new(t, 4);
            // Stride so every line lands in a new row (row_lines=16, 8
            // banks: stride by 16*8=128 lines revisits bank 0 row+1).
            let mut cmd_ch = Channel::new("cmd", 16);
            let mut rd_ch = Channel::new("rd", 64);
            let mut wr_ch = Channel::new("wr", 4);
            let mut stats = Stats::new();
            for i in 0..8u64 {
                cmd_ch.push(MemCommand::Read { port: 0, addr: i * 128, burst_len: 1 });
            }
            cmd_ch.commit();
            let mut got = 0;
            let mut last = 0;
            for c in 0..2000 {
                ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
                cmd_ch.commit();
                rd_ch.commit();
                while rd_ch.pop().is_some() {
                    got += 1;
                    last = c;
                }
                if got == 8 {
                    break;
                }
            }
            assert_eq!(got, 8);
            last
        };
        assert!(
            cross_rows > same_row + 4 * t.row_miss_cycles,
            "row misses too cheap: same={same_row} cross={cross_rows}"
        );
    }

    #[test]
    fn write_then_read_roundtrip() {
        let mut ctl = MemoryController::new(DdrTiming::ideal(), 4);
        let mut cmd_ch = Channel::new("cmd", 4);
        let mut rd_ch = Channel::new("rd", 16);
        let mut wr_ch = Channel::new("wr", 16);
        let mut stats = Stats::new();
        cmd_ch.push(MemCommand::Write { port: 1, addr: 5, burst_len: 2 });
        for i in 0..2 {
            wr_ch.push(mk_line(4, 40 + i));
        }
        cmd_ch.commit();
        wr_ch.commit();
        for c in 0..50 {
            ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
            cmd_ch.commit();
            rd_ch.commit();
            wr_ch.commit();
            if ctl.is_idle() && c > 5 {
                break;
            }
        }
        assert_eq!(ctl.lines_stored(), 2);
        assert_eq!(ctl.dump(5, 2), vec![mk_line(4, 40), mk_line(4, 41)]);
    }

    #[test]
    fn write_stalls_until_data_arrives() {
        let mut ctl = MemoryController::new(DdrTiming::ideal(), 4);
        let mut cmd_ch = Channel::new("cmd", 4);
        let mut rd_ch = Channel::new("rd", 4);
        let mut wr_ch = Channel::new("wr", 4);
        let mut stats = Stats::new();
        cmd_ch.push(MemCommand::Write { port: 0, addr: 0, burst_len: 1 });
        cmd_ch.commit();
        for c in 0..10 {
            ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
            cmd_ch.commit();
        }
        assert!(!ctl.is_idle(), "write must wait for its data");
        assert!(stats.get("dram.write_data_stall") > 0);
        wr_ch.push(mk_line(4, 7));
        wr_ch.commit();
        for c in 10..20 {
            ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
            wr_ch.commit();
        }
        assert!(ctl.is_idle());
        assert_eq!(ctl.dump(0, 1)[0], mk_line(4, 7));
    }

    #[test]
    fn backpressure_on_read_return() {
        let mut ctl = MemoryController::new(DdrTiming::ideal(), 4);
        ctl.preload(0, (0..4).map(|i| mk_line(4, i)));
        let mut cmd_ch = Channel::new("cmd", 4);
        let mut rd_ch = Channel::new("rd", 1); // tiny return channel
        let mut wr_ch = Channel::new("wr", 4);
        let mut stats = Stats::new();
        cmd_ch.push(MemCommand::Read { port: 0, addr: 0, burst_len: 4 });
        cmd_ch.commit();
        // Never pop rd_ch: the controller must stall, not drop lines.
        for c in 0..30 {
            ctl.tick(c, &mut cmd_ch, &mut rd_ch, &mut wr_ch, &mut stats);
            cmd_ch.commit();
            rd_ch.commit();
        }
        assert!(stats.get("dram.read_return_stall") > 0);
        assert_eq!(stats.get("dram.read_lines"), 1);
    }
}
