//! Table II: Medusa vs baseline full FPGA resource use at the
//! representative design point (§IV-C): 64-DPU layer processor, 512-bit
//! interface, 32 read + 32 write 16-bit ports, 32-line max bursts.

use crate::eval::report::{count_pct, Table};
use crate::fpga::resources::{
    baseline_read, baseline_write, full_design, medusa_read, medusa_write, Resources,
};
use crate::fpga::Device;
use crate::interconnect::Design;
use crate::types::Geometry;

/// Paper's Table II: (row, LUT, FF, BRAM18, DSP).
pub const PAPER: &[(&str, u64, u64, u64, u64)] = &[
    ("Baseline Read Network", 18_168, 19_210, 0, 0),
    ("Baseline Write Network", 26_810, 35_451, 0, 0),
    ("Baseline Total", 198_887, 240_449, 726, 2_048),
    ("Medusa Read Network", 4_733, 4_759, 32, 0),
    ("Medusa Write Network", 4_777, 4_325, 32, 0),
    ("Medusa Total", 156_409, 195_158, 790, 2_048),
];

pub fn geometry() -> Geometry {
    Geometry::paper_default()
}

pub const DPUS: usize = 64;

/// Model rows in the same order as `PAPER`. Closed-form formulas —
/// sequential on purpose; see `table1::model_rows`.
pub fn model_rows() -> Vec<(&'static str, Resources)> {
    let g = geometry();
    vec![
        ("Baseline Read Network", baseline_read(&g)),
        ("Baseline Write Network", baseline_write(&g)),
        ("Baseline Total", full_design(Design::Baseline, &g, DPUS)),
        ("Medusa Read Network", medusa_read(&g)),
        ("Medusa Write Network", medusa_write(&g)),
        ("Medusa Total", full_design(Design::Medusa, &g, DPUS)),
    ]
}

/// Regenerate Table II.
pub fn table2() -> Table {
    let dev = Device::virtex7_690t();
    let mut t = Table::new(
        "Table II — Medusa vs baseline FPGA resource use (512b, 32r+32w, 64 DPUs)",
        &["component", "LUT", "FF", "BRAM-18K", "DSP", "LUT paper", "FF paper"],
    );
    for ((name, r), (pname, plut, pff, _pbram, _pdsp)) in model_rows().iter().zip(PAPER.iter()) {
        assert_eq!(name, pname);
        t.row(vec![
            name.to_string(),
            count_pct(r.lut, dev.pct_lut(r.lut)),
            count_pct(r.ff, dev.pct_ff(r.ff)),
            count_pct(r.bram18, dev.pct_bram(r.bram18)),
            count_pct(r.dsp, dev.pct_dsp(r.dsp)),
            count_pct(*plut, dev.pct_lut(*plut)),
            count_pct(*pff, dev.pct_ff(*pff)),
        ]);
    }
    t
}

/// The headline factors the abstract quotes.
pub struct Headline {
    pub lut_factor: f64,
    pub ff_factor: f64,
    pub medusa_extra_bram: u64,
    /// Networks' share of total baseline LUT/FF (paper: 22.6% / 22.7%).
    pub baseline_net_lut_share: f64,
    pub baseline_net_ff_share: f64,
    /// ... reduced by Medusa to (paper: 6.1% / 4.7%).
    pub medusa_net_lut_share: f64,
    pub medusa_net_ff_share: f64,
}

pub fn headline() -> Headline {
    let g = geometry();
    let b = baseline_read(&g) + baseline_write(&g);
    let m = medusa_read(&g) + medusa_write(&g);
    let bt = full_design(Design::Baseline, &g, DPUS);
    let mt = full_design(Design::Medusa, &g, DPUS);
    Headline {
        lut_factor: b.lut as f64 / m.lut as f64,
        ff_factor: b.ff as f64 / m.ff as f64,
        medusa_extra_bram: m.bram18,
        baseline_net_lut_share: 100.0 * b.lut as f64 / bt.lut as f64,
        baseline_net_ff_share: 100.0 * b.ff as f64 / bt.ff as f64,
        medusa_net_lut_share: 100.0 * m.lut as f64 / mt.lut as f64,
        medusa_net_ff_share: 100.0 * m.ff as f64 / mt.ff as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_structure() {
        let t = table2();
        assert_eq!(t.rows.len(), 6);
        assert!(t.to_text().contains("Medusa Total"));
    }

    #[test]
    fn headline_shares_match_paper_shape() {
        // Paper §IV-C: networks are 22.6%/22.7% of baseline LUT/FF,
        // reduced to 6.1%/4.7% by Medusa.
        let h = headline();
        assert!((18.0..28.0).contains(&h.baseline_net_lut_share), "{}", h.baseline_net_lut_share);
        assert!((18.0..28.0).contains(&h.baseline_net_ff_share), "{}", h.baseline_net_ff_share);
        assert!(h.medusa_net_lut_share < 9.0, "{}", h.medusa_net_lut_share);
        assert!(h.medusa_net_ff_share < 7.0, "{}", h.medusa_net_ff_share);
        assert_eq!(h.medusa_extra_bram, 64);
    }
}
