//! Scenario matrix: every built-in workload scenario on every
//! interconnect design, with golden-model verification. This is the
//! system-level counterpart of the resource/frequency tables — where
//! Fig 6 asks "does the fabric build?", this asks "how does each
//! workload class actually move through it?".
//!
//! Scenario/design points are independent deterministic simulations, so
//! the matrix runs them across threads (`util::par_map`); results are
//! ordered and bit-identical to a sequential run.

use crate::config::SimBackend;
use crate::eval::report::Table;
use crate::interconnect::Design;
use crate::util::{par_map, par_map_with};
use crate::workload::engine::run_scenario;
use crate::workload::scenario::Scenario;
use anyhow::{Context, Result};

/// One cell of the matrix.
#[derive(Clone, Debug)]
pub struct ScenarioPoint {
    pub scenario: &'static str,
    pub design: Design,
    pub tenants: usize,
    pub fabric_cycles: u64,
    pub sim_time_us: f64,
    pub lines_moved: u64,
    pub verified: bool,
    /// Fingerprint of the full outcome (determinism checks).
    pub fingerprint: u64,
}

fn matrix_points() -> Vec<(&'static str, Design)> {
    let mut out = Vec::new();
    for &name in Scenario::builtin_names() {
        for design in [Design::Baseline, Design::Medusa] {
            out.push((name, design));
        }
    }
    out
}

fn run_point(name: &'static str, design: Design, backend: SimBackend) -> Result<ScenarioPoint> {
    let mut sc = Scenario::builtin(name)
        .ok_or_else(|| anyhow::anyhow!("unknown builtin scenario {name:?}"))?;
    sc.cfg.design = design;
    sc.cfg.sim = backend;
    let out =
        run_scenario(&sc).with_context(|| format!("scenario {name} on {}", design.name()))?;
    Ok(ScenarioPoint {
        scenario: name,
        design,
        tenants: out.tenants.len(),
        fabric_cycles: out.fabric_cycles,
        sim_time_us: out.now_ps as f64 / 1e6,
        lines_moved: out.tenants.iter().map(|t| t.report.total_lines_moved()).sum(),
        verified: out.all_verified(),
        fingerprint: out.fingerprint(),
    })
}

/// Run the matrix with an explicit worker count (determinism tests).
/// Uses the full reference backend: this matrix is where golden-model
/// verification earns its ✓ column.
#[deprecated(since = "0.7.0", note = "use run::RunOptions::new().threads(n).sweep()")]
pub fn sweep_with_threads(workers: usize) -> Result<Vec<ScenarioPoint>> {
    sweep_impl(workers, SimBackend::full())
}

/// The matrix under an explicit simulation backend.
#[deprecated(
    since = "0.7.0",
    note = "use run::RunOptions::new().threads(n).backend(b).sweep()"
)]
pub fn sweep_with_threads_backend(
    workers: usize,
    backend: SimBackend,
) -> Result<Vec<ScenarioPoint>> {
    sweep_impl(workers, backend)
}

/// The matrix under an explicit worker count and simulation backend —
/// the one implementation every public entry point (and
/// `run::RunOptions::sweep`) funnels through. Cycle counts, lines
/// moved, and fabric timing are backend-invariant; the elided backend
/// reports `verified` vacuously (nothing to check) and the fingerprint
/// differs only in the absent feature maps.
pub(crate) fn sweep_impl(workers: usize, backend: SimBackend) -> Result<Vec<ScenarioPoint>> {
    par_map_with(workers, &matrix_points(), move |&(name, design)| {
        run_point(name, design, backend)
    })
    .into_iter()
    .collect()
}

/// Run the full matrix (threaded per `MEDUSA_THREADS`).
pub fn sweep() -> Result<Vec<ScenarioPoint>> {
    par_map(&matrix_points(), |&(name, design)| run_point(name, design, SimBackend::full()))
        .into_iter()
        .collect()
}

/// Render the matrix as a table.
pub fn scenarios() -> Result<Table> {
    let mut t = Table::new(
        "Scenario matrix — workload classes through both interconnects",
        &["scenario", "design", "tenants", "fabric cycles", "sim us", "lines moved", "verified"],
    );
    for p in sweep()? {
        t.row(vec![
            p.scenario.to_string(),
            p.design.name().to_string(),
            p.tenants.to_string(),
            p.fabric_cycles.to_string(),
            format!("{:.1}", p.sim_time_us),
            p.lines_moved.to_string(),
            if p.verified { "✓".to_string() } else { "✗".to_string() },
        ]);
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::run::RunOptions;

    #[test]
    fn matrix_covers_all_builtins_on_both_designs() {
        let pts = RunOptions::new().threads(1).sweep().unwrap();
        assert_eq!(pts.len(), Scenario::builtin_names().len() * 2);
        assert!(pts.iter().all(|p| p.verified), "every matrix point must verify");
        assert!(pts.iter().all(|p| p.lines_moved > 0));
    }

    #[test]
    fn fast_backend_matrix_matches_full_backend_timing() {
        let full = RunOptions::new().threads(2).backend(SimBackend::full()).sweep().unwrap();
        let fast = RunOptions::new().threads(2).backend(SimBackend::fast()).sweep().unwrap();
        assert_eq!(full.len(), fast.len());
        for (a, b) in full.iter().zip(fast.iter()) {
            assert_eq!((a.scenario, a.design), (b.scenario, b.design));
            assert_eq!(a.fabric_cycles, b.fabric_cycles, "{} {:?}", a.scenario, a.design);
            assert_eq!(a.lines_moved, b.lines_moved, "{} {:?}", a.scenario, a.design);
            assert_eq!(a.sim_time_us, b.sim_time_us, "{} {:?}", a.scenario, a.design);
            assert!(a.verified && b.verified);
        }
    }

    #[test]
    fn table_renders() {
        let t = scenarios().unwrap();
        assert!(t.to_text().contains("multi-tenant-mix"));
        assert_eq!(t.rows.len(), Scenario::builtin_names().len() * 2);
    }
}
