//! Table rendering: aligned text for humans, CSV for plotting.

/// A simple table: header + rows of strings.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, header: &[&str]) -> Self {
        Table {
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn to_text(&self) -> String {
        let ncols = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("== {} ==\n", self.title));
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::new();
            for i in 0..ncols {
                if i > 0 {
                    s.push_str("  ");
                }
                s.push_str(&format!("{:>w$}", cells[i], w = widths[i]));
            }
            s.push('\n');
            s
        };
        out.push_str(&fmt_row(&self.header));
        let total: usize = widths.iter().sum::<usize>() + 2 * (ncols - 1);
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let esc = |s: &String| -> String {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.clone()
            }
        };
        let mut out = String::new();
        out.push_str(&self.header.iter().map(esc).collect::<Vec<_>>().join(","));
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(esc).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format a count with the percentage-of-device annotation the paper's
/// tables use, e.g. "18,168 (4.2%)".
pub fn count_pct(n: u64, pct: f64) -> String {
    format!("{} ({:.1}%)", group_thousands(n), pct)
}

/// 18168 -> "18,168".
pub fn group_thousands(n: u64) -> String {
    let s = n.to_string();
    let mut out = String::new();
    for (i, c) in s.chars().enumerate() {
        if i > 0 && (s.len() - i) % 3 == 0 {
            out.push(',');
        }
        out.push(c);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thousands_grouping() {
        assert_eq!(group_thousands(5), "5");
        assert_eq!(group_thousands(5313), "5,313");
        assert_eq!(group_thousands(198887), "198,887");
        assert_eq!(group_thousands(1000000), "1,000,000");
    }

    #[test]
    fn table_renders_aligned_and_csv() {
        let mut t = Table::new("demo", &["a", "metric"]);
        t.row(vec!["x".into(), "1".into()]);
        t.row(vec!["longer".into(), "22".into()]);
        let text = t.to_text();
        assert!(text.contains("== demo =="));
        assert!(text.lines().count() >= 5);
        let csv = t.to_csv();
        assert_eq!(csv.lines().next().unwrap(), "a,metric");
        assert_eq!(csv.lines().count(), 3);
    }

    #[test]
    fn csv_escapes_commas() {
        let mut t = Table::new("t", &["v"]);
        t.row(vec!["a,b".into()]);
        assert!(t.to_csv().contains("\"a,b\""));
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn row_width_checked() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(vec!["only one".into()]);
    }
}
