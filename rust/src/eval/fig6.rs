//! Figure 6: peak post-P&R frequency as the accelerator scales, baseline
//! vs Medusa, across the four memory-interface-width regions (§IV-D).

use crate::eval::report::Table;
use crate::fpga::par::search_peak_frequency;
use crate::fpga::timing::TimingModel;
use crate::fpga::{DesignPoint, Device};
use crate::interconnect::Design;
use crate::util::par_map;

/// One sweep point of the regenerated figure.
#[derive(Clone, Copy, Debug)]
pub struct Fig6Point {
    pub dsps: u64,
    pub ports: usize,
    pub w_line: usize,
    pub baseline_mhz: u32,
    pub medusa_mhz: u32,
}

/// Regenerate the Fig 6 series (both lines).
///
/// Each design point is an independent P&R frequency search, so the
/// sweep runs points across threads (`util::par_map`); results are
/// ordered and bit-identical to a sequential run — the search itself is
/// deterministic and shares no state between points. Set
/// `MEDUSA_THREADS=1` to force the sequential path.
pub fn sweep() -> Vec<Fig6Point> {
    let model = TimingModel::calibrated();
    let dev = Device::virtex7_690t();
    let pairs: Vec<(DesignPoint, DesignPoint)> = DesignPoint::fig6_sweep(Design::Baseline)
        .into_iter()
        .zip(DesignPoint::fig6_sweep(Design::Medusa))
        .collect();
    par_map(&pairs, |(b, m)| Fig6Point {
        dsps: b.dsps(),
        ports: b.geometry.read_ports,
        w_line: b.geometry.w_line,
        baseline_mhz: search_peak_frequency(&model, b, &dev).peak_mhz,
        medusa_mhz: search_peak_frequency(&model, m, &dev).peak_mhz,
    })
}

/// Render as the table backing the figure.
pub fn fig6() -> Table {
    let mut t = Table::new(
        "Fig 6 — peak frequency vs accelerator size (25 MHz P&R search)",
        &["DSPs", "r/w ports", "mem iface", "baseline MHz", "medusa MHz", "speedup"],
    );
    for p in sweep() {
        let speedup = if p.baseline_mhz == 0 {
            "∞".to_string()
        } else {
            format!("{:.2}x", p.medusa_mhz as f64 / p.baseline_mhz as f64)
        };
        t.row(vec![
            p.dsps.to_string(),
            format!("{}+{}", p.ports, p.ports),
            format!("{}-bit", p.w_line),
            p.baseline_mhz.to_string(),
            p.medusa_mhz.to_string(),
            speedup,
        ]);
    }
    t
}

/// ASCII rendering of the figure itself (frequency vs DSPs, two series).
pub fn ascii_plot() -> String {
    let pts = sweep();
    let fmax = 425u32;
    let mut out = String::new();
    out.push_str("peak MHz (B = baseline, M = medusa, X = both)\n");
    let rows: Vec<u32> = (0..=(fmax / 25)).rev().map(|i| i * 25).collect();
    for f in rows {
        let mut line = format!("{f:>4} |");
        for p in &pts {
            let b = p.baseline_mhz == f;
            let m = p.medusa_mhz == f;
            line.push_str(match (b, m) {
                (true, true) => "  X ",
                (true, false) => "  B ",
                (false, true) => "  M ",
                (false, false) => "  . ",
            });
        }
        out.push_str(&line);
        out.push('\n');
    }
    out.push_str("     +");
    for _ in &pts {
        out.push_str("----");
    }
    out.push('\n');
    out.push_str("      ");
    for p in &pts {
        out.push_str(&format!("{:>4}", p.dsps / 32)); // DPUs to keep width small
    }
    out.push_str("  (DPUs; x32 = DSPs)\n");
    out.push_str("      ");
    let mut last_w = 0;
    for p in &pts {
        if p.w_line != last_w {
            out.push_str(&format!("{:>4}", p.w_line));
            last_w = p.w_line;
        } else {
            out.push_str("   .");
        }
    }
    out.push_str("  (memory interface width regions)\n");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_has_eleven_points_and_four_regions() {
        let pts = sweep();
        assert_eq!(pts.len(), 11);
        let mut widths: Vec<usize> = pts.iter().map(|p| p.w_line).collect();
        widths.dedup();
        assert_eq!(widths, vec![128, 256, 512, 1024]);
        assert_eq!(pts[0].dsps, 512);
        assert_eq!(pts[10].dsps, 3072);
    }

    #[test]
    fn figure_reproduces_paper_claims() {
        let pts = sweep();
        // Medusa always outperforms from 1024 DSPs on.
        for p in pts.iter().filter(|p| p.dsps >= 1024) {
            assert!(p.medusa_mhz >= p.baseline_mhz, "{p:?}");
        }
        // 512-bit region: speedup reaches ~1.8x.
        let max_512: f64 = pts
            .iter()
            .filter(|p| p.w_line == 512 && p.baseline_mhz > 0)
            .map(|p| p.medusa_mhz as f64 / p.baseline_mhz as f64)
            .fold(0.0, f64::max);
        assert!(max_512 >= 1.5, "max 512b speedup {max_512:.2}");
        // 1024-bit region: baseline <= 50 MHz with some 0s; Medusa 200+.
        for p in pts.iter().filter(|p| p.w_line == 1024) {
            assert!(p.baseline_mhz <= 50, "{p:?}");
            assert!(p.medusa_mhz >= 200, "{p:?}");
        }
    }

    #[test]
    fn ascii_plot_renders() {
        let s = ascii_plot();
        assert!(s.contains('M'));
        assert!(s.contains("memory interface"));
        assert!(s.lines().count() > 15);
    }
}
