//! Rendering for the design-space explorer (`medusa explore`): the
//! Pareto-frontier table, the full evaluated-set CSV, and the
//! BENCH_PR4.json trajectory record. EXPERIMENTS.md documents how these
//! outputs relate to the paper's two-point comparison.

use crate::eval::report::Table;
use crate::explore::{DesignSpace, SearchResult};

fn point_row(
    p: &crate::explore::ExplorePoint,
    m: &crate::explore::Metrics,
    on_frontier: bool,
) -> Vec<String> {
    vec![
        p.design.spec(),
        format!("{}", p.geometry.w_line),
        format!("{}", p.geometry.read_ports),
        format!("{}", p.channel_depth),
        m.resources.lut.to_string(),
        m.resources.ff.to_string(),
        m.resources.bram18.to_string(),
        if m.feasible() { m.fmax_mhz.to_string() } else { "FAIL".to_string() },
        if m.feasible() { format!("{:.2}", m.gbps()) } else { "-".to_string() },
        if m.serving_p99 > 0 { m.serving_p99.to_string() } else { "-".to_string() },
        if on_frontier { "*".to_string() } else { "".to_string() },
    ]
}

const HEADER: &[&str] = &[
    "design", "iface", "ports", "depth", "LUT", "FF", "BRAM18", "Fmax MHz", "Gbit/s", "p99 cyc",
    "pareto",
];

/// The Pareto frontier as a table.
pub fn frontier_table(result: &SearchResult) -> Table {
    let mut t = Table::new(
        "Design-space explorer — Pareto frontier over {LUT, FF, Fmax, bandwidth}",
        HEADER,
    );
    for e in &result.frontier {
        t.row(point_row(&e.point, &e.metrics, true));
    }
    t
}

/// Every evaluated point (canonical grid order) with frontier markers.
pub fn full_table(result: &SearchResult) -> Table {
    let mut t = Table::new("Design-space explorer — evaluated points", HEADER);
    let on_frontier: Vec<bool> = {
        let mut v = vec![false; result.evaluated.len()];
        for e in &result.frontier {
            v[e.index] = true;
        }
        v
    };
    for ((p, m), f) in result.evaluated.iter().zip(on_frontier) {
        t.row(point_row(p, m, f));
    }
    t
}

/// One-line human summary.
pub fn summary_line(result: &SearchResult, space: &DesignSpace, strategy: &str) -> String {
    format!(
        "explore[{strategy}] probe={}: {} points evaluated ({} computed, {} cache hits), \
         {} feasible, frontier size {}",
        space.probe,
        result.evaluated.len(),
        result.computed,
        result.cache_hits,
        result.evaluated.iter().filter(|(_, m)| m.feasible()).count(),
        result.frontier.len(),
    )
}

/// The BENCH_PR4.json document. `extras` appends pre-rendered JSON
/// fields (the smoke benchmark's timing measurements); each entry is
/// `(key, raw_json_value)`.
pub fn bench_json(
    result: &SearchResult,
    space: &DesignSpace,
    strategy: &str,
    extras: &[(&str, String)],
) -> String {
    let mut j = String::from("{\n");
    j.push_str("  \"bench\": \"explore_pr4\",\n");
    j.push_str(&format!("  \"strategy\": \"{strategy}\",\n"));
    j.push_str(&format!("  \"probe\": \"{}\",\n", space.probe));
    j.push_str(&format!("  \"points_evaluated\": {},\n", result.evaluated.len()));
    j.push_str(&format!("  \"points_computed\": {},\n", result.computed));
    j.push_str(&format!("  \"cache_hits\": {},\n", result.cache_hits));
    for (k, v) in extras {
        j.push_str(&format!("  \"{k}\": {v},\n"));
    }
    // Campaign telemetry: per-point eval time and cache hit/miss, in
    // the same canonical grid order as `evaluated`. Host-side only —
    // the CSV (what CI byte-compares) never carries it.
    j.push_str("  \"telemetry\": {\n");
    let eval_total: f64 = result.timings.iter().map(|t| t.eval_s).sum();
    j.push_str(&format!("    \"eval_seconds_total\": {eval_total:.6},\n"));
    j.push_str("    \"points\": [\n");
    for (i, ((p, _), t)) in result.evaluated.iter().zip(result.timings.iter()).enumerate() {
        j.push_str(&format!(
            "      {{\"index\": {}, \"design\": \"{}\", \"cache_hit\": {}, \"eval_ms\": {:.3}}}{}\n",
            t.index,
            p.design.spec(),
            t.cache_hit,
            t.eval_s * 1e3,
            if i + 1 < result.evaluated.len() { "," } else { "" }
        ));
    }
    j.push_str("    ]\n  },\n");
    j.push_str("  \"frontier\": [\n");
    for (i, e) in result.frontier.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"design\": \"{}\", \"w_line\": {}, \"ports\": {}, \"channel_depth\": {}, \
             \"lut\": {}, \"ff\": {}, \"bram18\": {}, \"fmax_mhz\": {}, \"gbps\": {:.4}, \
             \"serving_p99\": {}}}{}\n",
            e.point.design.spec(),
            e.point.geometry.w_line,
            e.point.geometry.read_ports,
            e.point.channel_depth,
            e.metrics.resources.lut,
            e.metrics.resources.ff,
            e.metrics.resources.bram18,
            e.metrics.fmax_mhz,
            e.metrics.gbps(),
            e.metrics.serving_p99,
            if i + 1 < result.frontier.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    j
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::explore::{run_search, Strategy};

    fn smoke_result() -> (SearchResult, DesignSpace) {
        let space = DesignSpace::smoke();
        let r = run_search(&space, &Strategy::Grid, 1, 2, None).unwrap();
        (r, space)
    }

    #[test]
    fn tables_and_json_render() {
        let (r, space) = smoke_result();
        let ft = frontier_table(&r);
        assert_eq!(ft.rows.len(), r.frontier.len());
        assert!(ft.to_text().contains("Pareto"));
        let full = full_table(&r);
        assert_eq!(full.rows.len(), r.evaluated.len());
        assert_eq!(
            full.rows.iter().filter(|row| row.last().unwrap() == "*").count(),
            r.frontier.len()
        );
        let csv = full.to_csv();
        assert!(csv.lines().count() > r.evaluated.len());
        let j = bench_json(&r, &space, "grid", &[("elapsed_s", "1.5".to_string())]);
        assert!(j.contains("\"bench\": \"explore_pr4\""));
        assert!(j.contains("\"elapsed_s\": 1.5"));
        assert!(j.contains("\"frontier\""));
        assert!(j.contains("\"telemetry\""), "per-point campaign telemetry must render");
        assert!(j.contains("\"cache_hit\": false"));
        assert_eq!(j.matches("\"eval_ms\"").count(), r.evaluated.len());
        let line = summary_line(&r, &space, "grid");
        assert!(line.contains("frontier size"));
    }

    #[test]
    fn frontier_contains_medusa_like_points_on_smoke_grid() {
        // On small geometries the baseline is competitive (Fig 6's
        // left region), so the frontier should contain more than one
        // design family — the whole reason the explorer exists.
        let (r, _) = smoke_result();
        assert!(r.frontier.len() >= 2, "degenerate frontier: {:?}", r.frontier.len());
        let specs: Vec<String> = r.frontier.iter().map(|e| e.point.design.spec()).collect();
        let families: std::collections::BTreeSet<&str> = specs
            .iter()
            .map(|s| s.split(':').next().unwrap())
            .collect();
        assert!(families.len() >= 2, "frontier collapsed to one family: {specs:?}");
    }
}
