//! Table I: baseline data transfer networks vs AXI4-Stream networks
//! (1x256-bit port to 16x16-bit ports, FIFO depth 32; post-synthesis
//! LUT/FF; no DSPs or BRAMs are used by either).

use crate::eval::report::{count_pct, Table};
use crate::fpga::resources::{axis_read, axis_write, baseline_read, baseline_write, Resources};
use crate::fpga::Device;
use crate::types::Geometry;

/// The paper's published Table I numbers, for side-by-side reporting.
pub const PAPER: &[(&str, u64, u64)] = &[
    ("Base (Read)", 5_313, 5_404),
    ("AXIS (Read)", 11_562, 27_173),
    ("Base (Write)", 6_810, 9_023),
    ("AXIS (Write)", 9_170, 26_554),
];

pub fn geometry() -> Geometry {
    Geometry { w_line: 256, w_acc: 16, read_ports: 16, write_ports: 16, max_burst: 32 }
}

/// Model rows in paper order. The cells are closed-form resource
/// formulas (nanoseconds each), so this stays sequential — threads only
/// pay off for the P&R searches (`eval::fig6`) and the behavioural
/// simulations (bench targets).
pub fn model_rows() -> Vec<(&'static str, Resources)> {
    let g = geometry();
    vec![
        ("Base (Read)", baseline_read(&g)),
        ("AXIS (Read)", axis_read(&g)),
        ("Base (Write)", baseline_write(&g)),
        ("AXIS (Write)", axis_write(&g)),
    ]
}

/// Regenerate Table I from the resource model.
pub fn table1() -> Table {
    let dev = Device::virtex7_690t();
    let cells = model_rows();
    let mut t = Table::new(
        "Table I — baseline vs AXI4-Stream networks (256b -> 16x16b)",
        &["network", "LUT (model)", "FF (model)", "LUT (paper)", "FF (paper)", "LUT err%", "FF err%"],
    );
    for ((name, r), (pname, plut, pff)) in cells.iter().zip(PAPER.iter()) {
        assert_eq!(name, pname);
        let le = 100.0 * (r.lut as f64 - *plut as f64) / *plut as f64;
        let fe = 100.0 * (r.ff as f64 - *pff as f64) / *pff as f64;
        t.row(vec![
            name.to_string(),
            count_pct(r.lut, dev.pct_lut(r.lut)),
            count_pct(r.ff, dev.pct_ff(r.ff)),
            count_pct(*plut, dev.pct_lut(*plut)),
            count_pct(*pff, dev.pct_ff(*pff)),
            format!("{le:+.1}"),
            format!("{fe:+.1}"),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_has_all_four_networks() {
        let t = table1();
        assert_eq!(t.rows.len(), 4);
        let text = t.to_text();
        for (name, ..) in PAPER {
            assert!(text.contains(name), "missing {name}");
        }
    }

    #[test]
    fn table1_ordering_matches_paper() {
        // The claim Table I supports: our baseline is cheaper than the
        // AXIS IP on every metric.
        let g = geometry();
        assert!(baseline_read(&g).lut < axis_read(&g).lut);
        assert!(baseline_read(&g).ff < axis_read(&g).ff);
        assert!(baseline_write(&g).lut < axis_write(&g).lut);
        assert!(baseline_write(&g).ff < axis_write(&g).ff);
        // And the FF gap is the dominant one, as in the paper (5x / 2.9x).
        let ff_ratio = axis_read(&g).ff as f64 / baseline_read(&g).ff as f64;
        assert!(ff_ratio > 2.0, "AXIS read FF ratio {ff_ratio:.2}");
    }
}
