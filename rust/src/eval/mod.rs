//! Evaluation harness: regenerates every table and figure of the paper's
//! evaluation section (§IV), plus the design-space explorer output that
//! goes beyond it. Each submodule produces the same rows / series the
//! paper reports; `report` renders them as aligned text and CSV.
//! EXPERIMENTS.md (repo root) records paper-vs-measured for each cell.

pub mod explore;
pub mod fig6;
pub mod report;
pub mod scenarios;
pub mod table1;
pub mod table2;

pub use fig6::fig6;
pub use scenarios::scenarios;
pub use table1::table1;
pub use table2::table2;
