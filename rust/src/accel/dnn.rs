//! DNN workload descriptors: convolution layer shapes and small
//! VGG-style networks used by the traffic generators, the end-to-end
//! examples, and the benchmark harness.
//!
//! This module keeps the original straight-chain dense-conv form; the
//! generalized workload representation (grouped/depthwise convs, GEMMs,
//! residual graphs, multi-tenant scenarios) lives in
//! [`crate::workload`], which converts these legacy networks via
//! [`crate::workload::WorkloadNet::from_legacy`].

/// One 3D convolution layer (the paper's layer processors compute these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvLayer {
    pub name: &'static str,
    /// Input feature map: channels x height x width.
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    /// Output channels (number of filters).
    pub out_c: usize,
    /// Square kernel size.
    pub k: usize,
    /// Stride (same both dims).
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
    /// Apply ReLU after the conv.
    pub relu: bool,
}

impl ConvLayer {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k) / self.stride + 1
    }

    /// Words in the input feature map.
    pub fn ifmap_words(&self) -> usize {
        self.in_c * self.in_h * self.in_w
    }

    /// Words in the output feature map.
    pub fn ofmap_words(&self) -> usize {
        self.out_c * self.out_h() * self.out_w()
    }

    /// Words of weights (+ one bias word per output channel).
    pub fn weight_words(&self) -> usize {
        self.out_c * self.in_c * self.k * self.k + self.out_c
    }

    /// Multiply-accumulates to compute the layer.
    pub fn macs(&self) -> u64 {
        (self.out_c * self.out_h() * self.out_w() * self.in_c * self.k * self.k) as u64
    }
}

/// A feed-forward stack of conv layers.
#[derive(Clone, Debug)]
pub struct Network {
    pub name: &'static str,
    pub layers: Vec<ConvLayer>,
}

impl Network {
    /// A small VGG-style network on 32x32 inputs — the end-to-end
    /// example workload. Channel growth and 3x3/pad-1 structure follow
    /// VGGNet (the paper's buffer-sizing reference, §IV-A), scaled to a
    /// CIFAR-sized input so the full inference runs through the
    /// cycle-accurate interconnect in seconds.
    pub fn tiny_vgg() -> Network {
        let conv = |name, in_c, in_hw, out_c| ConvLayer {
            name,
            in_c,
            in_h: in_hw,
            in_w: in_hw,
            out_c,
            k: 3,
            stride: 1,
            pad: 1,
            relu: true,
        };
        Network {
            name: "tiny-vgg",
            layers: vec![
                conv("conv1", 3, 32, 16),
                conv("conv2", 16, 32, 16),
                // "Pooling" via stride-2 conv keeps the substrate pure-conv.
                ConvLayer { name: "down1", in_c: 16, in_h: 32, in_w: 32, out_c: 32, k: 3, stride: 2, pad: 1, relu: true },
                conv("conv3", 32, 16, 32),
                ConvLayer { name: "down2", in_c: 32, in_h: 16, in_w: 16, out_c: 64, k: 3, stride: 2, pad: 1, relu: true },
                conv("conv4", 64, 8, 64),
            ],
        }
    }

    /// The first conv layers of VGG-16 proper (for traffic realism in
    /// benchmarks; full fmaps, real bandwidth shapes).
    pub fn vgg16_head() -> Network {
        Network {
            name: "vgg16-head",
            layers: vec![
                ConvLayer { name: "conv1_1", in_c: 3, in_h: 224, in_w: 224, out_c: 64, k: 3, stride: 1, pad: 1, relu: true },
                ConvLayer { name: "conv1_2", in_c: 64, in_h: 224, in_w: 224, out_c: 64, k: 3, stride: 1, pad: 1, relu: true },
                ConvLayer { name: "conv2_1", in_c: 64, in_h: 112, in_w: 112, out_c: 128, k: 3, stride: 1, pad: 1, relu: true },
            ],
        }
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs()).sum()
    }

    /// Check layer shapes chain correctly (out of layer i feeds layer
    /// i+1, allowing spatial downsampling between them).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(!self.layers.is_empty(), "network has no layers");
        for w in self.layers.windows(2) {
            let (a, b) = (&w[0], &w[1]);
            anyhow::ensure!(
                a.out_c == b.in_c,
                "{}: out_c {} != {} in_c {}",
                a.name,
                a.out_c,
                b.name,
                b.in_c
            );
            anyhow::ensure!(
                a.out_h() == b.in_h && a.out_w() == b.in_w,
                "{} -> {}: spatial mismatch {}x{} -> {}x{}",
                a.name,
                b.name,
                a.out_h(),
                a.out_w(),
                b.in_h,
                b.in_w
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shape_arithmetic() {
        let l = ConvLayer { name: "t", in_c: 3, in_h: 32, in_w: 32, out_c: 16, k: 3, stride: 1, pad: 1, relu: true };
        assert_eq!(l.out_h(), 32);
        assert_eq!(l.out_w(), 32);
        assert_eq!(l.ifmap_words(), 3 * 32 * 32);
        assert_eq!(l.ofmap_words(), 16 * 32 * 32);
        assert_eq!(l.weight_words(), 16 * 3 * 9 + 16);
        assert_eq!(l.macs(), (16 * 32 * 32 * 3 * 9) as u64);
    }

    #[test]
    fn strided_conv_downsamples() {
        let l = ConvLayer { name: "t", in_c: 16, in_h: 32, in_w: 32, out_c: 32, k: 3, stride: 2, pad: 1, relu: true };
        assert_eq!(l.out_h(), 16);
        assert_eq!(l.out_w(), 16);
    }

    #[test]
    fn tiny_vgg_chains() {
        let n = Network::tiny_vgg();
        n.validate().unwrap();
        assert!(n.total_macs() > 5_000_000, "workload should be non-trivial");
    }

    #[test]
    fn vgg16_head_chains() {
        // conv1_2 -> conv2_1 has a 2x pool between them in real VGG; our
        // head models it by halving, so validate() must fail — the head
        // is used per-layer, not chained.
        let n = Network::vgg16_head();
        assert_eq!(n.layers[0].out_h(), 224);
        assert!(n.validate().is_err());
    }
}
