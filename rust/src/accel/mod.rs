//! The DNN accelerator substrate: convolutional layer processors, their
//! buffers and perfect-prefetch traffic, DNN workload descriptors, and
//! the 16-bit fixed-point arithmetic + golden reference the end-to-end
//! integrity checks rely on.

pub mod dnn;
pub mod golden;
pub mod layer_processor;
pub mod prefetch;
pub mod quant;

pub use dnn::{ConvLayer, Network};
pub use layer_processor::LayerProcessor;
pub use quant::Fixed16;
