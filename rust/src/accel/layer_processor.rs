//! Layer-processor model: the DMA + compute engine that drives the
//! interconnect exactly the way the paper's convolutional layer
//! processors do — every narrow port streaming its statically assigned,
//! perfectly prefetched share of the tensors at one word per cycle, with
//! double-buffered compute overlapped conceptually (compute stall cycles
//! are modelled; the arithmetic itself is delegated to the golden model
//! or the PJRT artifact by the coordinator).

use crate::accel::prefetch::{bursts, PortSchedule, Region};
use crate::config::PayloadMode;
use crate::interconnect::arbiter::Arbiter;
use crate::interconnect::{ReadNetwork, WriteNetwork};
use crate::sim::stats::Counter;
use crate::sim::Stats;
use crate::types::{Geometry, ReadRequest, Word, WriteRequest};
use std::collections::VecDeque;

/// Execution phase of one layer pass.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// Streaming ifmap + weights in through the read ports.
    Load,
    /// MAC array busy (modelled stall; coordinator runs the real math).
    Compute,
    /// Streaming the ofmap out through the write ports.
    Drain,
    Done,
}

/// The contiguous slice of fabric ports one layer processor drives.
///
/// A single-tenant system uses [`PortGroup::full`] (every port); the
/// workload scenario engine slices the fabric into disjoint groups so
/// several layer processors — one per tenant network — share one
/// interconnect and one DRAM controller.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct PortGroup {
    /// First global read-port index this processor owns.
    pub read_base: usize,
    /// Number of read ports it owns.
    pub read_ports: usize,
    /// First global write-port index it owns.
    pub write_base: usize,
    /// Number of write ports it owns.
    pub write_ports: usize,
}

impl PortGroup {
    /// The whole fabric (single-tenant case).
    pub fn full(geom: &Geometry) -> Self {
        PortGroup {
            read_base: 0,
            read_ports: geom.read_ports,
            write_base: 0,
            write_ports: geom.write_ports,
        }
    }

    pub fn validate(&self, geom: &Geometry) -> anyhow::Result<()> {
        anyhow::ensure!(self.read_ports >= 1, "port group needs at least one read port");
        anyhow::ensure!(self.write_ports >= 1, "port group needs at least one write port");
        anyhow::ensure!(
            self.read_base + self.read_ports <= geom.read_ports,
            "read port group {}..{} exceeds geometry ({} read ports)",
            self.read_base,
            self.read_base + self.read_ports,
            geom.read_ports
        );
        anyhow::ensure!(
            self.write_base + self.write_ports <= geom.write_ports,
            "write port group {}..{} exceeds geometry ({} write ports)",
            self.write_base,
            self.write_base + self.write_ports,
            geom.write_ports
        );
        Ok(())
    }
}

struct ReadPortState {
    /// Bursts not yet submitted to the arbiter.
    pending_bursts: VecDeque<Region>,
    /// Words still expected on this port.
    words_left: usize,
    /// Gathered words, in stream order.
    received: Vec<Word>,
}

/// The words a write port still has to stream: real data (full mode)
/// or a bare count of shadow words (payload-elided mode). Front/advance
/// semantics are identical, so the drain loop — and every stat and
/// stall decision in it — cannot tell the difference.
enum WordStream {
    Data(VecDeque<Word>),
    Counted(usize),
}

impl WordStream {
    /// The next word to push, if any (shadow words read as 0).
    fn front(&self) -> Option<Word> {
        match self {
            WordStream::Data(q) => q.front().copied(),
            WordStream::Counted(0) => None,
            WordStream::Counted(_) => Some(0),
        }
    }

    fn advance(&mut self) {
        match self {
            WordStream::Data(q) => {
                q.pop_front();
            }
            WordStream::Counted(left) => *left -= 1,
        }
    }

    fn is_empty(&self) -> bool {
        match self {
            WordStream::Data(q) => q.is_empty(),
            WordStream::Counted(left) => *left == 0,
        }
    }
}

struct WritePortState {
    pending_bursts: VecDeque<Region>,
    /// Words queued for pushing on this port.
    to_send: WordStream,
}

pub struct LayerProcessor {
    geom: Geometry,
    /// The slice of fabric ports this processor owns (local port `p`
    /// maps to global read port `group.read_base + p`, and likewise for
    /// writes).
    group: PortGroup,
    /// Number of vector dot-product units (compute-rate model).
    dpus: usize,
    phase: Phase,
    read_ports: Vec<ReadPortState>,
    write_ports: Vec<WritePortState>,
    compute_cycles_left: u64,
    /// MACs of the current layer (set at `begin_layer`).
    macs: u64,
    /// Phase cycle accounting.
    pub load_cycles: u64,
    pub compute_cycles: u64,
    pub drain_cycles: u64,
    /// Cumulative cycles each local read port spent waiting for a word
    /// (the per-port wait counters the trace expect-block records).
    read_wait_cycles: Vec<u64>,
    /// Cumulative cycles each local write port spent back-pressured.
    write_wait_cycles: Vec<u64>,
    /// Fast backend: don't retain loaded words (payload is shadows).
    payload: PayloadMode,
}

impl LayerProcessor {
    pub fn new(geom: Geometry, dpus: usize) -> Self {
        Self::new_grouped(geom, dpus, PortGroup::full(&geom))
    }

    /// A processor driving only `group`'s slice of the fabric ports
    /// (multi-tenant scenarios).
    pub fn new_grouped(geom: Geometry, dpus: usize, group: PortGroup) -> Self {
        LayerProcessor {
            geom,
            dpus,
            phase: Phase::Done,
            read_ports: Vec::new(),
            write_ports: Vec::new(),
            compute_cycles_left: 0,
            macs: 0,
            load_cycles: 0,
            compute_cycles: 0,
            drain_cycles: 0,
            read_wait_cycles: vec![0; group.read_ports],
            write_wait_cycles: vec![0; group.write_ports],
            payload: PayloadMode::Full,
            group,
        }
    }

    /// Select payload handling; call before the first `begin_layer`.
    pub fn set_payload_mode(&mut self, mode: PayloadMode) {
        assert_eq!(self.phase, Phase::Done, "payload mode change mid-layer");
        self.payload = mode;
    }

    pub fn phase(&self) -> Phase {
        self.phase
    }

    pub fn group(&self) -> PortGroup {
        self.group
    }

    /// Cumulative wait cycles of local read port `p` across all layers.
    pub fn read_wait_cycles(&self, p: usize) -> u64 {
        self.read_wait_cycles[p]
    }

    /// Cumulative back-pressure cycles of local write port `p`.
    pub fn write_wait_cycles(&self, p: usize) -> u64 {
        self.write_wait_cycles[p]
    }

    /// Arm the processor for one layer: per-port read schedules (from
    /// `prefetch::read_schedules`, one per *local* port) and the layer's
    /// MAC count.
    pub fn begin_layer(&mut self, read_scheds: &[PortSchedule], macs: u64) {
        assert_eq!(read_scheds.len(), self.group.read_ports);
        let n = self.geom.words_per_line();
        let elided = self.payload.is_elided();
        self.read_ports = read_scheds
            .iter()
            .map(|s| {
                let words = s.total_lines() * n;
                ReadPortState {
                    pending_bursts: bursts(s, self.geom.max_burst).into(),
                    words_left: words,
                    // Elided mode gathers nothing: `words_left` alone
                    // drives phase progress (and it must — the PR 3
                    // determinism invariant forbids data-driven control).
                    received: Vec::with_capacity(if elided { 0 } else { words }),
                }
            })
            .collect();
        self.macs = macs;
        self.phase = if self.read_ports.iter().all(|p| p.words_left == 0) {
            Phase::Compute
        } else {
            Phase::Load
        };
        if self.phase == Phase::Compute {
            self.compute_cycles_left = self.compute_stall_cycles();
        }
    }

    /// Cycles the MAC array needs for the layer: MACs / (DPUs x 32
    /// multipliers), at one issue per cycle (the paper's DPUs are
    /// 32-wide). The coordinator overlaps this with the next layer's
    /// load when double buffering is enabled.
    pub fn compute_stall_cycles(&self) -> u64 {
        (self.macs / (self.dpus as u64 * 32)).max(1)
    }

    /// The loaded words of read port `p`, in stream order. Valid once
    /// the phase has advanced past `Load`.
    pub fn loaded(&self, p: usize) -> &[Word] {
        &self.read_ports[p].received
    }

    /// Supply the computed output and its per-port write schedules; the
    /// processor moves to `Drain` and streams it out.
    pub fn supply_output(&mut self, write_scheds: &[PortSchedule], data_per_port: Vec<VecDeque<Word>>) {
        assert!(!self.payload.is_elided(), "full-payload output supplied in elided mode");
        assert_eq!(data_per_port.len(), self.group.write_ports);
        let n = self.geom.words_per_line();
        self.supply_output_streams(
            write_scheds,
            write_scheds
                .iter()
                .zip(data_per_port)
                .map(|(s, data)| {
                    assert_eq!(data.len(), s.total_lines() * n, "write data must fill whole lines");
                    WordStream::Data(data)
                })
                .collect(),
        );
    }

    /// Payload-elided twin of [`supply_output`]: arm the drain phase
    /// with shadow word counts derived from the schedules alone. Word
    /// counts, burst submissions, and phase transitions are identical
    /// to a full-mode drain of the same schedules.
    ///
    /// [`supply_output`]: LayerProcessor::supply_output
    pub fn supply_output_elided(&mut self, write_scheds: &[PortSchedule]) {
        assert!(self.payload.is_elided(), "elided output supplied in full mode");
        let n = self.geom.words_per_line();
        self.supply_output_streams(
            write_scheds,
            write_scheds.iter().map(|s| WordStream::Counted(s.total_lines() * n)).collect(),
        );
    }

    fn supply_output_streams(&mut self, write_scheds: &[PortSchedule], streams: Vec<WordStream>) {
        assert_eq!(self.phase, Phase::Compute);
        assert_eq!(write_scheds.len(), self.group.write_ports);
        self.write_ports = write_scheds
            .iter()
            .zip(streams)
            .map(|(s, to_send)| WritePortState {
                pending_bursts: bursts(s, self.geom.max_burst).into(),
                to_send,
            })
            .collect();
        self.phase = if self.write_ports.iter().all(|w| w.to_send.is_empty()) {
            Phase::Done
        } else {
            Phase::Drain
        };
    }

    /// One fabric cycle. The coordinator calls this after ticking the
    /// networks. Returns the (possibly advanced) phase.
    pub fn tick<R, W>(
        &mut self,
        rd_net: &mut R,
        wr_net: &mut W,
        arbiter: &mut Arbiter,
        stats: &mut Stats,
    ) -> Phase
    where
        R: ReadNetwork + ?Sized,
        W: WriteNetwork + ?Sized,
    {
        let elided = self.payload.is_elided();
        match self.phase {
            Phase::Load => {
                self.load_cycles += 1;
                let rbase = self.group.read_base;
                let mut all_done = true;
                for (p, st) in self.read_ports.iter_mut().enumerate() {
                    let gp = rbase + p;
                    // Submit the next burst request (the arbiter
                    // back-pressures via its bounded queue).
                    if let Some(&b) = st.pending_bursts.front() {
                        if arbiter.submit_read(ReadRequest { port: gp, addr: b.base, burst_len: b.lines }) {
                            st.pending_bursts.pop_front();
                            stats.bump(Counter::LpReadBurstsSubmitted);
                        }
                    }
                    // Consume one word per cycle — the paper's port rate.
                    if st.words_left > 0 {
                        if rd_net.port_word_available(gp) {
                            // The pop must happen in elided mode too —
                            // it advances the network's drain pointers;
                            // only the retention is payload.
                            let w = rd_net.port_take_word(gp).unwrap();
                            if !elided {
                                st.received.push(w);
                            }
                            st.words_left -= 1;
                            stats.bump(Counter::LpWordsLoaded);
                        } else {
                            stats.bump(Counter::LpLoadStallPortCycles);
                            self.read_wait_cycles[p] += 1;
                        }
                    }
                    all_done &= st.words_left == 0 && st.pending_bursts.is_empty();
                }
                if all_done {
                    self.phase = Phase::Compute;
                    self.compute_cycles_left = self.compute_stall_cycles();
                }
            }
            Phase::Compute => {
                self.compute_cycles += 1;
                self.compute_cycles_left = self.compute_cycles_left.saturating_sub(1);
                // The coordinator notices compute_done() and calls
                // supply_output(); we stay here until then.
            }
            Phase::Drain => {
                self.drain_cycles += 1;
                let wbase = self.group.write_base;
                let mut all_done = true;
                for (p, st) in self.write_ports.iter_mut().enumerate() {
                    let gp = wbase + p;
                    if let Some(&b) = st.pending_bursts.front() {
                        if arbiter.submit_write(WriteRequest { port: gp, addr: b.base, burst_len: b.lines }) {
                            st.pending_bursts.pop_front();
                            stats.bump(Counter::LpWriteBurstsSubmitted);
                        }
                    }
                    if let Some(w) = st.to_send.front() {
                        if wr_net.port_can_accept(gp) {
                            wr_net.port_push_word(gp, w);
                            st.to_send.advance();
                            stats.bump(Counter::LpWordsDrained);
                        } else {
                            stats.bump(Counter::LpDrainStallPortCycles);
                            self.write_wait_cycles[p] += 1;
                        }
                    }
                    all_done &= st.to_send.is_empty() && st.pending_bursts.is_empty();
                }
                if all_done {
                    self.phase = Phase::Done;
                }
            }
            Phase::Done => {}
        }
        self.phase
    }

    /// A forward-progress signature for the engine's per-tenant
    /// watchdog: changes whenever this processor does *anything* in a
    /// tick (every phase bumps its cycle counter unconditionally), so
    /// it freezes exactly when ticks are suppressed — a wedge — and
    /// keeps moving through ordinary backpressure stalls. Built only
    /// from backend-exact, payload-independent state, so the watchdog
    /// fires at the identical cycle under every backend.
    pub fn progress_sig(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV offset basis
        for v in [
            self.phase as u64,
            self.compute_cycles_left,
            self.load_cycles,
            self.compute_cycles,
            self.drain_cycles,
        ] {
            h = (h ^ v).wrapping_mul(0x0000_0100_0000_01b3);
        }
        h
    }

    /// True when the compute stall has elapsed and the coordinator
    /// should run the math + supply the output.
    pub fn compute_done(&self) -> bool {
        self.phase == Phase::Compute && self.compute_cycles_left == 0
    }

    /// Remaining modelled compute-stall cycles (0 outside `Compute`).
    /// This is the layer processor's `next_activity_edge()`: while in
    /// `Compute`, nothing observable happens for exactly this many
    /// fabric cycles.
    pub fn compute_cycles_left(&self) -> u64 {
        if self.phase == Phase::Compute {
            self.compute_cycles_left
        } else {
            0
        }
    }

    /// The idle-edge bulk skip: account `k` fabric cycles of compute
    /// stall at once. Exact: a `Compute` tick does nothing but
    /// `compute_cycles += 1; compute_cycles_left -= 1 (saturating)` —
    /// no stats, no network interaction — so `k` ticks compose in
    /// closed form. A skip that would cross the `compute_done` flip is
    /// refused (the caller's horizon must re-check at the flip); past
    /// the flip (`left == 0`, coordinator not yet reacted) any `k` is
    /// pure counter accumulation, exactly as stepwise.
    pub fn skip_compute_cycles(&mut self, k: u64) {
        assert_eq!(self.phase, Phase::Compute, "bulk compute skip outside Compute");
        assert!(
            self.compute_cycles_left == 0 || k <= self.compute_cycles_left,
            "compute skip overshoots the stall"
        );
        self.compute_cycles += k;
        self.compute_cycles_left = self.compute_cycles_left.saturating_sub(k);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::prefetch::{partition, Region};
    use crate::interconnect::arbiter::Policy;
    use crate::interconnect::medusa::{MedusaReadNetwork, MedusaWriteNetwork};
    use crate::types::TaggedLine;

    /// Load-only smoke test against a hand-driven Medusa read network:
    /// the LP submits bursts; we play DRAM, delivering requested lines.
    #[test]
    fn load_phase_gathers_all_words_in_order() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
        let n = g.words_per_line();
        let mut rd = MedusaReadNetwork::new(g);
        let mut wr = MedusaWriteNetwork::new(g);
        let mut arb = Arbiter::new(4, 4, Policy::RoundRobin);
        let mut lp = LayerProcessor::new(g, 4);
        let mut stats = Stats::new();

        let regions = [Region { base: 0, lines: 8 }];
        let scheds = partition(&regions, 4);
        lp.begin_layer(&scheds, 1000);
        assert_eq!(lp.phase(), Phase::Load);

        let mut cmd = crate::sim::Channel::new("cmd", 8);
        let mut wdata = crate::sim::Channel::new("wdata", 8);
        // Fake DRAM: serve read commands instantly, 1 line/cycle.
        let mut serve: VecDeque<TaggedLine> = VecDeque::new();
        for c in 0..2000u64 {
            rd.tick(c, &mut stats);
            wr.tick(c, &mut stats);
            arb.tick(&rd, &mut wr, &mut cmd, &mut wdata, &mut stats);
            cmd.commit();
            wdata.commit();
            if let Some(cmdv) = cmd.pop() {
                if let crate::interconnect::arbiter::MemCommand::Read { port, addr, burst_len } = cmdv {
                    for i in 0..burst_len as u64 {
                        let line = crate::types::Line::from_words(
                            (0..n as u64).map(|y| (addr + i) * 100 + y).collect(),
                        );
                        serve.push_back(TaggedLine { port, line });
                    }
                }
            }
            if let Some(tl) = serve.front() {
                if rd.mem_can_deliver(tl.port) {
                    let tl = serve.pop_front().unwrap();
                    let port = tl.port;
                    rd.mem_deliver(tl);
                    arb.on_read_line_delivered(port);
                }
            }
            if lp.tick(&mut rd, &mut wr, &mut arb, &mut stats) == Phase::Compute {
                break;
            }
        }
        assert_eq!(lp.phase(), Phase::Compute);
        // Each port got 2 lines; verify content and order.
        for p in 0..4 {
            let sched = &scheds[p];
            let mut expect = Vec::new();
            for r in &sched.runs {
                for a in r.base..r.end() {
                    for y in 0..n as u64 {
                        expect.push(a * 100 + y);
                    }
                }
            }
            assert_eq!(lp.loaded(p), &expect[..], "port {p}");
        }
        assert_eq!(stats.get("lp.words_loaded"), (8 * n) as u64);
    }

    #[test]
    fn compute_stall_scales_with_dpus() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
        let mut lp = LayerProcessor::new(g, 4);
        lp.begin_layer(&partition(&[], 4), 128 * 32);
        assert_eq!(lp.compute_stall_cycles(), 32);
        let mut lp = LayerProcessor::new(g, 64);
        lp.begin_layer(&partition(&[], 4), 128 * 32);
        assert_eq!(lp.compute_stall_cycles(), 2);
    }

    #[test]
    fn empty_layer_skips_to_compute() {
        let g = Geometry { w_line: 64, w_acc: 16, read_ports: 4, write_ports: 4, max_burst: 4 };
        let mut lp = LayerProcessor::new(g, 4);
        lp.begin_layer(&partition(&[], 4), 1);
        assert_eq!(lp.phase(), Phase::Compute);
    }
}
