//! Perfect-prefetch transfer planning.
//!
//! The paper's key workload observation (§I): "a layer processor knows
//! its access pattern and can perform perfect prefetch for future data
//! access". This module turns a conv layer + a DRAM tensor layout into
//! the deterministic, contiguous, bursty per-port transfer schedule the
//! layer processor executes — every read known in advance, bandwidth
//! statically and evenly partitioned across ports.

use crate::types::{Geometry, LineAddr};

/// A contiguous run of `W_line` lines in DRAM.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Region {
    pub base: LineAddr,
    pub lines: usize,
}

impl Region {
    pub fn end(&self) -> LineAddr {
        self.base + self.lines as u64
    }
}

/// Where a layer's tensors live in (line-granular) DRAM.
#[derive(Clone, Copy, Debug)]
pub struct TensorMap {
    pub ifmap: Region,
    pub weights: Region,
    pub ofmap: Region,
}

impl TensorMap {
    /// Lay out a layer's tensors back to back starting at `base`.
    /// `words_per_line` comes from the interconnect geometry; tensors are
    /// padded to line boundaries.
    pub fn layout(
        layer: &crate::accel::dnn::ConvLayer,
        words_per_line: usize,
        base: LineAddr,
    ) -> TensorMap {
        let lines = |words: usize| words.div_ceil(words_per_line);
        let ifmap = Region { base, lines: lines(layer.ifmap_words()) };
        let weights = Region { base: ifmap.end(), lines: lines(layer.weight_words()) };
        let ofmap = Region { base: weights.end(), lines: lines(layer.ofmap_words()) };
        TensorMap { ifmap, weights, ofmap }
    }

    pub fn total_lines(&self) -> usize {
        self.ifmap.lines + self.weights.lines + self.ofmap.lines
    }
}

/// A per-port schedule: the contiguous address runs this port will
/// stream, in order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct PortSchedule {
    pub runs: Vec<Region>,
}

impl PortSchedule {
    pub fn total_lines(&self) -> usize {
        self.runs.iter().map(|r| r.lines).sum()
    }
}

/// Evenly partition a set of regions across `ports`, keeping each port's
/// share contiguous (so its bursts are contiguous) and splitting at
/// region boundaries. Ports may receive zero lines when there are more
/// ports than lines.
pub fn partition(regions: &[Region], ports: usize) -> Vec<PortSchedule> {
    assert!(ports >= 1);
    let total: usize = regions.iter().map(|r| r.lines).sum();
    let mut out = vec![PortSchedule::default(); ports];
    if total == 0 {
        return out;
    }
    // Port p gets lines [p*total/ports, (p+1)*total/ports) of the
    // concatenated line sequence — even to within one line.
    let mut bounds: Vec<(usize, usize)> = (0..ports)
        .map(|p| (p * total / ports, (p + 1) * total / ports))
        .collect();
    bounds.retain(|(a, b)| b > a);
    let mut region_iter = regions.iter();
    let mut cur = *region_iter.next().unwrap();
    let mut consumed = 0usize; // lines of the concatenated sequence consumed
    for (p, (start, end)) in bounds.iter().enumerate() {
        let mut need = end - start;
        debug_assert_eq!(consumed, *start);
        while need > 0 {
            if cur.lines == 0 {
                cur = *region_iter.next().expect("ran out of regions");
                continue;
            }
            let take = need.min(cur.lines);
            out[p].runs.push(Region { base: cur.base, lines: take });
            cur.base += take as u64;
            cur.lines -= take;
            consumed += take;
            need -= take;
        }
    }
    out
}

/// Break a port schedule into bursts of at most `max_burst` lines — the
/// request stream the port hands to the arbiter.
pub fn bursts(schedule: &PortSchedule, max_burst: usize) -> Vec<Region> {
    let mut out = Vec::new();
    for run in &schedule.runs {
        let mut base = run.base;
        let mut left = run.lines;
        while left > 0 {
            let take = left.min(max_burst);
            out.push(Region { base, lines: take });
            base += take as u64;
            left -= take;
        }
    }
    out
}

/// Full read plan for a layer under a geometry: ifmap + weights streamed
/// through the read ports.
pub fn read_schedules(map: &TensorMap, geom: &Geometry) -> Vec<PortSchedule> {
    partition(&[map.ifmap, map.weights], geom.read_ports)
}

/// Write plan: ofmap streamed through the write ports.
pub fn write_schedules(map: &TensorMap, geom: &Geometry) -> Vec<PortSchedule> {
    partition(&[map.ofmap], geom.write_ports)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::dnn::ConvLayer;

    #[test]
    fn layout_is_contiguous_and_padded() {
        let l = ConvLayer { name: "t", in_c: 3, in_h: 8, in_w: 8, out_c: 4, k: 3, stride: 1, pad: 1, relu: true };
        let m = TensorMap::layout(&l, 32, 100);
        assert_eq!(m.ifmap.base, 100);
        assert_eq!(m.ifmap.lines, (3 * 64usize).div_ceil(32));
        assert_eq!(m.weights.base, m.ifmap.end());
        assert_eq!(m.ofmap.base, m.weights.end());
    }

    #[test]
    fn partition_covers_exactly_once() {
        let regions = [Region { base: 0, lines: 10 }, Region { base: 50, lines: 7 }];
        for ports in [1usize, 2, 3, 5, 17, 32] {
            let parts = partition(&regions, ports);
            assert_eq!(parts.len(), ports);
            let mut seen = Vec::new();
            for p in &parts {
                for r in &p.runs {
                    for a in r.base..r.end() {
                        seen.push(a);
                    }
                }
            }
            let expect: Vec<u64> = (0..10).chain(50..57).collect();
            assert_eq!(seen, expect, "ports={ports}");
        }
    }

    #[test]
    fn partition_is_even() {
        let regions = [Region { base: 0, lines: 64 }];
        let parts = partition(&regions, 8);
        for p in &parts {
            assert_eq!(p.total_lines(), 8);
        }
    }

    #[test]
    fn partition_more_ports_than_lines() {
        let regions = [Region { base: 0, lines: 3 }];
        let parts = partition(&regions, 8);
        let nonzero = parts.iter().filter(|p| p.total_lines() > 0).count();
        assert_eq!(nonzero, 3);
        let total: usize = parts.iter().map(|p| p.total_lines()).sum();
        assert_eq!(total, 3);
    }

    #[test]
    fn bursts_respect_max_and_cover() {
        let sched = PortSchedule { runs: vec![Region { base: 0, lines: 70 }, Region { base: 100, lines: 5 }] };
        let bs = bursts(&sched, 32);
        assert!(bs.iter().all(|b| b.lines <= 32));
        let total: usize = bs.iter().map(|b| b.lines).sum();
        assert_eq!(total, 75);
        assert_eq!(bs[0], Region { base: 0, lines: 32 });
        assert_eq!(bs[2], Region { base: 64, lines: 6 });
        assert_eq!(bs[3], Region { base: 100, lines: 5 });
    }

    #[test]
    fn schedules_match_geometry_ports() {
        let l = ConvLayer { name: "t", in_c: 16, in_h: 16, in_w: 16, out_c: 16, k: 3, stride: 1, pad: 1, relu: true };
        let g = crate::types::Geometry::paper_default();
        let m = TensorMap::layout(&l, g.words_per_line(), 0);
        let rs = read_schedules(&m, &g);
        let ws = write_schedules(&m, &g);
        assert_eq!(rs.len(), 32);
        assert_eq!(ws.len(), 32);
        let read_total: usize = rs.iter().map(|p| p.total_lines()).sum();
        assert_eq!(read_total, m.ifmap.lines + m.weights.lines);
        let write_total: usize = ws.iter().map(|p| p.total_lines()).sum();
        assert_eq!(write_total, m.ofmap.lines);
    }
}
