//! Golden (software) convolution in the exact Q8.8 semantics of the
//! hardware: wide accumulation, single round, saturate, optional ReLU.
//!
//! Used to verify (a) data integrity through the simulated interconnects
//! and (b) the PJRT-executed JAX/Pallas artifact, which implements the
//! same quantization (python/compile/kernels/ref.py).

use crate::accel::dnn::ConvLayer;
use crate::accel::quant::{relu, Fixed16};

/// Feature maps are stored channel-major: `fm[c][y][x]` flattened as
/// `c * H * W + y * W + x` — the layout the prefetch generators assume.
pub fn fmap_index(w: usize, h: usize, c: usize, y: usize, x: usize) -> usize {
    c * h * w + y * w + x
}

/// Weights stored as `weights[oc][ic][ky][kx]` flattened; biases appended
/// per output channel by the caller's buffer layout.
pub fn weight_index(l: &ConvLayer, oc: usize, ic: usize, ky: usize, kx: usize) -> usize {
    ((oc * l.in_c + ic) * l.k + ky) * l.k + kx
}

/// Compute one conv layer on quantized inputs. `ifmap` has
/// `in_c*in_h*in_w` words, `weights` has `out_c*in_c*k*k`, `bias` has
/// `out_c`. Returns the `out_c*out_h*out_w` output map.
pub fn conv2d_q88(
    l: &ConvLayer,
    ifmap: &[Fixed16],
    weights: &[Fixed16],
    bias: &[Fixed16],
) -> Vec<Fixed16> {
    assert_eq!(ifmap.len(), l.ifmap_words());
    assert_eq!(weights.len(), l.out_c * l.in_c * l.k * l.k);
    assert_eq!(bias.len(), l.out_c);
    let (oh, ow) = (l.out_h(), l.out_w());
    let mut out = vec![Fixed16::ZERO; l.out_c * oh * ow];
    for oc in 0..l.out_c {
        for oy in 0..oh {
            for ox in 0..ow {
                // Wide Q16.16 accumulation across the whole receptive
                // field (matches the DSP-cascade + final-round hardware
                // and the JAX kernel).
                let mut acc: i64 = (bias[oc].0 as i64) << super::quant::FRAC_BITS;
                for ic in 0..l.in_c {
                    for ky in 0..l.k {
                        for kx in 0..l.k {
                            let iy = (oy * l.stride + ky) as isize - l.pad as isize;
                            let ix = (ox * l.stride + kx) as isize - l.pad as isize;
                            if iy < 0 || ix < 0 || iy >= l.in_h as isize || ix >= l.in_w as isize {
                                continue; // zero padding
                            }
                            let iv = ifmap[fmap_index(l.in_w, l.in_h, ic, iy as usize, ix as usize)];
                            let wv = weights[weight_index(l, oc, ic, ky, kx)];
                            acc += iv.0 as i64 * wv.0 as i64;
                        }
                    }
                }
                let q = crate::accel::quant::Fixed16(
                    shift_round(acc).clamp(i16::MIN as i64, i16::MAX as i64) as i16,
                );
                let v = if l.relu { relu(q) } else { q };
                out[fmap_index(ow, oh, oc, oy, ox)] = v;
            }
        }
    }
    out
}

/// Grouped convolution in the same Q8.8 semantics: input and output
/// channels are split into `groups` equal slices and slice `g` of the
/// output only sees slice `g` of the input. `groups == 1` is exactly
/// [`conv2d_q88`]; `groups == in_c == out_c` is a depthwise convolution
/// (MobileNet-style stacks). Weights are laid out
/// `weights[oc][ic_local][ky][kx]` with `ic_local < in_c / groups`.
pub fn conv2d_grouped_q88(
    l: &ConvLayer,
    groups: usize,
    ifmap: &[Fixed16],
    weights: &[Fixed16],
    bias: &[Fixed16],
) -> Vec<Fixed16> {
    assert!(groups >= 1 && l.in_c % groups == 0 && l.out_c % groups == 0);
    let icg = l.in_c / groups;
    let ocg = l.out_c / groups;
    assert_eq!(ifmap.len(), l.ifmap_words());
    assert_eq!(weights.len(), l.out_c * icg * l.k * l.k);
    assert_eq!(bias.len(), l.out_c);
    let (oh, ow) = (l.out_h(), l.out_w());
    let mut out = vec![Fixed16::ZERO; l.out_c * oh * ow];
    for oc in 0..l.out_c {
        let g = oc / ocg;
        for oy in 0..oh {
            for ox in 0..ow {
                let mut acc: i64 = (bias[oc].0 as i64) << super::quant::FRAC_BITS;
                for ic_local in 0..icg {
                    let ic = g * icg + ic_local;
                    for ky in 0..l.k {
                        for kx in 0..l.k {
                            let iy = (oy * l.stride + ky) as isize - l.pad as isize;
                            let ix = (ox * l.stride + kx) as isize - l.pad as isize;
                            if iy < 0 || ix < 0 || iy >= l.in_h as isize || ix >= l.in_w as isize {
                                continue;
                            }
                            let iv = ifmap[fmap_index(l.in_w, l.in_h, ic, iy as usize, ix as usize)];
                            let wv = weights[((oc * icg + ic_local) * l.k + ky) * l.k + kx];
                            acc += iv.0 as i64 * wv.0 as i64;
                        }
                    }
                }
                let q = Fixed16(shift_round(acc).clamp(i16::MIN as i64, i16::MAX as i64) as i16);
                let v = if l.relu { relu(q) } else { q };
                out[fmap_index(ow, oh, oc, oy, ox)] = v;
            }
        }
    }
    out
}

/// Elementwise saturating Q8.8 add (residual joins), optional ReLU.
pub fn add_q88(a: &[Fixed16], b: &[Fixed16], apply_relu: bool) -> Vec<Fixed16> {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| {
            let s = x.add(*y);
            if apply_relu {
                relu(s)
            } else {
                s
            }
        })
        .collect()
}

fn shift_round(acc: i64) -> i64 {
    // Round-half-even shift by FRAC_BITS, same as quant::dot.
    let bits = super::quant::FRAC_BITS;
    let div = 1i64 << bits;
    let q = acc >> bits;
    let rem = acc - (q << bits);
    let half = div / 2;
    if rem > half || (rem == half && (q & 1) == 1) {
        q + 1
    } else {
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    fn identity_layer() -> ConvLayer {
        ConvLayer { name: "id", in_c: 1, in_h: 4, in_w: 4, out_c: 1, k: 1, stride: 1, pad: 0, relu: false }
    }

    #[test]
    fn identity_kernel_passes_through() {
        let l = identity_layer();
        let ifmap: Vec<Fixed16> = (0..16).map(|i| Fixed16::from_f32(i as f32 * 0.5)).collect();
        let weights = vec![Fixed16::from_f32(1.0)];
        let bias = vec![Fixed16::ZERO];
        let out = conv2d_q88(&l, &ifmap, &weights, &bias);
        assert_eq!(out, ifmap);
    }

    #[test]
    fn bias_adds() {
        let l = identity_layer();
        let ifmap = vec![Fixed16::ZERO; 16];
        let weights = vec![Fixed16::from_f32(1.0)];
        let bias = vec![Fixed16::from_f32(2.5)];
        let out = conv2d_q88(&l, &ifmap, &weights, &bias);
        assert!(out.iter().all(|v| v.to_f32() == 2.5));
    }

    #[test]
    fn averaging_kernel_3x3() {
        let l = ConvLayer { name: "avg", in_c: 1, in_h: 3, in_w: 3, out_c: 1, k: 3, stride: 1, pad: 0, relu: false };
        let ifmap: Vec<Fixed16> = (1..=9).map(|i| Fixed16::from_f32(i as f32)).collect();
        let weights = vec![Fixed16::from_f32(1.0 / 16.0); 9]; // Q8.8-exact
        let bias = vec![Fixed16::ZERO];
        let out = conv2d_q88(&l, &ifmap, &weights, &bias);
        // sum(1..9) = 45; 45/16 = 2.8125 exactly in Q8.8.
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].to_f32(), 2.8125);
    }

    #[test]
    fn padding_zeros_at_border() {
        let l = ConvLayer { name: "p", in_c: 1, in_h: 2, in_w: 2, out_c: 1, k: 3, stride: 1, pad: 1, relu: false };
        let ifmap = vec![Fixed16::from_f32(1.0); 4];
        let weights = vec![Fixed16::from_f32(1.0); 9];
        let bias = vec![Fixed16::ZERO];
        let out = conv2d_q88(&l, &ifmap, &weights, &bias);
        // Corner output sees 4 ones; all 4 outputs are corners on 2x2.
        assert!(out.iter().all(|v| v.to_f32() == 4.0));
    }

    #[test]
    fn relu_applied_when_requested() {
        let mut l = identity_layer();
        l.relu = true;
        let ifmap = vec![Fixed16::from_f32(-1.0); 16];
        let weights = vec![Fixed16::from_f32(1.0)];
        let bias = vec![Fixed16::ZERO];
        let out = conv2d_q88(&l, &ifmap, &weights, &bias);
        assert!(out.iter().all(|v| *v == Fixed16::ZERO));
    }

    #[test]
    fn grouped_conv_with_one_group_matches_dense() {
        let l = ConvLayer { name: "g1", in_c: 4, in_h: 5, in_w: 5, out_c: 6, k: 3, stride: 1, pad: 1, relu: true };
        let mut p = Prng::new(17);
        let ifmap: Vec<Fixed16> =
            (0..l.ifmap_words()).map(|_| Fixed16((p.next_u64() & 0x3ff) as i16 - 512)).collect();
        let weights: Vec<Fixed16> =
            (0..l.out_c * l.in_c * 9).map(|_| Fixed16((p.next_u64() & 0xff) as i16 - 128)).collect();
        let bias: Vec<Fixed16> = (0..l.out_c).map(|_| Fixed16((p.next_u64() & 0x7f) as i16)).collect();
        assert_eq!(conv2d_grouped_q88(&l, 1, &ifmap, &weights, &bias), conv2d_q88(&l, &ifmap, &weights, &bias));
    }

    #[test]
    fn depthwise_conv_keeps_channels_independent() {
        // 2-channel depthwise with an identity 1x1 kernel on channel 0
        // and a zero kernel on channel 1: output ch0 = input ch0, ch1 = 0.
        let l = ConvLayer { name: "dw", in_c: 2, in_h: 3, in_w: 3, out_c: 2, k: 1, stride: 1, pad: 0, relu: false };
        let ifmap: Vec<Fixed16> = (0..18).map(|i| Fixed16::from_f32(i as f32 * 0.25)).collect();
        let weights = vec![Fixed16::from_f32(1.0), Fixed16::ZERO];
        let bias = vec![Fixed16::ZERO; 2];
        let out = conv2d_grouped_q88(&l, 2, &ifmap, &weights, &bias);
        assert_eq!(&out[..9], &ifmap[..9]);
        assert!(out[9..].iter().all(|v| *v == Fixed16::ZERO));
    }

    #[test]
    fn add_saturates_and_relus() {
        let a = vec![Fixed16(30000), Fixed16(-200), Fixed16(100)];
        let b = vec![Fixed16(30000), Fixed16(100), Fixed16(28)];
        let plain = add_q88(&a, &b, false);
        assert_eq!(plain, vec![Fixed16(i16::MAX), Fixed16(-100), Fixed16(128)]);
        let relu = add_q88(&a, &b, true);
        assert_eq!(relu, vec![Fixed16(i16::MAX), Fixed16::ZERO, Fixed16(128)]);
    }

    #[test]
    fn deterministic_on_random_input() {
        let l = ConvLayer { name: "r", in_c: 2, in_h: 5, in_w: 5, out_c: 3, k: 3, stride: 1, pad: 1, relu: true };
        let mut p = Prng::new(5);
        let ifmap: Vec<Fixed16> =
            (0..l.ifmap_words()).map(|_| Fixed16((p.next_u64() & 0x3ff) as i16 - 512)).collect();
        let weights: Vec<Fixed16> =
            (0..l.out_c * l.in_c * 9).map(|_| Fixed16((p.next_u64() & 0xff) as i16 - 128)).collect();
        let bias: Vec<Fixed16> = (0..l.out_c).map(|_| Fixed16((p.next_u64() & 0xff) as i16)).collect();
        let a = conv2d_q88(&l, &ifmap, &weights, &bias);
        let b = conv2d_q88(&l, &ifmap, &weights, &bias);
        assert_eq!(a, b);
        assert_eq!(a.len(), l.ofmap_words());
    }
}
