//! 16-bit fixed-point arithmetic (Q8.8), the numeric format of the
//! paper's layer processors ("vectors of 16-bit fixed point values",
//! §IV-A).
//!
//! The same format is implemented on the Python side
//! (`python/compile/kernels/ref.py` quantization helpers), so the PJRT
//! artifact, the Rust golden model, and the simulated datapath agree
//! bit-for-bit after quantization.

use crate::types::Word;

/// Fractional bits in the Q8.8 format.
pub const FRAC_BITS: u32 = 8;
pub const SCALE: f32 = (1 << FRAC_BITS) as f32;

/// A Q8.8 fixed-point value carried in an i16.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Default)]
pub struct Fixed16(pub i16);

impl Fixed16 {
    pub const ZERO: Fixed16 = Fixed16(0);
    pub const MAX: Fixed16 = Fixed16(i16::MAX);
    pub const MIN: Fixed16 = Fixed16(i16::MIN);

    /// Quantize an f32 (round-to-nearest-even, saturating).
    pub fn from_f32(v: f32) -> Self {
        let scaled = v * SCALE;
        let r = round_half_even(scaled);
        Fixed16(r.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    pub fn to_f32(self) -> f32 {
        self.0 as f32 / SCALE
    }

    /// Saturating fixed-point multiply: (a*b) >> FRAC_BITS, round to
    /// nearest even, saturate.
    pub fn mul(self, o: Fixed16) -> Fixed16 {
        let prod = self.0 as i64 * o.0 as i64; // Q16.16
        let shifted = shift_round_half_even(prod, FRAC_BITS);
        Fixed16(shifted.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
    }

    /// Saturating add.
    pub fn add(self, o: Fixed16) -> Fixed16 {
        Fixed16(self.0.saturating_add(o.0))
    }

    /// Pack into a 16-bit interconnect word.
    pub fn to_word(self) -> Word {
        self.0 as u16 as Word
    }

    pub fn from_word(w: Word) -> Self {
        Fixed16((w & 0xffff) as u16 as i16)
    }
}

/// Dot product in widened Q16.16 accumulation (what a DSP cascade does:
/// full-precision accumulate, single rounding at the end).
pub fn dot(a: &[Fixed16], b: &[Fixed16]) -> Fixed16 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc: i64 = 0; // Q16.16
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x.0 as i64 * y.0 as i64;
    }
    let shifted = shift_round_half_even(acc, FRAC_BITS);
    Fixed16(shifted.clamp(i16::MIN as i64, i16::MAX as i64) as i16)
}

/// ReLU in fixed point.
pub fn relu(v: Fixed16) -> Fixed16 {
    if v.0 < 0 {
        Fixed16::ZERO
    } else {
        v
    }
}

fn round_half_even(v: f32) -> i64 {
    // f32 -> nearest integer, ties to even (matches jnp.round).
    let r = v.round_ties_even();
    r as i64
}

fn shift_round_half_even(v: i64, bits: u32) -> i64 {
    // Arithmetic shift floors, so `rem` is always in [0, 2^bits):
    // round up iff the remainder exceeds half, or ties with an odd
    // quotient (ties-to-even).
    let q = v >> bits;
    let rem = v - (q << bits);
    let half = 1i64 << (bits - 1);
    if rem > half || (rem == half && (q & 1) == 1) {
        q + 1
    } else {
        q
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_exact_values() {
        for v in [-3.5f32, -1.0, -0.00390625, 0.0, 0.5, 1.0, 2.25, 100.0] {
            let q = Fixed16::from_f32(v);
            assert_eq!(q.to_f32(), v, "Q8.8-exact value {v}");
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(Fixed16::from_f32(1e6), Fixed16::MAX);
        assert_eq!(Fixed16::from_f32(-1e6), Fixed16::MIN);
        assert_eq!(Fixed16::MAX.add(Fixed16::MAX), Fixed16::MAX);
        assert_eq!(Fixed16::MIN.add(Fixed16::MIN), Fixed16::MIN);
    }

    #[test]
    fn multiply_basics() {
        let a = Fixed16::from_f32(1.5);
        let b = Fixed16::from_f32(2.0);
        assert_eq!(a.mul(b).to_f32(), 3.0);
        let c = Fixed16::from_f32(-0.5);
        assert_eq!(a.mul(c).to_f32(), -0.75);
    }

    #[test]
    fn dot_matches_scalar_loop_with_wide_accumulation() {
        // Magnitudes chosen so the exact sum (~10.6) stays in Q8.8 range.
        let a: Vec<Fixed16> = (0..32).map(|i| Fixed16::from_f32(0.0625 * i as f32)).collect();
        let b: Vec<Fixed16> =
            (0..32).map(|i| Fixed16::from_f32(0.03125 * (32 - i) as f32)).collect();
        let d = dot(&a, &b);
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x.to_f32() * y.to_f32()).sum();
        assert!((d.to_f32() - expect).abs() <= 1.0 / SCALE, "{} vs {expect}", d.to_f32());
    }

    #[test]
    fn dot_saturates_on_overflow() {
        let a = vec![Fixed16::from_f32(100.0); 32];
        let b = vec![Fixed16::from_f32(100.0); 32];
        assert_eq!(dot(&a, &b), Fixed16::MAX);
    }

    #[test]
    fn word_roundtrip_negative() {
        let v = Fixed16::from_f32(-1.25);
        let w = v.to_word();
        assert!(w <= 0xffff);
        assert_eq!(Fixed16::from_word(w), v);
    }

    #[test]
    fn relu_clamps_negative() {
        assert_eq!(relu(Fixed16::from_f32(-2.0)), Fixed16::ZERO);
        assert_eq!(relu(Fixed16::from_f32(2.0)).to_f32(), 2.0);
    }

    #[test]
    fn rounding_ties_to_even() {
        // 0.5/256 in Q8.8 is exactly representable; check the mul path
        // rounding: 0.5 * (1/256) = 0.001953125 -> Q8.8 0.5 ties -> even.
        let half_lsb = Fixed16(1).mul(Fixed16::from_f32(0.5));
        assert_eq!(half_lsb, Fixed16(0), "0.5 LSB must round to even (0)");
        let one_and_half_lsb = Fixed16(3).mul(Fixed16::from_f32(0.5));
        assert_eq!(one_and_half_lsb, Fixed16(2), "1.5 LSB must round to even (2)");
    }
}
