//! Hand-rolled CLI argument parsing (no `clap` in the offline registry).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and per-subcommand help generation. Used by the `medusa` binary and
//! the example drivers.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Parsed arguments: options + positionals.
#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    /// Declared option names (for typo detection).
    known: Vec<(&'static str, &'static str, bool)>, // (name, help, takes_value)
}

impl Args {
    /// Declare an option that takes a value.
    pub fn opt(mut self, name: &'static str, help: &'static str) -> Self {
        self.known.push((name, help, true));
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.known.push((name, help, false));
        self
    }

    /// Parse a raw argument list (excluding argv[0] / subcommand).
    pub fn parse(mut self, raw: &[String]) -> Result<Self> {
        let mut it = raw.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                let (name, inline_val) = match rest.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let decl = self
                    .known
                    .iter()
                    .find(|(n, _, _)| *n == name)
                    .ok_or_else(|| anyhow!("unknown option --{name}\n{}", self.usage()))?;
                if decl.2 {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| anyhow!("option --{name} needs a value"))?
                            .clone(),
                    };
                    self.opts.insert(name, val);
                } else {
                    if inline_val.is_some() {
                        bail!("flag --{name} does not take a value");
                    }
                    self.flags.push(name);
                }
            } else {
                self.positional.push(a.clone());
            }
        }
        Ok(self)
    }

    pub fn usage(&self) -> String {
        let mut s = String::from("options:\n");
        for (name, help, takes_value) in &self.known {
            if *takes_value {
                s.push_str(&format!("  --{name} <value>   {help}\n"));
            } else {
                s.push_str(&format!("  --{name}           {help}\n"));
            }
        }
        s
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>> {
        self.get(name)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow!("--{name}: expected integer, got {v:?}")))
            .transpose()
    }

    pub fn get_f64(&self, name: &str) -> Result<Option<f64>> {
        self.get(name)
            .map(|v| v.parse::<f64>().map_err(|_| anyhow!("--{name}: expected number, got {v:?}")))
            .transpose()
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_opts_flags_positionals() {
        let a = Args::default()
            .opt("ports", "port count")
            .opt("design", "which design")
            .flag("verbose", "chatty")
            .parse(&argv(&["--ports", "32", "--design=medusa", "--verbose", "run.toml"]))
            .unwrap();
        assert_eq!(a.get("ports"), Some("32"));
        assert_eq!(a.get("design"), Some("medusa"));
        assert!(a.has_flag("verbose"));
        assert_eq!(a.positional(), &["run.toml".to_string()]);
        assert_eq!(a.get_usize("ports").unwrap(), Some(32));
    }

    #[test]
    fn unknown_option_rejected_with_usage() {
        let err = Args::default()
            .opt("ports", "port count")
            .parse(&argv(&["--prots", "32"]))
            .unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown option --prots"));
        assert!(msg.contains("--ports"));
    }

    #[test]
    fn missing_value_rejected() {
        let err = Args::default().opt("ports", "p").parse(&argv(&["--ports"])).unwrap_err();
        assert!(format!("{err}").contains("needs a value"));
    }

    #[test]
    fn bad_number_rejected() {
        let a = Args::default().opt("ports", "p").parse(&argv(&["--ports", "abc"])).unwrap();
        assert!(a.get_usize("ports").is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        let err = Args::default().flag("v", "verbose").parse(&argv(&["--v=1"])).unwrap_err();
        assert!(format!("{err}").contains("does not take a value"));
    }
}
