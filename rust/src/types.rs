//! Core data types shared across the simulator: words, memory lines, and
//! the interconnect geometry that parameterizes every design point.

use std::fmt;

/// One accelerator-port word. The paper's accelerators use 8- or 16-bit
/// fixed-point words; we carry them in a `u64` so a single simulator
/// handles any `W_acc` up to 64 bits. Values are always masked to
/// `W_acc` bits at the boundaries.
pub type Word = u64;

/// Index of a narrow accelerator port (read and write ports are numbered
/// independently, each from 0).
pub type PortId = usize;

/// Address of a memory line (in units of `W_line`-bit lines).
pub type LineAddr = u64;

/// Lines of up to this many words are stored inline (no heap). The
/// paper's widest common interface (512-bit / 16-bit words) is exactly
/// 32 words, so every hop of a paper-default `TaggedLine` through a
/// `Channel` or a controller store is a flat memcpy.
pub const LINE_INLINE_WORDS: usize = 32;

#[derive(Clone)]
enum LineRepr {
    /// Words `[0, len)` are live; the tail stays zeroed.
    Inline { len: u8, words: [Word; LINE_INLINE_WORDS] },
    /// Fallback for wide interfaces (1024-bit regions of Fig 6).
    Heap(Box<[Word]>),
    /// Payload-elided shadow: the line knows how many words it carries
    /// but stores none of them (fast-backend mode,
    /// [`crate::config::PayloadMode::Elided`]). Occupancy/credit logic
    /// sees an ordinary `len`-word line; payload accessors panic so a
    /// datapath that forgot to gate on the mode fails loudly instead of
    /// silently reading garbage. `word()` reads 0 (the canonical shadow
    /// word) because width converters legitimately stream shadow words.
    Elided { len: u16 },
}

/// One `W_line`-bit memory line, as the `N = W_line / W_acc` accelerator
/// words it carries. Word `y` of the line occupies bits
/// `[y*W_acc, (y+1)*W_acc)` of the DRAM controller interface.
///
/// Small lines (≤ [`LINE_INLINE_WORDS`] words) are stored inline, so
/// cloning them — the hot operation as lines hop between the DRAM
/// controller, CDC channels, and the networks — never touches the
/// allocator.
#[derive(Clone)]
pub struct Line {
    repr: LineRepr,
}

impl Line {
    /// A line of `n` zero words.
    #[inline]
    pub fn zeroed(n: usize) -> Self {
        if n <= LINE_INLINE_WORDS {
            Line { repr: LineRepr::Inline { len: n as u8, words: [0; LINE_INLINE_WORDS] } }
        } else {
            Line { repr: LineRepr::Heap(vec![0; n].into_boxed_slice()) }
        }
    }

    /// Build a line of `n` words from a per-index function, without an
    /// intermediate `Vec` (allocation-free for inline-sized lines).
    #[inline]
    pub fn from_fn(n: usize, mut f: impl FnMut(usize) -> Word) -> Self {
        let mut line = Line::zeroed(n);
        let words = line.words_mut();
        for (i, w) in words.iter_mut().enumerate() {
            *w = f(i);
        }
        line
    }

    /// Build a line from its words (word 0 = least-significant lane).
    /// Heap-sized lines move the existing allocation; inline-sized lines
    /// copy into the inline array and drop the `Vec`.
    pub fn from_words(words: Vec<Word>) -> Self {
        if words.len() > LINE_INLINE_WORDS {
            Line { repr: LineRepr::Heap(words.into_boxed_slice()) }
        } else {
            Line::from_slice(&words)
        }
    }

    /// Build a line by copying a word slice.
    #[inline]
    pub fn from_slice(words: &[Word]) -> Self {
        let mut line = Line::zeroed(words.len());
        line.words_mut().copy_from_slice(words);
        line
    }

    /// A payload-elided shadow of an `n`-word line (fast backend). It
    /// reports `num_words() == n` so every occupancy assertion and
    /// credit computation behaves exactly as in full mode, but carries
    /// no payload: cloning/moving it never copies word data.
    #[inline]
    pub fn elided(n: usize) -> Self {
        debug_assert!(n <= u16::MAX as usize, "elided line too wide");
        Line { repr: LineRepr::Elided { len: n as u16 } }
    }

    /// Is this a payload-elided shadow?
    #[inline]
    pub fn is_elided(&self) -> bool {
        matches!(self.repr, LineRepr::Elided { .. })
    }

    /// Number of `W_acc` words in the line (= interconnect port count N).
    #[inline]
    pub fn num_words(&self) -> usize {
        match &self.repr {
            LineRepr::Inline { len, .. } => *len as usize,
            LineRepr::Heap(w) => w.len(),
            LineRepr::Elided { len } => *len as usize,
        }
    }

    /// One word of the line. For elided shadows this is the canonical
    /// shadow word 0 (width converters stream it in place of payload);
    /// the index is still bounds-checked so occupancy bugs don't hide.
    #[inline]
    pub fn word(&self, idx: usize) -> Word {
        match &self.repr {
            LineRepr::Elided { len } => {
                assert!(idx < *len as usize, "word index {idx} out of elided line");
                0
            }
            _ => self.words()[idx],
        }
    }

    #[inline]
    pub fn set_word(&mut self, idx: usize, w: Word) {
        self.words_mut()[idx] = w;
    }

    /// The payload slice. Panics on elided shadows — any caller that
    /// reads payload must be gated off in elided mode, and a loud panic
    /// here is what keeps the fast backend honest.
    #[inline]
    pub fn words(&self) -> &[Word] {
        match &self.repr {
            LineRepr::Inline { len, words } => &words[..*len as usize],
            LineRepr::Heap(w) => w,
            LineRepr::Elided { .. } => {
                panic!("payload access on an elided line (fast-backend gating bug)")
            }
        }
    }

    #[inline]
    pub fn words_mut(&mut self) -> &mut [Word] {
        match &mut self.repr {
            LineRepr::Inline { len, words } => &mut words[..*len as usize],
            LineRepr::Heap(w) => w,
            LineRepr::Elided { .. } => {
                panic!("payload write on an elided line (fast-backend gating bug)")
            }
        }
    }

    /// Deterministic content hash (FNV-1a), used by integrity checks.
    /// Elided shadows hash their length under a distinct tag (content
    /// checks are meaningless in elided mode, but the hash must still
    /// be deterministic and length-sensitive).
    pub fn fnv1a(&self) -> u64 {
        let mut h = 0xcbf2_9ce4_8422_2325u64;
        if let LineRepr::Elided { len } = self.repr {
            h ^= 0xe11d_ed00 ^ len as u64;
            return h.wrapping_mul(0x1000_0000_01b3);
        }
        for w in self.words() {
            for b in w.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        }
        h
    }
}

impl PartialEq for Line {
    fn eq(&self, other: &Self) -> bool {
        match (&self.repr, &other.repr) {
            // Two shadows are equal iff they shadow the same width; a
            // shadow never equals a payload-carrying line (there is no
            // content to compare).
            (LineRepr::Elided { len: a }, LineRepr::Elided { len: b }) => a == b,
            (LineRepr::Elided { .. }, _) | (_, LineRepr::Elided { .. }) => false,
            _ => self.words() == other.words(),
        }
    }
}

impl Eq for Line {}

impl fmt::Debug for Line {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if let LineRepr::Elided { len } = self.repr {
            return write!(f, "Line[elided x{len}]");
        }
        write!(f, "Line[")?;
        for (i, w) in self.words().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{w:04x}")?;
        }
        write!(f, "]")
    }
}

/// Geometry of one interconnect design point: the widths and port counts
/// that parameterize both the baseline and the Medusa data-transfer
/// networks (paper §II-B notation).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Geometry {
    /// DRAM controller interface width in bits (`W_line`), e.g. 512.
    pub w_line: usize,
    /// Narrow accelerator-port width in bits (`W_acc`), e.g. 16.
    pub w_acc: usize,
    /// Number of read ports.
    pub read_ports: usize,
    /// Number of write ports.
    pub write_ports: usize,
    /// Maximum burst length a single port request may generate, in
    /// `W_line`-bit lines (the paper evaluates 32).
    pub max_burst: usize,
}

impl Geometry {
    /// The paper's representative design point (§IV-C): 512-bit DDR3
    /// controller interface multiplexed to 32 16-bit read ports and 32
    /// 16-bit write ports, 32-line maximum bursts.
    pub fn paper_default() -> Self {
        Geometry { w_line: 512, w_acc: 16, read_ports: 32, write_ports: 32, max_burst: 32 }
    }

    /// Words per memory line (`N` in the paper when ports fully subscribe
    /// the interface).
    pub fn words_per_line(&self) -> usize {
        debug_assert_eq!(self.w_line % self.w_acc, 0, "W_line must be a multiple of W_acc");
        self.w_line / self.w_acc
    }

    /// Mask selecting the low `w_acc` bits of a word.
    pub fn word_mask(&self) -> Word {
        if self.w_acc >= 64 {
            Word::MAX
        } else {
            (1u64 << self.w_acc) - 1
        }
    }

    /// The transposition latency overhead the paper derives in §III-E:
    /// `W_line / W_acc` fabric cycles.
    pub fn transpose_latency(&self) -> usize {
        self.words_per_line()
    }

    /// Validate structural constraints shared by all designs.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.w_line > 0 && self.w_acc > 0, "widths must be positive");
        anyhow::ensure!(self.w_line % self.w_acc == 0, "W_line must be a multiple of W_acc");
        anyhow::ensure!(self.w_line.is_power_of_two(), "W_line must be a power of two");
        anyhow::ensure!(self.w_acc <= 64, "W_acc wider than 64 bits is unsupported");
        anyhow::ensure!(self.read_ports >= 1, "need at least one read port");
        anyhow::ensure!(self.write_ports >= 1, "need at least one write port");
        anyhow::ensure!(
            self.read_ports <= self.words_per_line(),
            "more read ports than words per line ({} > {})",
            self.read_ports,
            self.words_per_line()
        );
        anyhow::ensure!(
            self.write_ports <= self.words_per_line(),
            "more write ports than words per line ({} > {})",
            self.write_ports,
            self.words_per_line()
        );
        anyhow::ensure!(self.max_burst >= 1, "max burst must be at least 1 line");
        Ok(())
    }
}

/// A burst read request issued on behalf of one narrow port: deliver
/// `burst_len` consecutive lines starting at `addr`, all destined to
/// `port` (paper §III-C1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ReadRequest {
    pub port: PortId,
    pub addr: LineAddr,
    pub burst_len: usize,
}

/// A burst write request issued on behalf of one narrow port: write
/// `burst_len` lines accumulated from `port` to memory starting at
/// `addr`. The arbiter only issues it once the port has accumulated the
/// full burst in the interconnect (paper §III-C2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WriteRequest {
    pub port: PortId,
    pub addr: LineAddr,
    pub burst_len: usize,
}

/// A line travelling from the DRAM controller toward the read network,
/// tagged with its destination port (the arbiter established the tag when
/// it issued the request).
#[derive(Clone, Debug)]
pub struct TaggedLine {
    pub port: PortId,
    pub line: Line,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geometry_paper_default_is_valid() {
        let g = Geometry::paper_default();
        g.validate().unwrap();
        assert_eq!(g.words_per_line(), 32);
        assert_eq!(g.transpose_latency(), 32);
        assert_eq!(g.word_mask(), 0xffff);
    }

    #[test]
    fn geometry_rejects_bad_widths() {
        let mut g = Geometry::paper_default();
        g.w_acc = 24; // not a divisor of 512
        assert!(g.validate().is_err());
        let mut g = Geometry::paper_default();
        g.read_ports = 64; // more ports than words per line
        assert!(g.validate().is_err());
    }

    #[test]
    fn geometry_allows_non_power_of_two_ports() {
        // Paper §III-G: irregular (non-power-of-two) port counts are legal.
        let g = Geometry { w_line: 512, w_acc: 16, read_ports: 20, write_ports: 20, max_burst: 32 };
        g.validate().unwrap();
    }

    #[test]
    fn line_inline_and_heap_reprs_agree() {
        // Inline (<= 32 words) and heap (> 32 words) lines expose the
        // same behaviour; equality is content-based across sizes.
        for n in [1usize, 31, 32, 33, 64] {
            let mut a = Line::zeroed(n);
            assert_eq!(a.num_words(), n);
            for i in 0..n {
                a.set_word(i, (i as Word) * 3 + 1);
            }
            let b = Line::from_fn(n, |i| (i as Word) * 3 + 1);
            let c = Line::from_words((0..n).map(|i| (i as Word) * 3 + 1).collect());
            assert_eq!(a, b, "n={n}");
            assert_eq!(b, c, "n={n}");
            assert_eq!(a.fnv1a(), c.fnv1a(), "n={n}");
            assert_eq!(a.words().len(), n);
        }
    }

    #[test]
    fn elided_lines_are_header_only_shadows() {
        let e = Line::elided(32);
        assert!(e.is_elided());
        assert_eq!(e.num_words(), 32);
        // The shadow word is 0, bounds-checked.
        assert_eq!(e.word(0), 0);
        assert_eq!(e.word(31), 0);
        // Clone preserves the shadow.
        let c = e.clone();
        assert!(c.is_elided());
        assert_eq!(e, c);
        // Same width ⇒ equal; different width or full line ⇒ not.
        assert_ne!(Line::elided(32), Line::elided(16));
        assert_ne!(Line::elided(4), Line::zeroed(4));
        // Hash is deterministic and width-sensitive.
        assert_eq!(Line::elided(8).fnv1a(), Line::elided(8).fnv1a());
        assert_ne!(Line::elided(8).fnv1a(), Line::elided(9).fnv1a());
        assert!(format!("{e:?}").contains("elided"));
    }

    #[test]
    #[should_panic(expected = "payload access on an elided line")]
    fn elided_payload_read_panics() {
        let _ = Line::elided(4).words();
    }

    #[test]
    #[should_panic(expected = "out of elided line")]
    fn elided_word_is_bounds_checked() {
        let _ = Line::elided(4).word(4);
    }

    #[test]
    fn line_roundtrip_and_hash() {
        let mut l = Line::zeroed(4);
        l.set_word(2, 0xbeef);
        assert_eq!(l.word(2), 0xbeef);
        assert_eq!(l.num_words(), 4);
        let l2 = Line::from_words(vec![0, 0, 0xbeef, 0]);
        assert_eq!(l, l2);
        assert_eq!(l.fnv1a(), l2.fnv1a());
        let l3 = Line::from_words(vec![0, 0, 0xbeee, 0]);
        assert_ne!(l.fnv1a(), l3.fnv1a());
    }
}
