//! Simulated place-and-route frequency search.
//!
//! The paper finds each design's peak frequency by re-running Vivado P&R
//! at 25 MHz steps (§IV-A). We reproduce the search discipline: walk the
//! grid from the ceiling down, "attempting" each target against the
//! timing model (which adds a small deterministic per-run jitter, as
//! real P&R exhibits run-to-run variance around the achievable point),
//! and report the highest target that closes.

use crate::fpga::timing::TimingModel;
use crate::fpga::{DesignPoint, Device};

/// Outcome of one frequency search.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ParResult {
    /// Highest 25 MHz grid point that met timing; 0 = failed at 25 MHz.
    pub peak_mhz: u32,
    /// Number of P&R attempts the search performed.
    pub attempts: u32,
    /// Worst negative slack (ns) observed at the first failing target
    /// above the peak (0 if the ceiling closed).
    pub wns_at_fail_ns: f64,
}

/// Deterministic per-(design-point, target) jitter in [-2%, +2%] of the
/// critical path — models P&R seed noise without breaking
/// reproducibility.
fn par_jitter(p: &DesignPoint, target_mhz: u32) -> f64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in [
        p.dpus as u64,
        p.geometry.w_line as u64,
        p.geometry.read_ports as u64,
        p.design.par_seed(),
        target_mhz as u64,
    ] {
        h ^= v;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    ((h >> 11) as f64 / (1u64 << 53) as f64 - 0.5) * 0.04
}

/// Run the 25 MHz-step search for one design point.
pub fn search_peak_frequency(model: &TimingModel, p: &DesignPoint, dev: &Device) -> ParResult {
    // The analytic achievable frequency (un-snapped).
    let analytic = model.peak_frequency_mhz(p, dev);
    let mut attempts = 0;
    let mut wns = 0.0;
    // Walk down from the model ceiling.
    let mut target = (model.f_max_mhz as u32 / 25) * 25;
    while target >= 25 {
        attempts += 1;
        // A target closes if the (jittered) critical path fits its period.
        let base_t_ns = 1000.0 / (analytic.max(1) as f64 + 12.5); // center of the snap bin
        let t_ns = base_t_ns * (1.0 + par_jitter(p, target));
        let period_ns = 1000.0 / target as f64;
        if t_ns <= period_ns {
            return ParResult { peak_mhz: target, attempts, wns_at_fail_ns: wns };
        }
        wns = t_ns - period_ns;
        target -= 25;
    }
    ParResult { peak_mhz: 0, attempts, wns_at_fail_ns: wns }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::interconnect::Design;

    #[test]
    fn search_agrees_with_model_within_one_step() {
        let model = TimingModel::calibrated();
        let dev = Device::virtex7_690t();
        for design in [Design::Baseline, Design::Medusa] {
            for p in DesignPoint::fig6_sweep(design) {
                let direct = model.peak_frequency_mhz(&p, &dev);
                let searched = search_peak_frequency(&model, &p, &dev).peak_mhz;
                let diff = (direct as i64 - searched as i64).abs();
                assert!(
                    diff <= 25,
                    "{design:?} {} DSPs: direct {direct} vs searched {searched}",
                    p.dsps()
                );
            }
        }
    }

    #[test]
    fn search_is_deterministic() {
        let model = TimingModel::calibrated();
        let dev = Device::virtex7_690t();
        let p = DesignPoint::fig6_step(Design::Medusa, 5);
        let a = search_peak_frequency(&model, &p, &dev);
        let b = search_peak_frequency(&model, &p, &dev);
        assert_eq!(a, b);
    }

    #[test]
    fn failing_designs_report_zero_with_slack() {
        let model = TimingModel::calibrated();
        let dev = Device::virtex7_690t();
        // Largest 1024-bit baseline point: expected to fail at 25 MHz.
        let p = DesignPoint::fig6_step(Design::Baseline, 10);
        let r = search_peak_frequency(&model, &p, &dev);
        if r.peak_mhz == 0 {
            assert!(r.wns_at_fail_ns > 0.0);
        }
        assert!(r.attempts >= 1);
    }
}
