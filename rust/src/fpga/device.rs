//! FPGA device capacity models.

/// Capacities of one FPGA device, used to normalize resource reports
/// (the percentages in Tables I/II) and to drive the congestion model.
#[derive(Clone, Copy, Debug)]
pub struct Device {
    pub name: &'static str,
    pub luts: u64,
    pub ffs: u64,
    /// 18Kbit BRAM blocks.
    pub bram18: u64,
    pub dsps: u64,
    /// Abstract routing supply (bit x span units) for the congestion
    /// model — calibrated, see `fpga::timing`.
    pub routing_supply: f64,
}

impl Device {
    /// Xilinx Virtex-7 690T — the paper's target device (§IV-A).
    /// Capacities cross-checked against the paper's own percentages:
    /// 198,887 LUT = 45.9% -> 433,200; 240,449 FF = 27.8% -> 866,400;
    /// 726 BRAM-18K = 24.7% -> 2,940; 2,048 DSP = 56.9% -> 3,600.
    pub fn virtex7_690t() -> Self {
        Device {
            name: "xc7vx690t",
            luts: 433_200,
            ffs: 866_400,
            bram18: 2_940,
            dsps: 3_600,
            routing_supply: 60_000.0,
        }
    }

    pub fn pct_lut(&self, n: u64) -> f64 {
        100.0 * n as f64 / self.luts as f64
    }

    pub fn pct_ff(&self, n: u64) -> f64 {
        100.0 * n as f64 / self.ffs as f64
    }

    pub fn pct_bram(&self, n: u64) -> f64 {
        100.0 * n as f64 / self.bram18 as f64
    }

    pub fn pct_dsp(&self, n: u64) -> f64 {
        100.0 * n as f64 / self.dsps as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_percentages_reproduce() {
        let d = Device::virtex7_690t();
        // Table II's own normalization.
        assert!((d.pct_lut(198_887) - 45.9).abs() < 0.05);
        assert!((d.pct_ff(240_449) - 27.8).abs() < 0.05);
        assert!((d.pct_bram(726) - 24.7).abs() < 0.05);
        assert!((d.pct_dsp(2_048) - 56.9).abs() < 0.05);
        // Table I's normalization.
        assert!((d.pct_lut(5_313) - 1.2).abs() < 0.05);
        assert!((d.pct_ff(27_173) - 3.1).abs() < 0.05);
    }
}
