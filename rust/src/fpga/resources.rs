//! Analytical FPGA resource model.
//!
//! Substitutes for Vivado synthesis reports (DESIGN.md §1). Counts are
//! built structurally — 2:1-mux equivalents, storage bits, pipeline
//! registers — exactly following the paper's own complexity analysis
//! (§II-B: baseline costs `W_line x (N-1)` mux2; §III-D: Medusa costs
//! `W_line x log2(N)` mux2), then mapped to LUT/FF/BRAM with Virtex-7
//! technology constants. The handful of per-port control constants are
//! calibrated once against Tables I and II; `rust/tests/calibration.rs`
//! locks every table cell to within tolerance.

use crate::types::Geometry;
use crate::util::{ceil_div, ceil_log2};
use std::fmt;
use std::ops::{Add, AddAssign};

/// A resource count (one Vivado utilization row).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Resources {
    pub lut: u64,
    pub ff: u64,
    pub bram18: u64,
    pub dsp: u64,
}

impl Add for Resources {
    type Output = Resources;
    fn add(self, o: Resources) -> Resources {
        Resources {
            lut: self.lut + o.lut,
            ff: self.ff + o.ff,
            bram18: self.bram18 + o.bram18,
            dsp: self.dsp + o.dsp,
        }
    }
}

impl AddAssign for Resources {
    fn add_assign(&mut self, o: Resources) {
        *self = *self + o;
    }
}

impl fmt::Display for Resources {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "LUT {:>7}  FF {:>7}  BRAM18 {:>4}  DSP {:>4}", self.lut, self.ff, self.bram18, self.dsp)
    }
}

// ---------------------------------------------------------------------------
// Technology constants (Virtex-7, 6-input LUTs). Structural counts come
// from the architecture; these map them to device primitives.

/// LUTs per 2:1-mux bit. A 6-LUT implements two 2:1 muxes (or one 4:1),
/// so 0.5 LUT per mux2 matches how Vivado packs mux trees.
const LUT_PER_MUX2: f64 = 0.5;

/// LUTs per 2:1-mux bit in the write packer's steering logic (write
/// enables rather than full data muxes pack slightly denser).
const LUT_PER_MUX2_PACK: f64 = 0.5;

/// LUTRAM: one SLICEM 6-LUT stores 32 deep x 2 bits wide (RAM32M),
/// i.e. 0.5 LUT per bit of width per 32 entries of depth.
fn lutram_luts(width_bits: usize, depth: usize) -> u64 {
    (ceil_div(depth.max(1), 32) as u64) * (width_bits as u64).div_ceil(2)
}

/// BRAM-18K count to implement `width x depth`, using the block's native
/// aspect ratios (512x36, 1Kx18, 2Kx9, 4Kx4, 8Kx2, 16Kx1) with depth
/// cascading, as Vivado maps simple dual-port memories.
pub fn bram18_for(width_bits: usize, depth: usize) -> u64 {
    if width_bits == 0 || depth == 0 {
        return 0;
    }
    // Pick the widest mode whose native depth covers the most rows,
    // counting width-stacks x depth-cascades; take the best packing.
    const MODES: &[(usize, usize)] = &[(36, 512), (18, 1024), (9, 2048), (4, 4096), (2, 8192), (1, 16384)];
    MODES
        .iter()
        .map(|&(w, d)| (ceil_div(width_bits, w) as u64) * (ceil_div(depth, d) as u64))
        .min()
        .unwrap()
}

/// Per-port control overhead of a baseline lane (FIFO pointers/flags
/// decode, burst counter, valid pipeline) — calibrated on Table I.
const BASE_PORT_CTRL_LUT: u64 = 80;
const BASE_PORT_CTRL_FF: u64 = 24;
/// FIFO pointer/flag registers per lane.
const BASE_FIFO_PTR_FF: u64 = 16;
/// Demux/mux select decode per port.
const BASE_SELECT_LUT: u64 = 4;

/// Medusa per-port control (head/tail/count pointers, per-port FSM) —
/// calibrated on Table II.
const MEDUSA_PORT_CTRL_LUT: u64 = 58;
const MEDUSA_PORT_CTRL_FF: u64 = 26;
/// Global control (rotation counter, bank address generation pipeline).
const MEDUSA_GLOBAL_LUT: u64 = 160;
const MEDUSA_GLOBAL_FF: u64 = 128;

/// AXI4-Stream IP overhead per port beyond the bare datapath
/// (handshake conversion, TKEEP/TLAST plumbing, protocol FSM) and its
/// register-built FIFO stages on the wide path — fit to Table I.
const AXIS_PORT_PROTO_LUT: u64 = 200;
const AXIS_PORT_PROTO_LUT_WR: u64 = 50;
const AXIS_REG_FIFO_DEPTH: u64 = 4;

// ---------------------------------------------------------------------------
// Data transfer networks

/// Baseline read network (paper Fig 1): per-port `W_line x MaxBurst`
/// LUTRAM FIFO + width converter (`W_acc x (N-1)` mux2) + line register.
pub fn baseline_read(g: &Geometry) -> Resources {
    let n = g.words_per_line();
    let p = g.read_ports as u64;
    let w = g.w_line as u64;
    let mux2_per_conv = (g.w_acc * (n - 1)) as f64;
    let lut = (lutram_luts(g.w_line, g.max_burst)) * p
        + (mux2_per_conv * LUT_PER_MUX2) as u64 * p
        + BASE_PORT_CTRL_LUT * p
        + BASE_SELECT_LUT * p;
    let ff = w * p                       // converter line register per port
        + w                              // demux input staging register
        + (g.w_acc as u64) * p           // output word register
        + BASE_FIFO_PTR_FF * p
        + BASE_PORT_CTRL_FF * p;
    Resources { lut, ff, bram18: 0, dsp: 0 }
}

/// Baseline write network (paper Fig 2): per-port packer (`W_acc x (N-1)`
/// mux2-equivalent steering + `W_line` accumulator) + LUTRAM FIFO +
/// `W_line`-wide N-to-1 output mux.
pub fn baseline_write(g: &Geometry) -> Resources {
    let n = g.words_per_line();
    let p = g.write_ports as u64;
    let w = g.w_line as u64;
    let mux2_per_conv = (g.w_acc * (n - 1)) as f64;
    let outmux_mux2 = (g.w_line as u64) * (p - 1).max(0);
    let lut = lutram_luts(g.w_line, g.max_burst) * p
        + (mux2_per_conv * LUT_PER_MUX2_PACK) as u64 * p
        + (outmux_mux2 as f64 * LUT_PER_MUX2) as u64
        + BASE_PORT_CTRL_LUT * p
        + BASE_SELECT_LUT * p;
    let ff = w * p                       // packer accumulator per port
        + w * p                          // FIFO output register per port (mux timing)
        + w                              // mux output pipeline register
        + BASE_FIFO_PTR_FF * p
        + BASE_PORT_CTRL_FF * p;
    Resources { lut, ff, bram18: 0, dsp: 0 }
}

/// Medusa read network (paper Fig 3a): BRAM input buffer (N banks x
/// W_acc x ports*MaxBurst), pipelined rotator (`W_line x log2 N` mux2 +
/// stage registers), LUTRAM output double buffer, per-port pointers.
pub fn medusa_read(g: &Geometry) -> Resources {
    let n = g.words_per_line();
    let p = g.read_ports as u64;
    let w = g.w_line as u64;
    let stages = ceil_log2(n) as u64;
    let rot_mux2 = w * stages;
    // Bank read-address distribution: the per-port head pointers are
    // rotated to the banks through an address rotator (log2 N stages x N
    // lanes x addr bits).
    let addr_bits = ceil_log2(g.read_ports.max(2) * g.max_burst) as u64;
    let addr_rot_mux2 = stages * (n as u64) * addr_bits;
    let lut = (rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (addr_rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + lutram_luts(g.w_acc, 2 * n) * p     // output double buffer
        + MEDUSA_PORT_CTRL_LUT * p
        + MEDUSA_GLOBAL_LUT;
    let ff = (w + n as u64) * stages           // rotator data+valid pipeline
        + addr_bits * (n as u64)               // address pipeline (one stage)
        + (g.w_acc as u64) * p                 // port output register
        + MEDUSA_PORT_CTRL_FF * p
        + MEDUSA_GLOBAL_FF;
    let bram = (n as u64) * bram18_for(g.w_acc, g.read_ports * g.max_burst);
    Resources { lut, ff, bram18: bram, dsp: 0 }
}

/// Medusa write network (paper Fig 3b): LUTRAM input double buffer,
/// rotator, BRAM output buffer, per-port pointers.
pub fn medusa_write(g: &Geometry) -> Resources {
    let n = g.words_per_line();
    let p = g.write_ports as u64;
    let w = g.w_line as u64;
    let stages = ceil_log2(n) as u64;
    let rot_mux2 = w * stages;
    let addr_bits = ceil_log2(g.write_ports.max(2) * g.max_burst) as u64;
    let addr_rot_mux2 = stages * (n as u64) * addr_bits;
    let lut = (rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (addr_rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + lutram_luts(g.w_acc, 2 * n) * p
        + MEDUSA_PORT_CTRL_LUT * p
        + MEDUSA_GLOBAL_LUT;
    let ff = (w + n as u64) * stages
        + addr_bits * (n as u64)
        + MEDUSA_PORT_CTRL_FF * p
        + MEDUSA_GLOBAL_FF;
    let bram = (n as u64) * bram18_for(g.w_acc, g.write_ports * g.max_burst);
    Resources { lut, ff, bram18: bram, dsp: 0 }
}

// ---------------------------------------------------------------------------
// Hybrid (partial-transpose) family

/// Fine-select steering decode per chunk per control group — the
/// hybrid's analogue of `BASE_SELECT_LUT`, covering the wider per-chunk
/// enable fan-in of the grouped datapath.
const HYBRID_SELECT_LUT: u64 = 8;

/// Hybrid read network: the structural interpolation between
/// [`baseline_read`] and [`medusa_read`] along the transpose radix.
///
/// The radix endpoints *are* the endpoint designs (the simulator
/// instantiates those exact datapaths — see `interconnect::hybrid`), so
/// their costs are taken from the calibrated endpoint models verbatim.
/// Intermediate radices are counted structurally with the same method
/// the endpoints were calibrated with: a shared radix-`r` rotator
/// (`W_line x log2 r` mux2 + stage registers), a per-port `(N/r):1`
/// fine-select mux (`W_acc x (N/r - 1)` mux2), Medusa's banked BRAM
/// input buffer and LUTRAM output double buffer, and Medusa-style
/// per-port control plus per-group chunk-select decode
/// (`port_group_width` ports share one decoder).
pub fn hybrid_read(g: &Geometry, hc: &crate::interconnect::hybrid::HybridConfig) -> Resources {
    let n = g.words_per_line();
    let r = hc.transpose_radix;
    assert!(
        r.is_power_of_two() && (2..=n).contains(&r),
        "hybrid radix {r} invalid for N = {n} (validate the config against the geometry first)"
    );
    if r == 2 {
        return baseline_read(g);
    }
    if r == n {
        return medusa_read(g);
    }
    let p = g.read_ports as u64;
    let w = g.w_line as u64;
    let chunks = (n / r) as u64;
    let stages = ceil_log2(r) as u64;
    let rot_mux2 = w * stages;
    let addr_bits = ceil_log2(g.read_ports.max(2) * g.max_burst) as u64;
    let addr_rot_mux2 = stages * (n as u64) * addr_bits;
    let fine_mux2 = (g.w_acc as u64) * (chunks - 1) * p;
    let groups = hc.select_groups(g.read_ports) as u64;
    let lut = (rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (addr_rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (fine_mux2 as f64 * LUT_PER_MUX2) as u64
        + lutram_luts(g.w_acc, 2 * n) * p          // output double buffer
        + HYBRID_SELECT_LUT * chunks * groups      // chunk-select decode
        + MEDUSA_PORT_CTRL_LUT * p
        + MEDUSA_GLOBAL_LUT;
    let ff = (w + n as u64) * stages               // rotator data+valid pipeline
        + addr_bits * (n as u64)                   // address pipeline (one stage)
        + ceil_log2(chunks as usize) as u64 * p    // fine-select registers
        + (g.w_acc as u64) * p                     // port output register
        + MEDUSA_PORT_CTRL_FF * p
        + MEDUSA_GLOBAL_FF;
    let bram = (n as u64) * bram18_for(g.w_acc, g.read_ports * g.max_burst);
    Resources { lut, ff, bram18: bram, dsp: 0 }
}

/// Hybrid write network (mirror of [`hybrid_read`]; see there).
pub fn hybrid_write(g: &Geometry, hc: &crate::interconnect::hybrid::HybridConfig) -> Resources {
    let n = g.words_per_line();
    let r = hc.transpose_radix;
    assert!(
        r.is_power_of_two() && (2..=n).contains(&r),
        "hybrid radix {r} invalid for N = {n} (validate the config against the geometry first)"
    );
    if r == 2 {
        return baseline_write(g);
    }
    if r == n {
        return medusa_write(g);
    }
    let p = g.write_ports as u64;
    let w = g.w_line as u64;
    let chunks = (n / r) as u64;
    let stages = ceil_log2(r) as u64;
    let rot_mux2 = w * stages;
    let addr_bits = ceil_log2(g.write_ports.max(2) * g.max_burst) as u64;
    let addr_rot_mux2 = stages * (n as u64) * addr_bits;
    let fine_mux2 = (g.w_acc as u64) * (chunks - 1) * p;
    let groups = hc.select_groups(g.write_ports) as u64;
    let lut = (rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (addr_rot_mux2 as f64 * LUT_PER_MUX2) as u64
        + (fine_mux2 as f64 * LUT_PER_MUX2_PACK) as u64
        + lutram_luts(g.w_acc, 2 * n) * p          // input double buffer
        + HYBRID_SELECT_LUT * chunks * groups
        + MEDUSA_PORT_CTRL_LUT * p
        + MEDUSA_GLOBAL_LUT;
    let ff = (w + n as u64) * stages
        + addr_bits * (n as u64)
        + ceil_log2(chunks as usize) as u64 * p
        + MEDUSA_PORT_CTRL_FF * p
        + MEDUSA_GLOBAL_FF;
    let bram = (n as u64) * bram18_for(g.w_acc, g.write_ports * g.max_burst);
    Resources { lut, ff, bram18: bram, dsp: 0 }
}

/// AXI4-Stream read network (Table I): baseline datapath + per-port
/// protocol plumbing + register-built FIFO stages on the wide path
/// (TDATA + TKEEP + control per stage).
pub fn axis_read(g: &Geometry) -> Resources {
    let base = baseline_read(g);
    let p = g.read_ports as u64;
    let wide_bits = (g.w_line + g.w_line / 8 + 8) as u64; // TDATA+TKEEP+ctl
    Resources {
        lut: base.lut + p * ((wide_bits as f64 * LUT_PER_MUX2) as u64 + AXIS_PORT_PROTO_LUT),
        ff: base.ff + p * AXIS_REG_FIFO_DEPTH * wide_bits,
        bram18: 0,
        dsp: 0,
    }
}

/// AXI4-Stream write network (Table I).
pub fn axis_write(g: &Geometry) -> Resources {
    let base = baseline_write(g);
    let p = g.write_ports as u64;
    let wide_bits = (g.w_line + g.w_line / 8 + 8) as u64;
    Resources {
        lut: base.lut + p * ((wide_bits as f64 * LUT_PER_MUX2 / 2.0) as u64 + AXIS_PORT_PROTO_LUT_WR),
        ff: base.ff + p * AXIS_REG_FIFO_DEPTH * wide_bits,
        bram18: 0,
        dsp: 0,
    }
}

// ---------------------------------------------------------------------------
// Hierarchical (clustered) family

/// Shared trunk arbiter/credit control per cluster (head-of-line
/// bookkeeping, credit counters, round-robin pointer).
const HIER_CLUSTER_CTRL_LUT: u64 = 40;
const HIER_CLUSTER_CTRL_FF: u64 = 24;

/// Hierarchical read network: one calibrated Medusa transposer per
/// cluster (plus one for the bypass group when present), a `W_line`-wide
/// trunk distribution mux across the clusters, and `levels - 1` trunk
/// pipeline register stages (one per hierarchy level crossed).
pub fn hierarchical_read(g: &Geometry, hc: &crate::interconnect::hierarchical::HierConfig) -> Resources {
    let w = g.w_line as u64;
    let n_clusters = hc.clusters(g.read_ports) as u64;
    let mut r = Resources::default();
    let sub = hc.sub_geom(g, hc.cluster_ports);
    for _ in 0..n_clusters {
        r += medusa_read(&sub);
    }
    if hc.bypass_ports > 0 {
        r += medusa_read(&hc.sub_geom(g, hc.bypass_ports));
    }
    // Trunk: line-wide (n_clusters):1 steering plus per-level registers.
    let trunk_mux2 = w * (n_clusters - 1);
    r.lut += (trunk_mux2 as f64 * LUT_PER_MUX2) as u64 + HIER_CLUSTER_CTRL_LUT * n_clusters;
    r.ff += w * hc.trunk_crossing() + HIER_CLUSTER_CTRL_FF * n_clusters;
    r
}

/// Hierarchical write network (mirror of [`hierarchical_read`]: per-port
/// staging registers on the memory side replace the distribution mux's
/// output register, same trunk pipeline).
pub fn hierarchical_write(g: &Geometry, hc: &crate::interconnect::hierarchical::HierConfig) -> Resources {
    let w = g.w_line as u64;
    let n_clusters = hc.clusters(g.write_ports) as u64;
    let mut r = Resources::default();
    let sub = hc.sub_geom(g, hc.cluster_ports);
    for _ in 0..n_clusters {
        r += medusa_write(&sub);
    }
    if hc.bypass_ports > 0 {
        r += medusa_write(&hc.sub_geom(g, hc.bypass_ports));
    }
    let trunk_mux2 = w * (n_clusters - 1);
    r.lut += (trunk_mux2 as f64 * LUT_PER_MUX2) as u64 + HIER_CLUSTER_CTRL_LUT * n_clusters;
    r.ff += w * hc.trunk_crossing() + HIER_CLUSTER_CTRL_FF * n_clusters;
    r
}

// ---------------------------------------------------------------------------
// Layer processor (the paper's §IV-A convolutional layer processor)

/// Buffer depths from §IV-A, "suitable for VGGNet and similar CNNs".
pub const IFMAP_BUF_DEPTH: usize = 2260;
pub const OFMAP_BUF_DEPTH: usize = 1792;
pub const WEIGHT_BUF_DEPTH: usize = 9;
/// Multipliers (DSP slices) per vector dot-product unit.
pub const DSP_PER_DPU: u64 = 32;

/// Per-DPU logic: 32 multiplier input/output registers, the adder-tree
/// beyond the DSP cascade, buffer addressing, and its share of control —
/// calibrated so the 64-DPU point reproduces the Table II totals net of
/// the networks.
const LP_LUT_PER_DPU: u64 = 2_350;
const LP_FF_PER_DPU: u64 = 2_900;
/// Shared layer-processor control (fit to Table II BRAM total 726).
const LP_SHARED_BRAM: u64 = 22;

/// Resource model of a convolutional layer processor with `dpus`
/// 32-wide vector dot-product units and double-buffered feature maps.
pub fn layer_processor(dpus: usize) -> Resources {
    let d = dpus as u64;
    let per_dpu_bram = (bram18_for(16, IFMAP_BUF_DEPTH) + bram18_for(16, OFMAP_BUF_DEPTH)) * 2
        + bram18_for(16, WEIGHT_BUF_DEPTH).max(1);
    Resources {
        lut: LP_LUT_PER_DPU * d,
        ff: LP_FF_PER_DPU * d,
        bram18: per_dpu_bram * d + LP_SHARED_BRAM,
        dsp: DSP_PER_DPU * d,
    }
}

// ---------------------------------------------------------------------------

/// Full-accelerator resource roll-up for a design point.
pub fn full_design(
    design: crate::interconnect::Design,
    g: &Geometry,
    dpus: usize,
) -> Resources {
    use crate::interconnect::Design;
    let (rd, wr) = match design {
        Design::Baseline => (baseline_read(g), baseline_write(g)),
        Design::Medusa => (medusa_read(g), medusa_write(g)),
        Design::Axis => (axis_read(g), axis_write(g)),
        Design::Hybrid(hc) => (hybrid_read(g, &hc), hybrid_write(g, &hc)),
        Design::Hierarchical(hc) => (hierarchical_read(g, &hc), hierarchical_write(g, &hc)),
    };
    layer_processor(dpus) + rd + wr
}

#[cfg(test)]
mod tests {
    use super::*;

    fn table1_geom() -> Geometry {
        // §IV-B: 256-bit interface, 16 x 16-bit ports, FIFO depth 32.
        Geometry { w_line: 256, w_acc: 16, read_ports: 16, write_ports: 16, max_burst: 32 }
    }

    fn table2_geom() -> Geometry {
        Geometry::paper_default()
    }

    fn pct_err(model: u64, paper: u64) -> f64 {
        100.0 * (model as f64 - paper as f64) / paper as f64
    }

    #[test]
    fn bram18_mapping_modes() {
        assert_eq!(bram18_for(16, 1024), 1); // Medusa bank: 1Kx18 mode
        assert_eq!(bram18_for(16, 2260), 3); // ifmap buffer
        assert_eq!(bram18_for(16, 1792), 2); // ofmap buffer
        assert_eq!(bram18_for(36, 512), 1);
        assert_eq!(bram18_for(72, 512), 2);
        assert_eq!(bram18_for(1, 16384), 1);
        assert_eq!(bram18_for(0, 100), 0);
    }

    #[test]
    fn lutram_mapping() {
        // 512 wide x 32 deep = one RAM32M-pair column: 256 LUTs.
        assert_eq!(lutram_luts(512, 32), 256);
        assert_eq!(lutram_luts(16, 64), 16);
    }

    #[test]
    fn paper_complexity_ordering_holds() {
        // §III-D: Medusa's mux count W*log2(N) beats baseline's W*(N-1)
        // and the gap grows with N.
        for &(w, ports) in &[(128usize, 8usize), (256, 16), (512, 32), (1024, 64)] {
            let g = Geometry { w_line: w, w_acc: 16, read_ports: ports, write_ports: ports, max_burst: 32 };
            let b = baseline_read(&g) + baseline_write(&g);
            let m = medusa_read(&g) + medusa_write(&g);
            assert!(m.lut < b.lut, "w={w}: medusa {} !< baseline {}", m.lut, b.lut);
            assert!(m.ff < b.ff, "w={w}");
            assert!(m.bram18 > 0 && b.bram18 == 0);
        }
    }

    #[test]
    fn table2_medusa_uses_64_brams() {
        let g = table2_geom();
        assert_eq!(medusa_read(&g).bram18, 32);
        assert_eq!(medusa_write(&g).bram18, 32);
    }

    #[test]
    fn baseline_brams_would_cost_960() {
        // §IV-C: "if the baseline design were to use BRAMs ... 960 BRAMs
        // would be needed": each 32x512b FIFO = 15 BRAM-18K x 64 FIFOs.
        let per_fifo = bram18_for(512, 32);
        assert_eq!(per_fifo, 15);
        assert_eq!(per_fifo * 64, 960);
    }

    #[test]
    fn table1_cells_within_tolerance() {
        let g = table1_geom();
        let cases: &[(&str, u64, u64)] = &[
            ("base_read.lut", baseline_read(&g).lut, 5_313),
            ("base_read.ff", baseline_read(&g).ff, 5_404),
            ("base_write.lut", baseline_write(&g).lut, 6_810),
            ("base_write.ff", baseline_write(&g).ff, 9_023),
            ("axis_read.lut", axis_read(&g).lut, 11_562),
            ("axis_read.ff", axis_read(&g).ff, 27_173),
            ("axis_write.lut", axis_write(&g).lut, 9_170),
            ("axis_write.ff", axis_write(&g).ff, 26_554),
        ];
        for (name, model, paper) in cases {
            let err = pct_err(*model, *paper);
            assert!(err.abs() < 15.0, "{name}: model {model} vs paper {paper} ({err:+.1}%)");
        }
        // The qualitative Table I claim: baseline strictly cheaper.
        assert!(baseline_read(&g).lut < axis_read(&g).lut);
        assert!(baseline_read(&g).ff < axis_read(&g).ff);
        assert!(baseline_write(&g).lut < axis_write(&g).lut);
        assert!(baseline_write(&g).ff < axis_write(&g).ff);
    }

    #[test]
    fn table2_cells_within_tolerance() {
        let g = table2_geom();
        let cases: &[(&str, u64, u64)] = &[
            ("base_read.lut", baseline_read(&g).lut, 18_168),
            ("base_read.ff", baseline_read(&g).ff, 19_210),
            ("base_write.lut", baseline_write(&g).lut, 26_810),
            ("base_write.ff", baseline_write(&g).ff, 35_451),
            ("medusa_read.lut", medusa_read(&g).lut, 4_733),
            ("medusa_read.ff", medusa_read(&g).ff, 4_759),
            ("medusa_write.lut", medusa_write(&g).lut, 4_777),
            ("medusa_write.ff", medusa_write(&g).ff, 4_325),
        ];
        for (name, model, paper) in cases {
            let err = pct_err(*model, *paper);
            assert!(err.abs() < 15.0, "{name}: model {model} vs paper {paper} ({err:+.1}%)");
        }
    }

    #[test]
    fn headline_savings_factors() {
        // Abstract: 4.7x LUT and 6.0x FF savings on the combined networks.
        let g = table2_geom();
        let b = baseline_read(&g) + baseline_write(&g);
        let m = medusa_read(&g) + medusa_write(&g);
        let lut_factor = b.lut as f64 / m.lut as f64;
        let ff_factor = b.ff as f64 / m.ff as f64;
        assert!((3.8..=5.6).contains(&lut_factor), "LUT factor {lut_factor:.2} (paper 4.73)");
        assert!((4.8..=7.2).contains(&ff_factor), "FF factor {ff_factor:.2} (paper 6.02)");
    }

    #[test]
    fn hybrid_endpoints_cost_exactly_like_the_endpoint_designs() {
        use crate::interconnect::hybrid::HybridConfig;
        for g in [table1_geom(), table2_geom()] {
            let n = g.words_per_line();
            let r2 = HybridConfig { transpose_radix: 2, ..Default::default() };
            assert_eq!(hybrid_read(&g, &r2), baseline_read(&g));
            assert_eq!(hybrid_write(&g, &r2), baseline_write(&g));
            let rn = HybridConfig { transpose_radix: n, ..Default::default() };
            assert_eq!(hybrid_read(&g, &rn), medusa_read(&g));
            assert_eq!(hybrid_write(&g, &rn), medusa_write(&g));
        }
    }

    #[test]
    fn hybrid_family_lut_interpolates_monotonically() {
        // §II-B vs §III-D: the family's mux count walks from
        // W x (N-1)-shaped down to W x log2(N)-shaped as the radix
        // grows; total LUTs must decrease strictly with radix.
        use crate::interconnect::hybrid::HybridConfig;
        let g = table2_geom(); // N = 32
        let lut_of = |r: usize| {
            let hc = HybridConfig { transpose_radix: r, ..Default::default() };
            (hybrid_read(&g, &hc) + hybrid_write(&g, &hc)).lut
        };
        let series: Vec<u64> = [2usize, 4, 8, 16, 32].iter().map(|&r| lut_of(r)).collect();
        for w in series.windows(2) {
            assert!(w[1] < w[0], "LUT must fall as radix grows: {series:?}");
        }
        // Partial points stay strictly between the endpoints on both
        // LUT and FF (FF: shared banks remove the baseline's per-port
        // wide registers immediately; the rotator pipeline then grows
        // with log2 r toward Medusa's fully pipelined count).
        let base = baseline_read(&g) + baseline_write(&g);
        for r in [4usize, 8, 16] {
            let hc = HybridConfig { transpose_radix: r, ..Default::default() };
            let h = hybrid_read(&g, &hc) + hybrid_write(&g, &hc);
            assert!(h.lut < base.lut && h.ff < base.ff, "radix {r} vs baseline");
            assert!(h.bram18 > 0, "partial transpose keeps the banked BRAM buffers");
        }
    }

    #[test]
    fn hybrid_port_grouping_amortizes_select_decode() {
        use crate::interconnect::hybrid::HybridConfig;
        let g = table2_geom();
        let lut_of = |pgw: usize| {
            let hc =
                HybridConfig { transpose_radix: 8, stage_pipelining: 0, port_group_width: pgw };
            hybrid_read(&g, &hc).lut
        };
        assert!(lut_of(4) < lut_of(1), "wider control groups must shed decode LUTs");
        assert!(lut_of(8) <= lut_of(4));
    }

    #[test]
    fn hierarchical_model_counts_clusters_trunk_and_bypass() {
        use crate::interconnect::hierarchical::HierConfig;
        let g = table2_geom(); // 32 ports a side
        let hc = HierConfig { levels: 2, cluster_ports: 8, bypass_ports: 0, trunk_mhz: 300 };
        let h = hierarchical_read(&g, &hc) + hierarchical_write(&g, &hc);
        let flat = medusa_read(&g) + medusa_write(&g);
        // The hierarchy replicates the rotator per cluster: it buys
        // locality (see fpga::timing) with logic, never for free.
        assert!(h.lut > flat.lut, "hier {} !> flat {}", h.lut, flat.lut);
        assert!(h.bram18 > 0);
        // More levels = more trunk pipeline registers, nothing else.
        let deep = HierConfig { levels: 4, ..hc };
        let hd = hierarchical_read(&g, &deep) + hierarchical_write(&g, &deep);
        assert_eq!(hd.lut, h.lut);
        assert_eq!(hd.ff, h.ff + 2 * 2 * g.w_line as u64);
        // Carving bypass ports out of a cluster costs extra: the bypass
        // group is a whole additional transposer instance.
        let byp = HierConfig { cluster_ports: 8, bypass_ports: 8, ..hc }; // 3 clusters + bypass
        let hb = hierarchical_read(&g, &byp);
        let three_plus_one = HierConfig { cluster_ports: 8, bypass_ports: 0, ..hc };
        let four_even = hierarchical_read(&g, &three_plus_one);
        assert!(hb.bram18 == four_even.bram18, "same total transposer count, same BRAM");
    }

    #[test]
    fn table2_totals_within_tolerance() {
        let g = table2_geom();
        let base = full_design(crate::interconnect::Design::Baseline, &g, 64);
        let med = full_design(crate::interconnect::Design::Medusa, &g, 64);
        assert!(pct_err(base.lut, 198_887).abs() < 10.0, "base total LUT {}", base.lut);
        assert!(pct_err(base.ff, 240_449).abs() < 10.0, "base total FF {}", base.ff);
        assert!(pct_err(base.bram18, 726).abs() < 5.0, "base total BRAM {}", base.bram18);
        assert_eq!(base.dsp, 2_048);
        assert!(pct_err(med.lut, 156_409).abs() < 10.0, "medusa total LUT {}", med.lut);
        assert!(pct_err(med.ff, 195_158).abs() < 10.0, "medusa total FF {}", med.ff);
        assert!(pct_err(med.bram18, 790).abs() < 5.0, "medusa total BRAM {}", med.bram18);
        assert_eq!(med.dsp, 2_048);
    }
}
