//! FPGA implementation models: device capacities, analytical resource
//! costing, and the routing-congestion timing model that substitutes for
//! Vivado synthesis + place-and-route (see DESIGN.md §1 and §5 for the
//! substitution rationale and calibration method).

pub mod device;
pub mod elaborate;
pub mod par;
pub mod resources;
pub mod timing;

pub use device::Device;
pub use elaborate::DesignPoint;
pub use resources::Resources;
