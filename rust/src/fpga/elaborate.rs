//! Design-point elaboration: the parameterized design generation the
//! paper did with Bluespec (§IV-A: "the implementations are highly
//! parameterized to allow easy generation of various design points").

use crate::fpga::resources::{self, Resources, DSP_PER_DPU};
use crate::fpga::Device;
use crate::interconnect::Design;
use crate::types::Geometry;
use crate::util::next_pow2;

/// One accelerator design point: a layer processor of `dpus` vector
/// dot-product units coupled to an interconnect of the given geometry.
#[derive(Clone, Copy, Debug)]
pub struct DesignPoint {
    pub design: Design,
    pub geometry: Geometry,
    pub dpus: usize,
}

impl DesignPoint {
    /// The Fig 6 scaling rule (§IV-D): start at 16 DPUs / 8r+8w ports /
    /// 128-bit interface; each step adds 8 DPUs and 4r+4w ports; the
    /// memory interface is the smallest power of two that accommodates
    /// all read ports.
    pub fn fig6_step(design: Design, step: usize) -> DesignPoint {
        let dpus = 16 + 8 * step;
        let ports = 8 + 4 * step;
        let w_line = next_pow2(ports * 16);
        DesignPoint {
            design,
            geometry: Geometry {
                w_line,
                w_acc: 16,
                read_ports: ports,
                write_ports: ports,
                max_burst: 32,
            },
            dpus,
        }
    }

    /// All Fig 6 points for one design (up to 3072 DSPs, where the
    /// 1024-bit region ends in the paper's figure).
    pub fn fig6_sweep(design: Design) -> Vec<DesignPoint> {
        (0..=10).map(|s| Self::fig6_step(design, s)).collect()
    }

    /// Accelerator size in DSP slices (Fig 6's x-axis).
    pub fn dsps(&self) -> u64 {
        self.dpus as u64 * DSP_PER_DPU
    }

    /// Total resource roll-up (layer processor + both networks).
    pub fn resources(&self) -> Resources {
        resources::full_design(self.design, &self.geometry, self.dpus)
    }

    /// Resource utilization pressure on a device: the max utilization
    /// fraction across resource classes — the quantity P&R difficulty
    /// tracks.
    pub fn utilization(&self, dev: &Device) -> f64 {
        let r = self.resources();
        let fracs = [
            r.lut as f64 / dev.luts as f64,
            r.ff as f64 / dev.ffs as f64,
            r.bram18 as f64 / dev.bram18 as f64,
            r.dsp as f64 / dev.dsps as f64,
        ];
        fracs.iter().cloned().fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig6_first_point_matches_paper() {
        let p = DesignPoint::fig6_step(Design::Baseline, 0);
        assert_eq!(p.dpus, 16);
        assert_eq!(p.dsps(), 512);
        assert_eq!(p.geometry.read_ports, 8);
        assert_eq!(p.geometry.w_line, 128);
    }

    #[test]
    fn fig6_interface_width_regions() {
        // §IV-D: (8,16] ports -> 256-bit; (16,32] -> 512-bit.
        let widths: Vec<usize> =
            DesignPoint::fig6_sweep(Design::Medusa).iter().map(|p| p.geometry.w_line).collect();
        assert_eq!(widths, vec![128, 256, 256, 512, 512, 512, 512, 1024, 1024, 1024, 1024]);
    }

    #[test]
    fn fig6_table2_point_is_2048_dsps() {
        // §IV-D: "the 2048-DSP points correspond to the designs whose
        // resource use metrics were evaluated in Table II".
        let p = DesignPoint::fig6_step(Design::Medusa, 6);
        assert_eq!(p.dsps(), 2048);
        assert_eq!(p.geometry, Geometry::paper_default());
        assert_eq!(p.dpus, 64);
    }

    #[test]
    fn utilization_monotonic_in_size() {
        let dev = Device::virtex7_690t();
        let sweep = DesignPoint::fig6_sweep(Design::Baseline);
        for w in sweep.windows(2) {
            assert!(w[1].utilization(&dev) > w[0].utilization(&dev));
        }
        // Largest point must still fit the device.
        assert!(sweep.last().unwrap().utilization(&dev) < 1.0);
    }
}
