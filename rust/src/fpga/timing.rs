//! Post-place-and-route timing model.
//!
//! Substitutes for Vivado P&R (DESIGN.md §1). The achievable clock of a
//! design point is modelled as the critical path through:
//!
//! * register clock-to-out + the design's logic levels (LUT delays) —
//!   baseline: the `N:1` width-converter mux tree read out of LUTRAM;
//!   Medusa: a BRAM access plus one (pipelined) rotator stage;
//! * a routing term that inflates with **congestion**: the ratio of the
//!   design's wide-bus wire demand to the device's routing supply,
//!   de-rated by how full the device is (placed logic both lengthens the
//!   wide buses and consumes routing). This is the mechanism the paper
//!   identifies (§II-C: "a large number of buses as wide as the DRAM
//!   controller interface is widely distributed within this design ...
//!   greatly limiting the peak clock frequency").
//!
//! Baseline wire demand scales with `W_line x N` distributed buses;
//! Medusa's with `W_line x log2(N)` localized rotator wiring — the same
//! asymmetry as the paper's logic-complexity analysis, §III-D.
//!
//! Constants are calibrated against Fig 6's anchors (see
//! `rust/tests/calibration.rs`): Medusa >= 1.8x in the 512-bit region's
//! large points, baseline collapsing below 25 MHz in the 1024-bit region
//! while Medusa holds 200–225 MHz.

use crate::fpga::{DesignPoint, Device};
use crate::interconnect::Design;
use crate::util::{ceil_log2, snap_to_freq_grid};

/// Route hybrid radix endpoints through the endpoint designs' timing
/// arms: those radices instantiate the *exact* baseline/Medusa
/// datapaths (see `interconnect::hybrid`), so they must cost exactly
/// like them here too.
fn canonical_design(design: Design, n_words: usize) -> Design {
    match design {
        Design::Hybrid(hc) if hc.transpose_radix == 2 => Design::Baseline,
        Design::Hybrid(hc) if hc.transpose_radix == n_words => Design::Medusa,
        d => d,
    }
}

/// Calibrated timing-model constants (Virtex-7 speed grade -2-ish).
#[derive(Clone, Copy, Debug)]
pub struct TimingModel {
    /// Register clock-to-out + setup (ns).
    pub t_ff_ns: f64,
    /// One LUT + local interconnect (ns).
    pub t_lut_ns: f64,
    /// BRAM clock-to-out (ns) — on Medusa's path.
    pub t_bram_ns: f64,
    /// LUTRAM read (ns) — on the baseline converter path.
    pub t_lutram_ns: f64,
    /// Base routing delay at zero congestion (ns).
    pub t_route0_ns: f64,
    /// Congestion exponent: route delay multiplies by (1+gamma)^beta.
    pub beta: f64,
    /// Global clocking ceiling (MHz).
    pub f_max_mhz: f64,
}

impl TimingModel {
    pub fn calibrated() -> Self {
        TimingModel {
            t_ff_ns: 0.5,
            t_lut_ns: 0.45,
            t_bram_ns: 1.7,
            t_lutram_ns: 0.9,
            t_route0_ns: 0.8,
            beta: 4.2,
            f_max_mhz: 400.0,
        }
    }

    /// Logic-levels delay of the design's critical path (ns).
    fn logic_delay_ns(&self, design: Design, n_words: usize) -> f64 {
        match canonical_design(design, n_words) {
            Design::Baseline | Design::Axis => {
                // LUTRAM FIFO read, then the N:1 converter mux tree
                // (4:1 per LUT level), plus a control level.
                let mux_levels = ceil_log2(n_words).div_ceil(2) as f64 + 1.0;
                self.t_ff_ns + self.t_lutram_ns + mux_levels * self.t_lut_ns
            }
            Design::Medusa => {
                // BRAM bank read, one rotator stage (pipelined), output
                // staging level.
                self.t_ff_ns + self.t_bram_ns + 2.0 * self.t_lut_ns
            }
            Design::Hybrid(hc) => {
                // BRAM bank read, the unpipelined remainder of the
                // radix-r rotator, the (N/r):1 fine chunk mux (4:1 per
                // LUT level), and an output staging level. Fully
                // pipelining the rotator (stage_pipelining >= log2 r)
                // leaves one stage on the path — continuous with the
                // Medusa formula as the chunk count reaches 1.
                let chunks = n_words / hc.transpose_radix;
                let rot_levels =
                    ceil_log2(hc.transpose_radix).saturating_sub(hc.stage_pipelining).max(1) as f64;
                let fine_levels = ceil_log2(chunks).div_ceil(2) as f64;
                self.t_ff_ns + self.t_bram_ns + (rot_levels + fine_levels + 1.0) * self.t_lut_ns
            }
            Design::Hierarchical(_) => {
                // A cluster's path is Medusa's (BRAM read + one rotator
                // stage + staging) plus the trunk distribution mux —
                // one extra LUT level. Trunk segments are registered
                // once per hierarchy level, so depth never lengthens
                // the combinational path.
                self.t_ff_ns + self.t_bram_ns + 3.0 * self.t_lut_ns
            }
        }
    }

    /// Congestion ratio gamma for a design point on a device.
    fn congestion(&self, p: &DesignPoint, dev: &Device) -> f64 {
        let u = p.utilization(dev);
        let w = p.geometry.w_line as f64;
        let ports = p.geometry.read_ports.max(p.geometry.write_ports) as f64;
        let n_words = p.geometry.words_per_line() as f64;
        let (bus_bits, spread) = match canonical_design(p.design, p.geometry.words_per_line()) {
            Design::Baseline | Design::Axis => {
                // N wide buses (demux legs + mux legs) distributed across
                // the die; their span grows as placed logic pushes
                // endpoints apart.
                let spread = 1.0 + 0.8 * u;
                (w * (ports + 2.0), spread * spread)
            }
            Design::Medusa => {
                // log2(N) rotator stages of W_line wiring, localized, plus
                // the narrow per-port wiring.
                let stages = n_words.log2().ceil().max(1.0);
                let loc = 1.0 + 0.25 * u;
                (w * (stages + 2.0) + p.geometry.w_acc as f64 * ports, loc * loc)
            }
            Design::Hybrid(hc) => {
                // log2(r) rotator stages of localized W_line wiring plus
                // the fine-select chunk buses (W_acc per chunk per port),
                // whose spread interpolates between Medusa's localized
                // 0.25 coefficient (1 chunk) and the baseline's
                // distributed 0.8 (N/2 chunks) as the radix shrinks.
                let stages = ceil_log2(hc.transpose_radix) as f64;
                let chunks = (p.geometry.words_per_line() / hc.transpose_radix) as f64;
                let frac = (chunks - 1.0) / (n_words / 2.0 - 1.0).max(1.0);
                let loc = 1.0 + (0.25 + 0.55 * frac) * u;
                (
                    w * (stages + 2.0) + p.geometry.w_acc as f64 * ports * chunks,
                    loc * loc,
                )
            }
            Design::Hierarchical(hc) => {
                // Each cluster's rotator competes only for its own
                // placement region's routing (the cluster regions tile
                // the die, supply pro-rated), so the hotspot demand is
                // one cluster's worth of Medusa wiring over its few
                // local ports — its spread de-rate shrinks with the
                // cluster's share of the machine. The only die-crossing
                // wiring left is the trunk: a single W_line bus whose
                // segments are registered every level, but which spreads
                // like the baseline's wide buses as the die fills.
                let stages = n_words.log2().ceil().max(1.0);
                let cp = hc.cluster_ports as f64;
                let loc = 1.0 + 0.25 * u * (cp / ports).min(1.0);
                let die = 1.0 + 0.8 * u;
                (
                    (w * (stages + 2.0) + p.geometry.w_acc as f64 * cp) * loc * loc
                        + w * die * die,
                    1.0,
                )
            }
        };
        bus_bits * spread / dev.routing_supply
    }

    /// Peak post-P&R frequency, snapped to the paper's 25 MHz search
    /// grid; 0 means "failed timing at 25 MHz".
    pub fn peak_frequency_mhz(&self, p: &DesignPoint, dev: &Device) -> u32 {
        let gamma = self.congestion(p, dev);
        let route = self.t_route0_ns * (1.0 + gamma).powf(self.beta);
        let t_ns = self.logic_delay_ns(p.design, p.geometry.words_per_line()) + route;
        let f = (1000.0 / t_ns).min(self.f_max_mhz);
        snap_to_freq_grid(f)
    }
}

/// Convenience: peak frequency with the calibrated model on the paper's
/// device.
pub fn peak_frequency(p: &DesignPoint) -> u32 {
    TimingModel::calibrated().peak_frequency_mhz(p, &Device::virtex7_690t())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::DesignPoint;

    fn sweep(design: Design) -> Vec<(u64, u32)> {
        DesignPoint::fig6_sweep(design).iter().map(|p| (p.dsps(), peak_frequency(p))).collect()
    }

    #[test]
    fn fig6_monotone_decrease_with_size() {
        for design in [Design::Baseline, Design::Medusa] {
            let s = sweep(design);
            for w in s.windows(2) {
                assert!(
                    w[1].1 <= w[0].1,
                    "{design:?}: freq must not increase with size: {s:?}"
                );
            }
        }
    }

    #[test]
    fn fig6_small_designs_baseline_competitive() {
        // Below 1024 DSPs the baseline meets or beats Medusa (§IV-D:
        // "starting from the point with 1024 DSPs, Medusa designs always
        // outperform baseline designs").
        let b = sweep(Design::Baseline);
        let m = sweep(Design::Medusa);
        for i in 0..2 {
            assert!(
                b[i].1 >= m[i].1,
                "at {} DSPs baseline {} should be >= medusa {}",
                b[i].0,
                b[i].1,
                m[i].1
            );
        }
        for i in 2..b.len() {
            assert!(
                m[i].1 >= b[i].1,
                "at {} DSPs medusa {} should be >= baseline {}",
                m[i].0,
                m[i].1,
                b[i].1
            );
        }
    }

    #[test]
    fn fig6_512bit_region_speedup() {
        // §IV-D: "within the 512-bit memory interface region ... Medusa
        // outperforms the baseline by up to 1.8x (the designs with 1280
        // DSPs and 2048 DSPs)".
        let b = sweep(Design::Baseline);
        let m = sweep(Design::Medusa);
        // Steps 3..=6 are the 512-bit region (1280..2048 DSPs).
        let mut max_ratio: f64 = 0.0;
        for i in 3..=6 {
            assert!(b[i].1 > 0, "baseline must still close timing in the 512b region");
            let r = m[i].1 as f64 / b[i].1 as f64;
            max_ratio = max_ratio.max(r);
        }
        assert!(
            (1.5..=2.4).contains(&max_ratio),
            "max 512b-region speedup {max_ratio:.2} (paper: 1.8x)"
        );
    }

    #[test]
    fn fig6_1024bit_region_baseline_collapses() {
        // §IV-D: "within the 1024-bit memory interface region ... the
        // baseline is barely usable, with some points failing to make
        // timing even at 50MHz or lower. Nonetheless, the Medusa designs
        // ... keep running at 200 to 225MHz".
        let b = sweep(Design::Baseline);
        let m = sweep(Design::Medusa);
        for i in 7..=10 {
            assert!(b[i].1 <= 50, "baseline at {} DSPs should be <=50 MHz, got {}", b[i].0, b[i].1);
            assert!(
                (200..=250).contains(&m[i].1),
                "medusa at {} DSPs should hold 200-225 MHz, got {}",
                m[i].0,
                m[i].1
            );
        }
        assert!(
            b[7..].iter().any(|&(_, f)| f == 0),
            "some 1024-bit baseline points must fail timing entirely"
        );
    }

    #[test]
    fn medusa_holds_mem_clock_at_table2_point() {
        // The representative 512-bit/DDR3-800 system needs >= 200 MHz to
        // match the controller clock; Medusa achieves it, baseline not.
        let m = DesignPoint::fig6_step(Design::Medusa, 6);
        let b = DesignPoint::fig6_step(Design::Baseline, 6);
        assert!(peak_frequency(&m) >= 200);
        assert!(peak_frequency(&b) < 200);
    }

    #[test]
    fn hybrid_endpoints_clock_exactly_like_the_endpoint_designs() {
        use crate::interconnect::hybrid::HybridConfig;
        for step in [3usize, 6, 9] {
            let m = DesignPoint::fig6_step(Design::Medusa, step);
            let b = DesignPoint::fig6_step(Design::Baseline, step);
            let n = m.geometry.words_per_line();
            let h2 = DesignPoint {
                design: Design::Hybrid(HybridConfig { transpose_radix: 2, ..Default::default() }),
                ..b
            };
            assert_eq!(peak_frequency(&h2), peak_frequency(&b), "step {step} radix 2");
            let hn = DesignPoint {
                design: Design::Hybrid(HybridConfig { transpose_radix: n, ..Default::default() }),
                ..m
            };
            assert_eq!(peak_frequency(&hn), peak_frequency(&m), "step {step} radix N");
        }
    }

    #[test]
    fn hybrid_frequency_interpolates_between_endpoints() {
        use crate::interconnect::hybrid::HybridConfig;
        // The representative 512-bit point (step 6, N = 32): partial
        // radices with a fully pipelined rotator must land between the
        // baseline's collapsed clock and Medusa's, improving with radix.
        let step = 6usize;
        let base = peak_frequency(&DesignPoint::fig6_step(Design::Baseline, step));
        let med = peak_frequency(&DesignPoint::fig6_step(Design::Medusa, step));
        let mut prev = base;
        for r in [4usize, 8, 16] {
            let hc = HybridConfig {
                transpose_radix: r,
                stage_pipelining: crate::util::ceil_log2(r),
                port_group_width: 1,
            };
            let p = DesignPoint {
                design: Design::Hybrid(hc),
                ..DesignPoint::fig6_step(Design::Medusa, step)
            };
            let f = peak_frequency(&p);
            assert!(f >= prev, "radix {r}: {f} MHz should be >= {prev}");
            assert!(f <= med, "radix {r}: {f} MHz should not beat Medusa's {med}");
            prev = f;
        }
        assert!(prev > base, "the pipelined partial transpose must beat the baseline");
    }

    #[test]
    fn pipelining_raises_hybrid_frequency() {
        use crate::interconnect::hybrid::HybridConfig;
        let mk = |s: usize| DesignPoint {
            design: Design::Hybrid(HybridConfig {
                transpose_radix: 8,
                stage_pipelining: s,
                port_group_width: 1,
            }),
            ..DesignPoint::fig6_step(Design::Medusa, 6)
        };
        assert!(peak_frequency(&mk(3)) >= peak_frequency(&mk(0)));
    }

    #[test]
    fn hierarchical_closes_timing_where_baseline_collapses() {
        use crate::interconnect::hierarchical::HierConfig;
        // 1024-bit region (steps 7..=10, 36..48 ports): the baseline is
        // barely usable; clustered transposers keep the wide wiring
        // local and only the registered trunk crosses the die, so the
        // hierarchy stays in fabric-clock territory.
        for step in 7usize..=10 {
            let b = DesignPoint::fig6_step(Design::Baseline, step);
            let hc = HierConfig { levels: 2, cluster_ports: 4, bypass_ports: 0, trunk_mhz: 300 };
            let h = DesignPoint { design: Design::Hierarchical(hc), ..b };
            assert!(peak_frequency(&b) <= 50, "step {step}");
            assert!(
                peak_frequency(&h) >= 150,
                "step {step}: hierarchical got {} MHz",
                peak_frequency(&h)
            );
        }
    }

    #[test]
    fn hierarchical_pays_the_trunk_mux_but_never_the_depth() {
        use crate::interconnect::hierarchical::HierConfig;
        let mk = |step: usize, levels: usize, cp: usize| {
            let hc = HierConfig { levels, cluster_ports: cp, bypass_ports: 0, trunk_mhz: 300 };
            DesignPoint { design: Design::Hierarchical(hc), ..DesignPoint::fig6_step(Design::Medusa, step) }
        };
        for step in [0usize, 2, 6, 10] {
            // One extra LUT level (the trunk distribution mux) means the
            // hierarchy never out-clocks the flat transposer it clusters.
            let m = DesignPoint::fig6_step(Design::Medusa, step);
            assert!(
                peak_frequency(&mk(step, 2, 4)) <= peak_frequency(&m),
                "step {step}"
            );
            // Trunk depth only adds pipeline registers, never logic
            // levels: the clock is identical at every legal depth.
            assert_eq!(peak_frequency(&mk(step, 2, 4)), peak_frequency(&mk(step, 4, 4)), "step {step}");
            // Smaller clusters localize harder; the clock never drops.
            if step >= 2 {
                // (step >= 2 has >= 16 ports, so clusters of 8 are legal)
                assert!(peak_frequency(&mk(step, 2, 4)) >= peak_frequency(&mk(step, 2, 8)), "step {step}");
            }
        }
    }

    #[test]
    fn axis_no_faster_than_baseline() {
        for step in [0usize, 1] {
            // (AXIS capped at 16 ports; steps 0-1 are 8/12 ports.)
            let a = DesignPoint::fig6_step(Design::Axis, step);
            let b = DesignPoint::fig6_step(Design::Baseline, step);
            assert!(peak_frequency(&a) <= peak_frequency(&b));
        }
    }
}
