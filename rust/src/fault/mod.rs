//! Deterministic fault injection (PR 6).
//!
//! A fault campaign is a *pre-scheduled*, seed-derived set of events —
//! never a coin flip taken mid-simulation — so injecting faults keeps
//! every standing determinism contract intact:
//!
//! * **data-independence**: whether a fault fires at cycle `c` depends
//!   only on `(spec, seed, c)`, never on payload words or occupancy, so
//!   elided-vs-full runs see the identical schedule;
//! * **leap-exactness**: every periodic window is closed-form
//!   ([`FaultWindow::active`], [`FaultWindow::count_active_in`]), so
//!   `System::try_leap_idle` can split a leapt span into in-window and
//!   out-of-window cycles arithmetically — fault edges feed the leap
//!   horizon exactly like staggered tenant starts;
//! * **seq-vs-par**: the schedule is owned by one `System`, which is
//!   single-threaded; parallel sweeps shard whole scenarios.
//!
//! Four fault classes are modelled (ISSUE 6):
//!
//! * **DRAM refresh/stall bursts** — periodic windows (mem-clock cycles)
//!   during which the controller freezes: no command accept, no line
//!   return, no write drain. Time still passes (`busy_until` elapses).
//! * **CDC stalls** — periodic windows (fabric cycles) during which the
//!   read-line crossing delivers nothing into the fabric.
//! * **Per-port-group slowdowns and wedges** — periodic windows (or a
//!   permanent wedge from a given cycle) during which one tenant's
//!   layer processor is not ticked at all. A wedge is what the
//!   watchdog/recovery layer exists to catch.
//! * **Line corruption (detect-only)** — every Nth delivered read line
//!   is tagged corrupt; a seeded parity bit decides whether the fabric's
//!   line parity *detects* it (`fault.detected`) or the flip lands on
//!   bits the parity misses (`fault.masked`). The payload is never
//!   mutated: the model measures detection coverage, not data loss, so
//!   golden verification and payload elision stay bit-identical.
//!
//! The watchdog/recovery half lives in `workload::engine` (progress
//! tracking, [`SimError::TenantStalled`], the degrade policy); the
//! injection points live in `coordinator::system` and
//! `dram::controller`.

use crate::config::Value;
use crate::util::Prng;
use anyhow::{anyhow, bail, ensure, Result};
use std::fmt;

/// Domain-separation keys so each component draws its window phase from
/// an independent stream of the campaign seed.
const DRAM_KEY: u64 = 0x6472_616d_5f66_6c74; // "dram_flt"
const CDC_KEY: u64 = 0x6364_635f_5f66_6c74; // "cdc__flt"
const LP_KEY: u64 = 0x6c70_5f5f_5f66_6c74; // "lp___flt"
const CORRUPT_KEY: u64 = 0x636f_7272_5f66_6c74; // "corr_flt"

/// Watchdog horizon used when the spec leaves `watchdog_cycles = 0`.
pub const DEFAULT_WATCHDOG_CYCLES: u64 = 10_000;

/// splitmix64 finalizer: cheap, well-mixed 64-bit hash for stream
/// keying and the corrupt detected/masked parity bit.
#[inline]
pub fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

/// What the engine does when the watchdog declares a tenant stalled.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultPolicy {
    /// Abort the run with [`SimError::TenantStalled`] (the default).
    #[default]
    Error,
    /// Quiesce and drain the stalled tenant's port group; the remaining
    /// tenants keep running (degraded goodput is reported).
    Degrade,
}

impl FaultPolicy {
    pub fn parse(s: &str) -> Option<FaultPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "error" | "abort" => Some(FaultPolicy::Error),
            "degrade" | "quiesce" => Some(FaultPolicy::Degrade),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultPolicy::Error => "error",
            FaultPolicy::Degrade => "degrade",
        }
    }
}

/// A periodic stall window with a seed-drawn phase: active on cycles
/// `c` where `(c - phase) mod period < len`. Everything about it is
/// closed-form, which is what keeps idle-edge leaping exact under
/// faults.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultWindow {
    /// First active cycle of each period, in `[0, period)`.
    pub phase: u64,
    pub period: u64,
    pub len: u64,
}

impl FaultWindow {
    /// Draw the phase for a `(period, len)` window from `prng`.
    /// Returns `None` when the window is disabled (`period == 0`).
    pub fn draw(prng: &mut Prng, period: u64, len: u64) -> Option<FaultWindow> {
        if period == 0 || len == 0 {
            return None;
        }
        Some(FaultWindow { phase: prng.below(period), period, len })
    }

    /// Is the window active at `cycle`?
    #[inline]
    pub fn active(&self, cycle: u64) -> bool {
        // phase < period, so the shift never underflows.
        (cycle + self.period - self.phase) % self.period < self.len
    }

    /// Number of active cycles in `[0, n)` (closed form).
    fn active_before(&self, n: u64) -> u64 {
        // Shift so windows start at multiples of `period`, then count
        // `y in [0, x)` with `y mod period < len`.
        let h = |x: u64| (x / self.period) * self.len + (x % self.period).min(self.len);
        let offset = self.period - self.phase;
        h(n + offset) - h(offset)
    }

    /// Number of active cycles in `[lo, hi)` (closed form). This is the
    /// leap-split primitive: a leapt span of idle controller edges
    /// divides into `count_active_in` refresh-stall cycles and the rest
    /// plain idle cycles.
    pub fn count_active_in(&self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        self.active_before(hi) - self.active_before(lo)
    }

    /// The first window-start cycle `>= cycle` (equals `cycle` when the
    /// window starts exactly there). Used to cap a leap so stepwise
    /// execution resumes before the window opens.
    pub fn next_start(&self, cycle: u64) -> u64 {
        let r = cycle % self.period;
        cycle + (self.phase + self.period - r) % self.period
    }
}

/// The user-facing fault campaign description: what a `[faults]`
/// scenario section or a `--faults=` CLI spec parses into, and what a
/// trace header records so capture/replay of faulty runs stays
/// bit-exact. All-zero (the default) means "no faults".
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultSpec {
    /// Campaign seed: draws every window phase and the corrupt parity
    /// stream (independent of the workload seed).
    pub seed: u64,
    /// DRAM refresh burst window, in memory-clock cycles.
    pub dram_refresh_period: u64,
    pub dram_refresh_len: u64,
    /// Read-line CDC stall window, in fabric cycles.
    pub cdc_stall_period: u64,
    pub cdc_stall_len: u64,
    /// Per-tenant layer-processor slowdown window, in fabric cycles
    /// (each tenant gets an independently-phased window).
    pub lp_slow_period: u64,
    pub lp_slow_len: u64,
    /// Tag every Nth delivered read line corrupt (0 = disabled).
    pub corrupt_period: u64,
    /// Permanently wedge this tenant's layer processor...
    pub wedge_tenant: Option<usize>,
    /// ...from this fabric cycle on.
    pub wedge_cycle: u64,
    /// Watchdog horizon in fabric cycles; 0 means
    /// [`DEFAULT_WATCHDOG_CYCLES`].
    pub watchdog_cycles: u64,
    /// What to do when the watchdog fires.
    pub policy: FaultPolicy,
}

impl FaultSpec {
    /// The empty campaign (injects nothing, watchdog disarmed).
    pub fn none() -> FaultSpec {
        FaultSpec::default()
    }

    /// True when this spec injects nothing — the watchdog is disarmed
    /// too, so a no-fault run is bit-identical to pre-fault builds.
    pub fn is_none(&self) -> bool {
        self.dram_refresh_period == 0
            && self.cdc_stall_period == 0
            && self.lp_slow_period == 0
            && self.corrupt_period == 0
            && self.wedge_tenant.is_none()
    }

    /// The effective watchdog horizon (fabric cycles).
    pub fn watchdog(&self) -> u64 {
        if self.watchdog_cycles == 0 {
            DEFAULT_WATCHDOG_CYCLES
        } else {
            self.watchdog_cycles
        }
    }

    /// Apply one parsed `faults.*` key (scenario files route their
    /// `[faults]` section here; trace headers route `faults.*` keys of
    /// `[header]`). Returns `Ok(false)` for keys outside the `faults.`
    /// namespace.
    pub fn apply_key(&mut self, key: &str, value: &Value) -> Result<bool> {
        let Some(k) = key.strip_prefix("faults.") else {
            return Ok(false);
        };
        let as_u64 = |v: &Value| -> Result<u64> { Ok(v.as_usize()? as u64) };
        match k {
            "seed" => self.seed = as_u64(value)?,
            "dram_refresh_period" => self.dram_refresh_period = as_u64(value)?,
            "dram_refresh_len" => self.dram_refresh_len = as_u64(value)?,
            "cdc_stall_period" => self.cdc_stall_period = as_u64(value)?,
            "cdc_stall_len" => self.cdc_stall_len = as_u64(value)?,
            "lp_slow_period" => self.lp_slow_period = as_u64(value)?,
            "lp_slow_len" => self.lp_slow_len = as_u64(value)?,
            "corrupt_period" => self.corrupt_period = as_u64(value)?,
            "wedge_tenant" => self.wedge_tenant = Some(value.as_usize()?),
            "wedge_cycle" => self.wedge_cycle = as_u64(value)?,
            "watchdog_cycles" => self.watchdog_cycles = as_u64(value)?,
            "policy" => {
                self.policy = FaultPolicy::parse(value.as_str()?).ok_or_else(|| {
                    anyhow!("faults.policy must be \"error\" or \"degrade\", got {value:?}")
                })?
            }
            _ => bail!("unknown faults key {key:?}"),
        }
        Ok(true)
    }

    /// Parse the compact CLI spec: comma-separated items of
    /// `dram_refresh=P/L`, `cdc=P/L`, `slow=P/L`, `corrupt=N`,
    /// `wedge=T@C`, `watchdog=N`, `seed=N`, `policy=error|degrade`.
    /// Example: `--faults=dram_refresh=512/16,cdc=256/8,corrupt=97`.
    pub fn parse_cli(spec: &str) -> Result<FaultSpec> {
        let mut out = FaultSpec::default();
        let num = |s: &str, what: &str| -> Result<u64> {
            s.parse::<u64>().map_err(|_| anyhow!("--faults: {what} must be an integer, got {s:?}"))
        };
        let pair = |s: &str, what: &str| -> Result<(u64, u64)> {
            let (p, l) = s
                .split_once('/')
                .ok_or_else(|| anyhow!("--faults: {what} wants PERIOD/LEN, got {s:?}"))?;
            Ok((num(p, what)?, num(l, what)?))
        };
        for item in spec.split(',').filter(|s| !s.is_empty()) {
            let (key, val) = item
                .split_once('=')
                .ok_or_else(|| anyhow!("--faults item {item:?}: expected key=value"))?;
            match key {
                "dram_refresh" => {
                    (out.dram_refresh_period, out.dram_refresh_len) = pair(val, key)?
                }
                "cdc" => (out.cdc_stall_period, out.cdc_stall_len) = pair(val, key)?,
                "slow" => (out.lp_slow_period, out.lp_slow_len) = pair(val, key)?,
                "corrupt" => out.corrupt_period = num(val, key)?,
                "wedge" => {
                    let (t, c) = val
                        .split_once('@')
                        .ok_or_else(|| anyhow!("--faults: wedge wants TENANT@CYCLE, got {val:?}"))?;
                    out.wedge_tenant = Some(num(t, "wedge tenant")? as usize);
                    out.wedge_cycle = num(c, "wedge cycle")?;
                }
                "watchdog" => out.watchdog_cycles = num(val, key)?,
                "seed" => out.seed = num(val, key)?,
                "policy" => {
                    out.policy = FaultPolicy::parse(val)
                        .ok_or_else(|| anyhow!("--faults: policy must be error or degrade"))?
                }
                _ => bail!("--faults: unknown item {key:?}"),
            }
        }
        out.validate(None)?;
        Ok(out)
    }

    /// Sanity-check the spec; `tenants`, when known, bounds
    /// `wedge_tenant`.
    pub fn validate(&self, tenants: Option<usize>) -> Result<()> {
        for (what, period, len) in [
            ("dram_refresh", self.dram_refresh_period, self.dram_refresh_len),
            ("cdc_stall", self.cdc_stall_period, self.cdc_stall_len),
            ("lp_slow", self.lp_slow_period, self.lp_slow_len),
        ] {
            ensure!(
                (period == 0) == (len == 0),
                "faults: {what} needs both period and len (got {period}/{len})"
            );
            ensure!(len <= period, "faults: {what} len {len} exceeds period {period}");
            // A stall window longer than the watchdog horizon would trip
            // the watchdog on a fault that is transient by construction.
            ensure!(
                len < self.watchdog(),
                "faults: {what} len {len} must stay below the watchdog horizon {}",
                self.watchdog()
            );
        }
        if let (Some(t), Some(n)) = (self.wedge_tenant, tenants) {
            ensure!(t < n, "faults: wedge_tenant {t} out of range (scenario has {n} tenants)");
        }
        Ok(())
    }

    /// Canonical `(key, value)` pairs for trace headers (TOML-subset
    /// syntax, fixed order). Empty for the no-fault spec, so non-faulty
    /// captures stay byte-identical to pre-fault builds.
    pub fn header_kv(&self) -> Vec<(&'static str, String)> {
        if self.is_none() {
            return Vec::new();
        }
        let mut kv: Vec<(&'static str, String)> = vec![
            ("faults.seed", self.seed.to_string()),
            ("faults.dram_refresh_period", self.dram_refresh_period.to_string()),
            ("faults.dram_refresh_len", self.dram_refresh_len.to_string()),
            ("faults.cdc_stall_period", self.cdc_stall_period.to_string()),
            ("faults.cdc_stall_len", self.cdc_stall_len.to_string()),
            ("faults.lp_slow_period", self.lp_slow_period.to_string()),
            ("faults.lp_slow_len", self.lp_slow_len.to_string()),
            ("faults.corrupt_period", self.corrupt_period.to_string()),
        ];
        if let Some(t) = self.wedge_tenant {
            kv.push(("faults.wedge_tenant", t.to_string()));
            kv.push(("faults.wedge_cycle", self.wedge_cycle.to_string()));
        }
        kv.push(("faults.watchdog_cycles", self.watchdog_cycles.to_string()));
        kv.push(("faults.policy", format!("\"{}\"", self.policy.name())));
        kv
    }
}

/// Per-delivery corrupt-line schedule: the `idx % period == phase`
/// deliveries carry a flipped line, and a seeded parity bit per event
/// decides detected-vs-masked. Counting deliveries is backend-safe:
/// line movement is bit-identical across all backends.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CorruptSchedule {
    pub period: u64,
    pub phase: u64,
    pub salt: u64,
    /// Read lines delivered into the fabric so far.
    pub delivered: u64,
}

impl CorruptSchedule {
    /// If delivery number `idx` is a corrupt event, returns
    /// `Some(detected)`.
    #[inline]
    pub fn event(&self, idx: u64) -> Option<bool> {
        if idx % self.period == self.phase {
            Some(mix64(self.salt ^ idx) & 1 == 0)
        } else {
            None
        }
    }
}

/// The materialized schedule a `System` executes: seed-drawn windows
/// per component, each from an independent PRNG stream. Per-tenant
/// streams are keyed by the port group's read base, so disjoint port
/// groups get independent fault streams no matter how tenants are
/// ordered (a property test locks this down).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct FaultState {
    pub spec: FaultSpec,
    pub dram_refresh: Option<FaultWindow>,
    pub cdc_stall: Option<FaultWindow>,
    /// One slowdown window per tenant/layer processor.
    pub lp_slow: Vec<Option<FaultWindow>>,
    pub corrupt: Option<CorruptSchedule>,
}

impl FaultState {
    /// The disabled schedule (what a fresh `System` carries).
    pub fn none() -> FaultState {
        FaultState {
            spec: FaultSpec::none(),
            dram_refresh: None,
            cdc_stall: None,
            lp_slow: Vec::new(),
            corrupt: None,
        }
    }

    /// Materialize a spec for a system whose tenants own port groups
    /// starting at `read_bases` (one entry per layer processor, in
    /// tenant order).
    pub fn build(spec: &FaultSpec, read_bases: &[usize]) -> Result<FaultState> {
        spec.validate(Some(read_bases.len()))?;
        let mut dram_prng = Prng::new(spec.seed ^ DRAM_KEY);
        let mut cdc_prng = Prng::new(spec.seed ^ CDC_KEY);
        let lp_slow = read_bases
            .iter()
            .map(|&base| {
                let mut prng = Prng::new(spec.seed ^ LP_KEY ^ mix64(base as u64));
                FaultWindow::draw(&mut prng, spec.lp_slow_period, spec.lp_slow_len)
            })
            .collect();
        let corrupt = (spec.corrupt_period > 0).then(|| {
            let mut prng = Prng::new(spec.seed ^ CORRUPT_KEY);
            CorruptSchedule {
                period: spec.corrupt_period,
                phase: prng.below(spec.corrupt_period),
                salt: prng.next_u64(),
                delivered: 0,
            }
        });
        Ok(FaultState {
            spec: spec.clone(),
            dram_refresh: FaultWindow::draw(
                &mut dram_prng,
                spec.dram_refresh_period,
                spec.dram_refresh_len,
            ),
            cdc_stall: FaultWindow::draw(&mut cdc_prng, spec.cdc_stall_period, spec.cdc_stall_len),
            lp_slow,
            corrupt,
        })
    }

    #[inline]
    pub fn is_none(&self) -> bool {
        self.spec.is_none()
    }

    /// DRAM refresh window active at this memory-clock cycle?
    #[inline]
    pub fn refresh_active(&self, mem_cycle: u64) -> bool {
        self.dram_refresh.is_some_and(|w| w.active(mem_cycle))
    }

    /// Refresh-stall cycles inside a leapt span of idle memory edges.
    pub fn refresh_count_in(&self, lo: u64, hi: u64) -> u64 {
        self.dram_refresh.map_or(0, |w| w.count_active_in(lo, hi))
    }

    /// Read-line CDC crossing stalled at this fabric cycle?
    #[inline]
    pub fn cdc_active(&self, fab_cycle: u64) -> bool {
        self.cdc_stall.is_some_and(|w| w.active(fab_cycle))
    }

    /// Tenant `t`'s layer processor inside its slowdown window?
    #[inline]
    pub fn lp_slow_active(&self, t: usize, fab_cycle: u64) -> bool {
        self.lp_slow.get(t).copied().flatten().is_some_and(|w| w.active(fab_cycle))
    }

    /// Tenant `t` permanently wedged at this fabric cycle?
    #[inline]
    pub fn wedged(&self, t: usize, fab_cycle: u64) -> bool {
        self.spec.wedge_tenant == Some(t) && fab_cycle >= self.spec.wedge_cycle
    }

    /// How far may an idle-edge leap extend from `fab_cycle` without
    /// skipping a fault edge that stepwise execution would observe?
    ///
    /// * `None`: leaping is disabled right now — a slowdown window is
    ///   open (suppressed ticks must be stepped so the per-cycle
    ///   `fault.lp_slowdown_cycles` accounting stays exact) or a wedge
    ///   is active (the watchdog must observe every edge).
    /// * `Some(k)`: leap at most `k` fabric cycles; the cap lands the
    ///   system exactly on the next slowdown-window start or wedge
    ///   cycle, like the staggered tenant-start cap in `drive()`.
    ///
    /// CDC windows never cap a leap: a leap only engages when the
    /// crossing channels are empty, and an empty crossing makes a CDC
    /// stall a no-op in stepwise execution too. DRAM refresh windows
    /// are split arithmetically by `try_leap_idle` instead.
    pub fn fabric_leap_cap(&self, fab_cycle: u64) -> Option<u64> {
        let mut cap = u64::MAX;
        if let Some(t) = self.spec.wedge_tenant {
            if self.wedged(t, fab_cycle) {
                return None;
            }
            cap = cap.min(self.spec.wedge_cycle - fab_cycle);
        }
        for w in self.lp_slow.iter().flatten() {
            if w.active(fab_cycle) {
                return None;
            }
            cap = cap.min(w.next_start(fab_cycle) - fab_cycle);
        }
        Some(cap)
    }
}

/// Typed simulation errors the engine can return instead of panicking
/// or hanging. Carried inside `anyhow::Error` (downcast to match).
#[derive(Debug)]
pub enum SimError {
    /// The per-tenant progress watchdog fired: `tenant` made no forward
    /// progress for the watchdog horizon ending at fabric cycle
    /// `cycle`. `state` is the tenant's engine state; `dump` the full
    /// per-tenant/per-domain state dump.
    TenantStalled { tenant: usize, cycle: u64, state: String, dump: String },
}

impl fmt::Display for SimError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SimError::TenantStalled { tenant, cycle, state, dump } => {
                write!(
                    f,
                    "tenant {tenant} stalled (no progress through fabric cycle {cycle}, \
                     state {state});\n{dump}"
                )
            }
        }
    }
}

impl std::error::Error for SimError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_active_matches_bruteforce_counts() {
        let w = FaultWindow { phase: 5, period: 16, len: 3 };
        for lo in 0..40u64 {
            for hi in lo..80 {
                let brute = (lo..hi).filter(|&c| w.active(c)).count() as u64;
                assert_eq!(w.count_active_in(lo, hi), brute, "[{lo}, {hi})");
            }
        }
    }

    #[test]
    fn window_next_start_is_first_start_at_or_after() {
        let w = FaultWindow { phase: 7, period: 12, len: 4 };
        for c in 0..60u64 {
            let s = w.next_start(c);
            assert!(s >= c);
            assert!(w.active(s), "start {s} must open a window");
            assert!(s == c || !(c..s).any(|x| x % w.period == w.phase));
        }
        // A window-start cycle maps to itself.
        assert_eq!(w.next_start(7), 7);
        assert_eq!(w.next_start(19), 19);
    }

    #[test]
    fn build_is_deterministic_and_keyed_by_read_base() {
        let mut spec = FaultSpec::none();
        spec.seed = 9;
        spec.lp_slow_period = 1 << 20;
        spec.lp_slow_len = 64;
        spec.dram_refresh_period = 4096;
        spec.dram_refresh_len = 32;
        let a = FaultState::build(&spec, &[0, 8]).unwrap();
        let b = FaultState::build(&spec, &[0, 8]).unwrap();
        assert_eq!(a, b, "same seed + groups must rebuild identically");
        // Same group (read base 0) keeps its stream when the *other*
        // group moves; the moved group draws a fresh phase.
        let c = FaultState::build(&spec, &[0, 16]).unwrap();
        assert_eq!(a.lp_slow[0], c.lp_slow[0]);
        assert_ne!(a.lp_slow[1], c.lp_slow[1]);
    }

    #[test]
    fn corrupt_schedule_splits_detected_and_masked() {
        let mut spec = FaultSpec::none();
        spec.corrupt_period = 3;
        let st = FaultState::build(&spec, &[0]).unwrap();
        let c = st.corrupt.unwrap();
        let events: Vec<bool> = (0..300).filter_map(|i| c.event(i)).collect();
        assert_eq!(events.len(), 100);
        assert!(events.iter().any(|&d| d) && events.iter().any(|&d| !d));
    }

    #[test]
    fn leap_cap_stops_at_slowdown_and_wedge_edges() {
        let mut spec = FaultSpec::none();
        spec.lp_slow_period = 100;
        spec.lp_slow_len = 10;
        spec.wedge_tenant = Some(0);
        spec.wedge_cycle = 1_000;
        let st = FaultState::build(&spec, &[0]).unwrap();
        let w = st.lp_slow[0].unwrap();
        // Just before a window: cap reaches exactly its start.
        let start = w.next_start(0);
        if start > 0 {
            assert_eq!(st.fabric_leap_cap(start - 1), Some(1));
        }
        // Inside a window: leaping disabled.
        assert_eq!(st.fabric_leap_cap(start), None);
        // After the wedge: leaping disabled for good.
        assert_eq!(st.fabric_leap_cap(1_000), None);
        assert_eq!(st.fabric_leap_cap(2_000), None);
    }

    #[test]
    fn cli_spec_round_trips_through_header_kv() {
        let spec =
            FaultSpec::parse_cli("dram_refresh=512/16,cdc=256/8,slow=1024/32,corrupt=97,wedge=1@5000,watchdog=20000,seed=3,policy=degrade")
                .unwrap();
        assert_eq!(spec.dram_refresh_period, 512);
        assert_eq!(spec.dram_refresh_len, 16);
        assert_eq!(spec.wedge_tenant, Some(1));
        assert_eq!(spec.policy, FaultPolicy::Degrade);
        // Feed the header kv back through apply_key: identical spec.
        let mut back = FaultSpec::none();
        for (k, v) in spec.header_kv() {
            let value = if let Some(inner) = v.strip_prefix('"') {
                Value::Str(inner.trim_end_matches('"').to_string())
            } else {
                Value::Int(v.parse().unwrap())
            };
            assert!(back.apply_key(k, &value).unwrap(), "{k} must be a faults key");
        }
        assert_eq!(back, spec);
    }

    #[test]
    fn bad_specs_are_rejected_with_typed_errors() {
        assert!(FaultSpec::parse_cli("dram_refresh=16").is_err(), "wants P/L");
        assert!(FaultSpec::parse_cli("bogus=1").is_err());
        assert!(FaultSpec::parse_cli("wedge=5").is_err(), "wants T@C");
        // len > period.
        assert!(FaultSpec::parse_cli("cdc=8/9").is_err());
        // Window longer than the watchdog horizon.
        assert!(FaultSpec::parse_cli("slow=50000/30000,watchdog=20000").is_err());
        // Wedge tenant bounded by the scenario's tenant count at build.
        let mut spec = FaultSpec::none();
        spec.wedge_tenant = Some(3);
        assert!(FaultState::build(&spec, &[0, 8]).is_err());
    }

    #[test]
    fn no_fault_spec_emits_no_header_keys() {
        assert!(FaultSpec::none().header_kv().is_empty());
        assert!(FaultSpec::none().is_none());
        let st = FaultState::none();
        assert!(st.is_none());
        assert_eq!(st.fabric_leap_cap(123), Some(u64::MAX));
        assert!(!st.refresh_active(5));
        assert!(!st.cdc_active(5));
        assert!(!st.lp_slow_active(0, 5));
        assert!(!st.wedged(0, 5));
    }

    #[test]
    fn sim_error_displays_and_downcasts() {
        let e = SimError::TenantStalled {
            tenant: 2,
            cycle: 4242,
            state: "Compute".into(),
            dump: "  lp2: phase=Compute".into(),
        };
        let any = anyhow::Error::new(e);
        let msg = format!("{any:#}");
        assert!(msg.contains("tenant 2 stalled"));
        assert!(msg.contains("4242"));
        assert!(any.downcast_ref::<SimError>().is_some());
    }
}
