//! `check(cfg, gen, prop)` — run `prop` over `cfg.cases` random inputs
//! drawn via `gen`; on failure, greedily shrink the failing input and
//! panic with the minimal case and the seed to reproduce it.

use crate::util::Prng;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrink_steps: usize,
}

/// Default seed: ASCII "MEDUSA!1".
pub const DEFAULT_SEED: u64 = 0x4d45_4455_5341_2131;

impl Default for Config {
    fn default() -> Self {
        // MEDUSA_PROP_CASES / MEDUSA_PROP_SEED override for soak runs.
        let cases = std::env::var("MEDUSA_PROP_CASES")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(64);
        let seed = std::env::var("MEDUSA_PROP_SEED")
            .ok()
            .and_then(|v| v.parse().ok())
            .unwrap_or(DEFAULT_SEED);
        Config { cases, seed, max_shrink_steps: 400 }
    }
}

/// Something that can generate values of `T` from randomness, and shrink
/// a failing value toward smaller cases.
pub trait Gen<T> {
    fn generate(&self, rng: &mut Prng) -> T;
    /// Candidate smaller values, most aggressive first. Empty = atomic.
    fn shrink(&self, value: &T) -> Vec<T>;
}

/// Run the property; panics with a reproducer on failure.
pub fn check<T, G, P>(cfg: Config, gen: &G, prop: P)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut rng = Prng::new(cfg.seed);
    for case in 0..cfg.cases {
        let input = gen.generate(&mut rng);
        if let Err(msg) = prop(&input) {
            let (min_input, min_msg, steps) =
                shrink_loop(cfg, gen, &prop, input.clone(), msg.clone());
            panic!(
                "property failed (case {case}/{}, seed {:#x}):\n  original: {input:?}\n  original error: {msg}\n  shrunk ({steps} steps): {min_input:?}\n  shrunk error: {min_msg}\n  reproduce with MEDUSA_PROP_SEED={}",
                cfg.cases, cfg.seed, cfg.seed
            );
        }
    }
}

fn shrink_loop<T, G, P>(
    cfg: Config,
    gen: &G,
    prop: &P,
    mut failing: T,
    mut msg: String,
) -> (T, String, usize)
where
    T: std::fmt::Debug + Clone,
    G: Gen<T>,
    P: Fn(&T) -> Result<(), String>,
{
    let mut steps = 0;
    'outer: while steps < cfg.max_shrink_steps {
        for cand in gen.shrink(&failing) {
            steps += 1;
            if steps >= cfg.max_shrink_steps {
                break 'outer;
            }
            if let Err(m) = prop(&cand) {
                failing = cand;
                msg = m;
                continue 'outer;
            }
        }
        break; // no candidate still fails: local minimum
    }
    (failing, msg, steps)
}

/// Generator for integers in an inclusive range, shrinking toward `lo`.
pub struct IntRange {
    pub lo: usize,
    pub hi: usize,
}

impl Gen<usize> for IntRange {
    fn generate(&self, rng: &mut Prng) -> usize {
        rng.range(self.lo, self.hi)
    }

    fn shrink(&self, value: &usize) -> Vec<usize> {
        let mut out = Vec::new();
        let v = *value;
        if v > self.lo {
            out.push(self.lo);
            out.push(self.lo + (v - self.lo) / 2);
            out.push(v - 1);
        }
        out.dedup();
        out.retain(|&c| c != v);
        out
    }
}

/// Generator pairing two independent generators.
pub struct Pair<A, B>(pub A, pub B);

impl<T, U, A: Gen<T>, B: Gen<U>> Gen<(T, U)> for Pair<A, B>
where
    T: Clone,
    U: Clone,
{
    fn generate(&self, rng: &mut Prng) -> (T, U) {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, value: &(T, U)) -> Vec<(T, U)> {
        let mut out = Vec::new();
        for a in self.0.shrink(&value.0) {
            out.push((a, value.1.clone()));
        }
        for b in self.1.shrink(&value.1) {
            out.push((value.0.clone(), b));
        }
        out
    }
}

/// Generator for vectors with length in `[0, max_len]`, shrinking by
/// halving the vector and shrinking elements.
pub struct VecOf<G> {
    pub elem: G,
    pub max_len: usize,
}

impl<T: Clone, G: Gen<T>> Gen<Vec<T>> for VecOf<G> {
    fn generate(&self, rng: &mut Prng) -> Vec<T> {
        let len = rng.range(0, self.max_len);
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, value: &Vec<T>) -> Vec<Vec<T>> {
        let mut out = Vec::new();
        if !value.is_empty() {
            out.push(value[..value.len() / 2].to_vec());
            out.push(value[1..].to_vec());
            let mut butlast = value.clone();
            butlast.pop();
            out.push(butlast);
            // Shrink the first element as a representative.
            for e in self.elem.shrink(&value[0]) {
                let mut v = value.clone();
                v[0] = e;
                out.push(v);
            }
        }
        out
    }
}

/// Round-trip properties for the Medusa networks on randomized irregular
/// geometries (PR 1 satellite): read and write transfers must match the
/// golden transpose — each port sees exactly the words of its own lines,
/// in order — for non-power-of-two port counts and varied `max_burst`,
/// and the answer must be identical whether the network is driven
/// through the boxed `dyn` path or the statically dispatched
/// `AnyReadNetwork`/`AnyWriteNetwork` path the system core uses.
#[cfg(test)]
mod medusa_roundtrip_props {
    use super::{check, Config, Gen};
    use crate::interconnect::harness::{drive_read, drive_write_streams, gen_lines, gen_write_streams};
    use crate::interconnect::{
        build_read_network, build_write_network, AnyReadNetwork, AnyWriteNetwork, Design,
    };
    use crate::types::{Geometry, Word};
    use crate::util::Prng;

    #[derive(Clone, Debug)]
    struct IrregularCase {
        geom: Geometry,
        lines: usize,
        seed: u64,
    }

    struct IrregularGen;

    impl Gen<IrregularCase> for IrregularGen {
        fn generate(&self, rng: &mut Prng) -> IrregularCase {
            let n = 1usize << rng.range(1, 5); // words/line in {2,...,32}
            let w_acc = 16;
            // Deliberately skew toward irregular (non-power-of-two) port
            // counts, the §III-G case the satellite calls out.
            let ports = rng.range(1, n);
            let max_burst = [1usize, 2, 3, 5, 8, 32][rng.range(0, 5)];
            IrregularCase {
                geom: Geometry { w_line: n * w_acc, w_acc, read_ports: ports, write_ports: ports, max_burst },
                lines: rng.range(1, 64),
                seed: rng.next_u64(),
            }
        }

        fn shrink(&self, c: &IrregularCase) -> Vec<IrregularCase> {
            let mut out = Vec::new();
            if c.lines > 1 {
                out.push(IrregularCase { lines: c.lines / 2, ..c.clone() });
            }
            if c.geom.read_ports > 1 {
                let mut g = c.geom;
                g.read_ports -= 1;
                g.write_ports -= 1;
                out.push(IrregularCase { geom: g, ..c.clone() });
            }
            if c.geom.max_burst > 1 {
                let mut g = c.geom;
                g.max_burst = 1;
                out.push(IrregularCase { geom: g, ..c.clone() });
            }
            out
        }
    }

    fn cfg() -> Config {
        Config { cases: 48, ..Config::default() }
    }

    #[test]
    fn prop_read_roundtrip_matches_golden_transpose_both_paths() {
        check(cfg(), &IrregularGen, |c: &IrregularCase| {
            let lines = gen_lines(&c.geom, c.lines, c.seed);
            // Golden transpose: port p receives its own lines' words in
            // order.
            let golden: Vec<Vec<Word>> = (0..c.geom.read_ports)
                .map(|p| {
                    lines
                        .iter()
                        .filter(|l| l.port == p)
                        .flat_map(|l| l.line.words().to_vec())
                        .collect()
                })
                .collect();
            // Old path: boxed trait object.
            let mut boxed = build_read_network(Design::Medusa, c.geom);
            let (_, got_dyn) = drive_read(boxed.as_mut(), &lines, true);
            // New path: statically dispatched enum.
            let mut any = AnyReadNetwork::build(Design::Medusa, c.geom);
            let (_, got_any) = drive_read(&mut any, &lines, true);
            if got_dyn != golden {
                return Err(format!("dyn path diverged from golden transpose ({c:?})"));
            }
            if got_any != golden {
                return Err(format!("enum path diverged from golden transpose ({c:?})"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_write_roundtrip_matches_golden_transpose_both_paths() {
        check(cfg(), &IrregularGen, |c: &IrregularCase| {
            let n = c.geom.words_per_line();
            let lines_per_port = (c.lines / c.geom.write_ports).clamp(1, 16);
            let streams = gen_write_streams(&c.geom, lines_per_port, c.seed);
            let mut boxed = build_write_network(Design::Medusa, c.geom);
            let (_, got_dyn) = drive_write_streams(boxed.as_mut(), &streams, true);
            let mut any = AnyWriteNetwork::build(Design::Medusa, c.geom);
            let (_, got_any) = drive_write_streams(&mut any, &streams, true);
            for p in 0..c.geom.write_ports {
                // Golden: the pushed stream, re-lined in order.
                let flat_dyn: Vec<Word> =
                    got_dyn[p].iter().flat_map(|l| l.words().to_vec()).collect();
                let flat_any: Vec<Word> =
                    got_any[p].iter().flat_map(|l| l.words().to_vec()).collect();
                if flat_dyn != streams[p] {
                    return Err(format!("dyn write path port {p} diverged ({c:?})"));
                }
                if flat_any != streams[p] {
                    return Err(format!("enum write path port {p} diverged ({c:?})"));
                }
                if got_dyn[p].iter().any(|l| l.num_words() != n) {
                    return Err(format!("port {p} emitted a short line ({c:?})"));
                }
            }
            Ok(())
        });
    }
}

/// Hybrid-family properties (PR 4 satellite): across randomized
/// irregular geometries, the radix-2 and radix-N family endpoints must
/// be *indistinguishable* from the baseline and Medusa networks — same
/// words, same per-port order, same cycle counts under the shared
/// saturation harness — and every intermediate radix must move data
/// with perfect integrity in both directions.
#[cfg(test)]
mod hybrid_family_props {
    use super::{check, Config, Gen};
    use crate::interconnect::harness::{
        drive_read, drive_write_streams, gen_lines, gen_write_streams,
    };
    use crate::interconnect::hybrid::HybridConfig;
    use crate::interconnect::{build_read_network, build_write_network, Design};
    use crate::types::{Geometry, Word};
    use crate::util::Prng;

    #[derive(Clone, Debug)]
    struct FamilyCase {
        geom: Geometry,
        lines: usize,
        seed: u64,
    }

    struct FamilyGen;

    impl Gen<FamilyCase> for FamilyGen {
        fn generate(&self, rng: &mut Prng) -> FamilyCase {
            // N in {4, 8, 16, 32} so both endpoints are distinct designs
            // and intermediate radices exist from N = 8 up; port counts
            // skew irregular (§III-G).
            let n = 1usize << rng.range(2, 5);
            let w_acc = 16;
            let ports = rng.range(1, n);
            let max_burst = [1usize, 2, 3, 5, 8][rng.range(0, 4)];
            FamilyCase {
                geom: Geometry {
                    w_line: n * w_acc,
                    w_acc,
                    read_ports: ports,
                    write_ports: ports,
                    max_burst,
                },
                lines: rng.range(1, 48),
                seed: rng.next_u64(),
            }
        }

        fn shrink(&self, c: &FamilyCase) -> Vec<FamilyCase> {
            let mut out = Vec::new();
            if c.lines > 1 {
                out.push(FamilyCase { lines: c.lines / 2, ..c.clone() });
            }
            if c.geom.read_ports > 1 {
                let mut g = c.geom;
                g.read_ports -= 1;
                g.write_ports -= 1;
                out.push(FamilyCase { geom: g, ..c.clone() });
            }
            if c.geom.max_burst > 1 {
                let mut g = c.geom;
                g.max_burst = 1;
                out.push(FamilyCase { geom: g, ..c.clone() });
            }
            out
        }
    }

    fn hybrid(r: usize) -> Design {
        Design::Hybrid(HybridConfig { transpose_radix: r, ..HybridConfig::default() })
    }

    /// Valid intermediate radices for `n` words per line.
    fn intermediates(n: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut r = 4usize;
        while r < n {
            out.push(r);
            r *= 2;
        }
        out
    }

    fn cfg() -> Config {
        Config { cases: 40, ..Config::default() }
    }

    #[test]
    fn prop_read_endpoints_indistinguishable_from_endpoint_designs() {
        check(cfg(), &FamilyGen, |c: &FamilyCase| {
            let n = c.geom.words_per_line();
            let lines = gen_lines(&c.geom, c.lines, c.seed);
            for (radix, partner) in [(2usize, Design::Baseline), (n, Design::Medusa)] {
                let mut h = build_read_network(hybrid(radix), c.geom);
                let (hres, hgot) = drive_read(h.as_mut(), &lines, true);
                let mut p = build_read_network(partner, c.geom);
                let (pres, pgot) = drive_read(p.as_mut(), &lines, true);
                if hgot != pgot {
                    return Err(format!("radix {radix} data diverged from {partner:?} ({c:?})"));
                }
                if (hres.cycles, hres.lines_moved, hres.words_moved)
                    != (pres.cycles, pres.lines_moved, pres.words_moved)
                {
                    return Err(format!(
                        "radix {radix} timing diverged from {partner:?}: {hres:?} vs {pres:?} ({c:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_write_endpoints_indistinguishable_from_endpoint_designs() {
        check(cfg(), &FamilyGen, |c: &FamilyCase| {
            let n = c.geom.words_per_line();
            let lines_per_port = (c.lines / c.geom.write_ports).clamp(1, 12);
            let streams = gen_write_streams(&c.geom, lines_per_port, c.seed);
            for (radix, partner) in [(2usize, Design::Baseline), (n, Design::Medusa)] {
                let mut h = build_write_network(hybrid(radix), c.geom);
                let (hres, hgot) = drive_write_streams(h.as_mut(), &streams, true);
                let mut p = build_write_network(partner, c.geom);
                let (pres, pgot) = drive_write_streams(p.as_mut(), &streams, true);
                if hgot != pgot {
                    return Err(format!("radix {radix} data diverged from {partner:?} ({c:?})"));
                }
                if (hres.cycles, hres.lines_moved) != (pres.cycles, pres.lines_moved) {
                    return Err(format!(
                        "radix {radix} timing diverged from {partner:?} ({c:?})"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_intermediate_radices_preserve_data_integrity() {
        check(cfg(), &FamilyGen, |c: &FamilyCase| {
            let n = c.geom.words_per_line();
            let lines = gen_lines(&c.geom, c.lines, c.seed);
            let golden: Vec<Vec<Word>> = (0..c.geom.read_ports)
                .map(|p| {
                    lines
                        .iter()
                        .filter(|l| l.port == p)
                        .flat_map(|l| l.line.words().to_vec())
                        .collect()
                })
                .collect();
            let lines_per_port = (c.lines / c.geom.write_ports).clamp(1, 12);
            let streams = gen_write_streams(&c.geom, lines_per_port, c.seed ^ 0xdead);
            for r in intermediates(n) {
                let mut net = build_read_network(hybrid(r), c.geom);
                let (_, got) = drive_read(net.as_mut(), &lines, true);
                if got != golden {
                    return Err(format!("radix {r} read diverged from golden transpose ({c:?})"));
                }
                let mut wnet = build_write_network(hybrid(r), c.geom);
                let (_, wgot) = drive_write_streams(wnet.as_mut(), &streams, true);
                for p in 0..c.geom.write_ports {
                    let flat: Vec<Word> =
                        wgot[p].iter().flat_map(|l| l.words().to_vec()).collect();
                    if flat != streams[p] {
                        return Err(format!("radix {r} write port {p} diverged ({c:?})"));
                    }
                }
            }
            Ok(())
        });
    }
}

/// Fast-backend properties (PR 5 satellite): over randomized irregular
/// geometries, (a) payload-elided networks of every family must move
/// the same *amount* of traffic in the same number of cycles as their
/// full-payload twins under the shared saturation harness, emitting
/// correctly sized shadow lines; and (b) `Scheduler::leap` must be
/// bit-identical to the equivalent number of `step()` calls for random
/// clock pairs, warm-ups, and spans.
#[cfg(test)]
mod fast_backend_props {
    use super::{check, Config, Gen};
    use crate::config::PayloadMode;
    use crate::interconnect::harness::{drive_read, drive_write_streams, gen_lines, gen_write_streams};
    use crate::interconnect::hybrid::HybridConfig;
    use crate::interconnect::{build_read_network, build_write_network, Design};
    use crate::sim::{ClockDomain, Scheduler};
    use crate::types::Geometry;
    use crate::util::Prng;

    #[derive(Clone, Debug)]
    struct ElisionCase {
        geom: Geometry,
        lines: usize,
        seed: u64,
    }

    struct ElisionGen;

    impl Gen<ElisionCase> for ElisionGen {
        fn generate(&self, rng: &mut Prng) -> ElisionCase {
            let n = 1usize << rng.range(2, 5); // N in {4, 8, 16, 32}
            let w_acc = 16;
            let ports = rng.range(1, n);
            let max_burst = [1usize, 2, 3, 5, 8][rng.range(0, 4)];
            ElisionCase {
                geom: Geometry {
                    w_line: n * w_acc,
                    w_acc,
                    read_ports: ports,
                    write_ports: ports,
                    max_burst,
                },
                lines: rng.range(1, 48),
                seed: rng.next_u64(),
            }
        }

        fn shrink(&self, c: &ElisionCase) -> Vec<ElisionCase> {
            let mut out = Vec::new();
            if c.lines > 1 {
                out.push(ElisionCase { lines: c.lines / 2, ..c.clone() });
            }
            if c.geom.read_ports > 1 {
                let mut g = c.geom;
                g.read_ports -= 1;
                g.write_ports -= 1;
                out.push(ElisionCase { geom: g, ..c.clone() });
            }
            out
        }
    }

    /// Every family on this geometry: both endpoints plus one genuine
    /// intermediate radix when N allows one.
    fn family(geom: &Geometry) -> Vec<Design> {
        let n = geom.words_per_line();
        let mut out = vec![Design::Baseline, Design::Medusa];
        if n >= 8 {
            out.push(Design::Hybrid(HybridConfig { transpose_radix: 4, ..Default::default() }));
        }
        if geom.read_ports <= 16 {
            out.push(Design::Axis);
        }
        out
    }

    fn cfg() -> Config {
        Config { cases: 40, ..Config::default() }
    }

    #[test]
    fn prop_elided_read_networks_keep_full_mode_timing() {
        check(cfg(), &ElisionGen, |c: &ElisionCase| {
            let lines = gen_lines(&c.geom, c.lines, c.seed);
            // What an elided-mode controller would deliver: the same
            // port sequence, header-only shadows instead of payload.
            let shadows: Vec<crate::types::TaggedLine> = lines
                .iter()
                .map(|tl| crate::types::TaggedLine {
                    port: tl.port,
                    line: crate::types::Line::elided(c.geom.words_per_line()),
                })
                .collect();
            for design in family(&c.geom) {
                let mut full = build_read_network(design, c.geom);
                let (fres, _) = drive_read(full.as_mut(), &lines, false);
                let mut elided = build_read_network(design, c.geom);
                elided.set_payload_mode(PayloadMode::Elided);
                let (eres, egot) = drive_read(elided.as_mut(), &shadows, true);
                if (fres.cycles, fres.lines_moved, fres.words_moved)
                    != (eres.cycles, eres.lines_moved, eres.words_moved)
                {
                    return Err(format!(
                        "{design:?}: elided read timing diverged ({fres:?} vs {eres:?}, {c:?})"
                    ));
                }
                // Elided ports deliver exactly the scheduled number of
                // shadow words (all zeros by definition).
                let total: usize = egot.iter().map(|v| v.len()).sum();
                if total != lines.len() * c.geom.words_per_line() {
                    return Err(format!("{design:?}: wrong shadow word count ({c:?})"));
                }
                if egot.iter().flatten().any(|&w| w != 0) {
                    return Err(format!("{design:?}: nonzero shadow word ({c:?})"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_elided_write_networks_keep_full_mode_timing() {
        check(cfg(), &ElisionGen, |c: &ElisionCase| {
            let n = c.geom.words_per_line();
            let lines_per_port = (c.lines / c.geom.write_ports).clamp(1, 12);
            let streams = gen_write_streams(&c.geom, lines_per_port, c.seed);
            for design in family(&c.geom) {
                let mut full = build_write_network(design, c.geom);
                let (fres, _) = drive_write_streams(full.as_mut(), &streams, false);
                let mut elided = build_write_network(design, c.geom);
                elided.set_payload_mode(PayloadMode::Elided);
                let (eres, egot) = drive_write_streams(elided.as_mut(), &streams, true);
                if (fres.cycles, fres.lines_moved) != (eres.cycles, eres.lines_moved) {
                    return Err(format!(
                        "{design:?}: elided write timing diverged ({fres:?} vs {eres:?}, {c:?})"
                    ));
                }
                for (p, got) in egot.iter().enumerate() {
                    if got.len() != lines_per_port {
                        return Err(format!("{design:?} port {p}: wrong line count ({c:?})"));
                    }
                    if got.iter().any(|l| !l.is_elided() || l.num_words() != n) {
                        return Err(format!(
                            "{design:?} port {p}: expected {n}-word shadows ({c:?})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[derive(Clone, Debug)]
    struct LeapCase {
        /// 2–4 clock rates (the N-domain generalization: fabric+mem,
        /// plus trunk and one more when present).
        mhz: Vec<usize>,
        warm: usize,
        k: usize,
    }

    struct LeapGen;

    impl Gen<LeapCase> for LeapGen {
        fn generate(&self, rng: &mut Prng) -> LeapCase {
            let n = rng.range(2, 4);
            LeapCase {
                mhz: (0..n).map(|_| rng.range(25, 450)).collect(),
                warm: rng.range(0, 32),
                k: rng.range(1, 5000),
            }
        }

        fn shrink(&self, c: &LeapCase) -> Vec<LeapCase> {
            let mut out = Vec::new();
            if c.k > 1 {
                out.push(LeapCase { k: c.k / 2, ..c.clone() });
                out.push(LeapCase { k: 1, ..c.clone() });
            }
            if c.warm > 0 {
                out.push(LeapCase { warm: 0, ..c.clone() });
            }
            if c.mhz.len() > 2 {
                out.push(LeapCase { mhz: c.mhz[..c.mhz.len() - 1].to_vec(), ..c.clone() });
            }
            out
        }
    }

    #[test]
    fn prop_scheduler_leap_equals_stepping() {
        const NAMES: [&str; 4] = ["a", "b", "c", "d"];
        check(Config { cases: 96, ..Config::default() }, &LeapGen, |c: &LeapCase| {
            for domain in 0..c.mhz.len() {
                let mk = || {
                    let mut s = Scheduler::new(
                        c.mhz
                            .iter()
                            .enumerate()
                            .map(|(i, &m)| ClockDomain::from_mhz(NAMES[i], m as f64))
                            .collect(),
                    );
                    for _ in 0..c.warm {
                        s.step();
                    }
                    s
                };
                let mut leaped = mk();
                let mut stepped = mk();
                let leap = leaped
                    .leap(domain, c.k as u64, u64::MAX)
                    .ok_or_else(|| format!("leap refused ({c:?})"))?;
                if leap.fired[domain] != c.k as u64 {
                    return Err(format!("leap truncated without budget ({c:?})"));
                }
                for _ in 0..leap.steps {
                    stepped.step();
                }
                if leaped.now_fs() != stepped.now_fs() {
                    return Err(format!(
                        "now_fs {} != {} ({c:?}, domain {domain})",
                        leaped.now_fs(),
                        stepped.now_fs()
                    ));
                }
                for d in 0..c.mhz.len() {
                    if leaped.domain(d).cycles != stepped.domain(d).cycles {
                        return Err(format!("domain {d} cycle drift ({c:?})"));
                    }
                }
                // The subsequent edge stream must continue in lockstep.
                for _ in 0..4 {
                    if leaped.step() != stepped.step() || leaped.now_fs() != stepped.now_fs() {
                        return Err(format!("post-leap edge stream diverged ({c:?})"));
                    }
                }
            }
            Ok(())
        });
    }
}

/// Workload-math properties (PR 3 satellite): layer word counts and MAC
/// counts must agree with closed-form recomputation for randomized
/// layers of every kind, and every zoo network must chain shape-exactly.
#[cfg(test)]
mod workload_math_props {
    use super::{check, Config, Gen};
    use crate::accel::dnn::ConvLayer;
    use crate::util::Prng;
    use crate::workload::graph::Layer;
    use crate::workload::zoo;

    struct LayerGen;

    impl Gen<Layer> for LayerGen {
        fn generate(&self, rng: &mut Prng) -> Layer {
            match rng.range(0, 2) {
                0 => {
                    // Grouped conv with groups dividing both channel counts.
                    let groups = 1usize << rng.range(0, 2); // 1, 2, 4
                    let in_c = groups * rng.range(1, 4);
                    let out_c = groups * rng.range(1, 4);
                    let k = [1usize, 3, 5][rng.range(0, 2)];
                    let stride = rng.range(1, 2);
                    let pad = rng.range(0, k / 2);
                    let hw = rng.range(k.max(4), 12);
                    Layer::Conv {
                        conv: ConvLayer {
                            name: "prop-conv",
                            in_c,
                            in_h: hw,
                            in_w: hw,
                            out_c,
                            k,
                            stride,
                            pad,
                            relu: rng.chance(0.5),
                        },
                        groups,
                    }
                }
                1 => Layer::Gemm {
                    name: "prop-gemm",
                    m: rng.range(1, 32),
                    k: rng.range(1, 32),
                    n: rng.range(1, 32),
                    relu: rng.chance(0.5),
                },
                _ => Layer::Add {
                    name: "prop-add",
                    c: rng.range(1, 8),
                    h: rng.range(1, 8),
                    w: rng.range(1, 8),
                    relu: rng.chance(0.5),
                },
            }
        }

        fn shrink(&self, _value: &Layer) -> Vec<Layer> {
            Vec::new()
        }
    }

    #[test]
    fn prop_layer_word_and_mac_counts_match_closed_form() {
        check(Config { cases: 128, ..Config::default() }, &LayerGen, |l: &Layer| {
            l.validate().map_err(|e| e.to_string())?;
            let (ic, ih, iw) = l.in_shape();
            let (oc, oh, ow) = l.out_shape();
            if l.ifmap_words() != ic * ih * iw {
                return Err(format!("ifmap_words {} != {}", l.ifmap_words(), ic * ih * iw));
            }
            if l.ofmap_words() != oc * oh * ow {
                return Err(format!("ofmap_words {} != {}", l.ofmap_words(), oc * oh * ow));
            }
            match l {
                Layer::Conv { conv, groups } => {
                    let icg = conv.in_c / groups;
                    let want_w = conv.out_c * icg * conv.k * conv.k + conv.out_c;
                    if l.weight_words() != want_w {
                        return Err(format!("weight_words {} != {want_w}", l.weight_words()));
                    }
                    // Closed-form spatial arithmetic.
                    let want_oh = (conv.in_h + 2 * conv.pad - conv.k) / conv.stride + 1;
                    if oh != want_oh {
                        return Err(format!("out_h {oh} != {want_oh}"));
                    }
                    let want_macs = (oc * oh * ow * icg * conv.k * conv.k) as u64;
                    if l.macs() != want_macs {
                        return Err(format!("macs {} != {want_macs}", l.macs()));
                    }
                    // Dense macs scale exactly by the group count.
                    let dense = Layer::Conv { conv: *conv, groups: 1 };
                    if dense.macs() != l.macs() * *groups as u64 {
                        return Err("grouping must divide macs exactly".into());
                    }
                }
                Layer::Gemm { m, k, n, .. } => {
                    if l.weight_words() != n * k + n {
                        return Err("gemm weight_words".into());
                    }
                    if l.macs() != (m * k * n) as u64 {
                        return Err("gemm macs".into());
                    }
                    // Lowering to a 1x1 conv preserves every count.
                    let c = l.lowered_conv();
                    if c.ifmap_words() != l.ifmap_words()
                        || c.ofmap_words() != l.ofmap_words()
                        || c.macs() != l.macs()
                    {
                        return Err("gemm lowering changed counts".into());
                    }
                }
                Layer::Add { c, h, w, .. } => {
                    if l.weight_words() != 0 || l.macs() != (c * h * w) as u64 {
                        return Err("add counts".into());
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_zoo_networks_chain_shapes_exactly() {
        // Not randomized, but uses the same harness idiom: every zoo
        // network's node shapes must chain, and its aggregate words/macs
        // must equal the per-node closed-form sums.
        for net in zoo::all() {
            net.validate().unwrap_or_else(|e| panic!("{}: {e}", net.name));
            let total: u64 = net.nodes.iter().map(|n| n.layer.macs()).sum();
            assert_eq!(net.total_macs(), total, "{}", net.name);
            for node in &net.nodes {
                let (c, h, w) = node.layer.out_shape();
                assert_eq!(node.layer.ofmap_words(), c * h * w, "{}", node.layer.name());
            }
        }
    }
}

#[cfg(test)]
mod fault_schedule_props {
    use super::{check, Config, Gen};
    use crate::fault::{FaultSpec, FaultState, FaultWindow};
    use crate::util::Prng;

    /// A randomized fault campaign plus the port-group layout it lands
    /// on: `tenants` groups of `ports_per_tenant` read ports each.
    #[derive(Clone, Debug)]
    struct CampaignCase {
        seed: u64,
        dram: (u64, u64),
        cdc: (u64, u64),
        slow: (u64, u64),
        corrupt: u64,
        ports_per_tenant: usize,
        tenants: usize,
    }

    impl CampaignCase {
        fn spec(&self) -> FaultSpec {
            FaultSpec {
                seed: self.seed,
                dram_refresh_period: self.dram.0,
                dram_refresh_len: self.dram.1,
                cdc_stall_period: self.cdc.0,
                cdc_stall_len: self.cdc.1,
                lp_slow_period: self.slow.0,
                lp_slow_len: self.slow.1,
                corrupt_period: self.corrupt,
                ..FaultSpec::none()
            }
        }

        fn bases(&self) -> Vec<usize> {
            (0..self.tenants).map(|t| t * self.ports_per_tenant).collect()
        }
    }

    struct CampaignGen;

    /// A `(period, len)` pair with `1 <= len <= period`, or `(0, 0)`
    /// (channel disabled) one time in four.
    fn gen_window(rng: &mut Prng) -> (u64, u64) {
        if rng.below(4) == 0 {
            return (0, 0);
        }
        let period = rng.range(4, 200) as u64;
        let len = rng.range(1, period as usize) as u64;
        (period, len)
    }

    impl Gen<CampaignCase> for CampaignGen {
        fn generate(&self, rng: &mut Prng) -> CampaignCase {
            CampaignCase {
                seed: rng.next_u64(),
                dram: gen_window(rng),
                cdc: gen_window(rng),
                slow: gen_window(rng),
                corrupt: if rng.below(4) == 0 { 0 } else { rng.range(1, 32) as u64 },
                ports_per_tenant: rng.range(1, 8),
                tenants: rng.range(1, 4),
            }
        }

        fn shrink(&self, c: &CampaignCase) -> Vec<CampaignCase> {
            let mut out = Vec::new();
            if c.tenants > 1 {
                out.push(CampaignCase { tenants: c.tenants - 1, ..c.clone() });
            }
            if c.dram != (0, 0) {
                out.push(CampaignCase { dram: (0, 0), ..c.clone() });
            }
            if c.cdc != (0, 0) {
                out.push(CampaignCase { cdc: (0, 0), ..c.clone() });
            }
            if c.slow != (0, 0) {
                out.push(CampaignCase { slow: (0, 0), ..c.clone() });
            }
            if c.corrupt != 0 {
                out.push(CampaignCase { corrupt: 0, ..c.clone() });
            }
            out
        }
    }

    fn cfg() -> Config {
        Config { cases: 48, ..Config::default() }
    }

    #[test]
    fn prop_same_seed_builds_identical_schedule() {
        // The determinism contract the trace header stands on: a spec
        // plus a port layout fully determines the materialized schedule.
        check(cfg(), &CampaignGen, |c: &CampaignCase| {
            let spec = c.spec();
            let a = FaultState::build(&spec, &c.bases()).map_err(|e| e.to_string())?;
            let b = FaultState::build(&spec, &c.bases()).map_err(|e| e.to_string())?;
            if a != b {
                return Err(format!("same seed built different schedules:\n{a:?}\nvs\n{b:?}"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_port_group_streams_are_independent() {
        check(cfg(), &CampaignGen, |c: &CampaignCase| {
            let spec = c.spec();
            let bases = c.bases();
            let full = FaultState::build(&spec, &bases).map_err(|e| e.to_string())?;
            // A tenant's slowdown window is keyed by its read base alone,
            // so building the schedule for that tenant in isolation must
            // reproduce its window exactly — adding or removing other
            // tenants cannot perturb the draw.
            for (t, &base) in bases.iter().enumerate() {
                let solo = FaultState::build(&spec, &[base]).map_err(|e| e.to_string())?;
                if solo.lp_slow[0] != full.lp_slow[t] {
                    return Err(format!(
                        "tenant {t} (base {base}) window depends on other tenants: \
                         {:?} vs {:?}",
                        solo.lp_slow[0], full.lp_slow[t]
                    ));
                }
            }
            // The shared streams (DRAM refresh, CDC, corruption) must not
            // move when the port-group layout does.
            let relaid: Vec<usize> = bases.iter().map(|b| b + 64).collect();
            let moved = FaultState::build(&spec, &relaid).map_err(|e| e.to_string())?;
            if moved.dram_refresh != full.dram_refresh
                || moved.cdc_stall != full.cdc_stall
                || moved.corrupt != full.corrupt
            {
                return Err("shared fault streams moved with the port layout".into());
            }
            Ok(())
        });
    }

    /// A single window plus a probe range for the closed-form checks.
    #[derive(Clone, Debug)]
    struct WindowCase {
        phase: u64,
        period: u64,
        len: u64,
        lo: u64,
        span: u64,
    }

    struct WindowGen;

    impl Gen<WindowCase> for WindowGen {
        fn generate(&self, rng: &mut Prng) -> WindowCase {
            let period = rng.range(1, 96) as u64;
            let len = rng.range(1, period as usize) as u64;
            WindowCase {
                phase: rng.below(period),
                period,
                len,
                lo: rng.below(4096),
                span: rng.below(512),
            }
        }

        fn shrink(&self, c: &WindowCase) -> Vec<WindowCase> {
            let mut out = Vec::new();
            if c.span > 0 {
                out.push(WindowCase { span: c.span / 2, ..c.clone() });
            }
            if c.lo > 0 {
                out.push(WindowCase { lo: c.lo / 2, ..c.clone() });
            }
            out
        }
    }

    #[test]
    fn prop_window_closed_form_matches_stepwise_count() {
        // `count_active_in` is the leap-split primitive: it must agree
        // with walking the same span cycle by cycle, and `next_start`
        // must be the first window start at or after the probe.
        check(cfg(), &WindowGen, |c: &WindowCase| {
            let w = FaultWindow { phase: c.phase, period: c.period, len: c.len };
            let (lo, hi) = (c.lo, c.lo + c.span);
            let brute = (lo..hi).filter(|&cy| w.active(cy)).count() as u64;
            let closed = w.count_active_in(lo, hi);
            if closed != brute {
                return Err(format!("count_active_in({lo},{hi}) = {closed}, stepwise = {brute}"));
            }
            let start = w.next_start(lo);
            if start < lo || start % w.period != w.phase {
                return Err(format!("next_start({lo}) = {start} is not a window start"));
            }
            if (lo..start).any(|cy| cy % w.period == w.phase) {
                return Err(format!("next_start({lo}) = {start} skipped an earlier start"));
            }
            Ok(())
        });
    }

    #[test]
    fn prop_leap_cap_never_skips_a_slowdown_edge() {
        // The leap-exactness guarantee: whenever the schedule allows a
        // leap of `k` cycles, no slowdown window opens strictly inside
        // the leapt span; `None` only ever means a window is open now.
        check(cfg(), &CampaignGen, |c: &CampaignCase| {
            let spec = c.spec();
            let st = FaultState::build(&spec, &c.bases()).map_err(|e| e.to_string())?;
            for probe in [0u64, 17, 63, 200, 999] {
                match st.fabric_leap_cap(probe) {
                    None => {
                        if !(0..c.tenants).any(|t| st.lp_slow_active(t, probe)) {
                            return Err(format!("cap = None at {probe} with no open window"));
                        }
                    }
                    Some(cap) => {
                        let hi = probe + cap.min(4096);
                        for cy in probe..hi {
                            if (0..c.tenants).any(|t| st.lp_slow_active(t, cy)) {
                                return Err(format!(
                                    "leap from {probe} (cap {cap}) skips a slowdown edge at {cy}"
                                ));
                            }
                        }
                    }
                }
            }
            Ok(())
        });
    }
}

/// Overload-robustness properties (PR 10 satellite): the retry-backoff
/// schedule must be a pure function of `(spec, tenant)` — same seed,
/// same table, independent of the arrival stream and of every queue
/// knob — and a serving run advanced by `next_event` leaps must be
/// bit-identical to the same run advanced cycle by cycle, deadlines,
/// sheds, retries and all, with no leap ever capped at zero.
#[cfg(test)]
mod serving_overload_props {
    use super::{check, Config, Gen};
    use crate::serving::{OverloadPolicy, ServingRun, ServingSpec, ServingState};
    use crate::sim::stats::Stats;
    use crate::util::Prng;

    #[derive(Clone, Debug)]
    struct SchedCase {
        seed: u64,
        tenants: usize,
        requests: usize,
        mean_gap: u64,
        retries: usize,
        backoff: u64,
    }

    struct SchedGen;

    impl Gen<SchedCase> for SchedGen {
        fn generate(&self, rng: &mut Prng) -> SchedCase {
            SchedCase {
                seed: rng.next_u64(),
                tenants: rng.range(1, 4),
                requests: rng.range(1, 24),
                mean_gap: rng.range(1, 2000) as u64,
                retries: rng.range(1, 3),
                backoff: rng.range(1, 64) as u64,
            }
        }

        fn shrink(&self, c: &SchedCase) -> Vec<SchedCase> {
            let mut out = Vec::new();
            if c.requests > 1 {
                out.push(SchedCase { requests: c.requests / 2, ..c.clone() });
            }
            if c.tenants > 1 {
                out.push(SchedCase { tenants: 1, ..c.clone() });
            }
            if c.retries > 1 {
                out.push(SchedCase { retries: 1, ..c.clone() });
            }
            out
        }
    }

    #[test]
    fn prop_backoff_schedule_is_a_pure_function_of_spec_and_tenant() {
        check(Config { cases: 48, ..Config::default() }, &SchedGen, |c: &SchedCase| {
            let spec = ServingSpec {
                seed: c.seed,
                requests: c.requests,
                mean_gap: c.mean_gap,
                max_batch: 1,
                retries: c.retries,
                backoff: c.backoff,
                ..ServingSpec::default()
            };
            let a = ServingState::build(&spec, c.tenants).map_err(|e| e.to_string())?;
            let b = ServingState::build(&spec, c.tenants).map_err(|e| e.to_string())?;
            if a != b {
                return Err("same spec built different schedules".into());
            }
            // The backoff stream is independent of the arrival stream:
            // turning retries off must not move a single arrival.
            let bare = ServingState::build(
                &ServingSpec { retries: 0, backoff: 0, ..spec.clone() },
                c.tenants,
            )
            .map_err(|e| e.to_string())?;
            if bare.arrivals != a.arrivals {
                return Err("retry knobs moved the arrival stream".into());
            }
            // ... and of every queue knob: bounding the queue, flipping
            // the policy, or arming deadlines re-draws nothing.
            let knobbed = ServingState::build(
                &ServingSpec {
                    queue_cap: 2,
                    overload: OverloadPolicy::DropOldest,
                    deadline: 500,
                    ..spec.clone()
                },
                c.tenants,
            )
            .map_err(|e| e.to_string())?;
            if knobbed.arrivals != a.arrivals || knobbed.backoffs != a.backoffs {
                return Err("queue knobs perturbed the pre-drawn schedules".into());
            }
            for (t, draws) in a.backoffs.iter().enumerate() {
                if draws.len() != c.requests * c.retries {
                    return Err(format!("tenant {t}: {} draws, want request-major stride", draws.len()));
                }
                for (i, &d) in draws.iter().enumerate() {
                    let k = (i % c.retries) as u32;
                    let base = c.backoff << k;
                    if d < base || d >= base + c.backoff {
                        return Err(format!(
                            "tenant {t} draw {i}: {d} outside [{base}, {})",
                            base + c.backoff
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// A single-tenant serving run with a fixed pass latency and a
    /// prescribed number of fail-fast batches (the degrade hand-off,
    /// minus the fabric).
    #[derive(Clone, Debug)]
    struct OverloadCase {
        seed: u64,
        arrivals: Vec<u64>,
        max_batch: usize,
        max_wait: u64,
        queue_cap: usize,
        drop_oldest: bool,
        deadline: u64,
        retries: usize,
        backoff: u64,
        pass_cycles: u64,
        fail_first: usize,
    }

    impl OverloadCase {
        fn spec(&self) -> ServingSpec {
            ServingSpec {
                seed: self.seed,
                arrivals: self.arrivals.clone(),
                max_batch: self.max_batch,
                max_wait: self.max_wait,
                queue_cap: self.queue_cap,
                overload: if self.drop_oldest && self.queue_cap > 0 {
                    OverloadPolicy::DropOldest
                } else {
                    OverloadPolicy::Reject
                },
                deadline: self.deadline,
                retries: self.retries,
                backoff: if self.retries > 0 { self.backoff } else { 0 },
                ..ServingSpec::default()
            }
        }
    }

    struct OverloadGen;

    impl Gen<OverloadCase> for OverloadGen {
        fn generate(&self, rng: &mut Prng) -> OverloadCase {
            let n = rng.range(1, 8);
            OverloadCase {
                seed: rng.next_u64(),
                arrivals: (0..n).map(|_| rng.range(1, 1500) as u64).collect(),
                max_batch: rng.range(1, 4),
                max_wait: rng.range(1, 200) as u64,
                queue_cap: rng.range(0, 3),
                drop_oldest: rng.chance(0.5),
                deadline: if rng.chance(0.5) { rng.range(50, 800) as u64 } else { 0 },
                retries: rng.range(0, 2),
                backoff: rng.range(1, 64) as u64,
                pass_cycles: rng.range(5, 300) as u64,
                fail_first: rng.range(0, 2),
            }
        }

        fn shrink(&self, c: &OverloadCase) -> Vec<OverloadCase> {
            let mut out = Vec::new();
            if c.arrivals.len() > 1 {
                out.push(OverloadCase { arrivals: c.arrivals[1..].to_vec(), ..c.clone() });
            }
            if c.fail_first > 0 {
                out.push(OverloadCase { fail_first: 0, ..c.clone() });
            }
            if c.deadline > 0 {
                out.push(OverloadCase { deadline: 0, ..c.clone() });
            }
            if c.queue_cap > 0 {
                out.push(OverloadCase { queue_cap: 0, drop_oldest: false, ..c.clone() });
            }
            out
        }
    }

    /// Drive the serving front-end of one tenant to completion, either
    /// cycle by cycle (`leap = false`) or jumping straight between
    /// `next_event` edges and pass completions (`leap = true`). The two
    /// trajectories must be indistinguishable in every observable.
    #[allow(clippy::type_complexity)]
    fn drive(c: &OverloadCase, leap: bool) -> Result<(u64, Vec<u64>, [usize; 6]), String> {
        let state = ServingState::build(&c.spec(), 1).map_err(|e| e.to_string())?;
        let mut run = ServingRun::new(state);
        let mut stats = Stats::new();
        let mut now = 0u64;
        let mut busy_until: Option<u64> = None;
        let mut failed_batches = 0usize;
        for _guard in 0..2_000_000 {
            if busy_until == Some(now) {
                if failed_batches < c.fail_first {
                    failed_batches += 1;
                    run.fail_batch(0, now, &mut stats);
                } else {
                    run.complete(0, now, &mut stats);
                }
                busy_until = None;
            }
            run.admit(now, &mut stats);
            run.expire(now, &mut stats);
            if busy_until.is_none() && run.dispatch(0, now, &mut stats).is_some() {
                busy_until = Some(now + c.pass_cycles);
            }
            if busy_until.is_none() && !run.has_more(0) {
                return Ok((
                    now,
                    run.latencies[0].clone(),
                    [
                        run.completed[0],
                        run.batches[0],
                        run.shed[0],
                        run.timed_out[0],
                        run.retried[0],
                        run.failed[0],
                    ],
                ));
            }
            now = if leap {
                let parked = [busy_until.is_none()];
                let ne = run.next_event(&parked);
                let target = busy_until.map_or(ne, |b| b.min(ne));
                if target == u64::MAX {
                    return Err(format!("live run with no next event at {now}"));
                }
                if target <= now {
                    return Err(format!("leap capped at zero: next event {target} at {now}"));
                }
                target
            } else {
                now + 1
            };
        }
        Err("run did not converge".into())
    }

    #[test]
    fn prop_leaping_between_next_events_matches_stepwise_serving() {
        check(Config { cases: 48, ..Config::default() }, &OverloadGen, |c: &OverloadCase| {
            let stepped = drive(c, false)?;
            let leaped = drive(c, true)?;
            if stepped != leaped {
                return Err(format!("leap diverged: stepwise {stepped:?} vs leap {leaped:?}"));
            }
            let (_, _, counters) = stepped;
            let resolved = counters[0] + counters[2] + counters[3] + counters[5];
            if resolved != c.arrivals.len() {
                return Err(format!(
                    "{} arrivals but {resolved} resolved (completed+shed+timed_out+failed)",
                    c.arrivals.len()
                ));
            }
            Ok(())
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let cfg = Config { cases: 50, seed: 1, max_shrink_steps: 10 };
        check(cfg, &IntRange { lo: 0, hi: 100 }, |&v| {
            if v <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    fn failing_property_shrinks_to_minimum() {
        let cfg = Config { cases: 200, seed: 2, max_shrink_steps: 200 };
        let result = std::panic::catch_unwind(|| {
            check(cfg, &IntRange { lo: 0, hi: 1000 }, |&v| {
                if v < 50 {
                    Ok(())
                } else {
                    Err(format!("{v} >= 50"))
                }
            });
        });
        let msg = *result.unwrap_err().downcast::<String>().unwrap();
        // Greedy shrink should land exactly on the boundary.
        assert!(msg.contains("shrunk"), "{msg}");
        assert!(msg.contains(": 50"), "should shrink to 50, got: {msg}");
    }

    #[test]
    fn vec_generator_shrinks_toward_empty() {
        let g = VecOf { elem: IntRange { lo: 0, hi: 9 }, max_len: 8 };
        let mut rng = Prng::new(3);
        let v = g.generate(&mut rng);
        assert!(v.len() <= 8);
        if !v.is_empty() {
            let shrunk = g.shrink(&v);
            assert!(shrunk.iter().any(|s| s.len() < v.len()));
        }
    }

    #[test]
    fn pair_generator_shrinks_components() {
        let g = Pair(IntRange { lo: 0, hi: 10 }, IntRange { lo: 5, hi: 9 });
        let cands = g.shrink(&(10, 9));
        assert!(cands.contains(&(0, 9)));
        assert!(cands.contains(&(10, 5)));
    }
}
