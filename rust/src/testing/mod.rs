//! Minimal property-based testing harness.
//!
//! The offline registry has no `proptest`/`quickcheck`, so invariant
//! tests use this small in-repo harness: seeded random case generation
//! with greedy shrinking on failure. It is intentionally tiny — enough to
//! express "for all geometries and traffic patterns, transposition
//! preserves data" style properties with reproducible failures.

pub mod prop;

pub use prop::{check, Config, Gen};
