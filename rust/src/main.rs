//! `medusa` — leader binary: evaluation harness, simulation launcher,
//! and design-space tools for the Medusa interconnect reproduction.
//!
//! Subcommands:
//!   eval <table1|table2|fig6|scenarios|all>  regenerate the evaluation
//!   infer [--design D] [...]        run tiny-VGG inference through the
//!                                   simulated system (golden or PJRT)
//!   run --scenario FILE [...]       run a workload scenario (TOML or a
//!                                   built-in name), optionally capturing
//!                                   a canonical trace
//!   replay FILE                     replay a canonical trace and check
//!                                   it against its recorded stats
//!   serve [--scenario S] [...]      open-loop inference serving: arrivals,
//!                                   dynamic batching, SLO latency report
//!   resources [--design D] [...]    resource report for a design point
//!   freq [--design D] [...]         P&R frequency for a design point
//!   sweep                           Fig 6 sweep as CSV
//!   explore [...]                   design-space Pareto search over the
//!                                   hybrid interconnect family
//!   info                            environment / artifact status

use anyhow::{bail, Result};
use medusa::accel::dnn::Network;
use medusa::accel::quant::Fixed16;
use medusa::cli::Args;
use medusa::config::{EdgeMode, PayloadMode, SimBackend, SystemConfig};
use medusa::coordinator::{ComputeBackend, InferenceDriver};
use medusa::eval;
use medusa::fpga::timing::peak_frequency;
use medusa::fpga::{DesignPoint, Device};
use medusa::interconnect::Design;
use medusa::runtime::{Artifacts, ConvExecutor};
use medusa::types::Geometry;
use medusa::util::logging;

fn main() {
    logging::init(None);
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&argv) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run(argv: &[String]) -> Result<()> {
    let Some(cmd) = argv.first() else {
        print_usage();
        return Ok(());
    };
    let rest = &argv[1..];
    match cmd.as_str() {
        "eval" => cmd_eval(rest),
        "infer" => cmd_infer(rest),
        "run" => cmd_run(rest),
        "replay" => cmd_replay(rest),
        "serve" => cmd_serve(rest),
        "resources" => cmd_resources(rest),
        "freq" => cmd_freq(rest),
        "sweep" => cmd_sweep(rest),
        "explore" => cmd_explore(rest),
        "profile" => cmd_profile(rest),
        "info" => cmd_info(),
        "help" | "--help" | "-h" => {
            print_usage();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (try `medusa help`)"),
    }
}

fn print_usage() {
    println!(
        "medusa — transposition-based memory interconnect reproduction\n\n\
         usage: medusa <subcommand> [options]\n\n\
         subcommands:\n\
         \x20 eval <table1|table2|fig6|scenarios|all>  regenerate the paper's evaluation\n\
         \x20 infer [options]                 tiny-VGG inference through the simulator\n\
         \x20 run --scenario FILE [options]   run a workload scenario (file or built-in name)\n\
         \x20 replay FILE                     replay + verify a canonical scenario trace\n\
         \x20 serve [options]                 open-loop serving: arrivals, batching, SLO report\n\
         \x20 resources [options]             resource report for one design point\n\
         \x20 freq [options]                  P&R peak frequency for one design point\n\
         \x20 sweep                           Fig 6 sweep as CSV\n\
         \x20 explore [options]               Pareto search over the hybrid design space\n\
         \x20 profile FILE                    pretty-print a --profile observability report\n\
         \x20 info                            environment / artifacts status\n"
    );
}

fn design_opt(args: &Args) -> Result<Design> {
    let s = args.get_or("design", "medusa");
    Design::parse(s).ok_or_else(|| anyhow::anyhow!("unknown design {s:?}"))
}

/// Resolve `--payload` / `--edges` into a backend, over a default.
fn backend_opts(args: &Args, default: SimBackend) -> Result<SimBackend> {
    let mut b = default;
    if let Some(p) = args.get("payload") {
        b.payload = PayloadMode::parse(p)
            .ok_or_else(|| anyhow::anyhow!("--payload must be full|elided, got {p:?}"))?;
    }
    if let Some(e) = args.get("edges") {
        b.edges = EdgeMode::parse(e)
            .ok_or_else(|| anyhow::anyhow!("--edges must be stepwise|leap, got {e:?}"))?;
    }
    Ok(b)
}

/// Resolve `--profile` / `--profile-window` into run options: the
/// report path (when profiling was requested) plus a [`RunOptions`]
/// with the profiling knob set accordingly.
fn profile_opts(args: &Args) -> Result<(Option<&str>, medusa::run::RunOptions)> {
    let path = args.get("profile");
    let window = args
        .get_usize("profile-window")?
        .map(|w| w as u64)
        .unwrap_or(medusa::obs::DEFAULT_WINDOW);
    let mut opts = medusa::run::RunOptions::new();
    if path.is_some() {
        opts = opts.profile(window);
    }
    Ok((path, opts))
}

/// Persist a profiled outcome's observability report as JSON (no-op
/// when `--profile` was not given).
fn write_profile(
    out: &medusa::workload::ScenarioOutcome,
    path: Option<&str>,
    backend: SimBackend,
) -> Result<()> {
    let Some(path) = path else { return Ok(()) };
    let p = out.profile.as_ref().expect("profiling enabled when --profile is given");
    let label = format!("{}+{}", backend.payload.name(), backend.edges.name());
    std::fs::write(
        path,
        medusa::obs::report::run_profile_json(p, &out.scenario, out.design, &label),
    )?;
    println!("wrote profile -> {path}");
    Ok(())
}

/// Hybrid/hierarchical specs carry parameters that only make sense on a
/// geometry; check them before handing the pair to any model.
fn check_design(design: Design, g: &Geometry) -> Result<()> {
    if let Design::Hybrid(hc) = design {
        hc.validate(g)?;
    }
    if let Design::Hierarchical(hc) = design {
        hc.validate(g)?;
    }
    Ok(())
}

fn geometry_opts(args: &Args) -> Result<Geometry> {
    let mut g = Geometry::paper_default();
    if let Some(v) = args.get_usize("w-line")? {
        g.w_line = v;
    }
    if let Some(v) = args.get_usize("ports")? {
        g.read_ports = v;
        g.write_ports = v;
    }
    if let Some(v) = args.get_usize("max-burst")? {
        g.max_burst = v;
    }
    g.validate()?;
    Ok(g)
}

fn cmd_eval(rest: &[String]) -> Result<()> {
    let which = rest.first().map(|s| s.as_str()).unwrap_or("all");
    match which {
        "table1" => print!("{}", eval::table1().to_text()),
        "table2" => {
            print!("{}", eval::table2().to_text());
            let h = eval::table2::headline();
            println!(
                "headline: {:.2}x LUT and {:.2}x FF savings on the combined networks \
                 (paper: 4.73x / 6.02x), +{} BRAM-18K",
                h.lut_factor, h.ff_factor, h.medusa_extra_bram
            );
        }
        "fig6" => {
            print!("{}", eval::fig6().to_text());
            println!();
            print!("{}", eval::fig6::ascii_plot());
        }
        "scenarios" => print!("{}", eval::scenarios()?.to_text()),
        "all" => {
            for t in ["table1", "table2", "fig6", "scenarios"] {
                cmd_eval(&[t.to_string()])?;
                println!();
            }
        }
        other => bail!("unknown eval target {other:?}"),
    }
    Ok(())
}

fn cmd_infer(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("design", "baseline | medusa | axis | hybrid:* | hierarchical:*")
        .opt("backend", "golden | pjrt")
        .opt("fabric-mhz", "pin the fabric clock (default: P&R model)")
        .opt("dpus", "dot-product units (default 64)")
        .opt("seed", "workload seed")
        .flag("ddr3", "use detailed DDR3 timing (default ideal)")
        .parse(rest)?;
    let mut cfg = SystemConfig::paper_default();
    cfg.design = design_opt(&args)?;
    cfg.ddr3_timing = args.has_flag("ddr3");
    if let Some(v) = args.get_f64("fabric-mhz")? {
        cfg.fabric_clock_mhz = Some(v);
    }
    if let Some(v) = args.get_usize("dpus")? {
        cfg.dotprod_units = v;
    }
    if let Some(v) = args.get_usize("seed")? {
        cfg.seed = v as u64;
    }
    let backend = match args.get_or("backend", "golden") {
        "golden" => ComputeBackend::Golden,
        "pjrt" => ComputeBackend::Pjrt(Box::new(ConvExecutor::new()?)),
        other => bail!("unknown backend {other:?}"),
    };
    let net = Network::tiny_vgg();
    let input: Vec<Fixed16> = {
        let mut p = medusa::util::Prng::new(cfg.seed ^ 0xda7a);
        (0..net.layers[0].ifmap_words())
            .map(|_| Fixed16::from_f32((p.f64() as f32) * 2.0 - 1.0))
            .collect()
    };
    let mut drv = InferenceDriver::new(cfg, backend)?;
    let (report, fm) = drv.run(&net, &input)?;
    println!("{report}");
    println!(
        "final feature map: {} values, checksum {:#018x}",
        fm.len(),
        fm.iter().fold(0xcbf29ce484222325u64, |h, v| {
            (h ^ (v.0 as u16 as u64)).wrapping_mul(0x100000001b3)
        })
    );
    anyhow::ensure!(report.all_verified(), "verification FAILED");
    println!("all layers verified ✓");
    Ok(())
}

fn cmd_run(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("scenario", "scenario TOML file or a built-in name")
        .opt("design", "override the scenario's design (baseline | medusa | axis | hybrid:* | hierarchical:*)")
        .opt("capture", "write the run's canonical trace to this file")
        .opt("seed", "override the system seed (re-derives tenant workload seeds)")
        .opt("payload", "full | elided — elided skips payload, stats stay exact (no data checks)")
        .opt("edges", "stepwise | leap — leap skips globally idle clock edges, exactly")
        .opt(
            "faults",
            "fault campaign: dram_refresh=P/L,cdc=P/L,slow=P/L,corrupt=N,wedge=T@C,\
             watchdog=N,seed=N,policy=error|degrade (overrides the scenario's [faults])",
        )
        .opt("fault-seed", "override the fault campaign seed (keeps the rest of the spec)")
        .opt("profile", "write the observability report (cycle attribution, leap telemetry, utilization) as JSON to this path")
        .opt("profile-window", "utilization sampling window in fabric cycles (default 4096)")
        .parse(rest)?;
    let which = args
        .get("scenario")
        .ok_or_else(|| anyhow::anyhow!("run needs --scenario <file|builtin>\n{}", args.usage()))?;
    let mut sc = match medusa::workload::Scenario::builtin(which) {
        Some(sc) => sc,
        None => medusa::workload::Scenario::from_file(which)?,
    };
    if let Some(d) = args.get("design") {
        sc.cfg.design =
            Design::parse(d).ok_or_else(|| anyhow::anyhow!("unknown design {d:?}"))?;
    }
    if let Some(s) = args.get_usize("seed")? {
        sc.reseed(s as u64);
    }
    if let Some(spec) = args.get("faults") {
        sc.faults = medusa::fault::FaultSpec::parse_cli(spec)?;
    }
    if let Some(s) = args.get_usize("fault-seed")? {
        sc.faults.seed = s as u64;
    }
    // Default to whatever the scenario file configured ([sim] section,
    // full/stepwise if absent); CLI flags override it.
    sc.cfg.sim = backend_opts(&args, sc.cfg.sim)?;
    if sc.cfg.sim.payload.is_elided() {
        println!("payload elided: stats/cycles exact, golden data checks skipped");
    }
    let capture = args.get("capture");
    let (profile_path, opts) = profile_opts(&args)?;
    let (outcome, trace) = if capture.is_some() {
        let (o, t) = opts.run_captured(&sc)?;
        (o, Some(t))
    } else {
        (opts.run(&sc)?, None)
    };
    write_profile(&outcome, profile_path, sc.cfg.sim)?;
    println!(
        "scenario {} on {} @ {:.0} MHz fabric: {} tenants, {} fabric cycles, {:.3} ms simulated",
        outcome.scenario,
        outcome.design,
        outcome.fabric_mhz,
        outcome.tenants.len(),
        outcome.fabric_cycles,
        outcome.now_ps as f64 / 1e9,
    );
    for (i, t) in outcome.tenants.iter().enumerate() {
        println!("--- tenant {i} ({}) ---", t.network);
        print!("{}", t.report);
        let waits: u64 = t.read_waits.iter().chain(t.write_waits.iter()).sum();
        println!("port wait cycles (total): {waits}");
    }
    println!("stats:\n{}", outcome.stats);
    // Verify BEFORE persisting the trace: a failed run must never be
    // laundered into a replayable "golden" whose expect block records
    // the broken counters as ground truth. Under the degrade fault
    // policy a quiesced tenant is unverified by construction, so the
    // run reports degraded completion instead of failing outright.
    let degrade = sc.faults.policy == medusa::fault::FaultPolicy::Degrade && !sc.faults.is_none();
    if !outcome.all_verified() && degrade {
        let unverified: Vec<usize> = outcome
            .tenants
            .iter()
            .enumerate()
            .filter(|(_, t)| !t.verified)
            .map(|(i, _)| i)
            .collect();
        println!(
            "run completed DEGRADED: tenant(s) {unverified:?} quiesced/unverified \
             (fingerprint {:#018x}); no trace written",
            outcome.fingerprint()
        );
        return Ok(());
    }
    anyhow::ensure!(outcome.all_verified(), "verification FAILED (no trace written)");
    if let (Some(path), Some(trace)) = (capture, trace) {
        trace.save(path)?;
        println!("captured trace -> {path}");
    }
    println!("all tenants verified ✓ (fingerprint {:#018x})", outcome.fingerprint());
    Ok(())
}

fn cmd_replay(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("payload", "full | elided — replay with payload shadows (stats still verified)")
        .opt("edges", "stepwise | leap — skip globally idle clock edges, exactly")
        .opt("profile", "write the observability report as JSON to this path")
        .opt("profile-window", "utilization sampling window in fabric cycles (default 4096)")
        .parse(rest)?;
    let [path] = args.positional() else {
        bail!("replay needs exactly one trace file argument");
    };
    let backend = backend_opts(&args, SimBackend::full())?;
    let trace = medusa::sim::trace::ScenarioTrace::from_file(path)?;
    let (profile_path, opts) = profile_opts(&args)?;
    let out = opts.backend(backend).verify_replay(&trace)?;
    write_profile(&out, profile_path, backend)?;
    println!(
        "replayed {} ({} steps, {} tenants) on {}: {} fabric cycles",
        trace.header.scenario,
        trace.steps.len(),
        trace.header.tenants.len(),
        trace.header.design,
        out.fabric_cycles
    );
    if trace.expect.timing_recorded {
        println!("exact + timing expectations reproduced ✓");
    } else {
        println!(
            "exact (data-movement) expectations reproduced ✓ \
             (trace has no recorded timing; re-capture to lock cycles)"
        );
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("scenario", "scenario TOML file or built-in name (default serving-poisson)")
        .opt("design", "override the scenario's design (baseline | medusa | axis | hybrid:* | hierarchical:*)")
        .opt(
            "serving",
            "serving spec: requests=N,mean_gap=N,max_batch=N,max_wait=N,slo=N,seed=N,\
             arrivals=C+C+...,queue_cap=N,overload=reject|drop-oldest,deadline=N,\
             retries=K,backoff=N (overrides the scenario's [serving])",
        )
        .opt("seed", "override the system seed (re-derives tenant workload seeds)")
        .opt("faults", "fault campaign (same syntax as `medusa run --faults`)")
        .opt("payload", "full | elided — elided skips payload, stats stay exact")
        .opt("edges", "stepwise | leap — leap skips idle inter-arrival gaps, exactly")
        .opt("json", "write the serving report as JSON to this path")
        .opt("profile", "write the observability report as JSON to this path")
        .opt("profile-window", "utilization sampling window in fabric cycles (default 4096)")
        .flag("smoke", "CI smoke: oversubscribed serving-overload builtin on the fast backend")
        .parse(rest)?;
    let smoke = args.has_flag("smoke");
    let which = args.get_or("scenario", if smoke { "serving-overload" } else { "serving-poisson" });
    let mut sc = match medusa::workload::Scenario::builtin(which) {
        Some(sc) => sc,
        None => medusa::workload::Scenario::from_file(which)?,
    };
    if let Some(d) = args.get("design") {
        sc.cfg.design =
            Design::parse(d).ok_or_else(|| anyhow::anyhow!("unknown design {d:?}"))?;
    }
    if let Some(s) = args.get_usize("seed")? {
        sc.reseed(s as u64);
    }
    if let Some(spec) = args.get("faults") {
        sc.faults = medusa::fault::FaultSpec::parse_cli(spec)?;
    }
    if let Some(spec) = args.get("serving") {
        sc.serving = medusa::serving::ServingSpec::parse_cli(spec)?;
    } else if smoke {
        // Oversubscribed smoke spec. The 12-cycle burst overruns the
        // 3-deep queue (drop-oldest sheds the overflow on admission,
        // whatever the design's pass latency), and the lone straggler
        // at cycle 50000 can never dispatch: solo, it only fires on
        // max_wait (5000 cycles) but its deadline (1000 cycles)
        // expires first. Nonzero shed + timed-out on every design.
        sc.serving = medusa::serving::ServingSpec::parse_cli(
            "arrivals=100+101+102+103+104+105+106+107+108+109+110+111+50000,\
             max_batch=2,max_wait=5000,slo=150000,seed=5,queue_cap=3,\
             overload=drop-oldest,deadline=1000,retries=1,backoff=500",
        )?;
    }
    anyhow::ensure!(
        !sc.serving.is_none(),
        "scenario {:?} has no [serving] section; pass --serving=requests=N,mean_gap=N,...",
        sc.name
    );
    let default_backend = if args.has_flag("smoke") { SimBackend::fast() } else { sc.cfg.sim };
    let backend = backend_opts(&args, default_backend)?;
    let (profile_path, opts) = profile_opts(&args)?;
    let out = opts.backend(backend).run(&sc)?;
    write_profile(&out, profile_path, backend)?;
    let report = out.serving.as_ref().expect("serving scenario must yield a serving report");
    println!(
        "served {} on {} @ {:.0} MHz fabric: {} fabric cycles, {:.3} ms simulated",
        out.scenario,
        out.design,
        out.fabric_mhz,
        out.fabric_cycles,
        out.now_ps as f64 / 1e9,
    );
    for (i, t) in report.tenants.iter().enumerate() {
        println!(
            "  tenant {i}: {} arrived, {} completed in {} batches | latency p50 {} p99 {} \
             max {} cycles | SLO met {}/{} | shed {} timed_out {} retried {} failed {} | \
             goodput {:.1} req/s{}",
            t.arrived,
            t.completed,
            t.batches,
            t.p50_cycles,
            t.p99_cycles,
            t.max_cycles,
            t.slo_met,
            t.completed,
            t.shed,
            t.timed_out,
            t.retried,
            t.failed,
            t.goodput_rps(out.now_ps),
            if t.starved { " | STARVED" } else { "" },
        );
    }
    if let Some(path) = args.get("json") {
        let mut s = String::from("{\n");
        s.push_str(&format!(
            "  \"scenario\": \"{}\",\n  \"design\": \"{}\",\n  \"fabric_cycles\": {},\n  \
             \"sim_ps\": {},\n  \"fingerprint\": \"{:#018x}\",\n  \"tenants\": [\n",
            out.scenario,
            out.design,
            out.fabric_cycles,
            out.now_ps,
            out.fingerprint()
        ));
        for (i, t) in report.tenants.iter().enumerate() {
            s.push_str(&format!(
                "    {{\"tenant\": {i}, \"arrived\": {}, \"completed\": {}, \"batches\": {}, \
                 \"slo_met\": {}, \"starved\": {}, \"shed\": {}, \"timed_out\": {}, \
                 \"retried\": {}, \"failed\": {}, \"p50_cycles\": {}, \"p99_cycles\": {}, \
                 \"max_cycles\": {}, \"goodput_rps\": {:.3}}}{}\n",
                t.arrived,
                t.completed,
                t.batches,
                t.slo_met,
                t.starved,
                t.shed,
                t.timed_out,
                t.retried,
                t.failed,
                t.p50_cycles,
                t.p99_cycles,
                t.max_cycles,
                t.goodput_rps(out.now_ps),
                if i + 1 == report.tenants.len() { "" } else { "," },
            ));
        }
        s.push_str("  ]\n}\n");
        std::fs::write(path, s)?;
        println!("wrote {path}");
    }
    anyhow::ensure!(out.all_verified(), "verification FAILED");
    println!("all tenants verified ✓ (fingerprint {:#018x})", out.fingerprint());
    Ok(())
}

fn cmd_resources(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("design", "baseline | medusa | axis | hybrid:* | hierarchical:*")
        .opt("w-line", "memory interface width bits")
        .opt("ports", "read (=write) port count")
        .opt("max-burst", "max burst in lines")
        .opt("dpus", "dot-product units")
        .parse(rest)?;
    let design = design_opt(&args)?;
    let g = geometry_opts(&args)?;
    check_design(design, &g)?;
    let dpus = args.get_usize("dpus")?.unwrap_or(64);
    let dev = Device::virtex7_690t();
    let dp = DesignPoint { design, geometry: g, dpus };
    let r = dp.resources();
    println!(
        "design point: {} | {}b iface | {}r+{}w ports | {} DPUs ({} DSPs)",
        design.name(),
        g.w_line,
        g.read_ports,
        g.write_ports,
        dpus,
        dp.dsps()
    );
    println!("  {r}");
    println!(
        "  utilization: LUT {:.1}%  FF {:.1}%  BRAM {:.1}%  DSP {:.1}%",
        dev.pct_lut(r.lut),
        dev.pct_ff(r.ff),
        dev.pct_bram(r.bram18),
        dev.pct_dsp(r.dsp)
    );
    Ok(())
}

fn cmd_freq(rest: &[String]) -> Result<()> {
    let args = Args::default()
        .opt("design", "baseline | medusa | axis | hybrid:* | hierarchical:*")
        .opt("w-line", "memory interface width bits")
        .opt("ports", "read (=write) port count")
        .opt("max-burst", "max burst in lines")
        .opt("dpus", "dot-product units")
        .parse(rest)?;
    let design = design_opt(&args)?;
    let g = geometry_opts(&args)?;
    check_design(design, &g)?;
    let dpus = args.get_usize("dpus")?.unwrap_or(64);
    let dp = DesignPoint { design, geometry: g, dpus };
    let f = peak_frequency(&dp);
    if f == 0 {
        println!("{}: FAILS timing at 25 MHz", design.name());
    } else {
        println!("{}: {f} MHz peak (25 MHz search grid)", design.name());
    }
    Ok(())
}

fn cmd_sweep(_rest: &[String]) -> Result<()> {
    print!("{}", eval::fig6().to_csv());
    Ok(())
}

fn cmd_profile(rest: &[String]) -> Result<()> {
    let args = Args::default().parse(rest)?;
    let [path] = args.positional() else {
        bail!("profile needs exactly one report file argument (a --profile output)");
    };
    let text = std::fs::read_to_string(path)?;
    let rendered = medusa::obs::report::pretty_print(&text)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    print!("{rendered}");
    Ok(())
}

fn cmd_explore(rest: &[String]) -> Result<()> {
    use medusa::explore::{DesignSpace, ExploreCache, Strategy};
    let args = Args::default()
        .opt("strategy", "grid | random | hill (default grid)")
        .opt("samples", "random strategy: points to sample (default 32)")
        .opt("restarts", "hill strategy: independent climbs (default 4)")
        .opt("steps", "hill strategy: max moves per climb (default 8)")
        .opt("seed", "search seed for random/hill (default 1)")
        .opt("probe", "zoo network driven through each point (default gemm-mlp)")
        .opt(
            "serve-probe",
            "attach an open-loop serving front-end to every probe run and measure \
             serving_p99 (same spec syntax as `medusa serve --serving`)",
        )
        .opt("cache", "result cache file (default .medusa-explore.cache)")
        .opt("json", "write BENCH_PR4.json-format results to this path")
        .opt("profile", "write campaign telemetry (per-point eval time, cache hit/miss, host spans) as JSON to this path")
        .opt("payload", "full | elided (default elided — stats-exact fast backend)")
        .opt("edges", "stepwise | leap (default leap)")
        .flag("smoke", "tiny CI grid instead of the default 100+ point grid")
        .flag("no-cache", "evaluate everything fresh, do not read or write the cache")
        .flag("csv", "emit the full evaluated set as CSV instead of tables")
        .parse(rest)?;
    let backend = backend_opts(&args, SimBackend::fast())?;
    let mut space = if args.has_flag("smoke") {
        DesignSpace::smoke()
    } else {
        DesignSpace::default_grid()
    };
    if let Some(p) = args.get("probe") {
        anyhow::ensure!(
            medusa::workload::zoo::by_name(p).is_some(),
            "unknown probe network {p:?} (zoo: {:?})",
            medusa::workload::zoo::names()
        );
        space.probe = p.to_string();
    }
    if let Some(spec) = args.get("serve-probe") {
        space.serving = Some(medusa::serving::ServingSpec::parse_cli(spec)?);
    }
    let seed = args.get_usize("seed")?.unwrap_or(1) as u64;
    let strategy = match args.get_or("strategy", "grid") {
        "grid" => Strategy::Grid,
        "random" => Strategy::Random { samples: args.get_usize("samples")?.unwrap_or(32) },
        "hill" => Strategy::HillClimb {
            restarts: args.get_usize("restarts")?.unwrap_or(4),
            steps: args.get_usize("steps")?.unwrap_or(8),
        },
        other => bail!("unknown strategy {other:?} (grid | random | hill)"),
    };
    let mut cache = if args.has_flag("no-cache") {
        None
    } else {
        Some(ExploreCache::open(args.get_or("cache", ".medusa-explore.cache")))
    };
    let t0 = std::time::Instant::now();
    let result = medusa::run::RunOptions::new()
        .backend(backend)
        .run_search(&space, &strategy, seed, cache.as_mut())?;
    let elapsed = t0.elapsed().as_secs_f64();
    let label = strategy.label();
    // In --csv mode stdout carries ONLY the CSV (the `medusa sweep`
    // contract); the human summary goes to stderr instead.
    let csv = args.has_flag("csv");
    let t_render = std::time::Instant::now();
    if csv {
        print!("{}", medusa::eval::explore::full_table(&result).to_csv());
    } else {
        print!("{}", medusa::eval::explore::full_table(&result).to_text());
        println!();
        print!("{}", medusa::eval::explore::frontier_table(&result).to_text());
    }
    if let Some(path) = args.get("profile") {
        let host = [("search", elapsed), ("render", t_render.elapsed().as_secs_f64())];
        let points: Vec<(String, medusa::obs::PointTiming)> = result
            .evaluated
            .iter()
            .zip(result.timings.iter())
            .map(|((p, _), t)| (p.design.spec(), *t))
            .collect();
        std::fs::write(
            path,
            medusa::obs::report::explore_profile_json(&label, &space.probe, &host, &points),
        )?;
        if csv {
            eprintln!("wrote profile -> {path}");
        } else {
            println!("wrote profile -> {path}");
        }
    }
    let note = |line: String| {
        if csv {
            eprintln!("{line}");
        } else {
            println!("{line}");
        }
    };
    note(format!(
        "{} in {elapsed:.2}s",
        medusa::eval::explore::summary_line(&result, &space, &label)
    ));
    if let Some(c) = &cache {
        note(format!("cache: {} entries at {}", c.len(), c.path().display()));
    }
    if let Some(path) = args.get("json") {
        let extras = [("elapsed_s", format!("{elapsed:.4}"))];
        std::fs::write(path, medusa::eval::explore::bench_json(&result, &space, &label, &extras))?;
        note(format!("wrote {path}"));
    }
    // Every feasible point must have golden-verified its probe run; a
    // silent verification failure would poison the frontier.
    anyhow::ensure!(
        result.evaluated.iter().all(|(_, m)| !m.feasible() || m.verified),
        "some feasible points failed golden verification"
    );
    Ok(())
}

fn cmd_info() -> Result<()> {
    println!("medusa {} — three-layer rust+JAX+Pallas reproduction", env!("CARGO_PKG_VERSION"));
    println!("device model: {:?}", Device::virtex7_690t().name);
    match Artifacts::discover() {
        Ok(a) => {
            println!("artifacts: {} ({} entries)", a.dir.display(), a.names().len());
            for e in a.entries() {
                println!(
                    "  {:<16} {:<9} {}x{}x{} -> {} (k={}, s={}, p={}, relu={})",
                    e.name, e.kind, e.in_c, e.in_h, e.in_w, e.out_c, e.k, e.stride, e.pad, e.relu
                );
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match medusa::runtime::RuntimeClient::cpu() {
        Ok(c) => println!("PJRT: platform {} OK", c.platform()),
        Err(e) => println!("PJRT: unavailable ({e})"),
    }
    Ok(())
}
